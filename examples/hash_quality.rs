//! Hash-quality diagnostics: avalanche matrices and dense-block bin
//! occupancy for every family — a visual companion to §4.1's "why weak
//! hashing fails on structured data".
//!
//! ```bash
//! cargo run --release --example hash_quality
//! ```

use mixtab::hash::{HashFamily, Hasher32};
use mixtab::util::rng::Xoshiro256;

fn avalanche_score(h: &dyn Hasher32, trials: usize) -> f64 {
    let mut rng = Xoshiro256::new(1);
    let mut flips = 0u64;
    for _ in 0..trials {
        let x = rng.next_u32();
        let bit = 1u32 << rng.below(32);
        flips += (h.hash(x) ^ h.hash(x ^ bit)).count_ones() as u64;
    }
    flips as f64 / (trials as f64 * 32.0)
}

/// Variance of bin occupancy (mod 64) for the dense block [0, 2000) over
/// seeds, relative to the binomial reference — the §4.1 mechanism: weak
/// schemes map dense blocks *too evenly* (≪ 1) which biases OPH minima.
fn occupancy_ratio(fam: HashFamily) -> f64 {
    let k = 64usize;
    let mut vars = Vec::new();
    for seed in 0..30u64 {
        let h = fam.build(seed);
        let mut counts = vec![0f64; k];
        for x in 0..2000u32 {
            counts[(h.hash(x) as usize) % k] += 1.0;
        }
        let mean = 2000.0 / k as f64;
        vars.push(counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / k as f64);
    }
    vars.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = vars[vars.len() / 2];
    let binomial = 2000.0 / k as f64 * (1.0 - 1.0 / k as f64);
    median / binomial
}

fn main() {
    println!(
        "{:<20} {:>10} {:>22}",
        "family", "avalanche", "occupancy var ratio"
    );
    println!("{:-<54}", "");
    for &fam in HashFamily::TABLE1 {
        let h = fam.build(42);
        let trials = if fam == HashFamily::Blake2 { 500 } else { 5000 };
        let av = avalanche_score(h.as_ref(), trials);
        let occ = occupancy_ratio(fam);
        println!(
            "{:<20} {:>10.4} {:>22.3}  {}",
            fam.id(),
            av,
            occ,
            if av < 0.45 || !(0.5..2.0).contains(&occ) {
                "← structured"
            } else {
                ""
            }
        );
    }
    println!(
        "\navalanche: 0.5 = ideal bit diffusion; multiply-shift / polyhash are\n\
         *not* designed to avalanche (low values expected).\n\
         occupancy ratio: 1.0 = binomial (truly-random-like) bin counts on a\n\
         dense id block; ≪1 means 'too even' — the §4.1 OPH bias mechanism."
    );
}
