//! Near-duplicate document detection with w-shingles + OPH — the classic
//! MinHash application (Broder '97; Manku et al. WWW'07 cited in §1).
//!
//! ```bash
//! cargo run --release --example dedup
//! ```
//!
//! Builds a small corpus with planted near-duplicates, shingles every
//! document (w = 5 bytes), re-ranks shingle ids by frequency (the
//! small-ids-for-frequent-shingles structure §4.1 argues breaks weak
//! hashing), and finds duplicate clusters through the LSH index.

use mixtab::data::shingle::{byte_shingles, frequency_rank_ids};
use mixtab::hash::HashFamily;
use mixtab::lsh::{LshIndex, LshParams};
use mixtab::sketch::SketchSpec;
use mixtab::sketch::estimators::jaccard_sorted;
use mixtab::util::rng::Xoshiro256;

const TEMPLATES: &[&str] = &[
    "the quick brown fox jumps over the lazy dog while the cat watches quietly from the fence",
    "practical hash functions for similarity estimation and dimensionality reduction in machine learning",
    "one permutation hashing with densification is the fast replacement for classic minwise hashing",
    "locality sensitive hashing retrieves near neighbours in sublinear time given a good sketch",
    "mixed tabulation hashing is almost as fast as multiply shift and provably strong in applications",
];

fn mutate(text: &str, edits: usize, rng: &mut Xoshiro256) -> String {
    let mut words: Vec<String> = text.split_whitespace().map(str::to_string).collect();
    for _ in 0..edits {
        let i = rng.range(0, words.len());
        match rng.below(3) {
            0 => words[i] = format!("{}x", words[i]),          // typo
            1 => words[i] = words[i].to_uppercase(),           // case change
            _ => {
                let j = rng.range(0, words.len());
                words.swap(i, j); // transposition
            }
        }
    }
    words.join(" ")
}

fn main() {
    let mut rng = Xoshiro256::new(2024);

    // Corpus: per template, one original + several light edits (near-dups)
    // + heavy edits (borderline) — plus unrelated noise documents.
    let mut docs: Vec<(String, usize)> = Vec::new(); // (text, template id)
    for (t, tpl) in TEMPLATES.iter().enumerate() {
        docs.push((tpl.to_string(), t));
        for _ in 0..4 {
            docs.push((mutate(tpl, 2, &mut rng), t));
        }
        for _ in 0..2 {
            docs.push((mutate(tpl, 8, &mut rng), t));
        }
    }
    for n in 0..30u64 {
        // Unique random tokens per document so noise docs share no shingles.
        let mut noise_rng = Xoshiro256::new(0xBAD5EED ^ n);
        let words: Vec<String> = (0..14)
            .map(|_| format!("{:012x}", noise_rng.next_u64() & 0xFFFF_FFFF_FFFF))
            .collect();
        docs.push((words.join(" "), usize::MAX));
    }
    println!("corpus: {} documents ({} templates + noise)", docs.len(), TEMPLATES.len());

    // Shingle + frequency-rank the ids (realistic id assignment).
    let shingled: Vec<Vec<u32>> = docs.iter().map(|(d, _)| byte_shingles(d, 5)).collect();
    let ranked = frequency_rank_ids(&shingled);

    // Index every document.
    let mut index = LshIndex::new(
        LshParams::new(6, 12),
        &SketchSpec::oph(HashFamily::MixedTab, 7, 72),
    );
    for (i, s) in ranked.iter().enumerate() {
        index.insert(i as u32, s);
    }

    // Cluster: query each doc, keep candidates verified at J ≥ 0.5.
    let mut reported = std::collections::HashSet::new();
    let mut clusters = 0;
    let mut pairs_found = 0;
    let mut pairs_correct = 0;
    for (i, s) in ranked.iter().enumerate() {
        if reported.contains(&(i as u32)) {
            continue;
        }
        let mut cluster: Vec<u32> = index
            .query(s)
            .into_iter()
            .filter(|&c| c as usize != i)
            .filter(|&c| jaccard_sorted(s, &ranked[c as usize]) >= 0.5)
            .collect();
        if cluster.is_empty() {
            continue;
        }
        cluster.push(i as u32);
        cluster.sort_unstable();
        clusters += 1;
        println!("\ncluster {clusters} (template {}):", docs[i].1);
        for &c in &cluster {
            reported.insert(c);
            let j = jaccard_sorted(s, &ranked[c as usize]);
            println!("  [{c:>3}] J={j:.2} {}", &docs[c as usize].0[..60.min(docs[c as usize].0.len())]);
            if c as usize != i {
                pairs_found += 1;
                if docs[c as usize].1 == docs[i].1 {
                    pairs_correct += 1;
                }
            }
        }
    }
    println!(
        "\nfound {clusters} clusters; {pairs_correct}/{pairs_found} verified links share a template"
    );
    assert!(clusters >= TEMPLATES.len(), "missed planted duplicate clusters");
    assert_eq!(pairs_correct, pairs_found, "false-positive cluster link");
    println!("dedup OK");
}
