//! Approximate near-neighbour search on MNIST-like data — the §4.2
//! scenario as a standalone application.
//!
//! ```bash
//! cargo run --release --example lsh_search [-- --family multiply_shift]
//! ```
//!
//! Builds a (K=10, L=10) LSH index over OPH sketches, runs every query,
//! and reports the Figure 5 metrics (recall@0.5, #retrieved/recall ratio)
//! for the chosen basic hash function. Run once with `mixed_tab` (default)
//! and once with `multiply_shift` to see the paper's contrast live.

use mixtab::data::mnist_like;
use mixtab::hash::HashFamily;
use mixtab::lsh::metrics::{ground_truth_batch, BatchEval, QueryEval};
use mixtab::lsh::{LshIndex, LshParams};
use mixtab::sketch::SketchSpec;
use mixtab::util::threadpool::ThreadPool;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let family = args
        .iter()
        .position(|a| a == "--family")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| HashFamily::parse(s))
        .unwrap_or(HashFamily::MixedTab);

    let (n_db, n_q) = (3000, 300);
    println!("generating MNIST-like data: {n_db} database + {n_q} query points…");
    let (db_ds, q_ds) = mnist_like::default_split(n_db, n_q, 42);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();

    println!("computing ground truth at T0 = 0.5…");
    let pool = ThreadPool::new(mixtab::util::threadpool::default_parallelism());
    let truth = ground_truth_batch(&pool, &db, &queries, 0.5);

    println!("building LSH index (K=10, L=10) with {}…", family.label());
    let t0 = Instant::now();
    let mut index = LshIndex::new(LshParams::new(10, 10), &SketchSpec::oph(family, 7, 100));
    for (i, s) in db.iter().enumerate() {
        index.insert(i as u32, s);
    }
    println!(
        "  built in {:.2?} — {} buckets, max bucket {}",
        t0.elapsed(),
        index.bucket_count(),
        index.max_bucket()
    );

    let t1 = Instant::now();
    let mut batch = BatchEval::default();
    let mut answered = 0;
    for (q, t) in queries.iter().zip(&truth) {
        if t.is_empty() {
            continue;
        }
        answered += 1;
        let retrieved = index.query(q);
        batch.push(QueryEval::evaluate(&retrieved, t, db.len()));
    }
    let q_time = t1.elapsed();

    println!("\n=== results ({}) ===", family.label());
    println!("queries with ≥1 true neighbour : {answered}");
    println!("mean #retrieved per query      : {:.1}", batch.mean_retrieved());
    println!("mean fraction of DB retrieved  : {:.4}", batch.mean_fraction_retrieved());
    println!("mean recall@0.5                : {:.3}", batch.mean_recall());
    println!("#retrieved / recall ratio      : {:.1}  (lower is better)", batch.ratio());
    println!("query throughput               : {:.0}/s", answered as f64 / q_time.as_secs_f64());
    println!("\n(try `--family multiply_shift` to reproduce the paper's Figure 5 contrast)");
}
