//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example fh_service
//! ```
//!
//! Boots the coordinator with the PJRT runtime (Layer-1 Pallas FH kernel,
//! AOT-lowered through Layer-2 JAX, executed from Rust via PJRT), starts
//! the TCP front-end, then drives it with concurrent clients streaming
//! News20-like documents:
//!
//! 1. every document is feature-hashed to d' = 128 through the dynamic
//!    batcher → PJRT executor;
//! 2. norms are validated against the native Rust path (layer agreement);
//! 3. latency/throughput and batcher occupancy are reported — the numbers
//!    recorded in EXPERIMENTS.md §E2E.

use mixtab::coordinator::config::CoordinatorConfig;
use mixtab::coordinator::request::{ExecPath, Request, Response};
use mixtab::coordinator::server::{Client, Server};
use mixtab::coordinator::Coordinator;
use mixtab::data::news20_like::{self, News20LikeParams};
use mixtab::stats::Summary;
use mixtab::{bail, ensure};
use std::sync::Arc;
use std::time::Instant;

fn main() -> mixtab::Result<()> {
    let n_docs = 480;
    let clients = 6;

    println!("=== mixtab end-to-end FH service ===");
    println!("[1/4] generating News20-like corpus ({n_docs} docs)…");
    let ds = news20_like::generate(n_docs, &News20LikeParams::default(), 77);
    println!("      {} docs, avg nnz {:.1}, dim {}", ds.len(), ds.avg_nnz(), ds.dim);

    println!("[2/4] booting coordinator (PJRT + batcher + TCP)…");
    let cfg = CoordinatorConfig {
        fh_dim: 128,
        max_delay_us: 300,
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::new(cfg));
    let pjrt = coordinator.pjrt_enabled();
    println!("      pjrt path: {}", if pjrt { "LIVE (artifacts loaded)" } else { "unavailable — native fallback (run `make artifacts`)" });
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0")?;
    let addr = server.addr();
    println!("      serving on {addr}");

    println!("[3/4] streaming documents from {clients} concurrent clients…");
    let docs: Vec<(Vec<u32>, Vec<f64>)> = ds
        .vectors
        .iter()
        .map(|v| (v.indices.clone(), v.values.clone()))
        .collect();
    let docs = Arc::new(docs);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let docs = Arc::clone(&docs);
            std::thread::spawn(move || -> mixtab::Result<(Summary, usize, usize)> {
                let mut client = Client::connect(addr)?;
                let mut lat = Summary::new();
                let (mut pjrt_rows, mut native_rows) = (0usize, 0usize);
                for (i, (idx, vals)) in docs.iter().enumerate() {
                    if i % clients != c {
                        continue;
                    }
                    let t = Instant::now();
                    let resp = client.call(&Request::FhTransform {
                        indices: idx.clone(),
                        values: vals.clone(),
                    })?;
                    lat.add(t.elapsed().as_micros() as f64);
                    match resp {
                        Response::Fh { out, sqnorm, path } => {
                            ensure!(out.len() == 128, "wrong dim");
                            ensure!(sqnorm.is_finite());
                            match path {
                                ExecPath::Pjrt => pjrt_rows += 1,
                                ExecPath::Native => native_rows += 1,
                            }
                        }
                        other => bail!("unexpected response {other:?}"),
                    }
                }
                Ok((lat, pjrt_rows, native_rows))
            })
        })
        .collect();

    let mut all_lat = Summary::new();
    let (mut total_pjrt, mut total_native) = (0usize, 0usize);
    for h in handles {
        let (lat, p, n) = h.join().expect("client thread")?;
        for &v in lat.values() {
            all_lat.add(v);
        }
        total_pjrt += p;
        total_native += n;
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("[4/4] validating against the native path…");
    // Spot-check 20 docs end-to-end against an offline native transform.
    let fh = coordinator
        .config()
        .fh_spec()
        .build_feature_hasher()
        .expect("fh spec");
    let mut client = Client::connect(addr)?;
    for v in ds.vectors.iter().take(20) {
        let Response::Fh { out, .. } = client.call(&Request::FhTransform {
            indices: v.indices.clone(),
            values: v.values.clone(),
        })?
        else {
            bail!("bad response");
        };
        let native = fh.transform(v);
        for (a, b) in out.iter().zip(&native) {
            ensure!((*a as f64 - b).abs() < 1e-4, "layer disagreement: {a} vs {b}");
        }
    }
    println!("      PJRT ≡ native on 20 spot-checked documents ✓");

    let (p50, p90, p99) = all_lat.latency_quantiles();
    let occupancy = coordinator.metrics.mean_batch_occupancy();
    println!("\n=== results ===");
    println!("documents processed : {}", all_lat.len());
    println!("rows via PJRT       : {total_pjrt}");
    println!("rows via native     : {total_native}");
    println!("throughput          : {:.0} docs/s", all_lat.len() as f64 / wall);
    println!("latency p50/p90/p99 : {p50:.0} / {p90:.0} / {p99:.0} µs");
    println!("mean batch occupancy: {occupancy:.2} rows/batch");
    if pjrt {
        ensure!(total_pjrt > 0, "pjrt path never used despite being live");
    }
    println!("\nfh_service OK");
    server.stop();
    Ok(())
}
