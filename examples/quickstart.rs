//! Quickstart: the public API in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Covers the paper's three primitives — basic hashing, similarity
//! estimation with OPH, and feature hashing — plus a micro LSH index.

use mixtab::hash::HashFamily;
use mixtab::lsh::{LshIndex, LshParams};
use mixtab::sketch::jaccard_exact;
use mixtab::sketch::{SignMode, SketchSpec};

fn main() {
    // 1. Basic hash functions — the paper's variable. Mixed tabulation is
    //    the recommended default: truly-random-like with proven guarantees.
    let h = HashFamily::MixedTab.build(42);
    println!("mixed_tab(1234567) = {:#010x}", h.hash(1_234_567));

    // 2. Similarity estimation with OPH (one hash evaluation per element).
    let a: Vec<u32> = (0..10_000).collect();
    let b: Vec<u32> = (2_500..12_500).collect(); // J = 7500/12500 = 0.6
    // Sketches are configuration: a declarative spec names the scheme,
    // parameters, hash family, and seed, and `build_oph` constructs it.
    // The same string works in `mixtab sketch --spec` and the coordinator's
    // `[sketch]` config section.
    let spec = SketchSpec::parse("oph(k=256,layout=mod,densify=paper,hash=mixed_tab,seed=7)")
        .expect("literal spec");
    let sketcher = spec.build_oph().expect("oph spec");
    let (sa, sb) = (sketcher.sketch(&a), sketcher.sketch(&b));
    println!(
        "OPH estimate = {:.4}   (exact J = {:.4})",
        sketcher.estimate(&sa, &sb),
        jaccard_exact(&a, &b)
    );

    // 3. Feature hashing: 1M-dim sparse vector → 512 dims, norm preserved
    //    (Theorem 1: concentration needs d' ≳ 16·ε⁻²·lg(1/δ)).
    let v = mixtab::data::SparseVector::unit_indicator(
        &(0..1000u32).map(|i| i * 997).collect::<Vec<_>>(),
    );
    let fh = SketchSpec::feature_hash(HashFamily::MixedTab, 3, 512, SignMode::Paired)
        .build_feature_hasher()
        .expect("fh spec");
    let dense = fh.transform(&v);
    let sq: f64 = dense.iter().map(|x| x * x).sum();
    println!("FH: {} nnz -> {} dims, ‖v'‖² = {sq:.4} (target 1.0)", v.nnz(), dense.len());

    // 4. LSH search over OPH sketches.
    let mut index = LshIndex::new(
        LshParams::new(8, 10),
        &SketchSpec::oph(HashFamily::MixedTab, 99, 80),
    );
    for i in 0..100u32 {
        let set: Vec<u32> = (i * 50..i * 50 + 500).collect(); // overlapping blocks
        index.insert(i, &set);
    }
    let query: Vec<u32> = (20 * 50..20 * 50 + 500).collect();
    let hits = index.query(&query);
    println!("LSH query retrieved {} candidates (incl. exact match 20: {})",
        hits.len(), hits.contains(&20));
}
