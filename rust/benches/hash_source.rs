//! Bench target wrapper: the hash-evaluation layer — unrolled mixed-tab
//! kernels vs scalar loops, pooled vs independent hash sources at matched
//! sketch widths. The workload lives in [`mixtab::benchsuite`] so the
//! `mixtab bench` CLI can run it in-process and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::hash_source(&mut bench);
}
