//! Bench: coordinator end-to-end — FH request latency/throughput through
//! the full service (router → batcher → PJRT executor → scatter) under
//! closed-loop concurrent clients, vs the native path. This is the
//! "serving" headline for EXPERIMENTS.md §Perf.

use mixtab::coordinator::config::CoordinatorConfig;
use mixtab::coordinator::request::{ExecPath, Request, Response};
use mixtab::coordinator::Coordinator;
use mixtab::stats::Summary;
use mixtab::util::bench::{fmt_rate, Bench};
use mixtab::util::rng::Xoshiro256;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn workload(n: usize, seed: u64) -> Vec<(Vec<u32>, Vec<f64>)> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let nnz = rng.range(50, 450);
            (
                (0..nnz).map(|_| rng.next_u32() % 1_000_000).collect(),
                (0..nnz).map(|_| rng.next_f64() - 0.5).collect(),
            )
        })
        .collect()
}

fn drive(c: &Arc<Coordinator>, clients: usize, per_client: usize, seed: u64) -> (f64, Summary, u64) {
    let done = Arc::new(AtomicU64::new(0));
    let lat_all = Arc::new(std::sync::Mutex::new(Summary::new()));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|cl| {
            let c = Arc::clone(c);
            let done = Arc::clone(&done);
            let lat_all = Arc::clone(&lat_all);
            std::thread::spawn(move || {
                let work = workload(per_client, seed + cl as u64);
                let mut lat = Summary::new();
                for (idx, vals) in work {
                    let t = Instant::now();
                    let resp = c.handle(Request::FhTransform {
                        indices: idx,
                        values: vals,
                    });
                    lat.add(t.elapsed().as_micros() as f64);
                    if matches!(resp, Response::Fh { .. }) {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
                lat_all.lock().unwrap().values().len(); // touch
                let mut g = lat_all.lock().unwrap();
                for &v in lat.values() {
                    g.add(v);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    let lat = Arc::try_unwrap(lat_all).unwrap().into_inner().unwrap();
    (wall, lat, total)
}

fn main() {
    let bench = Bench::new();
    let (clients, per_client) = if bench.is_quick() { (4, 25) } else { (8, 250) };
    println!("coordinator_service: {clients} closed-loop clients × {per_client} FH requests");

    for (label, enable_pjrt) in [("pjrt+batcher", true), ("native-only", false)] {
        let c = Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt,
            fh_dim: 128,
            max_delay_us: 200,
            ..Default::default()
        }));
        if enable_pjrt && !c.pjrt_enabled() {
            println!("  {label}: pjrt unavailable (run `make artifacts`), skipping");
            continue;
        }
        let (wall, lat, total) = drive(&c, clients, per_client, 99);
        let (p50, p90, p99) = lat.latency_quantiles();
        let snap = c.metrics.snapshot();
        let path_note = match (
            snap.get("fh_pjrt_rows").and_then(|j| j.as_i64()),
            snap.get("fh_native_rows").and_then(|j| j.as_i64()),
        ) {
            (Some(p), Some(n)) => format!("rows pjrt={p} native={n}"),
            _ => String::new(),
        };
        println!(
            "  {label:<14} {} req/s  lat p50={p50:.0}µs p90={p90:.0}µs p99={p99:.0}µs  occupancy={:.2}  {}",
            fmt_rate(total as f64 / wall),
            c.metrics.mean_batch_occupancy(),
            path_note
        );
        // Smoke assertion: everything completed.
        assert_eq!(total as usize, clients * per_client);
        let _ = ExecPath::Pjrt;
    }
}
