//! Bench target wrapper: coordinator end-to-end FH request throughput under
//! closed-loop concurrent clients. The workload lives in
//! [`mixtab::benchsuite`] so the `mixtab bench` CLI can run it in-process
//! and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::coordinator_service(&mut bench);
}
