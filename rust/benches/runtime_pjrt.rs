//! Bench target wrapper: PJRT artifact execution vs the native path (skips
//! without the `xla` feature or built artifacts). The workload lives in
//! [`mixtab::benchsuite`] so the `mixtab bench` CLI can run it in-process
//! and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::runtime_pjrt(&mut bench);
}
