//! Bench: PJRT artifact execution — FH and OPH batch latency/throughput vs
//! the native Rust path for the same work. Quantifies what the batcher buys
//! (and costs) on this CPU; on a real TPU the PJRT side is the accelerated
//! one, here it bounds the overhead story in EXPERIMENTS.md §Perf.

use mixtab::data::SparseVector;
use mixtab::hash::HashFamily;
use mixtab::runtime::artifact::{ArtifactKind, Manifest};
use mixtab::runtime::pjrt::PjrtEngine;
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::util::bench::{print_table, Bench};
use mixtab::util::rng::Xoshiro256;
use std::hint::black_box;

fn main() {
    if cfg!(not(feature = "xla")) {
        println!("runtime_pjrt: built without the `xla` feature (stub engine); skipping");
        return;
    }
    let bench = Bench::new();
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("runtime_pjrt: artifacts/ not built — run `make artifacts`; skipping");
        return;
    };
    let Some(meta) = manifest.find_fh(128, 512).cloned() else {
        println!("runtime_pjrt: no fh d'=128 artifact; skipping");
        return;
    };
    let ArtifactKind::Fh { batch, nnz, dim } = meta.kind else {
        unreachable!()
    };
    println!("runtime_pjrt: artifact {} [{batch}x{nnz}] -> d'={dim}", meta.name);
    let engine = PjrtEngine::load(&Manifest {
        artifacts: vec![meta.clone()],
    })
    .expect("engine");

    // Batch of realistic sparse vectors.
    let fh = FeatureHasher::new(HashFamily::MixedTab, 42, dim, SignMode::Paired);
    let mut rng = Xoshiro256::new(3);
    let vectors: Vec<SparseVector> = (0..batch)
        .map(|_| {
            let n = rng.range(100, 500);
            SparseVector::new(
                (0..n).map(|_| rng.next_u32() % 1_000_000).collect(),
                (0..n).map(|_| rng.next_f64() - 0.5).collect(),
            )
        })
        .collect();
    let mut bins = Vec::with_capacity(batch * nnz);
    let mut vals = Vec::with_capacity(batch * nnz);
    for v in &vectors {
        let (mut b, mut x) = fh.plan(v, nnz);
        bins.append(&mut b);
        vals.append(&mut x);
    }

    let mut rows = Vec::new();
    rows.push(bench.measure("pjrt_fh_batch", batch as u64, || {
        black_box(engine.run_fh(&meta.name, &bins, &vals).unwrap().sqnorm[0])
    }));
    let mut scratch = Vec::new();
    rows.push(bench.measure("native_fh_batch", batch as u64, || {
        let mut acc = 0.0;
        for v in &vectors {
            acc += fh.squared_norm(v, &mut scratch);
        }
        black_box(acc)
    }));
    print_table("FH batch of 16 vectors (per vector)", &rows);

    if let Some(oph_meta) = manifest.find_oph(200, 512).cloned() {
        let ArtifactKind::Oph { batch, nnz, k } = oph_meta.kind else {
            unreachable!()
        };
        let engine = PjrtEngine::load(&Manifest {
            artifacts: vec![oph_meta.clone()],
        })
        .expect("engine");
        let hasher = HashFamily::MixedTab.build(7);
        let mut h = vec![0i32; batch * nnz];
        let mut valid = vec![0i32; batch * nnz];
        let sets: Vec<Vec<u32>> = (0..batch)
            .map(|_| (0..400).map(|_| rng.next_u32()).collect())
            .collect();
        for (r, set) in sets.iter().enumerate() {
            for (i, &x) in set.iter().enumerate() {
                h[r * nnz + i] = hasher.hash(x) as i32;
                valid[r * nnz + i] = 1;
            }
        }
        let sketcher = mixtab::sketch::oph::OneHashSketcher::new(
            HashFamily::MixedTab.build(7),
            k,
            mixtab::sketch::oph::BinLayout::Mod,
            mixtab::sketch::DensifyMode::None,
        );
        let mut rows = Vec::new();
        rows.push(bench.measure("pjrt_oph_batch", batch as u64, || {
            black_box(engine.run_oph(&oph_meta.name, &h, &valid).unwrap()[0])
        }));
        rows.push(bench.measure("native_oph_batch", batch as u64, || {
            let mut acc = 0u64;
            for s in &sets {
                acc ^= sketcher.sketch_raw(s).bins[0];
            }
            black_box(acc)
        }));
        print_table("OPH batch of 16 sets (per set)", &rows);
    }
}
