//! Bench target wrapper: sharded LSH build + fan-out query through
//! `ShardedIndex` (N = 1 routing overhead vs N = 4 fan-out cost,
//! sequential and pool-parallel — the `query/shards4par` case). The
//! workload lives in [`mixtab::benchsuite`] so the `mixtab bench` CLI can
//! run it in-process and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::sharded_query(&mut bench);
}
