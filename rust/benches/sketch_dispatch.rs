//! Bench target wrapper: erased `DynSketcher` dispatch overhead vs direct
//! typed calls for spec-built sketchers. The workload lives in
//! [`mixtab::benchsuite`] so the `mixtab bench` CLI can run it in-process
//! and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::sketch_dispatch(&mut bench);
}
