//! Bench: sketching throughput — OPH vs k×MinHash (the paper's motivating
//! `O(|A|)` vs `O(k·|A|)` gap), densification cost, and FH sign-mode cost
//! (Corollary 1's single-hash trick vs two hashes).

use mixtab::data::synthetic::dataset1;
use mixtab::hash::HashFamily;
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::sketch::minhash::MinHash;
use mixtab::sketch::oph::{BinLayout, OneHashSketcher};
use mixtab::sketch::DensifyMode;
use mixtab::util::bench::{print_table, Bench};
use mixtab::util::rng::Xoshiro256;
use std::hint::black_box;

fn main() {
    let bench = Bench::new();
    let reps: usize = if bench.is_quick() { 20 } else { 500 };
    let mut rng = Xoshiro256::new(5);
    let pair = dataset1(2000, true, &mut rng);
    let set = &pair.a;
    let k = 200;

    println!("sketch_throughput: |A|={} k={k} reps={reps}", set.len());

    let mut rows = Vec::new();
    let oph = OneHashSketcher::new(
        HashFamily::MixedTab.build(1),
        k,
        BinLayout::Mod,
        DensifyMode::Paper,
    );
    rows.push(bench.measure("oph_densified", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph.sketch(set)).bins[0];
        }
        acc
    }));
    let oph_raw = OneHashSketcher::new(
        HashFamily::MixedTab.build(1),
        k,
        BinLayout::Mod,
        DensifyMode::None,
    );
    rows.push(bench.measure("oph_raw", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph_raw.sketch_raw(set)).bins[0];
        }
        acc
    }));
    let mh = MinHash::new(HashFamily::MixedTab, 1, k);
    let mh_reps = (reps / 50).max(1); // k× slower by construction
    rows.push(bench.measure("minhash_k200", (mh_reps * set.len()) as u64, || {
        let mut acc = 0u32;
        for _ in 0..mh_reps {
            acc ^= black_box(mh.sketch(set))[0];
        }
        acc
    }));
    print_table("set sketching (per element)", &rows);

    // FH sign modes.
    let v = mixtab::data::SparseVector::unit_indicator(set);
    let mut rows = Vec::new();
    for (name, mode) in [("fh_separate", SignMode::Separate), ("fh_paired", SignMode::Paired)] {
        let fh = FeatureHasher::new(HashFamily::MixedTab, 3, 128, mode);
        let mut scratch = Vec::new();
        rows.push(bench.measure(name, (reps * v.nnz()) as u64, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += fh.squared_norm(&v, &mut scratch);
            }
            black_box(acc)
        }));
    }
    print_table("feature hashing sign modes (per non-zero)", &rows);
}
