//! Bench target wrapper: sketching throughput — OPH vs k×MinHash, the
//! batched-vs-per-key Scratch contrast, and FH sign-mode cost. The workload
//! lives in [`mixtab::benchsuite`] so the `mixtab bench` CLI can run it
//! in-process and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::sketch_throughput(&mut bench);
}
