//! Bench target wrapper: LSH build + query latency on MNIST-like data
//! (Figure 5 operating point K = L = 10). The workload lives in
//! [`mixtab::benchsuite`] so the `mixtab bench` CLI can run it in-process
//! and gate the JSON records.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::lsh_query(&mut bench);
}
