//! Bench: LSH build + query latency on MNIST-like data, multiply-shift vs
//! mixed tabulation (the Figure 5 operating point K = L = 10). Weak hashing
//! inflates buckets on structured data, which shows up here as *slower
//! queries*, not just worse quality.

use mixtab::data::mnist_like;
use mixtab::hash::HashFamily;
use mixtab::lsh::{LshIndex, LshParams};
use mixtab::util::bench::{print_table, Bench};
use std::hint::black_box;

fn main() {
    let bench = Bench::new();
    let (n_db, n_q) = if bench.is_quick() { (400, 40) } else { (4000, 400) };
    let (db_ds, q_ds) = mnist_like::default_split(n_db, n_q, 42);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();
    println!("lsh_query: db={} queries={} K=L=10", db.len(), queries.len());

    for fam in [HashFamily::MixedTab, HashFamily::MultiplyShift, HashFamily::Murmur3] {
        let mut rows = Vec::new();
        let mut index = LshIndex::new(LshParams::new(10, 10), fam, 7);
        rows.push(bench.measure("build", db.len() as u64, || {
            index = LshIndex::new(LshParams::new(10, 10), fam, 7);
            for (i, s) in db.iter().enumerate() {
                index.insert(i as u32, s);
            }
            index.len()
        }));
        let mut retrieved_total = 0usize;
        rows.push(bench.measure("query", queries.len() as u64, || {
            retrieved_total = 0;
            for q in &queries {
                retrieved_total += black_box(index.query(q)).len();
            }
            retrieved_total
        }));
        print_table(&format!("LSH {} (per item)", fam.id()), &rows);
        println!(
            "  retrieved/query = {:.1}, max bucket = {}",
            retrieved_total as f64 / queries.len() as f64,
            index.max_bucket()
        );
    }
}
