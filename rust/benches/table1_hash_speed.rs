//! Bench: Table 1 — raw hash throughput (10⁷ keys) and FH-over-News20
//! timing for every family. `MIXTAB_BENCH_QUICK=1` shrinks the workload.
//!
//! Paper shape to verify: multiply-shift < poly2 < {mixed_tab, poly3} <
//! {murmur3, cityhash} ≪ blake2b; mixed_tab ≈ 0.7× murmur3.

use mixtab::data::news20_like::{self, News20LikeParams};
use mixtab::hash::HashFamily;
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::util::bench::{print_table, Bench};
use mixtab::util::rng::Xoshiro256;
use std::hint::black_box;

fn main() {
    let bench = Bench::new();
    let n_keys: usize = if bench.is_quick() { 200_000 } else { 10_000_000 };
    let n_docs: usize = if bench.is_quick() { 200 } else { 5_000 };

    let mut rng = Xoshiro256::new(0x7AB1E);
    let keys: Vec<u32> = (0..n_keys).map(|_| rng.next_u32()).collect();
    let mut out = vec![0u32; n_keys];

    println!("table1_hash_speed: {n_keys} keys / {n_docs} News20-like docs");
    let mut rows = Vec::new();
    for &fam in HashFamily::TABLE1 {
        let h = fam.build(42);
        // Blake2 at 1/100 scale to stay interactive.
        let slice = if fam == HashFamily::Blake2 {
            &keys[..n_keys / 100]
        } else {
            &keys[..]
        };
        let m = bench.measure(fam.id(), slice.len() as u64, || {
            h.hash_slice(slice, &mut out[..slice.len()]);
            black_box(out[0])
        });
        rows.push(m);
    }
    print_table("hash 32-bit keys", &rows);

    let news = news20_like::generate(n_docs, &News20LikeParams::default(), 99);
    let mut rows = Vec::new();
    for &fam in HashFamily::TABLE1 {
        let fh = FeatureHasher::new(fam, 42, 128, SignMode::Separate);
        let docs = if fam == HashFamily::Blake2 {
            &news.vectors[..n_docs / 20]
        } else {
            &news.vectors[..]
        };
        let mut scratch = Vec::new();
        let m = bench.measure(fam.id(), docs.len() as u64, || {
            let mut acc = 0.0;
            for v in docs {
                acc += fh.squared_norm(v, &mut scratch);
            }
            black_box(acc)
        });
        rows.push(m);
    }
    print_table("feature hashing News20-like (d'=128, per doc)", &rows);
}
