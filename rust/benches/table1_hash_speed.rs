//! Bench target wrapper: Table 1 — raw hash throughput and FH-over-News20
//! timing for every family. The workload lives in [`mixtab::benchsuite`] so
//! the `mixtab bench` CLI can run it in-process and gate the JSON records.
//! `MIXTAB_BENCH_QUICK=1` shrinks the workload.

use mixtab::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    mixtab::benchsuite::table1_hash_speed(&mut bench);
}
