//! Smoke-runs every experiment driver at tiny scale: each must complete,
//! write its CSVs, and preserve the paper's qualitative shape where the
//! scale still supports it.

use mixtab::experiments::{self, ExpContext};
use std::path::PathBuf;

fn ctx(tag: &str, scale: f64) -> (ExpContext, PathBuf) {
    let dir = std::env::temp_dir().join(format!("mixtab_exp_smoke_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    (
        ExpContext {
            out_dir: dir.clone(),
            scale,
            threads: 2,
            seed: 7777,
            data_dir: None,
        },
        dir,
    )
}

#[test]
fn all_ids_resolve() {
    for id in experiments::ALL {
        assert!(experiments::ALL.contains(id));
    }
    let (c, dir) = ctx("badid", 0.01);
    assert!(experiments::run("nonsense", &c).is_err());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig6_fig7_variants() {
    std::env::set_var("MIXTAB_BENCH_QUICK", "1");
    let (c, dir) = ctx("fig67", 0.02);
    let out6 = experiments::run("fig6", &c).unwrap();
    assert_eq!(out6.len(), 10); // 5 OPH + 5 FH families
    assert!(dir.join("fig6_oph/summary.csv").exists());
    assert!(dir.join("fig6_fh/summary.csv").exists());
    let out7 = experiments::run("fig7", &c).unwrap();
    assert_eq!(out7.len(), 10);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig8_dataset2() {
    let (c, dir) = ctx("fig8", 0.02);
    let out = experiments::run("fig8", &c).unwrap();
    assert_eq!(out.len(), 10);
    assert!(dir.join("fig8_oph/summary.csv").exists());
    assert!(dir.join("fig8_fh/summary.csv").exists());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig9_sparse_regime() {
    let (c, dir) = ctx("fig9", 0.05);
    let out = experiments::run("fig9", &c).unwrap();
    assert_eq!(out.len(), 5);
    // Estimates remain probabilities even in the heavy-densification regime.
    for s in &out {
        assert!(s.mean >= 0.0 && s.mean <= 1.0, "{s:?}");
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fig10_fig11_realworld_dims() {
    let (c, dir) = ctx("fig1011", 0.01);
    let out10 = experiments::run("fig10", &c).unwrap();
    assert_eq!(out10.len(), 10); // 2 datasets × 5 families
    let out11 = experiments::run("fig11", &c).unwrap();
    assert_eq!(out11.len(), 10);
    assert!(dir.join("fig10_mnist/summary.csv").exists());
    assert!(dir.join("fig11_news20/summary.csv").exists());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn table1_quick() {
    std::env::set_var("MIXTAB_BENCH_QUICK", "1");
    let (c, dir) = ctx("table1", 0.002);
    let out = experiments::run("table1", &c).unwrap();
    assert_eq!(out.len(), 7);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn synth2_ratio_table() {
    let (c, dir) = ctx("synth2", 0.02);
    let out = experiments::run("synth2", &c).unwrap();
    assert!(!out.is_empty());
    assert!(dir.join("synth2/ratios.csv").exists());
    let _ = std::fs::remove_dir_all(dir);
}
