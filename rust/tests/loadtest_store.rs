//! Loadtest result-store integration: `results.csv` round-trips through
//! the filesystem with hostile config strings, append validates the
//! header, compare/gate semantics match what the CI `loadtest-smoke` job
//! relies on, and a small end-to-end `loadtest::run` produces a row that
//! a doctored baseline demonstrably fails — the injected-regression
//! acceptance check.

use mixtab::loadtest::store::{
    append, diff, gate, last_run, load, RunRecord, HEADER, LOADTEST_SCHEMA,
};
use mixtab::loadtest::{self, LoadtestConfig};
use std::path::PathBuf;

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mixtab_loadtest_{}_{name}.csv", std::process::id()))
}

/// A full row with a config string exercising every CSV escape: commas
/// (real sketch specs contain them), quotes, and a newline.
fn sample_record() -> RunRecord {
    RunRecord {
        schema: LOADTEST_SCHEMA.to_string(),
        git_sha: "0123456789ab".into(),
        unix_ts: 1_754_000_000,
        quick: true,
        config: "spec=oph(k=64,layout=mod,densify=paper,hash=mixed_tab,seed=42) \
                 note=\"quoted, with comma\"\nsecond line"
            .into(),
        sets: 50_000,
        docs: 24_996,
        queries: 32,
        k: 10,
        clients: 4,
        window: 16,
        mix_ops: 20_000,
        query_frac: 0.5,
        load_qps: 81_234.5,
        mixed_qps: 64_321.25,
        recall_at_k: 0.6875,
        p50_us: 143.0,
        p99_us: 1_220.5,
        p999_us: 4_810.0,
        peak_rss_mb: 612.75,
        server_inserts: 60_021,
        server_queries: 10_011,
        server_errors: 0,
    }
}

#[test]
fn append_load_roundtrips_hostile_config_strings() {
    let path = tmp_path("roundtrip");
    std::fs::remove_file(&path).ok();
    let a = sample_record();
    let mut b = sample_record();
    b.git_sha = "ba9876543210".into();
    b.recall_at_k = 0.71875;
    append(&path, &a).unwrap();
    append(&path, &b).unwrap();
    let runs = load(&path).unwrap();
    assert_eq!(runs, vec![a, b.clone()], "every field survives the file");
    assert_eq!(last_run(&path).unwrap(), b, "last_run is the newest row");
    // The raw file keeps exactly one header line at the top.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(text.starts_with("schema,git_sha,"));
    assert_eq!(text.matches("schema,git_sha,").count(), 1);
}

#[test]
fn append_rejects_foreign_header() {
    // Appending a v1 row to a file with a different header would corrupt
    // the trajectory — it must error, not write.
    let path = tmp_path("foreign");
    std::fs::write(&path, "some,other,header\n1,2,3\n").unwrap();
    let err = append(&path, &sample_record()).unwrap_err();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(err.to_string().contains("header does not match"), "{err}");
    assert_eq!(text, "some,other,header\n1,2,3\n", "file left untouched");
}

#[test]
fn compare_semantics_missing_run_and_missing_column() {
    // A store with only a header has no runs: --compare must error, not
    // invent a baseline.
    let path = tmp_path("header_only");
    let header_line = HEADER.join(",") + "\n";
    std::fs::write(&path, &header_line).unwrap();
    let err = last_run(&path).unwrap_err();
    assert!(err.to_string().contains("no runs"), "{err}");
    std::fs::remove_file(&path).ok();

    // A row missing a column (truncated header + rows) errors by name.
    let path = tmp_path("missing_col");
    append(&path, &sample_record()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let truncated: String = text
        .replace("schema,git_sha,", "git_sha,")
        .replacen(&format!("{LOADTEST_SCHEMA},"), "", 1);
    std::fs::write(&path, truncated).unwrap();
    let err = load(&path).unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(err.to_string().contains("missing column 'schema'"), "{err}");

    // A missing file errors rather than silently passing a gate.
    assert!(last_run(tmp_path("nonexistent")).is_err());
}

#[test]
fn gate_at_and_over_tolerance_through_files() {
    let base_path = tmp_path("gate_base");
    std::fs::remove_file(&base_path).ok();
    append(&base_path, &sample_record()).unwrap();
    let baseline = last_run(&base_path).unwrap();
    std::fs::remove_file(&base_path).ok();

    // Exactly at tolerance on every gated axis: passes. Dyadic recall
    // values keep the boundary exact in f64 (0.6875 − 0.125 = 0.5625).
    let mut at = sample_record();
    at.recall_at_k = baseline.recall_at_k - 0.125;
    at.load_qps = baseline.load_qps * 0.5;
    at.mixed_qps = baseline.mixed_qps * 0.5;
    assert!(gate(&at, &baseline, 0.125, 0.5).unwrap().is_empty());

    // Clearly over on two axes: named failures, in gate order.
    let mut over = sample_record();
    over.recall_at_k = baseline.recall_at_k - 0.1875;
    over.load_qps = baseline.load_qps * 0.25;
    let fails = gate(&over, &baseline, 0.125, 0.5).unwrap();
    let names: Vec<&str> = fails.iter().map(|f| f.metric).collect();
    assert_eq!(names, ["recall_at_k", "load_qps"], "{fails:?}");

    // Latency and RSS are diffed but never gated.
    let mut slow = sample_record();
    slow.p99_us = baseline.p99_us * 100.0;
    slow.peak_rss_mb = baseline.peak_rss_mb * 100.0;
    assert!(gate(&slow, &baseline, 0.125, 0.5).unwrap().is_empty());
    assert!(diff(&baseline, &slow).iter().any(|d| d.name == "p99_us" && d.rel_change() > 1.0));
}

/// End-to-end acceptance: a miniature `loadtest::run` against the real
/// TCP coordinator yields a schema-valid row that (a) gates cleanly
/// against itself and (b) demonstrably fails against a baseline with an
/// injected recall/QPS regression.
#[test]
fn mini_run_end_to_end_and_injected_regression_fails_gate() {
    let cfg = LoadtestConfig {
        sets: 240,
        queries: 8,
        k: 5,
        clients: 2,
        window: 8,
        mix_ops: 120,
        oracle_workers: 2,
        quick: true,
        ..LoadtestConfig::quick()
    };
    let record = loadtest::run(&cfg).unwrap();

    // Schema-valid row with identity fields populated.
    assert_eq!(record.schema, LOADTEST_SCHEMA);
    assert!(!record.git_sha.is_empty());
    assert!(record.unix_ts > 0);
    assert!(record.config.contains("oph(k=64"), "{}", record.config);
    assert!(record.config.contains("seed=42"), "{}", record.config);
    assert_eq!(record.sets, 240);
    assert!((0.0..=1.0).contains(&record.recall_at_k));
    assert!(record.load_qps > 0.0 && record.mixed_qps > 0.0);
    assert!(record.p50_us > 0.0 && record.p999_us >= record.p99_us);
    assert_eq!(record.server_errors, 0);
    // Server saw the load phase, the mixed phase, and the oracle queries.
    assert!(record.server_inserts >= record.sets);
    assert!(record.server_queries >= record.queries);

    // It persists as a loadable row. Floats are stored at 6-decimal
    // precision (`csv::f`), so compare at store precision: identity
    // fields exactly, and a second render is byte-identical.
    let path = tmp_path("e2e");
    std::fs::remove_file(&path).ok();
    append(&path, &record).unwrap();
    let back = last_run(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.git_sha, record.git_sha);
    assert_eq!(back.config, record.config);
    assert_eq!(back.sets, record.sets);
    assert_eq!(back.to_fields(), record.to_fields());

    // Self-gate is clean at zero tolerance.
    assert!(gate(&record, &record, 0.0, 0.0).unwrap().is_empty());

    // Injected regression: a baseline claiming better recall and 10× the
    // throughput must fail the gate on all three gated metrics.
    let mut doctored = record.clone();
    doctored.recall_at_k = (record.recall_at_k + 0.5).min(1.5);
    doctored.load_qps = record.load_qps * 10.0;
    doctored.mixed_qps = record.mixed_qps * 10.0;
    let fails = gate(&record, &doctored, 0.02, 0.5).unwrap();
    let names: Vec<&str> = fails.iter().map(|f| f.metric).collect();
    assert_eq!(names, ["recall_at_k", "load_qps", "mixed_qps"], "{fails:?}");
}

#[test]
fn committed_quick_baseline_is_loadable_and_schema_valid() {
    // The repo-root floor baseline the CI loadtest-smoke job gates
    // against must always load and carry gateable (nonzero) floors.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../LOADTEST_baseline_quick.csv");
    let runs = load(path).expect("committed LOADTEST_baseline_quick.csv");
    assert!(!runs.is_empty());
    for r in &runs {
        assert_eq!(r.schema, LOADTEST_SCHEMA);
        assert!(r.quick, "baseline rows must be quick-mode");
        assert!(r.recall_at_k > 0.0, "recall floor must be gateable");
        assert!(r.load_qps > 0.0 && r.mixed_qps > 0.0, "qps floors gateable");
    }
}
