//! Runtime integration: every AOT artifact loads, compiles and executes on
//! the PJRT CPU client, and the PJRT FH path agrees with the native Rust
//! path to f32 rounding. Skips (with a notice) when `artifacts/` is absent —
//! run `make artifacts` first.

use mixtab::data::SparseVector;
use mixtab::hash::HashFamily;
use mixtab::runtime::artifact::{ArtifactKind, Manifest};
use mixtab::runtime::executor::ExecutorHandle;
use mixtab::runtime::pjrt::PjrtEngine;
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::sketch::oph::{BinLayout, OneHashSketcher};
use mixtab::sketch::DensifyMode;
use mixtab::util::rng::Xoshiro256;

fn manifest() -> Option<Manifest> {
    if cfg!(not(feature = "xla")) {
        eprintln!("SKIP: built without the `xla` feature (PJRT engine is a stub)");
        return None;
    }
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn every_artifact_compiles_and_runs() {
    let Some(m) = manifest() else { return };
    let engine = PjrtEngine::load(&m).expect("engine");
    assert_eq!(engine.names().len(), m.artifacts.len());
    for meta in &m.artifacts {
        match meta.kind {
            ArtifactKind::Fh { batch, nnz, dim } => {
                let bins = vec![0i32; batch * nnz];
                let vals = vec![0f32; batch * nnz];
                let out = engine.run_fh(&meta.name, &bins, &vals).expect("run fh");
                assert_eq!(out.out.len(), batch * dim);
                assert!(out.out.iter().all(|&x| x == 0.0));
                assert!(out.sqnorm.iter().all(|&x| x == 0.0));
            }
            ArtifactKind::Oph { batch, nnz, k } => {
                let h = vec![0i32; batch * nnz];
                let valid = vec![0i32; batch * nnz];
                let sk = engine.run_oph(&meta.name, &h, &valid).expect("run oph");
                assert_eq!(sk.len(), batch * k);
                assert!(sk.iter().all(|&x| x == i32::MAX), "padding ⇒ all empty");
            }
        }
    }
}

/// PJRT FH output ≡ native Rust FH output (f32 tolerance) across random
/// sparse vectors — the bit-compatibility contract the coordinator's
/// fallback relies on.
#[test]
fn pjrt_fh_matches_native_path() {
    let Some(m) = manifest() else { return };
    let Some(meta) = m.find_fh(128, 512).cloned() else {
        eprintln!("SKIP: no fh d'=128 artifact");
        return;
    };
    let ArtifactKind::Fh { batch, nnz, dim } = meta.kind else {
        unreachable!()
    };
    let engine = PjrtEngine::load(&Manifest {
        artifacts: vec![meta.clone()],
    })
    .expect("engine");

    let fh = FeatureHasher::new(HashFamily::MixedTab, 42, dim, SignMode::Paired);
    let mut rng = Xoshiro256::new(17);
    // Build a batch of random sparse vectors.
    let mut vectors = Vec::new();
    for _ in 0..batch {
        let nnz_v = rng.range(1, 400);
        let idx: Vec<u32> = (0..nnz_v).map(|_| rng.next_u32() % 1_000_000).collect();
        let val: Vec<f64> = (0..nnz_v).map(|_| rng.next_f64() * 2.0 - 1.0).collect();
        vectors.push(SparseVector::new(idx, val));
    }
    let mut bins_flat = Vec::with_capacity(batch * nnz);
    let mut vals_flat = Vec::with_capacity(batch * nnz);
    for v in &vectors {
        let (mut b, mut x) = fh.plan(v, nnz);
        bins_flat.append(&mut b);
        vals_flat.append(&mut x);
    }
    let out = engine
        .run_fh(&meta.name, &bins_flat, &vals_flat)
        .expect("run");
    for (r, v) in vectors.iter().enumerate() {
        let native = fh.transform(v);
        let row = &out.out[r * dim..(r + 1) * dim];
        for d in 0..dim {
            assert!(
                (row[d] as f64 - native[d]).abs() < 1e-4,
                "row {r} dim {d}: pjrt {} native {}",
                row[d],
                native[d]
            );
        }
        let native_sq: f64 = native.iter().map(|x| x * x).sum();
        assert!(
            (out.sqnorm[r] as f64 - native_sq).abs() < 1e-3,
            "row {r} sqnorm"
        );
    }
}

/// PJRT OPH raw sketch ≡ native raw sketch (same mod-layout arithmetic).
#[test]
fn pjrt_oph_matches_native_sketch() {
    let Some(m) = manifest() else { return };
    let Some(meta) = m.find_oph(200, 512).cloned() else {
        eprintln!("SKIP: no oph k=200 artifact");
        return;
    };
    let ArtifactKind::Oph { batch, nnz, k } = meta.kind else {
        unreachable!()
    };
    let engine = PjrtEngine::load(&Manifest {
        artifacts: vec![meta.clone()],
    })
    .expect("engine");

    let hasher = HashFamily::MixedTab.build(7);
    let sketcher = OneHashSketcher::from_hasher(
        HashFamily::MixedTab.build(7),
        k,
        BinLayout::Mod,
        DensifyMode::None,
    );
    let mut rng = Xoshiro256::new(23);
    let mut h_flat = vec![0i32; batch * nnz];
    let mut valid_flat = vec![0i32; batch * nnz];
    let mut sets = Vec::new();
    for r in 0..batch {
        let n = rng.range(10, nnz.min(400));
        let set: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        for (i, &x) in set.iter().enumerate() {
            h_flat[r * nnz + i] = hasher.hash(x) as i32;
            valid_flat[r * nnz + i] = 1;
        }
        sets.push(set);
    }
    let sk = engine
        .run_oph(&meta.name, &h_flat, &valid_flat)
        .expect("run");
    for (r, set) in sets.iter().enumerate() {
        let native = sketcher.sketch_raw(set);
        for j in 0..k {
            let pjrt_v = sk[r * k + j];
            let native_v = native.bins[j];
            if native_v == mixtab::sketch::EMPTY_BIN {
                assert_eq!(pjrt_v, i32::MAX, "row {r} bin {j} should be empty");
            } else {
                assert_eq!(pjrt_v as u64, native_v, "row {r} bin {j}");
            }
        }
    }
}

#[test]
fn executor_handle_roundtrip_and_errors() {
    let Some(m) = manifest() else { return };
    let exec = ExecutorHandle::spawn(m.clone()).expect("spawn");
    assert_eq!(exec.artifact_names().len(), m.artifacts.len());
    // Unknown artifact name errors cleanly.
    assert!(exec.run_fh("nope", vec![], vec![]).is_err());
    // Wrong input size errors cleanly.
    let fh_name = m.find_fh(128, 512).map(|a| a.name.clone());
    if let Some(name) = fh_name {
        assert!(exec.run_fh(&name, vec![0; 3], vec![0.0; 3]).is_err());
    }
}
