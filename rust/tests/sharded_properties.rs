//! Property tests for the sharded serving layer (`lsh::sharded`):
//!
//! * shard routing is deterministic across independently-built indices,
//!   seeds permitting (same spec ⇒ same routes; different seed ⇒ routes
//!   may and do differ),
//! * a `ShardedIndex` with N = 1 is bit-identical to a bare `LshIndex` —
//!   query results and persisted snapshot bytes,
//! * fan-out query results are independent of the shard count,
//! * the pool-parallel fan-out is bit-identical to the sequential path
//!   for N ∈ {1, 2, 4}, including non-default OPH layouts,
//! * the mutable-corpus tier holds for N ∈ {1, 2, 4}: a deleted id never
//!   comes back from a query, re-inserting a live id is idempotent in
//!   postings and `len`, compaction is bit-identical to a fresh rebuild
//!   of the surviving corpus, and tombstoned snapshots round-trip
//!   through persist.

use mixtab::hash::HashFamily;
use mixtab::lsh::{persist, LshIndex, LshParams, ShardedIndex};
use mixtab::sketch::{BinLayout, DensifyMode, OphParams, SketchSpec};
use mixtab::util::prop::{Gen, Runner};
use mixtab::util::rng::Xoshiro256;

fn oph_spec(family: HashFamily, seed: u64) -> SketchSpec {
    // Bin count is overridden by the index's (K, L).
    SketchSpec::oph(family, seed, 1)
}

/// Deterministic pseudo-random corpus of sets.
fn corpus(n: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let len = 40 + (rng.next_u32() % 120) as usize;
            (0..len).map(|_| rng.next_u32() % 1_000_000).collect()
        })
        .collect()
}

#[test]
fn prop_routing_deterministic_across_runs() {
    for family in [HashFamily::MixedTab, HashFamily::Murmur3] {
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            let params = LshParams::new(4, 4);
            let a = ShardedIndex::new(5, params, &oph_spec(family, seed));
            let b = ShardedIndex::new(5, params, &oph_spec(family, seed));
            Runner::new(256).run(
                &format!("route({}, seed={seed}) stable", family.id()),
                Gen::u32_any(),
                |&id| a.shard_of(id) == b.shard_of(id),
            );
        }
    }
}

#[test]
fn routing_depends_on_seed_not_process_state() {
    // Different seeds give different routings (whp over 512 ids) — the
    // route is a function of the spec, not of global state.
    let params = LshParams::new(4, 4);
    let a = ShardedIndex::new(8, params, &oph_spec(HashFamily::MixedTab, 1));
    let b = ShardedIndex::new(8, params, &oph_spec(HashFamily::MixedTab, 2));
    let differing = (0..512u32).filter(|&id| a.shard_of(id) != b.shard_of(id)).count();
    assert!(differing > 0, "seed does not influence routing");
}

#[test]
fn single_shard_matches_bare_index_results() {
    let params = LshParams::new(6, 8);
    let spec = oph_spec(HashFamily::MixedTab, 42);
    let mut bare = LshIndex::new(params, &spec);
    let sharded = ShardedIndex::new(1, params, &spec);
    let sets = corpus(60, 9);
    for (i, s) in sets.iter().enumerate() {
        bare.insert(i as u32, s);
        sharded.insert(i as u32, s);
    }
    assert_eq!(sharded.len(), bare.len());
    // Bit-identical sketches and query results on stored and novel sets.
    let probes = corpus(30, 10);
    for s in sets.iter().chain(&probes) {
        assert_eq!(sharded.sketch(s).bins, bare.sketch(s).bins);
        assert_eq!(sharded.query(s), bare.query(s));
    }
}

#[test]
fn single_shard_snapshot_bytes_identical_to_bare_index() {
    let dir = std::env::temp_dir().join("mixtab_sharded_props_n1");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(3, 5);
    let spec = oph_spec(HashFamily::Murmur3, 17);
    let mut bare = LshIndex::new(params, &spec);
    let sharded = ShardedIndex::new(1, params, &spec);
    for (i, s) in corpus(40, 21).iter().enumerate() {
        bare.insert(i as u32, s);
        sharded.insert(i as u32, s);
    }
    let bare_path = dir.join("bare.mxls");
    let sharded_path = dir.join("sharded.mxls");
    persist::save(&bare, spec.family, spec.seed, &bare_path).unwrap();
    sharded.save(&sharded_path).unwrap();
    let bare_bytes = std::fs::read(&bare_path).unwrap();
    let sharded_bytes = std::fs::read(&sharded_path).unwrap();
    assert!(!bare_bytes.is_empty());
    assert_eq!(
        bare_bytes, sharded_bytes,
        "N=1 sharded snapshot must be byte-identical to the bare index's"
    );
    // And it reloads through both loaders.
    let (loaded_bare, fam, seed) = persist::load(&sharded_path).unwrap();
    assert_eq!((fam, seed), (spec.family, spec.seed));
    assert_eq!(loaded_bare.len(), bare.len());
    let loaded_sharded = ShardedIndex::load(&bare_path).unwrap();
    assert_eq!(loaded_sharded.n_shards(), 1);
    assert_eq!(loaded_sharded.len(), bare.len());
    let probes = corpus(1, 33);
    assert_eq!(loaded_sharded.query(&probes[0]), bare.query(&probes[0]));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn query_results_independent_of_shard_count() {
    let params = LshParams::new(5, 6);
    let spec = oph_spec(HashFamily::MixedTab, 3);
    let sets = corpus(80, 5);
    let probes = corpus(40, 6);
    let reference = {
        let idx = ShardedIndex::new(1, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        probes.iter().map(|p| idx.query(p)).collect::<Vec<_>>()
    };
    for n in [2usize, 3, 7, 16] {
        let idx = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        assert_eq!(idx.len(), sets.len());
        for (p, expect) in probes.iter().zip(&reference) {
            assert_eq!(
                &idx.query(p),
                expect,
                "N={n} fan-out diverged from the unsharded result"
            );
        }
        // Self-retrieval holds at every shard count.
        for (i, s) in sets.iter().enumerate() {
            assert!(idx.query(s).contains(&(i as u32)));
        }
    }
}

#[test]
fn parallel_fanout_bit_identical_to_sequential() {
    use mixtab::util::threadpool::ThreadPool;
    use std::sync::Arc;
    let params = LshParams::new(5, 6);
    let specs = [
        // Paper-default layout/densify…
        oph_spec(HashFamily::MixedTab, 3),
        // …and a non-default layout + densification mode.
        SketchSpec::oph_with(
            HashFamily::MixedTab,
            13,
            OphParams {
                k: 1, // overridden by (K, L)
                layout: BinLayout::Range,
                densify: DensifyMode::Rotation,
            },
        ),
    ];
    let sets = corpus(80, 5);
    let probes = corpus(40, 6);
    // A pool narrower than the widest shard count, so tasks queue.
    let pool = Arc::new(ThreadPool::new(3));
    for spec in specs {
        for n in [1usize, 2, 4] {
            let mut par = ShardedIndex::new(n, params, &spec);
            par.set_pool(Some(Arc::clone(&pool)));
            assert_eq!(par.fanout_parallel(), n > 1);
            let seq = ShardedIndex::new(n, params, &spec);
            for (i, s) in sets.iter().enumerate() {
                par.insert(i as u32, s);
                seq.insert(i as u32, s);
            }
            for p in probes.iter().chain(sets.iter()) {
                let (ids, counts) = par.query_fanout(p);
                // Bit-identical to the same index's sequential reference
                // path — merged union *and* per-shard counts…
                assert_eq!(
                    (ids.clone(), counts),
                    par.query_fanout_sequential(p),
                    "N={n} spec={spec}"
                );
                // …and to an index that never had a pool.
                assert_eq!(ids, seq.query(p), "N={n} spec={spec}");
            }
        }
    }
}

#[test]
fn parallel_fanout_results_independent_of_shard_count() {
    use mixtab::util::threadpool::ThreadPool;
    use std::sync::Arc;
    // The PR-4 N-independence property, re-proven on the parallel path:
    // pool-backed fan-out at any N equals the unsharded reference.
    let params = LshParams::new(5, 6);
    let spec = oph_spec(HashFamily::MixedTab, 3);
    let sets = corpus(80, 5);
    let probes = corpus(40, 6);
    let reference = {
        let idx = ShardedIndex::new(1, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        probes.iter().map(|p| idx.query(p)).collect::<Vec<_>>()
    };
    let pool = Arc::new(ThreadPool::new(4));
    for n in [2usize, 4] {
        let mut idx = ShardedIndex::new(n, params, &spec);
        idx.set_pool(Some(Arc::clone(&pool)));
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        for (p, expect) in probes.iter().zip(&reference) {
            assert_eq!(
                &idx.query(p),
                expect,
                "N={n} parallel fan-out diverged from the unsharded result"
            );
        }
    }
}

/// Every on-disk byte of a snapshot: the `base` file (plain snapshot or
/// manifest) plus any per-shard files. Equal vectors mean the postings,
/// keys, and tombstones are physically identical, not merely
/// query-equivalent.
fn snapshot_bytes(idx: &ShardedIndex, base: &std::path::Path) -> Vec<Vec<u8>> {
    idx.save(base).unwrap();
    let mut out = vec![std::fs::read(base).unwrap()];
    for i in 0..idx.n_shards() {
        let p = ShardedIndex::shard_path(base, i);
        if p.exists() {
            out.push(std::fs::read(&p).unwrap());
        }
    }
    out
}

#[test]
fn deleted_ids_never_returned_at_any_shard_count() {
    let params = LshParams::new(5, 6);
    let spec = oph_spec(HashFamily::MixedTab, 3);
    let sets = corpus(60, 5);
    for n in [1usize, 2, 4] {
        let idx = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let mut deleted = 0;
        for i in (0..sets.len()).step_by(3) {
            let (shard, existed) = idx.delete(i as u32);
            assert!(existed, "N={n}: live id {i} reported absent on delete");
            assert_eq!(shard, idx.shard_of(i as u32));
            deleted += 1;
        }
        assert_eq!(idx.len(), sets.len() - deleted, "N={n}: len after deletes");
        for (i, s) in sets.iter().enumerate() {
            let hits = idx.query(s);
            if i % 3 == 0 {
                assert!(
                    !hits.contains(&(i as u32)),
                    "N={n}: deleted id {i} still returned"
                );
            } else {
                assert!(hits.contains(&(i as u32)), "N={n}: live id {i} lost");
            }
        }
        // Deleting an already-deleted or never-seen id is a clean no-op.
        assert!(!idx.delete(0).1, "N={n}: double delete reported existed");
        assert!(!idx.delete(9_999_999).1, "N={n}: unknown id reported existed");
        assert_eq!(idx.len(), sets.len() - deleted, "N={n}: no-op deletes moved len");
    }
}

#[test]
fn reinsert_of_live_id_idempotent_in_postings_and_len() {
    // The regression this PR fixes: the pre-upsert index pushed a fresh
    // posting into every table on re-insert, double-counting `len` and
    // serving stale buckets forever.
    let dir = std::env::temp_dir().join("mixtab_sharded_props_reinsert");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(5, 6);
    let spec = oph_spec(HashFamily::MixedTab, 3);
    let sets = corpus(50, 7);
    for n in [1usize, 2, 4] {
        let once = ShardedIndex::new(n, params, &spec);
        let twice = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            once.insert(i as u32, s);
            twice.insert(i as u32, s);
        }
        for (i, s) in sets.iter().enumerate() {
            twice.insert(i as u32, s);
        }
        assert_eq!(twice.len(), once.len(), "N={n}: re-insert double-counted len");
        for s in &sets {
            assert_eq!(twice.query(s), once.query(s), "N={n}: query drift");
        }
        assert_eq!(
            snapshot_bytes(&twice, &dir.join(format!("twice_n{n}"))),
            snapshot_bytes(&once, &dir.join(format!("once_n{n}"))),
            "N={n}: re-insert left different postings on disk"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compaction_bit_identical_to_fresh_rebuild() {
    let dir = std::env::temp_dir().join("mixtab_sharded_props_compact");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(5, 6);
    let spec = oph_spec(HashFamily::MixedTab, 11);
    let sets = corpus(64, 13);
    for n in [1usize, 2, 4] {
        let churned = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            churned.insert(i as u32, s);
        }
        for i in (0..sets.len()).step_by(2) {
            churned.delete(i as u32);
        }
        churned.compact();
        assert_eq!(churned.tombstone_count(), 0, "N={n}: compact left tombstones");

        let fresh = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            if i % 2 != 0 {
                fresh.insert(i as u32, s);
            }
        }
        assert_eq!(churned.len(), fresh.len(), "N={n}");
        assert_eq!(
            snapshot_bytes(&churned, &dir.join(format!("churned_n{n}"))),
            snapshot_bytes(&fresh, &dir.join(format!("fresh_n{n}"))),
            "N={n}: compacted index differs from a fresh rebuild of the survivors"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tombstoned_snapshots_roundtrip_through_persist() {
    let dir = std::env::temp_dir().join("mixtab_sharded_props_tomb");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(4, 6);
    let spec = oph_spec(HashFamily::MixedTab, 23);
    let sets = corpus(80, 29);
    for n in [1usize, 2, 4] {
        let idx = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        // Three deletes out of 80 stay far below the auto-compaction
        // threshold in every shard, so the tombstones are still pending
        // at save time — the case the snapshot format must carry.
        for id in 0..3u32 {
            assert!(idx.delete(id).1);
        }
        assert_eq!(idx.tombstone_count(), 3, "N={n}: expected pending tombstones");

        let base = dir.join(format!("snap_n{n}"));
        idx.save(&base).unwrap();
        let loaded = ShardedIndex::load(&base).unwrap();
        assert_eq!(loaded.tombstone_count(), 3, "N={n}: tombstones lost on reload");
        assert_eq!(loaded.len(), idx.len(), "N={n}: live count drifted");
        for (i, s) in sets.iter().enumerate() {
            assert_eq!(loaded.query(s), idx.query(s), "N={n}: query drift on reload");
            if i < 3 {
                assert!(
                    !loaded.query(s).contains(&(i as u32)),
                    "N={n}: deleted id {i} resurrected by reload"
                );
            }
        }
        // The reloaded index compacts to exactly what a fresh rebuild of
        // the survivors would be — tombstones survived as *data*, not as
        // baked-in postings.
        loaded.compact();
        assert_eq!(loaded.tombstone_count(), 0);
        let fresh = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            if i >= 3 {
                fresh.insert(i as u32, s);
            }
        }
        assert_eq!(
            snapshot_bytes(&loaded, &dir.join(format!("reloaded_n{n}"))),
            snapshot_bytes(&fresh, &dir.join(format!("freshtomb_n{n}"))),
            "N={n}: reload+compact differs from fresh rebuild"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn multi_shard_roundtrip_preserves_routing_and_results() {
    // Persist/load of an N>1 index preserves every query and the routing
    // (reloaded indices keep inserting into the same shards).
    let dir = std::env::temp_dir().join("mixtab_sharded_props_rt");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(4, 6);
    let spec = oph_spec(HashFamily::MixedTab, 99);
    let idx = ShardedIndex::new(4, params, &spec);
    let sets = corpus(50, 51);
    for (i, s) in sets.iter().enumerate() {
        idx.insert(i as u32, s);
    }
    let base = dir.join("snap");
    idx.save(&base).unwrap();
    let loaded = ShardedIndex::load(&base).unwrap();
    assert_eq!(loaded.n_shards(), 4);
    assert_eq!(loaded.per_shard_len(), idx.per_shard_len());
    for s in &sets {
        assert_eq!(loaded.query(s), idx.query(s));
    }
    for id in 0..200u32 {
        assert_eq!(loaded.shard_of(id), idx.shard_of(id));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn roundtrip_preserves_non_default_oph_params_at_any_shard_count() {
    // The manifest stores the full spec string, so an index built from a
    // non-default layout/densify reloads with the exact same sketcher —
    // not a silently-defaulted one. N = 1 takes the manifest format too
    // in this case (the plain format cannot encode layout/densify).
    let dir = std::env::temp_dir().join("mixtab_sharded_props_layout");
    let _ = std::fs::remove_dir_all(&dir);
    let params = LshParams::new(4, 5);
    let spec = SketchSpec::oph_with(
        HashFamily::MixedTab,
        13,
        OphParams {
            k: 1, // overridden by (K, L)
            layout: BinLayout::Range,
            densify: DensifyMode::Rotation,
        },
    );
    let sets = corpus(40, 71);
    for n in [1usize, 3] {
        let idx = ShardedIndex::new(n, params, &spec);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let base = dir.join(format!("snap_n{n}"));
        idx.save(&base).unwrap();
        let loaded = ShardedIndex::load(&base).unwrap();
        assert_eq!(loaded.n_shards(), n);
        assert_eq!(loaded.spec(), &spec);
        for s in &sets {
            assert_eq!(
                loaded.sketch(s).bins,
                idx.sketch(s).bins,
                "N={n}: sketcher diverged on reload"
            );
            assert_eq!(loaded.query(s), idx.query(s), "N={n}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pooled_spec_string_fully_determines_pool_and_sketches() {
    // The pooled source is a pure function of the spec string: two
    // constructions from the same canonical string (two "processes"
    // parsing the same config) fill identical pools and emit identical
    // sketches — no process-local state leaks into the pool.
    use mixtab::hash::source::{HashSource, PooledSource};
    let sets = corpus(40, 17);
    for text in [
        "minhash(k=64,pool=256,hash=mixed_tab,seed=21)",
        "simhash(bits=96,pool=512,hash=mixed_tab,seed=22)",
    ] {
        let a: SketchSpec = text.parse().unwrap();
        let b: SketchSpec = a.to_string().parse().unwrap();
        assert_eq!(a, b, "canonical form must round-trip");
        match a.scheme {
            mixtab::sketch::SketchScheme::MinHash { .. } => {
                let (ma, mb) = (a.build_minhash().unwrap(), b.build_minhash().unwrap());
                for s in &sets {
                    assert_eq!(ma.sketch_per_key(s), mb.sketch_per_key(s), "{text}");
                }
            }
            mixtab::sketch::SketchScheme::SimHash { .. } => {
                let (sa, sb) = (a.build_simhash().unwrap(), b.build_simhash().unwrap());
                for s in &sets {
                    let v = mixtab::data::SparseVector::unit_indicator(s);
                    assert_eq!(sa.sketch_per_key(&v), sb.sketch_per_key(&v), "{text}");
                }
            }
            _ => unreachable!(),
        }
    }
    // Pool contents themselves: same (family, seed, width) ⇒ the same
    // word-for-word pool for any key batch.
    let pa = PooledSource::new(HashFamily::MixedTab, 21, 64, 256);
    let pb = PooledSource::new(HashFamily::MixedTab, 21, 64, 256);
    assert_eq!(pa.offsets(), pb.offsets());
    let (mut wa, mut wb) = (Vec::new(), Vec::new());
    for s in sets.iter().take(5) {
        pa.begin(s, &mut wa);
        pb.begin(s, &mut wb);
        assert_eq!(wa, wb, "pool contents diverged across constructions");
    }
}

#[test]
fn pooled_scheme_sidecar_bytes_identical_across_shard_counts() {
    // A coordinator whose default `[sketch]` spec is pooled stores pooled
    // sketch values in the `save_index` sidecar. Those bytes must be a
    // pure function of (spec string, corpus): identical across
    // independently-built registries ("processes") and across index shard
    // counts — sharding routes postings, it must never touch sketches.
    use mixtab::coordinator::config::CoordinatorConfig;
    use mixtab::coordinator::metrics::Metrics;
    use mixtab::coordinator::SchemeRegistry;
    let dir = std::env::temp_dir().join("mixtab_sharded_props_pooled_sidecar");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let spec: SketchSpec = "minhash(k=32,pool=256,hash=mixed_tab,seed=21)".parse().unwrap();
    let sets = corpus(30, 23);
    let mut sidecars: Vec<Vec<u8>> = Vec::new();
    for n in [1usize, 2, 4] {
        let cfg = CoordinatorConfig {
            enable_pjrt: false,
            sketch: Some(spec),
            lsh_k: 4,
            lsh_l: 5,
            lsh_shards: n,
            ..Default::default()
        };
        let mut index_bytes: Vec<Vec<Vec<u8>>> = Vec::new();
        for run in 0..2 {
            let metrics = Metrics::new();
            let reg = SchemeRegistry::from_config(&cfg, &metrics, None);
            let scheme = reg.default_scheme();
            for (i, s) in sets.iter().enumerate() {
                scheme.insert(i as u32, s.clone()).unwrap();
            }
            let base = dir.join(format!("snap_n{n}_r{run}"));
            let base_str = base.to_str().unwrap().to_string();
            scheme.save_index(&base_str).unwrap();
            let mut files = vec![std::fs::read(&base).unwrap()];
            for i in 0..n {
                let p = ShardedIndex::shard_path(&base, i);
                if p.exists() {
                    files.push(std::fs::read(&p).unwrap());
                }
            }
            index_bytes.push(files);
            sidecars.push(std::fs::read(format!("{base_str}.sketches")).unwrap());
        }
        assert_eq!(
            index_bytes[0], index_bytes[1],
            "N={n}: index bytes diverged across registries"
        );
    }
    for w in sidecars.windows(2) {
        assert_eq!(
            w[0], w[1],
            "pooled sidecar bytes diverged across shard counts / registries"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
