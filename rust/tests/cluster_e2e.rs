//! Multi-process cluster end-to-end: real `mixtab` binaries on
//! localhost — N backend processes plus a router process — driven over
//! TCP. Proves the distribution tier's three acceptance properties:
//!
//! (a) router fan-out/merge over 2 backends is result-identical to a
//!     single-process `ShardedIndex` holding the same corpus,
//! (b) killing one replica mid-run trips its cooloff, queries keep
//!     succeeding from the survivor, and recovery after a same-port
//!     restart is epoch-tagged in the router's metrics,
//! (c) shadow routing at fraction 0.5 never changes primary responses:
//!     divergence stays 0 against an identical-spec shadow and goes
//!     positive against a different hash family.

use mixtab::coordinator::config::CoordinatorConfig;
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::Client;
use mixtab::coordinator::Coordinator;
use mixtab::util::json::Json;
use mixtab::util::rng::Xoshiro256;
use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// A spawned `mixtab serve` process, killed on drop.
struct ServerProc {
    child: Child,
    addr: SocketAddr,
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl ServerProc {
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn the real binary and block until it prints its readiness line.
fn spawn_mixtab(args: &[String]) -> ServerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_mixtab"))
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn mixtab");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    let addr = loop {
        line.clear();
        let n = reader.read_line(&mut line).expect("read child stdout");
        assert!(n > 0, "mixtab exited before readiness: {args:?}");
        if let Some(rest) = line.strip_prefix("serving on ") {
            break rest
                .split_whitespace()
                .next()
                .expect("addr after 'serving on'")
                .parse()
                .expect("parse served addr");
        }
    };
    // Keep draining so the child never blocks on a full stdout pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    ServerProc { child, addr }
}

/// Reserve a localhost port (bind-then-drop).
fn free_port() -> u16 {
    TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

fn temp_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mixtab_cluster_e2e_{test}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_cfg(dir: &Path, name: &str, text: &str) -> String {
    let path = dir.join(name);
    std::fs::write(&path, text).unwrap();
    path.display().to_string()
}

/// Backend service config: small native-path spec, 2-way sharded.
fn backend_cfg(family: &str) -> String {
    format!(
        "[batcher]\nenable_pjrt = false\n\n[fh]\ndim = 32\nhash = \"{family}\"\n\n\
         [oph]\nk = 40\n\n[lsh]\nk = 4\nl = 6\nshards = 2\n"
    )
}

/// In-process reference matching [`backend_cfg`]'s spec exactly.
fn reference() -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 32,
        oph_k: 40,
        lsh_k: 4,
        lsh_l: 6,
        lsh_shards: 2,
        ..Default::default()
    })
}

fn spawn_backend(dir: &Path, name: &str, port: u16, family: &str) -> ServerProc {
    let cfg = write_cfg(dir, &format!("{name}.toml"), &backend_cfg(family));
    spawn_mixtab(&[
        "serve".into(),
        "--config".into(),
        cfg,
        "--listen".into(),
        format!("127.0.0.1:{port}"),
    ])
}

fn spawn_router(dir: &Path, cfg_text: &str, port: u16) -> ServerProc {
    let cfg = write_cfg(dir, "router.toml", cfg_text);
    spawn_mixtab(&[
        "serve".into(),
        "--router".into(),
        "--config".into(),
        cfg,
        "--listen".into(),
        format!("127.0.0.1:{port}"),
    ])
}

/// Clustered corpus: `clusters` groups of `members` sets sharing a
/// per-cluster core (high in-cluster Jaccard, so LSH neighbour sets are
/// non-trivial and family-sensitive).
fn clustered_sets(clusters: usize, members: usize, core: usize, unique: usize) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    for c in 0..clusters {
        let mut core_rng = Xoshiro256::stream(0xE2E0, c as u64);
        let core_set: Vec<u32> = (0..core).map(|_| core_rng.next_u32() % 1_000_000).collect();
        for m in 0..members {
            let mut rng = Xoshiro256::stream(0xE2E1, (c * members + m) as u64);
            let mut s = core_set.clone();
            s.extend((0..unique).map(|_| rng.next_u32() % 1_000_000));
            out.push(s);
        }
    }
    out
}

fn stats(addr: SocketAddr) -> Json {
    let mut c = Client::connect(addr).unwrap();
    let Response::Stats { json } = c.call(&Request::Stats).unwrap() else {
        panic!("expected stats")
    };
    json
}

fn counter(json: &Json, path: &[&str]) -> i64 {
    let mut v = json;
    for key in path {
        v = v
            .get(key)
            .unwrap_or_else(|| panic!("missing stats key {path:?}"));
    }
    v.as_i64().unwrap_or_else(|| panic!("non-int stats key {path:?}"))
}

/// Acceptance (a): the router over two backend processes answers every
/// query and estimate exactly like one single-process sharded index
/// holding the same corpus.
#[test]
fn router_fanout_matches_single_process_index() {
    let dir = temp_dir("fanout");
    let (p0, p1, rp) = (free_port(), free_port(), free_port());
    let _b0 = spawn_backend(&dir, "b0", p0, "mixed_tab");
    let _b1 = spawn_backend(&dir, "b1", p1, "mixed_tab");
    let router_cfg = format!(
        "{}\n[cluster]\nreplicas = 2\nread_timeout_ms = 5000\n\n\
         [[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:{p0}\"\n\n\
         [[backends]]\nname = \"b1\"\naddr = \"127.0.0.1:{p1}\"\n",
        backend_cfg("mixed_tab")
    );
    let router = spawn_router(&dir, &router_cfg, rp);

    let reference = reference();
    let sets = clustered_sets(30, 6, 30, 10);
    let mut c = Client::connect(router.addr).unwrap();
    for (i, set) in sets.iter().enumerate() {
        let got = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        assert_eq!(got, Response::Inserted { id: i as u32 }, "insert {i}");
        reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }
    let mut nonempty = 0;
    for (i, set) in sets.iter().enumerate().step_by(5) {
        let got = c
            .call(&Request::LshQuery {
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        let want = reference.handle(Request::LshQuery {
            set: set.clone(),
            scheme: None,
        });
        assert_eq!(got, want, "query {i}: cluster != single-process");
        if let Response::Candidates { ids } = &got {
            nonempty += usize::from(ids.len() > 1);
        }
    }
    assert!(nonempty > 0, "no query had neighbours — vacuous comparison");
    for (a, b) in [(0u32, 1u32), (10, 40), (33, 77)] {
        let got = c.call(&Request::Estimate { a, b, scheme: None }).unwrap();
        let want = reference.handle(Request::Estimate { a, b, scheme: None });
        assert_eq!(got, want, "estimate({a},{b})");
    }
    // Both backends actually took traffic, through a router snapshot.
    let s = stats(router.addr);
    assert_eq!(s.get("router").unwrap().as_bool(), Some(true));
    assert_eq!(counter(&s, &["lsh_inserts"]), sets.len() as i64);
    for b in ["b0", "b1"] {
        assert!(counter(&s, &["backends", b, "requests"]) > 0, "{b} idle");
        assert_eq!(counter(&s, &["backends", b, "errors"]), 0, "{b} errored");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (b): killing one replica mid-run trips its breaker while
/// queries keep answering exactly from the survivor; restarting it and
/// letting the cooloff lapse recovers it with an epoch tag.
#[test]
fn replica_death_cooloff_and_epoch_tagged_recovery() {
    let dir = temp_dir("cooloff");
    let (p0, p1, rp) = (free_port(), free_port(), free_port());
    let mut b0 = spawn_backend(&dir, "b0", p0, "mixed_tab");
    let _b1 = spawn_backend(&dir, "b1", p1, "mixed_tab");
    let router_cfg = format!(
        "{}\n[cluster]\nreplicas = 2\nerror_limit = 3\ncooloff_ms = 300\nread_timeout_ms = 5000\n\n\
         [[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:{p0}\"\n\n\
         [[backends]]\nname = \"b1\"\naddr = \"127.0.0.1:{p1}\"\n",
        backend_cfg("mixed_tab")
    );
    let router = spawn_router(&dir, &router_cfg, rp);

    let reference = reference();
    let sets = clustered_sets(20, 5, 30, 10);
    let mut c = Client::connect(router.addr).unwrap();
    for (i, set) in sets.iter().enumerate() {
        let got = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        assert_eq!(got, Response::Inserted { id: i as u32 });
        reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }

    // Kill replica b0 mid-run. Full replication means the survivor holds
    // every id: queries must keep answering *exactly*, while b0's
    // transport failures trip its breaker.
    b0.kill();
    for (i, set) in sets.iter().enumerate().step_by(7) {
        let got = c
            .call(&Request::LshQuery {
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        let want = reference.handle(Request::LshQuery {
            set: set.clone(),
            scheme: None,
        });
        assert_eq!(got, want, "query {i} wrong after replica death");
    }
    let s = stats(router.addr);
    assert!(counter(&s, &["backends", "b0", "errors"]) > 0);
    assert!(counter(&s, &["backends", "b0", "cooloff_trips"]) >= 1);
    assert_eq!(counter(&s, &["backends", "b0", "epoch"]), 0);
    assert_eq!(counter(&s, &["backends", "b1", "errors"]), 0);
    assert_eq!(
        s.get("backends").unwrap().get("b1").unwrap().get("state").unwrap().as_str(),
        Some("healthy")
    );

    // Same-port restart + cooloff lapse: the next fan-out admits b0's
    // probe, which succeeds and mints recovery epoch 1.
    let _b0_again = spawn_backend(&dir, "b0_restarted", p0, "mixed_tab");
    std::thread::sleep(Duration::from_millis(500));
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let got = c
            .call(&Request::LshQuery {
                set: sets[0].clone(),
                scheme: None,
            })
            .unwrap();
        let want = reference.handle(Request::LshQuery {
            set: sets[0].clone(),
            scheme: None,
        });
        assert_eq!(got, want, "query wrong during recovery");
        let s = stats(router.addr);
        if counter(&s, &["backends", "b0", "epoch"]) == 1 {
            assert_eq!(
                s.get("backends").unwrap().get("b0").unwrap().get("state").unwrap().as_str(),
                Some("healthy")
            );
            break;
        }
        assert!(Instant::now() < deadline, "b0 never recovered: {s:?}");
        std::thread::sleep(Duration::from_millis(100));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Drive a shadowed router: insert everything, query every stored set,
/// then wait for the mirror queue to drain and return the final stats.
/// Primary responses are asserted identical to the in-process reference
/// throughout — shadow traffic must never change what the client sees.
fn drive_shadowed(router_addr: SocketAddr, sets: &[Vec<u32>]) -> Json {
    let reference = reference();
    let mut c = Client::connect(router_addr).unwrap();
    for (i, set) in sets.iter().enumerate() {
        let got = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        assert_eq!(got, Response::Inserted { id: i as u32 });
        reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }
    for set in sets {
        let got = c
            .call(&Request::LshQuery {
                set: set.clone(),
                scheme: None,
            })
            .unwrap();
        let want = reference.handle(Request::LshQuery {
            set: set.clone(),
            scheme: None,
        });
        assert_eq!(got, want, "shadow routing changed a primary response");
    }
    // All writes mirror; fraction 0.5 mirrors every second read.
    let expected_mirrored = (sets.len() + sets.len() / 2) as i64;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = stats(router_addr);
        assert_eq!(counter(&s, &["shadow", "shed"]), 0, "mirror queue shed");
        assert_eq!(counter(&s, &["shadow", "errors"]), 0, "mirror transport errors");
        assert_eq!(counter(&s, &["shadow", "mirrored"]), expected_mirrored);
        if counter(&s, &["shadow", "compared"]) == expected_mirrored {
            return s;
        }
        assert!(Instant::now() < deadline, "mirror never drained: {s:?}");
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn shadow_router_cfg(primary: u16, shadow: u16) -> String {
    format!(
        "{}\n[cluster]\nreplicas = 1\nshadow_fraction = 0.5\nshadow_backend = \"cand\"\n\
         read_timeout_ms = 5000\n\n\
         [[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:{primary}\"\n\n\
         [[backends]]\nname = \"cand\"\naddr = \"127.0.0.1:{shadow}\"\nweight = 0\n",
        backend_cfg("mixed_tab")
    )
}

/// Acceptance (c), same spec: shadowing half the reads to an
/// identical-spec backend produces zero divergence — the schemes answer
/// identically on identical corpora, and the mirror proves it online.
#[test]
fn shadow_identical_spec_zero_divergence() {
    let dir = temp_dir("shadow_same");
    let (p0, ps, rp) = (free_port(), free_port(), free_port());
    let _b0 = spawn_backend(&dir, "b0", p0, "mixed_tab");
    let _cand = spawn_backend(&dir, "cand", ps, "mixed_tab");
    let router = spawn_router(&dir, &shadow_router_cfg(p0, ps), rp);

    let s = drive_shadowed(router.addr, &clustered_sets(25, 6, 30, 10));
    assert_eq!(
        counter(&s, &["shadow", "divergence"]),
        0,
        "identical specs must never diverge: {s:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance (c), different family: the same corpus under a different
/// hash family answers borderline queries differently — the mirror's
/// divergence counter is the paper's family comparison on live traffic.
#[test]
fn shadow_different_family_diverges() {
    let dir = temp_dir("shadow_diff");
    let (p0, ps, rp) = (free_port(), free_port(), free_port());
    let _b0 = spawn_backend(&dir, "b0", p0, "mixed_tab");
    let _cand = spawn_backend(&dir, "cand", ps, "murmur3");
    let router = spawn_router(&dir, &shadow_router_cfg(p0, ps), rp);

    let s = drive_shadowed(router.addr, &clustered_sets(25, 6, 30, 10));
    assert!(
        counter(&s, &["shadow", "divergence"]) > 0,
        "different hash families should disagree on some neighbour sets: {s:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
