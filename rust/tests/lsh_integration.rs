//! LSH end-to-end: recall/ratio behaviour on the generated datasets —
//! the machinery behind Figure 5.

use mixtab::data::mnist_like;
use mixtab::hash::HashFamily;
use mixtab::lsh::metrics::{ground_truth, BatchEval, QueryEval};
use mixtab::lsh::{LshIndex, LshParams};
use mixtab::sketch::SketchSpec;

fn build_index(
    db: &[Vec<u32>],
    family: HashFamily,
    params: LshParams,
    seed: u64,
) -> LshIndex {
    let mut idx = LshIndex::new(params, &SketchSpec::oph(family, seed, params.sketch_bins()));
    for (i, s) in db.iter().enumerate() {
        idx.insert(i as u32, s);
    }
    idx
}

#[test]
fn mnist_like_recall_is_high_with_mixed_tab() {
    let (db_ds, q_ds) = mnist_like::default_split(600, 60, 42);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();
    let idx = build_index(&db, HashFamily::MixedTab, LshParams::new(8, 12), 7);
    let mut batch = BatchEval::default();
    for q in &queries {
        let truth = ground_truth(&db, q, 0.5);
        if truth.is_empty() {
            continue;
        }
        let retrieved = idx.query(q);
        batch.push(QueryEval::evaluate(&retrieved, &truth, db.len()));
    }
    assert!(!batch.evals.is_empty(), "no queries with neighbours");
    let recall = batch.mean_recall();
    // MNIST-like has heavy near-duplicate structure (J ≈ 0.85 within
    // prototype): L=12 tables at K=8 recall most of them.
    assert!(recall > 0.6, "recall {recall}");
    // And LSH must beat the trivial scan on retrieved volume.
    assert!(batch.mean_fraction_retrieved() < 0.6);
}

#[test]
fn ratio_improves_with_k_on_mnist_like() {
    let (db_ds, q_ds) = mnist_like::default_split(500, 40, 3);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();
    let eval = |k: usize| {
        let idx = build_index(&db, HashFamily::MixedTab, LshParams::new(k, 10), 11);
        let mut batch = BatchEval::default();
        for q in &queries {
            let truth = ground_truth(&db, q, 0.5);
            if truth.is_empty() {
                continue;
            }
            batch.push(QueryEval::evaluate(&idx.query(q), &truth, db.len()));
        }
        batch
    };
    let k2 = eval(2);
    let k10 = eval(10);
    // Bigger K retrieves fewer points.
    assert!(
        k10.mean_retrieved() < k2.mean_retrieved(),
        "k10 {} vs k2 {}",
        k10.mean_retrieved(),
        k2.mean_retrieved()
    );
}

#[test]
fn empty_index_returns_nothing() {
    let idx = LshIndex::new(
        LshParams::new(4, 4),
        &SketchSpec::oph(HashFamily::MixedTab, 1, 16),
    );
    assert!(idx.query(&[1, 2, 3]).is_empty());
    assert!(idx.is_empty());
}

#[test]
fn duplicate_ids_both_retrieved() {
    let mut idx = LshIndex::new(
        LshParams::new(4, 6),
        &SketchSpec::oph(HashFamily::MixedTab, 5, 24),
    );
    let set: Vec<u32> = (0..200).collect();
    idx.insert(7, &set);
    idx.insert(8, &set);
    let got = idx.query(&set);
    assert!(got.contains(&7) && got.contains(&8));
}

/// Weak hashing inflates bucket sizes on structured (dense-id) data — the
/// mechanism behind multiply-shift's worse retrieved/recall ratio in
/// Figure 5.
#[test]
fn multiply_shift_buckets_heavier_on_dense_ids() {
    // Database of structured sets: consecutive-id blocks (MNIST-like
    // support structure distilled to its essence).
    let db: Vec<Vec<u32>> = (0..400)
        .map(|i| ((i * 37) % 2000..((i * 37) % 2000) + 160).collect())
        .collect();
    let max_bucket = |fam: HashFamily| {
        let mut worst = 0usize;
        for seed in 0..12u64 {
            let idx = build_index(&db, fam, LshParams::new(10, 10), seed);
            worst = worst.max(idx.max_bucket());
        }
        worst
    };
    let ms = max_bucket(HashFamily::MultiplyShift);
    let mt = max_bucket(HashFamily::MixedTab);
    assert!(
        ms >= mt,
        "multiply-shift max bucket {ms} should be ≥ mixed tab {mt}"
    );
}
