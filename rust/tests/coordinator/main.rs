//! Deterministic concurrency harness for the event-driven server.
//!
//! The server's per-connection protocol machine ([`ConnState`]) does no
//! IO and takes every timestamp as a parameter, so these suites drive it
//! with scripted byte sequences and fake clocks — exact interleavings,
//! no wall-clock sleeps, no real sockets. The TCP suites then prove the
//! same properties end-to-end: pipelined out-of-order responses, the
//! cross-connection op batcher's bit-identity with sequential serving
//! for every sketch family, connection caps, and panic containment.
//!
//! Registered in Cargo.toml as the `coordinator` test target; the CI
//! `test-stress` job runs it single-threaded with the `#[ignore]`d soak
//! included.

mod batching;
mod cluster;
mod framing;
mod limits;
mod loadtest;
mod pipeline;
mod soak;

use mixtab::coordinator::config::{CoordinatorConfig, SchemeConfig};
use mixtab::coordinator::Coordinator;
use mixtab::hash::HashFamily;
use mixtab::sketch::feature_hash::SignMode;
use mixtab::sketch::SketchSpec;
use mixtab::util::rng::Xoshiro256;
use std::sync::Arc;

/// Base config: native path, small parameters, fast to construct.
pub fn base_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 32,
        oph_k: 40,
        lsh_k: 4,
        lsh_l: 6,
        lsh_shards: 2,
        ..Default::default()
    }
}

/// One named scheme per sketch family (plus the default OPH scheme), so
/// a single coordinator serves all five families the paper's estimators
/// cover: oph, minhash, simhash, featurehash, bbit.
pub fn five_family_cfg() -> CoordinatorConfig {
    CoordinatorConfig {
        schemes: vec![
            SchemeConfig {
                name: "mh".into(),
                spec: SketchSpec::minhash(HashFamily::MixedTab, 11, 24),
                shards: 1,
            },
            SchemeConfig {
                name: "sh".into(),
                spec: SketchSpec::simhash(HashFamily::MixedTab, 13, 64),
                shards: 1,
            },
            SchemeConfig {
                name: "fh".into(),
                spec: SketchSpec::feature_hash(HashFamily::MixedTab, 17, 32, SignMode::Paired),
                shards: 1,
            },
            SchemeConfig {
                name: "bb".into(),
                spec: SketchSpec::bbit(HashFamily::MixedTab, 19, 2, 32),
                shards: 1,
            },
        ],
        ..base_cfg()
    }
}

/// The scheme selectors covering all five families on one coordinator.
pub const FAMILY_SCHEMES: [Option<&str>; 5] =
    [None, Some("mh"), Some("sh"), Some("fh"), Some("bb")];

pub fn coordinator(cfg: CoordinatorConfig) -> Arc<Coordinator> {
    Arc::new(Coordinator::new(cfg))
}

/// Seeded test set: `n` elements drawn from a bounded universe (dense
/// enough for LSH collisions at the harness's small K×L).
pub fn seeded_set(seed: u64, stream: u64, n: usize) -> Vec<u32> {
    let mut rng = Xoshiro256::stream(seed, stream);
    (0..n).map(|_| rng.next_u32() % 50_000).collect()
}
