//! Soak: 256 concurrent pipelined clients against one event loop.
//!
//! Ignored by default — the CI `test-stress` job runs it (single-
//! threaded, under a job timeout) via `--include-ignored`.

use crate::{base_cfg, coordinator, seeded_set};
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{PipelinedClient, Server};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const CLIENTS: usize = 256;
const OPS: usize = 16;
const WINDOW: usize = 8;

#[derive(Clone, Copy)]
enum Kind {
    Insert(u32),
    Query,
    Sketch,
    Stats,
}

#[test]
#[ignore = "stress soak: run by the CI test-stress job (or --include-ignored)"]
fn soak_256_pipelined_clients() {
    let mut cfg = base_cfg();
    cfg.request_workers = 4;
    cfg.conn_queue_cap = 32;
    let c = coordinator(cfg);
    let server = Server::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let handles: Vec<_> = (0..CLIENTS)
        .map(|cl| {
            std::thread::spawn(move || {
                let mut conn = PipelinedClient::connect(addr).unwrap();
                let mut pending: HashMap<u64, Kind> = HashMap::new();
                let (mut sent, mut done) = (0usize, 0usize);
                while done < OPS {
                    while sent < OPS && pending.len() < WINDOW {
                        let uid = (cl * OPS + sent) as u64;
                        let (req, kind) = match sent % 4 {
                            0 => (
                                Request::LshInsert {
                                    id: uid as u32,
                                    set: seeded_set(5, uid, 30),
                                    scheme: None,
                                },
                                Kind::Insert(uid as u32),
                            ),
                            1 => (
                                Request::LshQuery {
                                    set: seeded_set(5, uid, 30),
                                    scheme: None,
                                },
                                Kind::Query,
                            ),
                            2 => (
                                Request::Sketch {
                                    set: seeded_set(5, uid, 30),
                                    spec: None,
                                    scheme: None,
                                },
                                Kind::Sketch,
                            ),
                            _ => (Request::Stats, Kind::Stats),
                        };
                        let rid = conn.send(&req).unwrap();
                        pending.insert(rid, kind);
                        sent += 1;
                    }
                    let (rid, resp) = conn.recv().unwrap();
                    match pending.remove(&rid.expect("tagged")).expect("known rid") {
                        Kind::Insert(id) => assert_eq!(resp, Response::Inserted { id }),
                        Kind::Query => assert!(matches!(resp, Response::Candidates { .. })),
                        Kind::Sketch => assert!(matches!(resp, Response::SketchValue { .. })),
                        Kind::Stats => assert!(matches!(resp, Response::Stats { .. })),
                    }
                    done += 1;
                }
                assert!(pending.is_empty());
                done
            })
        })
        .collect();
    let total: usize = handles.into_iter().map(|h| h.join().expect("client")).sum();
    assert_eq!(total, CLIENTS * OPS);

    // The pool decrements in-flight after the completion is sent, so a
    // client can observe its last response a beat before the counter
    // drains — poll with a bound instead of asserting immediately.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.requests_in_flight() != 0 {
        assert!(Instant::now() < deadline, "in-flight never drained");
        std::thread::yield_now();
    }

    assert_eq!(server.connection_count(), CLIENTS);
    assert_eq!(
        c.metrics.pipelined_requests.load(Ordering::Relaxed),
        (CLIENTS * OPS) as u64
    );
    assert_eq!(
        c.metrics.lsh_inserts.load(Ordering::Relaxed),
        (CLIENTS * OPS / 4) as u64
    );
    assert_eq!(c.metrics.errors.load(Ordering::Relaxed), 0);
    server.stop();
}
