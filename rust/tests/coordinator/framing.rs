//! Scripted-framer suite: drives [`ConnState`] — the server's IO-free
//! per-connection protocol machine — with exact byte sequences, fake
//! clocks, and hand-ordered completions. Every interleaving here is
//! deterministic: no sockets, no threads, no sleeps.

use crate::base_cfg;
use mixtab::coordinator::metrics::Metrics;
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{ConnState, Dispatch};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn err(msg: &str) -> Response {
    Response::Error {
        message: msg.into(),
    }
}

type Drained = (Vec<(Option<u64>, Response)>, Vec<Dispatch>);

/// Drain the outbound queue with full writes, decoding each line; also
/// returns any dispatches unblocked by the freed capacity.
fn drain_output(cs: &mut ConnState, now: Instant) -> Drained {
    let mut lines = Vec::new();
    let mut dispatches = Vec::new();
    while let Some(chunk) = cs.next_write().map(<[u8]>::to_vec) {
        dispatches.extend(cs.advance_write(chunk.len(), now));
        let text = String::from_utf8(chunk).expect("utf8 response line");
        for l in text.lines() {
            lines.push(Response::from_json_line_tagged(l).expect("decode response"));
        }
    }
    (lines, dispatches)
}

#[test]
fn frames_split_across_reads_and_ordered_lane_serializes() {
    let cfg = base_cfg();
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    let wire = format!(
        "{}\n{}\n",
        Request::Stats.to_json_line(),
        Request::OphSketch { set: vec![1, 2, 3] }.to_json_line()
    );
    // Trickle the bytes in 3-byte reads: nothing dispatches until a
    // newline completes a frame, and the ordered lane holds the second
    // request while the first is in flight.
    let mut dispatched = Vec::new();
    for chunk in wire.as_bytes().chunks(3) {
        dispatched.extend(cs.on_bytes(chunk, t0));
    }
    assert_eq!(dispatched.len(), 1);
    assert!(matches!(
        dispatched[0],
        Dispatch {
            rid: None,
            req: Request::Stats
        }
    ));
    assert_eq!(cs.pending(), 2, "one in flight, one queued");
    // Completing the first unblocks the second.
    let next = cs.on_response(None, &err("r1"), t0);
    assert_eq!(next.len(), 1);
    assert!(next[0].rid.is_none());
    assert!(matches!(&next[0].req, Request::OphSketch { .. }));
    assert!(cs.on_response(None, &err("r2"), t0).is_empty());
    // Responses drain in order, untagged — the legacy wire format.
    let (lines, unblocked) = drain_output(&mut cs, t0);
    assert!(unblocked.is_empty());
    assert_eq!(lines, vec![(None, err("r1")), (None, err("r2"))]);
    assert_eq!(cs.pending(), 0);
    assert!(!cs.should_close(t0));
}

#[test]
fn tagged_requests_dispatch_concurrently_and_echo_rids_out_of_order() {
    let cfg = base_cfg();
    let metrics = Arc::new(Metrics::new());
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::clone(&metrics), t0);
    let mut wire = String::new();
    for rid in [7u64, 9, 11] {
        wire.push_str(&Request::Stats.to_json_line_tagged(rid));
        wire.push('\n');
    }
    let ds = cs.on_bytes(wire.as_bytes(), t0);
    assert_eq!(
        ds.iter().map(|d| d.rid).collect::<Vec<_>>(),
        vec![Some(7), Some(9), Some(11)],
        "tagged lane has no serialization"
    );
    assert_eq!(metrics.pipelined_requests.load(Ordering::Relaxed), 3);
    // Complete out of order; each response line echoes its tag.
    for rid in [9u64, 11, 7] {
        assert!(cs
            .on_response(Some(rid), &err(&format!("r{rid}")), t0)
            .is_empty());
    }
    let (lines, _) = drain_output(&mut cs, t0);
    assert_eq!(
        lines.iter().map(|(r, _)| r.unwrap()).collect::<Vec<_>>(),
        vec![9, 11, 7],
        "responses return in completion order, mapped by rid"
    );
}

#[test]
fn ordered_lane_stays_sequential_amid_tagged_traffic() {
    let cfg = base_cfg();
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    // u1, t5, u2, t6 on the wire: both tagged dispatch immediately, the
    // ordered pair strictly one at a time.
    let wire = format!(
        "{}\n{}\n{}\n{}\n",
        Request::OphSketch { set: vec![1] }.to_json_line(),
        Request::Stats.to_json_line_tagged(5),
        Request::OphSketch { set: vec![2] }.to_json_line(),
        Request::Stats.to_json_line_tagged(6),
    );
    let ds = cs.on_bytes(wire.as_bytes(), t0);
    assert_eq!(
        ds.iter().map(|d| d.rid).collect::<Vec<_>>(),
        vec![Some(5), Some(6), None]
    );
    assert!(matches!(&ds[2].req, Request::OphSketch { set } if set == &vec![1]));
    assert_eq!(cs.pending(), 4);
    // Tagged completions never release the ordered lane.
    assert!(cs.on_response(Some(5), &err("t5"), t0).is_empty());
    assert!(cs.on_response(Some(6), &err("t6"), t0).is_empty());
    // Only u1's completion dispatches u2.
    let next = cs.on_response(None, &err("u1"), t0);
    assert_eq!(next.len(), 1);
    assert!(matches!(&next[0].req, Request::OphSketch { set } if set == &vec![2]));
    assert!(cs.on_response(None, &err("u2"), t0).is_empty());
    let (lines, _) = drain_output(&mut cs, t0);
    assert_eq!(lines.len(), 4);
}

#[test]
fn pending_cap_gates_extraction_until_writes_drain() {
    let mut cfg = base_cfg();
    cfg.conn_queue_cap = 2;
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    let mut wire = String::new();
    for rid in 0..5u64 {
        wire.push_str(&Request::Stats.to_json_line_tagged(rid));
        wire.push('\n');
    }
    let ds = cs.on_bytes(wire.as_bytes(), t0);
    assert_eq!(
        ds.iter().map(|d| d.rid).collect::<Vec<_>>(),
        vec![Some(0), Some(1)],
        "extraction stops at the pending cap"
    );
    assert!(!cs.wants_read(), "backpressure: stop reading the socket");
    // A completion alone frees nothing — the response line still holds a
    // pending slot until it is written out.
    assert!(cs.on_response(Some(0), &err("r0"), t0).is_empty());
    assert_eq!(cs.pending(), 2);
    // Write drain frees the slot and resumes extraction, one frame per
    // freed slot.
    let (lines, unblocked) = drain_output(&mut cs, t0);
    assert_eq!(lines.len(), 1);
    assert_eq!(unblocked.iter().map(|d| d.rid).collect::<Vec<_>>(), vec![Some(2)]);
    assert!(!cs.wants_read(), "cap re-filled by the resumed frame");
    // Keep completing + draining: the remaining frames flow through.
    let mut seen = Vec::new();
    for rid in [1u64, 2] {
        assert!(cs.on_response(Some(rid), &err("r"), t0).is_empty());
        let (lines, unblocked) = drain_output(&mut cs, t0);
        seen.extend(lines);
        assert_eq!(unblocked.len(), 1);
    }
    assert_eq!(cs.pending(), 2, "rids 3 and 4 now in flight");
    for rid in [3u64, 4] {
        assert!(cs.on_response(Some(rid), &err("r"), t0).is_empty());
    }
    let (lines, unblocked) = drain_output(&mut cs, t0);
    assert!(unblocked.is_empty());
    seen.extend(lines);
    assert_eq!(seen.len(), 4);
    assert_eq!(cs.pending(), 0);
    assert!(cs.wants_read());
}

#[test]
fn throttle_errors_echo_rid_and_token_refill_restores_service() {
    let mut cfg = base_cfg();
    cfg.rate_limit_rps = 1.0;
    cfg.rate_limit_burst = 2;
    let metrics = Arc::new(Metrics::new());
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::clone(&metrics), t0);
    let mut wire = String::new();
    for rid in [1u64, 2, 3] {
        wire.push_str(&Request::Stats.to_json_line_tagged(rid));
        wire.push('\n');
    }
    let ds = cs.on_bytes(wire.as_bytes(), t0);
    assert_eq!(
        ds.iter().map(|d| d.rid).collect::<Vec<_>>(),
        vec![Some(1), Some(2)],
        "burst of 2 admits exactly 2"
    );
    assert_eq!(metrics.throttled.load(Ordering::Relaxed), 1);
    // Blank keep-alive lines are free: no admission charge, no response.
    assert!(cs.on_bytes(b"\n \n", t0).is_empty());
    assert_eq!(metrics.throttled.load(Ordering::Relaxed), 1);
    // The rejection was synthesized before parse, yet still echoes the
    // tag so a pipelined client can map it.
    let (lines, _) = drain_output(&mut cs, t0);
    assert_eq!(lines.len(), 1);
    let (rid, Response::Error { message }) = lines[0].clone() else {
        panic!("expected error");
    };
    assert_eq!(rid, Some(3));
    assert!(message.contains("rate limited"), "got: {message}");
    // One second of fake clock buys exactly one more token.
    let t1 = t0 + Duration::from_secs(1);
    let ds = cs.on_bytes(
        format!("{}\n", Request::Stats.to_json_line_tagged(4)).as_bytes(),
        t1,
    );
    assert_eq!(ds.len(), 1);
    let ds = cs.on_bytes(
        format!("{}\n", Request::Stats.to_json_line_tagged(5)).as_bytes(),
        t1,
    );
    assert!(ds.is_empty());
    assert_eq!(metrics.throttled.load(Ordering::Relaxed), 2);
}

#[test]
fn budget_exhaustion_drains_admitted_work_then_closes() {
    let mut cfg = base_cfg();
    cfg.conn_request_budget = 2;
    let metrics = Arc::new(Metrics::new());
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::clone(&metrics), t0);
    let mut wire = String::new();
    for rid in [1u64, 2, 3, 4] {
        wire.push_str(&Request::Stats.to_json_line_tagged(rid));
        wire.push('\n');
    }
    let ds = cs.on_bytes(wire.as_bytes(), t0);
    assert_eq!(
        ds.iter().map(|d| d.rid).collect::<Vec<_>>(),
        vec![Some(1), Some(2)]
    );
    assert_eq!(
        metrics.throttled.load(Ordering::Relaxed),
        1,
        "budget rejection counts as throttled"
    );
    assert!(!cs.wants_read(), "no frames read past the budget error");
    assert!(
        !cs.should_close(t0),
        "admitted work drains before the close"
    );
    // In-flight completions still flow out.
    assert!(cs.on_response(Some(1), &err("r1"), t0).is_empty());
    assert!(cs.on_response(Some(2), &err("r2"), t0).is_empty());
    let (lines, _) = drain_output(&mut cs, t0);
    // The budget error was enqueued at admission time, ahead of the two
    // completions; rid 4 was never admitted at all.
    assert_eq!(lines.len(), 3);
    assert_eq!(lines[0].0, Some(3));
    let Response::Error { message } = &lines[0].1 else {
        panic!("expected error");
    };
    assert!(message.contains("budget exhausted"), "got: {message}");
    assert_eq!(lines[1].0, Some(1));
    assert_eq!(lines[2].0, Some(2));
    assert!(cs.should_close(t0), "drained: now close");
}

#[test]
fn oversized_line_yields_one_error_then_close() {
    let cfg = base_cfg();
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    cs.set_max_line(64);
    let ds = cs.on_bytes(&[b'x'; 80], t0);
    assert!(ds.is_empty());
    assert!(!cs.wants_read());
    let (lines, _) = drain_output(&mut cs, t0);
    assert_eq!(lines.len(), 1);
    let (rid, Response::Error { message }) = lines[0].clone() else {
        panic!("expected error");
    };
    assert_eq!(rid, None);
    assert!(message.contains("byte limit"), "got: {message}");
    assert!(cs.should_close(t0));
}

#[test]
fn idle_timeout_fires_on_fake_clock_only_when_quiescent() {
    let mut cfg = base_cfg();
    cfg.idle_timeout_ms = 50;
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    let ms = Duration::from_millis;
    assert!(!cs.idle_expired(t0 + ms(49)));
    assert!(cs.idle_expired(t0 + ms(50)));
    assert!(cs.should_close(t0 + ms(50)));
    // Any byte resets the window — even a partial frame.
    let t1 = t0 + ms(40);
    assert!(cs.on_bytes(b"{\"op\":", t1).is_empty());
    assert!(!cs.idle_expired(t1 + ms(49)));
    assert!(cs.idle_expired(t1 + ms(50)));
    // Never fires while a request is in flight, however long it runs.
    let t2 = t1 + ms(10);
    let ds = cs.on_bytes(b"\"stats\",\"rid\":1}\n", t2);
    assert_eq!(ds.len(), 1, "split frame completed and dispatched");
    assert!(!cs.idle_expired(t2 + Duration::from_secs(3600)));
    // The window restarts from the last write of the response.
    let t3 = t2 + ms(5);
    assert!(cs.on_response(Some(1), &err("r"), t3).is_empty());
    let t4 = t3 + ms(5);
    let (lines, _) = drain_output(&mut cs, t4);
    assert_eq!(lines.len(), 1);
    assert!(!cs.idle_expired(t4 + ms(49)));
    assert!(cs.idle_expired(t4 + ms(50)));
}

#[test]
fn eof_serves_final_unterminated_line_then_closes() {
    let cfg = base_cfg();
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    // The old blocking reader served a final line missing its newline;
    // the event loop keeps that contract.
    assert!(cs.on_bytes(b"{\"op\":\"stats\"}", t0).is_empty());
    let ds = cs.on_eof(t0);
    assert_eq!(ds.len(), 1);
    assert!(matches!(ds[0].req, Request::Stats));
    assert!(!cs.should_close(t0), "response still owed");
    assert!(cs.on_response(None, &err("r"), t0).is_empty());
    let (lines, _) = drain_output(&mut cs, t0);
    assert_eq!(lines.len(), 1);
    assert!(cs.should_close(t0));
}

#[test]
fn partial_writes_resume_mid_line_and_untagged_format_is_legacy() {
    let cfg = base_cfg();
    let t0 = Instant::now();
    let mut cs = ConnState::new(&cfg, Arc::new(Metrics::new()), t0);
    let ds = cs.on_bytes(
        format!("{}\n", Request::Stats.to_json_line()).as_bytes(),
        t0,
    );
    assert_eq!(ds.len(), 1);
    let resp = err("hello");
    cs.on_response(None, &resp, t0);
    // Untagged responses serialize byte-identically to the pre-pipelining
    // wire format.
    assert_eq!(resp.to_json_line_tagged(None), resp.to_json_line());
    let expected = format!("{}\n", resp.to_json_line()).into_bytes();
    assert_eq!(cs.next_write().unwrap(), &expected[..]);
    // A short write leaves the tail exactly where it stopped.
    assert!(cs.advance_write(5, t0).is_empty());
    assert_eq!(cs.next_write().unwrap(), &expected[5..]);
    assert!(cs.advance_write(expected.len() - 5, t0).is_empty());
    assert!(cs.next_write().is_none());
    assert_eq!(cs.pending(), 0);
}
