//! Global connection-cap and idle-timeout behaviour over real TCP.

use crate::{base_cfg, coordinator};
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{Client, Server};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Connection N+1 past `max_connections` gets a clean one-line error and
/// an orderly close — never a hang — and a freed slot is re-admittable.
#[test]
fn connection_cap_sheds_cleanly_and_recovers() {
    let mut cfg = base_cfg();
    cfg.max_connections = 2;
    let c = coordinator(cfg);
    let server = Server::start(Arc::clone(&c), "127.0.0.1:0").unwrap();
    let addr = server.addr();

    let mut c1 = Client::connect(addr).unwrap();
    let mut c2 = Client::connect(addr).unwrap();
    assert!(matches!(c1.call(&Request::Stats).unwrap(), Response::Stats { .. }));
    assert!(matches!(c2.call(&Request::Stats).unwrap(), Response::Stats { .. }));

    // Third connection: shed with a parseable error line, then EOF.
    let over = TcpStream::connect(addr).unwrap();
    over.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(over);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = Response::from_json_line(line.trim_end()).unwrap();
    let Response::Error { message } = resp else {
        panic!("capacity shed must be a wire error, got {resp:?}");
    };
    assert!(message.contains("capacity"), "got: {message}");
    line.clear();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "clean EOF after shed");
    assert_eq!(c.metrics.conns_rejected.load(Ordering::Relaxed), 1);

    // Admitted connections are unaffected by the shed…
    assert!(matches!(c2.call(&Request::Stats).unwrap(), Response::Stats { .. }));

    // …and closing one frees its slot for a new client.
    drop(c1);
    let mut admitted = false;
    for _ in 0..400 {
        if let Ok(mut fresh) = Client::connect(addr) {
            if matches!(fresh.call(&Request::Stats), Ok(Response::Stats { .. })) {
                admitted = true;
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(admitted, "freed slot was never re-admitted");
    server.stop();
}

/// A connection quiet past `idle_timeout_ms` is reaped: the server
/// closes the socket (blocking read sees EOF) and counts the reap.
#[test]
fn idle_timeout_reaps_quiet_connections_over_tcp() {
    let mut cfg = base_cfg();
    cfg.idle_timeout_ms = 150;
    let c = coordinator(cfg);
    let server = Server::start(Arc::clone(&c), "127.0.0.1:0").unwrap();

    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    // One served request proves the connection is live…
    stream
        .write_all(format!("{}\n", Request::Stats.to_json_line()).as_bytes())
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(matches!(
        Response::from_json_line(line.trim_end()).unwrap(),
        Response::Stats { .. }
    ));
    // …then we go quiet and the server must hang up on us.
    let mut rest = Vec::new();
    let n = reader.read_to_end(&mut rest).unwrap();
    assert_eq!(n, 0, "idle close must not write anything");
    assert_eq!(c.metrics.idle_closed.load(Ordering::Relaxed), 1);
    server.stop();
}
