//! The loadtest driver against a live server: the windowed multi-client
//! engine `mixtab loadtest` (and the coordinator bench) measures with.
//!
//! These suites prove the driver's accounting — every op answered exactly
//! once, `Response::Error` counted rather than dropped, one latency
//! sample per op — and that the op stream reaches the coordinator as the
//! pure-function-of-index workload promises.

use crate::{base_cfg, coordinator, seeded_set};
use mixtab::coordinator::request::Request;
use mixtab::coordinator::server::Server;
use mixtab::loadtest::driver::drive;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// 4 clients × mixed insert/query stream: every op is answered, counted,
/// and latency-sampled, and the server's metrics agree with the op mix.
#[test]
fn drive_accounts_for_every_op() {
    let c = coordinator(base_cfg());
    let metrics = Arc::clone(&c.metrics);
    let server = Server::start(c, "127.0.0.1:0").unwrap();
    let ops = 400usize;
    let stats = drive(server.addr(), 4, ops, 8, |i| {
        let set = seeded_set(31, i as u64, 40);
        if i % 4 == 0 {
            Request::LshQuery { set, scheme: None }
        } else {
            Request::LshInsert {
                id: i as u32,
                set,
                scheme: None,
            }
        }
    })
    .unwrap();
    server.stop();
    assert_eq!(stats.ok, ops as u64, "every op answered cleanly");
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.total(), ops as u64);
    assert_eq!(
        stats.latency_us.values().len(),
        ops,
        "one latency sample per op"
    );
    assert!(stats.wall_secs > 0.0 && stats.qps() > 0.0);
    assert_eq!(metrics.lsh_queries.load(Ordering::Relaxed), (ops / 4) as u64);
    assert_eq!(
        metrics.lsh_inserts.load(Ordering::Relaxed),
        (ops - ops / 4) as u64
    );
}

/// `Response::Error` is an *outcome*, not a wire failure: the driver keeps
/// the pipeline full and reports errors in the stats instead of bailing.
#[test]
fn drive_counts_error_responses() {
    let server = Server::start(coordinator(base_cfg()), "127.0.0.1:0").unwrap();
    let ops = 60usize;
    let stats = drive(server.addr(), 2, ops, 4, |i| {
        let set = seeded_set(32, i as u64, 20);
        let scheme = (i % 3 == 0).then(|| "no-such-scheme".to_string());
        Request::LshQuery { set, scheme }
    })
    .unwrap();
    server.stop();
    assert_eq!(stats.errors, ops as u64 / 3, "unknown scheme → error per op");
    assert_eq!(stats.ok, ops as u64 - stats.errors);
    assert_eq!(stats.total(), ops as u64);
}

/// More clients than ops: surplus connections exit cleanly and the
/// accounting still balances.
#[test]
fn drive_with_more_clients_than_ops() {
    let server = Server::start(coordinator(base_cfg()), "127.0.0.1:0").unwrap();
    let stats = drive(server.addr(), 8, 3, 16, |i| Request::LshInsert {
        id: i as u32,
        set: seeded_set(33, i as u64, 10),
        scheme: None,
    })
    .unwrap();
    server.stop();
    assert_eq!(stats.ok, 3);
    assert_eq!(stats.errors, 0);
}

/// The driver is deterministic in its *workload* (not its timing): two
/// drives of the same pure op stream leave the server with identical
/// insert/query counts.
#[test]
fn drive_workload_is_reproducible() {
    let mut counts = Vec::new();
    for _ in 0..2 {
        let c = coordinator(base_cfg());
        let metrics = Arc::clone(&c.metrics);
        let server = Server::start(c, "127.0.0.1:0").unwrap();
        let stats = drive(server.addr(), 3, 90, 8, |i| {
            let set = seeded_set(34, i as u64, 30);
            if i % 2 == 0 {
                Request::LshInsert {
                    id: i as u32,
                    set,
                    scheme: None,
                }
            } else {
                Request::LshQuery { set, scheme: None }
            }
        })
        .unwrap();
        server.stop();
        assert_eq!(stats.total(), 90);
        counts.push((
            metrics.lsh_inserts.load(Ordering::Relaxed),
            metrics.lsh_queries.load(Ordering::Relaxed),
        ));
    }
    assert_eq!(counts[0], counts[1]);
    assert_eq!(counts[0], (45, 45));
}
