//! OpBatcher semantics (fill vs deadline vs shed, queue-full rejection,
//! drain-on-shutdown) with an injected executor, plus the central
//! property: ops batched *across connections* are bit-identical to
//! sequential per-request serving for all five sketch families.

use crate::{coordinator, five_family_cfg, seeded_set, FAMILY_SCHEMES};
use mixtab::coordinator::batcher::{BatchOp, OpBatcher, OpExecutor, OpJob};
use mixtab::coordinator::metrics::Metrics;
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{Client, PipelinedClient, Server};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

const WAIT: Duration = Duration::from_secs(30);

fn err(msg: &str) -> Response {
    Response::Error {
        message: msg.into(),
    }
}

/// Records batch sizes in dispatch order; completes every job.
struct RecordingExec {
    batches: Mutex<Vec<usize>>,
}

impl OpExecutor for RecordingExec {
    fn run_ops(&self, jobs: Vec<OpJob>) {
        self.batches.lock().unwrap().push(jobs.len());
        for j in jobs {
            j.complete(err("done"));
        }
    }
}

/// Blocks inside `run_ops` until released; signals entry first.
struct GatedExec {
    entered: mpsc::Sender<()>,
    gate: Mutex<mpsc::Receiver<()>>,
}

impl OpExecutor for GatedExec {
    fn run_ops(&self, jobs: Vec<OpJob>) {
        self.entered.send(()).expect("test alive");
        self.gate.lock().unwrap().recv().expect("released");
        for j in jobs {
            j.complete(err("batched"));
        }
    }
}

fn submit_tagged(
    batcher: &OpBatcher,
    done_tx: &mpsc::Sender<&'static str>,
    tag: &'static str,
) -> std::result::Result<(), OpJob> {
    let tx = done_tx.clone();
    batcher.submit(OpJob {
        scheme: None,
        op: BatchOp::Query { set: vec![1] },
        done: Box::new(move |_| tx.send(tag).expect("test alive")),
    })
}

#[test]
fn fill_trigger_dispatches_exactly_at_max_batch() {
    let exec = Arc::new(RecordingExec {
        batches: Mutex::new(Vec::new()),
    });
    let metrics = Arc::new(Metrics::new());
    // 10s deadline: only the fill trigger can plausibly dispatch.
    let batcher = OpBatcher::spawn(
        Arc::clone(&exec) as Arc<dyn OpExecutor>,
        4,
        10_000_000,
        64,
        Arc::clone(&metrics),
    );
    let (tx, rx) = mpsc::channel();
    for i in 0..8u32 {
        let tx = tx.clone();
        batcher
            .submit(OpJob {
                scheme: None,
                op: BatchOp::Sketch { set: vec![i] },
                done: Box::new(move |_| tx.send(()).expect("test alive")),
            })
            .unwrap_or_else(|_| panic!("queue unexpectedly full"));
    }
    for _ in 0..8 {
        rx.recv_timeout(WAIT).expect("every job completes");
    }
    assert_eq!(
        *exec.batches.lock().unwrap(),
        vec![4, 4],
        "fill trigger cuts batches at max_batch, never waits for the deadline"
    );
    assert_eq!(metrics.op_batches.load(Ordering::Relaxed), 2);
    assert_eq!(metrics.op_batch_rows.load(Ordering::Relaxed), 8);
    drop(batcher);
}

#[test]
fn deadline_trigger_dispatches_partial_batches() {
    let exec = Arc::new(RecordingExec {
        batches: Mutex::new(Vec::new()),
    });
    // max_batch 100 can never fill from 3 jobs: only the deadline can
    // dispatch them. If the deadline path were broken this would hang
    // (and the recv_timeout below would fail), not flake.
    let batcher = OpBatcher::spawn(
        Arc::clone(&exec) as Arc<dyn OpExecutor>,
        100,
        2_000,
        64,
        Arc::new(Metrics::new()),
    );
    let (tx, rx) = mpsc::channel();
    for i in 0..3u32 {
        let tx = tx.clone();
        batcher
            .submit(OpJob {
                scheme: None,
                op: BatchOp::Insert {
                    id: i,
                    set: vec![i],
                },
                done: Box::new(move |_| tx.send(()).expect("test alive")),
            })
            .unwrap_or_else(|_| panic!("queue unexpectedly full"));
    }
    for _ in 0..3 {
        rx.recv_timeout(WAIT).expect("deadline dispatches partial batch");
    }
    let sizes = exec.batches.lock().unwrap().clone();
    assert_eq!(sizes.iter().sum::<usize>(), 3);
    assert!(
        sizes.iter().all(|&s| s < 100),
        "no batch ever filled: {sizes:?}"
    );
    drop(batcher);
}

#[test]
fn queue_full_sheds_job_back_to_caller_ahead_of_parked_work() {
    let (entered_tx, entered_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel();
    let exec = Arc::new(GatedExec {
        entered: entered_tx,
        gate: Mutex::new(release_rx),
    });
    // max_batch 1 + queue_cap 1: one job in run_ops, one in the queue,
    // the third must be handed back.
    let batcher = OpBatcher::spawn(exec as Arc<dyn OpExecutor>, 1, 0, 1, Arc::new(Metrics::new()));
    let (done_tx, done_rx) = mpsc::channel::<&'static str>();
    submit_tagged(&batcher, &done_tx, "A").expect("A accepted");
    entered_rx.recv_timeout(WAIT).expect("A entered run_ops");
    submit_tagged(&batcher, &done_tx, "B").expect("B queued");
    let rejected = submit_tagged(&batcher, &done_tx, "C").expect_err("C shed");
    // The shed job comes back payload-intact — load shedding, not loss.
    assert_eq!(rejected.op, BatchOp::Query { set: vec![1] });
    // The caller runs it directly: its completion lands while A and B
    // are still parked — shed work is never stuck behind the queue it
    // failed to enter.
    rejected.complete(err("direct"));
    assert_eq!(done_rx.recv_timeout(WAIT).unwrap(), "C");
    // Release the gate twice (A's batch, then B's): submit order holds
    // for accepted jobs.
    release_tx.send(()).expect("batcher alive");
    release_tx.send(()).expect("batcher alive");
    assert_eq!(done_rx.recv_timeout(WAIT).unwrap(), "A");
    assert_eq!(done_rx.recv_timeout(WAIT).unwrap(), "B");
    drop(batcher);
}

#[test]
fn drop_drains_queued_jobs_before_shutdown() {
    let (entered_tx, entered_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel();
    let exec = Arc::new(GatedExec {
        entered: entered_tx,
        gate: Mutex::new(release_rx),
    });
    let batcher = OpBatcher::spawn(exec as Arc<dyn OpExecutor>, 1, 0, 8, Arc::new(Metrics::new()));
    let (done_tx, done_rx) = mpsc::channel::<&'static str>();
    submit_tagged(&batcher, &done_tx, "A").expect("A accepted");
    entered_rx.recv_timeout(WAIT).expect("A entered run_ops");
    for tag in ["B", "C", "D"] {
        submit_tagged(&batcher, &done_tx, tag).expect("queued");
    }
    // Pre-load the releases, then drop the batcher while three jobs are
    // still queued: Drop must drain and complete them, not discard them.
    for _ in 0..4 {
        release_tx.send(()).expect("batcher alive");
    }
    let dropper = std::thread::spawn(move || drop(batcher));
    let mut got: Vec<&str> = (0..4)
        .map(|_| done_rx.recv_timeout(WAIT).expect("drained job completes"))
        .collect();
    dropper.join().expect("drop joins cleanly");
    got.sort_unstable();
    assert_eq!(got, vec!["A", "B", "C", "D"]);
}

/// The tentpole property: the same workload served (a) sequentially,
/// one blocking request at a time with batching disabled, and (b) from
/// concurrent pipelined connections coalesced by the cross-connection
/// OpBatcher, produces bit-identical responses for every sketch family.
#[test]
fn batched_across_connections_bit_identical_to_sequential_all_families() {
    let mut ref_cfg = five_family_cfg();
    ref_cfg.op_batch = 0; // reference: direct per-request path
    let mut bat_cfg = five_family_cfg();
    bat_cfg.op_batch = 16;
    bat_cfg.op_max_delay_us = 2_000; // generous coalescing window
    let ref_c = coordinator(ref_cfg);
    let bat_c = coordinator(bat_cfg);
    let ref_server = Server::start(Arc::clone(&ref_c), "127.0.0.1:0").unwrap();
    let bat_server = Server::start(Arc::clone(&bat_c), "127.0.0.1:0").unwrap();

    let sets: Vec<Vec<u32>> = (0..24).map(|i| seeded_set(42, i, 60)).collect();

    // Sequential reference sketches for all five schemes.
    let mut rc = Client::connect(ref_server.addr()).unwrap();
    let mut expect: HashMap<(usize, usize), Response> = HashMap::new();
    for (si, scheme) in FAMILY_SCHEMES.iter().enumerate() {
        for (i, s) in sets.iter().enumerate() {
            let r = rc
                .call(&Request::Sketch {
                    set: s.clone(),
                    spec: None,
                    scheme: scheme.map(str::to_string),
                })
                .unwrap();
            assert!(matches!(r, Response::SketchValue { .. }), "scheme {scheme:?}");
            expect.insert((si, i), r);
        }
    }

    // Subject: 4 pipelined connections interleaving all five schemes, so
    // the batcher coalesces mixed-scheme ops from different sockets.
    let addr = bat_server.addr();
    let shared_sets = Arc::new(sets.clone());
    let handles: Vec<_> = (0..4)
        .map(|conn| {
            let sets = Arc::clone(&shared_sets);
            std::thread::spawn(move || {
                let mut c = PipelinedClient::connect(addr).unwrap();
                let mut tags: HashMap<u64, (usize, usize)> = HashMap::new();
                for (si, scheme) in FAMILY_SCHEMES.iter().enumerate() {
                    for i in (conn..sets.len()).step_by(4) {
                        let rid = c
                            .send(&Request::Sketch {
                                set: sets[i].clone(),
                                spec: None,
                                scheme: scheme.map(str::to_string),
                            })
                            .unwrap();
                        tags.insert(rid, (si, i));
                    }
                }
                let mut got = HashMap::new();
                for _ in 0..tags.len() {
                    let (rid, resp) = c.recv().unwrap();
                    got.insert(tags[&rid.expect("tagged")], resp);
                }
                got
            })
        })
        .collect();
    let mut got: HashMap<(usize, usize), Response> = HashMap::new();
    for h in handles {
        got.extend(h.join().expect("client thread"));
    }
    assert_eq!(got.len(), FAMILY_SCHEMES.len() * sets.len());
    for (k, v) in &expect {
        assert_eq!(
            got.get(k),
            Some(v),
            "scheme #{} set #{}: batched-across-connections == sequential, bit for bit",
            k.0,
            k.1
        );
    }
    // The batcher really ran — this wasn't a silent direct fall-through.
    let batches = bat_c.metrics.op_batches.load(Ordering::Relaxed);
    assert!(batches > 0, "op batcher dispatched no batches");

    // Insert/query/estimate identity on the default OPH scheme: the
    // subject ingests from 4 concurrent pipelined connections, the
    // reference sequentially; stored sketches must be bit-identical
    // regardless of arrival order or batch boundaries.
    for (i, s) in sets.iter().enumerate() {
        let r = rc
            .call(&Request::LshInsert {
                id: i as u32,
                set: s.clone(),
                scheme: None,
            })
            .unwrap();
        assert!(matches!(r, Response::Inserted { .. }));
    }
    let handles: Vec<_> = (0..4)
        .map(|conn| {
            let sets = Arc::clone(&shared_sets);
            std::thread::spawn(move || {
                let mut c = PipelinedClient::connect(addr).unwrap();
                let mut n = 0;
                for i in (conn..sets.len()).step_by(4) {
                    c.send(&Request::LshInsert {
                        id: i as u32,
                        set: sets[i].clone(),
                        scheme: None,
                    })
                    .unwrap();
                    n += 1;
                }
                for _ in 0..n {
                    let (_, resp) = c.recv().unwrap();
                    assert!(matches!(resp, Response::Inserted { .. }));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("insert client");
    }
    let mut bc = Client::connect(addr).unwrap();
    for s in sets.iter() {
        let Response::Candidates { ids: mut a } = bc
            .call(&Request::LshQuery {
                set: s.clone(),
                scheme: None,
            })
            .unwrap()
        else {
            panic!("expected candidates")
        };
        let Response::Candidates { ids: mut b } = rc
            .call(&Request::LshQuery {
                set: s.clone(),
                scheme: None,
            })
            .unwrap()
        else {
            panic!("expected candidates")
        };
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "candidate sets agree");
    }
    for i in 1..sets.len() {
        let ra = bc
            .call(&Request::Estimate {
                a: 0,
                b: i as u32,
                scheme: None,
            })
            .unwrap();
        let rb = rc
            .call(&Request::Estimate {
                a: 0,
                b: i as u32,
                scheme: None,
            })
            .unwrap();
        assert_eq!(ra, rb, "estimates from stored sketches exactly equal");
    }
    bat_server.stop();
    ref_server.stop();
}

/// Doc ops ride the batcher too: `index_doc`/`query_doc` are shingled
/// *before* enqueue (`to_batch_op` with the shared `DOC_SHINGLE_W`), so
/// a batched doc op must be bit-identical to the direct path's
/// shingle-then-serve — same stored sketches, same candidates. A
/// tokenizer drift between the two paths fails this exactly.
#[test]
fn doc_ops_batched_bit_identical_to_direct() {
    let mut ref_cfg = five_family_cfg();
    ref_cfg.op_batch = 0; // direct path shingles inside the service
    let mut bat_cfg = five_family_cfg();
    bat_cfg.op_batch = 8;
    bat_cfg.op_max_delay_us = 2_000;
    let bat_c = coordinator(bat_cfg);
    let ref_server = Server::start(coordinator(ref_cfg), "127.0.0.1:0").unwrap();
    let bat_server = Server::start(Arc::clone(&bat_c), "127.0.0.1:0").unwrap();

    // Overlapping text docs: shared phrases make shingle collisions (and
    // so candidate hits) certain.
    let docs: Vec<String> = (0..20)
        .map(|i| {
            format!(
                "the quick brown fox {i} jumps over the lazy dog; \
                 minwise hashing estimates jaccard similarity {}",
                i % 4
            )
        })
        .collect();

    // Reference: sequential direct serving.
    let mut rc = Client::connect(ref_server.addr()).unwrap();
    for (i, text) in docs.iter().enumerate() {
        let r = rc
            .call(&Request::IndexDoc {
                id: i as u32,
                text: text.clone(),
                scheme: None,
            })
            .unwrap();
        assert_eq!(r, Response::Inserted { id: i as u32 });
    }

    // Subject: 2 pipelined connections interleaving the same docs
    // through the batcher.
    let addr = bat_server.addr();
    let shared = Arc::new(docs.clone());
    let handles: Vec<_> = (0..2)
        .map(|conn| {
            let docs = Arc::clone(&shared);
            std::thread::spawn(move || {
                let mut c = PipelinedClient::connect(addr).unwrap();
                let mut n = 0;
                for i in (conn..docs.len()).step_by(2) {
                    c.send(&Request::IndexDoc {
                        id: i as u32,
                        text: docs[i].clone(),
                        scheme: None,
                    })
                    .unwrap();
                    n += 1;
                }
                for _ in 0..n {
                    let (_, resp) = c.recv().unwrap();
                    assert!(matches!(resp, Response::Inserted { .. }));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("doc insert client");
    }

    let mut bc = Client::connect(addr).unwrap();
    let mut any_nonempty = false;
    for text in docs.iter() {
        let Response::Candidates { ids: mut a } = bc
            .call(&Request::QueryDoc {
                text: text.clone(),
                scheme: None,
            })
            .unwrap()
        else {
            panic!("expected candidates")
        };
        let Response::Candidates { ids: mut b } = rc
            .call(&Request::QueryDoc {
                text: text.clone(),
                scheme: None,
            })
            .unwrap()
        else {
            panic!("expected candidates")
        };
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "doc candidates agree for {text:?}");
        any_nonempty |= !a.is_empty();
    }
    assert!(any_nonempty, "workload produced no collisions — test is vacuous");
    // The doc ops really took the batched path.
    assert!(
        bat_c.metrics.op_batches.load(Ordering::Relaxed) > 0,
        "op batcher dispatched no batches for doc ops"
    );
    bat_server.stop();
    ref_server.stop();
}
