//! Deterministic cluster-tier suites: the health state machine driven by
//! a fake clock (no sleeps — every transition is an exact timestamp),
//! and the replica-merge invariant proved against in-process backends:
//! a router over N backends answers queries identically to one
//! coordinator holding the same corpus, for any N and replication
//! factor. Mirrors `sharded_properties.rs` one level up the topology.

use super::{base_cfg, coordinator, seeded_set};
use mixtab::coordinator::cluster::config::BackendConfig;
use mixtab::coordinator::cluster::{BackendHealth, ClusterConfig, ClusterRouter, HealthState};
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{Handler, Server};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Health machine, fake clock.
// ---------------------------------------------------------------------

fn health() -> (BackendHealth, Instant) {
    // error_limit 3, cooloff 100ms, clock origin t0.
    (BackendHealth::new(3, Duration::from_millis(100)), Instant::now())
}

fn at(t0: Instant, ms: u64) -> Instant {
    t0 + Duration::from_millis(ms)
}

#[test]
fn trips_only_on_consecutive_errors() {
    let (mut h, t0) = health();
    // Two errors, a success, two more errors: never 3 consecutive.
    for ms in [1, 2] {
        h.on_error(at(t0, ms));
    }
    h.on_success(at(t0, 3));
    for ms in [4, 5] {
        h.on_error(at(t0, ms));
    }
    assert_eq!(h.state(), HealthState::Healthy);
    assert_eq!(h.cooloff_trips(), 0);
    assert!(h.admit_at(at(t0, 6)));
    // The third consecutive error trips.
    h.on_error(at(t0, 7));
    assert_eq!(
        h.state(),
        HealthState::Cooloff {
            until: at(t0, 107)
        },
        "cooloff deadline = trip time + cooloff"
    );
    assert_eq!(h.cooloff_trips(), 1);
}

#[test]
fn cooloff_sheds_until_deadline_then_probes() {
    let (mut h, t0) = health();
    for ms in [1, 2, 3] {
        h.on_error(at(t0, ms));
    }
    // Shedding strictly before the deadline.
    assert!(!h.admit_at(at(t0, 50)));
    assert!(!h.admit_at(at(t0, 102)));
    assert_eq!(h.state(), HealthState::Cooloff { until: at(t0, 103) });
    // At the deadline: exactly one probe goes through (half-open).
    assert!(h.admit_at(at(t0, 103)));
    assert_eq!(h.state(), HealthState::HalfOpen);
    assert!(!h.admit_at(at(t0, 104)), "second concurrent probe shed");
}

#[test]
fn probe_success_recovers_and_bumps_epoch() {
    let (mut h, t0) = health();
    assert_eq!(h.epoch(), 0);
    for ms in [1, 2, 3] {
        h.on_error(at(t0, ms));
    }
    assert!(h.admit_at(at(t0, 200)));
    h.on_success(at(t0, 201));
    assert_eq!(h.state(), HealthState::Healthy);
    assert_eq!(h.epoch(), 1, "recovery is epoch-tagged");
    assert!(h.admit_at(at(t0, 202)));
    // An ordinary success does not mint epochs.
    h.on_success(at(t0, 203));
    assert_eq!(h.epoch(), 1);
}

#[test]
fn probe_failure_retrips_with_fresh_deadline() {
    let (mut h, t0) = health();
    for ms in [1, 2, 3] {
        h.on_error(at(t0, ms));
    }
    assert!(h.admit_at(at(t0, 150)));
    // One failed probe re-trips immediately — no 3-error grace while
    // half-open.
    h.on_error(at(t0, 151));
    assert_eq!(h.state(), HealthState::Cooloff { until: at(t0, 251) });
    assert_eq!(h.cooloff_trips(), 2);
    assert_eq!(h.epoch(), 0, "no recovery happened");
    assert!(!h.admit_at(at(t0, 250)));
    assert!(h.admit_at(at(t0, 251)));
}

// ---------------------------------------------------------------------
// Replica-merge independence over real in-process backends.
// ---------------------------------------------------------------------

/// Spawn `n` backend servers (each a full coordinator with the harness
/// base config) and a router over them with the given replication.
fn cluster_of(n: usize, replicas: usize) -> (Vec<Server>, ClusterRouter) {
    let servers: Vec<Server> = (0..n)
        .map(|_| Server::start(coordinator(base_cfg()), "127.0.0.1:0").unwrap())
        .collect();
    let cluster = ClusterConfig {
        backends: servers
            .iter()
            .enumerate()
            .map(|(i, s)| BackendConfig {
                name: format!("b{i}"),
                addr: s.addr().to_string(),
                weight: 1,
                schemes: Vec::new(),
            })
            .collect(),
        replicas,
        error_limit: 3,
        cooloff_ms: 1_000,
        read_timeout_ms: 5_000,
        shadow_fraction: 1.0,
        shadow_backend: None,
        shadow_scheme: None,
        shadow_queue: 1024,
    };
    let router = ClusterRouter::new(cluster, &base_cfg()).unwrap();
    (servers, router)
}

/// The workload: 300 seeded sets inserted under ids 0.., then every 10th
/// set queried.
fn corpus() -> Vec<Vec<u32>> {
    (0..300).map(|i| seeded_set(0xC1u64, i, 30)).collect()
}

#[test]
fn router_merge_is_independent_of_backend_count() {
    // Reference: one coordinator holding everything.
    let reference = coordinator(base_cfg());
    let sets = corpus();
    for (i, set) in sets.iter().enumerate() {
        let resp = reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
        assert_eq!(resp, Response::Inserted { id: i as u32 });
    }

    for (n, replicas) in [(2, 2), (3, 2), (3, 3)] {
        let (servers, router) = cluster_of(n, replicas);
        for (i, set) in sets.iter().enumerate() {
            let resp = router.handle(Request::LshInsert {
                id: i as u32,
                set: set.clone(),
                scheme: None,
            });
            assert_eq!(resp, Response::Inserted { id: i as u32 }, "insert {i}");
        }
        for (i, set) in sets.iter().enumerate().step_by(10) {
            let got = router.handle(Request::LshQuery {
                set: set.clone(),
                scheme: None,
            });
            let want = reference.handle(Request::LshQuery {
                set: set.clone(),
                scheme: None,
            });
            assert_eq!(
                got, want,
                "query {i} differs on {n} backends x{replicas} replicas"
            );
        }
        for s in servers {
            s.stop();
        }
    }
}

#[test]
fn estimate_served_from_replicas() {
    let (servers, router) = cluster_of(3, 2);
    let reference = coordinator(base_cfg());
    for (i, set) in corpus().iter().enumerate().take(40) {
        router.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
        reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }
    // Every stored pair estimates identically through the router: the
    // stored sketch is spec-determined, not placement-determined.
    for (a, b) in [(0u32, 1u32), (5, 25), (12, 39)] {
        let got = router.handle(Request::Estimate { a, b, scheme: None });
        let want = reference.handle(Request::Estimate { a, b, scheme: None });
        assert_eq!(got, want, "estimate({a},{b})");
    }
    for s in servers {
        s.stop();
    }
}

#[test]
fn dead_backend_sheds_but_queries_survive() {
    let (servers, router) = cluster_of(2, 2);
    let sets = corpus();
    for (i, set) in sets.iter().enumerate().take(100) {
        router.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }
    // Kill one backend: with full replication the survivor holds every
    // id, so queries keep answering exactly.
    let mut iter = servers.into_iter();
    let dead = iter.next().unwrap();
    dead.stop();
    let reference = coordinator(base_cfg());
    for (i, set) in sets.iter().enumerate().take(100) {
        reference.handle(Request::LshInsert {
            id: i as u32,
            set: set.clone(),
            scheme: None,
        });
    }
    for (i, set) in sets.iter().enumerate().take(100).step_by(10) {
        let got = router.handle(Request::LshQuery {
            set: set.clone(),
            scheme: None,
        });
        let want = reference.handle(Request::LshQuery {
            set: set.clone(),
            scheme: None,
        });
        assert_eq!(got, want, "query {i} after losing a replica");
    }
    // The dead backend's transport failures were counted and tripped its
    // breaker; the survivor stayed healthy.
    let stats = router.stats_json();
    let b0 = stats.get("backends").unwrap().get("b0").unwrap();
    let b1 = stats.get("backends").unwrap().get("b1").unwrap();
    assert!(b0.get("errors").unwrap().as_i64().unwrap() > 0);
    assert_eq!(b0.get("state").unwrap().as_str(), Some("cooloff"));
    assert_eq!(b1.get("state").unwrap().as_str(), Some("healthy"));
    assert_eq!(b1.get("errors").unwrap().as_i64(), Some(0));
    for s in iter {
        s.stop();
    }
}
