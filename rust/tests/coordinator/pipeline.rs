//! Pipelining property suite over real TCP: out-of-order rid mapping,
//! interleaved multi-op streams from 8 scripted clients with seeded
//! pipelining depths, and panic containment in the worker pool.

use crate::{base_cfg, coordinator, seeded_set};
use mixtab::coordinator::request::{Request, Response};
use mixtab::coordinator::server::{Handler, PipelinedClient, Server};
use mixtab::util::rng::Xoshiro256;
use std::collections::{HashMap, HashSet};
use std::sync::{mpsc, Arc, Mutex};

/// Parks any request whose set starts with 0 on a gate the *test* holds;
/// everything else answers immediately. Lets the test force a provable
/// out-of-order completion: it only opens the gate after the fast
/// response has already arrived on the wire.
struct GateHandler {
    gate: Mutex<mpsc::Receiver<()>>,
}

impl Handler for GateHandler {
    fn handle(&self, req: Request) -> Response {
        let Request::OphSketch { set } = req else {
            return Response::Error {
                message: "unexpected op".into(),
            };
        };
        if set.first() == Some(&0) {
            self.gate.lock().unwrap().recv().expect("gate opened");
            Response::Error {
                message: "slow".into(),
            }
        } else {
            Response::Error {
                message: "fast".into(),
            }
        }
    }
}

#[test]
fn responses_return_out_of_order_mapped_by_rid() {
    let (open_gate, gate) = mpsc::channel();
    let handler = Arc::new(GateHandler {
        gate: Mutex::new(gate),
    });
    let mut cfg = base_cfg();
    cfg.request_workers = 2; // slow and fast must run concurrently
    let server = Server::start_with_handler(handler, cfg, "127.0.0.1:0").unwrap();
    let mut c = PipelinedClient::connect(server.addr()).unwrap();
    let slow = c.send(&Request::OphSketch { set: vec![0] }).unwrap();
    let fast = c.send(&Request::OphSketch { set: vec![1] }).unwrap();
    // The fast response overtakes the parked slow one on the wire…
    let (rid, resp) = c.recv().unwrap();
    assert_eq!(rid, Some(fast));
    assert!(matches!(resp, Response::Error { message } if message == "fast"));
    // …and only then do we let the slow request finish.
    open_gate.send(()).unwrap();
    let (rid, resp) = c.recv().unwrap();
    assert_eq!(rid, Some(slow));
    assert!(matches!(resp, Response::Error { message } if message == "slow"));
    server.stop();
}

/// What each in-flight request must produce.
enum Expect {
    /// Bit-identical to a reference coordinator handling the same request.
    Exact(Request),
    /// Candidates must contain this id (LSH self-retrieval of an
    /// already-acknowledged insert).
    SelfHit(u32),
    StatsOk,
}

#[test]
fn interleaved_multi_op_streams_from_eight_scripted_clients() {
    let cfg = base_cfg(); // op batching on (default): exercised under load
    let subject = coordinator(cfg.clone());
    let reference = coordinator(cfg);
    let server = Server::start(subject, "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..8u64)
        .map(|cl| {
            let reference = Arc::clone(&reference);
            std::thread::spawn(move || {
                let mut rng = Xoshiro256::stream(99, cl);
                let mut c = PipelinedClient::connect(addr).unwrap();
                // Phase 1: pipeline this client's 8 inserts (ids are
                // disjoint per client) and check every ack by rid.
                let my_sets: Vec<Vec<u32>> =
                    (0..8).map(|i| seeded_set(7, cl * 8 + i, 50)).collect();
                let mut tags = HashMap::new();
                for (i, s) in my_sets.iter().enumerate() {
                    let id = (cl * 8) as u32 + i as u32;
                    let rid = c
                        .send(&Request::LshInsert {
                            id,
                            set: s.clone(),
                            scheme: None,
                        })
                        .unwrap();
                    tags.insert(rid, id);
                }
                for _ in 0..my_sets.len() {
                    let (rid, resp) = c.recv().unwrap();
                    let id = tags[&rid.expect("tagged")];
                    assert_eq!(resp, Response::Inserted { id });
                }
                // Phase 2: a seeded interleaving of sketch / transform /
                // query / stats ops at random pipelining depths. Sketches
                // and transforms must match the reference coordinator
                // bit for bit; queries must retrieve their own id.
                let total = 24usize;
                let mut pending: HashMap<u64, Expect> = HashMap::new();
                let (mut issued, mut done) = (0usize, 0usize);
                while done < total {
                    let depth = 1 + (rng.next_u32() % 6) as usize;
                    while issued < total && pending.len() < depth {
                        let exp = match rng.next_u32() % 4 {
                            0 => Expect::Exact(Request::Sketch {
                                set: seeded_set(11, rng.next_u64(), 40),
                                spec: None,
                                scheme: None,
                            }),
                            1 => {
                                let n = 20 + (rng.next_u32() % 20) as usize;
                                Expect::Exact(Request::FhTransform {
                                    indices: (0..n)
                                        .map(|_| rng.next_u32() % 1_000_000)
                                        .collect(),
                                    values: (0..n).map(|_| rng.next_f64() - 0.5).collect(),
                                })
                            }
                            2 => {
                                let j = issued % my_sets.len();
                                let rid = c
                                    .send(&Request::LshQuery {
                                        set: my_sets[j].clone(),
                                        scheme: None,
                                    })
                                    .unwrap();
                                pending.insert(rid, Expect::SelfHit((cl * 8) as u32 + j as u32));
                                issued += 1;
                                continue;
                            }
                            _ => {
                                let rid = c.send(&Request::Stats).unwrap();
                                pending.insert(rid, Expect::StatsOk);
                                issued += 1;
                                continue;
                            }
                        };
                        let Expect::Exact(ref req) = exp else {
                            unreachable!()
                        };
                        let rid = c.send(req).unwrap();
                        pending.insert(rid, exp);
                        issued += 1;
                    }
                    let (rid, resp) = c.recv().unwrap();
                    let exp = pending
                        .remove(&rid.expect("tagged"))
                        .expect("rid known and unanswered");
                    match exp {
                        Expect::Exact(req) => {
                            assert_eq!(
                                resp,
                                reference.handle(req),
                                "client {cl}: pipelined response bit-identical"
                            );
                        }
                        Expect::SelfHit(id) => {
                            let Response::Candidates { ids } = resp else {
                                panic!("client {cl}: expected candidates, got {resp:?}");
                            };
                            assert!(ids.contains(&id), "client {cl}: self-retrieval of {id}");
                        }
                        Expect::StatsOk => {
                            assert!(matches!(resp, Response::Stats { .. }));
                        }
                    }
                    done += 1;
                }
                assert!(pending.is_empty());
            })
        })
        .collect();
    for h in handles {
        h.join().expect("scripted client");
    }
    server.stop();
}

/// A handler that panics on poisoned payloads.
struct PanickyHandler;

impl Handler for PanickyHandler {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::OphSketch { set } if set.first() == Some(&666) => {
                panic!("injected handler panic")
            }
            Request::OphSketch { set } => Response::Candidates { ids: set },
            _ => Response::Error {
                message: "unexpected op".into(),
            },
        }
    }
}

#[test]
fn handler_panics_become_wire_errors_and_pool_survives() {
    let mut cfg = base_cfg();
    cfg.request_workers = 4;
    let server = Server::start_with_handler(Arc::new(PanickyHandler), cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..8u32)
        .map(|cl| {
            std::thread::spawn(move || {
                let mut c = PipelinedClient::connect(addr).unwrap();
                let mut poisoned = HashSet::new();
                for i in 0..12u32 {
                    let poison = (i + cl) % 3 == 0;
                    let set = if poison {
                        vec![666, cl, i]
                    } else {
                        vec![cl, i]
                    };
                    let rid = c.send(&Request::OphSketch { set }).unwrap();
                    if poison {
                        poisoned.insert(rid);
                    }
                }
                for _ in 0..12 {
                    let (rid, resp) = c.recv().unwrap();
                    let rid = rid.expect("tagged");
                    if poisoned.contains(&rid) {
                        let Response::Error { message } = resp else {
                            panic!("poisoned request must yield a wire error, got {resp:?}");
                        };
                        assert!(message.contains("panicked"), "got: {message}");
                    } else {
                        assert!(matches!(resp, Response::Candidates { .. }));
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("panic-mix client");
    }
    // 32 panics later the pool still serves a fresh connection.
    let mut c = PipelinedClient::connect(addr).unwrap();
    let rid = c.send(&Request::OphSketch { set: vec![5] }).unwrap();
    let (got, resp) = c.recv().unwrap();
    assert_eq!(got, Some(rid));
    assert!(matches!(resp, Response::Candidates { .. }));
    server.stop();
}
