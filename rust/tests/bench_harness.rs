//! Perf-regression harness integration: `BENCH_*.json` schema round-trip
//! through the filesystem, `Bench::compare` against real files, and the
//! self-compare invariant the CI `bench-smoke` gate relies on.

use mixtab::util::bench::{
    compare_records, parse_report, Bench, CaseRecord, BENCH_SCHEMA,
};
use std::path::PathBuf;

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mixtab_bench_{}_{name}.json", std::process::id()))
}

fn sample_bench() -> Bench {
    let mut b = Bench::with_quick(true);
    b.record_rate("table1_hash_speed", "hash32/mixed_tab", 2.5e8, 4.0);
    b.record_rate("table1_hash_speed", "hash32/murmur3", 1.75e8, 5.714285714285714);
    b.record_rate("sketch_throughput", "oph_raw_batched", 9.125e7, 10.958904109589041);
    b
}

#[test]
fn write_then_parse_roundtrips_all_fields() {
    let b = sample_bench();
    let path = tmp_path("roundtrip");
    b.write_json(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    // Schema tag present, and every record field survives exactly.
    assert!(text.contains(BENCH_SCHEMA));
    let parsed = parse_report(&text).unwrap();
    assert_eq!(parsed, b.records());
    // Field spot-check: the schema names the ISSUE-specified keys.
    for key in ["bench", "case", "keys_per_sec", "ns_per_key", "quick", "git_sha"] {
        assert!(text.contains(&format!("\"{key}\"")), "missing key {key}");
    }
}

#[test]
fn self_compare_has_zero_regressions() {
    // The acceptance invariant: a report diffed against itself is clean,
    // even at zero tolerance.
    let b = sample_bench();
    let path = tmp_path("self");
    b.write_json(&path).unwrap();
    let regs = b.compare(&path, 0.0).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(regs.is_empty(), "{regs:?}");
}

#[test]
fn compare_reports_missing_zero_and_tolerance_edges() {
    let rec = |case: &str, kps: f64| CaseRecord {
        bench: "w".into(),
        case: case.into(),
        keys_per_sec: kps,
        ns_per_key: if kps > 0.0 { 1e9 / kps } else { 0.0 },
        quick: true,
        git_sha: "baseline".into(),
    };
    let baseline = vec![
        rec("missing", 100.0),
        rec("zero_baseline", 0.0),
        rec("at_tolerance", 100.0),
        rec("past_tolerance", 100.0),
    ];
    let current = vec![
        // "missing" intentionally absent from the current run.
        rec("zero_baseline", 0.0),
        rec("at_tolerance", 75.0),   // loss = 0.25 exactly → passes
        rec("past_tolerance", 74.0), // loss = 0.26 → regression
    ];
    let regs = compare_records(&current, &baseline, 0.25);
    let names: Vec<&str> = regs.iter().map(|r| r.case.as_str()).collect();
    assert_eq!(names, ["missing", "past_tolerance"], "{regs:?}");
    assert_eq!(regs[0].current_keys_per_sec, 0.0);
    assert_eq!(regs[0].loss, 1.0);
    assert!((regs[1].loss - 0.26).abs() < 1e-12);
}

#[test]
fn compare_rejects_mode_mismatched_baseline() {
    // A quick-mode baseline must not gate a full-mode run (and vice
    // versa): the workload sizes differ, so the numbers are incomparable.
    let quick = sample_bench();
    let path = tmp_path("mode");
    quick.write_json(&path).unwrap();
    let full = Bench::with_quick(false);
    let err = full.compare(&path, 0.25).unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(err.to_string().contains("mode mismatch"), "{err}");
}

#[test]
fn compare_rejects_negative_tolerance() {
    let b = sample_bench();
    let path = tmp_path("negtol");
    b.write_json(&path).unwrap();
    let err = b.compare(&path, -0.1).unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(err.to_string().contains("non-negative"), "{err}");
}

#[test]
fn compare_against_corrupt_baseline_errors() {
    let b = sample_bench();
    let path = tmp_path("corrupt");
    std::fs::write(&path, "{ not json").unwrap();
    assert!(b.compare(&path, 0.25).is_err());
    std::fs::write(&path, r#"{"schema":"something-else","records":[]}"#).unwrap();
    assert!(b.compare(&path, 0.25).is_err());
    std::fs::remove_file(&path).ok();
    // Nonexistent path errors rather than silently passing the gate.
    assert!(b.compare(&path, 0.25).is_err());
}

#[test]
fn committed_quick_baseline_parses_and_matches_suite_names() {
    // The repo-root baseline CI gates against must always be loadable and
    // only name workloads that exist in the suite.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_baseline_quick.json");
    let text = std::fs::read_to_string(path).expect("committed BENCH_baseline_quick.json");
    let records = parse_report(&text).unwrap();
    assert!(!records.is_empty());
    let known: Vec<&str> = mixtab::benchsuite::ALL.iter().map(|(n, _)| *n).collect();
    for r in &records {
        assert!(known.contains(&r.bench.as_str()), "unknown bench {}", r.bench);
        assert!(r.quick, "baseline must be quick-mode: {}", r.case);
        assert!(r.keys_per_sec > 0.0, "ungated baseline case {}", r.case);
    }
}
