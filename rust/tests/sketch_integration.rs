//! Cross-module sketch integration: OPH vs MinHash agreement, estimator
//! quality on the paper's data shapes, FH vs the theory bounds.

use mixtab::data::synthetic::{dataset1, dataset2};
use mixtab::data::SparseVector;
use mixtab::hash::HashFamily;
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::sketch::minhash::MinHash;
use mixtab::sketch::oph::OneHashSketcher;
use mixtab::sketch::{jaccard_exact, DensifyMode, Scratch, SketchSpec};
use mixtab::stats::Summary;
use mixtab::util::rng::Xoshiro256;

fn oph(seed: u64, k: usize) -> OneHashSketcher {
    SketchSpec::oph(HashFamily::MixedTab, seed, k)
        .build_oph()
        .expect("oph spec")
}

/// OPH (densified) and k×MinHash estimate the same quantity: their means
/// over seeds agree with each other and the truth.
#[test]
fn oph_and_minhash_agree_on_random_sets() {
    let mut rng = Xoshiro256::new(11);
    let a: Vec<u32> = (0..2000).map(|_| rng.next_u32() % 50_000).collect();
    let b: Vec<u32> = a
        .iter()
        .map(|&x| if x % 2 == 0 { x } else { x.wrapping_add(60_000) })
        .collect();
    let truth = jaccard_exact(&a, &b);
    let reps = 40;
    let (mut s_oph, mut s_mh) = (Summary::new(), Summary::new());
    for seed in 0..reps {
        let sk = oph(seed, 128);
        s_oph.add(sk.estimate(&sk.sketch(&a), &sk.sketch(&b)));
        let mh = MinHash::new(HashFamily::MixedTab, seed, 128);
        s_mh.add(mh.estimate(&mh.sketch(&a), &mh.sketch(&b)));
    }
    assert!((s_oph.mean() - truth).abs() < 0.04, "oph {} truth {truth}", s_oph.mean());
    assert!((s_mh.mean() - truth).abs() < 0.04, "mh {} truth {truth}", s_mh.mean());
    assert!((s_oph.mean() - s_mh.mean()).abs() < 0.05);
}

/// Reproduces the §4.1 mechanism end-to-end at miniature scale: on the
/// dense-intersection dataset, multiply-shift OPH over-estimates J while
/// mixed tabulation stays centred (the Figure 2 shape).
#[test]
fn structured_data_bias_contrast() {
    let mut rng = Xoshiro256::new(3);
    let pair = dataset1(1000, true, &mut rng);
    let reps = 150;
    let estimate_with = |fam: HashFamily| {
        let mut s = Summary::new();
        for seed in 0..reps {
            let sk = SketchSpec::oph(fam, seed * 7 + 1, 200)
                .build_oph()
                .expect("oph spec");
            s.add(sk.estimate(&sk.sketch(&pair.a), &sk.sketch(&pair.b)));
        }
        s
    };
    let ms = estimate_with(HashFamily::MultiplyShift);
    let mt = estimate_with(HashFamily::MixedTab);
    // Mixed tabulation: small MSE, centred.
    assert!(
        (mt.mean() - pair.jaccard).abs() < 0.03,
        "mixed mean {} truth {}",
        mt.mean(),
        pair.jaccard
    );
    // Multiply-shift: higher MSE on this structured input (paper Figure 2).
    assert!(
        ms.mse(pair.jaccard) > mt.mse(pair.jaccard),
        "ms mse {:.2e} vs mt mse {:.2e}",
        ms.mse(pair.jaccard),
        mt.mse(pair.jaccard)
    );
}

/// Dataset 2 shows the same contrast (Figure 8's stronger version).
#[test]
fn dataset2_bias_contrast() {
    let mut rng = Xoshiro256::new(5);
    let pair = dataset2(1000, true, &mut rng);
    let reps = 120;
    let mse_with = |fam: HashFamily| {
        let mut s = Summary::new();
        for seed in 0..reps {
            let sk = SketchSpec::oph(fam, seed * 13 + 5, 200)
                .build_oph()
                .expect("oph spec");
            s.add(sk.estimate(&sk.sketch(&pair.a), &sk.sketch(&pair.b)));
        }
        s.mse(pair.jaccard)
    };
    let ms = mse_with(HashFamily::MultiplyShift);
    let mt = mse_with(HashFamily::MixedTab);
    assert!(ms > mt, "dataset2: ms {ms:.2e} should exceed mt {mt:.2e}");
}

/// Theorem 1 sanity: with mixed tabulation and d' = 16·ε⁻²·lg(1/δ), the
/// norm concentrates within 1±ε for ≫ 1−4δ of seeds on a sparse unit
/// vector respecting the ‖v‖∞ bound.
#[test]
fn theorem1_concentration_gate() {
    let eps = 0.5;
    let delta = 0.05f64;
    let dprime = (16.0 / (eps * eps) * (1.0 / delta).log2()).ceil() as usize; // 277
    let v = SparseVector::unit_indicator(&(0..4000u32).collect::<Vec<_>>());
    // ‖v‖∞ = 1/63 — comfortably under the Theorem 1 bound for these params.
    let reps = 200;
    let mut within = 0;
    let mut scratch = Scratch::new();
    for seed in 0..reps {
        let fh = FeatureHasher::new(HashFamily::MixedTab, seed, dprime, SignMode::Paired);
        let sq = fh.squared_norm(&v, &mut scratch);
        if (sq - 1.0).abs() < eps {
            within += 1;
        }
    }
    let frac = within as f64 / reps as f64;
    assert!(
        frac > 1.0 - 4.0 * delta,
        "concentration {frac} < {}",
        1.0 - 4.0 * delta
    );
}

/// The h* single-hash variant (Corollary 1) agrees with the two-hash
/// variant in distribution: means and MSEs within noise of each other.
#[test]
fn paired_vs_separate_sign_equivalent_quality() {
    let v = SparseVector::unit_indicator(&(0..1500u32).map(|i| i * 3).collect::<Vec<_>>());
    let reps = 120;
    let run = |mode: SignMode| {
        let mut s = Summary::new();
        let mut scratch = Scratch::new();
        for seed in 0..reps {
            let fh = FeatureHasher::new(HashFamily::MixedTab, seed, 128, mode);
            s.add(fh.squared_norm(&v, &mut scratch));
        }
        s
    };
    let sep = run(SignMode::Separate);
    let pair = run(SignMode::Paired);
    assert!((sep.mean() - 1.0).abs() < 0.05);
    assert!((pair.mean() - 1.0).abs() < 0.05);
    let ratio = sep.mse(1.0) / pair.mse(1.0);
    assert!(
        (0.3..3.0).contains(&ratio),
        "sign-mode MSE ratio {ratio} out of family"
    );
}

/// Densification modes: [33] (Paper) has no worse MSE than [32] (Rotation)
/// in the sparse regime it was designed for.
#[test]
fn paper_densification_not_worse_than_rotation() {
    let mut rng = Xoshiro256::new(9);
    let pair = dataset1(100, true, &mut rng); // sparse: ~150 elements, k=200
    let reps = 250;
    let mse_with = |mode: DensifyMode| {
        let mut s = Summary::new();
        for seed in 0..reps {
            let sk = SketchSpec::oph_with(
                HashFamily::MixedTab,
                seed * 3 + 11,
                mixtab::sketch::OphParams {
                    k: 200,
                    layout: mixtab::sketch::BinLayout::Mod,
                    densify: mode,
                },
            )
            .build_oph()
            .expect("oph spec");
            s.add(sk.estimate(&sk.sketch(&pair.a), &sk.sketch(&pair.b)));
        }
        s.mse(pair.jaccard)
    };
    let paper = mse_with(DensifyMode::Paper);
    let rotation = mse_with(DensifyMode::Rotation);
    assert!(
        paper <= rotation * 1.25,
        "paper densification {paper:.2e} vs rotation {rotation:.2e}"
    );
}
