//! Property-based tests over the whole stack (first-party `util::prop`
//! framework with shrinking).

use mixtab::hash::HashFamily;
use mixtab::sketch::densify::{densify, DensifyMode, OFFSET_C};
use mixtab::sketch::estimators::{bbit_correct, jaccard_exact, jaccard_sorted};
use mixtab::sketch::feature_hash::{FeatureHasher, SignMode};
use mixtab::sketch::oph::{BinLayout, OneHashSketcher, EMPTY_BIN};
use mixtab::util::prop::{pair, Gen, Runner};
use mixtab::util::rng::Xoshiro256;

fn set_gen(max_len: usize) -> Gen<Vec<u32>> {
    Gen::vec_of(Gen::u32_any(), 1, max_len)
}

#[test]
fn prop_hash_deterministic_all_families() {
    for fam in HashFamily::TABLE1 {
        let h1 = fam.build(123);
        let h2 = fam.build(123);
        let cases = if *fam == HashFamily::Blake2 { 32 } else { 256 };
        Runner::new(cases).run(&format!("determinism {}", fam.id()), Gen::u32_any(), |&x| {
            h1.hash(x) == h2.hash(x)
        });
    }
}

#[test]
fn prop_oph_estimate_in_unit_interval() {
    let sk = OneHashSketcher::from_hasher(
        HashFamily::MixedTab.build(5),
        64,
        BinLayout::Mod,
        DensifyMode::Paper,
    );
    Runner::new(64).run(
        "estimate ∈ [0,1]",
        pair(set_gen(200), set_gen(200)),
        |(a, b)| {
            let e = sk.estimate(&sk.sketch(a), &sk.sketch(b));
            (0.0..=1.0).contains(&e)
        },
    );
}

#[test]
fn prop_oph_self_similarity_is_one() {
    let sk = OneHashSketcher::from_hasher(
        HashFamily::MixedTab.build(9),
        128,
        BinLayout::Mod,
        DensifyMode::Paper,
    );
    Runner::new(64).run("J(A,A) = 1", set_gen(300), |a| {
        sk.estimate(&sk.sketch(a), &sk.sketch(a)) == 1.0
    });
}

#[test]
fn prop_densified_sketch_never_empty() {
    let sk = OneHashSketcher::from_hasher(
        HashFamily::MixedTab.build(13),
        200,
        BinLayout::Mod,
        DensifyMode::Paper,
    );
    Runner::new(96).run("no empty bins", set_gen(50), |a| {
        sk.sketch(a).bins.iter().all(|&b| b != EMPTY_BIN)
    });
}

#[test]
fn prop_densify_preserves_filled_bins() {
    // For arbitrary fill patterns, original values survive densification
    // and copies always carry a positive multiple of OFFSET_C.
    let patt = Gen::vec_of(Gen::u64_below(1 << 20), 2, 24);
    Runner::new(128).run("densify preserves", patt, |vals| {
        // Mark ~half empty deterministically from values.
        let mut bins: Vec<u64> = vals
            .iter()
            .map(|&v| if v % 3 == 0 { EMPTY_BIN } else { v })
            .collect();
        let dirs: Vec<bool> = vals.iter().map(|&v| v % 2 == 0).collect();
        let before = bins.clone();
        densify(&mut bins, &dirs, DensifyMode::Paper);
        before.iter().zip(&bins).all(|(&b, &a)| {
            if b != EMPTY_BIN {
                a == b
            } else if before.iter().all(|&x| x == EMPTY_BIN) {
                a == EMPTY_BIN
            } else {
                // copied: a = source + j*C with source < 2^20 << C
                a == EMPTY_BIN || (a % OFFSET_C) < (1 << 20) && a / OFFSET_C >= 1
            }
        })
    });
}

#[test]
fn prop_fh_linearity() {
    let fh = FeatureHasher::new(HashFamily::MixedTab, 3, 64, SignMode::Paired);
    Runner::new(48).run("FH additive", pair(set_gen(60), set_gen(60)), |(a, b)| {
        let va = mixtab::data::SparseVector::unit_indicator(a);
        let vb = mixtab::data::SparseVector::unit_indicator(b);
        let sum = va.add(&vb);
        let ta = fh.transform(&va);
        let tb = fh.transform(&vb);
        let ts = fh.transform(&sum);
        (0..64).all(|i| (ts[i] - (ta[i] + tb[i])).abs() < 1e-9)
    });
}

#[test]
fn prop_fh_scaling() {
    let fh = FeatureHasher::new(HashFamily::Murmur3, 7, 32, SignMode::Separate);
    Runner::new(48).run("FH homogeneous", pair(set_gen(40), Gen::u64_below(1000)), |(a, c)| {
        let scale = *c as f64 / 100.0 + 0.1;
        let v = mixtab::data::SparseVector::unit_indicator(a);
        let scaled = mixtab::data::SparseVector::new(
            v.indices.clone(),
            v.values.iter().map(|x| x * scale).collect(),
        );
        let tv = fh.transform(&v);
        let ts = fh.transform(&scaled);
        (0..32).all(|i| (ts[i] - scale * tv[i]).abs() < 1e-9)
    });
}

#[test]
fn prop_jaccard_symmetry_and_bounds() {
    Runner::new(128).run("J symmetric ∈ [0,1]", pair(set_gen(100), set_gen(100)), |(a, b)| {
        let j1 = jaccard_exact(a, b);
        let j2 = jaccard_exact(b, a);
        j1 == j2 && (0.0..=1.0).contains(&j1)
    });
}

#[test]
fn prop_jaccard_sorted_matches_exact() {
    Runner::new(128).run("sorted == exact", pair(set_gen(80), set_gen(80)), |(a, b)| {
        let mut sa = a.clone();
        sa.sort_unstable();
        sa.dedup();
        let mut sb = b.clone();
        sb.sort_unstable();
        sb.dedup();
        (jaccard_sorted(&sa, &sb) - jaccard_exact(a, b)).abs() < 1e-12
    });
}

#[test]
fn prop_bbit_correction_bounds() {
    Runner::new(256).run(
        "bbit correction clamps to [-1,1]",
        pair(Gen::u64_below(1001), Gen::u64_below(8)),
        |(f, b)| {
            let frac = *f as f64 / 1000.0;
            let est = bbit_correct(frac, *b as u32 + 1);
            (-1.0..=1.0).contains(&est)
        },
    );
}

#[test]
fn prop_mixed_tab_64_halves_deterministic() {
    let h = HashFamily::MixedTab.build64(21);
    Runner::new(128).run("hash64 deterministic", Gen::u32_any(), |&x| {
        h.hash64(x) == h.hash64(x)
    });
}

#[test]
fn prop_hash_slice_consistency() {
    for fam in [HashFamily::MixedTab, HashFamily::MultiplyShift, HashFamily::Poly2] {
        let h = fam.build(31);
        Runner::new(32).run(
            &format!("slice == scalar {}", fam.id()),
            Gen::vec_of(Gen::u32_any(), 1, 64),
            |keys| {
                let mut out = vec![0u32; keys.len()];
                h.hash_slice(keys, &mut out);
                keys.iter().zip(&out).all(|(&k, &o)| h.hash(k) == o)
            },
        );
    }
}

#[test]
fn prop_sparse_vector_invariants() {
    Runner::new(128).run("SparseVector sorted+dedup", set_gen(100), |ids| {
        let v = mixtab::data::SparseVector::unit_indicator(ids);
        v.indices.windows(2).all(|w| w[0] < w[1]) && (v.norm2() - 1.0).abs() < 1e-9
    });
}

#[test]
fn prop_rng_below_bound() {
    Runner::new(256).run("below() respects bound", Gen::u64_below(1 << 40), |&b| {
        let bound = b + 1;
        let mut rng = Xoshiro256::new(b);
        (0..16).all(|_| rng.below(bound) < bound)
    });
}

/// Acceptance property for the batched hot paths: the batched
/// (hash_slice + Scratch) OPH / MinHash / SimHash paths are bit-identical
/// to the per-key reference paths for every `HashFamily::TABLE1` family,
/// both bin layouts, and arbitrary (duplicate-containing, unsorted) sets.
#[test]
fn prop_batched_sketches_bit_identical_to_per_key() {
    use mixtab::data::SparseVector;
    use mixtab::sketch::minhash::MinHash;
    use mixtab::sketch::simhash::SimHash;
    use mixtab::sketch::Scratch;

    for fam in HashFamily::TABLE1 {
        // Blake2 hashes ~1000× slower; fewer cases keep the test quick.
        let cases = if *fam == HashFamily::Blake2 { 4 } else { 24 };
        let oph_mod =
            OneHashSketcher::from_hasher(fam.build(7), 64, BinLayout::Mod, DensifyMode::Paper);
        let oph_range =
            OneHashSketcher::from_hasher(fam.build(8), 64, BinLayout::Range, DensifyMode::None);
        let mh = MinHash::new(*fam, 9, 16);
        let sh = SimHash::new(*fam, 10, 32);
        Runner::new(cases).run(
            &format!("batched == per-key {}", fam.id()),
            set_gen(300),
            |set| {
                let mut scratch = Scratch::new();
                // Deterministic weights so SimHash sees mixed signs.
                let v = SparseVector::new(
                    set.clone(),
                    set.iter().map(|&x| (x % 17) as f64 - 8.0).collect(),
                );
                oph_mod.sketch_with(set, &mut scratch) == oph_mod.sketch_per_key(set)
                    && oph_mod.sketch_raw_with(set, &mut scratch)
                        == oph_mod.sketch_raw_per_key(set)
                    && oph_range.sketch_raw_with(set, &mut scratch)
                        == oph_range.sketch_raw_per_key(set)
                    && mh.sketch_with(set, &mut scratch) == mh.sketch_per_key(set)
                    && sh.sketch_with(&v, &mut scratch) == sh.sketch_per_key(&v)
            },
        );
    }
}

/// Acceptance property for the `SketchSpec` registry: for every Table 1
/// family, spec-built sketchers are bit-identical to the pre-redesign
/// direct constructions (injected-hasher OPH, family+seed MinHash /
/// SimHash / FeatureHasher), the erased `build()` path matches the typed
/// `build_*` path, and specs survive a parse/Display round trip with the
/// built sketcher still producing identical output.
#[test]
fn prop_spec_registry_bit_identical_to_direct_construction() {
    use mixtab::data::SparseVector;
    use mixtab::sketch::bbit::BbitSketch;
    use mixtab::sketch::minhash::MinHash;
    use mixtab::sketch::simhash::SimHash;
    use mixtab::sketch::{DynSketcher, Scratch, SketchSpec, SketchValue, Sketcher, SignMode};

    for fam in HashFamily::TABLE1 {
        let cases = if *fam == HashFamily::Blake2 { 4 } else { 16 };
        let seed = 0xC0DEu64;

        let oph_spec = SketchSpec::oph(*fam, seed, 64);
        let oph_direct =
            OneHashSketcher::from_hasher(fam.build(seed), 64, BinLayout::Mod, DensifyMode::Paper);
        let oph_spec_built = oph_spec.build_oph().unwrap();
        let oph_reparsed = SketchSpec::parse(&oph_spec.to_string())
            .unwrap()
            .build_oph()
            .unwrap();

        let mh_spec = SketchSpec::minhash(*fam, seed, 8);
        let mh_direct = MinHash::new(*fam, seed, 8);
        let mh_spec_built = mh_spec.build_minhash().unwrap();

        let sh_spec = SketchSpec::simhash(*fam, seed, 16);
        let sh_direct = SimHash::new(*fam, seed, 16);
        let sh_spec_built = sh_spec.build_simhash().unwrap();

        let fh_spec = SketchSpec::feature_hash(*fam, seed, 32, SignMode::Paired);
        let fh_direct = FeatureHasher::new(*fam, seed, 32, SignMode::Paired);
        let fh_spec_built = fh_spec.build_feature_hasher().unwrap();

        let bb_spec = SketchSpec::bbit(*fam, seed, 2, 64);
        let bb_spec_built = bb_spec.build_bbit().unwrap();

        let erased = [
            oph_spec.build(),
            mh_spec.build(),
            sh_spec.build(),
            fh_spec.build(),
            bb_spec.build(),
        ];

        Runner::new(cases).run(
            &format!("spec == direct {}", fam.id()),
            set_gen(200),
            |set| {
                let mut scratch = Scratch::new();
                let oph_out = oph_direct.sketch(set);
                let mh_out = mh_direct.sketch(set);
                let sh_out = Sketcher::sketch(&sh_direct, set);
                let fh_out = Sketcher::sketch(&fh_direct, set);
                let bb_out = BbitSketch::from_oph(&oph_out, 2);
                let erased_ok = erased.iter().zip([
                    SketchValue::Oph(oph_out.clone()),
                    SketchValue::MinHash(mh_out.clone()),
                    SketchValue::SimHash(sh_out.clone()),
                    SketchValue::FeatureHash(fh_out.clone()),
                    SketchValue::BBit(bb_out.clone()),
                ]) // the erased registry path agrees with the typed path
                .all(|(dyn_sk, expect)| dyn_sk.sketch_dyn(set, &mut scratch) == expect);
                // SimHash sketches the unit indicator of the set.
                let indicator = SparseVector::unit_indicator(set);
                oph_spec_built.sketch(set) == oph_out
                    && oph_reparsed.sketch(set) == oph_out
                    && mh_spec_built.sketch(set) == mh_out
                    && sh_spec_built.sketch_with(&indicator, &mut scratch) == sh_out
                    && Sketcher::sketch(&fh_spec_built, set) == fh_out
                    && bb_spec_built.sketch(set) == bb_out
                    && erased_ok
            },
        );
    }
}

/// The pooled half of the `HashSource` contract: pooled spec-built
/// sketchers are bit-identical to direct pooled constructions, the
/// batched (pool-in-Scratch) path equals the per-key reference, and the
/// canonical `pool=` string round-trips with identical output. (The
/// `pool=0`/absent path is pinned by
/// `prop_spec_registry_bit_identical_to_direct_construction` above: those
/// sketchers are the pre-refactor constructions behind
/// `IndependentSource`.)
#[test]
fn prop_pooled_sketchers_bit_identical_across_paths() {
    use mixtab::data::SparseVector;
    use mixtab::sketch::minhash::MinHash;
    use mixtab::sketch::simhash::SimHash;
    use mixtab::sketch::{Scratch, SketchSpec};

    for fam in [
        HashFamily::MixedTab,
        HashFamily::Murmur3,
        HashFamily::MultiplyShift,
    ] {
        let seed = 0xFACEu64;
        let mh_spec = SketchSpec::minhash_pooled(fam, seed, 16, 256);
        let mh_direct = MinHash::pooled(fam, seed, 16, 256);
        let mh_built = mh_spec.build_minhash().unwrap();
        let mh_reparsed = SketchSpec::parse(&mh_spec.to_string())
            .unwrap()
            .build_minhash()
            .unwrap();
        let sh_spec = SketchSpec::simhash_pooled(fam, seed, 24, 128);
        let sh_direct = SimHash::pooled(fam, seed, 24, 128);
        let sh_built = sh_spec.build_simhash().unwrap();
        let sh_reparsed = SketchSpec::parse(&sh_spec.to_string())
            .unwrap()
            .build_simhash()
            .unwrap();
        Runner::new(16).run(
            &format!("pooled spec == direct {}", fam.id()),
            set_gen(200),
            |set| {
                let mut scratch = Scratch::new();
                let v = SparseVector::unit_indicator(set);
                let mh_out = mh_direct.sketch_with(set, &mut scratch);
                let sh_out = sh_direct.sketch_with(&v, &mut scratch);
                mh_out == mh_direct.sketch_per_key(set)
                    && mh_built.sketch_with(set, &mut scratch) == mh_out
                    && mh_reparsed.sketch_with(set, &mut scratch) == mh_out
                    && sh_out == sh_direct.sketch_per_key(&v)
                    && sh_built.sketch_with(&v, &mut scratch) == sh_out
                    && sh_reparsed.sketch_with(&v, &mut scratch) == sh_out
            },
        );
    }
}
