//! Cross-family integration tests over the full hash zoo: reference
//! vectors, statistical quality gates, and the family registry.

use mixtab::hash::blake2::blake2b;
use mixtab::hash::murmur3::murmur3_x86_32;
use mixtab::hash::HashFamily;
use mixtab::util::rng::Xoshiro256;

/// Chi-squared uniformity gate over 256 buckets for every family: dense
/// sequential keys (the adversarial-for-weak-schemes input shape) must still
/// spread ~uniformly for the *strong* families, and at minimum produce every
/// bucket for all families.
#[test]
fn bucket_coverage_all_families() {
    for fam in HashFamily::TABLE1 {
        let h = fam.build(99);
        let mut counts = [0u32; 256];
        let n = if *fam == HashFamily::Blake2 { 20_000 } else { 200_000 };
        for x in 0..n as u32 {
            counts[(h.hash(x) >> 24) as usize] += 1;
        }
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert_eq!(nonzero, 256, "{}: empty buckets", fam.id());
    }
}

#[test]
fn strong_families_pass_chi_squared_on_dense_keys() {
    for fam in [HashFamily::MixedTab, HashFamily::Murmur3, HashFamily::City, HashFamily::Poly20] {
        let h = fam.build(7);
        let mut counts = [0f64; 256];
        let n = 256_000u32;
        for x in 0..n {
            counts[(h.hash(x) & 0xFF) as usize] += 1.0;
        }
        let expect = n as f64 / 256.0;
        let chi2: f64 = counts.iter().map(|c| (c - expect).powi(2) / expect).sum();
        // df = 255; mean 255, sd ≈ 22.6. Gate at +6σ ≈ 391.
        assert!(chi2 < 391.0, "{}: chi2 {chi2}", fam.id());
    }
}

/// Avalanche matrix gate: for strong families, each input bit flip changes
/// each output bit with probability ≈ 0.5 (aggregate check).
#[test]
fn avalanche_gate_strong_families() {
    for fam in [HashFamily::MixedTab, HashFamily::Murmur3, HashFamily::City] {
        let h = fam.build(3);
        let mut rng = Xoshiro256::new(1);
        let trials = 4000;
        let mut flips = 0u64;
        for _ in 0..trials {
            let x = rng.next_u32();
            let bit = 1u32 << rng.below(32);
            flips += (h.hash(x) ^ h.hash(x ^ bit)).count_ones() as u64;
        }
        let rate = flips as f64 / (trials as f64 * 32.0);
        assert!((rate - 0.5).abs() < 0.02, "{}: avalanche {rate}", fam.id());
    }
}

/// The weak families' *structural* weakness is visible: multiply-shift on a
/// dense block [0, n) produces bin assignments (mod k) that are far from
/// binomially distributed — exactly the §4.1 mechanism. Mixed tabulation
/// does not show this.
#[test]
fn dense_block_bin_occupancy_contrast() {
    let k = 64usize;
    let spread = |fam: HashFamily| -> f64 {
        // Variance of per-bin counts over many seeds; truly random ⇒
        // variance ≈ n·p·(1−p) ≈ 2000/64. Structured mappings deviate.
        let mut devs = Vec::new();
        for seed in 0..40u64 {
            let h = fam.build(seed);
            let mut counts = vec![0f64; k];
            for x in 0..2000u32 {
                counts[(h.hash(x) as usize) % k] += 1.0;
            }
            let mean = 2000.0 / k as f64;
            let var = counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / k as f64;
            devs.push(var);
        }
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        devs[devs.len() / 2]
    };
    let binomial_var = 2000.0 / 64.0 * (1.0 - 1.0 / 64.0);
    let mt = spread(HashFamily::MixedTab);
    let ms = spread(HashFamily::MultiplyShift);
    // Mixed tabulation tracks the binomial variance within 2×.
    assert!(
        mt < binomial_var * 2.0,
        "mixed_tab occupancy variance {mt} vs binomial {binomial_var}"
    );
    // Multiply-shift's dense-block occupancy is *too even* (sub-binomial) —
    // the systematic structure the paper exploits. Median across seeds
    // should sit well below the binomial variance.
    assert!(
        ms < binomial_var * 0.7,
        "multiply-shift should be anomalously even: {ms} vs {binomial_var}"
    );
}

#[test]
fn murmur3_spec_vectors_via_public_api() {
    assert_eq!(murmur3_x86_32(b"", 0), 0);
    assert_eq!(murmur3_x86_32(b"", 1), 0x514E_28B7);
    assert_eq!(murmur3_x86_32(&[0x21, 0x43, 0x65, 0x87], 0), 0xF55B_516B);
}

#[test]
fn blake2b_rfc_vector_via_public_api() {
    let d = blake2b(64, &[], b"abc");
    assert_eq!(d[0], 0xBA);
    assert_eq!(d[63], 0x23);
}

#[test]
fn hash64_splits_are_consistent() {
    for fam in [HashFamily::MixedTab, HashFamily::Murmur3] {
        let h64 = fam.build64(5);
        let a = h64.hash64(42);
        let b = h64.hash64(42);
        assert_eq!(a, b, "{}", fam.id());
        // Different keys give different wide values.
        assert_ne!(h64.hash64(1), h64.hash64(2));
    }
}

#[test]
fn registry_is_total() {
    for fam in HashFamily::TABLE1 {
        assert!(HashFamily::parse(fam.id()).is_some());
        assert!(!fam.label().is_empty());
    }
    for fam in HashFamily::FIGURES {
        let h = fam.build(1);
        let _ = h.hash(0);
    }
}
