//! Coordinator end-to-end: native and PJRT paths, TCP round-trips,
//! concurrent load, backpressure, multi-scheme serving, per-connection
//! throttling, and spec-cache behaviour under concurrency.

use mixtab::coordinator::config::{CoordinatorConfig, SchemeConfig};
use mixtab::coordinator::request::{ExecPath, Request, Response};
use mixtab::coordinator::server::{Client, Server};
use mixtab::coordinator::Coordinator;
use mixtab::data::mnist_like;
use mixtab::hash::HashFamily;
use mixtab::sketch::estimators::jaccard_exact;
use mixtab::sketch::SketchSpec;
use std::sync::Arc;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Full service flow over TCP with the native path.
#[test]
fn tcp_flow_native() {
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 64,
        oph_k: 100,
        lsh_k: 6,
        lsh_l: 8,
        ..Default::default()
    }));
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // Insert a small database.
    let (db_ds, _) = mnist_like::default_split(40, 5, 9);
    let sets = db_ds.as_sets();
    for (i, s) in sets.iter().enumerate() {
        let r = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: s.clone(),
                scheme: None,
            })
            .unwrap();
        assert!(matches!(r, Response::Inserted { .. }));
    }
    // Query with a database member: must retrieve itself.
    let r = c
        .call(&Request::LshQuery {
            set: sets[0].clone(),
            scheme: None,
        })
        .unwrap();
    let Response::Candidates { ids } = r else { panic!() };
    assert!(ids.contains(&0));

    // Estimate between two stored ids tracks the exact Jaccard loosely
    // (served from the sketches stored at insert time).
    let r = c
        .call(&Request::Estimate {
            a: 0,
            b: 1,
            scheme: None,
        })
        .unwrap();
    let Response::Estimate { jaccard } = r else { panic!() };
    let truth = jaccard_exact(&sets[0], &sets[1]);
    assert!((jaccard - truth).abs() < 0.25, "est {jaccard} truth {truth}");

    // Stats reflect the traffic.
    let Response::Stats { json } = c.call(&Request::Stats).unwrap() else {
        panic!()
    };
    assert_eq!(
        json.get("lsh_inserts").unwrap().as_i64(),
        Some(sets.len() as i64)
    );
    server.stop();
}

/// With artifacts present, FH requests flow through the PJRT batcher and
/// the result matches the native computation.
#[test]
fn pjrt_path_agrees_with_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let pjrt = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        max_delay_us: 100,
        ..Default::default()
    });
    if !pjrt.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let native = Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 128,
        ..Default::default()
    });
    let indices: Vec<u32> = (0..300u32).map(|i| i * 977).collect();
    let values: Vec<f64> = (0..300).map(|i| ((i % 17) as f64 - 8.0) / 10.0).collect();
    let rp = pjrt.handle(Request::FhTransform {
        indices: indices.clone(),
        values: values.clone(),
    });
    let rn = native.handle(Request::FhTransform { indices, values });
    let (Response::Fh { out: po, sqnorm: ps, path: pp }, Response::Fh { out: no, sqnorm: ns, path: np }) =
        (rp, rn)
    else {
        panic!("wrong response types");
    };
    assert_eq!(pp, ExecPath::Pjrt, "expected pjrt path");
    assert_eq!(np, ExecPath::Native);
    assert_eq!(po.len(), no.len());
    for (a, b) in po.iter().zip(&no) {
        assert!((a - b).abs() < 1e-4, "pjrt {a} native {b}");
    }
    assert!((ps - ns).abs() < 1e-2, "sqnorm {ps} vs {ns}");
}

/// Concurrent FH requests through the batcher: all complete, batching
/// actually batches (mean occupancy > 1 under parallel load).
#[test]
fn concurrent_fh_requests_batch() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        max_delay_us: 2000,
        ..Default::default()
    }));
    if !c.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..10u32 {
                    let resp = c.handle(Request::FhTransform {
                        indices: vec![t * 100 + i, t * 100 + i + 1],
                        values: vec![1.0, -1.0],
                    });
                    assert!(matches!(resp, Response::Fh { .. }));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let occupancy = c.metrics.mean_batch_occupancy();
    let pjrt_rows = c
        .metrics
        .fh_pjrt_rows
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(pjrt_rows > 0, "no rows took the pjrt path");
    assert!(
        occupancy > 1.0,
        "batcher never batched (occupancy {occupancy})"
    );
}

/// PJRT OPH batch path produces sketches identical to the native sketcher
/// (same hasher, same bin arithmetic, same densification bits).
#[test]
fn pjrt_oph_batch_matches_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        oph_k: 200, // matches the exported oph_b16_n512_k200 artifact
        ..Default::default()
    });
    if !c.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let sets: Vec<Vec<u32>> = (0..20u32)
        .map(|i| (i * 13..i * 13 + 150 + i * 3).map(|x| x.wrapping_mul(2654435761)).collect())
        .collect();
    let batched = c.oph_sketch_batch(&sets);
    assert_eq!(batched.len(), sets.len());
    for (set, sk) in sets.iter().zip(&batched) {
        // Must equal the service's native sketch exactly.
        let Response::Sketch { bins } = c.handle(Request::OphSketch { set: set.clone() })
        else {
            panic!()
        };
        assert_eq!(sk.bins, bins, "pjrt/native sketch divergence");
        assert_eq!(sk.empty_bins(), 0);
    }
}

/// Two named schemes served concurrently from one coordinator over TCP:
/// per-scheme inserts/queries are isolated, each scheme's index is
/// sharded, unknown names error cleanly, and the legacy `oph` op stays
/// byte-compatible with the pre-scheme coordinator.
#[test]
fn multi_scheme_roundtrips_over_tcp() {
    let cfg = CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 32,
        oph_k: 60,
        lsh_k: 4,
        lsh_l: 6,
        lsh_shards: 2,
        schemes: vec![
            SchemeConfig {
                name: "alpha".into(),
                spec: SketchSpec::oph(HashFamily::MixedTab, 5, 48),
                shards: 3,
            },
            SchemeConfig {
                name: "beta".into(),
                spec: SketchSpec::oph(HashFamily::Murmur3, 11, 32),
                shards: 2,
            },
            SchemeConfig {
                name: "dense".into(),
                spec: SketchSpec::minhash(HashFamily::MixedTab, 9, 16),
                shards: 1,
            },
        ],
        ..Default::default()
    };
    let oph_spec = cfg.oph_spec();
    let coordinator = Arc::new(Coordinator::new(cfg));
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let addr = server.addr();

    // Drive the two OPH schemes concurrently from separate connections.
    let handles: Vec<_> = ["alpha", "beta"]
        .into_iter()
        .map(|scheme| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let base = if scheme == "alpha" { 0u32 } else { 50_000 };
                let sets: Vec<Vec<u32>> = (0..20u32)
                    .map(|i| (base + i * 40..base + i * 40 + 80).collect())
                    .collect();
                for (i, s) in sets.iter().enumerate() {
                    let r = c
                        .call(&Request::LshInsert {
                            id: i as u32,
                            set: s.clone(),
                            scheme: Some(scheme.into()),
                        })
                        .unwrap();
                    assert!(matches!(r, Response::Inserted { .. }), "{scheme}");
                }
                // Every set retrieves itself within its own scheme.
                for (i, s) in sets.iter().enumerate() {
                    let Response::Candidates { ids } = c
                        .call(&Request::LshQuery {
                            set: s.clone(),
                            scheme: Some(scheme.into()),
                        })
                        .unwrap()
                    else {
                        panic!("{scheme}")
                    };
                    assert!(ids.contains(&(i as u32)), "{scheme} set {i}");
                }
                sets
            })
        })
        .collect();
    let mut per_scheme_sets = Vec::new();
    for h in handles {
        per_scheme_sets.push(h.join().unwrap());
    }

    let mut c = Client::connect(addr).unwrap();
    // Isolation: alpha's corpus is invisible to beta and to the default.
    for (scheme, foreign) in [("beta", &per_scheme_sets[0]), ("alpha", &per_scheme_sets[1])] {
        let Response::Candidates { ids } = c
            .call(&Request::LshQuery {
                set: foreign[0].clone(),
                scheme: Some(scheme.into()),
            })
            .unwrap()
        else {
            panic!()
        };
        assert!(ids.is_empty(), "{scheme} saw a foreign scheme's insert");
    }
    let Response::Candidates { ids } = c
        .call(&Request::LshQuery {
            set: per_scheme_sets[0][0].clone(),
            scheme: None,
        })
        .unwrap()
    else {
        panic!()
    };
    assert!(ids.is_empty(), "default scheme saw a named scheme's insert");

    // Scheme-selected sketching, including the index-less minhash scheme.
    let Response::SketchValue { value } = c
        .call(&Request::Sketch {
            set: (0..100).collect(),
            spec: None,
            scheme: Some("dense".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    assert_eq!((value.scheme_id(), value.len()), ("minhash", 16));
    let Response::Error { message } = c
        .call(&Request::LshInsert {
            id: 1,
            set: vec![1, 2, 3],
            scheme: Some("dense".into()),
        })
        .unwrap()
    else {
        panic!("index-less scheme must reject inserts")
    };
    assert!(message.contains("no LSH index"), "{message}");

    // Unknown scheme names are clean wire errors.
    for req in [
        Request::Sketch {
            set: vec![1],
            spec: None,
            scheme: Some("nope".into()),
        },
        Request::LshInsert {
            id: 1,
            set: vec![1],
            scheme: Some("nope".into()),
        },
        Request::LshQuery {
            set: vec![1],
            scheme: Some("nope".into()),
        },
    ] {
        let Response::Error { message } = c.call(&req).unwrap() else {
            panic!("expected unknown-scheme error")
        };
        assert!(message.contains("unknown scheme"), "{message}");
    }

    // Legacy `oph` op: still the `sketch` wire shape, bins bit-identical
    // to the pre-scheme coordinator's OPH sketcher.
    let set: Vec<u32> = (0..300).collect();
    let Response::Sketch { bins } = c
        .call(&Request::OphSketch { set: set.clone() })
        .unwrap()
    else {
        panic!()
    };
    let expected = oph_spec.build_oph().unwrap().sketch(&set);
    assert_eq!(bins, expected.bins, "legacy oph op diverged");

    // Per-scheme + per-shard counters surfaced through `stats`.
    let Response::Stats { json } = c.call(&Request::Stats).unwrap() else {
        panic!()
    };
    let schemes = json.get("schemes").unwrap();
    for (name, shards) in [("default", 2), ("alpha", 3), ("beta", 2), ("dense", 0)] {
        let block = schemes
            .get(name)
            .unwrap_or_else(|| panic!("scheme '{name}' missing from stats"));
        assert_eq!(
            block.get("shards").unwrap().as_arr().unwrap().len(),
            shards,
            "{name}"
        );
    }
    let alpha = schemes.get("alpha").unwrap();
    assert_eq!(alpha.get("inserts").unwrap().as_i64(), Some(20));
    let alpha_shard_inserts: i64 = alpha
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("inserts").unwrap().as_i64().unwrap())
        .sum();
    assert_eq!(alpha_shard_inserts, 20, "per-shard inserts must sum to total");
    server.stop();
}

/// Scheme-aware `estimate`/`save_index`/`load_index` over TCP, including
/// every panic-free error path: index-less (non-OPH) schemes reject
/// persistence cleanly (the pre-PR5 `save_index` expect would have killed
/// the connection thread), unknown schemes and ids error, provenance
/// mismatches are rejected, and a snapshot round-trips through
/// `load_index` on a fresh coordinator with a parallel fan-out pool.
#[test]
fn scheme_aware_estimate_and_persistence_over_tcp() {
    let dir = std::env::temp_dir().join("mixtab_e2e_load_save");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 32,
        oph_k: 60,
        lsh_k: 4,
        lsh_l: 6,
        workers: 3, // parallel fan-out over alpha's 3 shards
        schemes: vec![
            SchemeConfig {
                name: "alpha".into(),
                spec: SketchSpec::oph(HashFamily::MixedTab, 5, 48),
                shards: 3,
            },
            SchemeConfig {
                name: "dense".into(),
                spec: SketchSpec::minhash(HashFamily::MixedTab, 9, 16),
                shards: 1,
            },
        ],
        ..Default::default()
    };
    let coordinator = Arc::new(Coordinator::new(cfg.clone()));
    assert_eq!(coordinator.fanout_workers(), 3);
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    let (db_ds, _) = mnist_like::default_split(30, 5, 4);
    let sets = db_ds.as_sets();
    for (i, s) in sets.iter().enumerate() {
        let r = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: s.clone(),
                scheme: Some("alpha".into()),
            })
            .unwrap();
        assert!(matches!(r, Response::Inserted { .. }));
    }

    // Estimate within the named scheme: served from the 48-bin OPH
    // sketches alpha stored at insert time, tracking the exact Jaccard.
    let Response::Estimate { jaccard } = c
        .call(&Request::Estimate {
            a: 0,
            b: 1,
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    let truth = jaccard_exact(&sets[0], &sets[1]);
    assert!((jaccard - truth).abs() < 0.3, "est {jaccard} truth {truth}");
    // The default scheme never saw these ids — clean error, not a
    // cross-scheme answer.
    let Response::Error { message } = c
        .call(&Request::Estimate {
            a: 0,
            b: 1,
            scheme: None,
        })
        .unwrap()
    else {
        panic!()
    };
    assert!(message.contains("unknown id"), "{message}");

    // Unknown scheme names error cleanly on every new scheme-aware op.
    let snap = dir.join("alpha.mxsh").display().to_string();
    for req in [
        Request::Estimate {
            a: 0,
            b: 1,
            scheme: Some("nope".into()),
        },
        Request::SaveIndex {
            path: snap.clone(),
            scheme: Some("nope".into()),
        },
        Request::LoadIndex {
            path: snap.clone(),
            scheme: Some("nope".into()),
        },
        Request::IndexDoc {
            id: 1,
            text: "doc".into(),
            scheme: Some("nope".into()),
        },
        Request::QueryDoc {
            text: "doc".into(),
            scheme: Some("nope".into()),
        },
    ] {
        let Response::Error { message } = c.call(&req).unwrap() else {
            panic!("expected unknown-scheme error")
        };
        assert!(message.contains("unknown scheme"), "{message}");
    }

    // Index-less (non-OPH) scheme: save/load are wire errors and the
    // connection survives — this is the path that used to be an
    // `.expect()` away from killing the connection thread.
    for req in [
        Request::SaveIndex {
            path: dir.join("dense.mxsh").display().to_string(),
            scheme: Some("dense".into()),
        },
        Request::LoadIndex {
            path: snap.clone(),
            scheme: Some("dense".into()),
        },
    ] {
        let Response::Error { message } = c.call(&req).unwrap() else {
            panic!("index-less scheme must reject persistence")
        };
        assert!(message.contains("no LSH index"), "{message}");
    }
    assert!(matches!(
        c.call(&Request::Stats).unwrap(),
        Response::Stats { .. }
    ));

    // Snapshot alpha (3 shards → manifest + per-shard files).
    let Response::Saved { entries, .. } = c
        .call(&Request::SaveIndex {
            path: snap.clone(),
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    assert_eq!(entries, sets.len());
    server.stop();

    // A fresh coordinator restores the snapshot over TCP.
    let server = Server::start(Arc::new(Coordinator::new(cfg)), "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    // …but only into the scheme whose provenance matches: the default
    // scheme's spec (different seed/family derivation) is rejected.
    let Response::Error { message } = c
        .call(&Request::LoadIndex {
            path: snap.clone(),
            scheme: None,
        })
        .unwrap()
    else {
        panic!("default-scheme load of an alpha snapshot must fail")
    };
    assert!(message.contains("does not match"), "{message}");
    let Response::Loaded {
        entries, shards, ..
    } = c
        .call(&Request::LoadIndex {
            path: snap.clone(),
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    assert_eq!((entries, shards), (sets.len(), 3));
    // The reloaded shards serve fan-out queries (self-retrieval).
    for (i, s) in sets.iter().enumerate().take(8) {
        let Response::Candidates { ids } = c
            .call(&Request::LshQuery {
                set: s.clone(),
                scheme: Some("alpha".into()),
            })
            .unwrap()
        else {
            panic!()
        };
        assert!(ids.contains(&(i as u32)), "set {i} lost across save/load");
    }
    // The estimate sketch store is not part of snapshots (documented):
    // loaded ids serve queries, not estimates.
    let Response::Error { .. } = c
        .call(&Request::Estimate {
            a: 0,
            b: 1,
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    // A missing snapshot errors cleanly and leaves the loaded index
    // serving.
    let Response::Error { .. } = c
        .call(&Request::LoadIndex {
            path: dir.join("missing.mxsh").display().to_string(),
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    let Response::Candidates { ids } = c
        .call(&Request::LshQuery {
            set: sets[0].clone(),
            scheme: Some("alpha".into()),
        })
        .unwrap()
    else {
        panic!()
    };
    assert!(ids.contains(&0));
    // Stats surface the persistence counters and per-scheme estimates.
    let Response::Stats { json } = c.call(&Request::Stats).unwrap() else {
        panic!()
    };
    assert_eq!(json.get("index_loads").unwrap().as_i64(), Some(1));
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A request line carrying an unknown field — the classic mistyped
/// `scheme` — is rejected at the parser, not silently served by the
/// default scheme.
#[test]
fn mistyped_scheme_field_is_rejected_on_the_wire() {
    use std::io::{BufRead, BufReader, BufWriter, Write};
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 16,
        oph_k: 20,
        ..Default::default()
    }));
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let stream = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut w = BufWriter::new(stream.try_clone().unwrap());
    let mut r = BufReader::new(stream);
    w.write_all(b"{\"op\":\"estimate\",\"a\":1,\"b\":2,\"shceme\":\"alpha\"}\n")
        .unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let resp = Response::from_json_line(line.trim_end()).unwrap();
    let Response::Error { message } = resp else {
        panic!("mistyped field must not be served: {resp:?}")
    };
    assert!(message.contains("unknown field"), "{message}");
    server.stop();
}

/// An over-budget connection is throttled while a second connection on
/// the same server is unaffected — throttling state is per-connection.
#[test]
fn rate_limit_throttles_per_connection() {
    // Token bucket: burst 2, negligible refill over the test's lifetime.
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 16,
        oph_k: 20,
        rate_limit_rps: 0.001,
        rate_limit_burst: 2,
        ..Default::default()
    }));
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").unwrap();
    let mut hog = Client::connect(server.addr()).unwrap();
    let mut ok = 0;
    let mut throttled = 0;
    for _ in 0..6 {
        match hog.call(&Request::Stats).unwrap() {
            Response::Stats { .. } => ok += 1,
            Response::Error { message } => {
                assert!(message.contains("rate limited"), "{message}");
                throttled += 1;
            }
            other => panic!("unexpected response {other:?}"),
        }
    }
    assert_eq!(ok, 2, "exactly the burst should be admitted");
    assert_eq!(throttled, 4);
    // A fresh connection has its own full bucket.
    let mut second = Client::connect(server.addr()).unwrap();
    let r = second.call(&Request::Stats).unwrap();
    assert!(
        matches!(r, Response::Stats { .. }),
        "second connection must be unaffected"
    );
    // Throttled requests are counted.
    let throttled_metric = coordinator
        .metrics
        .throttled
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(throttled_metric, 4);
    server.stop();
}

/// A hard per-connection request budget: the over-budget connection gets
/// one final error and is closed; a new connection starts a fresh budget.
#[test]
fn request_budget_closes_connection() {
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 16,
        oph_k: 20,
        conn_request_budget: 3,
        ..Default::default()
    }));
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    for _ in 0..3 {
        assert!(matches!(
            c.call(&Request::Stats).unwrap(),
            Response::Stats { .. }
        ));
    }
    let Response::Error { message } = c.call(&Request::Stats).unwrap() else {
        panic!("expected budget-exhausted error")
    };
    assert!(message.contains("budget exhausted"), "{message}");
    // The server closed the connection: the next call fails.
    assert!(c.call(&Request::Stats).is_err());
    // A fresh connection gets a fresh budget.
    let mut fresh = Client::connect(server.addr()).unwrap();
    assert!(matches!(
        fresh.call(&Request::Stats).unwrap(),
        Response::Stats { .. }
    ));
    server.stop();
}

/// Hammer the per-request spec-sketcher cache from many threads with a
/// mix of repeated and distinct specs: no panics, no poisoned locks, and
/// the cache population stays within its bound.
#[test]
fn spec_cache_bounded_under_concurrency() {
    let c = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 16,
        oph_k: 20,
        ..Default::default()
    }));
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..30u32 {
                    // 8 threads × 30 iterations over ~20 distinct specs —
                    // far beyond the cache cap, with heavy key overlap.
                    let spec = format!("minhash(k=4,seed={})", (t * 30 + i) % 20);
                    let resp = c.handle(Request::Sketch {
                        set: vec![1, 2, 3, 4, 5],
                        spec: Some(spec),
                        scheme: None,
                    });
                    assert!(
                        matches!(resp, Response::SketchValue { .. }),
                        "sketch failed on thread {t}"
                    );
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert!(
        c.spec_cache_len() <= Coordinator::SPEC_CACHE_CAP,
        "cache grew past its cap: {}",
        c.spec_cache_len()
    );
    // The cache (and its locks) remain usable after the storm.
    let resp = c.handle(Request::Sketch {
        set: vec![9, 9, 9],
        spec: Some("minhash(k=4,seed=0)".into()),
        scheme: None,
    });
    assert!(matches!(resp, Response::SketchValue { .. }));
}

/// Oversized vectors (beyond the compiled nnz bound) fall back to native.
#[test]
fn oversized_vector_falls_back_to_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        ..Default::default()
    });
    if !c.pjrt_enabled() {
        return;
    }
    let indices: Vec<u32> = (0..2000u32).collect(); // > compiled nnz 512
    let values = vec![0.1f64; 2000];
    let Response::Fh { path, .. } = c.handle(Request::FhTransform { indices, values }) else {
        panic!()
    };
    assert_eq!(path, ExecPath::Native);
}
