//! Coordinator end-to-end: native and PJRT paths, TCP round-trips,
//! concurrent load, backpressure.

use mixtab::coordinator::config::CoordinatorConfig;
use mixtab::coordinator::request::{ExecPath, Request, Response};
use mixtab::coordinator::server::{Client, Server};
use mixtab::coordinator::Coordinator;
use mixtab::data::mnist_like;
use mixtab::sketch::estimators::jaccard_exact;
use std::sync::Arc;

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Full service flow over TCP with the native path.
#[test]
fn tcp_flow_native() {
    let coordinator = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 64,
        oph_k: 100,
        lsh_k: 6,
        lsh_l: 8,
        ..Default::default()
    }));
    let server = Server::start(coordinator, "127.0.0.1:0").unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // Insert a small database.
    let (db_ds, _) = mnist_like::default_split(40, 5, 9);
    let sets = db_ds.as_sets();
    for (i, s) in sets.iter().enumerate() {
        let r = c
            .call(&Request::LshInsert {
                id: i as u32,
                set: s.clone(),
            })
            .unwrap();
        assert!(matches!(r, Response::Inserted { .. }));
    }
    // Query with a database member: must retrieve itself.
    let r = c
        .call(&Request::LshQuery {
            set: sets[0].clone(),
        })
        .unwrap();
    let Response::Candidates { ids } = r else { panic!() };
    assert!(ids.contains(&0));

    // Estimate between two stored sets tracks the exact Jaccard loosely.
    let r = c.call(&Request::Estimate { a: 0, b: 1 }).unwrap();
    let Response::Estimate { jaccard } = r else { panic!() };
    let truth = jaccard_exact(&sets[0], &sets[1]);
    assert!((jaccard - truth).abs() < 0.25, "est {jaccard} truth {truth}");

    // Stats reflect the traffic.
    let Response::Stats { json } = c.call(&Request::Stats).unwrap() else {
        panic!()
    };
    assert_eq!(
        json.get("lsh_inserts").unwrap().as_i64(),
        Some(sets.len() as i64)
    );
    server.stop();
}

/// With artifacts present, FH requests flow through the PJRT batcher and
/// the result matches the native computation.
#[test]
fn pjrt_path_agrees_with_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let pjrt = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        max_delay_us: 100,
        ..Default::default()
    });
    if !pjrt.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let native = Coordinator::new(CoordinatorConfig {
        enable_pjrt: false,
        fh_dim: 128,
        ..Default::default()
    });
    let indices: Vec<u32> = (0..300u32).map(|i| i * 977).collect();
    let values: Vec<f64> = (0..300).map(|i| ((i % 17) as f64 - 8.0) / 10.0).collect();
    let rp = pjrt.handle(Request::FhTransform {
        indices: indices.clone(),
        values: values.clone(),
    });
    let rn = native.handle(Request::FhTransform { indices, values });
    let (Response::Fh { out: po, sqnorm: ps, path: pp }, Response::Fh { out: no, sqnorm: ns, path: np }) =
        (rp, rn)
    else {
        panic!("wrong response types");
    };
    assert_eq!(pp, ExecPath::Pjrt, "expected pjrt path");
    assert_eq!(np, ExecPath::Native);
    assert_eq!(po.len(), no.len());
    for (a, b) in po.iter().zip(&no) {
        assert!((a - b).abs() < 1e-4, "pjrt {a} native {b}");
    }
    assert!((ps - ns).abs() < 1e-2, "sqnorm {ps} vs {ns}");
}

/// Concurrent FH requests through the batcher: all complete, batching
/// actually batches (mean occupancy > 1 under parallel load).
#[test]
fn concurrent_fh_requests_batch() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Arc::new(Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        max_delay_us: 2000,
        ..Default::default()
    }));
    if !c.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                for i in 0..10u32 {
                    let resp = c.handle(Request::FhTransform {
                        indices: vec![t * 100 + i, t * 100 + i + 1],
                        values: vec![1.0, -1.0],
                    });
                    assert!(matches!(resp, Response::Fh { .. }));
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    let occupancy = c.metrics.mean_batch_occupancy();
    let pjrt_rows = c
        .metrics
        .fh_pjrt_rows
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(pjrt_rows > 0, "no rows took the pjrt path");
    assert!(
        occupancy > 1.0,
        "batcher never batched (occupancy {occupancy})"
    );
}

/// PJRT OPH batch path produces sketches identical to the native sketcher
/// (same hasher, same bin arithmetic, same densification bits).
#[test]
fn pjrt_oph_batch_matches_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        oph_k: 200, // matches the exported oph_b16_n512_k200 artifact
        ..Default::default()
    });
    if !c.pjrt_enabled() {
        eprintln!("SKIP: pjrt failed to initialise");
        return;
    }
    let sets: Vec<Vec<u32>> = (0..20u32)
        .map(|i| (i * 13..i * 13 + 150 + i * 3).map(|x| x.wrapping_mul(2654435761)).collect())
        .collect();
    let batched = c.oph_sketch_batch(&sets);
    assert_eq!(batched.len(), sets.len());
    for (set, sk) in sets.iter().zip(&batched) {
        // Must equal the service's native sketch exactly.
        let Response::Sketch { bins } = c.handle(Request::OphSketch { set: set.clone() })
        else {
            panic!()
        };
        assert_eq!(sk.bins, bins, "pjrt/native sketch divergence");
        assert_eq!(sk.empty_bins(), 0);
    }
}

/// Oversized vectors (beyond the compiled nnz bound) fall back to native.
#[test]
fn oversized_vector_falls_back_to_native() {
    if !artifacts_present() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let c = Coordinator::new(CoordinatorConfig {
        enable_pjrt: true,
        fh_dim: 128,
        ..Default::default()
    });
    if !c.pjrt_enabled() {
        return;
    }
    let indices: Vec<u32> = (0..2000u32).collect(); // > compiled nnz 512
    let values = vec![0.1f64; 2000];
    let Response::Fh { path, .. } = c.handle(Request::FhTransform { indices, values }) else {
        panic!()
    };
    assert_eq!(path, ExecPath::Native);
}
