//! Feature Hashing (§2.2) — Weinberger et al., ICML'09.
//!
//! Maps a sparse d-dimensional vector `v` to a dense d'-dimensional vector
//! `v'` with `v'_i = Σ_{j : h(j) = i} sgn(j)·v_j`. Theorem 1 (this paper)
//! shows `‖v′‖₂² ∈ 1 ± ε` whp. for unit `v` under truly random hashing, and
//! Corollary 1 transfers the bound to mixed tabulation for sparse vectors —
//! *including* the variant where bin and sign come from a **single** hash
//! evaluation `h*: [d] → {±1} × [d']` ([`SignMode::Paired`]).
//!
//! The hot loop is one hash + one fused multiply-add per non-zero; this is
//! the Rust-native path. The batched PJRT path (Layer 1/2) lives in
//! `python/compile/` and is fed by [`FeatureHasher::plan`], which exposes
//! the (bin, signed value) pairs for a batch without materialising `v'`.
//!
//! Per-document hash batches go through a caller-provided
//! [`Scratch`] buffer, so a transform stream performs zero hash-buffer
//! allocations after warm-up (the buffers settle at the largest document).

use super::scratch::Scratch;
use crate::data::sparse::SparseVector;
use crate::hash::{HashFamily, Hasher32};

/// Where the sign bit comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignMode {
    /// Independent second hash function for `sgn` (the classic setup of
    /// Weinberger et al.).
    Separate,
    /// Bin and sign extracted from one hash value (`h*` of Corollary 1):
    /// bit 31 is the sign, the low bits give the bin. One evaluation per
    /// non-zero — the speed trick mixed tabulation makes safe.
    Paired,
}

impl SignMode {
    /// Stable identifier used by [`crate::sketch::SketchSpec`] strings and
    /// the coordinator config.
    pub fn id(&self) -> &'static str {
        match self {
            SignMode::Separate => "separate",
            SignMode::Paired => "paired",
        }
    }

    /// Parse the [`Self::id`] form.
    pub fn parse(s: &str) -> Option<SignMode> {
        match s {
            "separate" => Some(SignMode::Separate),
            "paired" => Some(SignMode::Paired),
            _ => None,
        }
    }
}

/// A seeded feature-hashing transform `R^d → R^{d'}`.
///
/// Constructed either from explicit hashers ([`Self::from_hashers`], used
/// by tests with stub hashers) or — the configuration path — from a parsed
/// [`crate::sketch::SketchSpec`] via its `build`/`build_feature_hasher`
/// registry, which delegates to [`Self::new`].
pub struct FeatureHasher {
    hasher: Box<dyn Hasher32>,
    sign_hasher: Option<Box<dyn Hasher32>>,
    output_dim: usize,
    mode: SignMode,
    /// Loop-invariant `mod d'` without hardware division (§Perf).
    fm: crate::util::fastmod::FastMod32,
}

impl FeatureHasher {
    /// Build from a hash family and seed. `output_dim` is d'.
    pub fn new(family: HashFamily, seed: u64, output_dim: usize, mode: SignMode) -> Self {
        assert!(output_dim >= 1);
        let hasher = family.build(seed);
        let sign_hasher = match mode {
            SignMode::Separate => Some(family.build(seed ^ 0x5157_9AC3_11F0_77D2)),
            SignMode::Paired => None,
        };
        Self {
            hasher,
            sign_hasher,
            output_dim,
            mode,
            fm: crate::util::fastmod::FastMod32::new(output_dim as u32),
        }
    }

    /// Build from explicit hashers (used by tests with stub hashers).
    pub fn from_hashers(
        hasher: Box<dyn Hasher32>,
        sign_hasher: Option<Box<dyn Hasher32>>,
        output_dim: usize,
    ) -> Self {
        let mode = if sign_hasher.is_some() {
            SignMode::Separate
        } else {
            SignMode::Paired
        };
        Self {
            hasher,
            sign_hasher,
            output_dim,
            mode,
            fm: crate::util::fastmod::FastMod32::new(output_dim as u32),
        }
    }

    pub fn output_dim(&self) -> usize {
        self.output_dim
    }

    pub fn mode(&self) -> SignMode {
        self.mode
    }

    pub fn hasher_name(&self) -> &'static str {
        self.hasher.name()
    }

    /// Bin index and sign for feature id `j`.
    #[inline]
    pub fn slot(&self, j: u32) -> (usize, f64) {
        let h = self.hasher.hash(j);
        match self.mode {
            SignMode::Paired => {
                let bin = self.fm.rem(h & 0x7FFF_FFFF) as usize;
                let sign = if h & 0x8000_0000 != 0 { -1.0 } else { 1.0 };
                (bin, sign)
            }
            SignMode::Separate => {
                let bin = self.fm.rem(h) as usize;
                let s = self.sign_hasher.as_ref().unwrap().hash(j);
                let sign = if s & 1 == 1 { -1.0 } else { 1.0 };
                (bin, sign)
            }
        }
    }

    /// Transform a sparse vector into the dense d'-dim output. Convenience
    /// wrapper around [`Self::transform_into`] with a one-shot [`Scratch`].
    pub fn transform(&self, v: &SparseVector) -> Vec<f64> {
        let mut out = vec![0.0; self.output_dim];
        self.transform_into(v, &mut out, &mut Scratch::with_capacity(v.nnz()));
        out
    }

    /// Transform into a caller-provided buffer (hot path).
    ///
    /// Hashing goes through [`Hasher32::hash_slice`] so the per-key loop is
    /// monomorphic inside the hasher (one dynamic dispatch per vector, not
    /// per non-zero) — worth ~25% on News20-sized documents (§Perf). The
    /// hash batches land in `scratch`, so a loop reusing one [`Scratch`]
    /// allocates nothing per document.
    pub fn transform_into(&self, v: &SparseVector, out: &mut [f64], scratch: &mut Scratch) {
        assert_eq!(out.len(), self.output_dim);
        out.fill(0.0);
        let n = v.indices.len();
        match self.mode {
            SignMode::Paired => {
                let hbuf = scratch.hashes_mut(n);
                self.hasher.hash_slice(&v.indices, hbuf);
                for (&h, &val) in hbuf.iter().zip(&v.values) {
                    let bin = self.fm.rem(h & 0x7FFF_FFFF) as usize;
                    let sign = if h & 0x8000_0000 != 0 { -1.0 } else { 1.0 };
                    out[bin] += sign * val;
                }
            }
            SignMode::Separate => {
                let (hbuf, sbuf) = scratch.hash_pair_mut(n);
                self.hasher.hash_slice(&v.indices, hbuf);
                self.sign_hasher
                    .as_ref()
                    .unwrap()
                    .hash_slice(&v.indices, sbuf);
                for ((&h, &s), &val) in hbuf.iter().zip(sbuf.iter()).zip(&v.values) {
                    let bin = self.fm.rem(h) as usize;
                    let sign = if s & 1 == 1 { -1.0 } else { 1.0 };
                    out[bin] += sign * val;
                }
            }
        }
    }

    /// ‖v′‖₂² without materialising `v'` twice — the §4.1/§4.2 statistic.
    /// The dense output lives in `scratch` too, so repeated calls are
    /// allocation-free.
    pub fn squared_norm(&self, v: &SparseVector, scratch: &mut Scratch) -> f64 {
        // Take the dense buffer out so `scratch` stays available for the
        // hash batches inside `transform_into`.
        let mut dense = std::mem::take(&mut scratch.dense);
        dense.resize(self.output_dim, 0.0);
        self.transform_into(v, &mut dense, scratch);
        let sq = dense.iter().map(|x| x * x).sum();
        scratch.dense = dense;
        sq
    }

    /// Lowered form for the PJRT batch path: `(bins, signed_values)` for one
    /// vector, padded to `max_nnz` with (0, 0.0) no-ops.
    pub fn plan(&self, v: &SparseVector, max_nnz: usize) -> (Vec<i32>, Vec<f32>) {
        assert!(v.nnz() <= max_nnz, "vector nnz exceeds compiled bound");
        let mut bins = Vec::with_capacity(max_nnz);
        let mut vals = Vec::with_capacity(max_nnz);
        for (&j, &val) in v.indices.iter().zip(&v.values) {
            let (bin, sign) = self.slot(j);
            bins.push(bin as i32);
            vals.push((sign * val) as f32);
        }
        bins.resize(max_nnz, 0);
        vals.resize(max_nnz, 0.0);
        (bins, vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::SparseVector;
    use crate::util::rng::Xoshiro256;

    fn unit_indicator(ids: &[u32]) -> SparseVector {
        let val = 1.0 / (ids.len() as f64).sqrt();
        SparseVector::new(ids.to_vec(), vec![val; ids.len()])
    }

    #[test]
    fn preserves_norm_in_expectation() {
        // E[‖v'‖²] = ‖v‖² for any hash function that is 2-independent-ish;
        // average over seeds with mixed tabulation.
        let v = unit_indicator(&(0..300u32).map(|i| i * 7 + 3).collect::<Vec<_>>());
        let mut sum = 0.0;
        let reps = 80;
        let mut scratch = Scratch::new();
        for seed in 0..reps {
            let fh = FeatureHasher::new(HashFamily::MixedTab, seed, 128, SignMode::Separate);
            sum += fh.squared_norm(&v, &mut scratch);
        }
        let mean = sum / reps as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn paired_mode_preserves_norm_too() {
        let v = unit_indicator(&(0..300u32).collect::<Vec<_>>());
        let mut sum = 0.0;
        let reps = 80;
        let mut scratch = Scratch::new();
        for seed in 0..reps {
            let fh = FeatureHasher::new(HashFamily::MixedTab, seed, 128, SignMode::Paired);
            sum += fh.squared_norm(&v, &mut scratch);
        }
        let mean = sum / reps as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn linearity() {
        // FH is a linear map: T(a + b) = T(a) + T(b).
        let mut rng = Xoshiro256::new(4);
        let a = SparseVector::new(
            (0..50u32).collect(),
            (0..50).map(|_| rng.next_f64() - 0.5).collect(),
        );
        let b = SparseVector::new(
            (25..75u32).collect(),
            (0..50).map(|_| rng.next_f64() - 0.5).collect(),
        );
        let fh = FeatureHasher::new(HashFamily::MixedTab, 7, 64, SignMode::Separate);
        let ta = fh.transform(&a);
        let tb = fh.transform(&b);
        let sum_vec = a.add(&b);
        let tsum = fh.transform(&sum_vec);
        for i in 0..64 {
            assert!((tsum[i] - (ta[i] + tb[i])).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let v = unit_indicator(&[1, 5, 99, 1000]);
        let f1 = FeatureHasher::new(HashFamily::Murmur3, 42, 32, SignMode::Separate);
        let f2 = FeatureHasher::new(HashFamily::Murmur3, 42, 32, SignMode::Separate);
        assert_eq!(f1.transform(&v), f2.transform(&v));
    }

    #[test]
    fn plan_matches_transform() {
        let v = unit_indicator(&[3, 17, 256, 70000]);
        let fh = FeatureHasher::new(HashFamily::MixedTab, 11, 64, SignMode::Paired);
        let (bins, vals) = fh.plan(&v, 8);
        assert_eq!(bins.len(), 8);
        // Reconstruct dense output from the plan (f32 precision).
        let mut dense = vec![0.0f32; 64];
        for (b, x) in bins.iter().zip(&vals) {
            dense[*b as usize] += *x;
        }
        let direct = fh.transform(&v);
        for i in 0..64 {
            assert!((dense[i] as f64 - direct[i]).abs() < 1e-6, "bin {i}");
        }
    }

    #[test]
    #[should_panic]
    fn plan_rejects_oversized() {
        let v = unit_indicator(&[1, 2, 3, 4, 5]);
        let fh = FeatureHasher::new(HashFamily::MixedTab, 1, 16, SignMode::Paired);
        let _ = fh.plan(&v, 4);
    }

    #[test]
    fn single_feature_lands_in_one_bin() {
        let v = SparseVector::new(vec![42], vec![1.0]);
        let fh = FeatureHasher::new(HashFamily::Poly20, 5, 100, SignMode::Separate);
        let out = fh.transform(&v);
        let nonzero: Vec<usize> = (0..100).filter(|&i| out[i] != 0.0).collect();
        assert_eq!(nonzero.len(), 1);
        assert!((out[nonzero[0]].abs() - 1.0).abs() < 1e-12);
        // And ‖v'‖² is exactly 1 regardless of hash function.
        let mut scratch = Scratch::new();
        assert!((fh.squared_norm(&v, &mut scratch) - 1.0).abs() < 1e-12);
    }
}
