//! Densification of one-permutation sketches — empty-bin handling.
//!
//! The paper uses the scheme of Shrivastava & Li (UAI'14; [33]) described in
//! §2.1 and illustrated in Figure 1 (right): for each bin `i` a random
//! direction bit `b_i`; an empty bin copies the value of the closest
//! non-empty bin going left (circularly) if `b_i = 0`, going right if
//! `b_i = 1`, and adds `j·C` where `j` is the copy distance and `C` a large
//! offset — so two sketches only agree on a filled bin when they copied the
//! same value from the same distance.
//!
//! [`DensifyMode::Rotation`] additionally provides the one-directional
//! rotation scheme of the earlier ICML'14 paper ([32]) as an ablation, and
//! [`DensifyMode::None`] leaves empty bins in place (used for the raw
//! sketch experiments).

use super::oph::EMPTY_BIN;

/// The offset constant C (§2.1: "some sufficiently large offset parameter").
/// Raw values are `< 2^32`, so `2^33` keeps `v + j·C` collision-free for
/// distinct `(v, j)` pairs up to `j < 2^30`.
pub const OFFSET_C: u64 = 1 << 33;

/// Densification scheme selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DensifyMode {
    /// No densification: empty bins stay [`EMPTY_BIN`].
    None,
    /// Improved densification of [33] (UAI'14): random direction per bin +
    /// `j·C` offset. This is what the paper's experiments use.
    Paper,
    /// One-directional rotation of [32] (ICML'14): always borrow from the
    /// right (circularly), with the same `j·C` offset. Kept as an ablation;
    /// has provably worse variance than [`DensifyMode::Paper`].
    Rotation,
}

impl DensifyMode {
    /// Stable identifier used by [`crate::sketch::SketchSpec`] strings.
    pub fn id(&self) -> &'static str {
        match self {
            DensifyMode::None => "none",
            DensifyMode::Paper => "paper",
            DensifyMode::Rotation => "rotation",
        }
    }

    /// Parse the [`Self::id`] form.
    pub fn parse(s: &str) -> Option<DensifyMode> {
        match s {
            "none" => Some(DensifyMode::None),
            "paper" => Some(DensifyMode::Paper),
            "rotation" => Some(DensifyMode::Rotation),
            _ => None,
        }
    }
}

/// Densify `bins` in place. `directions[i]` is the random bit `b_i`
/// (`false` = left, `true` = right); it must be shared by every sketch that
/// will be compared (it lives in the sketcher, not the sketch).
///
/// If *all* bins are empty (empty input set) the sketch is left untouched.
pub fn densify(bins: &mut [u64], directions: &[bool], mode: DensifyMode) {
    if mode == DensifyMode::None {
        return;
    }
    let k = bins.len();
    assert_eq!(directions.len(), k, "direction bits must match bin count");
    if bins.iter().all(|&b| b == EMPTY_BIN) {
        return;
    }
    // Work from a snapshot so copies always come from *originally* filled
    // bins (copying from a copy would double-apply offsets).
    let snapshot: Vec<u64> = bins.to_vec();
    for i in 0..k {
        if snapshot[i] != EMPTY_BIN {
            continue;
        }
        let go_right = match mode {
            DensifyMode::Paper => directions[i],
            DensifyMode::Rotation => true,
            DensifyMode::None => unreachable!(),
        };
        let mut j = 1u64;
        loop {
            let src = if go_right {
                (i + j as usize) % k
            } else {
                (i + k - (j as usize % k)) % k
            };
            if snapshot[src] != EMPTY_BIN {
                bins[i] = snapshot[src] + j * OFFSET_C;
                break;
            }
            j += 1;
            debug_assert!(j <= k as u64, "no non-empty bin found");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const E: u64 = EMPTY_BIN;
    const C: u64 = OFFSET_C;

    /// Figure 1 (right) worked example: k = 6, non-empty bins
    /// {1: 2, 4: 1, 5: 3}, directions [0,1,1,0,0,1] →
    /// [3+C, 2, 1+2C, 2+2C, 1, 3].
    #[test]
    fn figure1_right_worked_example() {
        let mut bins = vec![E, 2, E, E, 1, 3];
        let dirs = vec![false, true, true, false, false, true];
        densify(&mut bins, &dirs, DensifyMode::Paper);
        assert_eq!(bins, vec![3 + C, 2, 1 + 2 * C, 2 + 2 * C, 1, 3]);
    }

    #[test]
    fn no_empty_bins_after_densify() {
        let mut bins = vec![E, E, 7, E, E, E, E, 9];
        let dirs = vec![true; 8];
        densify(&mut bins, &dirs, DensifyMode::Paper);
        assert!(bins.iter().all(|&b| b != E));
    }

    #[test]
    fn filled_bins_untouched() {
        let mut bins = vec![5, E, 7];
        let dirs = vec![false, false, false];
        densify(&mut bins, &dirs, DensifyMode::Paper);
        assert_eq!(bins[0], 5);
        assert_eq!(bins[2], 7);
        // bin 1 copies left (bin 0) at distance 1.
        assert_eq!(bins[1], 5 + C);
    }

    #[test]
    fn circular_wraparound_left_and_right() {
        // Only bin 2 filled in k = 4.
        let mut left = vec![E, E, 9, E];
        densify(&mut left, &[false, false, false, false], DensifyMode::Paper);
        // bin 0 going left: bin 3 (empty in snapshot!) → bin 2 at distance 2.
        assert_eq!(left[0], 9 + 2 * C);
        // bin 1 going left: bin 0 empty, ... distance 3.
        assert_eq!(left[1], 9 + 3 * C);
        // bin 3 going left: bin 2 at distance 1.
        assert_eq!(left[3], 9 + C);

        let mut right = vec![E, E, 9, E];
        densify(&mut right, &[true, true, true, true], DensifyMode::Paper);
        assert_eq!(right[0], 9 + 2 * C);
        assert_eq!(right[1], 9 + C);
        assert_eq!(right[3], 9 + 3 * C); // wraps 3→0→1→2
    }

    #[test]
    fn copies_only_from_original_bins() {
        // bins: [E, E, 4]; dirs all right. Bin 0 must copy 4 at distance 2,
        // NOT bin 1's densified value at distance 1.
        let mut bins = vec![E, E, 4];
        densify(&mut bins, &[true, true, true], DensifyMode::Paper);
        assert_eq!(bins[1], 4 + C);
        assert_eq!(bins[0], 4 + 2 * C);
    }

    #[test]
    fn rotation_mode_always_right() {
        let mut bins = vec![E, 2, E];
        densify(&mut bins, &[false, false, false], DensifyMode::Rotation);
        // Direction bits ignored: bin 0 borrows right (bin 1, distance 1);
        // bin 2 borrows right wrapping to bin 1 at distance 2.
        assert_eq!(bins, vec![2 + C, 2, 2 + 2 * C]);
    }

    #[test]
    fn none_mode_leaves_empties() {
        let mut bins = vec![E, 2, E];
        densify(&mut bins, &[true, true, true], DensifyMode::None);
        assert_eq!(bins, vec![E, 2, E]);
    }

    #[test]
    fn all_empty_left_alone() {
        let mut bins = vec![E, E, E];
        densify(&mut bins, &[true, false, true], DensifyMode::Paper);
        assert_eq!(bins, vec![E, E, E]);
    }

    /// The offset makes "same source, different distance" never collide:
    /// two sketches agreeing on a densified bin implies same value AND same
    /// distance.
    #[test]
    fn offset_disambiguates_distance() {
        // Sketch A: value 9 at bin 2 → bin 0 copies at distance 2.
        let mut a = vec![E, E, 9, E];
        densify(&mut a, &[false, false, false, false], DensifyMode::Paper);
        // Sketch B: value 9 at bin 3 → bin 0 copies at distance 1 (left).
        let mut b = vec![E, E, E, 9];
        densify(&mut b, &[false, false, false, false], DensifyMode::Paper);
        assert_ne!(a[0], b[0], "distance must disambiguate copies");
    }
}
