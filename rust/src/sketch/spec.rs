//! Declarative sketch specifications — sketches as configuration.
//!
//! [`SketchSpec`] is the sketch layer's counterpart to
//! [`HashFamily::parse`]/[`HashFamily::build`]: a value describing *which*
//! sketch to build (scheme + parameters + basic hash family + seed) that
//! round-trips through a canonical string form and is constructed in
//! exactly one place — the [`SketchSpec::build`] registry. Everything that
//! used to call the per-family constructors with a `HashFamily` + seed
//! (the coordinator, [`crate::lsh::LshIndex`], every `experiments/*`
//! module, `benchsuite`, the CLI) now goes through a spec, so the sketch
//! in use is a configuration knob rather than code.
//!
//! # Grammar
//!
//! `scheme(key=value,key=value,…)`, e.g.
//!
//! ```text
//! oph(k=200,layout=mod,densify=paper,hash=mixed_tab,seed=42)
//! minhash(k=128,hash=mixed_tab,seed=7)
//! minhash(k=128,pool=256,hash=mixed_tab,seed=7)
//! simhash(bits=64,hash=murmur3,seed=1)
//! simhash(bits=64,pool=256,hash=mixed_tab,seed=1)
//! featurehash(dim=128,sign=paired,hash=mixed_tab,seed=42)
//! bbit(b=2,k=200,layout=mod,densify=paper,hash=mixed_tab,seed=3)
//! ```
//!
//! `hash` (default `mixed_tab`) and `seed` (default `0`) are common to all
//! schemes; `layout`/`densify`/`sign` are optional with the paper's
//! defaults; the size parameters (`k`, `bits`, `dim`, `b`) are required.
//! `pool` (MinHash/SimHash only; default `0`) selects the hash-evaluation
//! source ([`crate::hash::source`]): absent or `0` = one independent
//! hasher per coordinate (bit-identical to the pre-pool sketchers);
//! `pool=N` = coordinates sample 32-bit windows from a shared pool of N
//! precomputed hash bits per key (N a multiple of 64). `pool` is
//! spec-level on purpose: it changes the sketch *function*, so it must
//! ride through canonical strings into persistence manifests and the
//! `load_index` provenance check like any other parameter.
//! [`std::fmt::Display`] emits the canonical fully-keyed form (omitting
//! `pool=` when 0, keeping pre-pool canonical strings stable) and
//! `parse(display(spec)) == spec` for every spec.
//!
//! # Equivalence guarantee
//!
//! `build_*` must construct sketchers bit-identical to the direct
//! constructors they replaced (`OneHashSketcher::from_hasher(family.build(seed), …)`,
//! `MinHash::new(family, seed, k)`, …) — pinned by the spec-equivalence
//! property tests in `rust/tests/properties.rs`.

use super::bbit::BbitSketcher;
use super::densify::DensifyMode;
use super::feature_hash::{FeatureHasher, SignMode};
use super::minhash::MinHash;
use super::oph::{BinLayout, OneHashSketcher};
use super::simhash::SimHash;
use super::sketcher::DynSketcher;
use crate::hash::HashFamily;
use crate::util::error::{bail, format_err, Result};
use std::collections::BTreeMap;
use std::fmt;

/// OPH structural parameters (shared by the plain and b-bit schemes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OphParams {
    /// Number of bins k.
    pub k: usize,
    /// How `h(x)` splits into (bin, value).
    pub layout: BinLayout,
    /// Empty-bin handling.
    pub densify: DensifyMode,
}

impl OphParams {
    /// Paper defaults: `mod` layout, [33] densification.
    pub fn new(k: usize) -> Self {
        Self {
            k,
            layout: BinLayout::Mod,
            densify: DensifyMode::Paper,
        }
    }
}

/// Which sketch family a [`SketchSpec`] builds, with its parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchScheme {
    /// One Permutation Hashing (§2.1).
    Oph(OphParams),
    /// Classic k×MinHash baseline. `pool=0` builds one independent hasher
    /// per repetition; `pool=N` samples repetitions from a shared N-bit
    /// precomputed pool ([`crate::hash::PooledSource`]).
    MinHash { k: usize, pool: usize },
    /// SimHash sign-random-projection bits, with the same `pool` knob.
    SimHash { bits: usize, pool: usize },
    /// Feature hashing to `dim` dense dimensions (§2.2).
    FeatureHash { dim: usize, sign: SignMode },
    /// b-bit truncation of a densified OPH sketch (§1.2).
    BBit { b: u32, inner: OphParams },
}

/// A complete, buildable sketch description: scheme + hash family + seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SketchSpec {
    pub scheme: SketchScheme,
    /// The paper's experimental variable: the basic hash family.
    pub family: HashFamily,
    /// Root seed for the sketcher's hash function(s).
    pub seed: u64,
}

impl SketchSpec {
    /// OPH spec with the paper defaults (`mod` layout, [33] densification).
    pub fn oph(family: HashFamily, seed: u64, k: usize) -> Self {
        Self::oph_with(family, seed, OphParams::new(k))
    }

    /// OPH spec with explicit layout/densification.
    pub fn oph_with(family: HashFamily, seed: u64, params: OphParams) -> Self {
        Self {
            scheme: SketchScheme::Oph(params),
            family,
            seed,
        }
    }

    /// k×MinHash spec (independent per-repetition hashers).
    pub fn minhash(family: HashFamily, seed: u64, k: usize) -> Self {
        Self::minhash_pooled(family, seed, k, 0)
    }

    /// k×MinHash spec with an explicit pool size (0 = independent).
    pub fn minhash_pooled(family: HashFamily, seed: u64, k: usize, pool: usize) -> Self {
        Self {
            scheme: SketchScheme::MinHash { k, pool },
            family,
            seed,
        }
    }

    /// SimHash spec (independent per-bit hashers).
    pub fn simhash(family: HashFamily, seed: u64, bits: usize) -> Self {
        Self::simhash_pooled(family, seed, bits, 0)
    }

    /// SimHash spec with an explicit pool size (0 = independent).
    pub fn simhash_pooled(family: HashFamily, seed: u64, bits: usize, pool: usize) -> Self {
        Self {
            scheme: SketchScheme::SimHash { bits, pool },
            family,
            seed,
        }
    }

    /// Feature-hashing spec.
    pub fn feature_hash(family: HashFamily, seed: u64, dim: usize, sign: SignMode) -> Self {
        Self {
            scheme: SketchScheme::FeatureHash { dim, sign },
            family,
            seed,
        }
    }

    /// b-bit spec over a default-parameter OPH inner sketch.
    pub fn bbit(family: HashFamily, seed: u64, b: u32, k: usize) -> Self {
        Self {
            scheme: SketchScheme::BBit {
                b,
                inner: OphParams::new(k),
            },
            family,
            seed,
        }
    }

    /// Scheme identifier (the grammar's scheme name).
    pub fn scheme_id(&self) -> &'static str {
        match self.scheme {
            SketchScheme::Oph(_) => "oph",
            SketchScheme::MinHash { .. } => "minhash",
            SketchScheme::SimHash { .. } => "simhash",
            SketchScheme::FeatureHash { .. } => "featurehash",
            SketchScheme::BBit { .. } => "bbit",
        }
    }

    /// Copy of this spec with the OPH bin count replaced — used by
    /// [`crate::lsh::LshIndex`], whose structural (K, L) parameters dictate
    /// the bin count. Panics if the scheme is not OPH.
    pub fn with_oph_k(mut self, k: usize) -> Self {
        match &mut self.scheme {
            SketchScheme::Oph(p) => p.k = k,
            other => panic!("with_oph_k on non-OPH scheme {other:?}"),
        }
        self
    }

    /// Copy of this spec with the SimHash bit count replaced — used by
    /// [`crate::lsh::AngularIndex`], whose structural (K, L) parameters
    /// dictate the bit count (K·L sign bits), while the hash family, seed,
    /// and `pool` stay user-chosen. Panics if the scheme is not SimHash.
    pub fn with_simhash_bits(mut self, new_bits: usize) -> Self {
        match &mut self.scheme {
            SketchScheme::SimHash { bits, .. } => *bits = new_bits,
            other => panic!("with_simhash_bits on non-SimHash scheme {other:?}"),
        }
        self
    }

    /// Parse from the canonical string form (see module docs).
    pub fn parse(s: &str) -> Result<SketchSpec> {
        let s = s.trim();
        let (name, args) = match s.find('(') {
            Some(i) => {
                let inner = s[i + 1..]
                    .strip_suffix(')')
                    .ok_or_else(|| format_err!("sketch spec '{s}' missing closing ')'"))?;
                (&s[..i], inner)
            }
            None => (s, ""),
        };
        let mut params: BTreeMap<&str, &str> = BTreeMap::new();
        for part in args.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format_err!("bad sketch spec parameter '{part}' (want key=value)"))?;
            if params.insert(key.trim(), value.trim()).is_some() {
                bail!("duplicate sketch spec parameter '{}'", key.trim());
            }
        }

        let family = match params.remove("hash") {
            Some(id) => HashFamily::parse(id)
                .ok_or_else(|| format_err!("unknown hash family '{id}' in sketch spec"))?,
            None => HashFamily::MixedTab,
        };
        let seed = match params.remove("seed") {
            Some(v) => parse_int::<u64>(v, "seed")?,
            None => 0,
        };
        let scheme = match name {
            "oph" => SketchScheme::Oph(take_oph_params(&mut params)?),
            "minhash" | "mh" => SketchScheme::MinHash {
                k: take_req::<usize>(&mut params, "k")?,
                pool: take_pool(&mut params)?,
            },
            "simhash" => SketchScheme::SimHash {
                bits: take_req::<usize>(&mut params, "bits")?,
                pool: take_pool(&mut params)?,
            },
            "featurehash" | "fh" => SketchScheme::FeatureHash {
                dim: take_req::<usize>(&mut params, "dim")?,
                sign: match params.remove("sign") {
                    Some(id) => SignMode::parse(id)
                        .ok_or_else(|| format_err!("unknown sign mode '{id}' in sketch spec"))?,
                    None => SignMode::Paired,
                },
            },
            "bbit" => {
                let b = take_req::<u32>(&mut params, "b")?;
                if !(1..=8).contains(&b) {
                    bail!("bbit spec needs b in 1..=8, got {b}");
                }
                SketchScheme::BBit {
                    b,
                    inner: take_oph_params(&mut params)?,
                }
            }
            other => bail!(
                "unknown sketch scheme '{other}' (expected oph|minhash|simhash|featurehash|bbit)"
            ),
        };
        if let Some(key) = params.keys().next() {
            bail!("unknown parameter '{key}' for sketch scheme '{name}'");
        }
        let spec = SketchSpec {
            scheme,
            family,
            seed,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Max coordinates for the O(size)-memory schemes (OPH, FH, b-bit).
    /// Parsed specs reach the registry from the wire (`sketch` op) and the
    /// CLI, so unparseable-but-huge sizes must not allocate.
    pub const MAX_COORDS: usize = 1 << 22;

    /// Max coordinates for the hasher-per-coordinate schemes (MinHash,
    /// SimHash), which additionally build one seeded hasher (tabulation
    /// tables included, multi-KB each) per coordinate — this cap also
    /// bounds what a server-side sketcher cache can retain per entry.
    /// The paper's largest repetition counts are k ≤ 500; 1024 is
    /// headroom, not a target. Applies to *parsed* specs only —
    /// programmatic construction (e.g. `lsh::AngularIndex`) is not capped.
    pub const MAX_HASHERS: usize = 1 << 10;

    /// Max `pool=` bits. A pool costs `pool/64` u64 hashers plus
    /// `pool/64` words per key of scratch; 64 Ki bits (1024 fillers,
    /// 8 KiB/key) is already far past any useful pool size.
    pub const MAX_POOL_BITS: usize = 1 << 16;

    fn validate(&self) -> Result<()> {
        let (size, cap) = match self.scheme {
            SketchScheme::Oph(p) | SketchScheme::BBit { inner: p, .. } => (p.k, Self::MAX_COORDS),
            SketchScheme::MinHash { k, .. } => (k, Self::MAX_HASHERS),
            SketchScheme::SimHash { bits, .. } => (bits, Self::MAX_HASHERS),
            SketchScheme::FeatureHash { dim, .. } => (dim, Self::MAX_COORDS),
        };
        if size == 0 {
            bail!("sketch spec '{self}' has a zero-sized sketch");
        }
        if size > cap {
            bail!("sketch spec '{self}' exceeds the size cap ({size} > {cap})");
        }
        if let SketchScheme::MinHash { pool, .. } | SketchScheme::SimHash { pool, .. } =
            self.scheme
        {
            // pool=0 is the independent source; a real pool must hold whole
            // u64 filler words and at least one 32-bit window.
            if pool != 0 && (pool < 64 || pool % 64 != 0) {
                bail!("sketch spec '{self}' needs pool=0 or a multiple of 64 >= 64, got {pool}");
            }
            if pool > Self::MAX_POOL_BITS {
                bail!(
                    "sketch spec '{self}' exceeds the pool cap ({pool} > {})",
                    Self::MAX_POOL_BITS
                );
            }
        }
        Ok(())
    }

    /// **The registry**: construct the erased sketcher this spec describes.
    /// This (with the typed `build_*` accessors below, which it delegates
    /// to) is the only place sketcher construction from configuration
    /// happens.
    pub fn build(&self) -> Box<dyn DynSketcher> {
        match self.scheme {
            SketchScheme::Oph(_) => Box::new(self.build_oph().expect("scheme checked")),
            SketchScheme::MinHash { .. } => Box::new(self.build_minhash().expect("scheme checked")),
            SketchScheme::SimHash { .. } => Box::new(self.build_simhash().expect("scheme checked")),
            SketchScheme::FeatureHash { .. } => {
                Box::new(self.build_feature_hasher().expect("scheme checked"))
            }
            SketchScheme::BBit { .. } => Box::new(self.build_bbit().expect("scheme checked")),
        }
    }

    /// Typed OPH construction; errors unless the scheme is [`SketchScheme::Oph`].
    pub fn build_oph(&self) -> Result<OneHashSketcher> {
        let SketchScheme::Oph(p) = self.scheme else {
            bail!("spec '{self}' is not an OPH spec");
        };
        Ok(OneHashSketcher::from_hasher(
            self.family.build(self.seed),
            p.k,
            p.layout,
            p.densify,
        ))
    }

    /// Typed MinHash construction; errors unless the scheme is
    /// [`SketchScheme::MinHash`]. `pool=0` delegates to [`MinHash::new`]
    /// (bit-identical to the pre-pool sketcher), `pool=N` to
    /// [`MinHash::pooled`].
    pub fn build_minhash(&self) -> Result<MinHash> {
        let SketchScheme::MinHash { k, pool } = self.scheme else {
            bail!("spec '{self}' is not a MinHash spec");
        };
        Ok(if pool == 0 {
            MinHash::new(self.family, self.seed, k)
        } else {
            MinHash::pooled(self.family, self.seed, k, pool)
        })
    }

    /// Typed SimHash construction; errors unless the scheme is
    /// [`SketchScheme::SimHash`]. `pool=0` delegates to [`SimHash::new`]
    /// (bit-identical to the pre-pool sketcher), `pool=N` to
    /// [`SimHash::pooled`].
    pub fn build_simhash(&self) -> Result<SimHash> {
        let SketchScheme::SimHash { bits, pool } = self.scheme else {
            bail!("spec '{self}' is not a SimHash spec");
        };
        Ok(if pool == 0 {
            SimHash::new(self.family, self.seed, bits)
        } else {
            SimHash::pooled(self.family, self.seed, bits, pool)
        })
    }

    /// Typed feature-hasher construction; errors unless the scheme is
    /// [`SketchScheme::FeatureHash`].
    pub fn build_feature_hasher(&self) -> Result<FeatureHasher> {
        let SketchScheme::FeatureHash { dim, sign } = self.scheme else {
            bail!("spec '{self}' is not a feature-hashing spec");
        };
        Ok(FeatureHasher::new(self.family, self.seed, dim, sign))
    }

    /// Typed b-bit construction; errors unless the scheme is [`SketchScheme::BBit`].
    pub fn build_bbit(&self) -> Result<BbitSketcher> {
        let SketchScheme::BBit { b, inner } = self.scheme else {
            bail!("spec '{self}' is not a b-bit spec");
        };
        let oph = SketchSpec::oph_with(self.family, self.seed, inner)
            .build_oph()
            .expect("inner scheme is OPH by construction");
        Ok(BbitSketcher::new(oph, b))
    }
}

impl fmt::Display for SketchSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let common = format!("hash={},seed={}", self.family.id(), self.seed);
        match self.scheme {
            SketchScheme::Oph(p) => write!(
                f,
                "oph(k={},layout={},densify={},{common})",
                p.k,
                p.layout.id(),
                p.densify.id(),
            ),
            SketchScheme::MinHash { k, pool: 0 } => write!(f, "minhash(k={k},{common})"),
            SketchScheme::MinHash { k, pool } => {
                write!(f, "minhash(k={k},pool={pool},{common})")
            }
            SketchScheme::SimHash { bits, pool: 0 } => write!(f, "simhash(bits={bits},{common})"),
            SketchScheme::SimHash { bits, pool } => {
                write!(f, "simhash(bits={bits},pool={pool},{common})")
            }
            SketchScheme::FeatureHash { dim, sign } => {
                write!(f, "featurehash(dim={dim},sign={},{common})", sign.id())
            }
            SketchScheme::BBit { b, inner } => write!(
                f,
                "bbit(b={b},k={},layout={},densify={},{common})",
                inner.k,
                inner.layout.id(),
                inner.densify.id(),
            ),
        }
    }
}

fn parse_int<T: std::str::FromStr>(value: &str, key: &str) -> Result<T> {
    value
        .parse::<T>()
        .map_err(|_| format_err!("bad integer '{value}' for sketch spec parameter '{key}'"))
}

fn take_req<T: std::str::FromStr>(params: &mut BTreeMap<&str, &str>, key: &str) -> Result<T> {
    let value = params
        .remove(key)
        .ok_or_else(|| format_err!("sketch spec is missing required parameter '{key}'"))?;
    parse_int::<T>(value, key)
}

fn take_pool(params: &mut BTreeMap<&str, &str>) -> Result<usize> {
    match params.remove("pool") {
        Some(v) => parse_int::<usize>(v, "pool"),
        None => Ok(0),
    }
}

fn take_oph_params(params: &mut BTreeMap<&str, &str>) -> Result<OphParams> {
    let k = take_req::<usize>(params, "k")?;
    let layout = match params.remove("layout") {
        Some(id) => BinLayout::parse(id)
            .ok_or_else(|| format_err!("unknown bin layout '{id}' in sketch spec"))?,
        None => BinLayout::Mod,
    };
    let densify = match params.remove("densify") {
        Some(id) => DensifyMode::parse(id)
            .ok_or_else(|| format_err!("unknown densify mode '{id}' in sketch spec"))?,
        None => DensifyMode::Paper,
    };
    Ok(OphParams { k, layout, densify })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_variants() -> Vec<SketchSpec> {
        vec![
            SketchSpec::oph(HashFamily::MixedTab, 42, 200),
            SketchSpec::oph_with(
                HashFamily::MultiplyShift,
                7,
                OphParams {
                    k: 64,
                    layout: BinLayout::Range,
                    densify: DensifyMode::None,
                },
            ),
            SketchSpec::oph_with(
                HashFamily::Poly2,
                1,
                OphParams {
                    k: 16,
                    layout: BinLayout::Mod,
                    densify: DensifyMode::Rotation,
                },
            ),
            SketchSpec::minhash(HashFamily::Murmur3, 9, 128),
            SketchSpec::minhash_pooled(HashFamily::MixedTab, 9, 128, 256),
            SketchSpec::simhash(HashFamily::City, 10, 64),
            SketchSpec::simhash_pooled(HashFamily::MixedTab, 10, 64, 512),
            SketchSpec::feature_hash(HashFamily::MixedTab, 42, 128, SignMode::Paired),
            SketchSpec::feature_hash(HashFamily::Blake2, 3, 32, SignMode::Separate),
            SketchSpec::bbit(HashFamily::MixedTab, 5, 2, 200),
        ]
    }

    #[test]
    fn display_parse_roundtrip_every_variant() {
        for spec in all_variants() {
            let text = spec.to_string();
            let back = SketchSpec::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
            assert_eq!(back, spec, "{text}");
        }
    }

    #[test]
    fn parse_applies_defaults() {
        let spec = SketchSpec::parse("oph(k=100)").unwrap();
        assert_eq!(spec, SketchSpec::oph(HashFamily::MixedTab, 0, 100));
        let spec = SketchSpec::parse("featurehash(dim=64)").unwrap();
        assert_eq!(
            spec,
            SketchSpec::feature_hash(HashFamily::MixedTab, 0, 64, SignMode::Paired)
        );
        // Aliases and whitespace tolerance.
        let spec = SketchSpec::parse(" mh( k=8 , hash=ms , seed=3 ) ").unwrap();
        assert_eq!(spec, SketchSpec::minhash(HashFamily::MultiplyShift, 3, 8));
        let spec = SketchSpec::parse("fh(dim=32,sign=separate)").unwrap();
        assert_eq!(
            spec,
            SketchSpec::feature_hash(HashFamily::MixedTab, 0, 32, SignMode::Separate)
        );
    }

    #[test]
    fn parse_rejects_bad_input() {
        for bad in [
            "",
            "oph",                          // missing required k
            "oph(k=100",                    // unterminated
            "oph(k=abc)",                   // bad integer
            "oph(k=0)",                     // zero-sized sketch
            "oph(k=100,k=200)",             // duplicate key
            "oph(k=100,layout=diag)",       // unknown layout
            "oph(k=100,densify=magic)",     // unknown densify mode
            "oph(k=100,hash=md5)",          // unknown family
            "oph(k=100,wibble=3)",          // unknown parameter
            "minhash(bits=4)",              // wrong size key for the scheme
            "simhash(k=4)",                 // ditto
            "minhash(k=8,pool=100)",        // pool not a multiple of 64
            "minhash(k=8,pool=32)",         // pool below one filler word
            "simhash(bits=8,pool=131072)",  // beyond MAX_POOL_BITS
            "oph(k=100,pool=256)",          // pool is minhash/simhash-only
            "featurehash(dim=64,sign=odd)", // unknown sign mode
            "bbit(b=0,k=100)",              // b out of range
            "bbit(b=9,k=100)",              // b out of range
            "oph(k=8589934592)",            // beyond MAX_COORDS (and 2^32)
            "minhash(k=2000000000)",        // beyond MAX_HASHERS
            "featurehash(dim=1000000000)",  // beyond MAX_COORDS
            "simhash(bits=100000)",         // beyond MAX_HASHERS
            "waveletsketch(k=4)",           // unknown scheme
            "oph(k)",                       // not key=value
        ] {
            assert!(SketchSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn build_all_variants() {
        let set: Vec<u32> = (0..200).collect();
        for spec in all_variants() {
            let sk = spec.build();
            assert_eq!(sk.scheme_id(), spec.scheme_id());
            let value = sk.sketch_dyn(&set, &mut crate::sketch::Scratch::new());
            assert_eq!(value.scheme_id(), spec.scheme_id());
        }
    }

    #[test]
    fn typed_builders_reject_scheme_mismatch() {
        let oph = SketchSpec::oph(HashFamily::MixedTab, 1, 8);
        let mh = SketchSpec::minhash(HashFamily::MixedTab, 1, 8);
        assert!(oph.build_minhash().is_err());
        assert!(oph.build_simhash().is_err());
        assert!(oph.build_feature_hasher().is_err());
        assert!(oph.build_bbit().is_err());
        assert!(mh.build_oph().is_err());
        assert!(mh.build_minhash().is_ok());
    }

    #[test]
    fn with_oph_k_overrides_bin_count() {
        let spec = SketchSpec::oph(HashFamily::MixedTab, 1, 8).with_oph_k(30);
        assert_eq!(spec.build_oph().unwrap().k(), 30);
    }

    #[test]
    #[should_panic]
    fn with_oph_k_panics_on_non_oph() {
        let _ = SketchSpec::minhash(HashFamily::MixedTab, 1, 8).with_oph_k(30);
    }

    #[test]
    fn with_simhash_bits_overrides_bit_count_and_keeps_pool() {
        let spec = SketchSpec::simhash_pooled(HashFamily::MixedTab, 1, 8, 256).with_simhash_bits(72);
        assert_eq!(spec.build_simhash().unwrap().bits(), 72);
        assert_eq!(
            spec.scheme,
            SketchScheme::SimHash {
                bits: 72,
                pool: 256
            }
        );
    }

    #[test]
    #[should_panic]
    fn with_simhash_bits_panics_on_non_simhash() {
        let _ = SketchSpec::minhash(HashFamily::MixedTab, 1, 8).with_simhash_bits(30);
    }

    #[test]
    fn pooled_specs_roundtrip_with_explicit_pool_key() {
        let spec = SketchSpec::parse("minhash(k=128,pool=256,hash=mixed_tab,seed=7)").unwrap();
        assert_eq!(
            spec,
            SketchSpec::minhash_pooled(HashFamily::MixedTab, 7, 128, 256)
        );
        assert_eq!(spec.to_string(), "minhash(k=128,pool=256,hash=mixed_tab,seed=7)");
        // pool=0 parses as the independent source and canonicalises with no
        // pool key — pre-pool canonical strings are stable.
        let spec = SketchSpec::parse("simhash(bits=64,pool=0,hash=city,seed=10)").unwrap();
        assert_eq!(spec, SketchSpec::simhash(HashFamily::City, 10, 64));
        assert_eq!(spec.to_string(), "simhash(bits=64,hash=city,seed=10)");
    }

    #[test]
    fn build_is_deterministic_for_fixed_seed() {
        let set: Vec<u32> = (0..300).collect();
        for spec in all_variants() {
            let mut scratch = crate::sketch::Scratch::new();
            let a = spec.build().sketch_dyn(&set, &mut scratch);
            let b = spec.build().sketch_dyn(&set, &mut scratch);
            assert_eq!(a, b, "{spec}");
        }
    }
}
