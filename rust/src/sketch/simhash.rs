//! SimHash (Charikar, STOC'02; [12] in the paper) — sign-random-projection
//! LSH for angular similarity.
//!
//! Included because the paper positions SimHash alongside OPH as the other
//! practical LSH family ("relying either on OPH [32, 33] or FH [12, 2]"):
//! SimHash applied to a feature-hashed vector is exactly the "FH + sign
//! projection" pipeline of Andoni et al. Each output bit is
//! `sign(Σ_j r_{i,j} v_j)` with `r_{i,j} ∈ {±1}` derived from a basic hash
//! function of `(i, j)` — so SimHash quality also reduces to basic-hash
//! quality, the paper's theme.

use super::scratch::Scratch;
use crate::data::sparse::SparseVector;
use crate::hash::{HashFamily, HashSource, Hasher32, IndependentSource, PooledSource};

/// k-bit SimHash sketcher drawing from a [`HashSource`].
///
/// Constructed either from injected hashers ([`Self::from_hashers`], used
/// by tests with stub hashers) or — the configuration path — from a parsed
/// [`crate::sketch::SketchSpec`] via its `build`/`build_simhash` registry,
/// which delegates to [`Self::new`] (`pool=0`, independent hashers,
/// bit-identical to the pre-`HashSource` sketcher) or [`Self::pooled`]
/// (`pool=N`, bits sampled from a shared precomputed pool — the angular
/// LSH case where K·L bits per key would otherwise cost K·L evaluations).
pub struct SimHash {
    source: Box<dyn HashSource>,
}

impl SimHash {
    pub fn new(family: HashFamily, seed: u64, bits: usize) -> Self {
        assert!(bits >= 1);
        let hashers = (0..bits)
            .map(|i| family.build(seed.wrapping_add(0xABCD_0000 + i as u64)))
            .collect();
        Self::from_hashers(hashers)
    }

    /// `bits` output bits sampled from a shared `pool_bits`-bit pool
    /// ([`PooledSource`]): O(pool) hash work per sketch instead of O(bits).
    pub fn pooled(family: HashFamily, seed: u64, bits: usize, pool_bits: usize) -> Self {
        assert!(bits >= 1);
        Self::from_source(Box::new(PooledSource::new(family, seed, bits, pool_bits)))
    }

    /// Build from explicit hashers (one per output bit).
    pub fn from_hashers(hashers: Vec<Box<dyn Hasher32>>) -> Self {
        assert!(!hashers.is_empty());
        Self::from_source(Box::new(IndependentSource::new(hashers)))
    }

    /// Build from any [`HashSource`] with one output per bit.
    pub fn from_source(source: Box<dyn HashSource>) -> Self {
        assert!(source.outputs() >= 1);
        Self { source }
    }

    pub fn bits(&self) -> usize {
        self.source.outputs()
    }

    /// Sketch: bit i = sign of the ±1 projection by hasher i. Convenience
    /// wrapper around [`Self::sketch_with`] with a one-shot [`Scratch`].
    pub fn sketch(&self, v: &SparseVector) -> Vec<bool> {
        self.sketch_with(v, &mut Scratch::with_capacity(v.indices.len()))
    }

    /// Sketch using a caller-provided [`Scratch`] (hot path): one
    /// [`HashSource::begin`] per vector (the pooled source hashes its
    /// whole pool here), then per output bit one [`HashSource::fill`]
    /// batch over the non-zero indices and a monomorphic ±1 accumulation.
    /// Bit-identical to [`Self::sketch_per_key`].
    pub fn sketch_with(&self, v: &SparseVector, scratch: &mut Scratch) -> Vec<bool> {
        let (pool, hashes) = scratch.pool_and_hashes_mut(v.indices.len());
        self.source.begin(&v.indices, pool);
        let mut out = Vec::with_capacity(self.source.outputs());
        for i in 0..self.source.outputs() {
            self.source.fill(i, &v.indices, pool, hashes);
            let mut acc = 0.0;
            for (&hv, &val) in hashes.iter().zip(&v.values) {
                let r = if hv & 1 == 1 { 1.0 } else { -1.0 };
                acc += r * val;
            }
            out.push(acc >= 0.0);
        }
        out
    }

    /// Per-key reference for [`Self::sketch_with`] (one dynamic dispatch per
    /// non-zero per bit). Correctness oracle for the batched path; not for
    /// production use.
    pub fn sketch_per_key(&self, v: &SparseVector) -> Vec<bool> {
        (0..self.source.outputs())
            .map(|i| {
                let mut acc = 0.0;
                for (&j, &val) in v.indices.iter().zip(&v.values) {
                    let r = if self.source.hash_one(i, j) & 1 == 1 { 1.0 } else { -1.0 };
                    acc += r * val;
                }
                acc >= 0.0
            })
            .collect()
    }

    /// Estimate the angle between the vectors:
    /// `P[bit match] = 1 − θ/π  ⇒  θ̂ = π · (1 − frac)`; returns the cosine
    /// similarity estimate `cos(θ̂)`.
    pub fn estimate_cosine(&self, a: &[bool], b: &[bool]) -> f64 {
        assert_eq!(a.len(), b.len());
        let frac = a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64;
        (std::f64::consts::PI * (1.0 - frac)).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::cosine_sorted;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn identical_vectors_full_match() {
        let sh = SimHash::new(HashFamily::MixedTab, 1, 64);
        let v = SparseVector::new(vec![1, 2, 3], vec![0.5, -0.25, 1.0]);
        let s = sh.sketch(&v);
        assert!((sh.estimate_cosine(&s, &s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn opposite_vectors_no_match() {
        let sh = SimHash::new(HashFamily::MixedTab, 2, 256);
        let v = SparseVector::new(vec![1, 2, 3], vec![0.5, -0.25, 1.0]);
        let neg = SparseVector::new(vec![1, 2, 3], vec![-0.5, 0.25, -1.0]);
        let est = sh.estimate_cosine(&sh.sketch(&v), &sh.sketch(&neg));
        assert!(est < -0.9, "est {est}");
    }

    #[test]
    fn batched_matches_per_key() {
        let mut rng = Xoshiro256::new(3);
        let v = SparseVector::new(
            (0..300u32).map(|i| i * 5 + 1).collect(),
            (0..300).map(|_| rng.normal()).collect(),
        );
        let sh = SimHash::new(HashFamily::MixedTab, 8, 128);
        let mut scratch = crate::sketch::scratch::Scratch::new();
        assert_eq!(sh.sketch_with(&v, &mut scratch), sh.sketch_per_key(&v));
    }

    #[test]
    fn pooled_batched_matches_per_key() {
        let mut rng = Xoshiro256::new(5);
        let v = SparseVector::new(
            (0..300u32).map(|i| i * 5 + 1).collect(),
            (0..300).map(|_| rng.normal()).collect(),
        );
        let sh = SimHash::pooled(HashFamily::MixedTab, 8, 128, 256);
        assert_eq!(sh.bits(), 128);
        let mut scratch = crate::sketch::scratch::Scratch::new();
        assert_eq!(sh.sketch_with(&v, &mut scratch), sh.sketch_per_key(&v));
    }

    #[test]
    fn pooled_tracks_cosine_on_random_vectors() {
        // Pooled bits are correlated (shared pool windows), but each bit is
        // still an unbiased sign projection, so the angle estimate must
        // still track the truth averaged over seeds.
        let mut rng = Xoshiro256::new(17);
        let idx: Vec<u32> = (0..400).collect();
        let v1: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        let v2: Vec<f64> = v1.iter().map(|x| x + rng.normal() * 0.7).collect();
        let truth = cosine_sorted(&idx, &v1, &idx, &v2);
        let a = SparseVector::new(idx.clone(), v1);
        let b = SparseVector::new(idx, v2);
        let mut sum = 0.0;
        let reps = 20;
        for seed in 0..reps {
            let sh = SimHash::pooled(HashFamily::MixedTab, seed, 256, 512);
            sum += sh.estimate_cosine(&sh.sketch(&a), &sh.sketch(&b));
        }
        let mean = sum / reps as f64;
        assert!((mean - truth).abs() < 0.1, "mean {mean} truth {truth}");
    }

    #[test]
    fn tracks_cosine_on_random_vectors() {
        let mut rng = Xoshiro256::new(7);
        let idx: Vec<u32> = (0..400).collect();
        let v1: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        // Correlated vector: v2 = v1 + noise.
        let v2: Vec<f64> = v1.iter().map(|x| x + rng.normal() * 0.7).collect();
        let truth = cosine_sorted(&idx, &v1, &idx, &v2);
        let a = SparseVector::new(idx.clone(), v1);
        let b = SparseVector::new(idx, v2);
        let mut sum = 0.0;
        let reps = 20;
        for seed in 0..reps {
            let sh = SimHash::new(HashFamily::MixedTab, seed, 256);
            sum += sh.estimate_cosine(&sh.sketch(&a), &sh.sketch(&b));
        }
        let mean = sum / reps as f64;
        assert!((mean - truth).abs() < 0.1, "mean {mean} truth {truth}");
    }
}
