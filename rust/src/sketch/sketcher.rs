//! The unified [`Sketcher`] trait — one sketching API for every family.
//!
//! The paper's hash layer already has a single abstraction
//! ([`crate::hash::Hasher32`] behind [`crate::hash::HashFamily`]); this
//! module gives the sketch layer its equivalent. Every sketch family
//! (OPH, MinHash, SimHash, feature hashing, b-bit) implements [`Sketcher`]
//! over sets of `u32` keys — the service domain — so generic code can be
//! written once, and implements the object-safe erased form
//! [`DynSketcher`] (producing a [`SketchValue`]) so runtime-selected paths
//! (the coordinator's scheme-aware `Sketch` endpoint, the `mixtab sketch`
//! CLI) can hold `Box<dyn DynSketcher>` built from a parsed
//! [`crate::sketch::SketchSpec`].
//!
//! Set semantics for the vector-valued families: SimHash and feature
//! hashing natively sketch a [`SparseVector`]; their [`Sketcher`] impls
//! treat the input set as its unit-norm indicator vector
//! ([`SparseVector::unit_indicator`]), which is exactly how the paper's
//! synthetic experiments feed sets to FH. The typed inherent APIs
//! (`SimHash::sketch_with(&SparseVector, …)`,
//! `FeatureHasher::transform_into`) remain the hot paths for real vector
//! workloads.

use super::bbit::{BbitSketch, BbitSketcher};
use super::feature_hash::FeatureHasher;
use super::minhash::MinHash;
use super::oph::{estimate_collision, OneHashSketcher, OphSketch};
use super::scratch::Scratch;
use super::simhash::SimHash;
use crate::data::sparse::SparseVector;
use crate::util::error::{bail, Result};

/// A sketch produced by an erased [`DynSketcher`] — one variant per family.
#[derive(Debug, Clone, PartialEq)]
pub enum SketchValue {
    /// Densified (per the sketcher's mode) One Permutation Hashing bins.
    Oph(OphSketch),
    /// k×MinHash coordinates.
    MinHash(Vec<u32>),
    /// SimHash sign bits.
    SimHash(Vec<bool>),
    /// Feature-hashed dense vector.
    FeatureHash(Vec<f64>),
    /// b-bit-truncated minwise sketch.
    BBit(BbitSketch),
}

impl SketchValue {
    /// Scheme identifier (matches [`crate::sketch::SketchSpec`] ids).
    pub fn scheme_id(&self) -> &'static str {
        match self {
            SketchValue::Oph(_) => "oph",
            SketchValue::MinHash(_) => "minhash",
            SketchValue::SimHash(_) => "simhash",
            SketchValue::FeatureHash(_) => "featurehash",
            SketchValue::BBit(_) => "bbit",
        }
    }

    /// Number of coordinates in the sketch.
    pub fn len(&self) -> usize {
        match self {
            SketchValue::Oph(s) => s.k(),
            SketchValue::MinHash(v) => v.len(),
            SketchValue::SimHash(v) => v.len(),
            SketchValue::FeatureHash(v) => v.len(),
            SketchValue::BBit(s) => s.vals.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Similarity estimate between two sketches produced by the *same*
    /// sketcher: the fraction of agreeing coordinates for OPH (§2.1) and
    /// MinHash (a Jaccard estimate), the Li–König corrected Jaccard
    /// estimate for b-bit, the sign-random-projection cosine estimate for
    /// SimHash, and the cosine of the hashed vectors for feature hashing.
    ///
    /// Scheme, size, or b-width mismatches are errors, never panics —
    /// this sits on the coordinator's `estimate` wire path, where the
    /// family estimators' `assert_eq!` guards must not fire.
    pub fn estimate(&self, other: &SketchValue) -> Result<f64> {
        if self.scheme_id() != other.scheme_id() {
            bail!(
                "cannot estimate across schemes '{}' and '{}'",
                self.scheme_id(),
                other.scheme_id()
            );
        }
        if self.len() != other.len() || self.is_empty() {
            bail!(
                "sketch size mismatch ({} vs {} coordinates)",
                self.len(),
                other.len()
            );
        }
        Ok(match (self, other) {
            (SketchValue::Oph(a), SketchValue::Oph(b)) => estimate_collision(a, b),
            (SketchValue::MinHash(a), SketchValue::MinHash(b)) => {
                a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64
            }
            (SketchValue::SimHash(a), SketchValue::SimHash(b)) => {
                // P[bit match] = 1 − θ/π ⇒ cos(π·(1 − frac)), as in
                // `SimHash::estimate_cosine`.
                let frac =
                    a.iter().zip(b).filter(|(x, y)| x == y).count() as f64 / a.len() as f64;
                (std::f64::consts::PI * (1.0 - frac)).cos()
            }
            (SketchValue::FeatureHash(a), SketchValue::FeatureHash(b)) => {
                let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
                let na = a.iter().map(|x| x * x).sum::<f64>().sqrt();
                let nb = b.iter().map(|x| x * x).sum::<f64>().sqrt();
                if na == 0.0 || nb == 0.0 {
                    0.0
                } else {
                    dot / (na * nb)
                }
            }
            (SketchValue::BBit(a), SketchValue::BBit(b)) => {
                if a.b != b.b {
                    bail!("b-bit width mismatch ({} vs {})", a.b, b.b);
                }
                a.estimate(b)
            }
            _ => unreachable!("scheme ids checked equal above"),
        })
    }
}

/// The unified sketching API over sets of `u32` keys.
///
/// Implementations must be deterministic for a fixed construction seed and
/// must route batch hashing through [`crate::hash::Hasher32::hash_slice`]
/// with the caller's [`Scratch`] (the PR-2 hot-path contract). The
/// convenience methods mirror the inherent per-family APIs: `sketch`
/// allocates a one-shot scratch, `sketch_batch` reuses one scratch across
/// a whole batch.
pub trait Sketcher {
    /// The family's native sketch type.
    type Sketch;

    /// Sketch one set using a caller-provided [`Scratch`] (hot path).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Self::Sketch;

    /// Convenience: sketch with a one-shot [`Scratch`].
    fn sketch(&self, set: &[u32]) -> Self::Sketch {
        self.sketch_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Sketch a batch of sets, reusing one [`Scratch`] across the batch so
    /// steady streams allocate no hash buffers per set.
    fn sketch_batch(&self, sets: &[Vec<u32>], scratch: &mut Scratch) -> Vec<Self::Sketch> {
        sets.iter().map(|s| self.sketch_with(s, scratch)).collect()
    }
}

/// Object-safe erased form of [`Sketcher`] for runtime-selected schemes.
///
/// Built by [`crate::sketch::SketchSpec::build`]; the output is wrapped in
/// the scheme-tagged [`SketchValue`] so wire codecs and CLIs can dispatch
/// without knowing the concrete type.
pub trait DynSketcher: Send + Sync {
    /// Sketch one set into the scheme-tagged value.
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue;

    /// Batch variant (one reused scratch).
    fn sketch_batch_dyn(&self, sets: &[Vec<u32>], scratch: &mut Scratch) -> Vec<SketchValue> {
        sets.iter().map(|s| self.sketch_dyn(s, scratch)).collect()
    }

    /// Scheme identifier (matches [`SketchValue::scheme_id`]).
    fn scheme_id(&self) -> &'static str;
}

impl Sketcher for OneHashSketcher {
    type Sketch = OphSketch;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> OphSketch {
        OneHashSketcher::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for OneHashSketcher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::Oph(OneHashSketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "oph"
    }
}

impl Sketcher for MinHash {
    type Sketch = Vec<u32>;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<u32> {
        MinHash::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for MinHash {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::MinHash(MinHash::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "minhash"
    }
}

impl Sketcher for SimHash {
    type Sketch = Vec<bool>;

    /// Sketches the set's unit-norm indicator vector (module docs).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<bool> {
        let v = SparseVector::unit_indicator(set);
        SimHash::sketch_with(self, &v, scratch)
    }
}

impl DynSketcher for SimHash {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::SimHash(Sketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "simhash"
    }
}

impl Sketcher for FeatureHasher {
    type Sketch = Vec<f64>;

    /// Transforms the set's unit-norm indicator vector (module docs).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<f64> {
        let v = SparseVector::unit_indicator(set);
        let mut out = vec![0.0; self.output_dim()];
        self.transform_into(&v, &mut out, scratch);
        out
    }
}

impl DynSketcher for FeatureHasher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::FeatureHash(Sketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "featurehash"
    }
}

impl Sketcher for BbitSketcher {
    type Sketch = BbitSketch;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> BbitSketch {
        BbitSketcher::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for BbitSketcher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::BBit(BbitSketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "bbit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;
    use crate::sketch::spec::SketchSpec;

    #[test]
    fn erased_matches_typed_for_every_scheme() {
        let set: Vec<u32> = (0..400u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut scratch = Scratch::new();
        for spec in [
            SketchSpec::oph(HashFamily::MixedTab, 3, 64),
            SketchSpec::minhash(HashFamily::MixedTab, 4, 16),
            SketchSpec::simhash(HashFamily::MixedTab, 5, 32),
            SketchSpec::feature_hash(
                HashFamily::MixedTab,
                6,
                64,
                crate::sketch::SignMode::Paired,
            ),
            SketchSpec::bbit(HashFamily::MixedTab, 7, 2, 64),
        ] {
            let erased = spec.build();
            assert_eq!(erased.scheme_id(), spec.scheme_id());
            let value = erased.sketch_dyn(&set, &mut scratch);
            assert_eq!(value.scheme_id(), spec.scheme_id());
            assert!(!value.is_empty());
            match &value {
                SketchValue::Oph(s) => {
                    assert_eq!(s, &spec.build_oph().unwrap().sketch(&set));
                }
                SketchValue::MinHash(v) => {
                    assert_eq!(v, &spec.build_minhash().unwrap().sketch(&set));
                }
                SketchValue::SimHash(v) => {
                    let sh = spec.build_simhash().unwrap();
                    assert_eq!(v, &Sketcher::sketch(&sh, &set));
                }
                SketchValue::FeatureHash(v) => {
                    let fh = spec.build_feature_hasher().unwrap();
                    assert_eq!(v, &Sketcher::sketch(&fh, &set));
                }
                SketchValue::BBit(s) => {
                    assert_eq!(s, &spec.build_bbit().unwrap().sketch(&set));
                }
            }
        }
    }

    #[test]
    fn batch_matches_per_set() {
        let sets: Vec<Vec<u32>> = (0..5u32).map(|i| (i * 100..i * 100 + 80).collect()).collect();
        let mut scratch = Scratch::new();
        let erased = SketchSpec::oph(HashFamily::MixedTab, 9, 32).build();
        let batch = erased.sketch_batch_dyn(&sets, &mut scratch);
        assert_eq!(batch.len(), sets.len());
        for (s, v) in sets.iter().zip(&batch) {
            assert_eq!(v, &erased.sketch_dyn(s, &mut scratch));
        }
    }

    #[test]
    fn value_estimate_matches_family_estimators() {
        let a: Vec<u32> = (0..300).collect();
        let b: Vec<u32> = (30..330).collect();
        let mut scratch = Scratch::new();

        // OPH: identical to the typed sketcher's estimate.
        let spec = SketchSpec::oph(HashFamily::MixedTab, 3, 64);
        let oph = spec.build_oph().unwrap();
        let erased = spec.build();
        let (va, vb) = (
            erased.sketch_dyn(&a, &mut scratch),
            erased.sketch_dyn(&b, &mut scratch),
        );
        let expect = oph.estimate(&oph.sketch(&a), &oph.sketch(&b));
        assert_eq!(va.estimate(&vb).unwrap(), expect);
        assert_eq!(va.estimate(&va).unwrap(), 1.0);

        // MinHash: identical to `MinHash::estimate`.
        let spec = SketchSpec::minhash(HashFamily::MixedTab, 4, 32);
        let mh = spec.build_minhash().unwrap();
        let erased = spec.build();
        let (va, vb) = (
            erased.sketch_dyn(&a, &mut scratch),
            erased.sketch_dyn(&b, &mut scratch),
        );
        let expect = mh.estimate(&mh.sketch(&a), &mh.sketch(&b));
        assert_eq!(va.estimate(&vb).unwrap(), expect);

        // SimHash: identical to `SimHash::estimate_cosine`.
        let spec = SketchSpec::simhash(HashFamily::MixedTab, 5, 64);
        let sh = spec.build_simhash().unwrap();
        let erased = spec.build();
        let (va, vb) = (
            erased.sketch_dyn(&a, &mut scratch),
            erased.sketch_dyn(&b, &mut scratch),
        );
        let (ta, tb) = (Sketcher::sketch(&sh, &a), Sketcher::sketch(&sh, &b));
        assert_eq!(va.estimate(&vb).unwrap(), sh.estimate_cosine(&ta, &tb));

        // b-bit: identical to `BbitSketch::estimate`.
        let spec = SketchSpec::bbit(HashFamily::MixedTab, 6, 2, 64);
        let bb = spec.build_bbit().unwrap();
        let erased = spec.build();
        let (va, vb) = (
            erased.sketch_dyn(&a, &mut scratch),
            erased.sketch_dyn(&b, &mut scratch),
        );
        let expect = bb.sketch(&a).estimate(&bb.sketch(&b));
        assert_eq!(va.estimate(&vb).unwrap(), expect);

        // Feature hashing: cosine of identical vectors is 1.
        let spec = SketchSpec::feature_hash(
            HashFamily::MixedTab,
            7,
            64,
            crate::sketch::SignMode::Paired,
        );
        let erased = spec.build();
        let va = erased.sketch_dyn(&a, &mut scratch);
        assert!((va.estimate(&va).unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn value_estimate_rejects_mismatches() {
        let set: Vec<u32> = (0..100).collect();
        let mut scratch = Scratch::new();
        let oph = SketchSpec::oph(HashFamily::MixedTab, 1, 32)
            .build()
            .sketch_dyn(&set, &mut scratch);
        let oph_small = SketchSpec::oph(HashFamily::MixedTab, 1, 16)
            .build()
            .sketch_dyn(&set, &mut scratch);
        let mh = SketchSpec::minhash(HashFamily::MixedTab, 1, 32)
            .build()
            .sketch_dyn(&set, &mut scratch);
        assert!(oph.estimate(&mh).is_err(), "scheme mismatch must error");
        assert!(oph.estimate(&oph_small).is_err(), "size mismatch must error");
        let b2 = SketchValue::BBit(crate::sketch::BbitSketch {
            b: 2,
            vals: vec![0, 1],
        });
        let b4 = SketchValue::BBit(crate::sketch::BbitSketch {
            b: 4,
            vals: vec![0, 1],
        });
        assert!(b2.estimate(&b4).is_err(), "b-width mismatch must error");
        let empty = SketchValue::MinHash(Vec::new());
        assert!(empty.estimate(&empty).is_err(), "empty sketches must error");
    }

    #[test]
    fn sketch_value_len_reports_coordinates() {
        assert_eq!(SketchValue::MinHash(vec![1, 2, 3]).len(), 3);
        assert_eq!(SketchValue::SimHash(vec![true; 8]).len(), 8);
        assert!(SketchValue::FeatureHash(Vec::new()).is_empty());
    }
}
