//! The unified [`Sketcher`] trait — one sketching API for every family.
//!
//! The paper's hash layer already has a single abstraction
//! ([`crate::hash::Hasher32`] behind [`crate::hash::HashFamily`]); this
//! module gives the sketch layer its equivalent. Every sketch family
//! (OPH, MinHash, SimHash, feature hashing, b-bit) implements [`Sketcher`]
//! over sets of `u32` keys — the service domain — so generic code can be
//! written once, and implements the object-safe erased form
//! [`DynSketcher`] (producing a [`SketchValue`]) so runtime-selected paths
//! (the coordinator's scheme-aware `Sketch` endpoint, the `mixtab sketch`
//! CLI) can hold `Box<dyn DynSketcher>` built from a parsed
//! [`crate::sketch::SketchSpec`].
//!
//! Set semantics for the vector-valued families: SimHash and feature
//! hashing natively sketch a [`SparseVector`]; their [`Sketcher`] impls
//! treat the input set as its unit-norm indicator vector
//! ([`SparseVector::unit_indicator`]), which is exactly how the paper's
//! synthetic experiments feed sets to FH. The typed inherent APIs
//! (`SimHash::sketch_with(&SparseVector, …)`,
//! `FeatureHasher::transform_into`) remain the hot paths for real vector
//! workloads.

use super::bbit::{BbitSketch, BbitSketcher};
use super::feature_hash::FeatureHasher;
use super::minhash::MinHash;
use super::oph::{OneHashSketcher, OphSketch};
use super::scratch::Scratch;
use super::simhash::SimHash;
use crate::data::sparse::SparseVector;

/// A sketch produced by an erased [`DynSketcher`] — one variant per family.
#[derive(Debug, Clone, PartialEq)]
pub enum SketchValue {
    /// Densified (per the sketcher's mode) One Permutation Hashing bins.
    Oph(OphSketch),
    /// k×MinHash coordinates.
    MinHash(Vec<u32>),
    /// SimHash sign bits.
    SimHash(Vec<bool>),
    /// Feature-hashed dense vector.
    FeatureHash(Vec<f64>),
    /// b-bit-truncated minwise sketch.
    BBit(BbitSketch),
}

impl SketchValue {
    /// Scheme identifier (matches [`crate::sketch::SketchSpec`] ids).
    pub fn scheme_id(&self) -> &'static str {
        match self {
            SketchValue::Oph(_) => "oph",
            SketchValue::MinHash(_) => "minhash",
            SketchValue::SimHash(_) => "simhash",
            SketchValue::FeatureHash(_) => "featurehash",
            SketchValue::BBit(_) => "bbit",
        }
    }

    /// Number of coordinates in the sketch.
    pub fn len(&self) -> usize {
        match self {
            SketchValue::Oph(s) => s.k(),
            SketchValue::MinHash(v) => v.len(),
            SketchValue::SimHash(v) => v.len(),
            SketchValue::FeatureHash(v) => v.len(),
            SketchValue::BBit(s) => s.vals.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The unified sketching API over sets of `u32` keys.
///
/// Implementations must be deterministic for a fixed construction seed and
/// must route batch hashing through [`crate::hash::Hasher32::hash_slice`]
/// with the caller's [`Scratch`] (the PR-2 hot-path contract). The
/// convenience methods mirror the inherent per-family APIs: `sketch`
/// allocates a one-shot scratch, `sketch_batch` reuses one scratch across
/// a whole batch.
pub trait Sketcher {
    /// The family's native sketch type.
    type Sketch;

    /// Sketch one set using a caller-provided [`Scratch`] (hot path).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Self::Sketch;

    /// Convenience: sketch with a one-shot [`Scratch`].
    fn sketch(&self, set: &[u32]) -> Self::Sketch {
        self.sketch_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Sketch a batch of sets, reusing one [`Scratch`] across the batch so
    /// steady streams allocate no hash buffers per set.
    fn sketch_batch(&self, sets: &[Vec<u32>], scratch: &mut Scratch) -> Vec<Self::Sketch> {
        sets.iter().map(|s| self.sketch_with(s, scratch)).collect()
    }
}

/// Object-safe erased form of [`Sketcher`] for runtime-selected schemes.
///
/// Built by [`crate::sketch::SketchSpec::build`]; the output is wrapped in
/// the scheme-tagged [`SketchValue`] so wire codecs and CLIs can dispatch
/// without knowing the concrete type.
pub trait DynSketcher: Send + Sync {
    /// Sketch one set into the scheme-tagged value.
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue;

    /// Batch variant (one reused scratch).
    fn sketch_batch_dyn(&self, sets: &[Vec<u32>], scratch: &mut Scratch) -> Vec<SketchValue> {
        sets.iter().map(|s| self.sketch_dyn(s, scratch)).collect()
    }

    /// Scheme identifier (matches [`SketchValue::scheme_id`]).
    fn scheme_id(&self) -> &'static str;
}

impl Sketcher for OneHashSketcher {
    type Sketch = OphSketch;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> OphSketch {
        OneHashSketcher::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for OneHashSketcher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::Oph(OneHashSketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "oph"
    }
}

impl Sketcher for MinHash {
    type Sketch = Vec<u32>;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<u32> {
        MinHash::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for MinHash {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::MinHash(MinHash::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "minhash"
    }
}

impl Sketcher for SimHash {
    type Sketch = Vec<bool>;

    /// Sketches the set's unit-norm indicator vector (module docs).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<bool> {
        let v = SparseVector::unit_indicator(set);
        SimHash::sketch_with(self, &v, scratch)
    }
}

impl DynSketcher for SimHash {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::SimHash(Sketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "simhash"
    }
}

impl Sketcher for FeatureHasher {
    type Sketch = Vec<f64>;

    /// Transforms the set's unit-norm indicator vector (module docs).
    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<f64> {
        let v = SparseVector::unit_indicator(set);
        let mut out = vec![0.0; self.output_dim()];
        self.transform_into(&v, &mut out, scratch);
        out
    }
}

impl DynSketcher for FeatureHasher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::FeatureHash(Sketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "featurehash"
    }
}

impl Sketcher for BbitSketcher {
    type Sketch = BbitSketch;

    fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> BbitSketch {
        BbitSketcher::sketch_with(self, set, scratch)
    }
}

impl DynSketcher for BbitSketcher {
    fn sketch_dyn(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        SketchValue::BBit(BbitSketcher::sketch_with(self, set, scratch))
    }

    fn scheme_id(&self) -> &'static str {
        "bbit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;
    use crate::sketch::spec::SketchSpec;

    #[test]
    fn erased_matches_typed_for_every_scheme() {
        let set: Vec<u32> = (0..400u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut scratch = Scratch::new();
        for spec in [
            SketchSpec::oph(HashFamily::MixedTab, 3, 64),
            SketchSpec::minhash(HashFamily::MixedTab, 4, 16),
            SketchSpec::simhash(HashFamily::MixedTab, 5, 32),
            SketchSpec::feature_hash(
                HashFamily::MixedTab,
                6,
                64,
                crate::sketch::SignMode::Paired,
            ),
            SketchSpec::bbit(HashFamily::MixedTab, 7, 2, 64),
        ] {
            let erased = spec.build();
            assert_eq!(erased.scheme_id(), spec.scheme_id());
            let value = erased.sketch_dyn(&set, &mut scratch);
            assert_eq!(value.scheme_id(), spec.scheme_id());
            assert!(!value.is_empty());
            match &value {
                SketchValue::Oph(s) => {
                    assert_eq!(s, &spec.build_oph().unwrap().sketch(&set));
                }
                SketchValue::MinHash(v) => {
                    assert_eq!(v, &spec.build_minhash().unwrap().sketch(&set));
                }
                SketchValue::SimHash(v) => {
                    let sh = spec.build_simhash().unwrap();
                    assert_eq!(v, &Sketcher::sketch(&sh, &set));
                }
                SketchValue::FeatureHash(v) => {
                    let fh = spec.build_feature_hasher().unwrap();
                    assert_eq!(v, &Sketcher::sketch(&fh, &set));
                }
                SketchValue::BBit(s) => {
                    assert_eq!(s, &spec.build_bbit().unwrap().sketch(&set));
                }
            }
        }
    }

    #[test]
    fn batch_matches_per_set() {
        let sets: Vec<Vec<u32>> = (0..5u32).map(|i| (i * 100..i * 100 + 80).collect()).collect();
        let mut scratch = Scratch::new();
        let erased = SketchSpec::oph(HashFamily::MixedTab, 9, 32).build();
        let batch = erased.sketch_batch_dyn(&sets, &mut scratch);
        assert_eq!(batch.len(), sets.len());
        for (s, v) in sets.iter().zip(&batch) {
            assert_eq!(v, &erased.sketch_dyn(s, &mut scratch));
        }
    }

    #[test]
    fn sketch_value_len_reports_coordinates() {
        assert_eq!(SketchValue::MinHash(vec![1, 2, 3]).len(), 3);
        assert_eq!(SketchValue::SimHash(vec![true; 8]).len(), 8);
        assert!(SketchValue::FeatureHash(Vec::new()).is_empty());
    }
}
