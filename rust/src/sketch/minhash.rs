//! Classic k×MinHash (Broder '97) — the `O(k·|A|)` baseline OPH replaces.
//!
//! Kept as (a) a correctness oracle for the OPH estimator on random data and
//! (b) the ablation point motivating OPH: `sketch()` here costs k hash
//! evaluations per element versus OPH's one.
//!
//! Each of the k repetitions hashes the whole set through
//! [`Hasher32::hash_slice`] into a [`Scratch`] buffer, so the cost is k
//! dynamic dispatches per set (not `k·|A|`); the per-key reference survives
//! as [`MinHash::sketch_per_key`] for equivalence testing.

use super::scratch::Scratch;
use crate::hash::{HashFamily, HashSource, Hasher32, IndependentSource, PooledSource};

/// k MinHash repetitions drawing from a [`HashSource`].
///
/// Constructed either from injected hashers ([`Self::from_hashers`], used
/// by tests with stub hashers) or — the configuration path — from a parsed
/// [`crate::sketch::SketchSpec`] via its `build`/`build_minhash` registry,
/// which delegates to [`Self::new`] (`pool=0`, independent hashers,
/// bit-identical to the pre-`HashSource` sketcher) or [`Self::pooled`]
/// (`pool=N`, repetitions sampled from a shared precomputed pool).
pub struct MinHash {
    source: Box<dyn HashSource>,
}

impl MinHash {
    pub fn new(family: HashFamily, seed: u64, k: usize) -> Self {
        assert!(k >= 1);
        let hashers = (0..k)
            .map(|i| family.build(seed.wrapping_add((i as u64) << 32 | 0x9E37)))
            .collect();
        Self::from_hashers(hashers)
    }

    /// k repetitions sampled from a shared `pool_bits`-bit pool
    /// ([`PooledSource`]): O(pool) hash work per sketch instead of O(k).
    pub fn pooled(family: HashFamily, seed: u64, k: usize, pool_bits: usize) -> Self {
        assert!(k >= 1);
        Self::from_source(Box::new(PooledSource::new(family, seed, k, pool_bits)))
    }

    /// Build from k explicit hashers (one per repetition).
    pub fn from_hashers(hashers: Vec<Box<dyn Hasher32>>) -> Self {
        assert!(!hashers.is_empty());
        Self::from_source(Box::new(IndependentSource::new(hashers)))
    }

    /// Build from any [`HashSource`] with one output per repetition.
    pub fn from_source(source: Box<dyn HashSource>) -> Self {
        assert!(source.outputs() >= 1);
        Self { source }
    }

    pub fn k(&self) -> usize {
        self.source.outputs()
    }

    /// Sketch: `S[i] = min_{a ∈ A} h_i(a)`. Empty sets get all-`u32::MAX`.
    /// Convenience wrapper around [`Self::sketch_with`] with a one-shot
    /// [`Scratch`].
    pub fn sketch(&self, set: &[u32]) -> Vec<u32> {
        self.sketch_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Sketch using a caller-provided [`Scratch`] (hot path): one
    /// [`HashSource::begin`] per set (the pooled source hashes its whole
    /// pool here), then per repetition a [`HashSource::fill`] batch and a
    /// monomorphic min-reduction over the buffer. Bit-identical to
    /// [`Self::sketch_per_key`].
    pub fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> Vec<u32> {
        let mut out = vec![u32::MAX; self.source.outputs()];
        let (pool, hashes) = scratch.pool_and_hashes_mut(set.len());
        self.source.begin(set, pool);
        for (i, o) in out.iter_mut().enumerate() {
            self.source.fill(i, set, pool, hashes);
            let mut m = u32::MAX;
            for &v in hashes.iter() {
                m = m.min(v);
            }
            *o = m;
        }
        out
    }

    /// Per-key reference for [`Self::sketch_with`] (one dynamic dispatch per
    /// element per repetition). Correctness oracle for the batched path; not
    /// for production use.
    pub fn sketch_per_key(&self, set: &[u32]) -> Vec<u32> {
        let mut out = vec![u32::MAX; self.source.outputs()];
        for (i, o) in out.iter_mut().enumerate() {
            let mut m = u32::MAX;
            for &x in set {
                m = m.min(self.source.hash_one(i, x));
            }
            *o = m;
        }
        out
    }

    /// Estimate Jaccard similarity as the fraction of agreeing coordinates.
    pub fn estimate(&self, a: &[u32], b: &[u32]) -> f64 {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), self.source.outputs());
        let m = a.iter().zip(b).filter(|(x, y)| x == y).count();
        m as f64 / a.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_exact;

    #[test]
    fn identical_sets() {
        let mh = MinHash::new(HashFamily::MixedTab, 1, 32);
        let s: Vec<u32> = (0..100).collect();
        let a = mh.sketch(&s);
        assert_eq!(mh.estimate(&a, &a), 1.0);
    }

    #[test]
    fn disjoint_sets_near_zero() {
        let mh = MinHash::new(HashFamily::MixedTab, 2, 128);
        let a: Vec<u32> = (0..1000).collect();
        let b: Vec<u32> = (100_000..101_000).collect();
        let est = mh.estimate(&mh.sketch(&a), &mh.sketch(&b));
        assert!(est < 0.05, "est {est}");
    }

    #[test]
    fn tracks_true_jaccard_on_random_data() {
        let a: Vec<u32> = (0..1500).collect();
        let b: Vec<u32> = (500..2000).collect(); // J = 1000/2000 = 0.5
        let truth = jaccard_exact(&a, &b);
        let mut sum = 0.0;
        let reps = 30;
        for seed in 0..reps {
            let mh = MinHash::new(HashFamily::MixedTab, seed, 100);
            sum += mh.estimate(&mh.sketch(&a), &mh.sketch(&b));
        }
        let mean = sum / reps as f64;
        assert!((mean - truth).abs() < 0.03, "mean {mean} truth {truth}");
    }

    #[test]
    fn empty_set_sketch_is_max() {
        let mh = MinHash::new(HashFamily::Murmur3, 3, 8);
        assert!(mh.sketch(&[]).iter().all(|&v| v == u32::MAX));
    }

    #[test]
    fn batched_matches_per_key() {
        let mh = MinHash::new(HashFamily::MixedTab, 11, 64);
        let set: Vec<u32> = (0..500u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut scratch = crate::sketch::scratch::Scratch::new();
        assert_eq!(mh.sketch_with(&set, &mut scratch), mh.sketch_per_key(&set));
        assert_eq!(mh.sketch_with(&[], &mut scratch), mh.sketch_per_key(&[]));
    }

    #[test]
    fn pooled_batched_matches_per_key() {
        let mh = MinHash::pooled(HashFamily::MixedTab, 11, 64, 256);
        assert_eq!(mh.k(), 64);
        let set: Vec<u32> = (0..500u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let mut scratch = crate::sketch::scratch::Scratch::new();
        assert_eq!(mh.sketch_with(&set, &mut scratch), mh.sketch_per_key(&set));
        assert_eq!(mh.sketch_with(&[], &mut scratch), mh.sketch_per_key(&[]));
    }

    #[test]
    fn pooled_tracks_true_jaccard_on_random_data() {
        // Pool windows overlap (coordinates are not independent), but each
        // coordinate is still a uniform hash, so the estimator stays
        // unbiased — only the variance grows. Averaged over seeds the
        // estimate must still track the truth.
        let a: Vec<u32> = (0..1500).collect();
        let b: Vec<u32> = (500..2000).collect(); // J = 1000/2000 = 0.5
        let truth = jaccard_exact(&a, &b);
        let mut sum = 0.0;
        let reps = 30;
        for seed in 0..reps {
            let mh = MinHash::pooled(HashFamily::MixedTab, seed, 100, 512);
            sum += mh.estimate(&mh.sketch(&a), &mh.sketch(&b));
        }
        let mean = sum / reps as f64;
        assert!((mean - truth).abs() < 0.05, "mean {mean} truth {truth}");
    }
}
