//! Similarity-estimation and dimensionality-reduction sketches (§2).
//!
//! Everything here is parameterised by a basic [`crate::hash::Hasher32`] —
//! the paper's experimental variable:
//!
//! * [`minhash`] — classic k×MinHash (Broder) baseline; `O(k·|A|)`.
//! * [`oph`] — One Permutation Hashing (Li, Owen, Zhang — NIPS'12); `O(|A|)`
//!   for a k-bin sketch, with the empty-bin problem solved by
//! * [`densify`] — the densification of Shrivastava & Li (UAI'14, [33] in
//!   the paper): directional circular copying with a `j·C` offset.
//! * [`feature_hash`] — Feature Hashing (Weinberger et al., ICML'09): sparse
//!   d-dim vector → dense d'-dim vector preserving ‖v‖₂ (§2.2, Theorem 1).
//! * [`simhash`] — SimHash (Charikar) for angular similarity (extension; the
//!   paper cites it as an LSH alternative).
//! * [`bbit`] — b-bit truncation of minwise sketches (Li–Shrivastava–König),
//!   discussed in §1.2.
//! * [`estimators`] — exact Jaccard ground truth and sketch estimators.
//! * [`scratch`] — reusable [`Scratch`] buffers backing the batched hot
//!   paths: every sketch hashes whole sets/documents through
//!   [`crate::hash::Hasher32::hash_slice`] (one dynamic dispatch per batch),
//!   and the `*_with` method variants reuse caller-owned buffers so steady
//!   streams allocate nothing per document.
//! * [`sketcher`] — the unified [`Sketcher`] trait implemented by every
//!   family, with the object-safe erased [`DynSketcher`] form producing a
//!   scheme-tagged [`SketchValue`].
//! * [`spec`] — declarative [`SketchSpec`] descriptions
//!   (`oph(k=200,hash=mixed_tab,seed=42)`, …) with `parse`/`Display`
//!   round-tripping, and the single `build()` registry through which every
//!   sketcher in the coordinator, LSH index, experiments, benchsuite, and
//!   CLI is constructed.

pub mod minhash;
pub mod oph;
pub mod densify;
pub mod feature_hash;
pub mod simhash;
pub mod bbit;
pub mod estimators;
pub mod scratch;
pub mod sketcher;
pub mod spec;

pub use bbit::{BbitSketch, BbitSketcher};
pub use densify::{densify, DensifyMode};
pub use estimators::jaccard_exact;
pub use feature_hash::{FeatureHasher, SignMode};
pub use minhash::MinHash;
pub use oph::{BinLayout, OneHashSketcher, OphSketch, EMPTY_BIN};
pub use scratch::Scratch;
pub use sketcher::{DynSketcher, SketchValue, Sketcher};
pub use spec::{OphParams, SketchScheme, SketchSpec};
