//! b-bit minwise hashing (Li–Shrivastava–König; [24] in the paper).
//!
//! Keeps only the lowest `b` bits of each sketch coordinate, shrinking the
//! sketch by a factor `32/b` at the cost of `2^-b` false-positive collisions
//! that the estimator corrects for. §1.2 notes applying the b-bit trick to
//! the paper's experiments "would only introduce a bias from false positives
//! for all basic hash functions and leave the conclusion the same" — the
//! ablation experiment `mixtab exp synth2 --bbit` verifies exactly that.

use super::estimators::bbit_correct;
use super::oph::{OneHashSketcher, OphSketch, EMPTY_BIN};
use super::scratch::Scratch;

/// A b-bit-truncated sketch. Coordinates are the low `b` bits of the source
/// sketch's values, stored one-per-u16 (b ≤ 8 is where the technique makes
/// sense; the paper's discussion uses b ∈ {1, 2, 4}).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BbitSketch {
    pub b: u32,
    pub vals: Vec<u16>,
}

impl BbitSketch {
    /// Truncate a densified OPH sketch to b bits per bin.
    pub fn from_oph(s: &OphSketch, b: u32) -> Self {
        assert!((1..=8).contains(&b), "b in 1..=8");
        let mask = (1u64 << b) - 1;
        let vals = s
            .bins
            .iter()
            .map(|&v| {
                if v == EMPTY_BIN {
                    // Undensified empty bins keep a sentinel that never
                    // matches a real value (bit b set).
                    1u16 << b
                } else {
                    (v & mask) as u16
                }
            })
            .collect();
        Self { b, vals }
    }

    /// Collision fraction between two b-bit sketches.
    pub fn collision_fraction(&self, other: &BbitSketch) -> f64 {
        assert_eq!(self.b, other.b);
        assert_eq!(self.vals.len(), other.vals.len());
        let sentinel = 1u16 << self.b;
        let m = self
            .vals
            .iter()
            .zip(&other.vals)
            .filter(|(x, y)| x == y && **x != sentinel)
            .count();
        m as f64 / self.vals.len() as f64
    }

    /// Bias-corrected Jaccard estimate.
    pub fn estimate(&self, other: &BbitSketch) -> f64 {
        bbit_correct(self.collision_fraction(other), self.b)
    }

    /// Storage bytes (packed) — what the 32/b compression buys.
    pub fn packed_bytes(&self) -> usize {
        (self.vals.len() * self.b as usize).div_ceil(8)
    }
}

/// End-to-end b-bit sketcher: an inner OPH sketcher whose densified output
/// is truncated to b bits per bin.
///
/// This is the `bbit(b=…, k=…)` scheme of
/// [`crate::sketch::SketchSpec`]; ad-hoc truncation of an existing
/// [`OphSketch`] stays available via [`BbitSketch::from_oph`].
pub struct BbitSketcher {
    oph: OneHashSketcher,
    b: u32,
}

impl BbitSketcher {
    /// Wrap an OPH sketcher; `b` must be in `1..=8`.
    pub fn new(oph: OneHashSketcher, b: u32) -> Self {
        assert!((1..=8).contains(&b), "b in 1..=8");
        Self { oph, b }
    }

    pub fn b(&self) -> u32 {
        self.b
    }

    /// The inner OPH sketcher (its `k` is the b-bit sketch length).
    pub fn inner(&self) -> &OneHashSketcher {
        &self.oph
    }

    /// Sketch using a caller-provided [`Scratch`] (hot path): densified
    /// OPH sketch, truncated to b bits per bin.
    pub fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> BbitSketch {
        BbitSketch::from_oph(&self.oph.sketch_with(set, scratch), self.b)
    }

    /// Convenience wrapper around [`Self::sketch_with`] with a one-shot
    /// [`Scratch`].
    pub fn sketch(&self, set: &[u32]) -> BbitSketch {
        self.sketch_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Bias-corrected Jaccard estimate between two sketches produced by
    /// *this* sketcher (shape-checked; `BbitSketch::estimate` additionally
    /// checks the two sketches against each other).
    pub fn estimate(&self, a: &BbitSketch, b: &BbitSketch) -> f64 {
        assert_eq!(a.b, self.b, "sketch b-width differs from this sketcher");
        assert_eq!(b.b, self.b, "sketch b-width differs from this sketcher");
        assert_eq!(a.vals.len(), self.oph.k(), "sketch length differs from k");
        a.estimate(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;
    use crate::sketch::oph::{BinLayout, OneHashSketcher};
    use crate::sketch::DensifyMode;

    fn sketcher(seed: u64, k: usize) -> OneHashSketcher {
        OneHashSketcher::from_hasher(
            HashFamily::MixedTab.build(seed),
            k,
            BinLayout::Mod,
            DensifyMode::Paper,
        )
    }

    #[test]
    fn bbit_sketcher_matches_manual_truncation() {
        let bs = BbitSketcher::new(sketcher(4, 128), 2);
        assert_eq!(bs.b(), 2);
        assert_eq!(bs.inner().k(), 128);
        let set: Vec<u32> = (0..400).collect();
        let manual = BbitSketch::from_oph(&sketcher(4, 128).sketch(&set), 2);
        assert_eq!(bs.sketch(&set), manual);
        let other = bs.sketch(&(200..600).collect::<Vec<_>>());
        let est = bs.estimate(&bs.sketch(&set), &other);
        assert!((-1.0..=1.0).contains(&est));
    }

    #[test]
    fn identical_sets_estimate_one() {
        let sk = sketcher(1, 128);
        let set: Vec<u32> = (0..500).collect();
        let s = BbitSketch::from_oph(&sk.sketch(&set), 2);
        assert!((s.estimate(&s) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_sets_near_zero_after_correction() {
        let sk = sketcher(3, 512);
        let a: Vec<u32> = (0..3000).collect();
        let b: Vec<u32> = (500_000..503_000).collect();
        let (sa, sb) = (sk.sketch(&a), sk.sketch(&b));
        for b_bits in [1u32, 2, 4] {
            let (ta, tb) = (
                BbitSketch::from_oph(&sa, b_bits),
                BbitSketch::from_oph(&sb, b_bits),
            );
            let frac = ta.collision_fraction(&tb);
            // Uncorrected collision fraction ≈ 2^-b…
            assert!(
                (frac - (0.5f64).powi(b_bits as i32)).abs() < 0.08,
                "b={b_bits} frac={frac}"
            );
            // …corrected estimate ≈ 0.
            assert!(ta.estimate(&tb).abs() < 0.1, "b={b_bits}");
        }
    }

    #[test]
    fn more_bits_tighter() {
        // With more bits the (same-seed) estimate variance shrinks; check
        // simple monotonicity of |est - truth| averaged over seeds.
        let a: Vec<u32> = (0..2000).collect();
        let b: Vec<u32> = (1000..3000).collect(); // J = 1/3
        let truth = 1.0 / 3.0;
        let mut err_b1 = 0.0;
        let mut err_b8 = 0.0;
        let reps = 20;
        for seed in 0..reps {
            let sk = sketcher(seed, 256);
            let (sa, sb) = (sk.sketch(&a), sk.sketch(&b));
            let e1 = BbitSketch::from_oph(&sa, 1).estimate(&BbitSketch::from_oph(&sb, 1));
            let e8 = BbitSketch::from_oph(&sa, 8).estimate(&BbitSketch::from_oph(&sb, 8));
            err_b1 += (e1 - truth).abs();
            err_b8 += (e8 - truth).abs();
        }
        assert!(err_b8 <= err_b1, "b=8 err {err_b8} vs b=1 err {err_b1}");
    }

    #[test]
    fn packed_size() {
        let sk = sketcher(5, 200);
        let s = BbitSketch::from_oph(&sk.sketch(&(0..100).collect::<Vec<_>>()), 2);
        assert_eq!(s.packed_bytes(), 50); // 200 bins × 2 bits = 400 bits
    }
}
