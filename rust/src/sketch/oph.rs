//! One Permutation Hashing (§2.1).
//!
//! One hash evaluation per element: `h(x)` is split into a bin index
//! `b(x) = h(x) mod k` and a value `v(x) = ⌊h(x)/k⌋`; the sketch keeps the
//! minimum value per bin. Empty bins are handled by [`super::densify`].
//!
//! The paper's Figure 1 uses the equivalent contiguous-range layout
//! (`b(x) = ⌊h(x)/(m/k)⌋`, `v(x) = h(x) mod (m/k)`); both layouts are
//! provided ([`BinLayout`]) and the Figure 1 worked example is reproduced in
//! the tests with [`BinLayout::Range`]. Experiments use the text's
//! [`BinLayout::Mod`].
//!
//! Sketching is batched: the whole set is hashed through
//! [`Hasher32::hash_slice`] into a [`Scratch`] buffer before the bin loop,
//! so the hot path pays one dynamic dispatch per set instead of per
//! element. The per-key reference path survives as
//! [`OneHashSketcher::sketch_raw_per_key`] and is property-tested
//! bit-identical to the batched path for every Table 1 family.

use super::densify::{densify, DensifyMode};
use super::scratch::Scratch;
use crate::hash::Hasher32;

/// Sentinel for an empty bin (no element hashed into it). All real values
/// are `< 2^32` so `u64::MAX` is unambiguous.
pub const EMPTY_BIN: u64 = u64::MAX;

/// How `h(x)` is split into (bin, value).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinLayout {
    /// `b = h mod k`, `v = h / k` (paper §2.1 text).
    Mod,
    /// `b = h / (m/k)`, `v = h mod (m/k)` with `m = 2^32` (paper Figure 1).
    Range,
}

impl BinLayout {
    /// Stable identifier used by [`crate::sketch::SketchSpec`] strings.
    pub fn id(&self) -> &'static str {
        match self {
            BinLayout::Mod => "mod",
            BinLayout::Range => "range",
        }
    }

    /// Parse the [`Self::id`] form.
    pub fn parse(s: &str) -> Option<BinLayout> {
        match s {
            "mod" => Some(BinLayout::Mod),
            "range" => Some(BinLayout::Range),
            _ => None,
        }
    }
}

/// A raw (pre-densification) OPH sketch: one `u64` per bin, either the
/// minimal value or [`EMPTY_BIN`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OphSketch {
    pub bins: Vec<u64>,
}

impl OphSketch {
    pub fn k(&self) -> usize {
        self.bins.len()
    }

    pub fn empty_bins(&self) -> usize {
        self.bins.iter().filter(|&&b| b == EMPTY_BIN).count()
    }
}

/// OPH sketcher: a basic hash function + parameters. The densification
/// direction bits are derived from the sketcher's own seed so that two sets
/// sketched by the *same* sketcher share them (required for the estimator).
///
/// Constructed either from an injected hasher ([`Self::from_hasher`], used
/// by tests with stub hashers) or — the configuration path — from a parsed
/// [`crate::sketch::SketchSpec`] via its `build`/`build_oph` registry.
pub struct OneHashSketcher {
    hasher: Box<dyn Hasher32>,
    k: usize,
    layout: BinLayout,
    mode: DensifyMode,
    /// Direction bits b_i for densification (§2.1 / Figure 1 right).
    directions: Vec<bool>,
}

impl OneHashSketcher {
    /// `k` bins over the given hasher. Direction bits come from the hasher
    /// itself evaluated on bin indices (any fixed derivation shared between
    /// sketches works; the paper just needs "for each index a random bit").
    pub fn from_hasher(
        hasher: Box<dyn Hasher32>,
        k: usize,
        layout: BinLayout,
        mode: DensifyMode,
    ) -> Self {
        assert!(k >= 1 && (k as u64) <= (1u64 << 32), "k must fit the hash range");
        let directions = (0..k)
            .map(|i| hasher.hash(0xD1B5_4A32u32.wrapping_add(i as u32)) & 1 == 1)
            .collect();
        Self {
            hasher,
            k,
            layout,
            mode,
            directions,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn hasher_name(&self) -> &'static str {
        self.hasher.name()
    }

    /// Raw sketch (may contain empty bins). Convenience wrapper around
    /// [`Self::sketch_raw_with`] with a one-shot [`Scratch`].
    pub fn sketch_raw(&self, set: &[u32]) -> OphSketch {
        self.sketch_raw_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Raw sketch using a caller-provided [`Scratch`] (hot path).
    ///
    /// The set is hashed in one [`Hasher32::hash_slice`] call — one dynamic
    /// dispatch per set, with the per-key loop monomorphised inside the
    /// hash implementation — then split into (bin, value) pairs.
    /// Bit-identical to [`Self::sketch_raw_per_key`].
    pub fn sketch_raw_with(&self, set: &[u32], scratch: &mut Scratch) -> OphSketch {
        let hashes = scratch.hashes_mut(set.len());
        self.hasher.hash_slice(set, hashes);
        let mut bins = vec![EMPTY_BIN; self.k];
        match self.layout {
            BinLayout::Mod => {
                let k = self.k as u64;
                for &h in hashes.iter() {
                    let h = h as u64;
                    let b = (h % k) as usize;
                    let v = h / k;
                    if v < bins[b] {
                        bins[b] = v;
                    }
                }
            }
            BinLayout::Range => {
                // Same arithmetic as `range_sketch` with m = 2^32 inlined
                // over the u32 hash buffer (no u64 widening pass).
                let range = (1u64 << 32) / self.k as u64;
                for &h in hashes.iter() {
                    let h = h as u64;
                    let b = ((h / range) as usize).min(self.k - 1);
                    let v = h % range;
                    if v < bins[b] {
                        bins[b] = v;
                    }
                }
            }
        }
        OphSketch { bins }
    }

    /// Per-key reference for [`Self::sketch_raw_with`]: one dynamic dispatch
    /// per element. Kept as the correctness oracle for the batched path
    /// (`rust/tests/properties.rs` asserts bit-identical output); not for
    /// production use.
    pub fn sketch_raw_per_key(&self, set: &[u32]) -> OphSketch {
        let mut bins = vec![EMPTY_BIN; self.k];
        let k = self.k as u64;
        match self.layout {
            BinLayout::Mod => {
                for &x in set {
                    let h = self.hasher.hash(x) as u64;
                    let b = (h % k) as usize;
                    let v = h / k;
                    if v < bins[b] {
                        bins[b] = v;
                    }
                }
            }
            BinLayout::Range => {
                let hashes: Vec<u64> =
                    set.iter().map(|&x| self.hasher.hash(x) as u64).collect();
                bins = range_sketch(&hashes, 1u64 << 32, self.k);
            }
        }
        OphSketch { bins }
    }

    /// Densified sketch: no empty bins (unless the set itself is empty).
    pub fn sketch(&self, set: &[u32]) -> OphSketch {
        self.sketch_with(set, &mut Scratch::with_capacity(set.len()))
    }

    /// Densified sketch using a caller-provided [`Scratch`] (hot path).
    pub fn sketch_with(&self, set: &[u32], scratch: &mut Scratch) -> OphSketch {
        let mut s = self.sketch_raw_with(set, scratch);
        densify(&mut s.bins, &self.directions, self.mode);
        s
    }

    /// Per-key reference for [`Self::sketch_with`] (reference path +
    /// densification); see [`Self::sketch_raw_per_key`].
    pub fn sketch_per_key(&self, set: &[u32]) -> OphSketch {
        let mut s = self.sketch_raw_per_key(set);
        densify(&mut s.bins, &self.directions, self.mode);
        s
    }

    /// Densify a raw sketch produced elsewhere (e.g. the PJRT OPH kernel)
    /// with *this* sketcher's direction bits — required for the result to
    /// be comparable with natively-produced sketches.
    pub fn densify_in_place(&self, s: &mut OphSketch) {
        assert_eq!(s.k(), self.k);
        densify(&mut s.bins, &self.directions, self.mode);
    }

    /// Estimate `J(A, B)` from two densified sketches produced by *this*
    /// sketcher: the fraction of agreeing bins (§2.1).
    pub fn estimate(&self, a: &OphSketch, b: &OphSketch) -> f64 {
        estimate_collision(a, b)
    }
}

/// Contiguous-range OPH (Figure 1 layout) over explicit hash values in
/// `[m]`: `b = ⌊h/(m/k)⌋`, `v = h mod (m/k)` — exposed separately so the
/// figure's worked example is testable at |U| = 20 and so the PJRT path can
/// reuse the exact same bin arithmetic. When k does not divide m the last
/// range absorbs the remainder.
pub fn range_sketch(hashes: &[u64], m: u64, k: usize) -> Vec<u64> {
    assert!(k >= 1 && m >= k as u64);
    let range = m / k as u64;
    let mut bins = vec![EMPTY_BIN; k];
    for &h in hashes {
        debug_assert!(h < m);
        let b = ((h / range) as usize).min(k - 1);
        let v = h % range;
        if v < bins[b] {
            bins[b] = v;
        }
    }
    bins
}

/// Fraction of agreeing bins between two equally-sized sketches.
pub fn estimate_collision(a: &OphSketch, b: &OphSketch) -> f64 {
    assert_eq!(a.k(), b.k(), "sketch sizes differ");
    assert!(a.k() > 0);
    let matches = a
        .bins
        .iter()
        .zip(&b.bins)
        .filter(|(x, y)| x == y && **x != EMPTY_BIN)
        .count();
    matches as f64 / a.k() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::{HashFamily, Hasher32};
    use crate::sketch::estimators::jaccard_exact;
    use crate::util::rng::Xoshiro256;

    /// A stub hasher with a fixed lookup — lets us drive the exact Figure 1
    /// scenario (|U| = 20, k = 5).
    struct TableHasher {
        map: std::collections::HashMap<u32, u32>,
    }
    impl Hasher32 for TableHasher {
        fn hash(&self, x: u32) -> u32 {
            *self.map.get(&x).unwrap_or(&x)
        }
        fn name(&self) -> &'static str {
            "table"
        }
    }

    /// Figure 1 (left): hash values of A as an indicator over [20]:
    /// 0011 0100 0000 1010 0010 → minima per 4-wide bin: [2, 1, -, 0, 2].
    #[test]
    fn figure1_left_worked_example() {
        let hashes = [2u64, 3, 5, 12, 14, 18]; // h(A) positions set to 1
        let s = super::range_sketch(&hashes, 20, 5);
        assert_eq!(s, vec![2, 1, EMPTY_BIN, 0, 2]);
    }

    #[test]
    fn range_sketch_on_32bit_universe_matches_layout() {
        // Sanity for the production m = 2^32 path: bins partition the hash
        // space and the per-bin value is the offset within the range.
        let m = 1u64 << 32;
        let k = 5usize;
        let range = m / k as u64;
        let hashes = [0u64, range - 1, range, 3 * range + 7, m - 1];
        let s = super::range_sketch(&hashes, m, k);
        assert_eq!(s[0], 0);
        assert_eq!(s[1], 0); // `range` lands at bin 1 offset 0
        assert_eq!(s[3], 7);
        // m-1 lands in the last bin (clamped) with offset m-1 - 4*range.
        assert_eq!(s[4], (m - 1) % range);
        assert_eq!(s[2], EMPTY_BIN);
    }

    #[test]
    fn mod_layout_definition() {
        // With the Mod layout, bins/values follow b = h mod k, v = h / k.
        let map: std::collections::HashMap<u32, u32> =
            [(1u32, 13u32), (2, 27), (3, 8)].into_iter().collect();
        let sketcher = OneHashSketcher::from_hasher(
            Box::new(TableHasher { map }),
            5,
            BinLayout::Mod,
            DensifyMode::None,
        );
        let s = sketcher.sketch_raw(&[1, 2, 3]);
        // 13 → bin 3, v 2; 27 → bin 2, v 5; 8 → bin 3, v 1 (min with 13's 2 → 1).
        assert_eq!(s.bins[3], 1);
        assert_eq!(s.bins[2], 5);
        assert_eq!(s.bins[0], EMPTY_BIN);
        assert_eq!(s.empty_bins(), 3);
    }

    #[test]
    fn identical_sets_estimate_one() {
        let sketcher = OneHashSketcher::from_hasher(
            HashFamily::MixedTab.build(3),
            64,
            BinLayout::Mod,
            DensifyMode::Paper,
        );
        let set: Vec<u32> = (0..500u32).map(|i| i.wrapping_mul(2654435761)).collect();
        let s1 = sketcher.sketch(&set);
        let s2 = sketcher.sketch(&set);
        assert_eq!(sketcher.estimate(&s1, &s2), 1.0);
    }

    #[test]
    fn disjoint_sets_estimate_near_zero() {
        let sketcher = OneHashSketcher::from_hasher(
            HashFamily::MixedTab.build(4),
            128,
            BinLayout::Mod,
            DensifyMode::Paper,
        );
        let a: Vec<u32> = (0..2000u32).collect();
        let b: Vec<u32> = (1_000_000..1_002_000u32).collect();
        let est = sketcher.estimate(&sketcher.sketch(&a), &sketcher.sketch(&b));
        assert!(est < 0.06, "est {est}");
    }

    #[test]
    fn estimator_tracks_true_jaccard() {
        // Average over independent sketcher seeds ≈ J (unbiasedness of the
        // densified estimator, [33]).
        let mut rng = Xoshiro256::new(5);
        let a: Vec<u32> = (0..3000u32).map(|_| rng.next_u32() % 10_000).collect();
        let b: Vec<u32> = a.iter().map(|&x| if x % 3 == 0 { x } else { x + 10_000 }).collect();
        let truth = jaccard_exact(&a, &b);
        let mut sum = 0.0;
        let reps = 60;
        for seed in 0..reps {
            let sk = OneHashSketcher::from_hasher(
                HashFamily::MixedTab.build(seed),
                200,
                BinLayout::Mod,
                DensifyMode::Paper,
            );
            sum += sk.estimate(&sk.sketch(&a), &sk.sketch(&b));
        }
        let mean = sum / reps as f64;
        assert!(
            (mean - truth).abs() < 0.03,
            "mean {mean} vs truth {truth}"
        );
    }

    #[test]
    fn batched_path_matches_per_key_reference() {
        use crate::sketch::scratch::Scratch;
        let set: Vec<u32> = (0..777u32).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        let mut scratch = Scratch::new();
        for layout in [BinLayout::Mod, BinLayout::Range] {
            let sk = OneHashSketcher::from_hasher(
                HashFamily::MixedTab.build(6),
                100,
                layout,
                DensifyMode::Paper,
            );
            assert_eq!(sk.sketch_raw_with(&set, &mut scratch), sk.sketch_raw_per_key(&set));
            assert_eq!(sk.sketch_with(&set, &mut scratch), sk.sketch_per_key(&set));
            // Empty set: both paths agree on all-empty bins.
            assert_eq!(sk.sketch_raw_with(&[], &mut scratch), sk.sketch_raw_per_key(&[]));
        }
    }

    #[test]
    fn sparse_sets_have_empty_bins_before_densification() {
        let sketcher = OneHashSketcher::from_hasher(
            HashFamily::MixedTab.build(9),
            200,
            BinLayout::Mod,
            DensifyMode::Paper,
        );
        let small: Vec<u32> = (0..100u32).collect(); // n = k/2 regime (Fig 9)
        let raw = sketcher.sketch_raw(&small);
        assert!(raw.empty_bins() > 50, "{} empty", raw.empty_bins());
        let dense = sketcher.sketch(&small);
        assert_eq!(dense.empty_bins(), 0);
    }
}
