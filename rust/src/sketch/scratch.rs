//! Reusable scratch buffers for the batched sketch hot paths.
//!
//! Every sketch in this module hashes a batch of keys through
//! [`crate::hash::Hasher32::hash_slice`] so the per-key loop monomorphises
//! inside the hash implementation — one dynamic dispatch per *batch* instead
//! of per key. The batch buffer itself must live somewhere; allocating it per
//! document re-introduces a malloc on every sketch call, which dominates for
//! short sets. [`Scratch`] is that buffer, owned by the caller and reused
//! across documents:
//!
//! ```
//! use mixtab::hash::HashFamily;
//! use mixtab::sketch::{Scratch, SketchSpec};
//!
//! let sk = SketchSpec::oph(HashFamily::MixedTab, 1, 64).build_oph().unwrap();
//! let mut scratch = Scratch::new();
//! for doc in [&[1u32, 2, 3][..], &[4, 5][..]] {
//!     let s = sk.sketch_with(doc, &mut scratch); // zero hash-buffer allocs
//!     assert_eq!(s.k(), 64);
//! }
//! ```
//!
//! The convenience entry points (`sketch`, `transform`, …) still exist and
//! allocate a fresh `Scratch` internally, so one-shot callers keep the
//! simple API while loops thread a `Scratch` through `*_with` variants.

/// Reusable scratch space for batched sketching.
///
/// Holds the per-batch hash output buffers ([`crate::sketch::oph`],
/// [`crate::sketch::minhash`], [`crate::sketch::simhash`],
/// [`crate::sketch::feature_hash`]) plus the dense output vector used by
/// [`crate::sketch::FeatureHasher::squared_norm`]. Buffers only ever grow;
/// a `Scratch` reused across a stream of documents settles at the largest
/// document size and stops allocating.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    /// Primary hash output buffer (bin hashes).
    pub(crate) hashes: Vec<u32>,
    /// Secondary hash output buffer (sign hashes in
    /// [`crate::sketch::SignMode::Separate`] feature hashing).
    pub(crate) signs: Vec<u32>,
    /// Dense d'-dimensional output reused by `squared_norm`.
    pub(crate) dense: Vec<f64>,
    /// Pool-word buffer for [`crate::hash::PooledSource`]-backed sketchers
    /// (`pool_bits / 64` u64 words per key, word-major). Stays empty for
    /// independent sources.
    pub(crate) pool: Vec<u64>,
}

impl Scratch {
    /// Empty scratch; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch pre-sized for batches of up to `keys` keys.
    pub fn with_capacity(keys: usize) -> Self {
        Self {
            hashes: Vec::with_capacity(keys),
            signs: Vec::new(),
            dense: Vec::new(),
            pool: Vec::new(),
        }
    }

    /// The primary hash buffer resized to `n` entries (contents
    /// unspecified — callers overwrite via `hash_slice`).
    pub(crate) fn hashes_mut(&mut self, n: usize) -> &mut [u32] {
        self.hashes.resize(n, 0);
        &mut self.hashes[..n]
    }

    /// The pool-word buffer plus the primary hash buffer at `n` entries —
    /// split borrows from distinct fields, so a
    /// [`crate::hash::HashSource`] can read the pool while writing hashes.
    pub(crate) fn pool_and_hashes_mut(&mut self, n: usize) -> (&mut Vec<u64>, &mut [u32]) {
        self.hashes.resize(n, 0);
        (&mut self.pool, &mut self.hashes[..n])
    }

    /// Two independent `n`-entry hash buffers (bin hashes, sign hashes).
    pub(crate) fn hash_pair_mut(&mut self, n: usize) -> (&mut [u32], &mut [u32]) {
        self.hashes.resize(n, 0);
        self.signs.resize(n, 0);
        (&mut self.hashes[..n], &mut self.signs[..n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_resize_and_reuse() {
        let mut s = Scratch::new();
        assert_eq!(s.hashes_mut(10).len(), 10);
        let cap = s.hashes.capacity();
        // Shrinking the logical size keeps the allocation.
        assert_eq!(s.hashes_mut(3).len(), 3);
        assert_eq!(s.hashes.capacity(), cap);
        let (h, g) = s.hash_pair_mut(7);
        assert_eq!((h.len(), g.len()), (7, 7));
    }

    #[test]
    fn with_capacity_starts_empty() {
        let s = Scratch::with_capacity(64);
        assert!(s.hashes.is_empty());
        assert!(s.hashes.capacity() >= 64);
    }
}
