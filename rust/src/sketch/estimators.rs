//! Ground truth and estimator utilities shared by the experiments.

use std::collections::HashSet;

/// Exact Jaccard similarity `|A ∩ B| / |A ∪ B|` (sets given as unsorted
/// slices possibly with duplicates — deduplicated internally).
pub fn jaccard_exact(a: &[u32], b: &[u32]) -> f64 {
    let sa: HashSet<u32> = a.iter().copied().collect();
    let sb: HashSet<u32> = b.iter().copied().collect();
    if sa.is_empty() && sb.is_empty() {
        return 1.0;
    }
    let inter = sa.intersection(&sb).count();
    let union = sa.len() + sb.len() - inter;
    inter as f64 / union as f64
}

/// Exact Jaccard over *sorted deduplicated* slices — `O(|A| + |B|)`; used by
/// the LSH ground-truth scan where the quadratic pair count dominates.
pub fn jaccard_sorted(a: &[u32], b: &[u32]) -> f64 {
    debug_assert!(a.windows(2).all(|w| w[0] < w[1]));
    debug_assert!(b.windows(2).all(|w| w[0] < w[1]));
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.len() + b.len() - inter;
    inter as f64 / union as f64
}

/// Cosine similarity between sparse vectors given as parallel (sorted
/// indices, values) — used by the SimHash tests and MNIST-like ground truth.
pub fn cosine_sorted(ai: &[u32], av: &[f64], bi: &[u32], bv: &[f64]) -> f64 {
    let (mut i, mut j) = (0usize, 0usize);
    let mut dot = 0.0;
    while i < ai.len() && j < bi.len() {
        match ai[i].cmp(&bi[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                dot += av[i] * bv[j];
                i += 1;
                j += 1;
            }
        }
    }
    let na: f64 = av.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = bv.iter().map(|x| x * x).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na * nb)
}

/// Unbiased Jaccard estimate from a b-bit collision fraction: with b bits,
/// unrelated coordinates still collide with probability `2^-b`, so
/// `E[frac] = J + (1 − J)·2^{−b}` and the corrected estimator is
/// `(frac − 2^{−b}) / (1 − 2^{−b})` (Li–König).
pub fn bbit_correct(collision_fraction: f64, b: u32) -> f64 {
    let fp = (0.5f64).powi(b as i32); // 2^{-b}
    ((collision_fraction - fp) / (1.0 - fp)).clamp(-1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jaccard_basics() {
        assert_eq!(jaccard_exact(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(jaccard_exact(&[1, 2], &[3, 4]), 0.0);
        assert!((jaccard_exact(&[1, 2, 3], &[2, 3, 4]) - 0.5).abs() < 1e-12);
        assert_eq!(jaccard_exact(&[], &[]), 1.0);
        assert_eq!(jaccard_exact(&[1], &[]), 0.0);
        // Duplicates ignored.
        assert_eq!(jaccard_exact(&[1, 1, 2], &[1, 2, 2]), 1.0);
    }

    #[test]
    fn sorted_matches_exact() {
        let a: Vec<u32> = (0..100).filter(|x| x % 2 == 0).collect();
        let b: Vec<u32> = (0..100).filter(|x| x % 3 == 0).collect();
        assert!((jaccard_sorted(&a, &b) - jaccard_exact(&a, &b)).abs() < 1e-12);
    }

    #[test]
    fn cosine_identical_and_orthogonal() {
        let i1 = [0u32, 1, 2];
        let v1 = [1.0, 2.0, 3.0];
        assert!((cosine_sorted(&i1, &v1, &i1, &v1) - 1.0).abs() < 1e-12);
        let i2 = [5u32, 6];
        let v2 = [1.0, 1.0];
        assert_eq!(cosine_sorted(&i1, &v1, &i2, &v2), 0.0);
    }

    #[test]
    fn bbit_correction() {
        // Perfect similarity: frac = 1 → J = 1.
        assert!((bbit_correct(1.0, 1) - 1.0).abs() < 1e-12);
        // Independent sketches: frac = 2^-b → J = 0.
        assert!(bbit_correct(0.5, 1).abs() < 1e-12);
        assert!(bbit_correct(0.25, 2).abs() < 1e-12);
        // Midpoint with b = 1: frac = 0.75 → J = 0.5.
        assert!((bbit_correct(0.75, 1) - 0.5).abs() < 1e-12);
    }
}
