//! News20 stand-in generator.
//!
//! Matches the statistics the paper reports for the News20 bag-of-words
//! data (§4.2): ≈ 1.3·10⁶ features, ≈ 500 non-zeros per document, and very
//! few similar pairs (≈ 0.2 neighbours per point above J = 1/2).
//!
//! Crucially it reproduces the *structural* property §4.1 argues makes weak
//! hash functions fail on text: token ids are assigned by frequency rank
//! ("it is quite common to let frequent words/shingles have the lowest
//! identifier"), so every document's support contains a dense block of
//! small ids. Token frequencies are Zipf-distributed; values are TF-style
//! counts normalised to unit length.

use crate::data::sparse::{Dataset, SparseVector};
use crate::util::rng::Xoshiro256;

/// Vocabulary size (≈ News20's 1.3M feature space).
pub const DIM: usize = 1_300_000;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct News20LikeParams {
    /// Zipf exponent for token frequencies.
    pub zipf_s: f64,
    /// Tokens drawn per document (with repetition → TF counts).
    pub tokens_per_doc: usize,
    /// Number of topics; each topic boosts a band of mid-frequency ids so
    /// documents cluster mildly without creating near-duplicates.
    pub topics: usize,
    /// Probability a token is drawn from the topic band instead of the
    /// global Zipf distribution.
    pub topic_mix: f64,
    /// Probability a document is a light mutation of an earlier one —
    /// matching the real News20's sparse near-duplicate structure (paper:
    /// ≈ 0.2 neighbours per point above J = 1/2, i.e. a small but non-zero
    /// duplicate population from cross-posts/quotes).
    pub near_dup_rate: f64,
}

impl Default for News20LikeParams {
    fn default() -> Self {
        Self {
            zipf_s: 1.05,
            tokens_per_doc: 800, // ≈ 500 distinct after TF-merging
            topics: 20,
            topic_mix: 0.25,
            near_dup_rate: 0.05,
        }
    }
}

/// Generate `n` documents.
pub fn generate(n: usize, params: &News20LikeParams, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::stream(seed, 0x4E45_5753_3230); // "NEWS20"
    let harmonic = Xoshiro256::zipf_harmonic(DIM, params.zipf_s);
    // Topic bands: contiguous id ranges in the mid-frequency zone.
    let band_width = 3_000usize;
    let bands: Vec<usize> = (0..params.topics)
        .map(|t| 10_000 + t * band_width * 2)
        .collect();
    let mut vectors: Vec<SparseVector> = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for doc_i in 0..n {
        // Near-duplicate: copy an earlier document and drop ~10% of its
        // support (a quoted/cross-posted message).
        if doc_i > 0 && rng.bernoulli(params.near_dup_rate) {
            let src = rng.range(0, vectors.len());
            let (idx, vals): (Vec<u32>, Vec<f64>) = vectors[src]
                .indices
                .iter()
                .zip(&vectors[src].values)
                .filter(|_| !rng.bernoulli(0.1))
                .map(|(&i, &v)| (i, v))
                .unzip();
            let mut v = SparseVector { indices: idx, values: vals };
            v.normalize();
            vectors.push(v);
            labels.push(labels[src]);
            continue;
        }
        let topic = rng.range(0, params.topics);
        let band_lo = bands[topic];
        let mut counts: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
        for _ in 0..params.tokens_per_doc {
            let id = if rng.bernoulli(params.topic_mix) {
                // Zipf *within* the topic band, keeping rank structure.
                let off = rng.zipf(band_width, 1.2, Xoshiro256::zipf_harmonic(band_width, 1.2));
                (band_lo + off) as u32
            } else {
                rng.zipf(DIM, params.zipf_s, harmonic) as u32
            };
            *counts.entry(id).or_insert(0.0) += 1.0;
        }
        let (idx, vals): (Vec<u32>, Vec<f64>) = {
            let mut pairs: Vec<(u32, f64)> = counts.into_iter().collect();
            pairs.sort_by_key(|p| p.0);
            pairs.into_iter().unzip()
        };
        let mut v = SparseVector {
            indices: idx,
            values: vals,
        };
        v.normalize();
        vectors.push(v);
        labels.push(topic as i32);
    }
    let mut ds = Dataset::new(vectors, labels);
    ds.dim = DIM;
    ds
}

/// Default database/query split (scaled-down from the paper's ~10k/10k).
pub fn default_split(n_db: usize, n_query: usize, seed: u64) -> (Dataset, Dataset) {
    let ds = generate(n_db + n_query, &News20LikeParams::default(), seed);
    ds.split(n_db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_sorted;

    #[test]
    fn statistics_match_news20() {
        let ds = generate(100, &News20LikeParams::default(), 3);
        assert_eq!(ds.dim, DIM);
        let avg = ds.avg_nnz();
        assert!(
            (350.0..650.0).contains(&avg),
            "avg nnz {avg} should be ~500"
        );
        for v in &ds.vectors {
            assert!((v.norm2() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn frequent_words_have_small_ids() {
        // The head of the id space must be much denser than the tail.
        let ds = generate(60, &News20LikeParams::default(), 5);
        let mut head = 0usize;
        let mut tail = 0usize;
        for v in &ds.vectors {
            for &i in &v.indices {
                if (i as usize) < 1000 {
                    head += 1;
                } else if (i as usize) > 500_000 {
                    tail += 1;
                }
            }
        }
        assert!(
            head > tail * 3,
            "head {head} should dominate tail {tail} (ids = frequency ranks)"
        );
    }

    #[test]
    fn few_similar_pairs_without_dups() {
        // Independent documents essentially never exceed J = 1/2.
        let params = News20LikeParams {
            near_dup_rate: 0.0,
            ..Default::default()
        };
        let ds = generate(80, &params, 7);
        let sets = ds.as_sets();
        let mut similar = 0usize;
        for i in 0..sets.len() {
            for j in (i + 1)..sets.len() {
                if jaccard_sorted(&sets[i], &sets[j]) > 0.5 {
                    similar += 1;
                }
            }
        }
        assert!(similar <= 2, "similar pairs {similar} (should be ~0)");
    }

    #[test]
    fn sparse_near_dup_population_at_default_rate() {
        // The default 5% near-dup rate yields a small but non-zero set of
        // J > 0.5 pairs (News20's ≈0.2-neighbours-per-point statistic).
        let ds = generate(120, &News20LikeParams::default(), 13);
        let sets = ds.as_sets();
        let mut similar = 0usize;
        for i in 0..sets.len() {
            for j in (i + 1)..sets.len() {
                if jaccard_sorted(&sets[i], &sets[j]) > 0.5 {
                    similar += 1;
                }
            }
        }
        assert!(
            (1..=30).contains(&similar),
            "similar pairs {similar} (want a small non-zero count)"
        );
    }

    #[test]
    fn topical_overlap_above_random() {
        // Same-topic documents should share more ids than cross-topic ones
        // (mild clustering, not near-duplication).
        let ds = generate(120, &News20LikeParams::default(), 9);
        let sets = ds.as_sets();
        let (mut same, mut same_n, mut cross, mut cross_n) = (0.0, 0, 0.0, 0);
        for i in 0..sets.len() {
            for j in (i + 1)..sets.len() {
                let jac = jaccard_sorted(&sets[i], &sets[j]);
                if ds.labels[i] == ds.labels[j] {
                    same += jac;
                    same_n += 1;
                } else {
                    cross += jac;
                    cross_n += 1;
                }
            }
        }
        let same_avg = same / same_n.max(1) as f64;
        let cross_avg = cross / cross_n.max(1) as f64;
        assert!(
            same_avg > cross_avg * 1.3,
            "same {same_avg} cross {cross_avg}"
        );
    }
}
