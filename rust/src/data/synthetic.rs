//! The paper's synthetic datasets (§4.1).
//!
//! **Dataset 1** (Figures 2, 3, 6, 7, 9): for a parameter `n`, the
//! intersection `A ∩ B` samples each integer in `[2n]` independently with
//! probability 1/2; the symmetric difference adds `n` numbers greater than
//! `2n`, split evenly between A and B. The dense `[2n]` block is exactly the
//! structure that defeats `(ax+b) mod p`-style hashing: the intersection is
//! spread too *evenly*, so its elements win the per-bin minima too often and
//! J(A, B) is over-estimated.
//!
//! **Dataset 2** (Figure 8, §4.1 "additional synthetic"): elements from
//! `[4n]`; the symmetric difference samples `{0..n−1} ∪ {3n..4n−1}` w.p. 1/2
//! and the intersection samples `{n..3n−1}` w.p. 1/2.
//!
//! **FH inputs**: indicator vectors of such sets, length-normalised (§4.1),
//! and for dataset 2 the variant sampling `[3n]` w.p. 1/2.
//!
//! Both generators take `sample = false` to produce the deterministic
//! no-sampling variants the paper says "showed an even wider gap".

use crate::data::sparse::SparseVector;
use crate::util::rng::Xoshiro256;

/// A generated set pair with known ground truth.
#[derive(Debug, Clone)]
pub struct SetPair {
    pub a: Vec<u32>,
    pub b: Vec<u32>,
    /// Exact Jaccard similarity.
    pub jaccard: f64,
}

/// §4.1 dataset 1. `n` controls both the dense block `[2n]` and the
/// symmetric-difference size `n`.
pub fn dataset1(n: usize, sample: bool, rng: &mut Xoshiro256) -> SetPair {
    let mut inter: Vec<u32> = Vec::with_capacity(n);
    for x in 0..(2 * n) as u32 {
        if !sample || rng.bernoulli(0.5) {
            inter.push(x);
        }
    }
    // n numbers greater than 2n, distributed evenly to A and B.
    // Use consecutive ids above 2n (the structure, not the identity, of the
    // difference matters; consecutive keeps them "structured" too).
    let mut a = inter.clone();
    let mut b = inter.clone();
    let base = (2 * n) as u32;
    for i in 0..n as u32 {
        if i % 2 == 0 {
            a.push(base + i);
        } else {
            b.push(base + i);
        }
    }
    let jaccard = inter.len() as f64 / (inter.len() + n) as f64;
    SetPair { a, b, jaccard }
}

/// §4.1 dataset 2 ("additional synthetic"): universe `[4n]`; symmetric
/// difference ⊂ `{0..n} ∪ {3n..4n}`, intersection ⊂ `{n..3n}`.
pub fn dataset2(n: usize, sample: bool, rng: &mut Xoshiro256) -> SetPair {
    let n32 = n as u32;
    let mut inter = Vec::new();
    for x in n32..3 * n32 {
        if !sample || rng.bernoulli(0.5) {
            inter.push(x);
        }
    }
    let mut diff = Vec::new();
    for x in (0..n32).chain(3 * n32..4 * n32) {
        if !sample || rng.bernoulli(0.5) {
            diff.push(x);
        }
    }
    let mut a = inter.clone();
    let mut b = inter.clone();
    for (i, &x) in diff.iter().enumerate() {
        if i % 2 == 0 {
            a.push(x);
        } else {
            b.push(x);
        }
    }
    let jaccard = inter.len() as f64 / (inter.len() + diff.len()) as f64;
    SetPair { a, b, jaccard }
}

/// FH input for dataset 1 (§4.1): unit-normalised indicator of a set `A`
/// generated as in [`dataset1`] — i.e. the dense half `[2n]` sampled w.p.
/// 1/2 plus `n/2` structured ids above `2n` (A's half of the difference).
pub fn fh_vector1(n: usize, sample: bool, rng: &mut Xoshiro256) -> SparseVector {
    let pair = dataset1(n, sample, rng);
    SparseVector::unit_indicator(&pair.a)
}

/// FH input for dataset 2 (§4.1 additional): ids sampled from `[3n]` w.p.
/// 1/2 (or all of `[3n]` when `sample = false`).
pub fn fh_vector2(n: usize, sample: bool, rng: &mut Xoshiro256) -> SparseVector {
    let mut ids = Vec::new();
    for x in 0..(3 * n) as u32 {
        if !sample || rng.bernoulli(0.5) {
            ids.push(x);
        }
    }
    SparseVector::unit_indicator(&ids)
}

/// Sparse variant used by Figure 9: a set of ~`size` elements drawn from the
/// same dense-block structure, scaled so OPH at k = 200 sees many empty bins
/// (`n = k/2` regime in the paper).
pub fn sparse_pair(size: usize, rng: &mut Xoshiro256) -> SetPair {
    dataset1(size, true, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_exact;

    #[test]
    fn dataset1_structure() {
        let mut rng = Xoshiro256::new(1);
        let p = dataset1(2000, true, &mut rng);
        // Intersection ≈ half of [4000]: expect near 2000 shared elements.
        let inter: Vec<u32> = p.a.iter().filter(|x| p.b.contains(x)).copied().collect();
        assert!((inter.len() as f64 - 2000.0).abs() < 200.0);
        // All intersection elements lie in the dense block [2n].
        assert!(inter.iter().all(|&x| x < 4000));
        // Ground truth matches exact recomputation.
        assert!((p.jaccard - jaccard_exact(&p.a, &p.b)).abs() < 1e-12);
        // |A∩B| ≈ n (half of [2n]); |A∪B| ≈ n + n ⇒ J ≈ 1/2.
        assert!((p.jaccard - 0.5).abs() < 0.05);
    }

    #[test]
    fn dataset1_no_sampling_exact() {
        let mut rng = Xoshiro256::new(1);
        let p = dataset1(100, false, &mut rng);
        assert!((p.jaccard - 200.0 / 300.0).abs() < 1e-12);
        assert_eq!(p.a.len(), 250); // 200 + 50
        assert_eq!(p.b.len(), 250);
    }

    #[test]
    fn dataset2_structure() {
        let mut rng = Xoshiro256::new(3);
        let p = dataset2(2000, true, &mut rng);
        assert!((p.jaccard - jaccard_exact(&p.a, &p.b)).abs() < 1e-12);
        // Intersection in the middle band, difference in outer bands.
        for x in &p.a {
            assert!(*x < 8000);
        }
        // J ≈ 2n / 4n = 0.5.
        assert!((p.jaccard - 0.5).abs() < 0.05);
    }

    #[test]
    fn fh_vectors_unit_norm() {
        let mut rng = Xoshiro256::new(9);
        let v1 = fh_vector1(2000, true, &mut rng);
        assert!((v1.norm2() - 1.0).abs() < 1e-12);
        assert!(v1.nnz() > 1500);
        let v2 = fh_vector2(2000, true, &mut rng);
        assert!((v2.norm2() - 1.0).abs() < 1e-12);
        // ≈ 3n/2 non-zeros.
        assert!((v2.nnz() as f64 - 3000.0).abs() < 300.0);
    }

    #[test]
    fn deterministic_given_rng_state() {
        let mut r1 = Xoshiro256::new(42);
        let mut r2 = Xoshiro256::new(42);
        let p1 = dataset1(500, true, &mut r1);
        let p2 = dataset1(500, true, &mut r2);
        assert_eq!(p1.a, p2.a);
        assert_eq!(p1.b, p2.b);
    }
}
