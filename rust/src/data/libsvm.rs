//! libsvm sparse-format IO.
//!
//! The paper's real datasets (MNIST, News20) are distributed in this format
//! by the LIBSVM project [11]. Drop the files into `data/real/` and the
//! experiment drivers use them instead of the generators:
//!
//! ```text
//! <label> <index>:<value> <index>:<value> ...
//! ```
//!
//! Indices are 1-based in the wild; we convert to 0-based on read and back
//! on write. Lines starting with `#` and blank lines are skipped.

use crate::data::sparse::{Dataset, SparseVector};
use crate::util::error::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

/// Parse a dataset from a reader.
pub fn read(reader: impl BufRead) -> Result<Dataset> {
    let mut vectors = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.context("read line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts.next().unwrap();
        let label: i32 = label_tok
            .parse::<f64>()
            .map(|f| f as i32)
            .with_context(|| format!("line {}: bad label '{label_tok}'", lineno + 1))?;
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("line {}: bad pair '{tok}'", lineno + 1))?;
            let i: u32 = i
                .parse()
                .with_context(|| format!("line {}: bad index '{i}'", lineno + 1))?;
            if i == 0 {
                bail!("line {}: libsvm indices are 1-based, got 0", lineno + 1);
            }
            let v: f64 = v
                .parse()
                .with_context(|| format!("line {}: bad value '{v}'", lineno + 1))?;
            idx.push(i - 1);
            val.push(v);
        }
        vectors.push(SparseVector::new(idx, val));
        labels.push(label);
    }
    Ok(Dataset::new(vectors, labels))
}

/// Load a dataset from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read(std::io::BufReader::new(f))
}

/// Write a dataset to a file (1-based indices).
pub fn save(ds: &Dataset, path: impl AsRef<Path>) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    for (i, v) in ds.vectors.iter().enumerate() {
        let label = ds.labels.get(i).copied().unwrap_or(0);
        write!(w, "{label}")?;
        for (&j, &x) in v.indices.iter().zip(&v.values) {
            write!(w, " {}:{}", j + 1, x)?;
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Look for `<name>` (and `<name>.t` query split) under `dir`; returns
/// `(database, queries)` when both exist.
pub fn load_split(dir: impl AsRef<Path>, name: &str) -> Option<(Dataset, Dataset)> {
    let dir = dir.as_ref();
    let db_path = dir.join(name);
    let q_path = dir.join(format!("{name}.t"));
    if db_path.exists() && q_path.exists() {
        match (load(&db_path), load(&q_path)) {
            (Ok(db), Ok(q)) => Some((db, q)),
            _ => None,
        }
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_basic() {
        let text = "1 3:0.5 7:1.25\n-1 1:2\n\n# comment\n0 2:1 2:1\n";
        let ds = read(Cursor::new(text)).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![1, -1, 0]);
        assert_eq!(ds.vectors[0].indices, vec![2, 6]); // 0-based
        assert_eq!(ds.vectors[0].values, vec![0.5, 1.25]);
        // Duplicate indices merged by SparseVector::new.
        assert_eq!(ds.vectors[2].values, vec![2.0]);
    }

    #[test]
    fn float_labels_truncate() {
        let ds = read(Cursor::new("2.0 1:1\n")).unwrap();
        assert_eq!(ds.labels, vec![2]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(read(Cursor::new("1 nocolon\n")).is_err());
        assert!(read(Cursor::new("notanumber 1:1\n")).is_err());
        assert!(read(Cursor::new("1 0:5\n")).is_err()); // 0 index
    }

    #[test]
    fn roundtrip() {
        let text = "1 1:0.5 9:2\n3 4:1\n";
        let ds = read(Cursor::new(text)).unwrap();
        let dir = std::env::temp_dir().join("mixtab_libsvm_test");
        let path = dir.join("data.svm");
        save(&ds, &path).unwrap();
        let ds2 = load(&path).unwrap();
        assert_eq!(ds.labels, ds2.labels);
        assert_eq!(ds.vectors[0], ds2.vectors[0]);
        assert_eq!(ds.vectors[1], ds2.vectors[1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_split_absent_is_none() {
        assert!(load_split("/nonexistent-dir-xyz", "mnist").is_none());
    }
}
