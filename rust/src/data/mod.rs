//! Dataset substrate.
//!
//! * [`sparse`] — sparse vector / dataset types shared by every layer.
//! * [`synthetic`] — the paper's §4.1 synthetic generators (both datasets).
//! * [`mnist_like`] — statistically-matched stand-in for MNIST (see
//!   DESIGN.md §4 for the substitution argument), plus a loader for the
//!   real data when available.
//! * [`news20_like`] — statistically-matched stand-in for News20.
//! * [`libsvm`] — reader/writer for the libsvm sparse format, so the real
//!   MNIST/News20 files can be dropped in.
//! * [`shingle`] — w-shingling of documents into 32-bit ids (§1: "data
//!   points are often stored as w-shingles").

pub mod sparse;
pub mod synthetic;
pub mod mnist_like;
pub mod news20_like;
pub mod libsvm;
pub mod shingle;

pub use sparse::{Dataset, SparseVector};
