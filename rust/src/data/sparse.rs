//! Sparse vectors and datasets.

/// A sparse vector: parallel `(indices, values)` with indices strictly
/// increasing. Feature ids are `u32` — the paper's universe is `[2^32]`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseVector {
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl SparseVector {
    /// Construct, sorting by index and combining duplicates.
    pub fn new(indices: Vec<u32>, values: Vec<f64>) -> Self {
        assert_eq!(indices.len(), values.len());
        let mut pairs: Vec<(u32, f64)> = indices.into_iter().zip(values).collect();
        pairs.sort_by_key(|p| p.0);
        let mut out_i = Vec::with_capacity(pairs.len());
        let mut out_v: Vec<f64> = Vec::with_capacity(pairs.len());
        for (i, v) in pairs {
            if out_i.last() == Some(&i) {
                *out_v.last_mut().unwrap() += v;
            } else {
                out_i.push(i);
                out_v.push(v);
            }
        }
        Self {
            indices: out_i,
            values: out_v,
        }
    }

    /// Indicator vector of a set, normalised to unit 2-norm — the FH input
    /// construction of §4.1 ("taking the indicator vector of a set A … and
    /// normalizing the length").
    pub fn unit_indicator(set: &[u32]) -> Self {
        let mut idx: Vec<u32> = set.to_vec();
        idx.sort_unstable();
        idx.dedup();
        let val = 1.0 / (idx.len().max(1) as f64).sqrt();
        let n = idx.len();
        Self {
            indices: idx,
            values: vec![val; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    pub fn norm2_sq(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum()
    }

    pub fn norm2(&self) -> f64 {
        self.norm2_sq().sqrt()
    }

    pub fn linf(&self) -> f64 {
        self.values.iter().fold(0.0, |m, v| m.max(v.abs()))
    }

    /// Scale to unit 2-norm (no-op for the zero vector).
    pub fn normalize(&mut self) {
        let n = self.norm2();
        if n > 0.0 {
            for v in &mut self.values {
                *v /= n;
            }
        }
    }

    /// Sparse addition.
    pub fn add(&self, other: &SparseVector) -> SparseVector {
        let mut idx = Vec::with_capacity(self.nnz() + other.nnz());
        let mut val = Vec::with_capacity(self.nnz() + other.nnz());
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.nnz() || j < other.nnz() {
            let take_self = j >= other.nnz()
                || (i < self.nnz() && self.indices[i] <= other.indices[j]);
            let take_other = i >= self.nnz()
                || (j < other.nnz() && other.indices[j] <= self.indices[i]);
            if take_self && take_other {
                idx.push(self.indices[i]);
                val.push(self.values[i] + other.values[j]);
                i += 1;
                j += 1;
            } else if take_self {
                idx.push(self.indices[i]);
                val.push(self.values[i]);
                i += 1;
            } else {
                idx.push(other.indices[j]);
                val.push(other.values[j]);
                j += 1;
            }
        }
        SparseVector {
            indices: idx,
            values: val,
        }
    }

    /// Dot product.
    pub fn dot(&self, other: &SparseVector) -> f64 {
        let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0);
        while i < self.nnz() && j < other.nnz() {
            match self.indices[i].cmp(&other.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[i] * other.values[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc
    }
}

/// A labelled sparse dataset.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub vectors: Vec<SparseVector>,
    pub labels: Vec<i32>,
    /// Total feature dimension (max index + 1 unless set explicitly).
    pub dim: usize,
}

impl Dataset {
    pub fn new(vectors: Vec<SparseVector>, labels: Vec<i32>) -> Self {
        assert!(labels.is_empty() || labels.len() == vectors.len());
        let dim = vectors
            .iter()
            .flat_map(|v| v.indices.last().copied())
            .max()
            .map(|m| m as usize + 1)
            .unwrap_or(0);
        Self {
            vectors,
            labels,
            dim,
        }
    }

    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    pub fn avg_nnz(&self) -> f64 {
        if self.vectors.is_empty() {
            return 0.0;
        }
        self.vectors.iter().map(|v| v.nnz()).sum::<usize>() as f64 / self.vectors.len() as f64
    }

    /// The vectors' support sets (for set-similarity experiments).
    pub fn as_sets(&self) -> Vec<Vec<u32>> {
        self.vectors.iter().map(|v| v.indices.clone()).collect()
    }

    /// Split into (database, queries) at `n_db`.
    pub fn split(mut self, n_db: usize) -> (Dataset, Dataset) {
        let n_db = n_db.min(self.vectors.len());
        let q_vecs = self.vectors.split_off(n_db);
        let q_labels = if self.labels.is_empty() {
            Vec::new()
        } else {
            self.labels.split_off(n_db)
        };
        let dim = self.dim;
        (
            Dataset {
                vectors: self.vectors,
                labels: self.labels,
                dim,
            },
            Dataset {
                vectors: q_vecs,
                labels: q_labels,
                dim,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_sorts_and_merges() {
        let v = SparseVector::new(vec![5, 1, 5, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v.indices, vec![1, 2, 5]);
        assert_eq!(v.values, vec![2.0, 4.0, 4.0]);
    }

    #[test]
    fn unit_indicator_norm() {
        let v = SparseVector::unit_indicator(&[9, 3, 3, 7]);
        assert_eq!(v.nnz(), 3);
        assert!((v.norm2() - 1.0).abs() < 1e-12);
        assert_eq!(v.indices, vec![3, 7, 9]);
    }

    #[test]
    fn add_and_dot() {
        let a = SparseVector::new(vec![1, 3], vec![1.0, 2.0]);
        let b = SparseVector::new(vec![3, 4], vec![5.0, 7.0]);
        let s = a.add(&b);
        assert_eq!(s.indices, vec![1, 3, 4]);
        assert_eq!(s.values, vec![1.0, 7.0, 7.0]);
        assert!((a.dot(&b) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_zero_safe() {
        let mut z = SparseVector::new(vec![], vec![]);
        z.normalize();
        assert_eq!(z.nnz(), 0);
        let mut v = SparseVector::new(vec![1, 2], vec![3.0, 4.0]);
        v.normalize();
        assert!((v.norm2() - 1.0).abs() < 1e-12);
        assert!((v.linf() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn dataset_stats_and_split() {
        let ds = Dataset::new(
            vec![
                SparseVector::new(vec![0, 9], vec![1.0, 1.0]),
                SparseVector::new(vec![5], vec![1.0]),
                SparseVector::new(vec![2, 3, 4], vec![1.0, 1.0, 1.0]),
            ],
            vec![0, 1, 0],
        );
        assert_eq!(ds.dim, 10);
        assert!((ds.avg_nnz() - 2.0).abs() < 1e-12);
        let (db, q) = ds.split(2);
        assert_eq!(db.len(), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.labels, vec![0]);
    }
}
