//! MNIST stand-in generator.
//!
//! The evaluation environment has no network access, so the real MNIST files
//! cannot be fetched. The experiments, however, only consume these dataset
//! statistics (paper §4.2):
//!
//! * 28×28 = 784 pixel grid, average ≈ 150 non-zeros per image;
//! * non-zeros are **spatially correlated** ("a pixel is more likely to have
//!   a non-zero value if its neighbouring pixels have non-zero values"),
//!   producing dense runs of consecutive feature ids — the structured-input
//!   regime where weak hashing fails;
//! * heavy near-duplicate structure: each point has thousands of neighbours
//!   with `J > 1/2` (paper: ≈ 3437 on average at 60k database points).
//!
//! The generator draws class/prototype "digit strokes" via random walks on
//! the grid and perturbs them per sample, matching all three statistics.
//! Real MNIST in libsvm format is used instead when present (see
//! [`crate::data::libsvm`] and the `--data-dir` experiment flag).

use crate::data::sparse::{Dataset, SparseVector};
use crate::util::rng::Xoshiro256;

/// Grid side (28×28 like MNIST).
pub const SIDE: usize = 28;
/// Feature dimension.
pub const DIM: usize = SIDE * SIDE;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct MnistLikeParams {
    /// Number of classes ("digits").
    pub classes: usize,
    /// Stroke prototypes per class; samples within a prototype are
    /// near-duplicates, so `samples / (classes × prototypes)` controls the
    /// average number of `J > 1/2` neighbours.
    pub prototypes_per_class: usize,
    /// Target non-zeros per prototype (~150 like MNIST).
    pub stroke_len: usize,
    /// Per-pixel drop probability when sampling from a prototype.
    pub drop_p: f64,
    /// Number of neighbour pixels toggled on per sample.
    pub jitter: usize,
}

impl Default for MnistLikeParams {
    fn default() -> Self {
        Self {
            classes: 10,
            prototypes_per_class: 3,
            stroke_len: 160,
            drop_p: 0.08,
            jitter: 6,
        }
    }
}

/// Random-walk stroke of `len` pixels starting near the centre.
fn walk_stroke(len: usize, rng: &mut Xoshiro256) -> Vec<u32> {
    let mut pixels = std::collections::HashSet::new();
    let mut x = (SIDE / 4 + rng.range(0, SIDE / 2)) as i32;
    let mut y = (SIDE / 4 + rng.range(0, SIDE / 2)) as i32;
    while pixels.len() < len {
        pixels.insert((y as usize * SIDE + x as usize) as u32);
        // step
        match rng.below(5) {
            0 => x += 1,
            1 => x -= 1,
            2 => y += 1,
            3 => y -= 1,
            _ => {
                // small diagonal drift to thicken strokes
                x += if rng.bernoulli(0.5) { 1 } else { -1 };
                y += if rng.bernoulli(0.5) { 1 } else { -1 };
            }
        }
        x = x.clamp(1, SIDE as i32 - 2);
        y = y.clamp(1, SIDE as i32 - 2);
        // occasional pen lift
        if rng.bernoulli(0.02) {
            x = rng.range(2, SIDE - 2) as i32;
            y = rng.range(2, SIDE - 2) as i32;
        }
    }
    let mut v: Vec<u32> = pixels.into_iter().collect();
    v.sort_unstable();
    v
}

/// A prototype: class-shared base stroke + prototype-specific stroke.
///
/// The hierarchy matters for Figure 5: real MNIST has a *continuum* of
/// pairwise similarities — near-duplicates (same writing style, J ≳ 0.7)
/// **and** a large moderate-similarity band (same digit, different style,
/// J ≈ 0.3–0.5). The moderate band is where a biased hash function changes
/// LSH retrieval; a flat prototype model (all cross-pair J ≈ 0) would hide
/// the paper's contrast.
fn make_prototype(base: &[u32], params: &MnistLikeParams, rng: &mut Xoshiro256) -> Vec<u32> {
    let extra = walk_stroke(params.stroke_len - params.stroke_len * 3 / 5, rng);
    let mut v: Vec<u32> = base.iter().copied().chain(extra).collect();
    v.sort_unstable();
    v.dedup();
    v
}

/// Generate an MNIST-like dataset of `n` images.
pub fn generate(n: usize, params: &MnistLikeParams, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::stream(seed, 0x4D4E_4953_54); // "MNIST"
    let mut protos: Vec<(i32, Vec<u32>)> = Vec::new();
    for class in 0..params.classes {
        // Class-shared base stroke (~60% of the support).
        let base = walk_stroke(params.stroke_len * 3 / 5, &mut rng);
        for _ in 0..params.prototypes_per_class {
            protos.push((class as i32, make_prototype(&base, params, &mut rng)));
        }
    }
    let mut vectors = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let (label, proto) = &protos[rng.range(0, protos.len())];
        let mut idx: Vec<u32> = proto
            .iter()
            .copied()
            .filter(|_| !rng.bernoulli(params.drop_p))
            .collect();
        // Jitter: toggle on neighbours of existing pixels.
        for _ in 0..params.jitter {
            if idx.is_empty() {
                break;
            }
            let p = idx[rng.range(0, idx.len())] as i32;
            let (px, py) = (p % SIDE as i32, p / SIDE as i32);
            let nx = (px + rng.range(0, 3) as i32 - 1).clamp(0, SIDE as i32 - 1);
            let ny = (py + rng.range(0, 3) as i32 - 1).clamp(0, SIDE as i32 - 1);
            idx.push((ny * SIDE as i32 + nx) as u32);
        }
        idx.sort_unstable();
        idx.dedup();
        // Grayscale-ish values: bright core with soft noise, in (0, 1].
        let values: Vec<f64> = idx
            .iter()
            .map(|_| (0.55 + 0.45 * rng.next_f64()).min(1.0))
            .collect();
        vectors.push(SparseVector::new(idx, values));
        labels.push(*label);
    }
    let mut ds = Dataset::new(vectors, labels);
    ds.dim = DIM;
    ds
}

/// Default database/query split used by the experiments (scaled-down from
/// the paper's 60000/10000; override with `--scale`).
pub fn default_split(n_db: usize, n_query: usize, seed: u64) -> (Dataset, Dataset) {
    let ds = generate(n_db + n_query, &MnistLikeParams::default(), seed);
    ds.split(n_db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_sorted;

    #[test]
    fn statistics_match_mnist() {
        let ds = generate(500, &MnistLikeParams::default(), 7);
        assert_eq!(ds.len(), 500);
        assert_eq!(ds.dim, 784);
        let avg = ds.avg_nnz();
        assert!(
            (120.0..190.0).contains(&avg),
            "avg nnz {avg} should be ~150"
        );
        for v in &ds.vectors {
            assert!(v.indices.iter().all(|&i| (i as usize) < DIM));
            assert!(v.values.iter().all(|&x| x > 0.0 && x <= 1.0));
        }
    }

    #[test]
    fn spatial_correlation() {
        // Non-zeros should have many adjacent non-zeros (consecutive ids).
        let ds = generate(50, &MnistLikeParams::default(), 3);
        let mut adjacent = 0usize;
        let mut total = 0usize;
        for v in &ds.vectors {
            let set: std::collections::HashSet<u32> = v.indices.iter().copied().collect();
            for &i in &v.indices {
                total += 1;
                if set.contains(&(i + 1)) || (i > 0 && set.contains(&(i - 1))) {
                    adjacent += 1;
                }
            }
        }
        let frac = adjacent as f64 / total as f64;
        assert!(frac > 0.4, "adjacency fraction {frac}");
    }

    #[test]
    fn near_duplicate_structure() {
        // Within-prototype pairs should frequently exceed J = 1/2.
        let ds = generate(300, &MnistLikeParams::default(), 11);
        let sets = ds.as_sets();
        let mut similar = 0usize;
        for i in 0..100 {
            for j in (i + 1)..100 {
                if jaccard_sorted(&sets[i], &sets[j]) > 0.5 {
                    similar += 1;
                }
            }
        }
        // With 30 prototypes over 100 points, expect ≳ 100 similar pairs.
        assert!(similar > 50, "similar pairs {similar}");
    }

    #[test]
    fn deterministic() {
        let a = generate(20, &MnistLikeParams::default(), 5);
        let b = generate(20, &MnistLikeParams::default(), 5);
        assert_eq!(a.vectors[7], b.vectors[7]);
        assert_eq!(a.labels, b.labels);
    }
}
