//! w-shingling: documents → sets of 32-bit ids (§1: "when working with
//! text, data points are often stored as w-shingles (i.e. w contiguous
//! words or bytes) with w ≥ 5").
//!
//! Shingles are reduced to `u32` ids with MurmurHash3 over the shingle
//! bytes; the resulting sets feed OPH/MinHash in the `dedup` example. A
//! frequency-ranked id mode mirrors the paper's observation that real
//! pipelines assign small ids to frequent shingles (the structure that
//! breaks weak hashing).

use crate::hash::murmur3::murmur3_x86_32;
use std::collections::HashMap;

/// Byte-level w-shingles, hashed to u32 ids (deduplicated, sorted).
pub fn byte_shingles(text: &str, w: usize) -> Vec<u32> {
    assert!(w >= 1);
    let bytes = text.as_bytes();
    if bytes.len() < w {
        if bytes.is_empty() {
            return Vec::new();
        }
        return vec![murmur3_x86_32(bytes, 0x5348_494E)];
    }
    let mut ids: Vec<u32> = bytes
        .windows(w)
        .map(|win| murmur3_x86_32(win, 0x5348_494E))
        .collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Word-level w-shingles (w consecutive whitespace-separated tokens).
pub fn word_shingles(text: &str, w: usize) -> Vec<u32> {
    assert!(w >= 1);
    let words: Vec<&str> = text.split_whitespace().collect();
    if words.is_empty() {
        return Vec::new();
    }
    if words.len() < w {
        return vec![murmur3_x86_32(text.trim().as_bytes(), 0x574F_5244)];
    }
    let mut ids: Vec<u32> = words
        .windows(w)
        .map(|win| murmur3_x86_32(win.join(" ").as_bytes(), 0x574F_5244))
        .collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// Re-map a corpus of shingle sets to frequency-ranked ids: the most common
/// shingle gets id 0, the next id 1, … (Huffman-style id assignment; §4.1
/// argues this is why real intersections form dense low-id blocks).
pub fn frequency_rank_ids(corpus: &[Vec<u32>]) -> Vec<Vec<u32>> {
    let mut freq: HashMap<u32, usize> = HashMap::new();
    for set in corpus {
        for &id in set {
            *freq.entry(id).or_insert(0) += 1;
        }
    }
    let mut by_freq: Vec<(u32, usize)> = freq.into_iter().collect();
    // Descending frequency, ties by id for determinism.
    by_freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let rank: HashMap<u32, u32> = by_freq
        .into_iter()
        .enumerate()
        .map(|(r, (id, _))| (id, r as u32))
        .collect();
    corpus
        .iter()
        .map(|set| {
            let mut out: Vec<u32> = set.iter().map(|id| rank[id]).collect();
            out.sort_unstable();
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_sorted;

    #[test]
    fn byte_shingles_basic() {
        let s = byte_shingles("abcdef", 3); // abc bcd cde def
        assert_eq!(s.len(), 4);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        // Repeated shingles dedup.
        let r = byte_shingles("aaaaaa", 3);
        assert_eq!(r.len(), 1);
        assert!(byte_shingles("", 3).is_empty());
        assert_eq!(byte_shingles("ab", 3).len(), 1);
    }

    #[test]
    fn word_shingles_basic() {
        let s = word_shingles("the quick brown fox jumps", 2);
        assert_eq!(s.len(), 4);
        assert_eq!(word_shingles("one", 2).len(), 1);
        assert!(word_shingles("", 2).is_empty());
    }

    #[test]
    fn similar_docs_high_jaccard() {
        let a = byte_shingles("the quick brown fox jumps over the lazy dog", 5);
        let b = byte_shingles("the quick brown fox jumped over the lazy dog", 5);
        let c = byte_shingles("completely different content here entirely", 5);
        assert!(jaccard_sorted(&a, &b) > 0.5);
        assert!(jaccard_sorted(&a, &c) < 0.1);
    }

    #[test]
    fn frequency_ranking_preserves_similarity() {
        let corpus = vec![
            byte_shingles("shared prefix alpha", 4),
            byte_shingles("shared prefix beta", 4),
            byte_shingles("unrelated text xyz", 4),
        ];
        let j_before = jaccard_sorted(&corpus[0], &corpus[1]);
        let ranked = frequency_rank_ids(&corpus);
        let j_after = jaccard_sorted(&ranked[0], &ranked[1]);
        assert!((j_before - j_after).abs() < 1e-12, "relabeling is a bijection");
        // Ranked ids are compact: max id < total distinct shingles.
        let total: std::collections::HashSet<u32> =
            corpus.iter().flatten().copied().collect();
        let max_rank = ranked.iter().flatten().max().copied().unwrap();
        assert!((max_rank as usize) < total.len());
        // Shared (frequent) shingles get the smallest ids.
        let shared: Vec<u32> = ranked[0]
            .iter()
            .filter(|x| ranked[1].contains(x))
            .copied()
            .collect();
        if !shared.is_empty() {
            let max_shared = *shared.iter().max().unwrap();
            assert!(max_shared as usize <= total.len() / 2 + shared.len());
        }
    }
}
