//! Loadtest corpus generation: clustered synthetic sets and shingled
//! documents at million-set scale.
//!
//! The corpus is built from *clusters* so that recall@k is well-defined:
//! every member of a cluster is an independent perturbation of the
//! cluster's base (a dense low-id block for synthetic clusters — the §4.1
//! structure that defeats weak hashing — or a base text for shingled-doc
//! clusters), so a held-out member of the same cluster has genuine near
//! neighbours with Jaccard ≈ 0.6–0.8, while adjacent clusters overlap at
//! J ≈ 0.1–0.2 and unrelated clusters at ≈ 0. Everything is a pure
//! function of `(seed, cluster, member)`, so the sustained-phase inserts
//! can be regenerated exactly for the brute-force oracle and two runs of
//! the same config sketch byte-identical corpora.

use crate::data::shingle::byte_shingles;
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::ThreadPool;

/// Knobs for [`generate`].
#[derive(Debug, Clone)]
pub struct CorpusParams {
    /// Total database sets (synthetic + shingled docs).
    pub n_sets: usize,
    /// Held-out query sets (one extra member per cluster, wrapping).
    pub n_queries: usize,
    /// Members per cluster. With recall@k ≤ `cluster_size − 1` genuine
    /// neighbours per query, keep `k < cluster_size`.
    pub cluster_size: usize,
    /// Fraction of clusters that are shingled documents (the rest are
    /// synthetic dense-block sets).
    pub doc_frac: f64,
    /// Root seed; every set derives from `(seed, cluster, member)`.
    pub seed: u64,
}

impl Default for CorpusParams {
    fn default() -> Self {
        Self {
            n_sets: 1_000_000,
            n_queries: 64,
            cluster_size: 12,
            doc_frac: 0.5,
            seed: 42,
        }
    }
}

/// A generated corpus: `sets[i]` is the set inserted under id `i`.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub sets: Vec<Vec<u32>>,
    /// Held-out queries (never inserted), one per sampled cluster.
    pub queries: Vec<Vec<u32>>,
    /// How many of `sets` are shingled documents.
    pub docs: usize,
}

const SYNTH_SALT: u64 = 0x51E7_C0DE;
const DOC_SALT: u64 = 0xD0C5_EED5;
const KIND_SALT: u64 = 0xC1A5_51F1;
const EXTRA_SALT: u64 = 0xE87A_5E75;

/// Whether cluster `c` is a shingled-doc cluster (seeded coin flip, so the
/// two kinds interleave at any corpus size). Public so the mixed-phase op
/// stream can regenerate any database set without holding the corpus.
pub fn cluster_is_doc(seed: u64, cluster: usize, doc_frac: f64) -> bool {
    Xoshiro256::stream(seed ^ KIND_SALT, cluster as u64).next_f64() < doc_frac
}

/// Generate the corpus, parallelised over `workers` threads.
pub fn generate(p: &CorpusParams, workers: usize) -> Corpus {
    assert!(p.cluster_size >= 1 && p.n_sets >= 1);
    let n_clusters = p.n_sets.div_ceil(p.cluster_size);
    let pool = ThreadPool::new(workers.max(1));
    // ~8 chunks per worker: coarse enough that spawn cost is invisible,
    // fine enough that the pool stays busy to the end.
    let chunk = n_clusters.div_ceil((pool.size() * 8).max(1)).max(1);
    let tasks: Vec<_> = (0..n_clusters)
        .step_by(chunk)
        .map(|start| {
            let end = (start + chunk).min(n_clusters);
            move || {
                let mut sets = Vec::with_capacity((end - start) * p.cluster_size);
                let mut docs = 0usize;
                for c in start..end {
                    let members = cluster_members(p, c);
                    let is_doc = cluster_is_doc(p.seed, c, p.doc_frac);
                    for m in 0..members {
                        sets.push(member_set(p.seed, c, m, is_doc));
                    }
                    if is_doc {
                        docs += members;
                    }
                }
                (sets, docs)
            }
        })
        .collect();
    let parts = pool.scope(tasks);
    let mut sets = Vec::with_capacity(p.n_sets);
    let mut docs = 0usize;
    for (part, d) in parts {
        sets.extend(part);
        docs += d;
    }
    debug_assert_eq!(sets.len(), p.n_sets);
    // Held-out queries: extra members (index ≥ cluster_size) of clusters
    // 0, 1, …, wrapping when n_queries > n_clusters.
    let queries = (0..p.n_queries)
        .map(|qi| {
            let c = qi % n_clusters;
            let m = p.cluster_size + qi / n_clusters;
            member_set(p.seed, c, m, cluster_is_doc(p.seed, c, p.doc_frac))
        })
        .collect();
    Corpus { sets, queries, docs }
}

/// How many members of cluster `c` are database sets (the last cluster may
/// be ragged).
fn cluster_members(p: &CorpusParams, c: usize) -> usize {
    (p.n_sets - c * p.cluster_size).min(p.cluster_size)
}

/// Member `m` of cluster `c` — deterministic in `(seed, cluster, member)`.
pub fn member_set(seed: u64, cluster: usize, member: usize, is_doc: bool) -> Vec<u32> {
    debug_assert!(member < 1 << 20, "member index overflows the stream split");
    if is_doc {
        doc_member(seed, cluster, member)
    } else {
        synth_member(seed, cluster, member)
    }
}

/// Synthetic member: the cluster's dense low-id base block (stride 37 with
/// length 64, so adjacent clusters overlap in 27 ids — graded similarity),
/// each id kept w.p. 0.95, plus 6 noise ids from a high disjoint range.
/// Same-cluster pairs land at J ≈ 0.75, adjacent clusters at ≈ 0.2.
fn synth_member(seed: u64, cluster: usize, member: usize) -> Vec<u32> {
    let mut rng = Xoshiro256::stream(seed ^ SYNTH_SALT, ((cluster as u64) << 20) | member as u64);
    let base_start = (cluster as u32 % 0x0010_0000).wrapping_mul(37);
    let mut out: Vec<u32> = (base_start..base_start + 64)
        .filter(|_| rng.bernoulli(0.95))
        .collect();
    for _ in 0..6 {
        out.push(0x4000_0000 | (rng.next_u32() & 0x3FFF_FFFF));
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Shingled-document member: the cluster's base text (24 seeded words),
/// with 2 word positions rewritten per member, reduced to 5-byte shingles.
/// Same-cluster pairs land at J ≈ 0.6–0.7.
fn doc_member(seed: u64, cluster: usize, member: usize) -> Vec<u32> {
    // Base words come from a reserved member stream so no real member can
    // collide with it (member < 2^20 is asserted upstream).
    let mut base_rng = Xoshiro256::stream(seed ^ DOC_SALT, ((cluster as u64) << 20) | 0xF_FFFF);
    let mut words: Vec<String> = (0..24).map(|_| random_word(&mut base_rng)).collect();
    let mut rng = Xoshiro256::stream(seed ^ DOC_SALT, ((cluster as u64) << 20) | member as u64);
    for _ in 0..2 {
        let pos = rng.range(0, words.len());
        words[pos] = random_word(&mut rng);
    }
    byte_shingles(&words.join(" "), 5)
}

fn random_word(rng: &mut Xoshiro256) -> String {
    let len = rng.range(3, 9);
    (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
}

/// A sustained-phase insert: a random set in a high id range, unrelated to
/// every cluster (it can enter a query's brute-force top-k only by beating
/// genuine neighbours, which a random set cannot). Pure in `(seed, i)`, so
/// the oracle regenerates phase-2 inserts exactly.
pub fn extra_set(seed: u64, i: u64) -> Vec<u32> {
    let mut rng = Xoshiro256::stream(seed ^ EXTRA_SALT, i);
    let mut out: Vec<u32> = (0..60).map(|_| 0x8000_0000 | (rng.next_u32() >> 1)).collect();
    out.sort_unstable();
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::estimators::jaccard_sorted;

    #[test]
    fn deterministic_and_sized() {
        let p = CorpusParams {
            n_sets: 100,
            n_queries: 7,
            cluster_size: 12,
            doc_frac: 0.5,
            seed: 9,
        };
        let a = generate(&p, 3);
        let b = generate(&p, 1);
        assert_eq!(a.sets, b.sets, "corpus must not depend on worker count");
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.docs, b.docs);
        assert_eq!(a.sets.len(), 100);
        assert_eq!(a.queries.len(), 7);
        assert!(a.docs > 0 && a.docs < 100, "both kinds present: {}", a.docs);
        for s in &a.sets {
            assert!(!s.is_empty());
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted dedup");
        }
    }

    #[test]
    fn cluster_structure_gives_graded_similarity() {
        let seed = 4;
        for is_doc in [false, true] {
            // Same cluster: near neighbours.
            let a = member_set(seed, 3, 0, is_doc);
            let b = member_set(seed, 3, 1, is_doc);
            let j_same = jaccard_sorted(&a, &b);
            assert!(j_same > 0.4, "same-cluster J too low ({is_doc}): {j_same}");
            // Distant cluster: near-zero similarity.
            let c = member_set(seed, 900, 0, is_doc);
            let j_far = jaccard_sorted(&a, &c);
            assert!(j_far < 0.05, "far-cluster J too high ({is_doc}): {j_far}");
            assert!(j_same > j_far);
        }
        // Adjacent synthetic clusters overlap, but less than co-members.
        let a = member_set(seed, 3, 0, false);
        let d = member_set(seed, 4, 0, false);
        let j_adj = jaccard_sorted(&a, &d);
        assert!(j_adj > 0.02 && j_adj < 0.45, "adjacent J: {j_adj}");
    }

    #[test]
    fn queries_are_held_out_near_neighbours() {
        let p = CorpusParams {
            n_sets: 60,
            n_queries: 3,
            cluster_size: 12,
            doc_frac: 0.0,
            seed: 11,
        };
        let c = generate(&p, 2);
        // Query qi targets cluster qi: its best database match is strong.
        for (qi, q) in c.queries.iter().enumerate() {
            let best = c
                .sets
                .iter()
                .map(|s| jaccard_sorted(q, s))
                .fold(0.0f64, f64::max);
            assert!(best > 0.4, "query {qi} has no near neighbour: {best}");
            // Held out: no database set is identical.
            assert!(c.sets.iter().all(|s| s != q));
        }
    }

    #[test]
    fn extra_sets_stay_out_of_cluster_space() {
        let e = extra_set(7, 123);
        assert!(e.windows(2).all(|w| w[0] < w[1]));
        assert!(e.iter().all(|&x| x >= 0x8000_0000));
        assert_eq!(e, extra_set(7, 123));
        assert_ne!(e, extra_set(7, 124));
    }
}
