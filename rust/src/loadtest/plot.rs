//! `mixtab loadtest --plot`: first-party SVG rendering of the results
//! store — the perf trajectory of record as a picture.
//!
//! Two stacked panels over run index (oldest → newest, the store's
//! order): throughput (load-phase and mixed-phase QPS) on top, recall@k
//! below on a fixed 0–1 axis so regressions read as absolute drops, not
//! rescaled wiggles. Pure string assembly — no graphics dependency, and
//! the output is deterministic in the input rows, so tests can assert on
//! structure.

use super::store::RunRecord;
use crate::util::error::{Context, Result};

/// Canvas and panel geometry (pixels).
const WIDTH: usize = 900;
const PANEL_H: usize = 200;
const GAP: usize = 46;
const MARGIN_L: usize = 72;
const MARGIN_R: usize = 24;
const MARGIN_T: usize = 34;
const MARGIN_B: usize = 40;

const HEIGHT: usize = MARGIN_T + PANEL_H + GAP + PANEL_H + MARGIN_B;

/// Series colours: load QPS, mixed QPS, recall.
const C_LOAD: &str = "#1f77b4";
const C_MIXED: &str = "#d62728";
const C_RECALL: &str = "#2ca02c";

/// One panel's plotting area.
struct Panel {
    top: usize,
    y_min: f64,
    y_max: f64,
}

impl Panel {
    fn x(&self, i: usize, n: usize) -> f64 {
        let usable = (WIDTH - MARGIN_L - MARGIN_R) as f64;
        // A single run plots mid-panel rather than dividing by zero.
        let frac = if n <= 1 {
            0.5
        } else {
            i as f64 / (n - 1) as f64
        };
        MARGIN_L as f64 + frac * usable
    }

    fn y(&self, v: f64) -> f64 {
        let span = (self.y_max - self.y_min).max(f64::MIN_POSITIVE);
        let frac = ((v - self.y_min) / span).clamp(0.0, 1.0);
        self.top as f64 + (1.0 - frac) * PANEL_H as f64
    }
}

/// Render the store's rows (oldest first, as [`super::store::load`]
/// returns them) to a standalone SVG document.
pub fn render(records: &[RunRecord]) -> Result<String> {
    crate::ensure!(
        !records.is_empty(),
        "nothing to plot: the results store has no rows"
    );
    let n = records.len();

    let qps_max = records
        .iter()
        .flat_map(|r| [r.load_qps, r.mixed_qps])
        .fold(0.0f64, f64::max)
        .max(1.0);
    let qps = Panel {
        top: MARGIN_T,
        y_min: 0.0,
        y_max: qps_max * 1.08,
    };
    let recall = Panel {
        top: MARGIN_T + PANEL_H + GAP,
        y_min: 0.0,
        y_max: 1.0,
    };

    let mut svg = String::with_capacity(8192);
    svg.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{WIDTH}\" height=\"{HEIGHT}\" \
         viewBox=\"0 0 {WIDTH} {HEIGHT}\" font-family=\"monospace\" font-size=\"12\">\n"
    ));
    svg.push_str("<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n");

    panel_frame(&mut svg, &qps, "throughput (ops/s)", &fmt_qps);
    panel_frame(&mut svg, &recall, "recall@k", &|v| format!("{v:.2}"));

    polyline(&mut svg, &qps, records, n, C_LOAD, |r| r.load_qps);
    polyline(&mut svg, &qps, records, n, C_MIXED, |r| r.mixed_qps);
    polyline(&mut svg, &recall, records, n, C_RECALL, |r| r.recall_at_k);

    // X labels: run index, thinned to at most ~12 ticks.
    let step = (n / 12).max(1);
    let label_y = recall.top + PANEL_H + 18;
    for i in (0..n).step_by(step) {
        let x = qps.x(i, n);
        svg.push_str(&format!(
            "<text x=\"{x:.1}\" y=\"{label_y}\" text-anchor=\"middle\" fill=\"#444\">{i}</text>\n"
        ));
    }
    svg.push_str(&format!(
        "<text x=\"{}\" y=\"{}\" text-anchor=\"middle\" fill=\"#444\">run (oldest \u{2192} newest; \
         last: {})</text>\n",
        WIDTH / 2,
        label_y + 18,
        records[n - 1].git_sha
    ));

    // Legend, top-right of the QPS panel.
    let lx = WIDTH - MARGIN_R - 170;
    for (j, (color, name)) in [(C_LOAD, "load qps"), (C_MIXED, "mixed qps")]
        .iter()
        .enumerate()
    {
        let y = MARGIN_T + 14 + j * 16;
        svg.push_str(&format!(
            "<rect x=\"{lx}\" y=\"{}\" width=\"10\" height=\"10\" fill=\"{color}\"/>\n\
             <text x=\"{}\" y=\"{}\" fill=\"#222\">{name}</text>\n",
            y - 9,
            lx + 16,
            y
        ));
    }

    svg.push_str("</svg>\n");
    Ok(svg)
}

/// Render and write to `path`.
pub fn write_svg(path: &str, records: &[RunRecord]) -> Result<()> {
    let svg = render(records)?;
    std::fs::write(path, svg).with_context(|| format!("write plot '{path}'"))
}

fn fmt_qps(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.0}k", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

/// Panel chrome: title, border, horizontal gridlines with y labels.
fn panel_frame(svg: &mut String, p: &Panel, title: &str, fmt: &dyn Fn(f64) -> String) {
    svg.push_str(&format!(
        "<text x=\"{MARGIN_L}\" y=\"{}\" fill=\"#000\" font-weight=\"bold\">{title}</text>\n",
        p.top - 8
    ));
    svg.push_str(&format!(
        "<rect x=\"{MARGIN_L}\" y=\"{}\" width=\"{}\" height=\"{PANEL_H}\" fill=\"none\" \
         stroke=\"#999\"/>\n",
        p.top,
        WIDTH - MARGIN_L - MARGIN_R
    ));
    for tick in 0..=4 {
        let v = p.y_min + (p.y_max - p.y_min) * tick as f64 / 4.0;
        let y = p.y(v);
        if tick > 0 && tick < 4 {
            svg.push_str(&format!(
                "<line x1=\"{MARGIN_L}\" y1=\"{y:.1}\" x2=\"{}\" y2=\"{y:.1}\" \
                 stroke=\"#e0e0e0\"/>\n",
                WIDTH - MARGIN_R
            ));
        }
        svg.push_str(&format!(
            "<text x=\"{}\" y=\"{:.1}\" text-anchor=\"end\" fill=\"#444\">{}</text>\n",
            MARGIN_L - 6,
            y + 4.0,
            fmt(v)
        ));
    }
}

/// One series: a polyline through every run plus a dot per point (a
/// single-run store still shows its dot).
fn polyline(
    svg: &mut String,
    p: &Panel,
    records: &[RunRecord],
    n: usize,
    color: &str,
    value: impl Fn(&RunRecord) -> f64,
) {
    let points: Vec<String> = records
        .iter()
        .enumerate()
        .map(|(i, r)| format!("{:.1},{:.1}", p.x(i, n), p.y(value(r))))
        .collect();
    if n > 1 {
        svg.push_str(&format!(
            "<polyline points=\"{}\" fill=\"none\" stroke=\"{color}\" stroke-width=\"1.5\"/>\n",
            points.join(" ")
        ));
    }
    for pt in &points {
        let (x, y) = pt.split_once(',').expect("formatted above");
        svg.push_str(&format!(
            "<circle cx=\"{x}\" cy=\"{y}\" r=\"2.5\" fill=\"{color}\"/>\n"
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadtest::store;

    fn row(i: u64, load_qps: f64, mixed_qps: f64, recall: f64) -> RunRecord {
        RunRecord {
            schema: store::LOADTEST_SCHEMA.to_string(),
            git_sha: format!("sha{i}"),
            unix_ts: 1_700_000_000 + i,
            quick: true,
            config: "spec=x".into(),
            sets: 100,
            docs: 10,
            queries: 8,
            k: 5,
            clients: 2,
            window: 4,
            mix_ops: 50,
            query_frac: 0.5,
            load_qps,
            mixed_qps,
            recall_at_k: recall,
            p50_us: 10.0,
            p99_us: 20.0,
            p999_us: 30.0,
            peak_rss_mb: 64.0,
            server_inserts: 100,
            server_queries: 8,
            server_errors: 0,
            churn_cycles: 0,
            server_deletes: 0,
            mean_candidates: 0.0,
        }
    }

    #[test]
    fn empty_store_is_an_error() {
        assert!(render(&[]).is_err());
    }

    #[test]
    fn renders_trajectory() {
        let rows: Vec<RunRecord> = (0..5)
            .map(|i| row(i, 1000.0 + i as f64 * 100.0, 500.0, 0.9))
            .collect();
        let svg = render(&rows).unwrap();
        assert!(svg.starts_with("<svg "));
        assert!(svg.trim_end().ends_with("</svg>"));
        assert_eq!(svg.matches("<polyline").count(), 3, "load, mixed, recall");
        // One dot per run per series.
        assert_eq!(svg.matches("<circle").count(), 15);
        assert!(svg.contains("recall@k"));
        assert!(svg.contains("sha4"), "newest sha labels the x axis");
    }

    #[test]
    fn single_run_renders_dots_without_lines() {
        let svg = render(&[row(0, 2000.0, 900.0, 0.8)]).unwrap();
        assert_eq!(svg.matches("<polyline").count(), 0);
        assert_eq!(svg.matches("<circle").count(), 3);
    }

    #[test]
    fn deterministic_output() {
        let rows = vec![row(0, 1.0, 2.0, 0.5), row(1, 3.0, 4.0, 0.6)];
        assert_eq!(render(&rows).unwrap(), render(&rows).unwrap());
    }
}
