//! Sampled brute-force recall oracle.
//!
//! Recall@k is measured over the corpus's held-out queries only — an
//! exhaustive all-pairs oracle at 10⁶ sets is ~10¹² Jaccard evaluations,
//! while `n_queries` brute-force scans are `n_queries × n_sets` and finish
//! in seconds on a thread pool (DESIGN.md §3.5). The database handed here
//! must be exactly what the server holds: the generated corpus plus the
//! regenerated sustained-phase inserts, id-aligned with the server's ids.

use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::PipelinedClient;
use crate::lsh::metrics::{recall_at_k, topk_ground_truth_batch};
use crate::util::error::{Context, Result};
use crate::util::threadpool::ThreadPool;
use std::net::SocketAddr;

/// Outcome of [`measure_recall`].
#[derive(Debug, Clone)]
pub struct RecallEval {
    /// Mean recall@k over evaluated queries (NaN when none evaluated).
    pub mean_recall: f64,
    /// Queries with non-empty brute-force truth.
    pub evaluated: usize,
    /// Queries skipped because they had no genuine neighbour (J > 0).
    pub skipped: usize,
}

/// Query the live server with every held-out query, then score the
/// retrieved candidates against brute-force top-k truth computed over
/// `db` (where `db[i]` is the set the server holds under id `i`; empty
/// slots are fine — they can never enter the truth).
pub fn measure_recall(
    addr: SocketAddr,
    db: &[Vec<u32>],
    queries: &[Vec<u32>],
    k: usize,
    workers: usize,
) -> Result<RecallEval> {
    // Retrieve live candidates first: one pipelined connection, the query
    // index as the rid, so out-of-order responses land in their slots.
    let mut client = PipelinedClient::connect(addr)?;
    for (qi, q) in queries.iter().enumerate() {
        client.send_with_rid(
            &Request::LshQuery {
                set: q.clone(),
                scheme: None,
            },
            qi as u64,
        )?;
    }
    let mut retrieved: Vec<Option<Vec<u32>>> = vec![None; queries.len()];
    for _ in 0..queries.len() {
        let (rid, resp) = client.recv()?;
        let rid = rid.context("untagged oracle response")? as usize;
        let slot = retrieved.get_mut(rid).context("oracle rid out of range")?;
        match resp {
            Response::Candidates { mut ids } => {
                // The index returns sorted merged ids already; enforce the
                // invariant here so recall_at_k's binary search is safe
                // even if a future server relaxes it.
                ids.sort_unstable();
                ids.dedup();
                *slot = Some(ids);
            }
            Response::Error { message } => crate::bail!("oracle query failed: {message}"),
            other => crate::bail!("unexpected oracle response: {other:?}"),
        }
    }

    let pool = ThreadPool::new(workers.max(1));
    let truth = topk_ground_truth_batch(&pool, db, queries, k);

    let (mut sum, mut evaluated, mut skipped) = (0.0f64, 0usize, 0usize);
    for (slot, t) in retrieved.iter().zip(&truth) {
        let ids = slot.as_ref().context("oracle query went unanswered")?;
        match recall_at_k(ids, t) {
            Some(r) => {
                sum += r;
                evaluated += 1;
            }
            None => skipped += 1,
        }
    }
    Ok(RecallEval {
        mean_recall: if evaluated == 0 {
            f64::NAN
        } else {
            sum / evaluated as f64
        },
        evaluated,
        skipped,
    })
}
