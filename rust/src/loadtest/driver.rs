//! Concurrent pipelined client driver for the real TCP coordinator.
//!
//! [`drive`] fans a deterministic op stream across `clients` concurrent
//! [`PipelinedClient`] connections: global op `i` is handled by client
//! `i % clients`, each connection keeps up to `window` tagged requests in
//! flight (the op's global index doubles as its `rid`), and every response
//! is timed from its send. This is the loadtest's closed-loop engine and —
//! via `benchsuite::coordinator_service` — the bench suite's TCP op-rate
//! measurement, so both trajectories measure with the same mechanics.

use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::PipelinedClient;
use crate::stats::Summary;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::time::Instant;

/// Aggregate outcome of one [`drive`] call.
#[derive(Debug, Clone)]
pub struct DriveStats {
    /// Ops answered with a non-error response.
    pub ok: u64,
    /// Ops answered with a wire error (still counted as completed).
    pub errors: u64,
    /// Wall time from the first request actually sent to the last
    /// response received. Connection setup is excluded on purpose: the
    /// old connect-anchored clock billed TCP handshakes to the server's
    /// op rate, deflating QPS for short runs with many clients.
    pub wall_secs: f64,
    /// Closed-loop per-op latency in microseconds, send to receive —
    /// includes client-side pipelining delay, which is what a real
    /// windowed client experiences.
    pub latency_us: Summary,
}

impl DriveStats {
    /// Completed ops (ok + errors).
    pub fn total(&self) -> u64 {
        self.ok + self.errors
    }

    /// Completed ops per second of wall time.
    pub fn qps(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.total() as f64 / self.wall_secs
    }
}

/// Drive `ops` requests at a running server from `clients` concurrent
/// pipelined connections with a per-connection window of `window`
/// in-flight ops. `gen` must be a pure function of the global op index —
/// it is called once per op, on the owning client's thread.
pub fn drive(
    addr: SocketAddr,
    clients: usize,
    ops: usize,
    window: usize,
    gen: impl Fn(usize) -> Request + Sync,
) -> Result<DriveStats> {
    assert!(clients >= 1 && window >= 1, "need ≥1 client and window");
    let gen = &gen;
    let t0 = Instant::now();
    let results: Vec<Result<(u64, u64, Summary, Option<Instant>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|cl| s.spawn(move || client_loop(addr, cl, clients, ops, window, gen)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("driver client thread panicked"))
            .collect()
    });
    let end = Instant::now();
    let (mut ok, mut errors) = (0u64, 0u64);
    let mut latency_us = Summary::new();
    let mut first_send: Option<Instant> = None;
    for r in results {
        let (o, e, lat, first) = r?;
        ok += o;
        errors += e;
        for &v in lat.values() {
            latency_us.add(v);
        }
        if let Some(t) = first {
            first_send = Some(first_send.map_or(t, |cur| cur.min(t)));
        }
    }
    // Anchor the clock at the earliest send across clients; a drive that
    // sent nothing (ops == 0) falls back to the call-entry clock.
    let wall_secs = end.duration_since(first_send.unwrap_or(t0)).as_secs_f64();
    Ok(DriveStats {
        ok,
        errors,
        wall_secs,
        latency_us,
    })
}

fn client_loop(
    addr: SocketAddr,
    cl: usize,
    clients: usize,
    ops: usize,
    window: usize,
    gen: &(impl Fn(usize) -> Request + Sync),
) -> Result<(u64, u64, Summary, Option<Instant>)> {
    let mut next = cl;
    if next >= ops {
        return Ok((0, 0, Summary::new(), None));
    }
    let mut client = PipelinedClient::connect(addr)?;
    let mut inflight: HashMap<u64, Instant> = HashMap::with_capacity(window);
    let (mut ok, mut errors) = (0u64, 0u64);
    let mut lat = Summary::new();
    let mut first_send: Option<Instant> = None;
    loop {
        while next < ops && inflight.len() < window {
            let req = gen(next);
            first_send.get_or_insert_with(Instant::now);
            client.send_with_rid(&req, next as u64)?;
            inflight.insert(next as u64, Instant::now());
            next += clients;
        }
        if inflight.is_empty() {
            break;
        }
        let (rid, resp) = client.recv()?;
        let rid = rid.context("untagged response on a pipelined connection")?;
        match inflight.remove(&rid) {
            Some(t) => lat.add(t.elapsed().as_secs_f64() * 1e6),
            None => crate::bail!("response for unknown rid {rid}"),
        }
        if matches!(resp, Response::Error { .. }) {
            errors += 1;
        } else {
            ok += 1;
        }
    }
    Ok((ok, errors, lat, first_send))
}
