//! Human-readable loadtest reporting + process peak-RSS measurement.

use super::store::{MetricDelta, RunRecord};
use crate::util::bench::fmt_rate;

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`). The server runs in-process, so this covers the
/// index, sketch store, and corpus together. Returns 0 where procfs is
/// unavailable (non-Linux) — recorded as-is rather than guessed.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Print one run the way `mixtab bench` prints cases.
pub fn print_run(r: &RunRecord) {
    println!("loadtest run @ {} ({})", r.git_sha, if r.quick { "quick" } else { "full" });
    println!("  config        {}", r.config);
    println!(
        "  corpus        {} sets ({} shingled docs), {} queries, k={}",
        r.sets, r.docs, r.queries, r.k
    );
    println!(
        "  drive         {} clients x window {}, {} mixed ops ({:.0}% queries)",
        r.clients,
        r.window,
        r.mix_ops,
        r.query_frac * 100.0
    );
    println!("  load_qps      {}", fmt_rate(r.load_qps));
    println!("  mixed_qps     {}", fmt_rate(r.mixed_qps));
    println!("  recall@{}     {:.4}", r.k, r.recall_at_k);
    println!(
        "  latency       p50 {:.0} us | p99 {:.0} us | p999 {:.0} us",
        r.p50_us, r.p99_us, r.p999_us
    );
    println!("  peak_rss      {:.1} MB", r.peak_rss_mb);
    println!(
        "  server        {} inserts, {} queries, {} errors",
        r.server_inserts, r.server_queries, r.server_errors
    );
    if r.churn_cycles > 0 {
        println!(
            "  churn         {} cycles, {} server deletes, mean candidates {:.1}",
            r.churn_cycles, r.server_deletes, r.mean_candidates
        );
    }
}

/// Print a `--compare` diff table between two runs.
pub fn print_compare(baseline: &RunRecord, current: &RunRecord, deltas: &[MetricDelta]) {
    println!(
        "baseline {} ({}) vs current {} ({})",
        baseline.git_sha,
        baseline.unix_ts,
        current.git_sha,
        current.unix_ts
    );
    if baseline.config != current.config {
        println!("  NOTE: configs differ");
        println!("    baseline: {}", baseline.config);
        println!("    current:  {}", current.config);
    }
    for d in deltas {
        let change = d.rel_change();
        let arrow = if change.abs() < 1e-12 {
            "="
        } else if (change > 0.0) == d.higher_is_better {
            "+"
        } else {
            "-"
        };
        println!(
            "  {arrow} {:<12} {:>14.4} -> {:>14.4}  ({:+.2}%)",
            d.name,
            d.baseline,
            d.current,
            change * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_rss_is_sane_on_linux() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // A test process has touched at least a megabyte.
            assert!(rss > 1 << 20, "VmHWM {rss}");
        }
    }
}
