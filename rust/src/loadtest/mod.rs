//! `mixtab loadtest`: the million-set recall/QPS harness.
//!
//! One run drives the *real* TCP coordinator end to end:
//!
//! 1. generate a clustered corpus ([`corpus`]) of synthetic sets and
//!    shingled documents,
//! 2. load it through concurrent pipelined clients ([`driver`]) — the
//!    insert-only **load phase**,
//! 3. run a sustained **mixed phase** of interleaved inserts and queries
//!    whose op stream is a pure function of the seed,
//! 4. optionally run **churn cycles** (`churn_cycles > 0`): each cycle
//!    deletes half the mixed-phase ids, updates the other half with fresh
//!    sets, compacts, then probes the held-out queries — bailing if any
//!    deleted id comes back as a candidate or if candidate sets grow
//!    across cycles (the duplicate-insert posting leak's signature),
//! 5. score recall@k for held-out queries against a sampled brute-force
//!    oracle ([`oracle`]) over exactly what the server holds — including
//!    every churn delete/update,
//! 6. append one [`store::RunRecord`] row — git sha, timestamp, full
//!    config, QPS, tail latency, recall, peak RSS — to the append-only
//!    results CSV ([`store`]), the repo's perf trajectory of record.
//!
//! Every input derives from `(seed, index)`, so a run is reproducible
//! bit-for-bit in workload terms; recall@k in particular is deterministic
//! given the config, which is what lets CI gate it tightly while gating
//! throughput loosely (see [`store::gate`]).

pub mod corpus;
pub mod driver;
pub mod oracle;
pub mod plot;
pub mod report;
pub mod store;

use crate::coordinator::config::CoordinatorConfig;
use crate::coordinator::request::Request;
use crate::coordinator::server::Server;
use crate::coordinator::service::Coordinator;
use crate::hash::HashFamily;
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;
use crate::util::threadpool::default_parallelism;
use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Stream salt for the mixed-phase op coin flips.
const MIX_SALT: u64 = 0xA11C_E5ED;

/// Stream salt for churn-cycle replacement sets (offset by the cycle
/// index, so each cycle's updates carry genuinely new content).
const CHURN_SALT: u64 = 0x0C4A_B1E5;

/// How much the per-cycle mean candidate-set size may exceed cycle 0's
/// before the churn phase fails the run. The pre-fix index grew postings
/// on every delete/re-insert cycle, so this gate is what would have
/// caught the bug.
const CHURN_CANDIDATE_GROWTH: f64 = 1.10;

/// All knobs of one loadtest run.
#[derive(Debug, Clone)]
pub struct LoadtestConfig {
    /// Database sets loaded in the load phase.
    pub sets: usize,
    /// Held-out queries scored for recall@k.
    pub queries: usize,
    /// Recall cutoff (must stay below `cluster_size` so truth is
    /// dominated by genuine same-cluster neighbours).
    pub k: usize,
    /// Concurrent pipelined client connections.
    pub clients: usize,
    /// Per-connection in-flight window.
    pub window: usize,
    /// Sustained-phase op count (inserts + queries).
    pub mix_ops: usize,
    /// Fraction of sustained-phase ops that are queries.
    pub query_frac: f64,
    /// Corpus cluster size (see [`corpus::CorpusParams`]).
    pub cluster_size: usize,
    /// Fraction of shingled-doc clusters.
    pub doc_frac: f64,
    /// Hash family under test (the paper's variable).
    pub family: HashFamily,
    /// Stored-sketch size (memory per set in the server's sketch store).
    pub oph_k: usize,
    /// LSH structural parameters: `lsh_l` bands of `lsh_k` bins.
    pub lsh_k: usize,
    pub lsh_l: usize,
    /// Index shards for the default scheme.
    pub shards: usize,
    /// Cross-connection op batch size (0 = off).
    pub op_batch: usize,
    /// Server request-worker pool width.
    pub request_workers: usize,
    /// Churn cycles after the mixed phase (0 = churn off). Each cycle
    /// deletes/updates every mixed-phase id, compacts, and probes.
    pub churn_cycles: usize,
    /// Root seed for corpus + op stream.
    pub seed: u64,
    /// Threads for corpus generation and the brute-force oracle.
    pub oracle_workers: usize,
    /// Whether this is the scaled-down CI shape (recorded in the row;
    /// quick and full runs are never gated against each other).
    pub quick: bool,
}

impl Default for LoadtestConfig {
    /// The full nightly shape: ≥1M sets against the coordinator.
    fn default() -> Self {
        Self {
            sets: 1_000_000,
            queries: 64,
            k: 10,
            clients: 8,
            window: 32,
            mix_ops: 200_000,
            query_frac: 0.5,
            cluster_size: 12,
            doc_frac: 0.5,
            family: HashFamily::MixedTab,
            oph_k: 64,
            lsh_k: 8,
            lsh_l: 12,
            shards: 2,
            op_batch: 32,
            request_workers: 4,
            churn_cycles: 0,
            seed: 42,
            oracle_workers: default_parallelism(),
            quick: false,
        }
    }
}

impl LoadtestConfig {
    /// The CI smoke shape: ~50k sets, same structure, minutes not hours.
    pub fn quick() -> Self {
        Self {
            sets: 50_000,
            queries: 32,
            mix_ops: 20_000,
            clients: 4,
            window: 16,
            quick: true,
            ..Self::default()
        }
    }

    /// The coordinator the run serves against.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            listen: "127.0.0.1:0".into(),
            family: self.family,
            seed: self.seed,
            oph_k: self.oph_k,
            lsh_k: self.lsh_k,
            lsh_l: self.lsh_l,
            lsh_shards: self.shards,
            workers: 2,
            request_workers: self.request_workers,
            op_batch: self.op_batch,
            enable_pjrt: false,
            ..CoordinatorConfig::default()
        }
    }

    /// The run's identity string, recorded in its results row. Contains
    /// the full sketch spec (commas and all — the store's CSV quoting is
    /// load-bearing) plus every workload knob that shapes the measurement.
    pub fn config_string(&self) -> String {
        let spec = self.coordinator_config().sketch_spec();
        let churn = if self.churn_cycles > 0 {
            format!(" churn={}", self.churn_cycles)
        } else {
            String::new()
        };
        format!(
            "spec={spec} lsh={}x{} shards={} op_batch={} request_workers={} \
             corpus(cluster={},doc_frac={}) seed={}{churn}",
            self.lsh_k,
            self.lsh_l,
            self.shards,
            self.op_batch,
            self.request_workers,
            self.cluster_size,
            self.doc_frac,
            self.seed,
        )
    }

    fn corpus_params(&self) -> corpus::CorpusParams {
        corpus::CorpusParams {
            n_sets: self.sets,
            n_queries: self.queries,
            cluster_size: self.cluster_size,
            doc_frac: self.doc_frac,
            seed: self.seed,
        }
    }

    /// The deterministic sustained-phase op for global index `i`. Pure in
    /// `(seed, i)`: the oracle replays the same stream to reconstruct the
    /// server's final database without talking to the driver.
    pub fn mixed_op(&self, i: usize) -> Request {
        let mut rng = Xoshiro256::stream(self.seed ^ MIX_SALT, i as u64);
        if rng.bernoulli(self.query_frac) {
            let target = rng.range(0, self.sets);
            let cluster = target / self.cluster_size;
            Request::LshQuery {
                set: corpus::member_set(
                    self.seed,
                    cluster,
                    target % self.cluster_size,
                    corpus::cluster_is_doc(self.seed, cluster, self.doc_frac),
                ),
                scheme: None,
            }
        } else {
            Request::LshInsert {
                id: (self.sets + i) as u32,
                set: corpus::extra_set(self.seed, i as u64),
                scheme: None,
            }
        }
    }
}

/// Run one loadtest end to end against an in-process server and return
/// the finished row (not yet persisted — the CLI decides where it goes).
pub fn run(cfg: &LoadtestConfig) -> Result<store::RunRecord> {
    run_at(cfg, None)
}

/// Like [`run`], but `external` points the phases at an already-running
/// server (plain or router) instead of spawning one in-process. The
/// workload, oracle and scoring are identical — this is how the harness
/// measures a cluster: point it at the router and the row records the
/// cluster's end-to-end recall/QPS. Server-side counters come from the
/// target's `stats` op (the router snapshot exposes the same top-level
/// keys as the single-host one).
pub fn run_at(cfg: &LoadtestConfig, external: Option<SocketAddr>) -> Result<store::RunRecord> {
    crate::ensure!(cfg.sets >= 1 && cfg.queries >= 1, "empty loadtest corpus");
    crate::ensure!(
        cfg.k < cfg.cluster_size,
        "k must stay below cluster_size for recall@k truth to be in-cluster"
    );
    crate::ensure!(
        (cfg.sets + cfg.mix_ops) <= u32::MAX as usize,
        "id space overflow: sets + mix_ops must fit u32"
    );

    println!(
        "loadtest: generating corpus ({} sets, {} queries, {} workers)",
        cfg.sets, cfg.queries, cfg.oracle_workers
    );
    let t = Instant::now();
    let corpus = corpus::generate(&cfg.corpus_params(), cfg.oracle_workers);
    println!(
        "loadtest: corpus ready in {:.1}s ({} shingled docs)",
        t.elapsed().as_secs_f64(),
        corpus.docs
    );

    let (server, metrics, addr) = match external {
        Some(addr) => {
            println!("loadtest: driving external server at {addr}");
            (None, None, addr)
        }
        None => {
            let coordinator = Arc::new(Coordinator::new(cfg.coordinator_config()));
            let metrics = Arc::clone(&coordinator.metrics);
            let server = Server::start(coordinator, "127.0.0.1:0")?;
            let addr: SocketAddr = server.addr();
            (Some(server), Some(metrics), addr)
        }
    };

    // Phase 1: load. Every corpus set inserted under its index as id.
    let sets_ref = &corpus.sets;
    let load = driver::drive(addr, cfg.clients, cfg.sets, cfg.window, |i| {
        Request::LshInsert {
            id: i as u32,
            set: sets_ref[i].clone(),
            scheme: None,
        }
    })?;
    crate::ensure!(
        load.errors == 0,
        "load phase saw {} wire errors (first run `mixtab serve` logs)",
        load.errors
    );
    println!(
        "loadtest: load phase {} inserts in {:.1}s ({})",
        load.ok,
        load.wall_secs,
        crate::util::bench::fmt_rate(load.qps())
    );

    // Phase 2: sustained mixed inserts + queries.
    let mixed = driver::drive(addr, cfg.clients, cfg.mix_ops, cfg.window, |i| {
        cfg.mixed_op(i)
    })?;
    crate::ensure!(
        mixed.errors == 0,
        "mixed phase saw {} wire errors",
        mixed.errors
    );
    println!(
        "loadtest: mixed phase {} ops in {:.1}s ({})",
        mixed.ok,
        mixed.wall_secs,
        crate::util::bench::fmt_rate(mixed.qps())
    );

    let docs = corpus.docs;
    let corpus::Corpus { sets: mut db, queries, .. } = corpus;

    // The mutable tail of the corpus: every mixed-phase insert,
    // regenerated from the pure op stream. Churn cycles mutate this view
    // in lockstep with the server so the oracle scores exactly what the
    // server holds at the end.
    let mut extras: Vec<Extra> = (0..cfg.mix_ops)
        .filter_map(|i| match cfg.mixed_op(i) {
            Request::LshInsert { id, set, .. } => Some(Extra {
                slot: cfg.sets + i,
                id,
                set,
                alive: true,
            }),
            _ => None,
        })
        .collect();

    // Phase 3 (optional): churn cycles — delete/update/compact/probe.
    let mean_candidates = if cfg.churn_cycles > 0 {
        let means = churn_phase(addr, cfg, &mut extras, &queries)?;
        means.last().copied().unwrap_or(0.0)
    } else {
        0.0
    };

    // Oracle database = exactly what the server now holds, id-aligned:
    // the corpus under ids 0..sets, plus each *live* mixed-phase insert
    // under id sets+i (query op slots and churn-deleted ids stay empty —
    // J=0 never enters the truth).
    db.resize(cfg.sets + cfg.mix_ops, Vec::new());
    for e in extras {
        if e.alive {
            db[e.slot] = e.set;
        }
    }
    let recall = oracle::measure_recall(addr, &db, &queries, cfg.k, cfg.oracle_workers)?;
    println!(
        "loadtest: recall@{} = {:.4} over {} queries ({} skipped)",
        cfg.k, recall.mean_recall, recall.evaluated, recall.skipped
    );

    // Server-side counters: straight off the metrics block in-process,
    // via the wire `stats` op when driving an external server.
    let (server_inserts, server_queries, server_errors, server_deletes) = match &metrics {
        Some(m) => (
            m.lsh_inserts.load(Ordering::Relaxed),
            m.lsh_queries.load(Ordering::Relaxed),
            m.errors.load(Ordering::Relaxed),
            m.lsh_deletes.load(Ordering::Relaxed),
        ),
        None => remote_counters(addr)?,
    };
    if let Some(server) = server {
        server.stop();
    }

    let (p50, p99, p999) = mixed.latency_us.tail_quantiles();
    Ok(store::RunRecord {
        schema: store::LOADTEST_SCHEMA.to_string(),
        git_sha: crate::util::bench::git_sha(),
        unix_ts: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0),
        quick: cfg.quick,
        config: cfg.config_string(),
        sets: cfg.sets as u64,
        docs: docs as u64,
        queries: cfg.queries as u64,
        k: cfg.k as u64,
        clients: cfg.clients as u64,
        window: cfg.window as u64,
        mix_ops: cfg.mix_ops as u64,
        query_frac: cfg.query_frac,
        load_qps: load.qps(),
        mixed_qps: mixed.qps(),
        recall_at_k: recall.mean_recall,
        p50_us: p50,
        p99_us: p99,
        p999_us: p999,
        peak_rss_mb: report::peak_rss_bytes() as f64 / (1024.0 * 1024.0),
        server_inserts,
        server_queries,
        server_errors,
        churn_cycles: cfg.churn_cycles as u64,
        server_deletes,
        mean_candidates,
    })
}

/// One mixed-phase insert, tracked through churn: where it lives in the
/// oracle db (`slot`), its wire id, and its current content/liveness.
struct Extra {
    slot: usize,
    id: u32,
    set: Vec<u32>,
    alive: bool,
}

/// The replacement set churn cycle `c` installs for `id`.
fn churn_set(seed: u64, cycle: usize, id: u32) -> Vec<u32> {
    corpus::extra_set(seed ^ CHURN_SALT.wrapping_add(cycle as u64), id as u64)
}

/// Run `cfg.churn_cycles` delete/update/compact/probe cycles against the
/// live server, mutating `extras` (the oracle's view) in lockstep.
/// Returns the per-cycle mean candidate-set size over the probe queries.
///
/// Each cycle alternates by `(position + cycle) % 2`: half the ids are
/// deleted, the other half updated with fresh content — so an id deleted
/// this cycle is re-inserted next cycle, exactly the delete→re-insert
/// shape that leaked postings before the index became an upsert. Two
/// in-run gates make the phase self-checking: a probe returning any
/// deleted id fails the run (stale candidates), and a cycle whose mean
/// candidate count exceeds cycle 0's by [`CHURN_CANDIDATE_GROWTH`] fails
/// the run (posting growth).
fn churn_phase(
    addr: SocketAddr,
    cfg: &LoadtestConfig,
    extras: &mut [Extra],
    queries: &[Vec<u32>],
) -> Result<Vec<f64>> {
    crate::ensure!(
        !extras.is_empty(),
        "churn needs mixed-phase inserts to delete/update (raise mix_ops or query_frac < 1)"
    );
    let mut means = Vec::with_capacity(cfg.churn_cycles);
    for c in 0..cfg.churn_cycles {
        // Every target id is distinct within a cycle, so fanning the
        // plan across clients/windows cannot reorder anything observable.
        let plan: Vec<Request> = extras
            .iter()
            .enumerate()
            .map(|(j, e)| {
                if (j + c) % 2 == 0 {
                    Request::LshDelete {
                        id: e.id,
                        scheme: None,
                    }
                } else {
                    Request::LshUpdate {
                        id: e.id,
                        set: churn_set(cfg.seed, c, e.id),
                        scheme: None,
                    }
                }
            })
            .collect();
        let plan_ref = &plan;
        let stats = driver::drive(addr, cfg.clients, plan.len(), cfg.window, |j| {
            plan_ref[j].clone()
        })?;
        crate::ensure!(
            stats.errors == 0,
            "churn cycle {c} saw {} wire errors",
            stats.errors
        );
        println!(
            "loadtest: churn cycle {c}: {} mutations in {:.1}s ({})",
            stats.ok,
            stats.wall_secs,
            crate::util::bench::fmt_rate(stats.qps())
        );
        // Mirror the plan onto the oracle's view.
        for (j, e) in extras.iter_mut().enumerate() {
            if (j + c) % 2 == 0 {
                e.alive = false;
            } else {
                e.alive = true;
                e.set = churn_set(cfg.seed, c, e.id);
            }
        }
        // Explicit compact: every cycle probes a rebuilt index, not a
        // tombstone backlog, so cycle-to-cycle numbers are comparable.
        let mut conn = crate::coordinator::server::PipelinedClient::connect(addr)?;
        let resp = crate::coordinator::cluster::client::roundtrip(
            &mut conn,
            &Request::Compact { scheme: None },
        )?;
        crate::ensure!(
            matches!(
                resp,
                crate::coordinator::request::Response::Compacted { .. }
            ),
            "churn compact answered {resp:?}"
        );
        let mean = probe_cycle(&mut conn, cfg, extras, queries, c)?;
        println!("loadtest: churn cycle {c}: mean candidates {mean:.1}");
        means.push(mean);
        crate::ensure!(
            mean <= means[0] * CHURN_CANDIDATE_GROWTH + 1e-9,
            "candidate sets grew across churn cycles: cycle 0 mean {:.2}, cycle {c} mean {mean:.2}",
            means[0]
        );
    }
    Ok(means)
}

/// Probe one churn cycle: pipeline every held-out query as both a plain
/// candidate query and a top-k re-rank, verify no deleted id surfaces in
/// either, and return the mean candidate-set size.
fn probe_cycle(
    conn: &mut crate::coordinator::server::PipelinedClient,
    cfg: &LoadtestConfig,
    extras: &[Extra],
    queries: &[Vec<u32>],
    cycle: usize,
) -> Result<f64> {
    use crate::coordinator::request::Response;
    use crate::util::error::Context as _;
    let dead: std::collections::HashSet<u32> = extras
        .iter()
        .filter(|e| !e.alive)
        .map(|e| e.id)
        .collect();
    for (qi, q) in queries.iter().enumerate() {
        conn.send_with_rid(
            &Request::LshQuery {
                set: q.clone(),
                scheme: None,
            },
            2 * qi as u64,
        )?;
        conn.send_with_rid(
            &Request::LshQueryTopK {
                set: q.clone(),
                k: cfg.k,
                scheme: None,
            },
            2 * qi as u64 + 1,
        )?;
    }
    let mut total = 0usize;
    for _ in 0..queries.len() * 2 {
        let (rid, resp) = conn.recv()?;
        let rid = rid.context("untagged churn probe response")?;
        match resp {
            Response::Candidates { ids } => {
                if let Some(stale) = ids.iter().find(|id| dead.contains(id)) {
                    crate::bail!(
                        "churn cycle {cycle}: deleted id {stale} returned as a candidate \
                         (probe rid {rid})"
                    );
                }
                total += ids.len();
            }
            Response::TopK { ids, scores } => {
                crate::ensure!(
                    ids.len() <= cfg.k && ids.len() == scores.len(),
                    "churn cycle {cycle}: malformed top-k answer (probe rid {rid})"
                );
                crate::ensure!(
                    scores.windows(2).all(|w| w[0] >= w[1]),
                    "churn cycle {cycle}: top-k scores not descending (probe rid {rid})"
                );
                if let Some(stale) = ids.iter().find(|id| dead.contains(id)) {
                    crate::bail!("churn cycle {cycle}: deleted id {stale} returned in top-k");
                }
            }
            Response::Error { message } => crate::bail!("churn probe failed: {message}"),
            other => crate::bail!("unexpected churn probe response: {other:?}"),
        }
    }
    Ok(total as f64 / queries.len() as f64)
}

/// Fetch `(lsh_inserts, lsh_queries, errors, lsh_deletes)` from an
/// external server's `stats` op. Both the single-host snapshot and the
/// router snapshot expose these as top-level keys; anything absent reads
/// as 0.
fn remote_counters(addr: SocketAddr) -> Result<(u64, u64, u64, u64)> {
    let mut conn = crate::coordinator::server::PipelinedClient::connect(addr)?;
    let resp = crate::coordinator::cluster::client::roundtrip(&mut conn, &Request::Stats)?;
    let crate::coordinator::request::Response::Stats { json } = resp else {
        crate::bail!("stats op answered with a non-stats response");
    };
    let count = |key: &str| {
        json.get(key)
            .and_then(|v| v.as_i64())
            .map(|n| n.max(0) as u64)
            .unwrap_or(0)
    };
    Ok((
        count("lsh_inserts"),
        count("lsh_queries"),
        count("errors"),
        count("lsh_deletes"),
    ))
}
