//! The loadtest result store: an append-only CSV, one row per run.
//!
//! CSV (not JSON) because the store is the *queryable perf trajectory of
//! record* — every row carries the git sha, timestamp, and full config
//! string, so `results.csv` loads straight into any spreadsheet/pandas
//! session and diffs with `mixtab loadtest --compare`. Quoting is handled
//! by [`crate::util::csv`]: the config string contains commas by
//! construction (`oph(k=64,...)`) and must round-trip exactly.
//!
//! The schema is versioned via the `schema` column ([`LOADTEST_SCHEMA`]);
//! readers look fields up *by header name*, so reordering or appending
//! columns in a later version keeps old files loadable, and a missing
//! column is a hard error rather than a silently-zero metric. The one
//! sanctioned exception: columns introduced by v2 (`churn_cycles`,
//! `server_deletes`, `mean_candidates`) default to zero when decoding a
//! row that *declares itself* v1 — committed floor baselines predate the
//! churn tier and must stay loadable and gateable.
//!
//! **QPS semantics** (v2, PR 9): `load_qps`/`mixed_qps` divide completed
//! ops by wall time measured from the *first request sent* to the last
//! response received ([`super::driver::DriveStats::wall_secs`]).
//! Connection setup is excluded; rows older than this PR anchored the
//! clock at connect and so read slightly low for short many-client runs.

use crate::util::csv;
use crate::util::error::{Context, Result};
use std::fmt;
use std::io::Write as _;
use std::path::Path;

/// Current row-schema identifier, recorded in every row.
pub const LOADTEST_SCHEMA: &str = "mixtab-loadtest-v2";

/// The pre-churn schema: same columns minus `churn_cycles`,
/// `server_deletes`, `mean_candidates`. Still decodable (the new fields
/// default to zero) and still a legal gate baseline.
pub const LOADTEST_SCHEMA_V1: &str = "mixtab-loadtest-v1";

/// Column names, in file order. `from_fields` looks up by name, not
/// position — the order here only fixes what new files look like.
pub const HEADER: [&str; 26] = [
    "schema",
    "git_sha",
    "unix_ts",
    "quick",
    "config",
    "sets",
    "docs",
    "queries",
    "k",
    "clients",
    "window",
    "mix_ops",
    "query_frac",
    "load_qps",
    "mixed_qps",
    "recall_at_k",
    "p50_us",
    "p99_us",
    "p999_us",
    "peak_rss_mb",
    "server_inserts",
    "server_queries",
    "server_errors",
    "churn_cycles",
    "server_deletes",
    "mean_candidates",
];

/// One loadtest run — a row of the trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    pub schema: String,
    pub git_sha: String,
    pub unix_ts: u64,
    pub quick: bool,
    /// Full config string (scheme spec + workload knobs) — the run's
    /// identity for apples-to-apples comparisons.
    pub config: String,
    pub sets: u64,
    pub docs: u64,
    pub queries: u64,
    pub k: u64,
    pub clients: u64,
    pub window: u64,
    pub mix_ops: u64,
    pub query_frac: f64,
    /// Insert-only load phase throughput (ops/s).
    pub load_qps: f64,
    /// Sustained mixed-phase throughput (ops/s).
    pub mixed_qps: f64,
    pub recall_at_k: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub peak_rss_mb: f64,
    pub server_inserts: u64,
    pub server_queries: u64,
    pub server_errors: u64,
    /// Churn cycles run after the mixed phase (0 = churn off; v1 rows
    /// decode as 0).
    pub churn_cycles: u64,
    /// Server-side `lsh_deletes` counter at the end of the run.
    pub server_deletes: u64,
    /// Mean candidate-set size over the final churn cycle's probe
    /// queries (0 when churn is off) — the metric whose growth across
    /// cycles was the duplicate-insert posting leak.
    pub mean_candidates: f64,
}

impl RunRecord {
    /// Render in [`HEADER`] order.
    pub fn to_fields(&self) -> Vec<String> {
        vec![
            self.schema.clone(),
            self.git_sha.clone(),
            self.unix_ts.to_string(),
            self.quick.to_string(),
            self.config.clone(),
            self.sets.to_string(),
            self.docs.to_string(),
            self.queries.to_string(),
            self.k.to_string(),
            self.clients.to_string(),
            self.window.to_string(),
            self.mix_ops.to_string(),
            csv::f(self.query_frac),
            csv::f(self.load_qps),
            csv::f(self.mixed_qps),
            csv::f(self.recall_at_k),
            csv::f(self.p50_us),
            csv::f(self.p99_us),
            csv::f(self.p999_us),
            csv::f(self.peak_rss_mb),
            self.server_inserts.to_string(),
            self.server_queries.to_string(),
            self.server_errors.to_string(),
            self.churn_cycles.to_string(),
            self.server_deletes.to_string(),
            csv::f(self.mean_candidates),
        ]
    }

    /// Decode one data row against its file's header (lookup by name).
    pub fn from_fields(header: &[String], row: &[String]) -> Result<RunRecord> {
        let get = |name: &str| -> Result<&str> {
            let idx = header
                .iter()
                .position(|h| h == name)
                .with_context(|| format!("results csv: missing column '{name}'"))?;
            row.get(idx)
                .map(String::as_str)
                .with_context(|| format!("results csv: row too short for column '{name}'"))
        };
        let u = |name: &str| -> Result<u64> {
            get(name)?
                .parse()
                .with_context(|| format!("results csv: bad integer in '{name}'"))
        };
        let fl = |name: &str| -> Result<f64> {
            get(name)?
                .parse()
                .with_context(|| format!("results csv: bad number in '{name}'"))
        };
        let schema = get("schema")?.to_string();
        // v1 rows predate the churn columns; every other schema must
        // carry them (a *typo'd* column name should error, not zero).
        let v1 = schema == LOADTEST_SCHEMA_V1;
        let u_v2 = |name: &str| -> Result<u64> {
            if v1 && !header.iter().any(|h| h == name) {
                return Ok(0);
            }
            u(name)
        };
        let fl_v2 = |name: &str| -> Result<f64> {
            if v1 && !header.iter().any(|h| h == name) {
                return Ok(0.0);
            }
            fl(name)
        };
        Ok(RunRecord {
            schema,
            git_sha: get("git_sha")?.to_string(),
            unix_ts: u("unix_ts")?,
            quick: get("quick")? == "true",
            config: get("config")?.to_string(),
            sets: u("sets")?,
            docs: u("docs")?,
            queries: u("queries")?,
            k: u("k")?,
            clients: u("clients")?,
            window: u("window")?,
            mix_ops: u("mix_ops")?,
            query_frac: fl("query_frac")?,
            load_qps: fl("load_qps")?,
            mixed_qps: fl("mixed_qps")?,
            recall_at_k: fl("recall_at_k")?,
            p50_us: fl("p50_us")?,
            p99_us: fl("p99_us")?,
            p999_us: fl("p999_us")?,
            peak_rss_mb: fl("peak_rss_mb")?,
            server_inserts: u("server_inserts")?,
            server_queries: u("server_queries")?,
            server_errors: u("server_errors")?,
            churn_cycles: u_v2("churn_cycles")?,
            server_deletes: u_v2("server_deletes")?,
            mean_candidates: fl_v2("mean_candidates")?,
        })
    }
}

/// Append one run to `path`, creating the file (with header) on first
/// write. An existing file must carry exactly the current [`HEADER`] —
/// appending a v1 row to a foreign or future-schema file would corrupt
/// the trajectory, so it errors instead.
pub fn append(path: impl AsRef<Path>, record: &RunRecord) -> Result<()> {
    let path = path.as_ref();
    if path.exists() {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read results store {}", path.display()))?;
        let rows = csv::parse(&text)?;
        let header = rows.first().context("results csv: empty existing file")?;
        crate::ensure!(
            header.iter().map(String::as_str).eq(HEADER),
            "results csv {}: header does not match schema {LOADTEST_SCHEMA}",
            path.display()
        );
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("open results store {}", path.display()))?;
        f.write_all(csv::format_record(record.to_fields()).as_bytes())?;
    } else {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut text = csv::format_record(HEADER);
        text.push_str(&csv::format_record(record.to_fields()));
        std::fs::write(path, text)
            .with_context(|| format!("create results store {}", path.display()))?;
    }
    Ok(())
}

/// Load every run in `path`, oldest first.
pub fn load(path: impl AsRef<Path>) -> Result<Vec<RunRecord>> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read results store {}", path.display()))?;
    let rows = csv::parse(&text)?;
    let mut it = rows.into_iter();
    let header = it.next().context("results csv: missing header")?;
    it.map(|row| RunRecord::from_fields(&header, &row)).collect()
}

/// The most recent run in `path` — errors when the store has no runs.
pub fn last_run(path: impl AsRef<Path>) -> Result<RunRecord> {
    let path = path.as_ref();
    load(path)?
        .pop()
        .with_context(|| format!("results csv {}: no runs", path.display()))
}

/// One metric's movement between two runs.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    pub name: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// Whether larger is better for this metric (throughput/recall yes,
    /// latency/RSS no) — lets reports colour regressions consistently.
    pub higher_is_better: bool,
}

impl MetricDelta {
    /// Relative change, current vs baseline (positive = increased).
    pub fn rel_change(&self) -> f64 {
        if self.baseline == 0.0 {
            return if self.current == 0.0 { 0.0 } else { f64::INFINITY };
        }
        self.current / self.baseline - 1.0
    }
}

/// Diff the trajectory metrics of two runs (baseline vs current).
pub fn diff(baseline: &RunRecord, current: &RunRecord) -> Vec<MetricDelta> {
    let m = |name, b, c, hib| MetricDelta {
        name,
        baseline: b,
        current: c,
        higher_is_better: hib,
    };
    vec![
        m("load_qps", baseline.load_qps, current.load_qps, true),
        m("mixed_qps", baseline.mixed_qps, current.mixed_qps, true),
        m("recall_at_k", baseline.recall_at_k, current.recall_at_k, true),
        m("p50_us", baseline.p50_us, current.p50_us, false),
        m("p99_us", baseline.p99_us, current.p99_us, false),
        m("p999_us", baseline.p999_us, current.p999_us, false),
        m("peak_rss_mb", baseline.peak_rss_mb, current.peak_rss_mb, false),
    ]
}

/// One gate violation (current worse than baseline beyond tolerance).
#[derive(Debug, Clone)]
pub struct GateFailure {
    pub metric: &'static str,
    pub baseline: f64,
    pub current: f64,
    /// The tolerance the drop exceeded (absolute for recall, fractional
    /// for throughput).
    pub allowed: f64,
    /// The observed drop, in the same units as `allowed`.
    pub observed: f64,
}

impl fmt::Display for GateFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: baseline {:.4} -> current {:.4} (drop {:.4} > allowed {:.4})",
            self.metric, self.baseline, self.current, self.observed, self.allowed
        )
    }
}

/// Gate `current` against `baseline`: recall@k is gated on **absolute**
/// drop (it is deterministic given the config, so the tolerance can be
/// tight), throughput on **fractional** loss (shared-runner noise), via
/// [`crate::util::bench::frac_loss`] — the same loss definition the bench
/// suite gates on. Latency and RSS are reported by [`diff`] but not
/// gated: on shared CI runners their variance would either force useless
/// tolerances or flake.
///
/// Errors (rather than "passes") when the two runs are not comparable:
/// different row schema or different quick/full shape.
pub fn gate(
    current: &RunRecord,
    baseline: &RunRecord,
    recall_tol: f64,
    qps_tol: f64,
) -> Result<Vec<GateFailure>> {
    // v1 is a legal *baseline* for a v2 run (committed floor files
    // predate the churn columns); every other mix is incomparable.
    let comparable = current.schema == baseline.schema
        || (baseline.schema == LOADTEST_SCHEMA_V1 && current.schema == LOADTEST_SCHEMA);
    crate::ensure!(
        comparable,
        "gate: schema mismatch (baseline {}, current {})",
        baseline.schema,
        current.schema
    );
    crate::ensure!(
        current.quick == baseline.quick,
        "gate: comparing a quick run against a full baseline (or vice versa)"
    );
    let mut failures = Vec::new();
    let recall_drop = baseline.recall_at_k - current.recall_at_k;
    if recall_drop > recall_tol {
        failures.push(GateFailure {
            metric: "recall_at_k",
            baseline: baseline.recall_at_k,
            current: current.recall_at_k,
            allowed: recall_tol,
            observed: recall_drop,
        });
    }
    for (name, b, c) in [
        ("load_qps", baseline.load_qps, current.load_qps),
        ("mixed_qps", baseline.mixed_qps, current.mixed_qps),
    ] {
        let loss = crate::util::bench::frac_loss(b, c);
        if loss > qps_tol {
            failures.push(GateFailure {
                metric: name,
                baseline: b,
                current: c,
                allowed: qps_tol,
                observed: loss,
            });
        }
    }
    Ok(failures)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample(recall: f64, qps: f64) -> RunRecord {
        RunRecord {
            schema: LOADTEST_SCHEMA.to_string(),
            git_sha: "deadbeef".into(),
            unix_ts: 1_700_000_000,
            quick: true,
            config: "oph(k=64,layout=mod,densify=paper,hash=mixed_tab,seed=42) lsh=8x12".into(),
            sets: 50_000,
            docs: 25_000,
            queries: 32,
            k: 10,
            clients: 4,
            window: 16,
            mix_ops: 20_000,
            query_frac: 0.5,
            load_qps: qps,
            mixed_qps: qps * 0.8,
            recall_at_k: recall,
            p50_us: 120.0,
            p99_us: 900.0,
            p999_us: 2500.0,
            peak_rss_mb: 512.0,
            server_inserts: 60_000,
            server_queries: 10_032,
            server_errors: 0,
            churn_cycles: 4,
            server_deletes: 20_000,
            mean_candidates: 11.5,
        }
    }

    #[test]
    fn fields_roundtrip_by_name() {
        let r = sample(0.8, 10_000.0);
        let header: Vec<String> = HEADER.iter().map(|s| s.to_string()).collect();
        let back = RunRecord::from_fields(&header, &r.to_fields()).unwrap();
        assert_eq!(back, r);
        // Name-based lookup: a reordered header still decodes.
        let mut rev_header = header.clone();
        rev_header.reverse();
        let mut rev_row = r.to_fields();
        rev_row.reverse();
        assert_eq!(RunRecord::from_fields(&rev_header, &rev_row).unwrap(), r);
        // A missing column is a hard error naming the column.
        let short: Vec<String> = header[1..].to_vec();
        let err = RunRecord::from_fields(&short, &rev_row).unwrap_err();
        assert!(err.to_string().contains("missing column 'schema'"), "{err}");
    }

    #[test]
    fn v1_rows_decode_with_defaulted_churn_columns() {
        // A v1 file: today's header minus the three churn columns.
        let v1_header: Vec<String> = HEADER[..23].iter().map(|s| s.to_string()).collect();
        let mut r = sample(0.8, 10_000.0);
        r.schema = LOADTEST_SCHEMA_V1.to_string();
        let v1_row: Vec<String> = r.to_fields()[..23].to_vec();
        let back = RunRecord::from_fields(&v1_header, &v1_row).unwrap();
        assert_eq!(back.schema, LOADTEST_SCHEMA_V1);
        assert_eq!(back.churn_cycles, 0);
        assert_eq!(back.server_deletes, 0);
        assert_eq!(back.mean_candidates, 0.0);
        assert_eq!(back.recall_at_k, 0.8, "shared columns decode unchanged");
        // A row *claiming* v2 with the columns missing stays a hard error.
        let mut fake = v1_row.clone();
        fake[0] = LOADTEST_SCHEMA.to_string();
        let err = RunRecord::from_fields(&v1_header, &fake).unwrap_err();
        assert!(err.to_string().contains("churn_cycles"), "{err}");
    }

    #[test]
    fn gate_accepts_v1_baseline_for_v2_run() {
        let mut base = sample(0.75, 10_000.0);
        base.schema = LOADTEST_SCHEMA_V1.to_string();
        base.churn_cycles = 0;
        let cur = sample(0.75, 10_000.0);
        assert!(gate(&cur, &base, 0.125, 0.2).unwrap().is_empty());
        // The other direction (v2 baseline, v1 current) is not a thing.
        assert!(gate(&base, &cur, 0.125, 0.2).is_err());
    }

    #[test]
    fn gate_tolerances() {
        // Dyadic recall values (exact in f64) so "at tolerance" is an
        // exact boundary, not a rounding accident.
        let base = sample(0.75, 10_000.0);
        // At tolerance: recall drop exactly 0.125, qps loss 0.2 − ε.
        let at = sample(0.625, 8_000.0);
        assert!(gate(&at, &base, 0.125, 0.2).unwrap().is_empty());
        // Over tolerance on each axis.
        let bad_recall = sample(0.5, 10_000.0);
        let fails = gate(&bad_recall, &base, 0.125, 0.2).unwrap();
        assert_eq!(fails.len(), 1);
        assert_eq!(fails[0].metric, "recall_at_k");
        assert!(fails[0].to_string().contains("recall_at_k"), "{}", fails[0]);
        let bad_qps = sample(0.75, 7_000.0);
        let fails = gate(&bad_qps, &base, 0.125, 0.2).unwrap();
        assert_eq!(fails.len(), 2, "both load and mixed qps dropped");
        // Improvements never fail.
        assert!(gate(&sample(0.9375, 20_000.0), &base, 0.125, 0.2).unwrap().is_empty());
        // Incomparable runs are an error, not a pass.
        let mut full = sample(0.75, 10_000.0);
        full.quick = false;
        assert!(gate(&full, &base, 0.125, 0.2).is_err());
        let mut foreign = sample(0.75, 10_000.0);
        foreign.schema = "mixtab-loadtest-v0".into();
        assert!(gate(&foreign, &base, 0.125, 0.2).is_err());
    }

    #[test]
    fn diff_directions() {
        let base = sample(0.8, 10_000.0);
        let cur = sample(0.9, 9_000.0);
        let deltas = diff(&base, &cur);
        let load = deltas.iter().find(|d| d.name == "load_qps").unwrap();
        assert!(load.higher_is_better && load.rel_change() < 0.0);
        let recall = deltas.iter().find(|d| d.name == "recall_at_k").unwrap();
        assert!(recall.rel_change() > 0.0);
        let p99 = deltas.iter().find(|d| d.name == "p99_us").unwrap();
        assert!(!p99.higher_is_better);
    }
}
