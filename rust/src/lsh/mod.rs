//! Locality-sensitive hashing for approximate near-neighbour search (§2.3),
//! built on OPH sketches — the §4.2 "Similarity search with LSH" setup.
//!
//! * [`index`] — the (K, L) table structure: one OPH sketch of `K·L` bins
//!   per set, partitioned into L bucket keys of K bins each (the
//!   one-permutation construction of Shrivastava & Li [32]).
//! * [`metrics`] — brute-force ground truth, recall@T₀ and the
//!   #retrieved/recall ratio reported in Figure 5.
//! * [`sharded`] — N independently-locked shards behind deterministic
//!   id→shard routing with fan-out query + merge (the multi-scheme
//!   coordinator's per-scheme index).
//! * [`topk`] — bounded top-k selection for the re-rank serving stage
//!   (`query_topk` over stored sketches).

pub mod index;
pub mod metrics;
pub mod persist;
pub mod angular;
pub mod sharded;
pub mod topk;

pub use angular::{AngularIndex, AngularParams};
pub use index::{LshIndex, LshParams};
pub use metrics::{ground_truth, QueryEval};
pub use sharded::ShardedIndex;
pub use topk::{Scored, TopK};
