//! LSH index snapshots: save a built index to disk and reload it without
//! re-sketching the corpus — what a serving deployment does on restart.
//!
//! The snapshot stores the structural parameters, the hash-family id + seed
//! (so the reloaded index re-derives the *same* sketcher — sketches are
//! only comparable under the same hash function), and every table's
//! buckets.

use crate::hash::HashFamily;
use crate::lsh::index::{LshIndex, LshParams};
use crate::util::binio::{BinReader, BinWriter};
use crate::util::error::{bail, format_err, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x4D58_4C53; // "MXLS"
const VERSION: u8 = 1;

/// Serialize an index (with its provenance) to a writer.
pub fn save_to(index: &LshIndex, family: HashFamily, seed: u64, w: impl Write) -> Result<()> {
    let mut w = BinWriter::new(w);
    w.u32(MAGIC)?;
    w.u8(VERSION)?;
    w.str(family.id())?;
    w.u64(seed)?;
    let p = index.params();
    w.u64(p.k as u64)?;
    w.u64(p.l as u64)?;
    w.u64(index.len() as u64)?;
    let tables = index.tables_raw();
    w.u64(tables.len() as u64)?;
    for table in tables {
        w.u64(table.len() as u64)?;
        for (key, ids) in table {
            w.u64(*key)?;
            w.u32s(ids)?;
        }
    }
    Ok(())
}

/// Save to a file path — atomically and durably: the bytes go to
/// `<path>.tmp`, are flushed and fsync'd, then renamed over `path`.
/// Re-saving over an existing snapshot can therefore never truncate it,
/// and a crash mid-write leaves the old file intact (plus at worst a
/// stale `.tmp`). The file contents are exactly [`save_to`]'s byte
/// stream — rename does not change them, so byte-identity guarantees
/// (e.g. the N=1 sharded snapshot) are unaffected.
pub fn save(index: &LshIndex, family: HashFamily, seed: u64, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let f = std::fs::File::create(&tmp)?;
    let mut w = BufWriter::new(f);
    save_to(index, family, seed, &mut w)?;
    w.flush()?;
    let f = w
        .into_inner()
        .map_err(|e| format_err!("flush snapshot buffer: {e}"))?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Reload an index from a reader. Returns `(index, family, seed)`.
pub fn load_from(r: impl Read) -> Result<(LshIndex, HashFamily, u64)> {
    let mut r = BinReader::new(r);
    if r.u32()? != MAGIC {
        bail!("not an LSH snapshot (bad magic)");
    }
    let version = r.u8()?;
    if version != VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let fam_id = r.str()?;
    let family = HashFamily::parse(&fam_id)
        .with_context(|| format!("unknown hash family '{fam_id}' in snapshot"))?;
    let seed = r.u64()?;
    let k = r.u64()? as usize;
    let l = r.u64()? as usize;
    let len = r.u64()? as usize;
    let n_tables = r.u64()? as usize;
    if n_tables != l {
        bail!("snapshot table count {n_tables} != L {l}");
    }
    let mut index = LshIndex::new(
        LshParams::new(k, l),
        &crate::sketch::SketchSpec::oph(family, seed, k * l),
    );
    let mut tables = Vec::with_capacity(n_tables);
    for _ in 0..n_tables {
        let buckets = r.u64()? as usize;
        let mut table = std::collections::HashMap::with_capacity(buckets);
        for _ in 0..buckets {
            let key = r.u64()?;
            let ids = r.u32s()?;
            table.insert(key, ids);
        }
        tables.push(table);
    }
    index.restore_raw(tables, len);
    Ok((index, family, seed))
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<(LshIndex, HashFamily, u64)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    load_from(BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSpec;

    #[test]
    fn roundtrip_preserves_queries() {
        let mut index = LshIndex::new(
            LshParams::new(4, 6),
            &SketchSpec::oph(HashFamily::MixedTab, 77, 24),
        );
        let sets: Vec<Vec<u32>> = (0..30u32).map(|i| (i * 40..i * 40 + 120).collect()).collect();
        for (i, s) in sets.iter().enumerate() {
            index.insert(i as u32, s);
        }
        let mut buf = Vec::new();
        save_to(&index, HashFamily::MixedTab, 77, &mut buf).unwrap();
        let (loaded, fam, seed) = load_from(&buf[..]).unwrap();
        assert_eq!(fam, HashFamily::MixedTab);
        assert_eq!(seed, 77);
        assert_eq!(loaded.len(), index.len());
        assert_eq!(loaded.params(), index.params());
        // Every query returns identical candidates.
        for s in &sets {
            assert_eq!(loaded.query(s), index.query(s));
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("mixtab_lsh_persist");
        let _ = std::fs::remove_dir_all(&dir);
        let mut index =
            LshIndex::new(LshParams::new(3, 3), &SketchSpec::oph(HashFamily::Murmur3, 5, 9));
        index.insert(1, &(0..50).collect::<Vec<_>>());
        let path = dir.join("snap.mxls");
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        let (loaded, _, _) = load(&path).unwrap();
        assert_eq!(loaded.query(&(0..50).collect::<Vec<_>>()), vec![1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_is_atomic_over_existing_snapshot() {
        let dir = std::env::temp_dir().join("mixtab_lsh_persist_atomic");
        let _ = std::fs::remove_dir_all(&dir);
        let mut index =
            LshIndex::new(LshParams::new(3, 3), &SketchSpec::oph(HashFamily::Murmur3, 5, 9));
        index.insert(1, &(0..50).collect::<Vec<_>>());
        let path = dir.join("snap.mxls");
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        // Re-save over the existing snapshot: committed via rename, and
        // no temp file is left behind.
        index.insert(2, &(100..160).collect::<Vec<_>>());
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        assert!(!dir.join("snap.mxls.tmp").exists(), "temp file left behind");
        let (loaded, _, _) = load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_garbage() {
        assert!(load_from(&b"garbage!"[..]).is_err());
        let mut buf = Vec::new();
        let idx = LshIndex::new(LshParams::new(2, 2), &SketchSpec::oph(HashFamily::MixedTab, 1, 4));
        save_to(&idx, HashFamily::MixedTab, 1, &mut buf).unwrap();
        buf[4] = 99; // bad version
        assert!(load_from(&buf[..]).is_err());
        // Truncated.
        let mut buf2 = Vec::new();
        save_to(&idx, HashFamily::MixedTab, 1, &mut buf2).unwrap();
        buf2.truncate(buf2.len() / 2);
        assert!(load_from(&buf2[..]).is_err());
    }
}
