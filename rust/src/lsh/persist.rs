//! LSH index snapshots: save a built index to disk and reload it without
//! re-sketching the corpus — what a serving deployment does on restart.
//!
//! The snapshot stores the structural parameters, the hash-family id + seed
//! (so the reloaded index re-derives the *same* sketcher — sketches are
//! only comparable under the same hash function), every table's buckets,
//! and (since v2) the per-id bucket keys plus the tombstone set, so a
//! mutable corpus round-trips mid-churn without forcing a compaction.
//!
//! Version 1 snapshots (insert-only, no keys/tombstones) still load: the
//! per-id keys are reconstructed from the tables themselves — every id
//! appears exactly once per table in a clean v1 file, which the loader
//! verifies against the stored length.

use crate::hash::HashFamily;
use crate::lsh::index::{LshIndex, LshParams};
use crate::util::binio::{BinReader, BinWriter};
use crate::util::error::{bail, format_err, Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: u32 = 0x4D58_4C53; // "MXLS"
const VERSION: u8 = 2;

/// Serialize an index (with its provenance) to a writer.
pub fn save_to(index: &LshIndex, family: HashFamily, seed: u64, w: impl Write) -> Result<()> {
    let mut w = BinWriter::new(w);
    w.u32(MAGIC)?;
    w.u8(VERSION)?;
    w.str(family.id())?;
    w.u64(seed)?;
    let p = index.params();
    w.u64(p.k as u64)?;
    w.u64(p.l as u64)?;
    w.u64(index.len() as u64)?;
    let tables = index.tables_raw();
    w.u64(tables.len() as u64)?;
    for table in tables {
        w.u64(table.len() as u64)?;
        for (key, ids) in table {
            w.u64(*key)?;
            w.u32s(ids)?;
        }
    }
    // v2: per-id bucket keys and tombstones, in sorted-id order so two
    // saves of the same logical state write identical bytes.
    let keys = index.keys_raw();
    let mut ids: Vec<u32> = keys.keys().copied().collect();
    ids.sort_unstable();
    w.u64(ids.len() as u64)?;
    for id in &ids {
        w.u32(*id)?;
        for key in &keys[id] {
            w.u64(*key)?;
        }
    }
    let mut dead: Vec<u32> = index.tombstones_raw().iter().copied().collect();
    dead.sort_unstable();
    w.u32s(&dead)?;
    Ok(())
}

/// Save to a file path — atomically and durably: the bytes go to
/// `<path>.tmp`, are flushed and fsync'd, then renamed over `path`.
/// Re-saving over an existing snapshot can therefore never truncate it,
/// and a crash mid-write leaves the old file intact (plus at worst a
/// stale `.tmp`). The file contents are exactly [`save_to`]'s byte
/// stream — rename does not change them, so byte-identity guarantees
/// (e.g. the N=1 sharded snapshot) are unaffected.
pub fn save(index: &LshIndex, family: HashFamily, seed: u64, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let f = std::fs::File::create(&tmp)?;
    let mut w = BufWriter::new(f);
    save_to(index, family, seed, &mut w)?;
    w.flush()?;
    let f = w
        .into_inner()
        .map_err(|e| format_err!("flush snapshot buffer: {e}"))?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Reload an index from a reader. Returns `(index, family, seed)`.
pub fn load_from(r: impl Read) -> Result<(LshIndex, HashFamily, u64)> {
    let mut r = BinReader::new(r);
    if r.u32()? != MAGIC {
        bail!("not an LSH snapshot (bad magic)");
    }
    let version = r.u8()?;
    if version == 0 || version > VERSION {
        bail!("unsupported snapshot version {version}");
    }
    let fam_id = r.str()?;
    let family = HashFamily::parse(&fam_id)
        .with_context(|| format!("unknown hash family '{fam_id}' in snapshot"))?;
    let seed = r.u64()?;
    let k = r.u64()? as usize;
    let l = r.u64()? as usize;
    let len = r.u64()? as usize;
    let n_tables = r.u64()? as usize;
    if n_tables != l {
        bail!("snapshot table count {n_tables} != L {l}");
    }
    let mut index = LshIndex::new(
        LshParams::new(k, l),
        &crate::sketch::SketchSpec::oph(family, seed, k * l),
    );
    let mut tables = Vec::with_capacity(n_tables);
    for _ in 0..n_tables {
        let buckets = r.u64()? as usize;
        let mut table = HashMap::with_capacity(buckets);
        for _ in 0..buckets {
            let key = r.u64()?;
            let ids = r.u32s()?;
            table.insert(key, ids);
        }
        tables.push(table);
    }
    let (keys, tombstones) = if version >= 2 {
        let n_ids = r.u64()? as usize;
        let mut keys: HashMap<u32, Vec<u64>> = HashMap::with_capacity(n_ids);
        for _ in 0..n_ids {
            let id = r.u32()?;
            let mut id_keys = Vec::with_capacity(l);
            for _ in 0..l {
                id_keys.push(r.u64()?);
            }
            keys.insert(id, id_keys);
        }
        let tombstones: HashSet<u32> = r.u32s()?.into_iter().collect();
        if tombstones.iter().any(|id| !keys.contains_key(id)) {
            bail!("snapshot tombstones reference unknown ids");
        }
        if keys.len() - tombstones.len() != len {
            bail!(
                "snapshot live count {} != stored len {len}",
                keys.len() - tombstones.len()
            );
        }
        (keys, tombstones)
    } else {
        // v1 (insert-only): reconstruct each id's bucket keys from the
        // tables. A clean v1 file holds every id exactly once per table;
        // a file written by the pre-fix duplicate-insert path does not,
        // and the length check below rejects it loudly.
        let mut keys: HashMap<u32, Vec<u64>> = HashMap::with_capacity(len);
        let mut entries = 0usize;
        for (li, table) in tables.iter().enumerate() {
            for (key, ids) in table {
                entries += ids.len();
                for &id in ids {
                    keys.entry(id).or_insert_with(|| vec![0u64; l])[li] = *key;
                }
            }
        }
        if keys.len() != len || entries != len * l {
            bail!(
                "v1 snapshot is inconsistent ({} ids / {entries} entries vs len {len}) — \
                 likely written after duplicate inserts",
                keys.len()
            );
        }
        (keys, HashSet::new())
    };
    index.restore_raw(tables, keys, tombstones);
    Ok((index, family, seed))
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<(LshIndex, HashFamily, u64)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    load_from(BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::SketchSpec;

    #[test]
    fn roundtrip_preserves_queries() {
        let mut index = LshIndex::new(
            LshParams::new(4, 6),
            &SketchSpec::oph(HashFamily::MixedTab, 77, 24),
        );
        let sets: Vec<Vec<u32>> = (0..30u32).map(|i| (i * 40..i * 40 + 120).collect()).collect();
        for (i, s) in sets.iter().enumerate() {
            index.insert(i as u32, s);
        }
        let mut buf = Vec::new();
        save_to(&index, HashFamily::MixedTab, 77, &mut buf).unwrap();
        let (loaded, fam, seed) = load_from(&buf[..]).unwrap();
        assert_eq!(fam, HashFamily::MixedTab);
        assert_eq!(seed, 77);
        assert_eq!(loaded.len(), index.len());
        assert_eq!(loaded.params(), index.params());
        // Every query returns identical candidates.
        for s in &sets {
            assert_eq!(loaded.query(s), index.query(s));
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("mixtab_lsh_persist");
        let _ = std::fs::remove_dir_all(&dir);
        let mut index =
            LshIndex::new(LshParams::new(3, 3), &SketchSpec::oph(HashFamily::Murmur3, 5, 9));
        index.insert(1, &(0..50).collect::<Vec<_>>());
        let path = dir.join("snap.mxls");
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        let (loaded, _, _) = load(&path).unwrap();
        assert_eq!(loaded.query(&(0..50).collect::<Vec<_>>()), vec![1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_is_atomic_over_existing_snapshot() {
        let dir = std::env::temp_dir().join("mixtab_lsh_persist_atomic");
        let _ = std::fs::remove_dir_all(&dir);
        let mut index =
            LshIndex::new(LshParams::new(3, 3), &SketchSpec::oph(HashFamily::Murmur3, 5, 9));
        index.insert(1, &(0..50).collect::<Vec<_>>());
        let path = dir.join("snap.mxls");
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        // Re-save over the existing snapshot: committed via rename, and
        // no temp file is left behind.
        index.insert(2, &(100..160).collect::<Vec<_>>());
        save(&index, HashFamily::Murmur3, 5, &path).unwrap();
        assert!(!dir.join("snap.mxls.tmp").exists(), "temp file left behind");
        let (loaded, _, _) = load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tombstoned_snapshot_roundtrips() {
        let mut index = LshIndex::new(
            LshParams::new(4, 5),
            &SketchSpec::oph(HashFamily::MixedTab, 31, 20),
        );
        let sets: Vec<Vec<u32>> = (0..40u32).map(|i| (i * 60..i * 60 + 50).collect()).collect();
        for (i, s) in sets.iter().enumerate() {
            index.insert(i as u32, s);
        }
        index.delete(3);
        index.delete(17);
        let mut buf = Vec::new();
        save_to(&index, HashFamily::MixedTab, 31, &mut buf).unwrap();
        let (mut loaded, _, _) = load_from(&buf[..]).unwrap();
        assert_eq!(loaded.len(), 38);
        assert_eq!(loaded.tombstone_count(), 2);
        for s in &sets {
            assert_eq!(loaded.query(s), index.query(s));
        }
        // The restored tombstones still drive compaction correctly.
        loaded.compact();
        assert_eq!(loaded.tombstone_count(), 0);
        assert!(!loaded.query(&sets[3]).contains(&3));
        assert!(!loaded.query(&sets[17]).contains(&17));
    }

    /// v1 (insert-only) snapshots load with keys reconstructed from the
    /// tables, so deletes and upserts work on a corpus restored from a
    /// pre-v2 file.
    #[test]
    fn v1_snapshot_still_loads_and_is_mutable() {
        let mut index = LshIndex::new(
            LshParams::new(3, 4),
            &SketchSpec::oph(HashFamily::Murmur3, 9, 12),
        );
        let sets: Vec<Vec<u32>> = (0..12u32).map(|i| (i * 80..i * 80 + 70).collect()).collect();
        for (i, s) in sets.iter().enumerate() {
            index.insert(i as u32, s);
        }
        // Serialize the v1 layout by hand (header + tables, no trailer).
        let mut buf = Vec::new();
        {
            let mut w = BinWriter::new(&mut buf);
            w.u32(MAGIC).unwrap();
            w.u8(1).unwrap();
            w.str(HashFamily::Murmur3.id()).unwrap();
            w.u64(9).unwrap();
            w.u64(3).unwrap();
            w.u64(4).unwrap();
            w.u64(index.len() as u64).unwrap();
            let tables = index.tables_raw();
            w.u64(tables.len() as u64).unwrap();
            for table in tables {
                w.u64(table.len() as u64).unwrap();
                for (key, ids) in table {
                    w.u64(*key).unwrap();
                    w.u32s(ids).unwrap();
                }
            }
        }
        let (mut loaded, fam, seed) = load_from(&buf[..]).unwrap();
        assert_eq!((fam, seed), (HashFamily::Murmur3, 9));
        assert_eq!(loaded.len(), 12);
        for s in &sets {
            assert_eq!(loaded.query(s), index.query(s));
        }
        // Reconstructed keys make the restored corpus fully mutable.
        assert!(loaded.delete(4));
        assert!(!loaded.query(&sets[4]).contains(&4));
        loaded.insert(5, &(900_000..900_070).collect::<Vec<_>>());
        assert!(!loaded.query(&sets[5]).contains(&5), "upsert left stale postings");
        assert_eq!(loaded.len(), 11);
        // A v1 file whose stored len disagrees with its tables (the
        // duplicate-insert artifact) is rejected, not silently loaded.
        let mut bad = Vec::new();
        {
            let mut w = BinWriter::new(&mut bad);
            w.u32(MAGIC).unwrap();
            w.u8(1).unwrap();
            w.str(HashFamily::Murmur3.id()).unwrap();
            w.u64(9).unwrap();
            w.u64(3).unwrap();
            w.u64(4).unwrap();
            w.u64(index.len() as u64 + 1).unwrap();
            let tables = index.tables_raw();
            w.u64(tables.len() as u64).unwrap();
            for table in tables {
                w.u64(table.len() as u64).unwrap();
                for (key, ids) in table {
                    w.u64(*key).unwrap();
                    w.u32s(ids).unwrap();
                }
            }
        }
        assert!(load_from(&bad[..]).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(load_from(&b"garbage!"[..]).is_err());
        let mut buf = Vec::new();
        let idx = LshIndex::new(LshParams::new(2, 2), &SketchSpec::oph(HashFamily::MixedTab, 1, 4));
        save_to(&idx, HashFamily::MixedTab, 1, &mut buf).unwrap();
        buf[4] = 99; // bad version
        assert!(load_from(&buf[..]).is_err());
        // Truncated.
        let mut buf2 = Vec::new();
        save_to(&idx, HashFamily::MixedTab, 1, &mut buf2).unwrap();
        buf2.truncate(buf2.len() / 2);
        assert!(load_from(&buf2[..]).is_err());
    }
}
