//! LSH evaluation metrics (§4.2, following the setup of [32]).
//!
//! 1. the fraction of total data points retrieved per query,
//! 2. recall at threshold T₀ — retrieved points with `J ≥ T₀` over all
//!    points with `J ≥ T₀`,
//! 3. the **#retrieved / recall ratio** (lower is better) — Figure 5's
//!    y-axis, chosen because recall alone "may be inflated by poor hash
//!    functions that just retrieve many data points".

use crate::sketch::estimators::jaccard_sorted;
use crate::util::threadpool::ThreadPool;

/// Per-query ground truth: ids of database sets with `J(q, x) ≥ t0`.
pub fn ground_truth(db: &[Vec<u32>], query: &[u32], t0: f64) -> Vec<u32> {
    db.iter()
        .enumerate()
        .filter(|(_, x)| jaccard_sorted(query, x) >= t0)
        .map(|(i, _)| i as u32)
        .collect()
}

/// Ground truth for many queries, parallelised over a pool.
pub fn ground_truth_batch(
    pool: &ThreadPool,
    db: &[Vec<u32>],
    queries: &[Vec<u32>],
    t0: f64,
) -> Vec<Vec<u32>> {
    let tasks: Vec<_> = queries
        .iter()
        .map(|q| {
            let db = &db;
            let q = &q[..];
            move || ground_truth(db, q, t0)
        })
        .collect();
    pool.scope(tasks)
}

/// Per-query top-k ground truth: the ids of the `k` database sets with the
/// highest Jaccard similarity to `query` (ties broken by smaller id, for
/// determinism), **excluding** zero-similarity sets — a random database can
/// never pad the truth, so recall@k stays meaningful when a query has
/// fewer than `k` genuine neighbours. This is the brute-force oracle the
/// `mixtab loadtest` recall gate samples (see DESIGN.md §3.5 for why it is
/// sampled over queries rather than exhaustive at 10⁶ sets).
pub fn topk_ground_truth(db: &[Vec<u32>], query: &[u32], k: usize) -> Vec<u32> {
    if k == 0 {
        return Vec::new();
    }
    // Bounded selection: keep the best k seen so far, sorted descending by
    // (similarity, smaller-id-wins). k is small (≤ ~100), so linear insert
    // beats a heap on constant factors and keeps ordering deterministic.
    let mut best: Vec<(f64, u32)> = Vec::with_capacity(k + 1);
    for (i, x) in db.iter().enumerate() {
        let j = jaccard_sorted(query, x);
        if j <= 0.0 {
            continue;
        }
        let id = i as u32;
        if best.len() == k {
            let (wj, wid) = best[k - 1];
            if j < wj || (j == wj && id > wid) {
                continue;
            }
        }
        let pos = best
            .iter()
            .position(|&(bj, bid)| j > bj || (j == bj && id < bid))
            .unwrap_or(best.len());
        best.insert(pos, (j, id));
        best.truncate(k);
    }
    best.into_iter().map(|(_, id)| id).collect()
}

/// Top-k ground truth for many queries, parallelised over a pool.
pub fn topk_ground_truth_batch(
    pool: &ThreadPool,
    db: &[Vec<u32>],
    queries: &[Vec<u32>],
    k: usize,
) -> Vec<Vec<u32>> {
    let tasks: Vec<_> = queries
        .iter()
        .map(|q| {
            let db = &db;
            let q = &q[..];
            move || topk_ground_truth(db, q, k)
        })
        .collect();
    pool.scope(tasks)
}

/// recall@k: the fraction of the true top-k (as returned by
/// [`topk_ground_truth`]) present in the retrieved candidate set.
/// `retrieved` must be sorted ascending (the index's merge invariant);
/// `None` when the truth is empty (no genuine neighbours — skipped
/// upstream, mirroring [`QueryEval::recall`]).
pub fn recall_at_k(retrieved: &[u32], truth_topk: &[u32]) -> Option<f64> {
    debug_assert!(retrieved.windows(2).all(|w| w[0] < w[1]));
    if truth_topk.is_empty() {
        return None;
    }
    let hits = truth_topk
        .iter()
        .filter(|id| retrieved.binary_search(id).is_ok())
        .count();
    Some(hits as f64 / truth_topk.len() as f64)
}

/// Evaluation of one query's retrieved set.
#[derive(Debug, Clone)]
pub struct QueryEval {
    /// Number of candidates the index returned.
    pub retrieved: usize,
    /// Number of true near neighbours (J ≥ T₀).
    pub relevant: usize,
    /// Retrieved ∩ relevant.
    pub hits: usize,
    /// Database size.
    pub db_size: usize,
}

impl QueryEval {
    /// Compare a retrieved id list against ground truth (both sorted).
    pub fn evaluate(retrieved: &[u32], truth: &[u32], db_size: usize) -> Self {
        debug_assert!(retrieved.windows(2).all(|w| w[0] < w[1]));
        let truth_sorted: Vec<u32> = {
            let mut t = truth.to_vec();
            t.sort_unstable();
            t
        };
        let mut hits = 0usize;
        let mut j = 0usize;
        for &r in retrieved {
            while j < truth_sorted.len() && truth_sorted[j] < r {
                j += 1;
            }
            if j < truth_sorted.len() && truth_sorted[j] == r {
                hits += 1;
                j += 1;
            }
        }
        Self {
            retrieved: retrieved.len(),
            relevant: truth.len(),
            hits,
            db_size,
        }
    }

    /// Metric 1: fraction of the database retrieved.
    pub fn fraction_retrieved(&self) -> f64 {
        if self.db_size == 0 {
            return 0.0;
        }
        self.retrieved as f64 / self.db_size as f64
    }

    /// Metric 2: recall@T₀. Queries with no relevant neighbours are skipped
    /// upstream (paper follows [32]); we return `None` for them.
    pub fn recall(&self) -> Option<f64> {
        if self.relevant == 0 {
            return None;
        }
        Some(self.hits as f64 / self.relevant as f64)
    }

    /// Metric 3: #retrieved / recall ratio (lower is better). `None` when
    /// recall is undefined or zero (the paper's plots aggregate over many
    /// queries so zero-recall single queries fold into the mean upstream).
    pub fn retrieved_recall_ratio(&self) -> Option<f64> {
        match self.recall() {
            Some(r) if r > 0.0 => Some(self.retrieved as f64 / r),
            _ => None,
        }
    }
}

/// Aggregate evaluation across queries: mean fraction retrieved, mean
/// recall, and the ratio of *totals* (Σ retrieved / mean recall) which is
/// how a batch of queries experiences the trade-off.
#[derive(Debug, Clone, Default)]
pub struct BatchEval {
    pub evals: Vec<QueryEval>,
}

impl BatchEval {
    pub fn push(&mut self, e: QueryEval) {
        self.evals.push(e);
    }

    pub fn mean_fraction_retrieved(&self) -> f64 {
        mean(self.evals.iter().map(|e| e.fraction_retrieved()))
    }

    /// Mean recall over queries that have ≥ 1 relevant neighbour.
    pub fn mean_recall(&self) -> f64 {
        mean(self.evals.iter().filter_map(|e| e.recall()))
    }

    /// Mean retrieved count per query.
    pub fn mean_retrieved(&self) -> f64 {
        mean(self.evals.iter().map(|e| e.retrieved as f64))
    }

    /// The Figure 5 statistic aggregated batch-level: mean #retrieved
    /// divided by mean recall (in percent recalled, as the paper divides by
    /// "the percentage of recalled data points").
    pub fn ratio(&self) -> f64 {
        let r = self.mean_recall();
        if r <= 0.0 {
            return f64::INFINITY;
        }
        self.mean_retrieved() / r
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0, 0usize);
    for x in it {
        s += x;
        n += 1;
    }
    if n == 0 {
        f64::NAN
    } else {
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ground_truth_thresholding() {
        let db = vec![
            (0..100u32).collect::<Vec<_>>(),          // J = 1.0
            (50..150u32).collect::<Vec<_>>(),         // J = 50/150 = 1/3
            (1000..1100u32).collect::<Vec<_>>(),      // J = 0
        ];
        let q: Vec<u32> = (0..100).collect();
        assert_eq!(ground_truth(&db, &q, 0.5), vec![0]);
        assert_eq!(ground_truth(&db, &q, 0.3), vec![0, 1]);
        assert_eq!(ground_truth(&db, &q, 0.0).len(), 3);
    }

    #[test]
    fn topk_orders_by_similarity_then_id() {
        let db = vec![
            (0..100u32).collect::<Vec<_>>(),     // J = 1.0
            (0..50u32).collect::<Vec<_>>(),      // J = 0.5
            (50..150u32).collect::<Vec<_>>(),    // J = 1/3
            (0..50u32).collect::<Vec<_>>(),      // J = 0.5 (duplicate of id 1)
            (1000..1100u32).collect::<Vec<_>>(), // J = 0
        ];
        let q: Vec<u32> = (0..100).collect();
        // Ties at J = 0.5 resolve to the smaller id first.
        assert_eq!(topk_ground_truth(&db, &q, 3), vec![0, 1, 3]);
        assert_eq!(topk_ground_truth(&db, &q, 2), vec![0, 1]);
        // Zero-similarity sets never pad the truth.
        assert_eq!(topk_ground_truth(&db, &q, 10), vec![0, 1, 3, 2]);
        assert!(topk_ground_truth(&db, &q, 0).is_empty());
    }

    #[test]
    fn recall_at_k_counts_hits() {
        assert_eq!(recall_at_k(&[1, 3, 5], &[3, 5, 9]), Some(2.0 / 3.0));
        assert_eq!(recall_at_k(&[1, 3, 5], &[7]), Some(0.0));
        assert_eq!(recall_at_k(&[], &[7]), Some(0.0));
        assert_eq!(recall_at_k(&[1, 2], &[]), None);
    }

    #[test]
    fn parallel_topk_matches_serial() {
        let db: Vec<Vec<u32>> = (0..40).map(|i| (i * 7..i * 7 + 60).collect()).collect();
        let queries: Vec<Vec<u32>> = (0..9).map(|i| (i * 15..i * 15 + 60).collect()).collect();
        let pool = ThreadPool::new(3);
        let par = topk_ground_truth_batch(&pool, &db, &queries, 5);
        for (q, expect) in queries.iter().zip(&par) {
            assert_eq!(&topk_ground_truth(&db, q, 5), expect);
        }
    }

    #[test]
    fn query_eval_counts() {
        let e = QueryEval::evaluate(&[1, 3, 5, 7], &[3, 7, 9], 100);
        assert_eq!(e.hits, 2);
        assert_eq!(e.relevant, 3);
        assert_eq!(e.retrieved, 4);
        assert!((e.recall().unwrap() - 2.0 / 3.0).abs() < 1e-12);
        assert!((e.fraction_retrieved() - 0.04).abs() < 1e-12);
        let ratio = e.retrieved_recall_ratio().unwrap();
        assert!((ratio - 4.0 / (2.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn zero_relevant_is_none() {
        let e = QueryEval::evaluate(&[1, 2], &[], 10);
        assert!(e.recall().is_none());
        assert!(e.retrieved_recall_ratio().is_none());
    }

    #[test]
    fn zero_recall_ratio_none() {
        let e = QueryEval::evaluate(&[1, 2], &[9], 10);
        assert_eq!(e.recall(), Some(0.0));
        assert!(e.retrieved_recall_ratio().is_none());
    }

    #[test]
    fn batch_aggregation() {
        let mut b = BatchEval::default();
        b.push(QueryEval::evaluate(&[0, 1], &[0], 10)); // recall 1, retrieved 2
        b.push(QueryEval::evaluate(&[2, 3, 4, 5], &[2, 9], 10)); // recall .5, retrieved 4
        assert!((b.mean_recall() - 0.75).abs() < 1e-12);
        assert!((b.mean_retrieved() - 3.0).abs() < 1e-12);
        assert!((b.ratio() - 4.0).abs() < 1e-12);
        assert!((b.mean_fraction_retrieved() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn parallel_ground_truth_matches_serial() {
        let db: Vec<Vec<u32>> = (0..30)
            .map(|i| (i * 10..i * 10 + 50).collect())
            .collect();
        let queries: Vec<Vec<u32>> = (0..7).map(|i| (i * 20..i * 20 + 50).collect()).collect();
        let pool = ThreadPool::new(3);
        let par = ground_truth_batch(&pool, &db, &queries, 0.3);
        for (q, expect) in queries.iter().zip(&par) {
            assert_eq!(&ground_truth(&db, q, 0.3), expect);
        }
    }
}
