//! Sharded LSH serving: N independent [`LshIndex`] shards behind one
//! routing front, as in the k-partition/sharded-statistics setting of
//! Dahlgaard et al. ("Hashing for statistics over k-partitions").
//!
//! **Routing invariant**: a stored id lives in exactly one shard, chosen
//! by hashing the *id* with the index's configured hash family under a
//! routing-specific seed ([`SHARD_ROUTE_SALT`]). The route therefore
//! depends only on `(family, seed, n_shards, id)` — it is deterministic
//! across runs and processes, which is what makes per-shard snapshots
//! reloadable and lets shards be rebuilt independently.
//!
//! **Merge semantics**: every shard is built from the *same* OPH
//! [`SketchSpec`] (same family + seed ⇒ identical sketcher), so a query is
//! sketched once and fanned out to all shards; the result is the sorted,
//! deduplicated union of the per-shard candidate lists. Because each id is
//! in exactly one shard and all shards share the sketcher, that union is
//! identical to what a single unsharded index holding the whole corpus
//! would return — fan-out results are independent of the shard count
//! (property-tested in `rust/tests/sharded_properties.rs`).
//!
//! **Concurrency**: shards are individually mutexed, so inserts routed to
//! different shards and fan-out queries proceed without a global index
//! lock — the coordinator serves `insert`/`query` from many connection
//! threads against one `ShardedIndex` by shared reference. With a shared
//! [`ThreadPool`] attached ([`ShardedIndex::set_pool`]) the fan-out visits
//! shards **in parallel** (one scoped task per shard, sketch borrowed, at
//! most pool-width concurrent); the merge is order-independent, so the
//! parallel path is bit-identical to the sequential one — property-tested
//! in `rust/tests/sharded_properties.rs` against
//! [`ShardedIndex::query_fanout_sequential`].
//!
//! With `n_shards = 1` the structure degenerates to a bare [`LshIndex`]:
//! identical query results and — via [`ShardedIndex::save`], which emits
//! the plain single-index snapshot format for paper-default specs (the
//! only ones that format can encode) — byte-identical persisted
//! snapshots.

use crate::hash::Hasher32;
use crate::lsh::index::{LshIndex, LshParams};
use crate::lsh::persist;
use crate::sketch::densify::DensifyMode;
use crate::sketch::oph::{BinLayout, OneHashSketcher, OphSketch};
use crate::sketch::spec::{SketchScheme, SketchSpec};
use crate::util::binio::{BinReader, BinWriter};
use crate::util::error::{bail, format_err, Context, Result};
use crate::util::sync::lock_unpoisoned;
use crate::util::threadpool::ThreadPool;
use std::io::{BufReader, BufWriter, Read};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Seed salt separating the id→shard routing hash stream from the sketch
/// hash stream of the same spec (they share the configured family).
pub const SHARD_ROUTE_SALT: u64 = 0x5AAD_ED01;

/// Tombstone fraction at which a delete triggers an automatic shard
/// compaction (checked under the same shard lock the delete took, so the
/// rewrite races with nothing). 25% bounds both the posting-list bloat a
/// churning corpus can accumulate and the amortized rewrite cost: each
/// compaction is O(tombstones · L) targeted bucket edits, paid at most
/// once per quarter-corpus of deletes. An explicit `compact` op purges
/// unconditionally.
pub const COMPACT_TOMBSTONE_FRAC: f64 = 0.25;

/// Magic/version of the multi-shard snapshot manifest. Single-shard
/// indices are saved in the plain [`persist`] format instead (`MXLS`), so
/// `n_shards = 1` snapshots stay byte-identical to unsharded ones. The
/// manifest records the **full canonical spec string** (not just family +
/// seed), so non-default OPH layout/densify settings survive reload; the
/// single-file MXLS path inherits [`persist`]'s family+seed-only
/// provenance (paper-default layout/densify assumed), a pre-existing
/// limitation of that format.
const MANIFEST_MAGIC: u32 = 0x4D58_5348; // "MXSH"
const MANIFEST_VERSION: u8 = 1;

/// An LSH index split into N independently-locked shards.
pub struct ShardedIndex {
    params: LshParams,
    spec: SketchSpec,
    /// Routes ids to shards; built from the spec's family under
    /// [`SHARD_ROUTE_SALT`].
    router: Box<dyn Hasher32>,
    /// Shared query/insert sketcher — identical to every shard's internal
    /// sketcher (same spec), so sets are sketched once per operation, not
    /// once per shard.
    sketcher: OneHashSketcher,
    /// Arc-wrapped so threshold-triggered compactions can run as
    /// `'static` background jobs on the shared pool while the deleting
    /// connection moves on.
    shards: Vec<Arc<Mutex<LshIndex>>>,
    /// Shared worker pool for parallel shard fan-out and background
    /// compaction; `None` (the default) keeps queries sequential and
    /// compacts inline on the deleting thread. Attached by the
    /// coordinator ([`Self::set_pool`]); never serialized.
    pool: Option<Arc<ThreadPool>>,
    /// Threshold compactions completed on the pool (not explicit
    /// `compact` calls, not inline fallbacks) — surfaced in server stats
    /// as `compactions_background`.
    bg_compactions: Arc<AtomicU64>,
}

impl ShardedIndex {
    /// Build an empty sharded index: `n_shards` copies of
    /// `LshIndex::new(params, spec)` plus the routing hasher. Panics if
    /// `n_shards == 0` or the spec's scheme is not OPH (same contract as
    /// [`LshIndex::new`]).
    pub fn new(n_shards: usize, params: LshParams, spec: &SketchSpec) -> Self {
        assert!(n_shards >= 1, "ShardedIndex needs at least one shard");
        assert!(
            matches!(spec.scheme, SketchScheme::Oph(_)),
            "ShardedIndex needs an OPH sketch spec, got '{spec}'"
        );
        // Each shard's inner index builds its own (unused) sketcher —
        // ShardedIndex always sketches with the shared one. That keeps
        // LshIndex self-contained (the N=1 equivalence is with a *bare*
        // index, sketcher and all) at a bounded cost: a few KB of tables
        // per shard, once, with shard counts capped at MAX_SHARDS.
        let shards = (0..n_shards)
            .map(|_| Arc::new(Mutex::new(LshIndex::new(params, spec))))
            .collect();
        Self::assemble(params, spec, shards)
    }

    /// Wire up the routing hasher + shared sketcher around pre-built
    /// shards (construction and [`Self::load`], which already has the
    /// deserialized per-shard indices in hand).
    fn assemble(params: LshParams, spec: &SketchSpec, shards: Vec<Arc<Mutex<LshIndex>>>) -> Self {
        let sketcher = spec
            .with_oph_k(params.sketch_bins())
            .build_oph()
            .expect("caller checked the scheme is OPH");
        Self {
            params,
            spec: *spec,
            router: spec.family.build(spec.seed ^ SHARD_ROUTE_SALT),
            sketcher,
            shards,
            pool: None,
            bg_compactions: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Attach (or detach) a shared fan-out pool. With a pool and more
    /// than one shard, [`Self::query_fanout`] visits shards in parallel;
    /// results stay bit-identical to the sequential path (module docs).
    pub fn set_pool(&mut self, pool: Option<Arc<ThreadPool>>) {
        self.pool = pool;
    }

    /// Whether queries currently fan out in parallel.
    pub fn fanout_parallel(&self) -> bool {
        self.pool.is_some() && self.shards.len() > 1
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn params(&self) -> LshParams {
        self.params
    }

    /// The OPH spec every shard (and the shared sketcher) is built from.
    pub fn spec(&self) -> &SketchSpec {
        &self.spec
    }

    /// The shard an id routes to (deterministic — see module docs).
    pub fn shard_of(&self, id: u32) -> usize {
        self.router.hash(id) as usize % self.shards.len()
    }

    /// Total stored sets across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| lock_unpoisoned(s).len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stored sets per shard (diagnostics / per-shard metrics).
    pub fn per_shard_len(&self) -> Vec<usize> {
        self.shards.iter().map(|s| lock_unpoisoned(s).len()).collect()
    }

    /// Sketch a set with the shared sketcher (identical to every shard's).
    pub fn sketch(&self, set: &[u32]) -> OphSketch {
        self.sketcher.sketch(set)
    }

    /// Insert a set under `id` into its routed shard. Returns the shard
    /// index it landed in (for per-shard metrics). The shard lock is
    /// taken poison-tolerantly: `insert_sketch` cannot unwind mid-write
    /// here (its only assert checks the bin count, which the shared
    /// sketcher guarantees), so a guard recovered after an unrelated
    /// panic still protects consistent state.
    pub fn insert(&self, id: u32, set: &[u32]) -> usize {
        let sketch = self.sketch(set);
        let shard = self.shard_of(id);
        lock_unpoisoned(&self.shards[shard]).insert_sketch(id, &sketch);
        shard
    }

    /// Delete `id` from its routed shard (tombstone + query-time filter —
    /// see [`LshIndex::delete`]). Returns `(shard, existed)`.
    ///
    /// If the delete pushes the shard's tombstone fraction to
    /// [`COMPACT_TOMBSTONE_FRAC`] or beyond: with a pool attached the
    /// compaction is scheduled as a background job — the deleting
    /// connection returns immediately instead of paying the O(tombstones
    /// · L) rewrite, and the job re-checks the threshold under the shard
    /// lock (a concurrent compaction may already have cleared the
    /// backlog, so duplicate triggers coalesce into no-ops). Without a
    /// pool it compacts inline before the lock is released, exactly as
    /// before. Background completions are counted in
    /// [`Self::background_compactions`]. Tombstoned ids are filtered at
    /// query time either way, so deferral never changes results.
    pub fn delete(&self, id: u32) -> (usize, bool) {
        let shard = self.shard_of(id);
        let mut guard = lock_unpoisoned(&self.shards[shard]);
        let existed = guard.delete(id);
        if existed && guard.tombstone_fraction() >= COMPACT_TOMBSTONE_FRAC {
            match &self.pool {
                Some(pool) => {
                    drop(guard);
                    let shard_arc = Arc::clone(&self.shards[shard]);
                    let completed = Arc::clone(&self.bg_compactions);
                    pool.execute(move || {
                        let mut g = lock_unpoisoned(&shard_arc);
                        if g.tombstone_fraction() >= COMPACT_TOMBSTONE_FRAC {
                            g.compact();
                            completed.fetch_add(1, Ordering::Relaxed);
                        }
                    });
                }
                None => {
                    guard.compact();
                }
            }
        }
        (shard, existed)
    }

    /// Threshold compactions completed on the background pool (explicit
    /// [`Self::compact`] calls and inline no-pool compactions excluded).
    pub fn background_compactions(&self) -> u64 {
        self.bg_compactions.load(Ordering::Relaxed)
    }

    /// Update (upsert) `id` with new content: delete + insert under one
    /// shard lock. [`LshIndex::insert_sketch`] already purges any prior
    /// postings for the id, so this is exactly the delete+insert
    /// composition — stale entries from the superseded content are
    /// physically gone when the lock drops. Returns the shard index.
    pub fn update(&self, id: u32, set: &[u32]) -> usize {
        self.insert(id, set)
    }

    /// Physically purge every shard's tombstones ([`LshIndex::compact`]).
    /// Returns the total number of posting entries removed. Shards are
    /// compacted one lock at a time — concurrent inserts/queries on other
    /// shards proceed.
    pub fn compact(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).compact())
            .sum()
    }

    /// Total tombstoned (deleted, not yet compacted) ids across shards.
    pub fn tombstone_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).tombstone_count())
            .sum()
    }

    /// Query: sketch once, fan out to every shard, merge to the sorted,
    /// deduplicated union (identical to an unsharded index — module docs).
    pub fn query(&self, set: &[u32]) -> Vec<u32> {
        self.query_fanout(set).0
    }

    /// [`Self::query`] plus the raw per-shard candidate counts (before the
    /// merge dedup), for per-shard metrics.
    ///
    /// With a pool attached and more than one shard, the per-shard
    /// lookups run as scoped tasks on the shared [`ThreadPool`] — the
    /// sketch is borrowed (sketched once, no copies), concurrency is
    /// bounded by the pool width, and results land in shard order no
    /// matter which task finishes first, so per-shard counts and the
    /// merged union are bit-identical to the sequential path.
    pub fn query_fanout(&self, set: &[u32]) -> (Vec<u32>, Vec<usize>) {
        let sketch = self.sketch(set);
        let per_shard: Vec<Vec<u32>> = match &self.pool {
            Some(pool) if self.shards.len() > 1 => {
                let sketch = &sketch;
                pool.scope(
                    self.shards
                        .iter()
                        .map(|shard| move || lock_unpoisoned(shard).query_sketch(sketch))
                        .collect(),
                )
            }
            _ => self
                .shards
                .iter()
                .map(|shard| lock_unpoisoned(shard).query_sketch(&sketch))
                .collect(),
        };
        Self::merge(per_shard)
    }

    /// Sequential reference fan-out, ignoring any attached pool — the
    /// property tests prove [`Self::query_fanout`] bit-identical to this,
    /// and the `sharded_query` bench compares the two.
    pub fn query_fanout_sequential(&self, set: &[u32]) -> (Vec<u32>, Vec<usize>) {
        let sketch = self.sketch(set);
        Self::merge(
            self.shards
                .iter()
                .map(|shard| lock_unpoisoned(shard).query_sketch(&sketch))
                .collect(),
        )
    }

    /// Merge per-shard candidate lists (in shard order) into the sorted
    /// deduplicated union + raw per-shard counts. Sorting makes the
    /// result independent of both shard order and completion order.
    fn merge(per_shard: Vec<Vec<u32>>) -> (Vec<u32>, Vec<usize>) {
        let counts = per_shard.iter().map(Vec::len).collect();
        let mut merged = per_shard.concat();
        merged.sort_unstable();
        merged.dedup();
        (merged, counts)
    }

    /// The path shard `i`'s snapshot is written to / read from, for a
    /// multi-shard index saved at `base`.
    pub fn shard_path(base: &Path, i: usize) -> PathBuf {
        PathBuf::from(format!("{}.shard{i}", base.display()))
    }

    /// Snapshot to disk. With one shard **and a paper-default spec**
    /// (layout `mod`, densify `paper` — all the plain format can encode)
    /// this writes exactly the plain [`persist`] snapshot at `base`
    /// (byte-identical to saving the bare [`LshIndex`]); a one-shard index
    /// with non-default layout/densify takes the manifest format instead,
    /// because the plain format's family+seed-only provenance would
    /// silently reload it with the wrong sketcher. With N > 1 it writes
    /// one plain snapshot per shard at
    /// [`Self::shard_path`] and **then** the manifest at `base` — the
    /// manifest is the commit point, and every file involved (each shard
    /// snapshot via [`persist::save`], and the manifest here) is written
    /// atomically and durably: temp file, fsync, rename. An interrupted
    /// save therefore can neither leave a fresh manifest pointing at
    /// unwritten shard files nor truncate any previously valid file; the
    /// remaining (documented) gap is that a crash between shard renames
    /// leaves a mix of old and new *complete* shard snapshots under the
    /// old manifest — a consistent-per-shard but corpus-mixed cut; whole-
    /// set atomicity would need a versioned snapshot directory.
    /// Returns the number of snapshotted entries, counted under the same
    /// shard locks the bytes were written under — so the count always
    /// matches the snapshot even with concurrent inserts. (With N > 1 each
    /// *shard* is a consistent cut, but the shards are locked one at a
    /// time, not globally.)
    pub fn save(&self, base: impl AsRef<Path>) -> Result<usize> {
        let base = base.as_ref();
        let plain_encodable = matches!(
            self.spec.scheme,
            SketchScheme::Oph(p) if p.layout == BinLayout::Mod && p.densify == DensifyMode::Paper
        );
        if self.shards.len() == 1 && plain_encodable {
            let shard = lock_unpoisoned(&self.shards[0]);
            persist::save(&shard, self.spec.family, self.spec.seed, base)?;
            return Ok(shard.len());
        }
        if let Some(parent) = base.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut entries = 0;
        for (i, shard) in self.shards.iter().enumerate() {
            let shard = lock_unpoisoned(shard);
            persist::save(&shard, self.spec.family, self.spec.seed, Self::shard_path(base, i))?;
            entries += shard.len();
        }
        // The manifest is the commit point, so it must be atomic *and*
        // durable: write it to `<base>.tmp`, fsync, then rename over
        // `base`. A crash mid-save can leave a stale `.tmp` (and fresh
        // shard files under an old manifest) but never a truncated or
        // unsynced manifest claiming shard files that aren't there.
        let tmp = PathBuf::from(format!("{}.tmp", base.display()));
        let f = std::fs::File::create(&tmp)?;
        let mut w = BinWriter::new(BufWriter::new(f));
        w.u32(MANIFEST_MAGIC)?;
        w.u8(MANIFEST_VERSION)?;
        // The full canonical spec — family, seed, *and* layout/densify —
        // so reload rebuilds the exact sketcher the corpus was indexed
        // under (the shard files' own headers only carry family + seed).
        w.str(&self.spec.to_string())?;
        w.u64(self.params.k as u64)?;
        w.u64(self.params.l as u64)?;
        w.u64(self.shards.len() as u64)?;
        let mut manifest = w.finish();
        std::io::Write::flush(&mut manifest)?;
        let file = manifest
            .into_inner()
            .map_err(|e| format_err!("flush sharded manifest buffer: {e}"))?;
        file.sync_all()?;
        std::fs::rename(&tmp, base)?;
        Ok(entries)
    }

    /// Reload a snapshot written by [`Self::save`]. Sniffs the magic at
    /// `base`: a plain `MXLS` snapshot loads as a one-shard index, an
    /// `MXSH` manifest loads every shard file and checks each against the
    /// manifest's provenance (family, seed, K, L).
    pub fn load(base: impl AsRef<Path>) -> Result<ShardedIndex> {
        let base = base.as_ref();
        let mut magic_bytes = [0u8; 4];
        {
            let mut f = std::fs::File::open(base)
                .with_context(|| format!("open {}", base.display()))?;
            f.read_exact(&mut magic_bytes)
                .with_context(|| format!("read magic of {}", base.display()))?;
        }
        if u32::from_le_bytes(magic_bytes) != MANIFEST_MAGIC {
            // Plain single-index snapshot (family+seed provenance only —
            // paper-default layout/densify, as with `persist::load`).
            let (index, family, seed) = persist::load(base)?;
            let params = index.params();
            let spec = SketchSpec::oph(family, seed, params.sketch_bins());
            return Ok(Self::assemble(params, &spec, vec![Arc::new(Mutex::new(index))]));
        }
        let f = std::fs::File::open(base)?;
        let mut r = BinReader::new(BufReader::new(f));
        if r.u32()? != MANIFEST_MAGIC {
            bail!("not a sharded LSH manifest (bad magic)");
        }
        let version = r.u8()?;
        if version != MANIFEST_VERSION {
            bail!("unsupported sharded manifest version {version}");
        }
        let spec_str = r.str()?;
        let spec = SketchSpec::parse(&spec_str)
            .with_context(|| format!("bad sketch spec '{spec_str}' in sharded manifest"))?;
        if !matches!(spec.scheme, SketchScheme::Oph(_)) {
            bail!("sharded manifest spec '{spec}' is not OPH");
        }
        let k = r.u64()? as usize;
        let l = r.u64()? as usize;
        let n_shards = r.u64()? as usize;
        if n_shards == 0 {
            bail!("sharded manifest declares zero shards");
        }
        let params = LshParams::new(k, l);
        let mut shards = Vec::with_capacity(n_shards);
        for i in 0..n_shards {
            let path = Self::shard_path(base, i);
            let (index, shard_family, shard_seed) = persist::load(&path)
                .with_context(|| format!("load shard snapshot {}", path.display()))?;
            if shard_family != spec.family || shard_seed != spec.seed || index.params() != params {
                bail!(
                    "shard snapshot {} does not match manifest provenance",
                    path.display()
                );
            }
            shards.push(Arc::new(Mutex::new(index)));
        }
        Ok(Self::assemble(params, &spec, shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;

    fn spec(seed: u64) -> SketchSpec {
        SketchSpec::oph(HashFamily::MixedTab, seed, 1)
    }

    fn corpus(n: u32) -> Vec<Vec<u32>> {
        (0..n).map(|i| (i * 37..i * 37 + 60).collect()).collect()
    }

    #[test]
    fn routes_spread_and_are_stable() {
        let idx = ShardedIndex::new(4, LshParams::new(4, 4), &spec(3));
        let mut counts = [0usize; 4];
        for id in 0..400u32 {
            let s = idx.shard_of(id);
            assert_eq!(s, idx.shard_of(id), "route not stable");
            counts[s] += 1;
        }
        // The routing hash spreads ids over every shard (loose bound).
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 40, "shard {i} got only {c}/400 ids");
        }
    }

    #[test]
    fn insert_lands_in_routed_shard_only() {
        let idx = ShardedIndex::new(3, LshParams::new(3, 3), &spec(5));
        let sets = corpus(30);
        for (i, s) in sets.iter().enumerate() {
            let shard = idx.insert(i as u32, s);
            assert_eq!(shard, idx.shard_of(i as u32));
        }
        assert_eq!(idx.len(), 30);
        assert_eq!(idx.per_shard_len().iter().sum::<usize>(), 30);
        // Every stored set retrieves itself through the fan-out.
        for (i, s) in sets.iter().enumerate() {
            assert!(idx.query(s).contains(&(i as u32)), "set {i} missed itself");
        }
    }

    #[test]
    fn query_merge_is_sorted_and_deduplicated() {
        let idx = ShardedIndex::new(2, LshParams::new(2, 4), &spec(9));
        let sets = corpus(20);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let (merged, per_shard) = idx.query_fanout(&sets[0]);
        assert_eq!(per_shard.len(), 2);
        let mut expect = merged.clone();
        expect.sort_unstable();
        expect.dedup();
        assert_eq!(merged, expect);
    }

    #[test]
    fn delete_filters_and_auto_compacts() {
        let idx = ShardedIndex::new(2, LshParams::new(3, 4), &spec(21));
        let sets = corpus(40);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let (shard, existed) = idx.delete(7);
        assert!(existed);
        assert_eq!(shard, idx.shard_of(7));
        assert!(!idx.delete(7).1, "double delete reported live");
        assert!(!idx.delete(1000).1);
        assert_eq!(idx.len(), 39);
        assert!(!idx.query(&sets[7]).contains(&7));
        // Deleting a quarter of one shard's ids trips the auto-compaction
        // threshold: tombstones never exceed COMPACT_TOMBSTONE_FRAC of a
        // shard's recorded ids once the dust settles.
        for id in 0..30u32 {
            idx.delete(id);
        }
        for s in idx.shards.iter() {
            let s = lock_unpoisoned(s);
            assert!(
                s.tombstone_fraction() < COMPACT_TOMBSTONE_FRAC,
                "auto-compaction did not keep tombstones bounded"
            );
        }
        // Explicit compaction purges whatever is left.
        idx.compact();
        assert_eq!(idx.tombstone_count(), 0);
        for id in 0..30u32 {
            assert!(!idx.query(&sets[id as usize]).contains(&id));
        }
    }

    #[test]
    fn background_compaction_on_pool_keeps_tombstones_bounded() {
        let mut idx = ShardedIndex::new(2, LshParams::new(3, 4), &spec(21));
        let pool = Arc::new(ThreadPool::new(2));
        idx.set_pool(Some(Arc::clone(&pool)));
        let sets = corpus(40);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        for id in 0..30u32 {
            idx.delete(id);
            // Drain after every delete so the threshold dynamics match the
            // inline path deterministically.
            pool.wait_idle();
        }
        assert!(
            idx.background_compactions() >= 1,
            "no compaction ran on the pool"
        );
        for s in idx.shards.iter() {
            let s = lock_unpoisoned(s);
            assert!(
                s.tombstone_fraction() < COMPACT_TOMBSTONE_FRAC,
                "background compaction did not keep tombstones bounded"
            );
        }
        // Deferral never changes visibility: deleted ids stay filtered.
        for id in 0..30u32 {
            assert!(!idx.query(&sets[id as usize]).contains(&id));
        }
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn update_supersedes_across_shards() {
        let idx = ShardedIndex::new(4, LshParams::new(3, 4), &spec(23));
        let sets = corpus(20);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let replacement: Vec<u32> = (700_000..700_060).collect();
        idx.update(3, &replacement);
        assert_eq!(idx.len(), 20);
        assert!(
            !idx.query(&sets[3]).contains(&3),
            "superseded content still retrieved after update"
        );
        assert!(idx.query(&replacement).contains(&3));
    }

    #[test]
    fn multi_shard_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("mixtab_sharded_persist");
        let _ = std::fs::remove_dir_all(&dir);
        let idx = ShardedIndex::new(3, LshParams::new(3, 4), &spec(11));
        let sets = corpus(25);
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let base = dir.join("snap.mxsh");
        assert_eq!(idx.save(&base).unwrap(), idx.len());
        let loaded = ShardedIndex::load(&base).unwrap();
        assert_eq!(loaded.n_shards(), 3);
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.per_shard_len(), idx.per_shard_len());
        for s in &sets {
            assert_eq!(loaded.query(s), idx.query(s));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_commits_manifest_via_temp_rename() {
        let dir = std::env::temp_dir().join("mixtab_sharded_tmp_rename");
        let _ = std::fs::remove_dir_all(&dir);
        let idx = ShardedIndex::new(2, LshParams::new(2, 3), &spec(4));
        for (i, s) in corpus(10).iter().enumerate() {
            idx.insert(i as u32, s);
        }
        let base = dir.join("snap.mxsh");
        idx.save(&base).unwrap();
        assert!(base.exists(), "manifest missing after save");
        let tmp = PathBuf::from(format!("{}.tmp", base.display()));
        assert!(!tmp.exists(), "temp manifest left behind after rename");
        for i in 0..2 {
            let shard = ShardedIndex::shard_path(&base, i);
            assert!(shard.exists(), "shard {i} snapshot missing");
            let shard_tmp = PathBuf::from(format!("{}.tmp", shard.display()));
            assert!(!shard_tmp.exists(), "shard {i} temp file left behind");
        }
        assert!(ShardedIndex::load(&base).is_ok());
        // Re-saving over an existing snapshot also commits cleanly.
        idx.insert(99, &(0..50).collect::<Vec<_>>());
        assert_eq!(idx.save(&base).unwrap(), idx.len());
        assert!(!tmp.exists());
        assert_eq!(ShardedIndex::load(&base).unwrap().len(), idx.len());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_mismatched_and_garbage() {
        let dir = std::env::temp_dir().join("mixtab_sharded_reject");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let garbage = dir.join("garbage");
        std::fs::write(&garbage, b"zz").unwrap();
        assert!(ShardedIndex::load(&garbage).is_err());
        // Manifest whose shard files are missing.
        let idx = ShardedIndex::new(2, LshParams::new(2, 2), &spec(1));
        idx.insert(1, &(0..40).collect::<Vec<_>>());
        let base = dir.join("snap");
        idx.save(&base).unwrap();
        std::fs::remove_file(ShardedIndex::shard_path(&base, 1)).unwrap();
        assert!(ShardedIndex::load(&base).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
