//! Angular LSH over SimHash bits — the "FH [12, 2]" branch of §2.3.
//!
//! For cosine/angular similarity the practical LSH family is sign-random-
//! projection (SimHash, Charikar [12]); Andoni et al. [2] compose it with
//! feature hashing for dimensionality reduction first. This index mirrors
//! [`super::index::LshIndex`] but keys buckets on K SimHash bits per table,
//! L tables — and, like everything else in this crate, is parameterised by
//! the basic hash family that generates the ±1 projections.

use crate::data::sparse::SparseVector;
use crate::hash::HashFamily;
use crate::sketch::simhash::SimHash;
use crate::sketch::spec::SketchSpec;
use std::collections::HashMap;

/// Angular LSH parameters: K bits per table, L tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AngularParams {
    pub k: usize,
    pub l: usize,
}

/// SimHash-based LSH index over sparse vectors.
pub struct AngularIndex {
    params: AngularParams,
    sketcher: SimHash,
    tables: Vec<HashMap<u64, Vec<u32>>>,
    len: usize,
}

impl AngularIndex {
    pub fn new(params: AngularParams, family: HashFamily, seed: u64) -> Self {
        assert!(params.k >= 1 && params.k <= 64 && params.l >= 1);
        let sketcher = SketchSpec::simhash(family, seed, params.k * params.l)
            .build_simhash()
            .expect("simhash spec");
        Self {
            params,
            sketcher,
            tables: vec![HashMap::new(); params.l],
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn keys(&self, v: &SparseVector) -> Vec<u64> {
        let bits = self.sketcher.sketch(v);
        (0..self.params.l)
            .map(|l| {
                let mut key = 0u64;
                for i in 0..self.params.k {
                    key = (key << 1) | bits[l * self.params.k + i] as u64;
                }
                key
            })
            .collect()
    }

    pub fn insert(&mut self, id: u32, v: &SparseVector) {
        let keys = self.keys(v);
        for (table, key) in self.tables.iter_mut().zip(keys) {
            table.entry(key).or_default().push(id);
        }
        self.len += 1;
    }

    /// Candidates colliding in ≥ 1 table (sorted, deduplicated).
    pub fn query(&self, v: &SparseVector) -> Vec<u32> {
        let mut out = Vec::new();
        for (table, key) in self.tables.iter().zip(self.keys(v)) {
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn randvec(rng: &mut Xoshiro256, dim: u32, nnz: usize) -> SparseVector {
        SparseVector::new(
            (0..nnz).map(|_| rng.next_u32() % dim).collect(),
            (0..nnz).map(|_| rng.normal()).collect(),
        )
    }

    #[test]
    fn self_retrieval() {
        let mut rng = Xoshiro256::new(1);
        let mut idx = AngularIndex::new(AngularParams { k: 8, l: 8 }, HashFamily::MixedTab, 3);
        let vs: Vec<SparseVector> = (0..25).map(|_| randvec(&mut rng, 5000, 60)).collect();
        for (i, v) in vs.iter().enumerate() {
            idx.insert(i as u32, v);
        }
        for (i, v) in vs.iter().enumerate() {
            assert!(idx.query(v).contains(&(i as u32)), "vector {i} missed itself");
        }
    }

    #[test]
    fn correlated_vectors_collide_more() {
        let mut rng = Xoshiro256::new(5);
        let base = randvec(&mut rng, 2000, 200);
        // Near-duplicate: small perturbation.
        let near = SparseVector::new(
            base.indices.clone(),
            base.values.iter().map(|x| x + rng.normal() * 0.1).collect(),
        );
        let mut near_hits = 0;
        let mut far_hits = 0;
        for seed in 0..20u64 {
            let mut idx =
                AngularIndex::new(AngularParams { k: 10, l: 6 }, HashFamily::MixedTab, seed);
            idx.insert(0, &near);
            let far = randvec(&mut rng, 2000, 200);
            idx.insert(1, &far);
            let got = idx.query(&base);
            near_hits += got.contains(&0) as u32;
            far_hits += got.contains(&1) as u32;
        }
        assert!(
            near_hits > far_hits + 5,
            "near {near_hits} vs far {far_hits}"
        );
    }

    #[test]
    fn opposite_vector_never_collides_fully() {
        let mut rng = Xoshiro256::new(9);
        let v = randvec(&mut rng, 1000, 100);
        let neg = SparseVector::new(v.indices.clone(), v.values.iter().map(|x| -x).collect());
        let mut idx = AngularIndex::new(AngularParams { k: 12, l: 4 }, HashFamily::MixedTab, 1);
        idx.insert(0, &neg);
        // With 12 bits per key, an antipodal vector collides with
        // probability ~0 (every bit flips).
        assert!(idx.query(&v).is_empty());
    }
}
