//! Angular LSH over SimHash bits — the "FH [12, 2]" branch of §2.3.
//!
//! For cosine/angular similarity the practical LSH family is sign-random-
//! projection (SimHash, Charikar [12]); Andoni et al. [2] compose it with
//! feature hashing for dimensionality reduction first. This index mirrors
//! [`super::index::LshIndex`] but keys buckets on K SimHash bits per table,
//! L tables — and, like everything else in this crate, is parameterised by
//! a [`SketchSpec`], so both the basic hash family generating the ±1
//! projections *and* the hash-evaluation source (`pool=0` independent
//! hashers vs `pool=N` shared-pool sampling, see [`crate::hash::source`])
//! come from configuration. The structural bit count is always K·L; the
//! spec's own `bits` value is overridden via
//! [`SketchSpec::with_simhash_bits`].

use crate::data::sparse::SparseVector;
use crate::sketch::simhash::SimHash;
use crate::sketch::spec::SketchSpec;
use std::collections::HashMap;

/// Angular LSH parameters: K bits per table, L tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AngularParams {
    pub k: usize,
    pub l: usize,
}

/// SimHash-based LSH index over sparse vectors.
///
/// `insert` is an upsert keyed on id, mirroring [`super::index::LshIndex`]:
/// re-inserting a live id replaces its old bucket postings instead of
/// leaking a second copy, and `len` counts distinct ids.
pub struct AngularIndex {
    params: AngularParams,
    sketcher: SimHash,
    tables: Vec<HashMap<u64, Vec<u32>>>,
    /// id → the L bucket keys its current vector hashed to. Source of
    /// truth for membership (`len == keys.len()`) and for purging stale
    /// postings on re-insert.
    keys: HashMap<u32, Vec<u64>>,
}

impl AngularIndex {
    /// Build over a SimHash spec. The spec's bit count is overridden to
    /// the structural K·L; family, seed, and `pool` are taken from the
    /// spec. Panics if the spec is not SimHash or params are degenerate.
    pub fn new(params: AngularParams, spec: &SketchSpec) -> Self {
        assert!(params.k >= 1 && params.k <= 64 && params.l >= 1);
        let sketcher = spec
            .with_simhash_bits(params.k * params.l)
            .build_simhash()
            .expect("AngularIndex needs a SimHash sketch spec");
        Self {
            params,
            sketcher,
            tables: vec![HashMap::new(); params.l],
            keys: HashMap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    fn bucket_keys(&self, v: &SparseVector) -> Vec<u64> {
        let bits = self.sketcher.sketch(v);
        (0..self.params.l)
            .map(|l| {
                let mut key = 0u64;
                for i in 0..self.params.k {
                    key = (key << 1) | bits[l * self.params.k + i] as u64;
                }
                key
            })
            .collect()
    }

    /// Insert or replace `id`. Re-inserting identical content is a no-op;
    /// changed content purges the old postings first so each live id
    /// occurs exactly once per table.
    pub fn insert(&mut self, id: u32, v: &SparseVector) {
        let new_keys = self.bucket_keys(v);
        if let Some(old_keys) = self.keys.get(&id) {
            if *old_keys == new_keys {
                return;
            }
            let old_keys = old_keys.clone();
            self.purge_postings(id, &old_keys);
        }
        for (table, &key) in self.tables.iter_mut().zip(&new_keys) {
            table.entry(key).or_default().push(id);
        }
        self.keys.insert(id, new_keys);
    }

    /// Drop `id` from the buckets its old keys point at, removing buckets
    /// that become empty.
    fn purge_postings(&mut self, id: u32, old_keys: &[u64]) {
        for (table, &key) in self.tables.iter_mut().zip(old_keys) {
            if let Some(ids) = table.get_mut(&key) {
                ids.retain(|&x| x != id);
                if ids.is_empty() {
                    table.remove(&key);
                }
            }
        }
    }

    /// Candidates colliding in ≥ 1 table (sorted, deduplicated).
    pub fn query(&self, v: &SparseVector) -> Vec<u32> {
        let mut out = Vec::new();
        for (table, key) in self.tables.iter().zip(self.bucket_keys(v)) {
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;
    use crate::util::rng::Xoshiro256;

    fn spec(seed: u64) -> SketchSpec {
        SketchSpec::simhash(HashFamily::MixedTab, seed, 1)
    }

    fn randvec(rng: &mut Xoshiro256, dim: u32, nnz: usize) -> SparseVector {
        SparseVector::new(
            (0..nnz).map(|_| rng.next_u32() % dim).collect(),
            (0..nnz).map(|_| rng.normal()).collect(),
        )
    }

    #[test]
    fn self_retrieval() {
        let mut rng = Xoshiro256::new(1);
        let mut idx = AngularIndex::new(AngularParams { k: 8, l: 8 }, &spec(3));
        let vs: Vec<SparseVector> = (0..25).map(|_| randvec(&mut rng, 5000, 60)).collect();
        for (i, v) in vs.iter().enumerate() {
            idx.insert(i as u32, v);
        }
        assert_eq!(idx.len(), 25);
        for (i, v) in vs.iter().enumerate() {
            assert!(idx.query(v).contains(&(i as u32)), "vector {i} missed itself");
        }
    }

    #[test]
    fn reinsert_is_upsert_not_leak() {
        // Against the pre-upsert code this fails twice over: len double-counts
        // and the stale postings keep the old vector retrievable.
        let mut rng = Xoshiro256::new(2);
        let mut idx = AngularIndex::new(AngularParams { k: 6, l: 8 }, &spec(7));
        let a = randvec(&mut rng, 2000, 150);
        let b = SparseVector::new(a.indices.clone(), a.values.iter().map(|x| -x).collect());
        idx.insert(0, &a);
        idx.insert(0, &a); // identical re-insert: no-op
        idx.insert(0, &b); // changed content: supersedes
        assert_eq!(idx.len(), 1);
        for (l, table) in idx.tables.iter().enumerate() {
            let occurrences: usize = table
                .values()
                .map(|ids| ids.iter().filter(|&&id| id == 0).count())
                .sum();
            assert_eq!(occurrences, 1, "table {l} posts id 0 {occurrences} times");
        }
        // The live content is b's; a (antipodal, every bit flipped) must not
        // reach id 0 through stale postings.
        assert!(idx.query(&b).contains(&0));
        assert!(!idx.query(&a).contains(&0));
    }

    #[test]
    fn correlated_vectors_collide_more() {
        let mut rng = Xoshiro256::new(5);
        let base = randvec(&mut rng, 2000, 200);
        // Near-duplicate: small perturbation.
        let near = SparseVector::new(
            base.indices.clone(),
            base.values.iter().map(|x| x + rng.normal() * 0.1).collect(),
        );
        let mut near_hits = 0;
        let mut far_hits = 0;
        for seed in 0..20u64 {
            let mut idx = AngularIndex::new(AngularParams { k: 10, l: 6 }, &spec(seed));
            idx.insert(0, &near);
            let far = randvec(&mut rng, 2000, 200);
            idx.insert(1, &far);
            let got = idx.query(&base);
            near_hits += got.contains(&0) as u32;
            far_hits += got.contains(&1) as u32;
        }
        assert!(
            near_hits > far_hits + 5,
            "near {near_hits} vs far {far_hits}"
        );
    }

    #[test]
    fn opposite_vector_never_collides_fully() {
        let mut rng = Xoshiro256::new(9);
        let v = randvec(&mut rng, 1000, 100);
        let neg = SparseVector::new(v.indices.clone(), v.values.iter().map(|x| -x).collect());
        let mut idx = AngularIndex::new(AngularParams { k: 12, l: 4 }, &spec(1));
        idx.insert(0, &neg);
        // With 12 bits per key, an antipodal vector collides with
        // probability ~0 (every bit flips).
        assert!(idx.query(&v).is_empty());
    }

    /// Fig-5-style recall parity: pooled SimHash bits must buy their O(pool)
    /// sketch cost without giving up recall. Planted near-duplicates at
    /// cos ≈ 0.97 with (K=6, L=12) put per-query recall ≈ 1 for independent
    /// bits (miss ≈ 0.39^12 ≈ 1e-5); the pooled source's correlated bits
    /// must stay within 0.02 absolute at the same structural parameters.
    #[test]
    fn pooled_recall_parity_with_independent_bits() {
        let params = AngularParams { k: 6, l: 12 };
        let n: usize = 40;
        let mut rng = Xoshiro256::new(33);
        let mut recalls = [0.0f64; 2]; // [independent, pooled]
        let seeds = 5u64;
        for seed in 0..seeds {
            let base: Vec<SparseVector> = (0..n).map(|_| randvec(&mut rng, 4000, 200)).collect();
            // Queries: base + noise, cos ≈ 1/sqrt(1 + 0.25²) ≈ 0.97.
            let queries: Vec<SparseVector> = base
                .iter()
                .map(|v| {
                    SparseVector::new(
                        v.indices.clone(),
                        v.values.iter().map(|x| x + rng.normal() * 0.25).collect(),
                    )
                })
                .collect();
            let specs = [
                SketchSpec::simhash(HashFamily::MixedTab, seed, 1),
                SketchSpec::simhash_pooled(HashFamily::MixedTab, seed, 1, 256),
            ];
            for (r, sp) in recalls.iter_mut().zip(&specs) {
                let mut idx = AngularIndex::new(params, sp);
                for (i, v) in base.iter().enumerate() {
                    idx.insert(i as u32, v);
                }
                let hits = queries
                    .iter()
                    .enumerate()
                    .filter(|(i, q)| idx.query(q).contains(&(*i as u32)))
                    .count();
                *r += hits as f64 / (n as f64 * seeds as f64);
            }
        }
        let [indep, pooled] = recalls;
        assert!(indep >= 0.9, "independent recall {indep}");
        assert!(pooled >= 0.9, "pooled recall {pooled}");
        assert!(
            (indep - pooled).abs() <= 0.02,
            "recall gap: independent {indep} vs pooled {pooled}"
        );
    }
}
