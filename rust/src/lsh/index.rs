//! The (K, L) LSH index over OPH sketches.
//!
//! Each stored set gets **one** OPH sketch with `k = K·L` densified bins
//! (one hash evaluation per element — the whole point of OPH, [32]); table
//! `l` keys on bins `[lK, (l+1)K)`. A query retrieves the union of its L
//! buckets. Larger K → fewer false positives per table; larger L → more
//! chances for a true near neighbour to collide (§2.3).

use crate::sketch::oph::{OneHashSketcher, OphSketch};
use crate::sketch::spec::{SketchScheme, SketchSpec};
use std::collections::{HashMap, HashSet};

/// LSH structural parameters (paper sweeps K, L ∈ {8, 10, 12}).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LshParams {
    pub k: usize,
    pub l: usize,
}

impl LshParams {
    pub fn new(k: usize, l: usize) -> Self {
        assert!(k >= 1 && l >= 1);
        Self { k, l }
    }

    /// Total OPH bins needed.
    pub fn sketch_bins(&self) -> usize {
        self.k * self.l
    }
}

/// Combine K bin values into one 64-bit bucket key (FNV-1a over the bytes;
/// keys only need to separate distinct K-tuples).
fn bucket_key(bins: &[u64]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &v in bins {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// An LSH index over sets of `u32` ids, supporting deletes via
/// tombstones.
///
/// Mutation model (DESIGN.md §3.7): `insert` is an **upsert** — if the id
/// already holds postings (live or tombstoned), the old entries are
/// purged first via the recorded per-id bucket keys, so a superseded
/// sketch can never serve stale candidates. `delete` is O(1) metadata
/// (tombstone + query-time filter); the physical posting rewrite is
/// deferred to [`LshIndex::compact`].
pub struct LshIndex {
    params: LshParams,
    sketcher: OneHashSketcher,
    /// `tables[l]: bucket key → ids`.
    tables: Vec<HashMap<u64, Vec<u32>>>,
    /// Per-id bucket keys recorded at insert time (live **and**
    /// tombstoned ids) — what makes targeted purges O(L) instead of a
    /// full table scan.
    keys: HashMap<u32, Vec<u64>>,
    /// Ids logically deleted; their postings remain until [`Self::compact`]
    /// and are filtered out of every query.
    tombstones: HashSet<u32>,
}

impl LshIndex {
    /// Build an empty index from an OPH [`SketchSpec`] — the hash family
    /// and seed are the paper's experimental variable; the spec's bin
    /// count is overridden to `params.sketch_bins()` (the structural
    /// (K, L) parameters dictate it). Panics if the spec's scheme is not
    /// OPH — the (K, L) bucket construction is defined over OPH bins.
    pub fn new(params: LshParams, spec: &SketchSpec) -> Self {
        assert!(
            matches!(spec.scheme, SketchScheme::Oph(_)),
            "LshIndex needs an OPH sketch spec, got '{spec}'"
        );
        let sketcher = spec
            .with_oph_k(params.sketch_bins())
            .build_oph()
            .expect("scheme checked above");
        Self {
            params,
            sketcher,
            tables: vec![HashMap::new(); params.l],
            keys: HashMap::new(),
            tombstones: HashSet::new(),
        }
    }

    pub fn params(&self) -> LshParams {
        self.params
    }

    /// Number of **live** sets (tombstoned ids excluded) — exact at all
    /// times, including between a delete and the compaction that purges
    /// its postings.
    pub fn len(&self) -> usize {
        self.keys.len() - self.tombstones.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ids deleted but not yet physically purged.
    pub fn tombstone_count(&self) -> usize {
        self.tombstones.len()
    }

    /// Tombstoned fraction of all recorded ids (0 for an empty index) —
    /// the compaction trigger signal.
    pub fn tombstone_fraction(&self) -> f64 {
        if self.keys.is_empty() {
            return 0.0;
        }
        self.tombstones.len() as f64 / self.keys.len() as f64
    }

    /// Sketch a set with this index's sketcher.
    pub fn sketch(&self, set: &[u32]) -> OphSketch {
        self.sketcher.sketch(set)
    }

    /// Insert a set under `id`.
    pub fn insert(&mut self, id: u32, set: &[u32]) {
        let s = self.sketch(set);
        self.insert_sketch(id, &s);
    }

    /// The L bucket keys a sketch lands in.
    fn sketch_keys(&self, s: &OphSketch) -> Vec<u64> {
        (0..self.params.l)
            .map(|l| bucket_key(&s.bins[l * self.params.k..(l + 1) * self.params.k]))
            .collect()
    }

    /// Insert a pre-computed sketch (the coordinator's worker pool sketches
    /// off-thread and inserts here).
    ///
    /// This is an **upsert**: re-inserting a live id with identical
    /// content is a no-op (postings and `len` unchanged), and any prior
    /// postings under this id — a live id re-inserted with different
    /// content, or a tombstoned id being resurrected — are purged before
    /// the new ones land. Duplicate posting entries are therefore
    /// structurally impossible.
    pub fn insert_sketch(&mut self, id: u32, s: &OphSketch) {
        assert_eq!(s.k(), self.params.sketch_bins());
        let new_keys = self.sketch_keys(s);
        if let Some(old_keys) = self.keys.get(&id) {
            let resurrected = self.tombstones.remove(&id);
            if !resurrected && *old_keys == new_keys {
                return; // idempotent re-insert of identical content
            }
            let old_keys = old_keys.clone();
            self.purge_postings(id, &old_keys);
        }
        for (table, &key) in self.tables.iter_mut().zip(&new_keys) {
            table.entry(key).or_default().push(id);
        }
        self.keys.insert(id, new_keys);
    }

    /// Logically delete `id`: O(1) — the id is tombstoned and filtered
    /// from every query; its posting entries stay until [`Self::compact`].
    /// Returns whether the id was live.
    pub fn delete(&mut self, id: u32) -> bool {
        self.keys.contains_key(&id) && self.tombstones.insert(id)
    }

    /// Remove `id`'s posting entries from the buckets named by `keys`,
    /// dropping buckets that become empty (a freshly built index never
    /// holds an empty bucket, and compaction must match it bit for bit).
    fn purge_postings(&mut self, id: u32, keys: &[u64]) -> usize {
        let mut purged = 0;
        for (table, key) in self.tables.iter_mut().zip(keys) {
            if let Some(ids) = table.get_mut(key) {
                let before = ids.len();
                ids.retain(|&x| x != id);
                purged += before - ids.len();
                if ids.is_empty() {
                    table.remove(key);
                }
            }
        }
        purged
    }

    /// Physically purge every tombstoned id's postings and forget its
    /// keys, leaving the index bit-identical to one freshly built over
    /// the surviving corpus (in original insertion order). Returns the
    /// number of posting entries removed.
    pub fn compact(&mut self) -> usize {
        let dead: Vec<u32> = self.tombstones.drain().collect();
        let mut purged = 0;
        for id in dead {
            if let Some(keys) = self.keys.remove(&id) {
                purged += self.purge_postings(id, &keys);
            }
        }
        purged
    }

    /// Query: ids colliding with `set` in ≥ 1 table (deduplicated, sorted).
    pub fn query(&self, set: &[u32]) -> Vec<u32> {
        self.query_sketch(&self.sketch(set))
    }

    /// Query with a pre-computed sketch. Tombstoned ids are filtered out
    /// — a deleted id never surfaces, compacted or not.
    pub fn query_sketch(&self, s: &OphSketch) -> Vec<u32> {
        assert_eq!(s.k(), self.params.sketch_bins());
        let mut out: Vec<u32> = Vec::new();
        for (l, table) in self.tables.iter().enumerate() {
            let key = bucket_key(&s.bins[l * self.params.k..(l + 1) * self.params.k]);
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        if !self.tombstones.is_empty() {
            out.retain(|id| !self.tombstones.contains(id));
        }
        out
    }

    /// Total buckets across tables (diagnostics).
    pub fn bucket_count(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    /// Raw table access for snapshotting ([`super::persist`]).
    pub fn tables_raw(&self) -> &[HashMap<u64, Vec<u32>>] {
        &self.tables
    }

    /// Per-id bucket keys for snapshotting ([`super::persist`]).
    pub fn keys_raw(&self) -> &HashMap<u32, Vec<u64>> {
        &self.keys
    }

    /// Tombstoned ids for snapshotting ([`super::persist`]).
    pub fn tombstones_raw(&self) -> &HashSet<u32> {
        &self.tombstones
    }

    /// Replace contents from a snapshot ([`super::persist`]). The caller
    /// guarantees the tables were produced by an identically-seeded index
    /// (same family, seed, K, L) — enforced by the snapshot header — and
    /// that `keys` records every id's L bucket keys with
    /// `tombstones ⊆ keys`.
    pub fn restore_raw(
        &mut self,
        tables: Vec<HashMap<u64, Vec<u32>>>,
        keys: HashMap<u32, Vec<u64>>,
        tombstones: HashSet<u32>,
    ) {
        assert_eq!(tables.len(), self.params.l);
        debug_assert!(keys.values().all(|k| k.len() == self.params.l));
        debug_assert!(tombstones.iter().all(|id| keys.contains_key(id)));
        self.tables = tables;
        self.keys = keys;
        self.tombstones = tombstones;
    }

    /// Size of the largest bucket (diagnostics; weak hash functions produce
    /// heavy buckets on structured data — the Figure 5 failure mode).
    pub fn max_bucket(&self) -> usize {
        self.tables
            .iter()
            .flat_map(|t| t.values().map(Vec::len))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::dataset1;
    use crate::hash::HashFamily;
    use crate::util::rng::Xoshiro256;

    /// Bin count is overridden by the index, so any positive k works here.
    fn oph_spec(seed: u64) -> SketchSpec {
        SketchSpec::oph(HashFamily::MixedTab, seed, 1)
    }

    #[test]
    fn self_query_hits() {
        let mut idx = LshIndex::new(LshParams::new(4, 4), &oph_spec(1));
        let sets: Vec<Vec<u32>> = (0..20u32)
            .map(|i| (i * 50..i * 50 + 40).collect())
            .collect();
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        assert_eq!(idx.len(), 20);
        // A stored set always retrieves itself (identical sketch).
        for (i, s) in sets.iter().enumerate() {
            let got = idx.query(s);
            assert!(got.contains(&(i as u32)), "set {i} missed itself");
        }
    }

    #[test]
    fn near_duplicates_retrieved_distant_sets_mostly_not() {
        let mut rng = Xoshiro256::new(3);
        let mut idx = LshIndex::new(LshParams::new(8, 10), &oph_spec(7));
        // Database: 50 random sets + one near-duplicate of the query.
        let query: Vec<u32> = (0..400u32).collect();
        let mut near = query.clone();
        for i in 0..20 {
            near[i as usize] = 100_000 + i; // J ≈ 0.905
        }
        idx.insert(0, &near);
        for i in 1..51u32 {
            let set: Vec<u32> = (0..400).map(|_| rng.next_u32() % 1_000_000).collect();
            idx.insert(i, &set);
        }
        let got = idx.query(&query);
        assert!(got.contains(&0), "near-duplicate not retrieved");
        // Unrelated sets: tolerate a few accidental collisions.
        assert!(got.len() <= 5, "retrieved too many: {}", got.len());
    }

    #[test]
    fn more_tables_more_recall() {
        // Recall of a moderately-similar pair increases with L.
        let mut rng = Xoshiro256::new(9);
        let pairs: Vec<_> = (0..40).map(|_| dataset1(300, true, &mut rng)).collect();
        let mut hits_l2 = 0;
        let mut hits_l16 = 0;
        for (i, p) in pairs.iter().enumerate() {
            let seed = 1000 + i as u64;
            let mut small = LshIndex::new(LshParams::new(6, 2), &oph_spec(seed));
            small.insert(1, &p.a);
            hits_l2 += small.query(&p.b).contains(&1) as u32;
            let mut big = LshIndex::new(LshParams::new(6, 16), &oph_spec(seed));
            big.insert(1, &p.a);
            hits_l16 += big.query(&p.b).contains(&1) as u32;
        }
        assert!(
            hits_l16 > hits_l2,
            "L=16 hits {hits_l16} should beat L=2 hits {hits_l2}"
        );
    }

    #[test]
    fn larger_k_fewer_false_positives() {
        let mut rng = Xoshiro256::new(21);
        // Moderate similarity (J ≈ 0.6): K = 1 collides per-table w.p. ≈ J,
        // K = 8 w.p. ≈ J^8 — the selectivity the test asserts.
        let core: Vec<u32> = (0..150u32).collect();
        let db: Vec<Vec<u32>> = (0..100)
            .map(|_| {
                let mut s = core.clone();
                s.extend((0..50).map(|_| 1000 + rng.next_u32() % 100_000));
                s
            })
            .collect();
        let mut query: Vec<u32> = core.clone();
        query.extend((0..50).map(|_| 1000 + rng.next_u32() % 100_000));
        let mut retrieved_k1 = 0usize;
        let mut retrieved_k8 = 0usize;
        for seed in 0..5 {
            let mut k1 = LshIndex::new(LshParams::new(1, 4), &oph_spec(seed));
            let mut k8 = LshIndex::new(LshParams::new(8, 4), &oph_spec(seed));
            for (i, s) in db.iter().enumerate() {
                k1.insert(i as u32, s);
                k8.insert(i as u32, s);
            }
            retrieved_k1 += k1.query(&query).len();
            retrieved_k8 += k8.query(&query).len();
        }
        assert!(
            retrieved_k8 < retrieved_k1,
            "K=8 retrieved {retrieved_k8} should be < K=1 retrieved {retrieved_k1}"
        );
    }

    #[test]
    fn sketch_insert_query_roundtrip() {
        let mut idx = LshIndex::new(LshParams::new(3, 3), &oph_spec(2));
        let set: Vec<u32> = (100..200).collect();
        let sk = idx.sketch(&set);
        idx.insert_sketch(42, &sk);
        assert_eq!(idx.query_sketch(&sk), vec![42]);
        assert!(idx.bucket_count() >= 1);
        assert!(idx.max_bucket() >= 1);
    }

    /// Regression for the duplicate-insert posting leak: before the
    /// upsert fix, re-inserting an id pushed a second copy into every
    /// bucket and double-counted `len`; re-inserting with *different*
    /// content left the old sketch's entries serving stale candidates.
    #[test]
    fn reinsert_is_idempotent_and_supersedes() {
        let mut idx = LshIndex::new(LshParams::new(4, 6), &oph_spec(11));
        let a: Vec<u32> = (0..120).collect();
        let b: Vec<u32> = (500_000..500_120).collect();
        idx.insert(7, &a);
        let tables_once = idx.tables_raw().to_vec();

        // Same id, same content: postings and len must not change.
        idx.insert(7, &a);
        assert_eq!(idx.len(), 1, "re-insert double-counted len");
        assert_eq!(
            idx.tables_raw(),
            &tables_once[..],
            "re-insert duplicated posting entries"
        );

        // Same id, different content: the old sketch's buckets must stop
        // serving the id (no superseded candidates), the new ones start.
        idx.insert(7, &b);
        assert_eq!(idx.len(), 1);
        assert!(
            !idx.query(&a).contains(&7),
            "superseded content still retrieved"
        );
        assert!(idx.query(&b).contains(&7));
    }

    #[test]
    fn delete_tombstones_then_compact_purges() {
        let mut idx = LshIndex::new(LshParams::new(4, 6), &oph_spec(13));
        let sets: Vec<Vec<u32>> = (0..30u32).map(|i| (i * 40..i * 40 + 35).collect()).collect();
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        assert!(idx.delete(5));
        assert!(!idx.delete(5), "double delete reported live");
        assert!(!idx.delete(999), "deleting an unknown id reported live");
        assert_eq!(idx.len(), 29);
        assert_eq!(idx.tombstone_count(), 1);
        assert!((idx.tombstone_fraction() - 1.0 / 30.0).abs() < 1e-12);
        assert!(
            !idx.query(&sets[5]).contains(&5),
            "tombstoned id surfaced pre-compaction"
        );

        let purged = idx.compact();
        assert_eq!(purged, idx.params().l, "one posting entry per table");
        assert_eq!(idx.tombstone_count(), 0);
        assert!(!idx.query(&sets[5]).contains(&5));

        // Compaction leaves the index bit-identical to a fresh build over
        // the survivors in original insertion order.
        let mut fresh = LshIndex::new(LshParams::new(4, 6), &oph_spec(13));
        for (i, s) in sets.iter().enumerate() {
            if i != 5 {
                fresh.insert(i as u32, s);
            }
        }
        assert_eq!(idx.tables_raw(), fresh.tables_raw());
        assert_eq!(idx.len(), fresh.len());
    }

    #[test]
    fn delete_then_reinsert_resurrects_cleanly() {
        let mut idx = LshIndex::new(LshParams::new(3, 4), &oph_spec(17));
        let a: Vec<u32> = (0..90).collect();
        let b: Vec<u32> = (200_000..200_090).collect();
        idx.insert(1, &a);
        idx.delete(1);
        // Resurrect under different content: the pre-delete postings must
        // be purged, not merely unfiltered — otherwise queries near the
        // old content would surface the id again.
        idx.insert(1, &b);
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.tombstone_count(), 0);
        assert!(!idx.query(&a).contains(&1), "pre-delete postings leaked");
        assert!(idx.query(&b).contains(&1));
    }
}
