//! The (K, L) LSH index over OPH sketches.
//!
//! Each stored set gets **one** OPH sketch with `k = K·L` densified bins
//! (one hash evaluation per element — the whole point of OPH, [32]); table
//! `l` keys on bins `[lK, (l+1)K)`. A query retrieves the union of its L
//! buckets. Larger K → fewer false positives per table; larger L → more
//! chances for a true near neighbour to collide (§2.3).

use crate::sketch::oph::{OneHashSketcher, OphSketch};
use crate::sketch::spec::{SketchScheme, SketchSpec};
use std::collections::HashMap;

/// LSH structural parameters (paper sweeps K, L ∈ {8, 10, 12}).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LshParams {
    pub k: usize,
    pub l: usize,
}

impl LshParams {
    pub fn new(k: usize, l: usize) -> Self {
        assert!(k >= 1 && l >= 1);
        Self { k, l }
    }

    /// Total OPH bins needed.
    pub fn sketch_bins(&self) -> usize {
        self.k * self.l
    }
}

/// Combine K bin values into one 64-bit bucket key (FNV-1a over the bytes;
/// keys only need to separate distinct K-tuples).
fn bucket_key(bins: &[u64]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &v in bins {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// An LSH index over sets of `u32` ids.
pub struct LshIndex {
    params: LshParams,
    sketcher: OneHashSketcher,
    /// `tables[l]: bucket key → ids`.
    tables: Vec<HashMap<u64, Vec<u32>>>,
    /// Number of indexed sets.
    len: usize,
}

impl LshIndex {
    /// Build an empty index from an OPH [`SketchSpec`] — the hash family
    /// and seed are the paper's experimental variable; the spec's bin
    /// count is overridden to `params.sketch_bins()` (the structural
    /// (K, L) parameters dictate it). Panics if the spec's scheme is not
    /// OPH — the (K, L) bucket construction is defined over OPH bins.
    pub fn new(params: LshParams, spec: &SketchSpec) -> Self {
        assert!(
            matches!(spec.scheme, SketchScheme::Oph(_)),
            "LshIndex needs an OPH sketch spec, got '{spec}'"
        );
        let sketcher = spec
            .with_oph_k(params.sketch_bins())
            .build_oph()
            .expect("scheme checked above");
        Self {
            params,
            sketcher,
            tables: vec![HashMap::new(); params.l],
            len: 0,
        }
    }

    pub fn params(&self) -> LshParams {
        self.params
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Sketch a set with this index's sketcher.
    pub fn sketch(&self, set: &[u32]) -> OphSketch {
        self.sketcher.sketch(set)
    }

    /// Insert a set under `id`.
    pub fn insert(&mut self, id: u32, set: &[u32]) {
        let s = self.sketch(set);
        self.insert_sketch(id, &s);
    }

    /// Insert a pre-computed sketch (the coordinator's worker pool sketches
    /// off-thread and inserts here).
    pub fn insert_sketch(&mut self, id: u32, s: &OphSketch) {
        assert_eq!(s.k(), self.params.sketch_bins());
        for (l, table) in self.tables.iter_mut().enumerate() {
            let key = bucket_key(&s.bins[l * self.params.k..(l + 1) * self.params.k]);
            table.entry(key).or_default().push(id);
        }
        self.len += 1;
    }

    /// Query: ids colliding with `set` in ≥ 1 table (deduplicated, sorted).
    pub fn query(&self, set: &[u32]) -> Vec<u32> {
        self.query_sketch(&self.sketch(set))
    }

    /// Query with a pre-computed sketch.
    pub fn query_sketch(&self, s: &OphSketch) -> Vec<u32> {
        assert_eq!(s.k(), self.params.sketch_bins());
        let mut out: Vec<u32> = Vec::new();
        for (l, table) in self.tables.iter().enumerate() {
            let key = bucket_key(&s.bins[l * self.params.k..(l + 1) * self.params.k]);
            if let Some(ids) = table.get(&key) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Total buckets across tables (diagnostics).
    pub fn bucket_count(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    /// Raw table access for snapshotting ([`super::persist`]).
    pub fn tables_raw(&self) -> &[HashMap<u64, Vec<u32>>] {
        &self.tables
    }

    /// Replace table contents from a snapshot ([`super::persist`]). The
    /// caller guarantees the tables were produced by an identically-seeded
    /// index (same family, seed, K, L) — enforced by the snapshot header.
    pub fn restore_raw(&mut self, tables: Vec<HashMap<u64, Vec<u32>>>, len: usize) {
        assert_eq!(tables.len(), self.params.l);
        self.tables = tables;
        self.len = len;
    }

    /// Size of the largest bucket (diagnostics; weak hash functions produce
    /// heavy buckets on structured data — the Figure 5 failure mode).
    pub fn max_bucket(&self) -> usize {
        self.tables
            .iter()
            .flat_map(|t| t.values().map(Vec::len))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::dataset1;
    use crate::hash::HashFamily;
    use crate::util::rng::Xoshiro256;

    /// Bin count is overridden by the index, so any positive k works here.
    fn oph_spec(seed: u64) -> SketchSpec {
        SketchSpec::oph(HashFamily::MixedTab, seed, 1)
    }

    #[test]
    fn self_query_hits() {
        let mut idx = LshIndex::new(LshParams::new(4, 4), &oph_spec(1));
        let sets: Vec<Vec<u32>> = (0..20u32)
            .map(|i| (i * 50..i * 50 + 40).collect())
            .collect();
        for (i, s) in sets.iter().enumerate() {
            idx.insert(i as u32, s);
        }
        assert_eq!(idx.len(), 20);
        // A stored set always retrieves itself (identical sketch).
        for (i, s) in sets.iter().enumerate() {
            let got = idx.query(s);
            assert!(got.contains(&(i as u32)), "set {i} missed itself");
        }
    }

    #[test]
    fn near_duplicates_retrieved_distant_sets_mostly_not() {
        let mut rng = Xoshiro256::new(3);
        let mut idx = LshIndex::new(LshParams::new(8, 10), &oph_spec(7));
        // Database: 50 random sets + one near-duplicate of the query.
        let query: Vec<u32> = (0..400u32).collect();
        let mut near = query.clone();
        for i in 0..20 {
            near[i as usize] = 100_000 + i; // J ≈ 0.905
        }
        idx.insert(0, &near);
        for i in 1..51u32 {
            let set: Vec<u32> = (0..400).map(|_| rng.next_u32() % 1_000_000).collect();
            idx.insert(i, &set);
        }
        let got = idx.query(&query);
        assert!(got.contains(&0), "near-duplicate not retrieved");
        // Unrelated sets: tolerate a few accidental collisions.
        assert!(got.len() <= 5, "retrieved too many: {}", got.len());
    }

    #[test]
    fn more_tables_more_recall() {
        // Recall of a moderately-similar pair increases with L.
        let mut rng = Xoshiro256::new(9);
        let pairs: Vec<_> = (0..40).map(|_| dataset1(300, true, &mut rng)).collect();
        let mut hits_l2 = 0;
        let mut hits_l16 = 0;
        for (i, p) in pairs.iter().enumerate() {
            let seed = 1000 + i as u64;
            let mut small = LshIndex::new(LshParams::new(6, 2), &oph_spec(seed));
            small.insert(1, &p.a);
            hits_l2 += small.query(&p.b).contains(&1) as u32;
            let mut big = LshIndex::new(LshParams::new(6, 16), &oph_spec(seed));
            big.insert(1, &p.a);
            hits_l16 += big.query(&p.b).contains(&1) as u32;
        }
        assert!(
            hits_l16 > hits_l2,
            "L=16 hits {hits_l16} should beat L=2 hits {hits_l2}"
        );
    }

    #[test]
    fn larger_k_fewer_false_positives() {
        let mut rng = Xoshiro256::new(21);
        // Moderate similarity (J ≈ 0.6): K = 1 collides per-table w.p. ≈ J,
        // K = 8 w.p. ≈ J^8 — the selectivity the test asserts.
        let core: Vec<u32> = (0..150u32).collect();
        let db: Vec<Vec<u32>> = (0..100)
            .map(|_| {
                let mut s = core.clone();
                s.extend((0..50).map(|_| 1000 + rng.next_u32() % 100_000));
                s
            })
            .collect();
        let mut query: Vec<u32> = core.clone();
        query.extend((0..50).map(|_| 1000 + rng.next_u32() % 100_000));
        let mut retrieved_k1 = 0usize;
        let mut retrieved_k8 = 0usize;
        for seed in 0..5 {
            let mut k1 = LshIndex::new(LshParams::new(1, 4), &oph_spec(seed));
            let mut k8 = LshIndex::new(LshParams::new(8, 4), &oph_spec(seed));
            for (i, s) in db.iter().enumerate() {
                k1.insert(i as u32, s);
                k8.insert(i as u32, s);
            }
            retrieved_k1 += k1.query(&query).len();
            retrieved_k8 += k8.query(&query).len();
        }
        assert!(
            retrieved_k8 < retrieved_k1,
            "K=8 retrieved {retrieved_k8} should be < K=1 retrieved {retrieved_k1}"
        );
    }

    #[test]
    fn sketch_insert_query_roundtrip() {
        let mut idx = LshIndex::new(LshParams::new(3, 3), &oph_spec(2));
        let set: Vec<u32> = (100..200).collect();
        let sk = idx.sketch(&set);
        idx.insert_sketch(42, &sk);
        assert_eq!(idx.query_sketch(&sk), vec![42]);
        assert!(idx.bucket_count() >= 1);
        assert!(idx.max_bucket() >= 1);
    }
}
