//! Bounded top-k selection for re-rank serving (after clann's k-NN
//! `MaxHeap`: keep the k best seen so far in a size-capped binary heap
//! whose root is the current worst, so each candidate costs one peek and
//! at most one push/pop).
//!
//! `query_topk` retrieves an LSH candidate set, scores every candidate's
//! stored sketch against the query sketch, and needs the k highest
//! scores in deterministic order. Scores are estimator outputs (f64 in
//! [0, 1]); ties are broken toward the **smaller id** so results are
//! reproducible across runs, shard counts, and merge orders.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scored candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scored {
    pub id: u32,
    pub score: f64,
}

impl Eq for Scored {}

impl Ord for Scored {
    /// Total order: higher score ranks higher; equal scores rank the
    /// smaller id higher. `f64::total_cmp` keeps the order total even if
    /// an estimator ever emits NaN (it sorts below every real score).
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for Scored {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// A bounded top-k accumulator: O(log k) per offered candidate, O(k)
/// memory regardless of candidate-set size.
#[derive(Debug)]
pub struct TopK {
    k: usize,
    /// Min-heap (via [`std::cmp::Reverse`]) of the best k seen: the root
    /// is the *worst* kept entry — the bar a new candidate must clear.
    heap: BinaryHeap<std::cmp::Reverse<Scored>>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self {
            k,
            heap: BinaryHeap::with_capacity(k.saturating_add(1)),
        }
    }

    /// Offer a candidate; it is kept iff it beats the current worst of a
    /// full heap (or the heap has room).
    pub fn offer(&mut self, id: u32, score: f64) {
        if self.k == 0 {
            return;
        }
        let entry = Scored { id, score };
        if self.heap.len() < self.k {
            self.heap.push(std::cmp::Reverse(entry));
        } else if self
            .heap
            .peek()
            .is_some_and(|worst| entry > worst.0)
        {
            self.heap.pop();
            self.heap.push(std::cmp::Reverse(entry));
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The kept candidates, best first (score descending, ties by
    /// ascending id).
    pub fn into_sorted(self) -> Vec<Scored> {
        let mut out: Vec<Scored> = self.heap.into_iter().map(|r| r.0).collect();
        out.sort_unstable_by(|a, b| b.cmp(a));
        out
    }

    /// Merge the contents of another bounded selection into this one
    /// (the router's cross-backend top-k merge). Duplicate ids must be
    /// deduplicated by the caller if the sources can overlap.
    pub fn absorb(&mut self, other: TopK) {
        for std::cmp::Reverse(s) in other.heap {
            self.offer(s.id, s.score);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_k_best_in_order() {
        let mut t = TopK::new(3);
        for (id, score) in [(1, 0.2), (2, 0.9), (3, 0.5), (4, 0.7), (5, 0.1)] {
            t.offer(id, score);
        }
        let got = t.into_sorted();
        assert_eq!(
            got.iter().map(|s| s.id).collect::<Vec<_>>(),
            vec![2, 4, 3],
            "{got:?}"
        );
        assert!(got[0].score >= got[1].score && got[1].score >= got[2].score);
    }

    #[test]
    fn ties_break_toward_smaller_id() {
        let mut t = TopK::new(2);
        for id in [9, 3, 7, 1] {
            t.offer(id, 0.5);
        }
        let ids: Vec<u32> = t.into_sorted().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 3]);
    }

    #[test]
    fn fewer_candidates_than_k() {
        let mut t = TopK::new(10);
        t.offer(4, 0.4);
        t.offer(2, 0.8);
        let ids: Vec<u32> = t.into_sorted().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 4]);
    }

    #[test]
    fn k_zero_keeps_nothing() {
        let mut t = TopK::new(0);
        t.offer(1, 1.0);
        assert!(t.is_empty());
        assert!(t.into_sorted().is_empty());
    }

    #[test]
    fn absorb_merges_selections() {
        let mut a = TopK::new(2);
        a.offer(1, 0.3);
        a.offer(2, 0.6);
        let mut b = TopK::new(2);
        b.offer(3, 0.9);
        b.offer(4, 0.1);
        a.absorb(b);
        let ids: Vec<u32> = a.into_sorted().iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![3, 2]);
    }

    #[test]
    fn matches_full_sort_on_random_input() {
        let mut rng = crate::util::rng::Xoshiro256::new(77);
        let scored: Vec<Scored> = (0..500u32)
            .map(|id| Scored {
                id,
                // Quantized scores force plenty of ties.
                score: (rng.next_u32() % 16) as f64 / 16.0,
            })
            .collect();
        let mut t = TopK::new(25);
        for s in &scored {
            t.offer(s.id, s.score);
        }
        let mut full = scored.clone();
        full.sort_unstable_by(|a, b| b.cmp(a));
        full.truncate(25);
        assert_eq!(t.into_sorted(), full);
    }
}
