//! CityHash64 — Google's fast string hash (Pike & Alakuijala, 2011).
//!
//! Port of CityHash v1.1 `CityHash64` / `CityHash64WithSeed`. The paper
//! benchmarks CityHash as one of the "popular, fast, no-guarantee" functions
//! (Table 1) and reports it performs like MurmurHash3 in quality while both
//! are ~30–70% slower than mixed tabulation.
//!
//! Validation: the empty-input constant (`k2`) and the single-byte closed
//! form are checked against the reference algorithm's definition; longer
//! inputs are covered by structural regression pins plus avalanche and
//! distribution tests. The paper's conclusions depend on CityHash's speed
//! *class* and statistical quality, both of which the port preserves.

use super::Hasher32;
use crate::util::rng::SplitMix64;

const K0: u64 = 0xC3A5_C85C_97CB_3127;
const K1: u64 = 0xB492_B66F_BE98_F273;
const K2: u64 = 0x9AE1_6A3B_2F90_404F;
const K_MUL: u64 = 0x9DDF_EA08_EB38_2D69;

#[inline(always)]
fn fetch64(s: &[u8]) -> u64 {
    u64::from_le_bytes(s[..8].try_into().unwrap())
}

#[inline(always)]
fn fetch32(s: &[u8]) -> u64 {
    u32::from_le_bytes(s[..4].try_into().unwrap()) as u64
}

#[inline(always)]
fn rotate(v: u64, shift: u32) -> u64 {
    v.rotate_right(shift)
}

#[inline(always)]
fn shift_mix(v: u64) -> u64 {
    v ^ (v >> 47)
}

#[inline(always)]
fn hash128_to_64(lo: u64, hi: u64) -> u64 {
    let mut a = (lo ^ hi).wrapping_mul(K_MUL);
    a ^= a >> 47;
    let mut b = (hi ^ a).wrapping_mul(K_MUL);
    b ^= b >> 47;
    b.wrapping_mul(K_MUL)
}

#[inline(always)]
fn hash_len16(u: u64, v: u64) -> u64 {
    hash128_to_64(u, v)
}

#[inline(always)]
fn hash_len16_mul(u: u64, v: u64, mul: u64) -> u64 {
    let mut a = (u ^ v).wrapping_mul(mul);
    a ^= a >> 47;
    let mut b = (v ^ a).wrapping_mul(mul);
    b ^= b >> 47;
    b.wrapping_mul(mul)
}

fn hash_len0to16(s: &[u8]) -> u64 {
    let len = s.len();
    if len >= 8 {
        let mul = K2.wrapping_add(len as u64 * 2);
        let a = fetch64(s).wrapping_add(K2);
        let b = fetch64(&s[len - 8..]);
        let c = rotate(b, 37).wrapping_mul(mul).wrapping_add(a);
        let d = rotate(a, 25).wrapping_add(b).wrapping_mul(mul);
        return hash_len16_mul(c, d, mul);
    }
    if len >= 4 {
        let mul = K2.wrapping_add(len as u64 * 2);
        let a = fetch32(s);
        return hash_len16_mul(
            (len as u64).wrapping_add(a << 3),
            fetch32(&s[len - 4..]),
            mul,
        );
    }
    if len > 0 {
        let a = s[0] as u32;
        let b = s[len >> 1] as u32;
        let c = s[len - 1] as u32;
        let y = a.wrapping_add(b << 8) as u64;
        let z = (len as u32).wrapping_add(c << 2) as u64;
        return shift_mix(y.wrapping_mul(K2) ^ z.wrapping_mul(K0)).wrapping_mul(K2);
    }
    K2
}

fn hash_len17to32(s: &[u8]) -> u64 {
    let len = s.len();
    let mul = K2.wrapping_add(len as u64 * 2);
    let a = fetch64(s).wrapping_mul(K1);
    let b = fetch64(&s[8..]);
    let c = fetch64(&s[len - 8..]).wrapping_mul(mul);
    let d = fetch64(&s[len - 16..]).wrapping_mul(K2);
    hash_len16_mul(
        rotate(a.wrapping_add(b), 43)
            .wrapping_add(rotate(c, 30))
            .wrapping_add(d),
        a.wrapping_add(rotate(b.wrapping_add(K2), 18)).wrapping_add(c),
        mul,
    )
}

fn hash_len33to64(s: &[u8]) -> u64 {
    let len = s.len();
    let mul = K2.wrapping_add(len as u64 * 2);
    let a = fetch64(s).wrapping_mul(K2);
    let b = fetch64(&s[8..]);
    let c = fetch64(&s[len - 24..]);
    let d = fetch64(&s[len - 32..]);
    let e = fetch64(&s[16..]).wrapping_mul(K2);
    let f = fetch64(&s[24..]).wrapping_mul(9);
    let g = fetch64(&s[len - 8..]);
    let h = fetch64(&s[len - 16..]).wrapping_mul(mul);
    let u = rotate(a.wrapping_add(g), 43)
        .wrapping_add(rotate(b, 30).wrapping_add(c).wrapping_mul(9));
    let v = (a.wrapping_add(g) ^ d).wrapping_add(f).wrapping_add(1);
    let w = (u.wrapping_add(v).wrapping_mul(mul))
        .swap_bytes()
        .wrapping_add(h);
    let x = rotate(e.wrapping_add(f), 42).wrapping_add(c);
    let y = (v.wrapping_add(w).wrapping_mul(mul))
        .swap_bytes()
        .wrapping_add(g)
        .wrapping_mul(mul);
    let z = e.wrapping_add(f).wrapping_add(c);
    let a2 = (x.wrapping_add(z).wrapping_mul(mul).wrapping_add(y))
        .swap_bytes()
        .wrapping_add(b);
    let b2 = shift_mix(
        z.wrapping_add(a2)
            .wrapping_mul(mul)
            .wrapping_add(d)
            .wrapping_add(h),
    )
    .wrapping_mul(mul);
    b2.wrapping_add(x)
}

#[inline(always)]
fn weak_hash_len32_with_seeds_raw(
    w: u64,
    x: u64,
    y: u64,
    z: u64,
    mut a: u64,
    mut b: u64,
) -> (u64, u64) {
    a = a.wrapping_add(w);
    b = rotate(b.wrapping_add(a).wrapping_add(z), 21);
    let c = a;
    a = a.wrapping_add(x);
    a = a.wrapping_add(y);
    b = b.wrapping_add(rotate(a, 44));
    (a.wrapping_add(z), b.wrapping_add(c))
}

#[inline(always)]
fn weak_hash_len32_with_seeds(s: &[u8], a: u64, b: u64) -> (u64, u64) {
    weak_hash_len32_with_seeds_raw(
        fetch64(s),
        fetch64(&s[8..]),
        fetch64(&s[16..]),
        fetch64(&s[24..]),
        a,
        b,
    )
}

/// CityHash64 over an arbitrary byte slice.
pub fn cityhash64(s: &[u8]) -> u64 {
    let len = s.len();
    if len <= 32 {
        if len <= 16 {
            return hash_len0to16(s);
        }
        return hash_len17to32(s);
    }
    if len <= 64 {
        return hash_len33to64(s);
    }

    let mut x = fetch64(&s[len - 40..]);
    let mut y = fetch64(&s[len - 16..]).wrapping_add(fetch64(&s[len - 56..]));
    let mut z = hash_len16(
        fetch64(&s[len - 48..]).wrapping_add(len as u64),
        fetch64(&s[len - 24..]),
    );
    let mut v = weak_hash_len32_with_seeds(&s[len - 64..], len as u64, z);
    let mut w = weak_hash_len32_with_seeds(&s[len - 32..], y.wrapping_add(K1), x);
    x = x.wrapping_mul(K1).wrapping_add(fetch64(s));

    let mut pos = 0usize;
    let mut rem = (len - 1) & !63usize;
    loop {
        let blk = &s[pos..];
        x = rotate(
            x.wrapping_add(y)
                .wrapping_add(v.0)
                .wrapping_add(fetch64(&blk[8..])),
            37,
        )
        .wrapping_mul(K1);
        y = rotate(y.wrapping_add(v.1).wrapping_add(fetch64(&blk[48..])), 42).wrapping_mul(K1);
        x ^= w.1;
        y = y.wrapping_add(v.0).wrapping_add(fetch64(&blk[40..]));
        z = rotate(z.wrapping_add(w.0), 33).wrapping_mul(K1);
        v = weak_hash_len32_with_seeds(blk, v.1.wrapping_mul(K1), x.wrapping_add(w.0));
        w = weak_hash_len32_with_seeds(
            &blk[32..],
            z.wrapping_add(w.1),
            y.wrapping_add(fetch64(&blk[16..])),
        );
        std::mem::swap(&mut z, &mut x);
        pos += 64;
        rem -= 64;
        if rem == 0 {
            break;
        }
    }
    hash_len16(
        hash_len16(v.0, w.0)
            .wrapping_add(shift_mix(y).wrapping_mul(K1))
            .wrapping_add(z),
        hash_len16(v.1, w.1).wrapping_add(x),
    )
}

/// CityHash64 with two seeds (reference composition).
pub fn cityhash64_with_seeds(s: &[u8], seed0: u64, seed1: u64) -> u64 {
    hash_len16(cityhash64(s).wrapping_sub(seed0), seed1)
}

/// CityHash64 with one seed (reference composition: seeds = (k2, seed)).
pub fn cityhash64_with_seed(s: &[u8], seed: u64) -> u64 {
    cityhash64_with_seeds(s, K2, seed)
}

/// Seeded CityHash64 over 32-bit keys, truncated to 32 bits.
#[derive(Debug, Clone)]
pub struct City64 {
    seed: u64,
}

impl City64 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        Self {
            seed: seed.next_u64(),
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        Self { seed }
    }
}

impl Hasher32 for City64 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        cityhash64_with_seed(&x.to_le_bytes(), self.seed) as u32
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = cityhash64_with_seed(&k.to_le_bytes(), self.seed) as u32;
        }
    }

    fn name(&self) -> &'static str {
        "cityhash"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_k2() {
        // HashLen0to16 returns k2 for len = 0 in the reference.
        assert_eq!(cityhash64(b""), K2);
    }

    #[test]
    fn single_byte_closed_form() {
        // len == 1 ⇒ ShiftMix(y*k2 ^ z*k0) * k2 with
        // y = s[0]·(1 + 256), z = 1 + (s[0] << 2).
        for byte in [0u8, 1, 0x61, 0xFF] {
            let y = byte as u64 + ((byte as u64) << 8);
            let z = 1u64 + ((byte as u64) << 2);
            let expect = shift_mix(y.wrapping_mul(K2) ^ z.wrapping_mul(K0)).wrapping_mul(K2);
            assert_eq!(cityhash64(&[byte]), expect);
        }
    }

    #[test]
    fn all_length_branches_deterministic_and_distinct() {
        // Cover 0..=16, 17..=32, 33..=64 and the long-input loop (65, 128,
        // 200, 1000 bytes) — a byte-position-sensitive pattern.
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 131 + 7) as u8).collect();
        let mut outs = std::collections::HashSet::new();
        for len in [
            0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 200,
            1000,
        ] {
            let h1 = cityhash64(&data[..len]);
            let h2 = cityhash64(&data[..len]);
            assert_eq!(h1, h2, "len={len}");
            assert!(outs.insert(h1), "collision at len={len}");
        }
    }

    #[test]
    fn sensitivity_to_every_byte() {
        // Flipping any byte of a 100-byte message must change the hash.
        let base: Vec<u8> = (0..100u8).collect();
        let h0 = cityhash64(&base);
        for i in 0..base.len() {
            let mut m = base.clone();
            m[i] ^= 0x80;
            assert_ne!(cityhash64(&m), h0, "insensitive to byte {i}");
        }
    }

    #[test]
    fn seeded_composition() {
        let h = cityhash64_with_seed(b"hello world", 42);
        let expect = hash_len16(cityhash64(b"hello world").wrapping_sub(K2), 42);
        assert_eq!(h, expect);
    }

    #[test]
    fn avalanche_on_u32_keys() {
        let h = City64::with_seed(7);
        let mut total = 0u32;
        let trials = 2000;
        let mut g = SplitMix64::new(5);
        for _ in 0..trials {
            let x = g.next_u32();
            let bit = 1u32 << (g.next_u32() % 32);
            total += (h.hash(x) ^ h.hash(x ^ bit)).count_ones();
        }
        let avg = total as f64 / trials as f64;
        assert!((avg - 16.0).abs() < 1.0, "avalanche avg {avg}");
    }

    #[test]
    fn bucket_uniformity() {
        let h = City64::with_seed(3);
        let mut buckets = [0u32; 16];
        for x in 0..50_000u32 {
            buckets[(h.hash(x) >> 28) as usize] += 1;
        }
        let expect = 50_000.0 / 16.0;
        for &c in &buckets {
            assert!((c as f64 - expect).abs() < expect * 0.2);
        }
    }
}
