//! BLAKE2b — RFC 7693. The paper's cryptographic baseline.
//!
//! Table 1 includes BLAKE2 to show the cost of cryptographic guarantees:
//! "orders of magnitude slower" than the combinatorial schemes. We implement
//! the full RFC 7693 BLAKE2b (any digest size 1–64, optional key) and wrap
//! it as a [`Hasher32`] by hashing the 4 little-endian key bytes with an
//! 8-byte seed key.

use super::Hasher32;
use crate::util::rng::SplitMix64;

/// BLAKE2b initialisation vector (RFC 7693 §2.6).
const IV: [u64; 8] = [
    0x6A09_E667_F3BC_C908,
    0xBB67_AE85_84CA_A73B,
    0x3C6E_F372_FE94_F82B,
    0xA54F_F53A_5F1D_36F1,
    0x510E_527F_ADE6_82D1,
    0x9B05_688C_2B3E_6C1F,
    0x1F83_D9AB_FB41_BD6B,
    0x5BE0_CD19_137E_2179,
];

/// Message schedule (RFC 7693 §2.7).
const SIGMA: [[usize; 16]; 12] = [
    [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15],
    [14, 10, 4, 8, 9, 15, 13, 6, 1, 12, 0, 2, 11, 7, 5, 3],
    [11, 8, 12, 0, 5, 2, 15, 13, 10, 14, 3, 6, 7, 1, 9, 4],
    [7, 9, 3, 1, 13, 12, 11, 14, 2, 6, 5, 10, 4, 0, 15, 8],
    [9, 0, 5, 7, 2, 4, 10, 15, 14, 1, 11, 12, 6, 8, 3, 13],
    [2, 12, 6, 10, 0, 11, 8, 3, 4, 13, 7, 5, 15, 14, 1, 9],
    [12, 5, 1, 15, 14, 13, 4, 10, 0, 7, 6, 3, 9, 2, 8, 11],
    [13, 11, 7, 14, 12, 1, 3, 9, 5, 0, 15, 4, 8, 6, 2, 10],
    [6, 15, 14, 9, 11, 3, 0, 8, 12, 2, 13, 7, 1, 4, 10, 5],
    [10, 2, 8, 4, 7, 6, 1, 5, 15, 11, 9, 14, 3, 12, 13, 0],
    [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15],
    [14, 10, 4, 8, 9, 15, 13, 6, 1, 12, 0, 2, 11, 7, 5, 3],
];

#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn g(v: &mut [u64; 16], a: usize, b: usize, c: usize, d: usize, x: u64, y: u64) {
    v[a] = v[a].wrapping_add(v[b]).wrapping_add(x);
    v[d] = (v[d] ^ v[a]).rotate_right(32);
    v[c] = v[c].wrapping_add(v[d]);
    v[b] = (v[b] ^ v[c]).rotate_right(24);
    v[a] = v[a].wrapping_add(v[b]).wrapping_add(y);
    v[d] = (v[d] ^ v[a]).rotate_right(16);
    v[c] = v[c].wrapping_add(v[d]);
    v[b] = (v[b] ^ v[c]).rotate_right(63);
}

/// Compression function F (RFC 7693 §3.2).
fn compress(h: &mut [u64; 8], block: &[u8; 128], t: u128, last: bool) {
    let mut m = [0u64; 16];
    for (i, chunk) in block.chunks_exact(8).enumerate() {
        m[i] = u64::from_le_bytes(chunk.try_into().unwrap());
    }
    let mut v = [0u64; 16];
    v[..8].copy_from_slice(h);
    v[8..].copy_from_slice(&IV);
    v[12] ^= t as u64;
    v[13] ^= (t >> 64) as u64;
    if last {
        v[14] = !v[14];
    }
    for s in &SIGMA {
        g(&mut v, 0, 4, 8, 12, m[s[0]], m[s[1]]);
        g(&mut v, 1, 5, 9, 13, m[s[2]], m[s[3]]);
        g(&mut v, 2, 6, 10, 14, m[s[4]], m[s[5]]);
        g(&mut v, 3, 7, 11, 15, m[s[6]], m[s[7]]);
        g(&mut v, 0, 5, 10, 15, m[s[8]], m[s[9]]);
        g(&mut v, 1, 6, 11, 12, m[s[10]], m[s[11]]);
        g(&mut v, 2, 7, 8, 13, m[s[12]], m[s[13]]);
        g(&mut v, 3, 4, 9, 14, m[s[14]], m[s[15]]);
    }
    for i in 0..8 {
        h[i] ^= v[i] ^ v[i + 8];
    }
}

/// BLAKE2b with digest length `out_len` (1..=64) and optional key (≤ 64
/// bytes). Returns `out_len` bytes.
pub fn blake2b(out_len: usize, key: &[u8], data: &[u8]) -> Vec<u8> {
    assert!((1..=64).contains(&out_len), "digest length 1..=64");
    assert!(key.len() <= 64, "key length <= 64");
    let mut h = IV;
    // Parameter block: digest length, key length, fanout = depth = 1.
    h[0] ^= 0x0101_0000 ^ ((key.len() as u64) << 8) ^ out_len as u64;

    let mut t: u128 = 0;
    let process = |h: &mut [u64; 8], chunk: &[u8], last: bool, t: &mut u128| {
        let mut block = [0u8; 128];
        block[..chunk.len()].copy_from_slice(chunk);
        *t += chunk.len() as u128;
        compress(h, &block, *t, last);
    };

    if !key.is_empty() {
        // Keyed mode: the key, zero-padded to a full block, is block 0.
        let mut kb = [0u8; 128];
        kb[..key.len()].copy_from_slice(key);
        if data.is_empty() {
            t += 128;
            compress(&mut h, &kb, t, true);
            return digest_bytes(&h, out_len);
        }
        t += 128;
        compress(&mut h, &kb, t, false);
    } else if data.is_empty() {
        // Empty unkeyed message: a single all-zero final block with t = 0.
        process(&mut h, &[], true, &mut t);
        return digest_bytes(&h, out_len);
    }

    let nblocks = data.len().div_ceil(128);
    for i in 0..nblocks {
        let chunk = &data[i * 128..(data.len().min((i + 1) * 128))];
        if i + 1 == nblocks {
            process(&mut h, chunk, true, &mut t);
        } else {
            // Full non-final block.
            let mut block = [0u8; 128];
            block.copy_from_slice(chunk);
            t += 128;
            compress(&mut h, &block, t, false);
        }
    }
    digest_bytes(&h, out_len)
}

fn digest_bytes(h: &[u64; 8], out_len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    for w in h {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.truncate(out_len);
    out
}

/// BLAKE2b-based [`Hasher32`]: keyed BLAKE2b-64bit over the 4 key bytes.
#[derive(Debug, Clone)]
pub struct Blake2b {
    key: [u8; 8],
}

impl Blake2b {
    /// Construct the seeded hasher (named `hasher` to keep `Blake2b` free
    /// for the raw function namespace).
    pub fn hasher(seed: &mut SplitMix64) -> Self {
        Self {
            key: seed.next_u64().to_le_bytes(),
        }
    }

    pub fn with_key(key: [u8; 8]) -> Self {
        Self { key }
    }
}

impl Hasher32 for Blake2b {
    fn hash(&self, x: u32) -> u32 {
        let d = blake2b(8, &self.key, &x.to_le_bytes());
        u32::from_le_bytes(d[..4].try_into().unwrap())
    }

    /// Monomorphic batch loop. The compression function dominates, so the
    /// win over the default is small here, but every Table 1 family keeps
    /// the one-dispatch-per-batch contract of [`Hasher32::hash_slice`].
    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            let d = blake2b(8, &self.key, &k.to_le_bytes());
            *o = u32::from_le_bytes(d[..4].try_into().unwrap());
        }
    }

    fn name(&self) -> &'static str {
        "blake2b"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// RFC 7693 Appendix A: BLAKE2b-512("abc").
    #[test]
    fn rfc7693_abc() {
        let d = blake2b(64, &[], b"abc");
        assert_eq!(
            hex(&d),
            "ba80a53f981c4d0d6a2797b69f12f6e94c212f14685ac4b74b12bb6fdbffa2d1\
             7d87c5392aab792dc252d5de4533cc9518d38aa8dbf1925ab92386edd4009923"
        );
    }

    /// Well-known BLAKE2b-512 of the empty string.
    #[test]
    fn empty_string() {
        let d = blake2b(64, &[], b"");
        assert_eq!(
            hex(&d),
            "786a02f742015903c6c6fd852552d272912f4740e15847618a86e217f71f5419\
             d25e1031afee585313896444934eb04b903a685b1448b755d56f701afe9be2ce"
        );
    }

    #[test]
    fn multiblock_messages() {
        // Exactly one block, one block + 1 byte, several blocks.
        let long = vec![0xABu8; 300];
        let d128 = blake2b(64, &[], &long[..128]);
        let d129 = blake2b(64, &[], &long[..129]);
        let d300 = blake2b(64, &[], &long);
        assert_ne!(d128, d129);
        assert_ne!(d129, d300);
        // Determinism.
        assert_eq!(d300, blake2b(64, &[], &long));
    }

    #[test]
    fn keyed_mode_differs_and_is_deterministic() {
        let a = blake2b(32, b"key-one!", b"message");
        let b = blake2b(32, b"key-two!", b"message");
        let c = blake2b(32, b"key-one!", b"message");
        assert_ne!(a, b);
        assert_eq!(a, c);
        // Keyed empty message path.
        let d = blake2b(16, b"k", b"");
        assert_eq!(d.len(), 16);
    }

    #[test]
    fn digest_lengths() {
        for n in [1usize, 4, 8, 20, 32, 48, 64] {
            assert_eq!(blake2b(n, &[], b"x").len(), n);
        }
        // Different output lengths give unrelated digests (length is in the
        // parameter block), not truncations of each other.
        let d32 = blake2b(32, &[], b"x");
        let d64 = blake2b(64, &[], b"x");
        assert_ne!(&d64[..32], &d32[..]);
    }

    #[test]
    fn hasher32_wrapper() {
        let h = Blake2b::with_key(*b"seedseed");
        let a = h.hash(1);
        let b = h.hash(2);
        assert_ne!(a, b);
        assert_eq!(a, Blake2b::with_key(*b"seedseed").hash(1));
    }

    #[test]
    fn avalanche() {
        let h = Blake2b::with_key(*b"\x01\x02\x03\x04\x05\x06\x07\x08");
        let mut total = 0u32;
        let trials = 300; // blake2 is slow; fewer trials
        let mut g = SplitMix64::new(5);
        for _ in 0..trials {
            let x = g.next_u32();
            let bit = 1u32 << (g.next_u32() % 32);
            total += (h.hash(x) ^ h.hash(x ^ bit)).count_ones();
        }
        let avg = total as f64 / trials as f64;
        assert!((avg - 16.0).abs() < 1.5, "avalanche avg {avg}");
    }
}
