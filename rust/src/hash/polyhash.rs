//! k-wise independent polynomial hashing over the Mersenne prime 2^61 − 1.
//!
//! `h(x) = (c_{k−1} x^{k−1} + … + c_1 x + c_0 mod p) mod 2^32` evaluated by
//! Horner's rule with the division-free Mersenne reduction from
//! [`super::multiply_shift::mod_mersenne61`]. A degree-(k−1) polynomial with
//! uniform coefficients is exactly k-independent.
//!
//! The paper uses k = 2 and 3 as fast-but-weak baselines and **k = 20 as the
//! "(cheating) way to simulate truly random hashing"**; the same 20-wise
//! instance also fills the mixed-tabulation tables (§2.4: a Θ(log |U|)-
//! independent seeder suffices).

use super::multiply_shift::{mod_mersenne61, MERSENNE61};
use super::Hasher32;
use crate::util::rng::SplitMix64;

/// k-wise PolyHash (degree k−1 polynomial over GF(p), p = 2^61 − 1).
#[derive(Debug, Clone)]
pub struct PolyHash {
    /// Coefficients, highest degree first (Horner order). `coeffs.len() == k`.
    coeffs: Vec<u64>,
}

impl PolyHash {
    /// Draw a random degree-(k−1) polynomial. `k >= 1`. The leading
    /// coefficient is drawn from `[1, p)` so the polynomial has true degree
    /// k−1.
    pub fn new(k: usize, seed: &mut SplitMix64) -> Self {
        assert!(k >= 1, "PolyHash needs k >= 1");
        let mut coeffs = Vec::with_capacity(k);
        coeffs.push(1 + seed.next_u64() % (MERSENNE61 - 1));
        for _ in 1..k {
            coeffs.push(seed.next_u64() % MERSENNE61);
        }
        Self { coeffs }
    }

    /// Independence degree k.
    pub fn k(&self) -> usize {
        self.coeffs.len()
    }

    /// Full 61-bit evaluation (before truncation to 32 bits) — also used by
    /// the tabulation seeder, which needs the full-width output.
    #[inline]
    pub fn eval61(&self, x: u32) -> u64 {
        let x = x as u128;
        let mut acc = self.coeffs[0] as u128;
        for &c in &self.coeffs[1..] {
            acc = mod_mersenne61(acc * x) as u128 + c as u128;
        }
        mod_mersenne61(acc)
    }
}

impl Hasher32 for PolyHash {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval61(x) as u32
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        match self.coeffs.len() {
            // Monomorphic fast paths for the degrees on the paper's hot path.
            2 => {
                let (c0, c1) = (self.coeffs[0], self.coeffs[1]);
                for (k, o) in keys.iter().zip(out.iter_mut()) {
                    let acc = c0 as u128 * *k as u128 + c1 as u128;
                    *o = mod_mersenne61(acc) as u32;
                }
            }
            3 => {
                let (c0, c1, c2) = (self.coeffs[0], self.coeffs[1], self.coeffs[2]);
                for (k, o) in keys.iter().zip(out.iter_mut()) {
                    let x = *k as u128;
                    let acc = mod_mersenne61(c0 as u128 * x) as u128 + c1 as u128;
                    let acc = mod_mersenne61(acc) as u128 * x + c2 as u128;
                    *o = mod_mersenne61(acc) as u32;
                }
            }
            _ => {
                for (k, o) in keys.iter().zip(out.iter_mut()) {
                    *o = self.eval61(*k) as u32;
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        match self.coeffs.len() {
            2 => "polyhash2",
            3 => "polyhash3",
            20 => "polyhash20",
            _ => "polyhash",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_one_is_affine() {
        // k=2: h61(x) = (c0 x + c1) mod p — verify against direct u128 math.
        let mut sm = SplitMix64::new(3);
        let h = PolyHash::new(2, &mut sm);
        for x in [0u32, 1, 77, u32::MAX] {
            let expect = ((h.coeffs[0] as u128 * x as u128 + h.coeffs[1] as u128)
                % MERSENNE61 as u128) as u64;
            assert_eq!(h.eval61(x), expect);
        }
    }

    #[test]
    fn horner_matches_naive_powers() {
        let mut sm = SplitMix64::new(17);
        let h = PolyHash::new(7, &mut sm);
        let p = MERSENNE61 as u128;
        for x in [1u32, 5, 123456, u32::MAX] {
            // naive: sum c_i * x^{k-1-i} mod p
            let k = h.coeffs.len();
            let mut expect: u128 = 0;
            for (i, &c) in h.coeffs.iter().enumerate() {
                let mut term = c as u128;
                for _ in 0..(k - 1 - i) {
                    term = term * (x as u128) % p;
                }
                expect = (expect + term) % p;
            }
            assert_eq!(h.eval61(x) as u128, expect, "x={x}");
        }
    }

    #[test]
    fn eval_below_p() {
        let mut sm = SplitMix64::new(29);
        let h = PolyHash::new(20, &mut sm);
        for x in (0..5000u32).map(|i| i.wrapping_mul(2654435761)) {
            assert!(h.eval61(x) < MERSENNE61);
        }
    }

    #[test]
    fn slice_matches_scalar_all_degrees() {
        for k in [2usize, 3, 4, 20] {
            let mut sm = SplitMix64::new(k as u64);
            let h = PolyHash::new(k, &mut sm);
            let keys: Vec<u32> = (0..100).map(|i| i * 37 + 5).collect();
            let mut out = vec![0u32; keys.len()];
            h.hash_slice(&keys, &mut out);
            for (x, o) in keys.iter().zip(&out) {
                assert_eq!(h.hash(*x), *o, "k={k}");
            }
        }
    }

    #[test]
    fn pairwise_collision_rate() {
        // 2-independence implies collision probability ~2^-32 on the
        // truncated output; sanity-check no systematic collisions over a
        // small structured key set.
        let mut sm = SplitMix64::new(101);
        let h = PolyHash::new(2, &mut sm);
        let mut seen = std::collections::HashSet::new();
        let mut collisions = 0;
        for x in 0..20_000u32 {
            if !seen.insert(h.hash(x)) {
                collisions += 1;
            }
        }
        // Birthday bound: expect ~0.05 collisions; allow a couple.
        assert!(collisions <= 3, "collisions={collisions}");
    }
}
