//! Statistical quality diagnostics for basic hash functions.
//!
//! The machinery behind the `hash_quality` example and several test gates:
//! avalanche matrices, chi-squared bucket uniformity, and the dense-block
//! occupancy ratio that makes §4.1's failure mechanism *measurable*: weak
//! multiplicative schemes map a dense id block `[0, n)` across `k` bins
//! "too evenly" (sub-binomial occupancy variance), which systematically
//! favours intersection elements in per-bin minima and biases OPH.

use super::Hasher32;
use crate::util::rng::Xoshiro256;

/// Avalanche statistics of a 32→32-bit function.
#[derive(Debug, Clone)]
pub struct Avalanche {
    /// Mean fraction of output bits flipped per single-bit input flip
    /// (ideal: 0.5).
    pub mean_flip_rate: f64,
    /// Worst |p − 0.5| over the 32×32 (input bit, output bit) matrix.
    pub worst_bias: f64,
}

/// Estimate the avalanche matrix with `trials` random keys per input bit.
pub fn avalanche(h: &dyn Hasher32, trials: usize, seed: u64) -> Avalanche {
    let mut rng = Xoshiro256::new(seed);
    let mut flip_counts = [[0u32; 32]; 32];
    for _ in 0..trials {
        let x = rng.next_u32();
        let base = h.hash(x);
        for in_bit in 0..32 {
            let diff = base ^ h.hash(x ^ (1u32 << in_bit));
            for out_bit in 0..32 {
                flip_counts[in_bit][out_bit] += (diff >> out_bit) & 1;
            }
        }
    }
    let mut total = 0f64;
    let mut worst = 0f64;
    for row in &flip_counts {
        for &c in row {
            let p = c as f64 / trials as f64;
            total += p;
            worst = worst.max((p - 0.5).abs());
        }
    }
    Avalanche {
        mean_flip_rate: total / (32.0 * 32.0),
        worst_bias: worst,
    }
}

/// Chi-squared statistic of the low-byte distribution over `n` sequential
/// keys (dense block — the structured input of §4.1). 255 degrees of
/// freedom; values ≫ 255 + 6·√510 ≈ 391 indicate non-uniformity.
pub fn chi_squared_low_byte(h: &dyn Hasher32, n: u32) -> f64 {
    let mut counts = [0f64; 256];
    for x in 0..n {
        counts[(h.hash(x) & 0xFF) as usize] += 1.0;
    }
    let expect = n as f64 / 256.0;
    counts.iter().map(|c| (c - expect).powi(2) / expect).sum()
}

/// Median (over seeds) of the per-bin occupancy variance of the dense block
/// `[0, n)` mapped to `k` bins via `hash(x) mod k`, normalised by the
/// binomial reference `n/k·(1 − 1/k)`.
///
/// ≈ 1.0: truly-random-like. ≪ 1.0: "too even" — the OPH bias mechanism.
/// ≫ 1.0: clustered (also bad, different failure).
pub fn occupancy_ratio(
    build: impl Fn(u64) -> Box<dyn Hasher32>,
    n: u32,
    k: usize,
    seeds: u64,
) -> f64 {
    let mut vars: Vec<f64> = (0..seeds)
        .map(|seed| {
            let h = build(seed);
            let mut counts = vec![0f64; k];
            for x in 0..n {
                counts[(h.hash(x) as usize) % k] += 1.0;
            }
            let mean = n as f64 / k as f64;
            counts.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / k as f64
        })
        .collect();
    vars.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = vars[vars.len() / 2];
    let binomial = n as f64 / k as f64 * (1.0 - 1.0 / k as f64);
    median / binomial
}

/// Serial correlation of consecutive outputs over sequential keys, in
/// [-1, 1] (ideal ≈ 0). Multiplicative schemes on sequential keys produce
/// strongly structured (lattice) output sequences.
pub fn serial_correlation(h: &dyn Hasher32, n: u32) -> f64 {
    let xs: Vec<f64> = (0..n).map(|x| h.hash(x) as f64).collect();
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let mut cov = 0.0;
    let mut var = 0.0;
    for i in 0..xs.len() {
        var += (xs[i] - mean).powi(2);
        if i + 1 < xs.len() {
            cov += (xs[i] - mean) * (xs[i + 1] - mean);
        }
    }
    if var == 0.0 {
        return 0.0;
    }
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashFamily;

    #[test]
    fn mixed_tab_near_ideal_avalanche() {
        let h = HashFamily::MixedTab.build(1);
        let a = avalanche(h.as_ref(), 800, 42);
        assert!((a.mean_flip_rate - 0.5).abs() < 0.01, "{a:?}");
        assert!(a.worst_bias < 0.12, "{a:?}");
    }

    #[test]
    fn multiply_shift_poor_avalanche() {
        // Low input bits barely influence high output bits in (ax+b)>>32.
        let h = HashFamily::MultiplyShift.build(1);
        let a = avalanche(h.as_ref(), 800, 42);
        assert!(a.worst_bias > 0.3, "expected structured matrix: {a:?}");
    }

    #[test]
    fn chi_squared_separates() {
        let strong = HashFamily::MixedTab.build(3);
        assert!(chi_squared_low_byte(strong.as_ref(), 100_000) < 391.0);
    }

    #[test]
    fn occupancy_contrast() {
        let mt = occupancy_ratio(|s| HashFamily::MixedTab.build(s), 2000, 64, 21);
        let ms = occupancy_ratio(|s| HashFamily::MultiplyShift.build(s), 2000, 64, 21);
        assert!((0.5..2.0).contains(&mt), "mixed_tab ratio {mt}");
        assert!(ms < mt, "ms {ms} should be below mt {mt} (too even)");
    }

    #[test]
    fn serial_correlation_bounds() {
        for fam in [HashFamily::MixedTab, HashFamily::Murmur3] {
            let h = fam.build(5);
            let c = serial_correlation(h.as_ref(), 20_000);
            assert!(c.abs() < 0.05, "{}: corr {c}", fam.id());
        }
    }
}
