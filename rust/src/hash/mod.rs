//! The basic hash function zoo evaluated by the paper.
//!
//! The paper's central question is *which concrete hash function should
//! implement OPH / FH / LSH*. This module provides every family from the
//! paper's Table 1 behind one object-safe trait, so that sketches and the
//! coordinator treat the hash function as a swappable configuration knob:
//!
//! | Family | Paper row | Guarantee |
//! |---|---|---|
//! | [`MultiplyShift`] | multiply-shift | 2-independent (Dietzfelbinger) |
//! | [`MultiplyModPrime`] | `(ax+b) mod p` | 2-independent |
//! | [`PolyHash`] (k=2,3,…,20) | k-wise PolyHash | k-independent |
//! | [`Murmur3`] | MurmurHash3 (x86_32) | none (broken by [1]) |
//! | [`City64`] | CityHash64 | none (broken by [1]) |
//! | [`Blake2b`] | Blake2 | cryptographic |
//! | [`SimpleTab32`] | — (ablation) | 3-independent |
//! | [`TwistedTab32`] | — (ablation, SODA'13) | beyond 3-independent, short of mixed |
//! | [`MixedTab32`] / [`MixedTab64`] | mixed tabulation | truly-random-like for OPH/FH [14] |
//!
//! All hashers map 32-bit keys to 32-bit (or 64-bit) values, matching the
//! paper's experimental setup ("All keys and hash outputs were 32-bit
//! integers").
//!
//! # References
//!
//! The bracketed markers in the table above follow the source paper's
//! bibliography (Dahlgaard, Knudsen, Thorup — *Practical Hash Functions for
//! Similarity Estimation and Dimensionality Reduction*, NIPS 2017):
//!
//! * `[1]` — J.-P. Aumasson and D. J. Bernstein. *SipHash: a fast
//!   short-input PRF*. INDOCRYPT 2012. Exhibits seed-independent
//!   multicollisions in MurmurHash3 and CityHash64 — the basis for the
//!   "broken" verdict on those rows.
//! * `[14]` — S. Dahlgaard, M. B. T. Knudsen, E. Rotenberg, and M. Thorup.
//!   *Hashing for statistics over k-partitions*. FOCS 2015. Introduces
//!   mixed tabulation and proves its truly-random-like behaviour for the
//!   statistics underlying OPH; the source paper extends the argument to
//!   feature hashing on sparse input.
//!
//! Named inline: multiply-shift is 2-independent by Dietzfelbinger
//! (*Universal hashing and k-wise independent random variables via integer
//! arithmetic without primes*, STACS 1996); twisted tabulation is
//! Pătrașcu–Thorup (*Twisted tabulation hashing*, SODA 2013).

pub mod multiply_shift;
pub mod polyhash;
pub mod murmur3;
pub mod city;
pub mod blake2;
pub mod tabulation;
pub mod twisted;
pub mod quality;
pub mod source;

pub use blake2::Blake2b;
pub use city::City64;
pub use source::{HashSource, IndependentSource, PooledSource};
pub use multiply_shift::{MultiplyModPrime, MultiplyShift};
pub use murmur3::Murmur3;
pub use polyhash::PolyHash;
pub use tabulation::{MixedTab32, MixedTab64, SimpleTab32};
pub use twisted::TwistedTab32;

use crate::util::rng::SplitMix64;

/// A basic hash function over 32-bit keys, as used throughout the paper.
///
/// Implementations must be deterministic for a fixed seed and cheap to call
/// in a tight loop. `hash_slice` exists so the hot loop monomorphises inside
/// each implementation (one dynamic dispatch per *batch*, not per key).
///
/// Every family in [`HashFamily::TABLE1`] overrides `hash_slice`; the
/// sketches (`sketch::oph`, `sketch::minhash`, `sketch::simhash`,
/// `sketch::feature_hash`) route whole sets/documents through it via a
/// reusable `sketch::Scratch` buffer, which is what makes the measured
/// Table 1 throughput (`mixtab bench`, `benches/table1_hash_speed.rs`)
/// reflect the hash function rather than virtual-call overhead.
/// `hash_slice(keys, out)` must be observably equivalent to calling `hash`
/// per key — the batched/per-key sketch equivalence property tests rely on
/// it.
pub trait Hasher32: Send + Sync {
    /// Hash one 32-bit key to a 32-bit value.
    fn hash(&self, x: u32) -> u32;

    /// Hash a batch; override for a monomorphic inner loop.
    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.hash(*k);
        }
    }

    /// Family name (used in experiment outputs).
    fn name(&self) -> &'static str;
}

/// A hash function producing 64 output bits per 32-bit key.
///
/// Mixed tabulation gets this essentially for free by widening its tables
/// (§2.4: the two 32-bit halves are independent whp.), which is one of its
/// practical advantages; other families must evaluate twice.
pub trait Hasher64: Send + Sync {
    fn hash64(&self, x: u32) -> u64;

    /// Hash a batch of keys; override for a monomorphic inner loop. Must be
    /// observably equivalent to calling `hash64` per key — the pooled
    /// [`source::PooledSource`] fills its whole pool through this method
    /// (one dynamic dispatch per pool word per batch) and its per-key
    /// reference path relies on the equivalence.
    fn hash64_slice(&self, keys: &[u32], out: &mut [u64]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.hash64(*k);
        }
    }

    fn name64(&self) -> &'static str;
}

/// The hash families of the paper's evaluation (Table 1 ordering), plus the
/// tabulation extensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HashFamily {
    MultiplyShift,
    MultiplyModPrime,
    Poly2,
    Poly3,
    /// 20-wise PolyHash — the paper's "(cheating) way to simulate truly
    /// random hashing".
    Poly20,
    Murmur3,
    City,
    Blake2,
    SimpleTab,
    /// Twisted tabulation (Pătrașcu–Thorup SODA'13) — tabulation ablation
    /// between simple and mixed.
    TwistedTab,
    MixedTab,
}

impl HashFamily {
    /// All families benchmarked in Table 1.
    pub const TABLE1: &'static [HashFamily] = &[
        HashFamily::MultiplyShift,
        HashFamily::Poly2,
        HashFamily::Poly3,
        HashFamily::Murmur3,
        HashFamily::City,
        HashFamily::Blake2,
        HashFamily::MixedTab,
    ];

    /// The five families compared in Figures 2–4 (chosen in §4 based on
    /// Table 1): multiply-shift, 2-wise PolyHash, MurmurHash3, mixed
    /// tabulation, and 20-wise PolyHash as the truly-random stand-in.
    pub const FIGURES: &'static [HashFamily] = &[
        HashFamily::MultiplyShift,
        HashFamily::Poly2,
        HashFamily::MixedTab,
        HashFamily::Murmur3,
        HashFamily::Poly20,
    ];

    /// The tabulation progression for the densification/tabulation ablation
    /// (simple → twisted → mixed).
    pub const TABULATIONS: &'static [HashFamily] = &[
        HashFamily::SimpleTab,
        HashFamily::TwistedTab,
        HashFamily::MixedTab,
    ];

    /// Stable identifier used in CSV outputs and CLI arguments.
    pub fn id(&self) -> &'static str {
        match self {
            HashFamily::MultiplyShift => "multiply_shift",
            HashFamily::MultiplyModPrime => "multiply_mod_prime",
            HashFamily::Poly2 => "polyhash2",
            HashFamily::Poly3 => "polyhash3",
            HashFamily::Poly20 => "polyhash20",
            HashFamily::Murmur3 => "murmur3",
            HashFamily::City => "cityhash",
            HashFamily::Blake2 => "blake2b",
            HashFamily::SimpleTab => "simple_tab",
            HashFamily::TwistedTab => "twisted_tab",
            HashFamily::MixedTab => "mixed_tab",
        }
    }

    /// Human label as used in the paper's figures.
    pub fn label(&self) -> &'static str {
        match self {
            HashFamily::MultiplyShift => "Multiply-shift",
            HashFamily::MultiplyModPrime => "Multiply-mod-prime",
            HashFamily::Poly2 => "2-wise PolyHash",
            HashFamily::Poly3 => "3-wise PolyHash",
            HashFamily::Poly20 => "20-wise PolyHash",
            HashFamily::Murmur3 => "MurmurHash3",
            HashFamily::City => "CityHash",
            HashFamily::Blake2 => "Blake2",
            HashFamily::SimpleTab => "Simple tabulation",
            HashFamily::TwistedTab => "Twisted tabulation",
            HashFamily::MixedTab => "Mixed tabulation",
        }
    }

    /// Parse from the CLI/CSV identifier.
    pub fn parse(s: &str) -> Option<HashFamily> {
        Some(match s {
            "multiply_shift" | "ms" => HashFamily::MultiplyShift,
            "multiply_mod_prime" | "mmp" => HashFamily::MultiplyModPrime,
            "polyhash2" | "poly2" => HashFamily::Poly2,
            "polyhash3" | "poly3" => HashFamily::Poly3,
            "polyhash20" | "poly20" | "random" => HashFamily::Poly20,
            "murmur3" | "murmur" => HashFamily::Murmur3,
            "cityhash" | "city" => HashFamily::City,
            "blake2b" | "blake2" => HashFamily::Blake2,
            "simple_tab" => HashFamily::SimpleTab,
            "twisted_tab" => HashFamily::TwistedTab,
            "mixed_tab" | "mixedtab" | "mt" => HashFamily::MixedTab,
            _ => return None,
        })
    }

    /// Instantiate a boxed hasher with an independent seed stream.
    pub fn build(&self, seed: u64) -> Box<dyn Hasher32> {
        let mut sm = SplitMix64::new(seed);
        match self {
            HashFamily::MultiplyShift => Box::new(MultiplyShift::new(&mut sm)),
            HashFamily::MultiplyModPrime => Box::new(MultiplyModPrime::new(&mut sm)),
            HashFamily::Poly2 => Box::new(PolyHash::new(2, &mut sm)),
            HashFamily::Poly3 => Box::new(PolyHash::new(3, &mut sm)),
            HashFamily::Poly20 => Box::new(PolyHash::new(20, &mut sm)),
            HashFamily::Murmur3 => Box::new(Murmur3::new(&mut sm)),
            HashFamily::City => Box::new(City64::new(&mut sm)),
            HashFamily::Blake2 => Box::new(Blake2b::hasher(&mut sm)),
            HashFamily::SimpleTab => Box::new(SimpleTab32::new(&mut sm)),
            HashFamily::TwistedTab => Box::new(TwistedTab32::new(&mut sm)),
            HashFamily::MixedTab => Box::new(MixedTab32::new(&mut sm)),
        }
    }

    /// Instantiate a 64-bit-output hasher (two evaluations for families
    /// without a native wide output; native wide path for mixed tabulation).
    pub fn build64(&self, seed: u64) -> Box<dyn Hasher64> {
        let mut sm = SplitMix64::new(seed);
        match self {
            HashFamily::MixedTab => Box::new(MixedTab64::new(&mut sm)),
            _ => {
                let a = self.build(seed);
                let b = self.build(SplitMix64::new(seed ^ 0x9E3779B97F4A7C15).next_u64());
                Box::new(PairHasher64 { a, b })
            }
        }
    }
}

/// 64-bit output from two independent 32-bit hashers (the "evaluate twice"
/// fallback the paper contrasts against mixed tabulation's widened tables).
pub struct PairHasher64 {
    a: Box<dyn Hasher32>,
    b: Box<dyn Hasher32>,
}

impl Hasher64 for PairHasher64 {
    fn hash64(&self, x: u32) -> u64 {
        ((self.a.hash(x) as u64) << 32) | self.b.hash(x) as u64
    }
    fn name64(&self) -> &'static str {
        self.a.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip() {
        for fam in [
            HashFamily::MultiplyShift,
            HashFamily::MultiplyModPrime,
            HashFamily::Poly2,
            HashFamily::Poly3,
            HashFamily::Poly20,
            HashFamily::Murmur3,
            HashFamily::City,
            HashFamily::Blake2,
            HashFamily::SimpleTab,
            HashFamily::MixedTab,
        ] {
            assert_eq!(HashFamily::parse(fam.id()), Some(fam), "{}", fam.id());
        }
        assert_eq!(HashFamily::parse("nope"), None);
    }

    #[test]
    fn build_all_and_hash() {
        for fam in HashFamily::TABLE1 {
            let h = fam.build(42);
            let a = h.hash(1);
            let b = h.hash(2);
            // Deterministic given seed:
            let h2 = fam.build(42);
            assert_eq!(h2.hash(1), a, "{}", fam.id());
            assert_eq!(h2.hash(2), b, "{}", fam.id());
        }
    }

    #[test]
    fn seed_changes_function() {
        for fam in HashFamily::TABLE1 {
            let h1 = fam.build(1);
            let h2 = fam.build(2);
            let diff = (0u32..64).filter(|&x| h1.hash(x) != h2.hash(x)).count();
            assert!(diff > 32, "{} seed insensitivity: {diff}", fam.id());
        }
    }

    #[test]
    fn hash_slice_matches_scalar() {
        for fam in HashFamily::TABLE1 {
            let h = fam.build(7);
            let keys: Vec<u32> = (0u32..257).map(|i| i.wrapping_mul(2654435761)).collect();
            let mut out = vec![0u32; keys.len()];
            h.hash_slice(&keys, &mut out);
            for (k, o) in keys.iter().zip(&out) {
                assert_eq!(h.hash(*k), *o, "{}", fam.id());
            }
        }
    }

    #[test]
    fn pair_hasher64_combines_halves() {
        let h = HashFamily::Murmur3.build64(3);
        let v = h.hash64(123);
        assert_ne!(v >> 32, v & 0xFFFF_FFFF);
        let h2 = HashFamily::Murmur3.build64(3);
        assert_eq!(h2.hash64(123), v);
    }

    #[test]
    fn hash64_slice_matches_scalar() {
        // Covers both the MixedTab64 staged kernel and the PairHasher64
        // default loop.
        for fam in [HashFamily::MixedTab, HashFamily::Murmur3] {
            let h = fam.build64(7);
            let keys: Vec<u32> = (0u32..101).map(|i| i.wrapping_mul(2654435761)).collect();
            let mut out = vec![0u64; keys.len()];
            h.hash64_slice(&keys, &mut out);
            for (k, o) in keys.iter().zip(&out) {
                assert_eq!(h.hash64(*k), *o, "{}", fam.id());
            }
        }
    }

    #[test]
    fn mixedtab64_is_native() {
        let h = HashFamily::MixedTab.build64(9);
        assert_eq!(h.name64(), "mixed_tab");
        // determinism
        let h2 = HashFamily::MixedTab.build64(9);
        for x in [0u32, 1, 0xFFFF_FFFF, 12345] {
            assert_eq!(h.hash64(x), h2.hash64(x));
        }
    }
}
