//! Multiply-shift and multiply-mod-prime — the "classic" fast 2-independent
//! schemes the paper stress-tests.
//!
//! * [`MultiplyShift`] is Dietzfelbinger's strongly-universal scheme
//!   `h(x) = ((a·x + b) mod 2^64) >> 32` with `a, b` uniform 64-bit — the
//!   fastest known 2-independent hash (one multiply, one add, one shift).
//! * [`MultiplyModPrime`] is the textbook `((a·x + b) mod p) mod 2^32` with
//!   `p = 2^61 − 1` (Mersenne, so `mod p` is two adds and a shift).
//!
//! Both are *provably* 2-independent and *provably* no more: the paper's
//! Figures 2–4 show exactly where that breaks down (dense structured
//! inputs), which is the reproduction target — so resist any temptation to
//! "strengthen" these implementations.

use super::Hasher32;
use crate::util::rng::SplitMix64;

/// Dietzfelbinger et al. multiply-shift: `(a·x + b) >> 32` over `u64`.
#[derive(Debug, Clone)]
pub struct MultiplyShift {
    a: u64,
    b: u64,
}

impl MultiplyShift {
    /// Draw the two 64-bit parameters. `a` is forced odd — the standard
    /// choice that avoids the degenerate even-multiplier functions.
    pub fn new(seed: &mut SplitMix64) -> Self {
        Self {
            a: seed.next_u64() | 1,
            b: seed.next_u64(),
        }
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        (self.a.wrapping_mul(x as u64).wrapping_add(self.b) >> 32) as u32
    }
}

impl Hasher32 for MultiplyShift {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        let (a, b) = (self.a, self.b);
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = (a.wrapping_mul(*k as u64).wrapping_add(b) >> 32) as u32;
        }
    }

    fn name(&self) -> &'static str {
        "multiply_shift"
    }
}

/// The Mersenne prime `2^61 − 1` used for multiply-mod-prime and PolyHash.
pub const MERSENNE61: u64 = (1 << 61) - 1;

/// Reduce a 122-bit product modulo `2^61 − 1` without division.
///
/// For `z < 2^122`: `z ≡ (z mod 2^61) + (z div 2^61)  (mod p)`, and one
/// conditional subtraction completes the reduction (result may be `p`
/// itself, folded to 0; both represent the same residue and a second fold
/// keeps the value `< p`).
#[inline(always)]
pub fn mod_mersenne61(z: u128) -> u64 {
    let folded = (z & MERSENNE61 as u128) as u64 + (z >> 61) as u64;
    // folded < 2^62, one more fold brings it below 2^61 + something small.
    let folded = (folded & MERSENNE61) + (folded >> 61);
    if folded >= MERSENNE61 {
        folded - MERSENNE61
    } else {
        folded
    }
}

/// `((a·x + b) mod p) mod 2^32`, `p = 2^61 − 1` — the abstract's
/// "classic multiply-mod-prime scheme".
#[derive(Debug, Clone)]
pub struct MultiplyModPrime {
    a: u64,
    b: u64,
}

impl MultiplyModPrime {
    pub fn new(seed: &mut SplitMix64) -> Self {
        // a ∈ [1, p), b ∈ [0, p)
        let a = 1 + seed.next_u64() % (MERSENNE61 - 1);
        let b = seed.next_u64() % MERSENNE61;
        Self { a, b }
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        let z = self.a as u128 * x as u128 + self.b as u128;
        mod_mersenne61(z) as u32
    }
}

impl Hasher32 for MultiplyModPrime {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.eval(*k);
        }
    }

    fn name(&self) -> &'static str {
        "multiply_mod_prime"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(seed: u64) -> MultiplyShift {
        MultiplyShift::new(&mut SplitMix64::new(seed))
    }

    #[test]
    fn multiply_shift_algebra() {
        // With b = 0 and a known, the definition is directly checkable.
        let h = MultiplyShift { a: 0x1234_5678_9ABC_DEF1, b: 0 };
        for x in [0u32, 1, 2, 0xFFFF_FFFF] {
            let expect = (0x1234_5678_9ABC_DEF1u64.wrapping_mul(x as u64) >> 32) as u32;
            assert_eq!(h.hash(x), expect);
        }
    }

    #[test]
    fn multiplier_is_odd() {
        for s in 0..32 {
            let h = ms(s);
            assert_eq!(h.a & 1, 1);
        }
    }

    #[test]
    fn mod_mersenne_matches_naive() {
        // Compare against naive u128 remainder on structured + random values.
        let p = MERSENNE61 as u128;
        let mut g = SplitMix64::new(99);
        for i in 0..10_000u64 {
            let z = if i < 100 {
                // Edge region: multiples and near-multiples of p.
                (i as u128) * p + (i as u128 % 3)
            } else {
                (g.next_u64() as u128) << 57 | g.next_u64() as u128
            };
            assert_eq!(mod_mersenne61(z) as u128, z % p, "z={z}");
        }
        assert_eq!(mod_mersenne61(0), 0);
        assert_eq!(mod_mersenne61(p), 0);
        assert_eq!(mod_mersenne61(p - 1), MERSENNE61 - 1);
        assert_eq!(mod_mersenne61(2 * p), 0);
    }

    #[test]
    fn mmp_is_linear_mod_p() {
        // h(x) as a full 61-bit value is (a x + b) mod p; check the linear
        // structure via finite differences on the *pre-truncation* values.
        let mut sm = SplitMix64::new(5);
        let h = MultiplyModPrime::new(&mut sm);
        let full = |x: u32| mod_mersenne61(h.a as u128 * x as u128 + h.b as u128);
        let d1 = (full(11) + MERSENNE61 - full(10)) % MERSENNE61;
        let d2 = (full(21) + MERSENNE61 - full(20)) % MERSENNE61;
        assert_eq!(d1, d2, "constant difference = a mod p");
        assert_eq!(d1, h.a % MERSENNE61);
    }

    #[test]
    fn distribution_smoke() {
        // 2-independent families should spread uniform keys evenly.
        let h = ms(7);
        let mut buckets = [0u32; 16];
        for x in 0..100_000u32 {
            buckets[(h.hash(x) >> 28) as usize] += 1;
        }
        let expect = 100_000.0 / 16.0;
        for (i, &c) in buckets.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < expect * 0.2,
                "bucket {i} count {c}"
            );
        }
    }
}
