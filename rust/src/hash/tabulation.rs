//! Tabulation-based hashing: simple tabulation and the paper's **mixed
//! tabulation** (Dahlgaard, Knudsen, Rotenberg, Thorup — FOCS'15).
//!
//! Mixed tabulation with c = d = 4 over 8-bit characters (§2.4): view the
//! 32-bit key as 4 characters, derive 4 additional characters via XOR of
//! `T1` lookups, and XOR `T2` lookups of both original and derived
//! characters. [`MixedTab32`] mirrors the paper's sample implementation
//! bit-for-bit:
//!
//! ```c
//! uint64_t mt_T1[256][4];  uint32_t mt_T2[256][4];
//! uint32_t mixedtab(uint32_t x) {
//!   uint64_t h = 0;
//!   for (int i = 0; i < 4; ++i, x >>= 8)  h ^= mt_T1[(uint8_t)x][i];
//!   uint32_t drv = h >> 32;
//!   for (int i = 0; i < 4; ++i, drv >>= 8) h ^= mt_T2[(uint8_t)drv][i];
//!   return (uint32_t)h;
//! }
//! ```
//!
//! (the low 32 bits of the `T1` XOR are the `T2,i` contribution of the input
//! characters; the high 32 bits are the derived characters).
//!
//! [`MixedTab64`] widens the tables to produce 64 output bits in one
//! evaluation — the §2.4 trick for generating many hash values per key: the
//! two 32-bit halves are independent whp. over `T1`.
//!
//! Tables are filled by a 20-wise PolyHash ([`super::PolyHash`]), exactly as
//! in the paper's experiments ("the seed for mixed tabulation was filled out
//! using a random 20-wise PolyHash function"); Θ(log |U|)-independence
//! suffices for all applications considered [14].

use super::polyhash::PolyHash;
use super::Hasher32;
use crate::hash::Hasher64;
use crate::util::rng::SplitMix64;

/// Fill a u64 table using a 20-wise PolyHash evaluated on sequential points.
///
/// Each 61-bit polynomial evaluation yields one table word's low 61 bits;
/// a second evaluation tops up the high bits so all 64 bits are seeded.
fn fill_u64(seeder: &PolyHash, counter: &mut u32, n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let lo = seeder.eval61(*counter);
        *counter += 1;
        let hi = seeder.eval61(*counter);
        *counter += 1;
        out.push(lo | (hi << 61));
    }
    out
}

/// Simple tabulation over 32-bit keys: 4 tables of 256 random 32-bit words.
/// 3-independent; fast but provably weaker than mixed tabulation for
/// statistics over k-partitions. Included as an ablation point.
pub struct SimpleTab32 {
    /// `t[i][c]` = table for character position i. Flattened [4 * 256].
    t: Vec<u32>,
}

impl SimpleTab32 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        let seeder = PolyHash::new(20, &mut SplitMix64::new(seed.next_u64()));
        let mut counter = 0u32;
        let words = fill_u64(&seeder, &mut counter, 512);
        // 512 u64 words -> 1024 u32 entries.
        let mut t = Vec::with_capacity(1024);
        for w in words {
            t.push(w as u32);
            t.push((w >> 32) as u32);
        }
        Self { t }
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        let b0 = (x & 0xFF) as usize;
        let b1 = ((x >> 8) & 0xFF) as usize;
        let b2 = ((x >> 16) & 0xFF) as usize;
        let b3 = (x >> 24) as usize;
        self.t[b0] ^ self.t[256 + b1] ^ self.t[512 + b2] ^ self.t[768 + b3]
    }
}

impl Hasher32 for SimpleTab32 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.eval(*k);
        }
    }

    fn name(&self) -> &'static str {
        "simple_tab"
    }
}

/// Mixed tabulation, c = d = 4, 32-bit keys → 32-bit values.
///
/// Layout note (perf): `t1` is indexed `[char_value][position]` exactly like
/// the paper's `mt_T1[256][4]` so one key's four position lookups for the
/// same byte value share cache lines; total table footprint is
/// 4·256·8 + 4·256·4 = 12 KiB — resident in L1d, which is where mixed
/// tabulation's speed comes from.
pub struct MixedTab32 {
    /// `t1[pos * 256 + byte]`: u64 entries (low 32 output bits ⊕ high 32
    /// derived bits). Fixed-size boxed array: index expressions are
    /// `offset + (byte & 0xFF)` with compile-time-provable bounds, so the
    /// optimiser elides every bounds check (§Perf: Vec-backed tables cost
    /// ~25% on the Table 1 hot loop).
    t1: Box<[u64; 1024]>,
    /// `t2[pos * 256 + byte]`: u32 entries folded over the derived chars.
    t2: Box<[u32; 1024]>,
}

impl MixedTab32 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        let seeder = PolyHash::new(20, &mut SplitMix64::new(seed.next_u64()));
        let mut counter = 0u32;
        let t1: Box<[u64; 1024]> = fill_u64(&seeder, &mut counter, 4 * 256)
            .try_into()
            .unwrap();
        let t2_vec: Vec<u32> = fill_u64(&seeder, &mut counter, 2 * 256)
            .into_iter()
            .flat_map(|w| [w as u32, (w >> 32) as u32])
            .collect();
        let t2: Box<[u32; 1024]> = t2_vec.try_into().unwrap();
        Self { t1, t2 }
    }

    /// First stage: XOR of the four T1 lookups (low 32 bits = output
    /// contribution, high 32 bits = derived characters).
    #[inline(always)]
    fn t1_acc(&self, x: u32) -> u64 {
        self.t1[(x & 0xFF) as usize]
            ^ self.t1[256 + ((x >> 8) & 0xFF) as usize]
            ^ self.t1[512 + ((x >> 16) & 0xFF) as usize]
            ^ self.t1[768 + (x >> 24) as usize]
    }

    /// Second stage: fold the T2 lookups of the derived characters into the
    /// T1 accumulator and truncate to the 32 output bits.
    #[inline(always)]
    fn t2_fold(&self, mut h: u64) -> u32 {
        let drv = (h >> 32) as u32;
        h ^= self.t2[(drv & 0xFF) as usize] as u64;
        h ^= self.t2[256 + ((drv >> 8) & 0xFF) as usize] as u64;
        h ^= self.t2[512 + ((drv >> 16) & 0xFF) as usize] as u64;
        h ^= self.t2[768 + (drv >> 24) as usize] as u64;
        h as u32
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        self.t2_fold(self.t1_acc(x))
    }
}

impl Hasher32 for MixedTab32 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        // Four keys per iteration, *staged*: all four T1 accumulations
        // issue before any T2 fold, so the four independent T1→T2
        // dependency chains (~13 cycles deep each) overlap and both L1d
        // load ports stay busy instead of serialising per key (§Perf).
        let chunks = keys.len() / 4 * 4;
        let mut i = 0;
        while i < chunks {
            let h0 = self.t1_acc(keys[i]);
            let h1 = self.t1_acc(keys[i + 1]);
            let h2 = self.t1_acc(keys[i + 2]);
            let h3 = self.t1_acc(keys[i + 3]);
            out[i] = self.t2_fold(h0);
            out[i + 1] = self.t2_fold(h1);
            out[i + 2] = self.t2_fold(h2);
            out[i + 3] = self.t2_fold(h3);
            i += 4;
        }
        for j in chunks..keys.len() {
            out[j] = self.eval(keys[j]);
        }
    }

    fn name(&self) -> &'static str {
        "mixed_tab"
    }
}

/// Mixed tabulation with 64 output bits per evaluation (§2.4 widened-table
/// trick). `T1` entries carry 64 output bits + 32 derived bits; `T2` carries
/// 64 bits per derived character.
pub struct MixedTab64 {
    /// Output-part of T1: `[pos * 256 + byte]`.
    t1_out: Vec<u64>,
    /// Derived-characters part of T1.
    t1_drv: Vec<u32>,
    /// T2 over derived characters: u64 entries.
    t2: Vec<u64>,
}

impl MixedTab64 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        let seeder = PolyHash::new(20, &mut SplitMix64::new(seed.next_u64()));
        let mut counter = 0u32;
        let t1_out = fill_u64(&seeder, &mut counter, 4 * 256);
        let t1_drv: Vec<u32> = fill_u64(&seeder, &mut counter, 2 * 256)
            .into_iter()
            .flat_map(|w| [w as u32, (w >> 32) as u32])
            .collect();
        let t2 = fill_u64(&seeder, &mut counter, 4 * 256);
        Self { t1_out, t1_drv, t2 }
    }

    /// First stage: the four T1 lookups, returning `(output accumulator,
    /// derived characters)`.
    #[inline(always)]
    fn t1_stage(&self, x: u32) -> (u64, u32) {
        let i0 = (x & 0xFF) as usize;
        let i1 = ((x >> 8) & 0xFF) as usize;
        let i2 = ((x >> 16) & 0xFF) as usize;
        let i3 = (x >> 24) as usize;
        let h = self.t1_out[i0] ^ self.t1_out[256 + i1] ^ self.t1_out[512 + i2]
            ^ self.t1_out[768 + i3];
        let drv =
            self.t1_drv[i0] ^ self.t1_drv[256 + i1] ^ self.t1_drv[512 + i2] ^ self.t1_drv[768 + i3];
        (h, drv)
    }

    /// Second stage: fold T2 over the derived characters.
    #[inline(always)]
    fn t2_fold(&self, mut h: u64, drv: u32) -> u64 {
        h ^= self.t2[(drv & 0xFF) as usize];
        h ^= self.t2[256 + ((drv >> 8) & 0xFF) as usize];
        h ^= self.t2[512 + ((drv >> 16) & 0xFF) as usize];
        h ^= self.t2[768 + (drv >> 24) as usize];
        h
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u64 {
        let (h, drv) = self.t1_stage(x);
        self.t2_fold(h, drv)
    }
}

impl Hasher64 for MixedTab64 {
    #[inline]
    fn hash64(&self, x: u32) -> u64 {
        self.eval(x)
    }

    fn hash64_slice(&self, keys: &[u32], out: &mut [u64]) {
        assert_eq!(keys.len(), out.len());
        // The pooled-source fill kernel: four keys per iteration with the
        // T1 stage fully issued before any T2 fold, same rationale as
        // [`MixedTab32::hash_slice`] — this is the batch that fills a whole
        // hash pool in one pass, so it is the hottest loop of pooled
        // sketching.
        let chunks = keys.len() / 4 * 4;
        let mut i = 0;
        while i < chunks {
            let (h0, d0) = self.t1_stage(keys[i]);
            let (h1, d1) = self.t1_stage(keys[i + 1]);
            let (h2, d2) = self.t1_stage(keys[i + 2]);
            let (h3, d3) = self.t1_stage(keys[i + 3]);
            out[i] = self.t2_fold(h0, d0);
            out[i + 1] = self.t2_fold(h1, d1);
            out[i + 2] = self.t2_fold(h2, d2);
            out[i + 3] = self.t2_fold(h3, d3);
            i += 4;
        }
        for j in chunks..keys.len() {
            out[j] = self.eval(keys[j]);
        }
    }

    fn name64(&self) -> &'static str {
        "mixed_tab"
    }
}

/// Also expose the 64-bit variant's low half as a `Hasher32` (used when one
/// seeded instance must serve both interfaces).
impl Hasher32 for MixedTab64 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x) as u32
    }

    fn name(&self) -> &'static str {
        "mixed_tab"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mt32(seed: u64) -> MixedTab32 {
        MixedTab32::new(&mut SplitMix64::new(seed))
    }

    #[test]
    fn matches_reference_loop_structure() {
        // Re-evaluate via the paper's loop shape (x >>= 8 / drv >>= 8) with
        // direct table indexing and compare — guards the unrolled version.
        let h = mt32(11);
        let mut g = SplitMix64::new(2);
        for _ in 0..2000 {
            let key = g.next_u32();
            let mut acc: u64 = 0;
            let mut x = key;
            for i in 0..4 {
                acc ^= h.t1[i * 256 + (x & 0xFF) as usize];
                x >>= 8;
            }
            let mut drv = (acc >> 32) as u32;
            for i in 0..4 {
                acc ^= h.t2[i * 256 + (drv & 0xFF) as usize] as u64;
                drv >>= 8;
            }
            assert_eq!(h.hash(key), acc as u32);
        }
    }

    #[test]
    fn xor_structure_of_t1_layer() {
        // For keys differing in a single character, the T1 XOR difference
        // must equal the XOR of the two table entries at that position
        // (before the T2 layer mixes in derived characters). We verify on
        // the internal T1 accumulation.
        let h = mt32(3);
        let t1_acc = |x: u32| -> u64 {
            h.t1[(x & 0xFF) as usize]
                ^ h.t1[256 + ((x >> 8) & 0xFF) as usize]
                ^ h.t1[512 + ((x >> 16) & 0xFF) as usize]
                ^ h.t1[768 + (x >> 24) as usize]
        };
        let a = t1_acc(0x0000_0001);
        let b = t1_acc(0x0000_0002);
        assert_eq!(a ^ b, h.t1[1] ^ h.t1[2]);
        let c = t1_acc(0x0100_0001);
        assert_eq!(a ^ c, h.t1[768] ^ h.t1[768 + 1]);
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = mt32(1);
        let b = mt32(1);
        let c = mt32(2);
        let mut differs = 0;
        for x in 0..512u32 {
            assert_eq!(a.hash(x), b.hash(x));
            if a.hash(x) != c.hash(x) {
                differs += 1;
            }
        }
        assert!(differs > 500);
    }

    #[test]
    fn bucket_uniformity_structured_keys() {
        // Dense consecutive keys — the exact regime where multiply-shift
        // fails; tabulation should spread them uniformly.
        let h = mt32(5);
        let mut buckets = [0u32; 64];
        for x in 0..100_000u32 {
            buckets[(h.hash(x) % 64) as usize] += 1;
        }
        let expect = 100_000.0 / 64.0;
        for &c in &buckets {
            assert!((c as f64 - expect).abs() < expect * 0.25, "count {c}");
        }
    }

    #[test]
    fn avalanche() {
        let h = mt32(7);
        let mut total = 0u32;
        let trials = 4000;
        let mut g = SplitMix64::new(5);
        for _ in 0..trials {
            let x = g.next_u32();
            let bit = 1u32 << (g.next_u32() % 32);
            total += (h.hash(x) ^ h.hash(x ^ bit)).count_ones();
        }
        let avg = total as f64 / trials as f64;
        assert!((avg - 16.0).abs() < 1.0, "avalanche avg {avg}");
    }

    #[test]
    fn mixedtab64_halves_behave_independently() {
        let h = MixedTab64::new(&mut SplitMix64::new(9));
        // The low and high halves should not be correlated: count matching
        // bits between halves across keys; expect ~16/32.
        let mut total = 0u32;
        let n = 4000;
        for x in 0..n {
            let v = h.hash64(x);
            total += ((v as u32) ^ (v >> 32) as u32).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!((avg - 16.0).abs() < 1.0, "half-correlation avg {avg}");
        // And Hasher32 view is the low half.
        assert_eq!(Hasher32::hash(&h, 123), h.hash64(123) as u32);
    }

    #[test]
    fn mixedtab64_slice_matches_scalar_at_every_length() {
        // Guards the staged/unrolled hash64_slice kernel, including the
        // remainder tail at every length mod 4.
        let h = MixedTab64::new(&mut SplitMix64::new(17));
        let mut g = SplitMix64::new(23);
        for n in 0..=19usize {
            let keys: Vec<u32> = (0..n).map(|_| g.next_u32()).collect();
            let mut out = vec![0u64; n];
            h.hash64_slice(&keys, &mut out);
            for (k, o) in keys.iter().zip(&out) {
                assert_eq!(*o, h.hash64(*k), "n={n}");
            }
        }
    }

    #[test]
    fn mixedtab32_slice_matches_scalar_at_every_length() {
        let h = mt32(19);
        let mut g = SplitMix64::new(29);
        for n in 0..=19usize {
            let keys: Vec<u32> = (0..n).map(|_| g.next_u32()).collect();
            let mut out = vec![0u32; n];
            h.hash_slice(&keys, &mut out);
            for (k, o) in keys.iter().zip(&out) {
                assert_eq!(*o, h.hash(*k), "n={n}");
            }
        }
    }

    #[test]
    fn simple_tab_linearity_over_xor_of_disjoint_chars() {
        // Simple tabulation: h(x) ^ h(y) ^ h(x ^ y) == h(0) when x and y
        // occupy disjoint character positions (XOR-linearity per position).
        let h = SimpleTab32::new(&mut SplitMix64::new(4));
        let x = 0x0000_00ABu32;
        let y = 0x00CD_0000u32;
        assert_eq!(h.hash(x) ^ h.hash(y) ^ h.hash(x ^ y), h.hash(0));
    }

    #[test]
    fn mixed_tab_breaks_simple_tab_linearity() {
        // The derived-character layer should destroy the above relation for
        // most seeds/keys — that is mixed tabulation's entire point.
        let mut broken = 0;
        for seed in 0..8u64 {
            let h = mt32(seed);
            let x = 0x0000_00ABu32;
            let y = 0x00CD_0000u32;
            if h.hash(x) ^ h.hash(y) ^ h.hash(x ^ y) != h.hash(0) {
                broken += 1;
            }
        }
        assert!(broken >= 7, "linearity persisted in {}/8 seeds", 8 - broken);
    }
}
