//! Twisted tabulation (Pătrașcu & Thorup, SODA'13) — the intermediate point
//! between simple and mixed tabulation, included as an ablation.
//!
//! Like simple tabulation, but the first character's table additionally
//! yields a "twist": a random value XORed into the *key's remaining
//! characters* before they index their tables. Twisted tabulation fixes
//! several of simple tabulation's failure modes (e.g. min-wise bias on
//! structured sets) at one extra lookup, but does not reach mixed
//! tabulation's Chernoff-style guarantees for statistics over k-partitions
//! — which is exactly the gap the paper's [14] closed. Having all three in
//! the zoo lets `exp synth2 --ablate-tabulation` show the progression.

use super::polyhash::PolyHash;
use super::Hasher32;
use crate::util::rng::SplitMix64;

/// Twisted tabulation over 32-bit keys, c = 4 characters of 8 bits.
///
/// `t0[b]` returns 64 bits: low 32 = output contribution, high 24 used to
/// twist the remaining three characters.
pub struct TwistedTab32 {
    /// Twist table for character 0: `[256]` entries of (out32 | twist<<32).
    t0: Vec<u64>,
    /// Plain tables for characters 1..4: `t[pos-1][byte]` flattened.
    t: Vec<u32>,
}

impl TwistedTab32 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        let seeder = PolyHash::new(20, &mut SplitMix64::new(seed.next_u64()));
        let mut counter = 0u32;
        let next64 = |c: &mut u32| {
            let lo = seeder.eval61(*c);
            *c += 1;
            let hi = seeder.eval61(*c);
            *c += 1;
            lo | (hi << 61)
        };
        let t0: Vec<u64> = (0..256).map(|_| next64(&mut counter)).collect();
        // Three plain tables of 256 u32 entries (positions 1..4).
        let t: Vec<u32> = (0..768)
            .map(|_| next64(&mut counter) as u32)
            .collect();
        Self { t0, t }
    }

    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        let e0 = self.t0[(x & 0xFF) as usize];
        // Twist the upper 24 bits of the key.
        let rest = (x >> 8) ^ ((e0 >> 32) as u32 & 0x00FF_FFFF);
        let b1 = (rest & 0xFF) as usize;
        let b2 = ((rest >> 8) & 0xFF) as usize;
        let b3 = ((rest >> 16) & 0xFF) as usize;
        (e0 as u32) ^ self.t[b1] ^ self.t[256 + b2] ^ self.t[512 + b3]
    }
}

impl Hasher32 for TwistedTab32 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.eval(*k);
        }
    }

    fn name(&self) -> &'static str {
        "twisted_tab"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tt(seed: u64) -> TwistedTab32 {
        TwistedTab32::new(&mut SplitMix64::new(seed))
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = tt(1);
        let b = tt(1);
        let c = tt(2);
        let mut diff = 0;
        for x in 0..512u32 {
            assert_eq!(a.hash(x), b.hash(x));
            diff += (a.hash(x) != c.hash(x)) as u32;
        }
        assert!(diff > 500);
    }

    #[test]
    fn twist_breaks_char_linearity() {
        // Simple tabulation satisfies h(x)^h(y)^h(x^y)^h(0) == 0 for keys in
        // disjoint character positions; the twist must break this for most
        // seeds when character 0 participates.
        let mut broken = 0;
        for seed in 0..8u64 {
            let h = tt(seed);
            let x = 0x0000_00ABu32; // char 0
            let y = 0x00CD_0000u32; // char 2
            if h.hash(x) ^ h.hash(y) ^ h.hash(x ^ y) != h.hash(0) {
                broken += 1;
            }
        }
        assert!(broken >= 7, "twist ineffective in {}/8 seeds", 8 - broken);
    }

    #[test]
    fn uniform_buckets() {
        let h = tt(7);
        let mut buckets = [0u32; 64];
        for x in 0..100_000u32 {
            buckets[(h.hash(x) % 64) as usize] += 1;
        }
        let expect = 100_000.0 / 64.0;
        for &c in &buckets {
            assert!((c as f64 - expect).abs() < expect * 0.25);
        }
    }
}
