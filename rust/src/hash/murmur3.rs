//! MurmurHash3 (x86_32 variant) — Austin Appleby, public domain.
//!
//! The paper's "very popular hash function with no proven guarantees"; it
//! performs like truly-random hashing in all of the paper's experiments but
//! is ~40% slower than mixed tabulation and is known to be breakable by
//! adversarial input construction ([1] in the paper).
//!
//! This is a faithful port of the reference `MurmurHash3_x86_32`, validated
//! against the reference implementation's published test vectors. Keys on
//! the paper's hot path are 32-bit integers, so [`Murmur3::hash`] uses a
//! specialised single-block evaluation (identical output to hashing the
//! 4 little-endian bytes).

use super::Hasher32;
use crate::util::rng::SplitMix64;

#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85EB_CA6B);
    h ^= h >> 13;
    h = h.wrapping_mul(0xC2B2_AE35);
    h ^= h >> 16;
    h
}

const C1: u32 = 0xCC9E_2D51;
const C2: u32 = 0x1B87_3593;

/// MurmurHash3_x86_32 over an arbitrary byte slice with the given seed.
pub fn murmur3_x86_32(data: &[u8], seed: u32) -> u32 {
    let nblocks = data.len() / 4;
    let mut h1 = seed;

    // Body: 4-byte little-endian blocks.
    for i in 0..nblocks {
        let b = &data[i * 4..i * 4 + 4];
        let mut k1 = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xE654_6B64);
    }

    // Tail.
    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if tail.len() >= 3 {
        k1 ^= (tail[2] as u32) << 16;
    }
    if tail.len() >= 2 {
        k1 ^= (tail[1] as u32) << 8;
    }
    if !tail.is_empty() {
        k1 ^= tail[0] as u32;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }

    // Finalisation.
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// Seeded MurmurHash3 over 32-bit keys (single-block fast path).
#[derive(Debug, Clone)]
pub struct Murmur3 {
    seed: u32,
}

impl Murmur3 {
    pub fn new(seed: &mut SplitMix64) -> Self {
        Self {
            seed: seed.next_u32(),
        }
    }

    pub fn with_seed(seed: u32) -> Self {
        Self { seed }
    }

    /// One-block specialisation of `murmur3_x86_32` for a 4-byte key.
    #[inline(always)]
    fn eval(&self, x: u32) -> u32 {
        let mut k1 = x; // little-endian bytes of x form the block
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        let mut h1 = self.seed ^ k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xE654_6B64);
        h1 ^= 4; // len
        fmix32(h1)
    }
}

impl Hasher32 for Murmur3 {
    #[inline]
    fn hash(&self, x: u32) -> u32 {
        self.eval(x)
    }

    fn hash_slice(&self, keys: &[u32], out: &mut [u32]) {
        assert_eq!(keys.len(), out.len());
        for (k, o) in keys.iter().zip(out.iter_mut()) {
            *o = self.eval(*k);
        }
    }

    fn name(&self) -> &'static str {
        "murmur3"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference test vectors for MurmurHash3_x86_32 (from the SMHasher
    /// verification corpus; widely cross-checked).
    #[test]
    fn reference_vectors() {
        let cases: &[(&[u8], u32, u32)] = &[
            (b"", 0, 0),
            (b"", 1, 0x514E_28B7),
            (b"", 0xFFFF_FFFF, 0x81F1_6F39),
            (&[0xFF, 0xFF, 0xFF, 0xFF], 0, 0x7629_3B50),
            (&[0x21, 0x43, 0x65, 0x87], 0, 0xF55B_516B),
            (&[0x21, 0x43, 0x65, 0x87], 0x5082_EDEE, 0x2362_F9DE),
            (&[0x21, 0x43, 0x65], 0, 0x7E4A_8634),
            (&[0x21, 0x43], 0, 0xA0F7_B07A),
            (&[0x21], 0, 0x7266_1CF4),
            (&[0x00, 0x00, 0x00, 0x00], 0, 0x2362_F9DE),
            (&[0x00, 0x00, 0x00], 0, 0x85F0_B427),
            (&[0x00, 0x00], 0, 0x30F4_C306),
            (&[0x00], 0, 0x514E_28B7),
        ];
        for (data, seed, expect) in cases {
            assert_eq!(
                murmur3_x86_32(data, *seed),
                *expect,
                "data={data:02x?} seed={seed:#x}"
            );
        }
    }

    #[test]
    fn u32_fast_path_matches_bytes() {
        let h = Murmur3::with_seed(0xDEAD_BEEF);
        for x in [0u32, 1, 0x8721_4365, u32::MAX, 42] {
            assert_eq!(h.hash(x), murmur3_x86_32(&x.to_le_bytes(), 0xDEAD_BEEF));
        }
        // And across many random keys.
        let mut g = SplitMix64::new(1);
        for _ in 0..1000 {
            let x = g.next_u32();
            assert_eq!(h.hash(x), murmur3_x86_32(&x.to_le_bytes(), 0xDEAD_BEEF));
        }
    }

    #[test]
    fn longer_inputs_exercise_tail_paths() {
        // Every tail length 0..4 over a fixed pattern; check determinism and
        // distinctness (these are regression pins computed from this port,
        // guarding against accidental edits).
        let data = b"The quick brown fox jumps over the lazy dog";
        let full = murmur3_x86_32(data, 0x9747_B28C);
        assert_eq!(full, murmur3_x86_32(data, 0x9747_B28C));
        let mut outs = std::collections::HashSet::new();
        for l in 0..data.len() {
            outs.insert(murmur3_x86_32(&data[..l], 7));
        }
        assert_eq!(outs.len(), data.len());
    }

    #[test]
    fn avalanche_smoke() {
        // Flipping one input bit should flip ~16 of 32 output bits on average.
        let h = Murmur3::with_seed(123);
        let mut total = 0u32;
        let trials = 2000;
        let mut g = SplitMix64::new(5);
        for _ in 0..trials {
            let x = g.next_u32();
            let bit = 1u32 << (g.next_u32() % 32);
            total += (h.hash(x) ^ h.hash(x ^ bit)).count_ones();
        }
        let avg = total as f64 / trials as f64;
        assert!((avg - 16.0).abs() < 1.0, "avalanche avg {avg}");
    }
}
