//! One hash-evaluation layer for multi-coordinate sketches.
//!
//! `SimHash`/`MinHash` need one 32-bit hash value per key per output
//! coordinate (K·L bits for angular LSH, k values for MinHash). A
//! [`HashSource`] abstracts *where those values come from*:
//!
//! * [`IndependentSource`] — one seeded hasher per coordinate, today's
//!   behaviour refactored behind the trait. Bit-identical to the
//!   pre-refactor sketchers because the sketchers keep deriving the exact
//!   same per-coordinate hashers and merely hand them over.
//! * [`PooledSource`] — the Puffinn `hash_source/pool.hpp` pattern: a
//!   shared pool of `pool_bits` precomputed hash bits per key, filled by
//!   one batched mixed-tabulation pass ([`crate::hash::Hasher64::
//!   hash64_slice`], `pool_bits / 64` wide evaluations per key), from
//!   which each coordinate reads a deterministic 32-bit window. Sketch
//!   cost becomes O(pool) hash work instead of O(coordinates) — for
//!   angular LSH with K·L = 100+ bits and `pool=256`, a ~3× cut in hash
//!   evaluations — at a quantifiable independence cost: windows overlap,
//!   so coordinates are no longer independent functions. The fig5-style
//!   recall-parity property test bounds that cost (≤ 0.02 absolute
//!   recall gap at matched (K, L)).
//!
//! The pool is *per batch of keys*, not global state: callers provide a
//! reusable word buffer (one lives in [`crate::sketch::Scratch`]), the
//! source fills it once in [`HashSource::begin`], and every
//! [`HashSource::fill`] call reads windows out of it. Everything is a
//! pure function of `(family, seed)` — same spec string ⇒ identical pool
//! contents, sketches, and snapshot bytes across processes.

use super::{HashFamily, Hasher32, Hasher64};
use crate::util::rng::SplitMix64;

/// Seed salt for the pool's word fillers (one [`Hasher64`] per 64 pool
/// bits). Distinct from every other salt in the crate so pooled and
/// independent sketchers never share hash functions by accident.
const POOL_FILL_SALT: u64 = 0xB175_EED0_0F11_1E55;

/// Seed salt for the per-coordinate window offsets.
const POOL_OFFSET_SALT: u64 = 0x0FF5_E7D0_0B17_5EED;

/// Where a sketcher's per-coordinate hash values come from.
///
/// Contract: for every coordinate `i < outputs()` and key batch `keys`,
/// `begin(keys, pool)` followed by `fill(i, keys, pool, out)` must leave
/// `out[j] == hash_one(i, keys[j])` — the batched path and the scalar
/// reference are interchangeable, which is what the per-key reference
/// sketch paths (`sketch_per_key`) and their equivalence tests rely on.
pub trait HashSource: Send + Sync {
    /// Number of 32-bit values produced per key (the sketch width served).
    fn outputs(&self) -> usize;

    /// Prepare for a batch of keys: pooled sources hash the whole pool
    /// into `pool` here (resizing it as needed); independent sources do
    /// nothing. Call once per batch, before any [`HashSource::fill`].
    fn begin(&self, keys: &[u32], pool: &mut Vec<u64>);

    /// Write coordinate `i`'s hash value for every key into `out`
    /// (`out.len() == keys.len()`), reading the pool prepared by
    /// [`HashSource::begin`] for the same `keys`.
    fn fill(&self, i: usize, keys: &[u32], pool: &[u64], out: &mut [u32]);

    /// Scalar reference: coordinate `i`'s hash value for one key.
    fn hash_one(&self, i: usize, key: u32) -> u32;
}

/// One seeded [`Hasher32`] per output coordinate — the pre-refactor
/// behaviour. The sketchers construct the hashers themselves (keeping
/// their seed-derivation loops bit-identical) and wrap them here.
pub struct IndependentSource {
    hashers: Vec<Box<dyn Hasher32>>,
}

impl IndependentSource {
    pub fn new(hashers: Vec<Box<dyn Hasher32>>) -> Self {
        Self { hashers }
    }

    /// The underlying per-coordinate hashers (diagnostics / tests).
    pub fn hashers(&self) -> &[Box<dyn Hasher32>] {
        &self.hashers
    }
}

impl HashSource for IndependentSource {
    fn outputs(&self) -> usize {
        self.hashers.len()
    }

    fn begin(&self, _keys: &[u32], _pool: &mut Vec<u64>) {}

    fn fill(&self, i: usize, keys: &[u32], _pool: &[u64], out: &mut [u32]) {
        self.hashers[i].hash_slice(keys, out);
    }

    fn hash_one(&self, i: usize, key: u32) -> u32 {
        self.hashers[i].hash(key)
    }
}

/// A shared pool of `pool_bits` hash bits per key; each coordinate reads
/// a fixed 32-bit window at a seed-derived bit offset.
///
/// Pool layout in the scratch buffer is **word-major**: word `w`'s values
/// for all keys are contiguous (`pool[w * n + j]` = word `w` of key `j`),
/// so [`HashSource::begin`] is `pool_bits / 64` calls to
/// [`Hasher64::hash64_slice`] — each a monomorphic batched kernel — and
/// [`HashSource::fill`]'s window extraction walks two contiguous runs.
pub struct PooledSource {
    /// One wide hasher per 64 pool bits, seeds drawn from
    /// `SplitMix64(seed ^ POOL_FILL_SALT)`.
    fillers: Vec<Box<dyn Hasher64>>,
    /// Per-coordinate window offsets in `[0, pool_bits - 32]`, drawn from
    /// `SplitMix64(seed ^ POOL_OFFSET_SALT)`.
    offsets: Vec<u32>,
    pool_bits: usize,
}

impl PooledSource {
    /// `pool_bits` must be a positive multiple of 64 (whole pool words)
    /// so every 32-bit window fits; the spec layer validates this before
    /// construction ([`crate::sketch::SketchSpec`]'s `pool=` parameter).
    pub fn new(family: HashFamily, seed: u64, outputs: usize, pool_bits: usize) -> Self {
        assert!(
            pool_bits >= 64 && pool_bits % 64 == 0,
            "pool_bits must be a positive multiple of 64, got {pool_bits}"
        );
        let words = pool_bits / 64;
        let mut fill_seeds = SplitMix64::new(seed ^ POOL_FILL_SALT);
        let fillers = (0..words)
            .map(|_| family.build64(fill_seeds.next_u64()))
            .collect();
        let mut off_seeds = SplitMix64::new(seed ^ POOL_OFFSET_SALT);
        // Offsets range over [0, pool_bits - 32] so the window's last bit
        // (offset + 31) stays inside the pool.
        let offsets = (0..outputs)
            .map(|_| (off_seeds.next_u64() % (pool_bits as u64 - 31)) as u32)
            .collect();
        Self {
            fillers,
            offsets,
            pool_bits,
        }
    }

    pub fn pool_bits(&self) -> usize {
        self.pool_bits
    }

    /// The coordinate → pool-bit-offset map (tests / diagnostics).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Extract the 32-bit window at bit `off` from key `j`'s pool words
    /// laid out word-major over `n` keys.
    #[inline(always)]
    fn window(&self, pool: &[u64], n: usize, j: usize, off: u32) -> u32 {
        let w = (off >> 6) as usize;
        let s = off & 63;
        let lo = pool[w * n + j] >> s;
        let v = if s > 32 {
            lo | (pool[(w + 1) * n + j] << (64 - s))
        } else {
            lo
        };
        v as u32
    }
}

impl HashSource for PooledSource {
    fn outputs(&self) -> usize {
        self.offsets.len()
    }

    fn begin(&self, keys: &[u32], pool: &mut Vec<u64>) {
        let n = keys.len();
        pool.clear();
        pool.resize(self.fillers.len() * n, 0);
        for (w, filler) in self.fillers.iter().enumerate() {
            filler.hash64_slice(keys, &mut pool[w * n..(w + 1) * n]);
        }
    }

    fn fill(&self, i: usize, keys: &[u32], pool: &[u64], out: &mut [u32]) {
        let n = keys.len();
        assert_eq!(out.len(), n);
        assert_eq!(pool.len(), self.fillers.len() * n, "begin() not called for this batch");
        let off = self.offsets[i];
        for (j, o) in out.iter_mut().enumerate() {
            *o = self.window(pool, n, j, off);
        }
    }

    fn hash_one(&self, i: usize, key: u32) -> u32 {
        let off = self.offsets[i];
        let w = (off >> 6) as usize;
        let s = off & 63;
        let lo = self.fillers[w].hash64(key) >> s;
        let v = if s > 32 {
            lo | (self.fillers[w + 1].hash64(key) << (64 - s))
        } else {
            lo
        };
        v as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize, seed: u64) -> Vec<u32> {
        let mut g = SplitMix64::new(seed);
        (0..n).map(|_| g.next_u32()).collect()
    }

    #[test]
    fn independent_source_is_the_wrapped_hashers() {
        let hashers: Vec<Box<dyn Hasher32>> = (0..6u64)
            .map(|i| HashFamily::MixedTab.build(100 + i))
            .collect();
        let reference: Vec<Box<dyn Hasher32>> = (0..6u64)
            .map(|i| HashFamily::MixedTab.build(100 + i))
            .collect();
        let src = IndependentSource::new(hashers);
        assert_eq!(src.outputs(), 6);
        let ks = keys(33, 1);
        let mut pool = Vec::new();
        src.begin(&ks, &mut pool);
        let mut out = vec![0u32; ks.len()];
        for i in 0..src.outputs() {
            src.fill(i, &ks, &pool, &mut out);
            for (k, o) in ks.iter().zip(&out) {
                assert_eq!(*o, reference[i].hash(*k));
                assert_eq!(*o, src.hash_one(i, *k));
            }
        }
    }

    #[test]
    fn pooled_fill_matches_scalar_reference() {
        // The batched window extraction must equal hash_one for every
        // coordinate and key — including batch lengths around the
        // hash64_slice unroll width.
        for family in [HashFamily::MixedTab, HashFamily::Murmur3] {
            let src = PooledSource::new(family, 42, 24, 256);
            let mut pool = Vec::new();
            for n in [1usize, 3, 4, 7, 64] {
                let ks = keys(n, 9);
                src.begin(&ks, &mut pool);
                let mut out = vec![0u32; n];
                for i in 0..src.outputs() {
                    src.fill(i, &ks, &pool, &mut out);
                    for (k, o) in ks.iter().zip(&out) {
                        assert_eq!(*o, src.hash_one(i, *k), "{} n={n} i={i}", family.id());
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_offsets_keep_windows_inside_pool() {
        for pool_bits in [64usize, 128, 256, 1024] {
            let src = PooledSource::new(HashFamily::MixedTab, 7, 200, pool_bits);
            assert_eq!(src.pool_bits(), pool_bits);
            for &off in src.offsets() {
                assert!(
                    (off as usize) + 32 <= pool_bits,
                    "offset {off} overruns a {pool_bits}-bit pool"
                );
            }
        }
    }

    #[test]
    fn pooled_source_deterministic_and_seed_sensitive() {
        let a = PooledSource::new(HashFamily::MixedTab, 5, 16, 256);
        let b = PooledSource::new(HashFamily::MixedTab, 5, 16, 256);
        let c = PooledSource::new(HashFamily::MixedTab, 6, 16, 256);
        assert_eq!(a.offsets(), b.offsets());
        let ks = keys(40, 3);
        let (mut pa, mut pc) = (Vec::new(), Vec::new());
        a.begin(&ks, &mut pa);
        b.begin(&ks, &mut pc);
        assert_eq!(pa, pc, "same seed must fill identical pools");
        c.begin(&ks, &mut pc);
        assert_ne!(pa, pc, "different seed must fill a different pool");
        let mut differs = 0;
        for i in 0..a.outputs() {
            for &k in &ks {
                assert_eq!(a.hash_one(i, k), b.hash_one(i, k));
                differs += (a.hash_one(i, k) != c.hash_one(i, k)) as u32;
            }
        }
        assert!(differs > 0);
    }

    #[test]
    fn pooled_coordinates_spread_across_the_pool() {
        // Distinct coordinates should mostly read distinct windows —
        // otherwise the pool degenerates into one shared function.
        let src = PooledSource::new(HashFamily::MixedTab, 11, 64, 512);
        let mut offs: Vec<u32> = src.offsets().to_vec();
        offs.sort_unstable();
        offs.dedup();
        assert!(offs.len() > 32, "only {} distinct offsets of 64", offs.len());
    }
}
