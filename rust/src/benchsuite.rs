//! The eight `cargo bench` workloads as in-process library functions.
//!
//! Each `rust/benches/*.rs` target is a thin `fn main` wrapper around one
//! function here, and the `mixtab bench` CLI subcommand runs any subset of
//! them in one process — printing the usual human-readable tables *and*
//! accumulating machine-readable [`CaseRecord`](crate::util::bench::CaseRecord)s
//! on the shared [`Bench`], which the CLI then writes as `BENCH_<name>.json`
//! and gates against a committed baseline (see `util::bench` and CI's
//! `bench-smoke` job).
//!
//! Workloads honour quick mode ([`Bench::is_quick`]): CI smoke runs shrink
//! key counts and repetitions, full runs reproduce the paper-scale numbers.

use crate::coordinator::config::CoordinatorConfig;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::Coordinator;
use crate::data::news20_like::{self, News20LikeParams};
use crate::data::synthetic::dataset1;
use crate::data::SparseVector;
use crate::hash::HashFamily;
use crate::lsh::{LshIndex, LshParams, ShardedIndex};
use crate::sketch::feature_hash::SignMode;
use crate::sketch::sketcher::{DynSketcher, SketchValue};
use crate::sketch::{BinLayout, DensifyMode, OphParams, Scratch, SketchSpec};
use crate::stats::Summary;
use crate::util::bench::{fmt_rate, print_table, Bench};
use crate::util::rng::Xoshiro256;
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// All workloads in execution order: `(name, entry point)`. The names are
/// the bench-target names and the `--only` values of `mixtab bench`.
pub const ALL: &[(&str, fn(&mut Bench))] = &[
    ("table1_hash_speed", table1_hash_speed),
    ("hash_source", hash_source),
    ("sketch_throughput", sketch_throughput),
    ("sketch_dispatch", sketch_dispatch),
    ("lsh_query", lsh_query),
    ("sharded_query", sharded_query),
    ("coordinator_service", coordinator_service),
    ("runtime_pjrt", runtime_pjrt),
];

/// Run every workload, accumulating records on `bench`.
pub fn run_all(bench: &mut Bench) {
    for (_, f) in ALL {
        f(bench);
    }
}

/// Table 1 — raw hash throughput and FH-over-News20 timing for every
/// family. Paper shape to verify: multiply-shift < poly2 < {mixed_tab,
/// poly3} < {murmur3, cityhash} ≪ blake2b; mixed_tab ≈ 0.7× murmur3.
pub fn table1_hash_speed(bench: &mut Bench) {
    let n_keys: usize = if bench.is_quick() { 200_000 } else { 10_000_000 };
    let n_docs: usize = if bench.is_quick() { 200 } else { 5_000 };

    let mut rng = Xoshiro256::new(0x7AB1E);
    let keys: Vec<u32> = (0..n_keys).map(|_| rng.next_u32()).collect();
    let mut out = vec![0u32; n_keys];

    println!("table1_hash_speed: {n_keys} keys / {n_docs} News20-like docs");
    let mut rows = Vec::new();
    for &fam in HashFamily::TABLE1 {
        let h = fam.build(42);
        // Blake2 at 1/100 scale to stay interactive.
        let slice = if fam == HashFamily::Blake2 {
            &keys[..n_keys / 100]
        } else {
            &keys[..]
        };
        let m = bench.measure(&format!("hash32/{}", fam.id()), slice.len() as u64, || {
            h.hash_slice(slice, &mut out[..slice.len()]);
            black_box(out[0])
        });
        bench.record("table1_hash_speed", &m);
        rows.push(m);
    }
    print_table("hash 32-bit keys", &rows);

    let news = news20_like::generate(n_docs, &News20LikeParams::default(), 99);
    let mut rows = Vec::new();
    for &fam in HashFamily::TABLE1 {
        let fh = SketchSpec::feature_hash(fam, 42, 128, SignMode::Separate)
            .build_feature_hasher()
            .expect("fh spec");
        let docs = if fam == HashFamily::Blake2 {
            &news.vectors[..n_docs / 20]
        } else {
            &news.vectors[..]
        };
        let mut scratch = Scratch::new();
        let m = bench.measure(&format!("fh_news20/{}", fam.id()), docs.len() as u64, || {
            let mut acc = 0.0;
            for v in docs {
                acc += fh.squared_norm(v, &mut scratch);
            }
            black_box(acc)
        });
        bench.record("table1_hash_speed", &m);
        rows.push(m);
    }
    print_table("feature hashing News20-like (d'=128, per doc)", &rows);
}

/// The hash-evaluation layer in isolation — the unrolled multi-key
/// mixed-tabulation kernels vs their scalar loops, and pooled vs
/// independent [`crate::hash::source::HashSource`]s feeding the same
/// sketch widths. The kernel cases bound what the 4-key unroll buys on
/// raw throughput (acceptance: slice ≥ scalar on both widths); the
/// source cases show the O(pool) vs O(coordinates) gap the pool exists
/// for — simhash bits=96 / pool=256 pays 4 wide hash passes per batch
/// instead of 96 narrow ones (acceptance: pooled ≥ 2× independent).
pub fn hash_source(bench: &mut Bench) {
    let n_keys: usize = if bench.is_quick() { 200_000 } else { 4_000_000 };
    let reps: usize = if bench.is_quick() { 20 } else { 200 };

    let mut rng = Xoshiro256::new(0x9001);
    let keys: Vec<u32> = (0..n_keys).map(|_| rng.next_u32()).collect();
    println!("hash_source: {n_keys} keys, sketch reps={reps}");

    // Unrolled slice kernels vs a per-key loop over the same hashers.
    let mut rows = Vec::new();
    let h32 = HashFamily::MixedTab.build(42);
    let mut out32 = vec![0u32; n_keys];
    let m = bench.measure("mt32_slice", n_keys as u64, || {
        h32.hash_slice(&keys, &mut out32);
        black_box(out32[0])
    });
    bench.record("hash_source", &m);
    rows.push(m);
    let m = bench.measure("mt32_scalar", n_keys as u64, || {
        for (k, o) in keys.iter().zip(out32.iter_mut()) {
            *o = h32.hash(*k);
        }
        black_box(out32[0])
    });
    bench.record("hash_source", &m);
    rows.push(m);
    let h64 = HashFamily::MixedTab.build64(42);
    let mut out64 = vec![0u64; n_keys];
    let m = bench.measure("mt64_slice", n_keys as u64, || {
        h64.hash64_slice(&keys, &mut out64);
        black_box(out64[0])
    });
    bench.record("hash_source", &m);
    rows.push(m);
    let m = bench.measure("mt64_scalar", n_keys as u64, || {
        for (k, o) in keys.iter().zip(out64.iter_mut()) {
            *o = h64.hash64(*k);
        }
        black_box(out64[0])
    });
    bench.record("hash_source", &m);
    rows.push(m);
    print_table("mixed-tab kernels (per key)", &rows);

    // Pooled vs independent sources at matched sketch widths, through the
    // same spec-built sketchers the serving path uses.
    let set: Vec<u32> = (0..2000).map(|_| rng.next_u32()).collect();
    let v = SparseVector::unit_indicator(&set);
    let mut scratch = Scratch::new();
    let mut rows = Vec::new();
    for (name, spec) in [
        ("simhash_indep", SketchSpec::simhash(HashFamily::MixedTab, 7, 96)),
        (
            "simhash_pooled",
            SketchSpec::simhash_pooled(HashFamily::MixedTab, 7, 96, 256),
        ),
    ] {
        let sh = spec.build_simhash().expect("simhash spec");
        let m = bench.measure(name, (reps * set.len()) as u64, || {
            let mut acc = false;
            for _ in 0..reps {
                acc ^= black_box(sh.sketch_with(&v, &mut scratch))[0];
            }
            acc
        });
        bench.record("hash_source", &m);
        rows.push(m);
    }
    let mh_reps = (reps / 10).max(1); // k=128 narrow passes on the indep path
    for (name, spec) in [
        ("minhash_indep", SketchSpec::minhash(HashFamily::MixedTab, 7, 128)),
        (
            "minhash_pooled",
            SketchSpec::minhash_pooled(HashFamily::MixedTab, 7, 128, 256),
        ),
    ] {
        let mh = spec.build_minhash().expect("minhash spec");
        let m = bench.measure(name, (mh_reps * set.len()) as u64, || {
            let mut acc = 0u32;
            for _ in 0..mh_reps {
                acc ^= black_box(mh.sketch_with(&set, &mut scratch))[0];
            }
            acc
        });
        bench.record("hash_source", &m);
        rows.push(m);
    }
    print_table("hash sources at matched widths (per element)", &rows);
}

/// Sketching throughput — OPH vs k×MinHash (the paper's motivating
/// `O(|A|)` vs `O(k·|A|)` gap), the batched-vs-per-key contrast the
/// `Scratch` hot paths buy, densification cost, and FH sign-mode cost
/// (Corollary 1's single-hash trick vs two hashes).
pub fn sketch_throughput(bench: &mut Bench) {
    let reps: usize = if bench.is_quick() { 20 } else { 500 };
    let mut rng = Xoshiro256::new(5);
    let pair = dataset1(2000, true, &mut rng);
    let set = &pair.a;
    let k = 200;

    println!("sketch_throughput: |A|={} k={k} reps={reps}", set.len());

    let mut rows = Vec::new();
    let oph = SketchSpec::oph(HashFamily::MixedTab, 1, k)
        .build_oph()
        .expect("oph spec");
    let mut scratch = Scratch::new();
    let m = bench.measure("oph_densified", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph.sketch_with(set, &mut scratch)).bins[0];
        }
        acc
    });
    bench.record("sketch_throughput", &m);
    rows.push(m);
    let oph_raw = SketchSpec::oph_with(
        HashFamily::MixedTab,
        1,
        OphParams {
            k,
            layout: BinLayout::Mod,
            densify: DensifyMode::None,
        },
    )
    .build_oph()
    .expect("oph spec");
    // Batched (hash_slice + reused scratch) vs per-key reference: the
    // dispatch-per-batch win in isolation. Acceptance: batched ≥ 1.2× on
    // the tabulation family.
    let m = bench.measure("oph_raw_batched", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph_raw.sketch_raw_with(set, &mut scratch)).bins[0];
        }
        acc
    });
    bench.record("sketch_throughput", &m);
    rows.push(m);
    let m = bench.measure("oph_raw_per_key", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph_raw.sketch_raw_per_key(set)).bins[0];
        }
        acc
    });
    bench.record("sketch_throughput", &m);
    rows.push(m);
    let mh = SketchSpec::minhash(HashFamily::MixedTab, 1, k)
        .build_minhash()
        .expect("minhash spec");
    let mh_reps = (reps / 50).max(1); // k× slower by construction
    let m = bench.measure("minhash_k200", (mh_reps * set.len()) as u64, || {
        let mut acc = 0u32;
        for _ in 0..mh_reps {
            acc ^= black_box(mh.sketch_with(set, &mut scratch))[0];
        }
        acc
    });
    bench.record("sketch_throughput", &m);
    rows.push(m);
    print_table("set sketching (per element)", &rows);

    // FH sign modes.
    let v = SparseVector::unit_indicator(set);
    let mut rows = Vec::new();
    for (name, mode) in [("fh_separate", SignMode::Separate), ("fh_paired", SignMode::Paired)] {
        let fh = SketchSpec::feature_hash(HashFamily::MixedTab, 3, 128, mode)
            .build_feature_hasher()
            .expect("fh spec");
        let mut scratch = Scratch::new();
        let m = bench.measure(name, (reps * v.nnz()) as u64, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += fh.squared_norm(&v, &mut scratch);
            }
            black_box(acc)
        });
        bench.record("sketch_throughput", &m);
        rows.push(m);
    }
    print_table("feature hashing sign modes (per non-zero)", &rows);
}

/// Erased-dispatch overhead — the same spec-built sketchers driven through
/// the typed [`crate::sketch::Sketcher`] path vs the erased
/// [`crate::sketch::DynSketcher`] path (`SketchSpec::build`), which is what
/// the coordinator's scheme-aware `sketch` endpoint and the `mixtab sketch`
/// CLI use. Acceptance: the erased path stays within a few percent of the
/// direct calls — the per-set work (hashing + bin loop) dominates the one
/// extra virtual call and enum wrap.
pub fn sketch_dispatch(bench: &mut Bench) {
    let reps: usize = if bench.is_quick() { 20 } else { 500 };
    let mut rng = Xoshiro256::new(0xD15);
    let set: Vec<u32> = (0..2000).map(|_| rng.next_u32()).collect();
    println!("sketch_dispatch: |A|={} reps={reps}", set.len());
    let mut scratch = Scratch::new();
    let mut rows = Vec::new();

    let oph_spec = SketchSpec::oph(HashFamily::MixedTab, 7, 200);
    let oph = oph_spec.build_oph().expect("oph spec");
    let oph_erased = oph_spec.build();
    let m = bench.measure("direct/oph", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            acc ^= black_box(oph.sketch_with(&set, &mut scratch)).bins[0];
        }
        acc
    });
    bench.record("sketch_dispatch", &m);
    rows.push(m);
    let m = bench.measure("erased/oph", (reps * set.len()) as u64, || {
        let mut acc = 0u64;
        for _ in 0..reps {
            let SketchValue::Oph(s) = oph_erased.sketch_dyn(&set, &mut scratch) else {
                unreachable!()
            };
            acc ^= black_box(s.bins[0]);
        }
        acc
    });
    bench.record("sketch_dispatch", &m);
    rows.push(m);

    let mh_spec = SketchSpec::minhash(HashFamily::MixedTab, 7, 16);
    let mh = mh_spec.build_minhash().expect("minhash spec");
    let mh_erased = mh_spec.build();
    let mh_reps = (reps / 8).max(1); // 16 hash passes per set
    let m = bench.measure("direct/minhash", (mh_reps * set.len()) as u64, || {
        let mut acc = 0u32;
        for _ in 0..mh_reps {
            acc ^= black_box(mh.sketch_with(&set, &mut scratch))[0];
        }
        acc
    });
    bench.record("sketch_dispatch", &m);
    rows.push(m);
    let m = bench.measure("erased/minhash", (mh_reps * set.len()) as u64, || {
        let mut acc = 0u32;
        for _ in 0..mh_reps {
            let SketchValue::MinHash(v) = mh_erased.sketch_dyn(&set, &mut scratch) else {
                unreachable!()
            };
            acc ^= black_box(v[0]);
        }
        acc
    });
    bench.record("sketch_dispatch", &m);
    rows.push(m);
    print_table("spec-registry dispatch (per element)", &rows);
}

/// LSH build + query latency on MNIST-like data (the Figure 5 operating
/// point K = L = 10). Weak hashing inflates buckets on structured data,
/// which shows up here as *slower queries*, not just worse quality.
pub fn lsh_query(bench: &mut Bench) {
    let (n_db, n_q) = if bench.is_quick() { (400, 40) } else { (4000, 400) };
    let (db_ds, q_ds) = crate::data::mnist_like::default_split(n_db, n_q, 42);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();
    println!("lsh_query: db={} queries={} K=L=10", db.len(), queries.len());

    for fam in [HashFamily::MixedTab, HashFamily::MultiplyShift, HashFamily::Murmur3] {
        let mut rows = Vec::new();
        let spec = SketchSpec::oph(fam, 7, 100);
        let mut index = LshIndex::new(LshParams::new(10, 10), &spec);
        let m = bench.measure(&format!("build/{}", fam.id()), db.len() as u64, || {
            index = LshIndex::new(LshParams::new(10, 10), &spec);
            for (i, s) in db.iter().enumerate() {
                index.insert(i as u32, s);
            }
            index.len()
        });
        bench.record("lsh_query", &m);
        rows.push(m);
        let mut retrieved_total = 0usize;
        let m = bench.measure(&format!("query/{}", fam.id()), queries.len() as u64, || {
            retrieved_total = 0;
            for q in &queries {
                retrieved_total += black_box(index.query(q)).len();
            }
            retrieved_total
        });
        bench.record("lsh_query", &m);
        rows.push(m);
        print_table(&format!("LSH {} (per item)", fam.id()), &rows);
        println!(
            "  retrieved/query = {:.1}, max bucket = {}",
            retrieved_total as f64 / queries.len() as f64,
            index.max_bucket()
        );
    }
}

/// Sharded LSH serving — build + fan-out query through [`ShardedIndex`]
/// with N ∈ {1, 4} shards over the same MNIST-like corpus and spec as
/// `lsh_query`'s operating point. N = 1 measures the routing layer's
/// overhead over a bare index (acceptance: negligible — one extra hash per
/// insert and a no-op merge per query); N = 4 measures the fan-out cost
/// the multi-scheme coordinator pays for shard-level lock granularity,
/// sequentially and (`query/shards4par`) through the shared worker pool
/// the coordinator attaches when `[service] workers ≥ 2` — the
/// parallel-vs-sequential contrast of the scoped per-query fan-out.
pub fn sharded_query(bench: &mut Bench) {
    let (n_db, n_q) = if bench.is_quick() { (400, 40) } else { (4000, 400) };
    let (db_ds, q_ds) = crate::data::mnist_like::default_split(n_db, n_q, 77);
    let db = db_ds.as_sets();
    let queries = q_ds.as_sets();
    println!(
        "sharded_query: db={} queries={} K=L=10",
        db.len(),
        queries.len()
    );

    let spec = SketchSpec::oph(HashFamily::MixedTab, 7, 100);
    for shards in [1usize, 4] {
        let mut rows = Vec::new();
        let mut index = ShardedIndex::new(shards, LshParams::new(10, 10), &spec);
        let m = bench.measure(&format!("build/shards{shards}"), db.len() as u64, || {
            index = ShardedIndex::new(shards, LshParams::new(10, 10), &spec);
            for (i, s) in db.iter().enumerate() {
                index.insert(i as u32, s);
            }
            index.len()
        });
        bench.record("sharded_query", &m);
        rows.push(m);
        let mut retrieved_total = 0usize;
        let m = bench.measure(&format!("query/shards{shards}"), queries.len() as u64, || {
            retrieved_total = 0;
            for q in &queries {
                retrieved_total += black_box(index.query(q)).len();
            }
            retrieved_total
        });
        bench.record("sharded_query", &m);
        rows.push(m);
        print_table(&format!("sharded LSH N={shards} (per item)"), &rows);
        println!(
            "  retrieved/query = {:.1}, per-shard sizes = {:?}",
            retrieved_total as f64 / queries.len() as f64,
            index.per_shard_len()
        );
    }

    // Parallel fan-out: the N=4 index again, with a shared 4-worker pool
    // attached — per-shard lookups run as scoped pool tasks. Results are
    // bit-identical to the sequential path (asserted below; the property
    // suite proves it exhaustively), so this case isolates the fan-out
    // mechanics: scoped-spawn overhead vs parallel shard lookups.
    let pool = Arc::new(crate::util::threadpool::ThreadPool::new(4));
    let mut index = ShardedIndex::new(4, LshParams::new(10, 10), &spec);
    index.set_pool(Some(pool));
    for (i, s) in db.iter().enumerate() {
        index.insert(i as u32, s);
    }
    let mut rows = Vec::new();
    let mut retrieved_total = 0usize;
    let m = bench.measure("query/shards4par", queries.len() as u64, || {
        retrieved_total = 0;
        for q in &queries {
            retrieved_total += black_box(index.query(q)).len();
        }
        retrieved_total
    });
    bench.record("sharded_query", &m);
    rows.push(m);
    print_table("sharded LSH N=4 parallel fan-out (per item)", &rows);
    for q in queries.iter().take(8) {
        assert_eq!(
            index.query_fanout(q),
            index.query_fanout_sequential(q),
            "parallel fan-out diverged from sequential"
        );
    }
}

fn coordinator_workload(n: usize, seed: u64) -> Vec<(Vec<u32>, Vec<f64>)> {
    let mut rng = Xoshiro256::new(seed);
    (0..n)
        .map(|_| {
            let nnz = rng.range(50, 450);
            (
                (0..nnz).map(|_| rng.next_u32() % 1_000_000).collect(),
                (0..nnz).map(|_| rng.next_f64() - 0.5).collect(),
            )
        })
        .collect()
}

fn coordinator_drive(
    c: &Arc<Coordinator>,
    clients: usize,
    per_client: usize,
    seed: u64,
) -> (f64, Summary, u64) {
    let done = Arc::new(AtomicU64::new(0));
    let lat_all = Arc::new(std::sync::Mutex::new(Summary::new()));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|cl| {
            let c = Arc::clone(c);
            let done = Arc::clone(&done);
            let lat_all = Arc::clone(&lat_all);
            std::thread::spawn(move || {
                let work = coordinator_workload(per_client, seed + cl as u64);
                let mut lat = Summary::new();
                for (idx, vals) in work {
                    let t = Instant::now();
                    let resp = c.handle(Request::FhTransform {
                        indices: idx,
                        values: vals,
                    });
                    lat.add(t.elapsed().as_micros() as f64);
                    if matches!(resp, Response::Fh { .. }) {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let mut g = lat_all.lock().unwrap();
                for &v in lat.values() {
                    g.add(v);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    let lat = Arc::try_unwrap(lat_all).unwrap().into_inner().unwrap();
    (wall, lat, total)
}

/// Coordinator end-to-end — FH request latency/throughput through the full
/// service (router → batcher → PJRT executor → scatter) under closed-loop
/// concurrent clients, vs the native path.
pub fn coordinator_service(bench: &mut Bench) {
    let (clients, per_client) = if bench.is_quick() { (4, 25) } else { (8, 250) };
    println!("coordinator_service: {clients} closed-loop clients × {per_client} FH requests");

    for (label, enable_pjrt) in [("pjrt+batcher", true), ("native-only", false)] {
        let c = Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt,
            fh_dim: 128,
            max_delay_us: 200,
            ..Default::default()
        }));
        if enable_pjrt && !c.pjrt_enabled() {
            println!("  {label}: pjrt unavailable (run `make artifacts`), skipping");
            continue;
        }
        let (wall, lat, total) = coordinator_drive(&c, clients, per_client, 99);
        let (p50, p90, p99) = lat.latency_quantiles();
        let snap = c.metrics.snapshot();
        let path_note = match (
            snap.get("fh_pjrt_rows").and_then(|j| j.as_i64()),
            snap.get("fh_native_rows").and_then(|j| j.as_i64()),
        ) {
            (Some(p), Some(n)) => format!("rows pjrt={p} native={n}"),
            _ => String::new(),
        };
        let rps = total as f64 / wall;
        println!(
            "  {label:<14} {} req/s  lat p50={p50:.0}µs p90={p90:.0}µs p99={p99:.0}µs  occupancy={:.2}  {}",
            fmt_rate(rps),
            c.metrics.mean_batch_occupancy(),
            path_note
        );
        bench.record_rate(
            "coordinator_service",
            &format!("{label}/req_rate"),
            rps,
            if rps > 0.0 { 1e9 / rps } else { 0.0 },
        );
        // Smoke assertion: everything completed.
        assert_eq!(total as usize, clients * per_client);
    }

    // Batched vs unbatched op throughput over TCP: the same pipelined
    // sketch/insert/query mix served with the cross-connection OpBatcher
    // on (default) and off (every op on the direct worker path). Driven
    // by `loadtest::driver::drive` — the same closed-loop windowed engine
    // the `mixtab loadtest` trajectory measures with, so the bench and
    // the loadtest stay comparable by construction.
    use crate::coordinator::server::Server;
    use crate::loadtest::driver;
    let (tcp_clients, ops_per_client) = if bench.is_quick() { (4, 50) } else { (8, 400) };
    let ops = tcp_clients * ops_per_client;
    println!(
        "coordinator_service: {tcp_clients} pipelined TCP clients × {ops_per_client} ops (insert/query/sketch mix)"
    );
    for (label, op_batch) in [("batched", 32usize), ("unbatched", 0)] {
        let c = Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt: false,
            oph_k: 64,
            op_batch,
            request_workers: 4,
            ..Default::default()
        }));
        let server = Server::start(Arc::clone(&c), "127.0.0.1:0").expect("server");
        // The op stream is a pure function of the global op index: same
        // mix and same sets regardless of how ops land on connections.
        let stats = driver::drive(server.addr(), tcp_clients, ops, 16, |i| {
            let mut rng = Xoshiro256::stream(7, i as u64);
            let set: Vec<u32> = (0..40).map(|_| rng.next_u32() % 100_000).collect();
            match i % 3 {
                0 => Request::LshInsert {
                    id: i as u32,
                    set,
                    scheme: None,
                },
                1 => Request::LshQuery { set, scheme: None },
                _ => Request::Sketch {
                    set,
                    spec: None,
                    scheme: None,
                },
            }
        })
        .expect("drive");
        let rps = stats.qps();
        let snap = c.metrics.snapshot();
        let occupancy = match (
            snap.get("op_batches").and_then(|j| j.as_i64()),
            snap.get("op_batch_rows").and_then(|j| j.as_i64()),
        ) {
            (Some(b), Some(r)) if b > 0 => r as f64 / b as f64,
            _ => 0.0,
        };
        let (p50, p99, _) = stats.latency_us.tail_quantiles();
        println!(
            "  {label:<14} {} op/s  lat p50={p50:.0}µs p99={p99:.0}µs  op-batch occupancy={occupancy:.2}",
            fmt_rate(rps)
        );
        bench.record_rate(
            "coordinator_service",
            &format!("{label}/op_rate"),
            rps,
            if rps > 0.0 { 1e9 / rps } else { 0.0 },
        );
        assert_eq!(stats.ok as usize, ops, "{label}: every op answered cleanly");
        assert_eq!(stats.errors, 0, "{label}: no wire errors");
        server.stop();
    }
}

/// PJRT artifact execution — FH and OPH batch latency/throughput vs the
/// native Rust path for the same work. Skips (recording nothing) without
/// the `xla` feature or built artifacts.
pub fn runtime_pjrt(bench: &mut Bench) {
    if cfg!(not(feature = "xla")) {
        println!("runtime_pjrt: built without the `xla` feature (stub engine); skipping");
        return;
    }
    use crate::runtime::artifact::{ArtifactKind, Manifest};
    use crate::runtime::pjrt::PjrtEngine;
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("runtime_pjrt: artifacts/ not built — run `make artifacts`; skipping");
        return;
    };
    let Some(meta) = manifest.find_fh(128, 512).cloned() else {
        println!("runtime_pjrt: no fh d'=128 artifact; skipping");
        return;
    };
    let ArtifactKind::Fh { batch, nnz, dim } = meta.kind else {
        unreachable!()
    };
    println!("runtime_pjrt: artifact {} [{batch}x{nnz}] -> d'={dim}", meta.name);
    let engine = PjrtEngine::load(&Manifest {
        artifacts: vec![meta.clone()],
    })
    .expect("engine");

    // Batch of realistic sparse vectors.
    let fh = SketchSpec::feature_hash(HashFamily::MixedTab, 42, dim, SignMode::Paired)
        .build_feature_hasher()
        .expect("fh spec");
    let mut rng = Xoshiro256::new(3);
    let vectors: Vec<SparseVector> = (0..batch)
        .map(|_| {
            let n = rng.range(100, 500);
            SparseVector::new(
                (0..n).map(|_| rng.next_u32() % 1_000_000).collect(),
                (0..n).map(|_| rng.next_f64() - 0.5).collect(),
            )
        })
        .collect();
    let mut bins = Vec::with_capacity(batch * nnz);
    let mut vals = Vec::with_capacity(batch * nnz);
    for v in &vectors {
        let (mut b, mut x) = fh.plan(v, nnz);
        bins.append(&mut b);
        vals.append(&mut x);
    }

    let mut rows = Vec::new();
    let m = bench.measure("pjrt_fh_batch", batch as u64, || {
        black_box(engine.run_fh(&meta.name, &bins, &vals).unwrap().sqnorm[0])
    });
    bench.record("runtime_pjrt", &m);
    rows.push(m);
    let mut scratch = Scratch::new();
    let m = bench.measure("native_fh_batch", batch as u64, || {
        let mut acc = 0.0;
        for v in &vectors {
            acc += fh.squared_norm(v, &mut scratch);
        }
        black_box(acc)
    });
    bench.record("runtime_pjrt", &m);
    rows.push(m);
    print_table("FH batch of 16 vectors (per vector)", &rows);

    if let Some(oph_meta) = manifest.find_oph(200, 512).cloned() {
        let ArtifactKind::Oph { batch, nnz, k } = oph_meta.kind else {
            unreachable!()
        };
        let engine = PjrtEngine::load(&Manifest {
            artifacts: vec![oph_meta.clone()],
        })
        .expect("engine");
        let hasher = HashFamily::MixedTab.build(7);
        let mut h = vec![0i32; batch * nnz];
        let mut valid = vec![0i32; batch * nnz];
        let sets: Vec<Vec<u32>> = (0..batch)
            .map(|_| (0..400).map(|_| rng.next_u32()).collect())
            .collect();
        for (r, set) in sets.iter().enumerate() {
            for (i, &x) in set.iter().enumerate() {
                h[r * nnz + i] = hasher.hash(x) as i32;
                valid[r * nnz + i] = 1;
            }
        }
        let sketcher = SketchSpec::oph_with(
            HashFamily::MixedTab,
            7,
            OphParams {
                k,
                layout: BinLayout::Mod,
                densify: DensifyMode::None,
            },
        )
        .build_oph()
        .expect("oph spec");
        let mut rows = Vec::new();
        let m = bench.measure("pjrt_oph_batch", batch as u64, || {
            black_box(engine.run_oph(&oph_meta.name, &h, &valid).unwrap()[0])
        });
        bench.record("runtime_pjrt", &m);
        rows.push(m);
        let m = bench.measure("native_oph_batch", batch as u64, || {
            let mut acc = 0u64;
            for s in &sets {
                acc ^= sketcher.sketch_raw_with(s, &mut scratch).bins[0];
            }
            black_box(acc)
        });
        bench.record("runtime_pjrt", &m);
        rows.push(m);
        print_table("OPH batch of 16 sets (per set)", &rows);
    }
}
