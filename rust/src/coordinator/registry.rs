//! The scheme registry: several named sketch schemes served concurrently
//! from one coordinator.
//!
//! PR 3 made the sketch *scheme* configuration ([`SketchSpec`]); this
//! module makes it **plural**. A [`SchemeRegistry`] holds one [`Scheme`]
//! per configured name — the implicit [`DEFAULT_SCHEME`] derived from the
//! scalar config (preserving the single-scheme wire behaviour bit-for-bit)
//! plus one per `[[schemes]]` entry — and the wire ops' optional `scheme`
//! field selects among them. Each scheme owns:
//!
//! * an erased [`DynSketcher`] serving its `sketch` requests,
//! * for OPH specs, a [`ShardedIndex`] (per-scheme sharding — the
//!   `shards` key) serving `insert`/`query`,
//! * a set store backing `estimate` on the default scheme,
//! * a [`SchemeCounters`] block surfaced through the `stats` op.
//!
//! Non-OPH schemes (MinHash, SimHash, FH, b-bit) have no LSH index — the
//! (K, L) bucket construction is defined over OPH bins — so `insert`/
//! `query` against them is a clean wire error, not a panic.

use crate::coordinator::config::{CoordinatorConfig, DEFAULT_SCHEME};
use crate::coordinator::metrics::{Metrics, SchemeCounters};
use crate::lsh::sharded::ShardedIndex;
use crate::lsh::LshParams;
use crate::sketch::sketcher::{DynSketcher, SketchValue};
use crate::sketch::spec::{SketchScheme, SketchSpec};
use crate::sketch::Scratch;
use crate::util::error::{bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One named scheme: sketcher + optional sharded index + set store.
pub struct Scheme {
    name: String,
    spec: SketchSpec,
    sketcher: Box<dyn DynSketcher>,
    /// OPH-backed sharded LSH index; `None` for non-OPH specs.
    index: Option<ShardedIndex>,
    /// Inserted sets, kept for the `estimate` op. Only the default scheme
    /// carries one — `estimate` serves the default scheme only, and
    /// retaining every named scheme's raw corpus would double its memory
    /// for an op that never reads it.
    store: Option<Mutex<HashMap<u32, Vec<u32>>>>,
    counters: Arc<SchemeCounters>,
}

impl Scheme {
    fn new(
        name: &str,
        spec: SketchSpec,
        index_spec: Option<(SketchSpec, LshParams, usize)>,
        with_store: bool,
        metrics: &Metrics,
    ) -> Self {
        let index =
            index_spec.map(|(spec, params, shards)| ShardedIndex::new(shards, params, &spec));
        let counters =
            metrics.register_scheme(name, index.as_ref().map_or(0, ShardedIndex::n_shards));
        Self {
            name: name.to_string(),
            spec,
            sketcher: spec.build(),
            index,
            store: with_store.then(|| Mutex::new(HashMap::new())),
            counters,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The spec serving this scheme's `sketch` requests.
    pub fn spec(&self) -> &SketchSpec {
        &self.spec
    }

    /// The scheme's sharded index, when its spec supports one.
    pub fn index(&self) -> Option<&ShardedIndex> {
        self.index.as_ref()
    }

    /// Sketch a set with this scheme's sketcher.
    pub fn sketch(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        Metrics::inc(&self.counters.sketches);
        self.sketcher.sketch_dyn(set, scratch)
    }

    /// Insert a set into this scheme's index (and, on the default scheme,
    /// the estimate store). Errors for index-less (non-OPH) schemes.
    pub fn insert(&self, id: u32, set: Vec<u32>) -> Result<()> {
        let index = self.require_index()?;
        let shard = index.insert(id, &set);
        Metrics::inc(&self.counters.inserts);
        Metrics::inc(&self.counters.shard_inserts[shard]);
        if let Some(store) = &self.store {
            store.lock().unwrap().insert(id, set);
        }
        Ok(())
    }

    /// Fan-out query over this scheme's index. Errors for index-less
    /// (non-OPH) schemes.
    pub fn query(&self, set: &[u32]) -> Result<Vec<u32>> {
        let index = self.require_index()?;
        let (ids, per_shard) = index.query_fanout(set);
        Metrics::inc(&self.counters.queries);
        for (counter, n) in self.counters.shard_candidates.iter().zip(per_shard) {
            Metrics::add(counter, n as u64);
        }
        Ok(ids)
    }

    /// A stored set by id (cloned out so no lock is held while sketching).
    /// Always `None` on store-less (named) schemes.
    pub fn stored(&self, id: u32) -> Option<Vec<u32>> {
        self.store.as_ref()?.lock().unwrap().get(&id).cloned()
    }

    fn require_index(&self) -> Result<&ShardedIndex> {
        match &self.index {
            Some(index) => Ok(index),
            None => bail!(
                "scheme '{}' has no LSH index (spec '{}' is not OPH)",
                self.name,
                self.spec
            ),
        }
    }
}

/// All schemes served by one coordinator, looked up by wire name.
pub struct SchemeRegistry {
    /// Registration order: default first, then `[[schemes]]` file order.
    schemes: Vec<Scheme>,
}

impl SchemeRegistry {
    /// Build the registry from config: the implicit default scheme
    /// (sketcher from `cfg.sketch_spec()`, index from `cfg.lsh_spec()`
    /// sharded `cfg.lsh_shards` ways — with one shard this is bit-identical
    /// to the pre-registry coordinator) plus every `[[schemes]]` entry.
    /// Name collisions are rejected at config parse time.
    pub fn from_config(cfg: &CoordinatorConfig, metrics: &Metrics) -> Self {
        let params = LshParams::new(cfg.lsh_k, cfg.lsh_l);
        let mut schemes = vec![Scheme::new(
            DEFAULT_SCHEME,
            cfg.sketch_spec(),
            Some((cfg.lsh_spec(), params, cfg.lsh_shards)),
            true,
            metrics,
        )];
        for sc in &cfg.schemes {
            let index_spec = matches!(sc.spec.scheme, SketchScheme::Oph(_))
                .then_some((sc.spec, params, sc.shards));
            schemes.push(Scheme::new(&sc.name, sc.spec, index_spec, false, metrics));
        }
        Self { schemes }
    }

    /// Look up a scheme by wire name; `None` selects the default scheme.
    pub fn get(&self, name: Option<&str>) -> Result<&Scheme> {
        let name = name.unwrap_or(DEFAULT_SCHEME);
        match self.schemes.iter().find(|s| s.name == name) {
            Some(scheme) => Ok(scheme),
            None => bail!(
                "unknown scheme '{name}' (serving: {})",
                self.names().join(", ")
            ),
        }
    }

    /// The implicit default scheme.
    pub fn default_scheme(&self) -> &Scheme {
        &self.schemes[0]
    }

    /// Served scheme names, registration order (default first).
    pub fn names(&self) -> Vec<&str> {
        self.schemes.iter().map(|s| s.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeConfig;
    use crate::hash::HashFamily;

    fn registry_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            enable_pjrt: false,
            lsh_k: 3,
            lsh_l: 4,
            lsh_shards: 2,
            schemes: vec![
                SchemeConfig {
                    name: "fast".into(),
                    spec: SketchSpec::oph(HashFamily::MultiplyShift, 7, 64),
                    shards: 3,
                },
                SchemeConfig {
                    name: "dense".into(),
                    spec: SketchSpec::minhash(HashFamily::MixedTab, 9, 16),
                    shards: 1,
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn registry_serves_default_and_named_schemes() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics);
        assert_eq!(reg.names(), vec![DEFAULT_SCHEME, "fast", "dense"]);
        assert_eq!(reg.get(None).unwrap().name(), DEFAULT_SCHEME);
        assert_eq!(reg.get(Some("fast")).unwrap().name(), "fast");
        assert!(reg.get(Some("nope")).is_err());
        // Shard counts follow the per-scheme config.
        assert_eq!(reg.default_scheme().index().unwrap().n_shards(), 2);
        assert_eq!(reg.get(Some("fast")).unwrap().index().unwrap().n_shards(), 3);
        // Non-OPH scheme: sketching works, indexing errors cleanly.
        let dense = reg.get(Some("dense")).unwrap();
        assert!(dense.index().is_none());
        let value = dense.sketch(&(0..100).collect::<Vec<_>>(), &mut Scratch::new());
        assert_eq!(value.scheme_id(), "minhash");
        assert!(dense.insert(1, vec![1, 2, 3]).is_err());
        assert!(dense.query(&[1, 2, 3]).is_err());
    }

    #[test]
    fn schemes_are_isolated() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics);
        let set: Vec<u32> = (0..80).collect();
        reg.get(Some("fast")).unwrap().insert(5, set.clone()).unwrap();
        // The insert is visible in "fast" but not in the default scheme.
        assert!(reg.get(Some("fast")).unwrap().query(&set).unwrap().contains(&5));
        assert!(reg.get(None).unwrap().query(&set).unwrap().is_empty());
        // Only the default scheme retains raw sets (the estimate store);
        // named schemes index without a second copy of the corpus.
        assert_eq!(reg.get(Some("fast")).unwrap().stored(5), None);
        assert_eq!(reg.get(None).unwrap().stored(5), None);
        reg.get(None).unwrap().insert(6, set.clone()).unwrap();
        assert_eq!(reg.get(None).unwrap().stored(6), Some(set));
    }
}
