//! The scheme registry: several named sketch schemes served concurrently
//! from one coordinator.
//!
//! PR 3 made the sketch *scheme* configuration ([`SketchSpec`]); this
//! module makes it **plural**. A [`SchemeRegistry`] holds one [`Scheme`]
//! per configured name — the implicit [`DEFAULT_SCHEME`] derived from the
//! scalar config (preserving the single-scheme wire behaviour bit-for-bit)
//! plus one per `[[schemes]]` entry — and the wire ops' optional `scheme`
//! field selects among them. Each scheme owns:
//!
//! * an erased [`DynSketcher`] serving its `sketch` requests,
//! * for OPH specs, a [`ShardedIndex`] (per-scheme sharding — the
//!   `shards` key) serving `insert`/`query`/`save_index`/`load_index`,
//!   behind an `RwLock` so `load_index` can swap in a reloaded snapshot
//!   while serving,
//! * a **sketch store**: the scheme's own sketch of every inserted set,
//!   computed once at insert time. `estimate` compares these directly —
//!   no per-request re-sketching, no raw-set retention, and no legacy-
//!   sketcher mismatch when the scheme's spec is not the derived OPH
//!   default. Every scheme (not just the default) serves `estimate`.
//! * a [`SchemeCounters`] block surfaced through the `stats` op.
//!
//! Non-OPH schemes (MinHash, SimHash, FH, b-bit) have no LSH index — the
//! (K, L) bucket construction is defined over OPH bins — so `insert`/
//! `query`/`save_index`/`load_index` against them is a clean wire error,
//! not a panic. All locks on these paths are taken poison-tolerantly
//! ([`crate::util::sync`]): a wire request must never be able to wedge
//! the service behind a poisoned mutex.

use crate::coordinator::config::{CoordinatorConfig, DEFAULT_SCHEME};
use crate::coordinator::metrics::{Metrics, SchemeCounters};
use crate::coordinator::request::{sketch_value_from_json, sketch_value_to_json};
use crate::lsh::sharded::ShardedIndex;
use crate::lsh::topk::{Scored, TopK};
use crate::lsh::LshParams;
use crate::sketch::sketcher::{DynSketcher, SketchValue};
use crate::sketch::spec::{SketchScheme, SketchSpec};
use crate::sketch::Scratch;
use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;
use crate::util::sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

/// Header line of the sketch-store sidecar written next to index
/// snapshots ([`Scheme::save_index`]): `<SKETCHES_SCHEMA> <count>`.
const SKETCHES_SCHEMA: &str = "mixtab-sketches-v1";

/// The sidecar path for an index snapshot at `base`.
fn sketches_path(base: &str) -> PathBuf {
    PathBuf::from(format!("{base}.sketches"))
}

/// Write the sketch store next to an index snapshot: a header line
/// (`mixtab-sketches-v1 <count>`), then one `<id> <sketch-json>` line per
/// id in ascending id order (deterministic output for identical stores).
/// Atomic like the index files: tmp + flush + `sync_all` + rename.
fn write_sketch_sidecar(path: &Path, store: &HashMap<u32, SketchValue>) -> Result<()> {
    let tmp = path.with_extension("sketches.tmp");
    {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        writeln!(w, "{SKETCHES_SCHEMA} {}", store.len())?;
        let mut ids: Vec<u32> = store.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let json = sketch_value_to_json(&store[&id]);
            writeln!(w, "{id} {}", crate::util::json::to_string(&json))?;
        }
        w.flush()?;
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Parse a sidecar written by [`write_sketch_sidecar`]. Strict about the
/// schema line, the declared count, and duplicate ids — a truncated or
/// doubled-up file is an error, never a silently smaller store.
fn read_sketch_sidecar(path: &Path) -> Result<HashMap<u32, SketchValue>> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines.next().unwrap_or_default();
    let (schema, count) = header
        .split_once(' ')
        .with_context(|| format!("sidecar header '{header}' is not '<schema> <count>'"))?;
    if schema != SKETCHES_SCHEMA {
        bail!("sidecar schema '{schema}' != expected '{SKETCHES_SCHEMA}'");
    }
    let count: usize = count
        .parse()
        .with_context(|| format!("sidecar count '{count}'"))?;
    let mut store = HashMap::with_capacity(count);
    for (i, line) in lines.enumerate() {
        let (id, json) = line
            .split_once(' ')
            .with_context(|| format!("sidecar line {} is not '<id> <json>'", i + 2))?;
        let id: u32 = id.parse().with_context(|| format!("sidecar id '{id}'"))?;
        let value = sketch_value_from_json(&Json::parse(json)?)
            .with_context(|| format!("sidecar sketch for id {id}"))?;
        if store.insert(id, value).is_some() {
            bail!("sidecar repeats id {id}");
        }
    }
    if store.len() != count {
        bail!(
            "sidecar declares {count} sketches but carries {}",
            store.len()
        );
    }
    Ok(store)
}

/// One named scheme: sketcher + optional sharded index + sketch store.
pub struct Scheme {
    name: String,
    spec: SketchSpec,
    sketcher: Box<dyn DynSketcher>,
    /// OPH-backed sharded LSH index; `None` for non-OPH specs. `RwLock`
    /// so [`Self::load_index`] can replace it at runtime; `insert`/
    /// `query` take the read lock (the shard mutexes provide write
    /// granularity, so readers of this lock still insert concurrently).
    index: RwLock<Option<ShardedIndex>>,
    /// The (spec, params) the index was configured from — `load_index`
    /// validates snapshot provenance against it.
    index_spec: Option<(SketchSpec, LshParams)>,
    /// Sketches of inserted sets, keyed by id, produced by **this
    /// scheme's own sketcher** at insert time. `estimate` and
    /// `query_topk` read these; a sketch is k coordinates, far smaller
    /// than the raw set it replaced in the pre-PR5 default-scheme store.
    /// Persisted alongside index snapshots as a sidecar (documented on
    /// [`Self::save_index`] / [`Self::load_index`]).
    sketches: Mutex<HashMap<u32, SketchValue>>,
    /// Reusable sketching scratch for single-op paths (`insert`,
    /// `update`, `query_topk`) — one allocation per scheme lifetime
    /// instead of one per op; batch paths carry their own.
    scratch: Mutex<Scratch>,
    /// Fan-out pool handed to the configured index and to every index
    /// swapped in by [`Self::load_index`].
    pool: Option<Arc<ThreadPool>>,
    counters: Arc<SchemeCounters>,
}

impl Scheme {
    fn new(
        name: &str,
        spec: SketchSpec,
        index_spec: Option<(SketchSpec, LshParams, usize)>,
        pool: Option<Arc<ThreadPool>>,
        metrics: &Metrics,
    ) -> Self {
        let index = index_spec.map(|(ispec, params, shards)| {
            let mut idx = ShardedIndex::new(shards, params, &ispec);
            idx.set_pool(pool.clone());
            idx
        });
        let counters =
            metrics.register_scheme(name, index.as_ref().map_or(0, ShardedIndex::n_shards));
        Self {
            name: name.to_string(),
            spec,
            sketcher: spec.build(),
            index: RwLock::new(index),
            index_spec: index_spec.map(|(ispec, params, _)| (ispec, params)),
            sketches: Mutex::new(HashMap::new()),
            scratch: Mutex::new(Scratch::new()),
            pool,
            counters,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The spec serving this scheme's `sketch` requests.
    pub fn spec(&self) -> &SketchSpec {
        &self.spec
    }

    /// Whether this scheme serves an LSH index (OPH specs only).
    pub fn has_index(&self) -> bool {
        read_unpoisoned(&self.index).is_some()
    }

    /// Shard count of the serving index (0 for index-less schemes). May
    /// differ from the configured count after [`Self::load_index`].
    pub fn n_shards(&self) -> usize {
        read_unpoisoned(&self.index)
            .as_ref()
            .map_or(0, ShardedIndex::n_shards)
    }

    /// Stored sets in the serving index (0 for index-less schemes).
    pub fn index_len(&self) -> usize {
        read_unpoisoned(&self.index)
            .as_ref()
            .map_or(0, ShardedIndex::len)
    }

    /// Sketch a set with this scheme's sketcher.
    pub fn sketch(&self, set: &[u32], scratch: &mut Scratch) -> SketchValue {
        Metrics::inc(&self.counters.sketches);
        self.sketcher.sketch_dyn(set, scratch)
    }

    /// Shared write-through path for [`Self::insert`] and
    /// [`Self::update`]: upsert the index (any prior postings for the id
    /// are purged — [`crate::lsh::index::LshIndex::insert_sketch`]) and
    /// overwrite the stored sketch. Index and sketch store are updated
    /// one after the other (not atomically together): a concurrent
    /// `estimate` racing a write may miss the id, exactly as it would
    /// have a moment earlier. The single-op sketch reuses the scheme's
    /// hoisted scratch — no per-op allocation on this hot path.
    fn write_through(&self, id: u32, set: &[u32]) -> Result<()> {
        {
            let guard = read_unpoisoned(&self.index);
            let Some(index) = guard.as_ref() else {
                return self.no_index();
            };
            let shard = index.insert(id, set);
            // A loaded snapshot may serve more shards than the counter
            // block registered at startup; out-of-range shards simply go
            // uncounted per-shard (the scheme totals stay exact).
            if let Some(counter) = self.counters.shard_inserts.get(shard) {
                Metrics::inc(counter);
            }
        }
        let value = self
            .sketcher
            .sketch_dyn(set, &mut lock_unpoisoned(&self.scratch));
        lock_unpoisoned(&self.sketches).insert(id, value);
        Ok(())
    }

    /// Insert a set into this scheme's index and record the scheme's own
    /// sketch of it for `estimate`/`query_topk`. Re-inserting an
    /// existing id is an upsert — old postings never linger. Errors for
    /// index-less (non-OPH) schemes.
    pub fn insert(&self, id: u32, set: Vec<u32>) -> Result<()> {
        self.write_through(id, &set)?;
        Metrics::inc(&self.counters.inserts);
        Ok(())
    }

    /// Update (delete + insert under one shard lock) `id` with new
    /// content. Functionally the same upsert as [`Self::insert`] — the
    /// separate op exists so churn workloads are distinguishable in
    /// metrics and routing.
    pub fn update(&self, id: u32, set: Vec<u32>) -> Result<()> {
        self.write_through(id, &set)?;
        Metrics::inc(&self.counters.updates);
        Ok(())
    }

    /// Delete `id`: tombstone it in the index (compaction reclaims the
    /// postings — [`crate::lsh::sharded::ShardedIndex::delete`]) and drop
    /// its stored sketch. Returns whether the id was live. Errors for
    /// index-less schemes.
    pub fn delete(&self, id: u32) -> Result<bool> {
        let existed = {
            let guard = read_unpoisoned(&self.index);
            let Some(index) = guard.as_ref() else {
                return self.no_index();
            };
            index.delete(id).1
        };
        lock_unpoisoned(&self.sketches).remove(&id);
        Metrics::inc(&self.counters.deletes);
        Ok(existed)
    }

    /// Explicitly compact every shard of this scheme's index, purging
    /// all tombstoned postings. Returns the number of posting entries
    /// removed. Errors for index-less schemes.
    pub fn compact(&self) -> Result<usize> {
        let guard = read_unpoisoned(&self.index);
        let Some(index) = guard.as_ref() else {
            return self.no_index();
        };
        Ok(index.compact())
    }

    /// Tombstoned (deleted, not yet compacted) ids in the serving index.
    pub fn tombstone_count(&self) -> usize {
        read_unpoisoned(&self.index)
            .as_ref()
            .map_or(0, ShardedIndex::tombstone_count)
    }

    /// Top-k serving: retrieve the LSH candidate set, then re-rank it
    /// with this scheme's estimator over the stored sketches, keeping
    /// the k best in a bounded heap ([`TopK`]). Results are (id, score)
    /// pairs, score descending with ties broken by ascending id.
    /// Candidates without a stored sketch (possible only for a corpus
    /// restored from a pre-sidecar snapshot and not re-inserted) are
    /// skipped — they cannot be scored. Errors for index-less schemes.
    pub fn query_topk(&self, set: &[u32], k: usize) -> Result<Vec<Scored>> {
        let candidates = {
            let guard = read_unpoisoned(&self.index);
            let Some(index) = guard.as_ref() else {
                return self.no_index();
            };
            let (ids, per_shard) = index.query_fanout(set);
            for (counter, n) in self.counters.shard_candidates.iter().zip(per_shard) {
                Metrics::add(counter, n as u64);
            }
            ids
        };
        let probe = self
            .sketcher
            .sketch_dyn(set, &mut lock_unpoisoned(&self.scratch));
        let mut top = TopK::new(k);
        {
            let store = lock_unpoisoned(&self.sketches);
            for id in candidates {
                if let Some(stored) = store.get(&id) {
                    top.offer(id, probe.estimate(stored)?);
                }
            }
        }
        Metrics::inc(&self.counters.topk_queries);
        let out = top.into_sorted();
        if out.len() < k {
            // Short list: the candidate set (or its scoreable subset)
            // was smaller than the requested k — surfaced per scheme so
            // recall starvation shows up in `stats` before it shows up
            // in application quality.
            Metrics::inc(&self.counters.topk_short);
        }
        Ok(out)
    }

    /// Threshold compactions completed on the background pool by this
    /// scheme's serving index ([`ShardedIndex::background_compactions`];
    /// 0 for index-less schemes).
    pub fn background_compactions(&self) -> u64 {
        read_unpoisoned(&self.index)
            .as_ref()
            .map_or(0, ShardedIndex::background_compactions)
    }

    /// Batched [`Self::sketch`]: one scratch reused across the batch.
    /// Per-set results are bit-identical to `sketch` (the batch
    /// entry point is property-tested equal per set), and the per-scheme
    /// counter advances by the batch size, as singles would.
    pub fn sketch_batch(&self, sets: &[Vec<u32>]) -> Vec<SketchValue> {
        Metrics::add(&self.counters.sketches, sets.len() as u64);
        let cap = sets.iter().map(Vec::len).max().unwrap_or(0);
        self.sketcher
            .sketch_batch_dyn(sets, &mut Scratch::with_capacity(cap))
    }

    /// Batched [`Self::insert`]: one index read-lock acquisition for the
    /// index writes, one scratch and one store-lock acquisition for the
    /// sketch store. Per-item effects — index contents, stored sketch,
    /// counters — are identical to calling `insert` per id. Errors for
    /// index-less schemes (the whole batch, no partial application).
    pub fn insert_batch(&self, items: &[(u32, Vec<u32>)]) -> Result<()> {
        {
            let guard = read_unpoisoned(&self.index);
            let Some(index) = guard.as_ref() else {
                return self.no_index();
            };
            for (id, set) in items {
                let shard = index.insert(*id, set);
                Metrics::inc(&self.counters.inserts);
                if let Some(counter) = self.counters.shard_inserts.get(shard) {
                    Metrics::inc(counter);
                }
            }
        }
        let cap = items.iter().map(|(_, s)| s.len()).max().unwrap_or(0);
        let mut scratch = Scratch::with_capacity(cap);
        let values: Vec<(u32, SketchValue)> = items
            .iter()
            .map(|(id, set)| (*id, self.sketcher.sketch_dyn(set, &mut scratch)))
            .collect();
        let mut store = lock_unpoisoned(&self.sketches);
        for (id, value) in values {
            store.insert(id, value);
        }
        Ok(())
    }

    /// Batched [`Self::query`]: one read-lock acquisition, per-set
    /// results identical to `query`. Errors for index-less schemes.
    pub fn query_batch(&self, sets: &[Vec<u32>]) -> Result<Vec<Vec<u32>>> {
        let guard = read_unpoisoned(&self.index);
        let Some(index) = guard.as_ref() else {
            return self.no_index();
        };
        let mut out = Vec::with_capacity(sets.len());
        for set in sets {
            let (ids, per_shard) = index.query_fanout(set);
            Metrics::inc(&self.counters.queries);
            for (counter, n) in self.counters.shard_candidates.iter().zip(per_shard) {
                Metrics::add(counter, n as u64);
            }
            out.push(ids);
        }
        Ok(out)
    }

    /// Fan-out query over this scheme's index (parallel across shards
    /// when the coordinator attached a pool). Errors for index-less
    /// (non-OPH) schemes.
    pub fn query(&self, set: &[u32]) -> Result<Vec<u32>> {
        let guard = read_unpoisoned(&self.index);
        let Some(index) = guard.as_ref() else {
            return self.no_index();
        };
        let (ids, per_shard) = index.query_fanout(set);
        Metrics::inc(&self.counters.queries);
        for (counter, n) in self.counters.shard_candidates.iter().zip(per_shard) {
            Metrics::add(counter, n as u64);
        }
        Ok(ids)
    }

    /// Similarity estimate between two previously inserted ids, from
    /// their stored sketches — this scheme's own sketcher, compared with
    /// the scheme-appropriate estimator ([`SketchValue::estimate`]). No
    /// re-sketching happens on this path.
    pub fn estimate(&self, a: u32, b: u32) -> Result<f64> {
        let sketches = lock_unpoisoned(&self.sketches);
        let (Some(sa), Some(sb)) = (sketches.get(&a), sketches.get(&b)) else {
            bail!("unknown id(s): {a}, {b}");
        };
        let est = sa.estimate(sb)?;
        Metrics::inc(&self.counters.estimates);
        Ok(est)
    }

    /// Number of ids with a stored sketch (tests/diagnostics).
    pub fn sketch_store_len(&self) -> usize {
        lock_unpoisoned(&self.sketches).len()
    }

    /// Snapshot this scheme's index to a server-side path, plus the
    /// sketch store as a `<path>.sketches` sidecar so a reload serves
    /// `estimate`/`query_topk` without re-insertion; returns the entry
    /// count. Errors (never panics) for index-less schemes. The sketch
    /// store is captured after the index files are written — a write
    /// racing the save can appear in the sidecar but not the index
    /// (it behaves as if inserted just after the snapshot).
    pub fn save_index(&self, path: &str) -> Result<usize> {
        let n = {
            let guard = read_unpoisoned(&self.index);
            let Some(index) = guard.as_ref() else {
                return self.no_index();
            };
            index.save(path)?
        };
        let side = sketches_path(path);
        write_sketch_sidecar(&side, &lock_unpoisoned(&self.sketches))
            .with_context(|| format!("writing sketch sidecar '{}'", side.display()))?;
        Ok(n)
    }

    /// Replace this scheme's index with a snapshot written by
    /// [`Self::save_index`] / [`ShardedIndex::save`]. The snapshot's
    /// provenance must match the scheme's configured index spec — hash
    /// family, seed, layout/densify, and (K, L) — so a reload can never
    /// silently change the serving sketcher; the shard *count* may
    /// differ (routing is deterministic per count and snapshots are
    /// self-consistent). Returns `(entries, shards)`.
    ///
    /// The sketch store rides along as the `<path>.sketches` sidecar
    /// ([`Self::save_index`]): when present it **replaces** the live
    /// store, so restored ids serve `estimate`/`query_topk` immediately.
    /// A snapshot without a sidecar (written before the sidecar existed,
    /// or with it deleted) **clears** the store instead: the old sketches
    /// describe the corpus being replaced, and keeping them would let
    /// `estimate` answer for ids the restored index no longer contains
    /// (or now maps to different sets) — such ids serve `query`
    /// immediately and `estimate` after re-insertion. (An `insert` racing
    /// the swap can still slip its sketch in after the store swap while
    /// its set misses the new index — inherent to replace-by-swap; the id
    /// simply behaves as if inserted just before the load.)
    pub fn load_index(&self, path: &str) -> Result<(usize, usize)> {
        let Some((ispec, params)) = self.index_spec else {
            return self.no_index();
        };
        let mut loaded = ShardedIndex::load(path)?;
        let side = sketches_path(path);
        let restored = if side.exists() {
            Some(
                read_sketch_sidecar(&side)
                    .with_context(|| format!("reading sketch sidecar '{}'", side.display()))?,
            )
        } else {
            None
        };
        // Normalise both specs to the index's structural bin count before
        // comparing: configured specs keep their nominal k (the index
        // overrides it), plain snapshots record k = K·L.
        let bins = params.sketch_bins();
        if loaded.params() != params || loaded.spec().with_oph_k(bins) != ispec.with_oph_k(bins) {
            bail!(
                "snapshot '{path}' does not match scheme '{}': snapshot has spec '{}' K={} L={}, scheme expects spec '{}' K={} L={}",
                self.name,
                loaded.spec(),
                loaded.params().k,
                loaded.params().l,
                ispec,
                params.k,
                params.l
            );
        }
        loaded.set_pool(self.pool.clone());
        let (entries, shards) = (loaded.len(), loaded.n_shards());
        // Swap the sketch store under the index write lock so no
        // `estimate` can observe the new index paired with the old
        // corpus's sketches. (No other path holds the sketch-store lock
        // while waiting on the index lock, so the nesting cannot
        // deadlock.)
        let mut guard = write_unpoisoned(&self.index);
        {
            let mut store = lock_unpoisoned(&self.sketches);
            match restored {
                Some(map) => *store = map,
                None => store.clear(),
            }
        }
        *guard = Some(loaded);
        Ok((entries, shards))
    }

    fn no_index<T>(&self) -> Result<T> {
        bail!(
            "scheme '{}' has no LSH index (spec '{}' is not OPH)",
            self.name,
            self.spec
        )
    }
}

/// All schemes served by one coordinator, looked up by wire name.
pub struct SchemeRegistry {
    /// Registration order: default first, then `[[schemes]]` file order.
    schemes: Vec<Scheme>,
}

impl SchemeRegistry {
    /// Build the registry from config: the implicit default scheme
    /// (sketcher from `cfg.sketch_spec()`, index from `cfg.lsh_spec()`
    /// sharded `cfg.lsh_shards` ways — with one shard this is bit-identical
    /// to the pre-registry coordinator) plus every `[[schemes]]` entry.
    /// Name collisions are rejected at config parse time. `pool`, when
    /// given, is shared by every scheme's index for parallel shard
    /// fan-out.
    pub fn from_config(
        cfg: &CoordinatorConfig,
        metrics: &Metrics,
        pool: Option<Arc<ThreadPool>>,
    ) -> Self {
        let params = LshParams::new(cfg.lsh_k, cfg.lsh_l);
        let mut schemes = vec![Scheme::new(
            DEFAULT_SCHEME,
            cfg.sketch_spec(),
            Some((cfg.lsh_spec(), params, cfg.lsh_shards)),
            pool.clone(),
            metrics,
        )];
        for sc in &cfg.schemes {
            let index_spec = matches!(sc.spec.scheme, SketchScheme::Oph(_))
                .then_some((sc.spec, params, sc.shards));
            schemes.push(Scheme::new(&sc.name, sc.spec, index_spec, pool.clone(), metrics));
        }
        Self { schemes }
    }

    /// Look up a scheme by wire name; `None` selects the default scheme.
    pub fn get(&self, name: Option<&str>) -> Result<&Scheme> {
        let name = name.unwrap_or(DEFAULT_SCHEME);
        match self.schemes.iter().find(|s| s.name == name) {
            Some(scheme) => Ok(scheme),
            None => bail!(
                "unknown scheme '{name}' (serving: {})",
                self.names().join(", ")
            ),
        }
    }

    /// The implicit default scheme.
    pub fn default_scheme(&self) -> &Scheme {
        &self.schemes[0]
    }

    /// Served scheme names, registration order (default first).
    pub fn names(&self) -> Vec<&str> {
        self.schemes.iter().map(|s| s.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SchemeConfig;
    use crate::hash::HashFamily;
    use crate::sketch::estimators::jaccard_exact;

    fn registry_cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            enable_pjrt: false,
            lsh_k: 3,
            lsh_l: 4,
            lsh_shards: 2,
            schemes: vec![
                SchemeConfig {
                    name: "fast".into(),
                    spec: SketchSpec::oph(HashFamily::MultiplyShift, 7, 64),
                    shards: 3,
                },
                SchemeConfig {
                    name: "dense".into(),
                    spec: SketchSpec::minhash(HashFamily::MixedTab, 9, 16),
                    shards: 1,
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn registry_serves_default_and_named_schemes() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        assert_eq!(reg.names(), vec![DEFAULT_SCHEME, "fast", "dense"]);
        assert_eq!(reg.get(None).unwrap().name(), DEFAULT_SCHEME);
        assert_eq!(reg.get(Some("fast")).unwrap().name(), "fast");
        assert!(reg.get(Some("nope")).is_err());
        // Shard counts follow the per-scheme config.
        assert_eq!(reg.default_scheme().n_shards(), 2);
        assert_eq!(reg.get(Some("fast")).unwrap().n_shards(), 3);
        // Non-OPH scheme: sketching works, index ops error cleanly.
        let dense = reg.get(Some("dense")).unwrap();
        assert!(!dense.has_index());
        let value = dense.sketch(&(0..100).collect::<Vec<_>>(), &mut Scratch::new());
        assert_eq!(value.scheme_id(), "minhash");
        assert!(dense.insert(1, vec![1, 2, 3]).is_err());
        assert!(dense.query(&[1, 2, 3]).is_err());
        assert!(dense.save_index("/tmp/never-written.mxsh").is_err());
        assert!(dense.load_index("/tmp/never-read.mxsh").is_err());
    }

    #[test]
    fn schemes_are_isolated() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        let set: Vec<u32> = (0..80).collect();
        reg.get(Some("fast")).unwrap().insert(5, set.clone()).unwrap();
        // The insert is visible in "fast" but not in the default scheme.
        assert!(reg.get(Some("fast")).unwrap().query(&set).unwrap().contains(&5));
        assert!(reg.get(None).unwrap().query(&set).unwrap().is_empty());
        // Sketch stores are per-scheme too: "fast" can estimate its own
        // inserts, the default scheme knows nothing about them.
        reg.get(Some("fast")).unwrap().insert(6, set.clone()).unwrap();
        assert_eq!(reg.get(Some("fast")).unwrap().estimate(5, 6).unwrap(), 1.0);
        assert!(reg.get(None).unwrap().estimate(5, 6).is_err());
        assert_eq!(reg.get(Some("fast")).unwrap().sketch_store_len(), 2);
        assert_eq!(reg.get(None).unwrap().sketch_store_len(), 0);
    }

    #[test]
    fn estimate_uses_stored_scheme_sketches() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        let a: Vec<u32> = (0..300).collect();
        let b: Vec<u32> = (30..330).collect(); // J = 270/330 ≈ 0.82
        let fast = reg.get(Some("fast")).unwrap();
        fast.insert(1, a.clone()).unwrap();
        fast.insert(2, b.clone()).unwrap();
        let est = fast.estimate(1, 2).unwrap();
        let truth = jaccard_exact(&a, &b);
        assert!((est - truth).abs() < 0.25, "est {est} truth {truth}");
        // Bit-identical to comparing this scheme's own sketches directly
        // — the store holds the sketcher's output, not a re-derivation.
        let sk = fast.spec().build();
        let mut scratch = Scratch::new();
        let expect = sk
            .sketch_dyn(&a, &mut scratch)
            .estimate(&sk.sketch_dyn(&b, &mut scratch))
            .unwrap();
        assert_eq!(est, expect);
        // Unknown ids are clean errors.
        assert!(fast.estimate(1, 99).is_err());
        assert!(fast.estimate(98, 99).is_err());
    }

    /// The batch entry points are the op batcher's substrate: their
    /// per-item effects must be bit-identical to the single-op methods.
    #[test]
    fn batch_ops_match_singles() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        let metrics_b = Metrics::new();
        let reg_b = SchemeRegistry::from_config(&registry_cfg(), &metrics_b, None);
        let sets: Vec<Vec<u32>> = (0..12u32)
            .map(|i| (i * 30..i * 30 + 50).collect())
            .collect();
        let single = reg.get(Some("fast")).unwrap();
        let batched = reg_b.get(Some("fast")).unwrap();
        for (i, s) in sets.iter().enumerate() {
            single.insert(i as u32, s.clone()).unwrap();
        }
        let items: Vec<(u32, Vec<u32>)> = sets
            .iter()
            .enumerate()
            .map(|(i, s)| (i as u32, s.clone()))
            .collect();
        batched.insert_batch(&items).unwrap();
        // Queries agree item-for-item across both indices and both entry
        // points, and the sketch stores estimate identically.
        let results = batched.query_batch(&sets).unwrap();
        for (i, s) in sets.iter().enumerate() {
            assert_eq!(single.query(s).unwrap(), results[i], "set {i}");
            assert_eq!(batched.query(s).unwrap(), results[i], "set {i}");
        }
        assert_eq!(single.estimate(0, 1).unwrap(), batched.estimate(0, 1).unwrap());
        assert_eq!(batched.sketch_store_len(), sets.len());
        // Batched sketching is bit-identical to singles.
        let values = single.sketch_batch(&sets);
        for (i, s) in sets.iter().enumerate() {
            assert_eq!(single.sketch(s, &mut Scratch::new()), values[i], "set {i}");
        }
        // Counters advanced per item, exactly as singles.
        let s = metrics_b.snapshot();
        let fast = s.get("schemes").unwrap().get("fast").unwrap();
        assert_eq!(fast.get("inserts").unwrap().as_i64(), Some(12));
        assert_eq!(fast.get("queries").unwrap().as_i64(), Some(24));
        // Index-less schemes: batch index ops are clean errors.
        let dense = reg.get(Some("dense")).unwrap();
        assert!(dense.insert_batch(&items).is_err());
        assert!(dense.query_batch(&sets).is_err());
        assert_eq!(dense.sketch_batch(&sets).len(), sets.len());
    }

    #[test]
    fn load_index_validates_and_swaps() {
        let dir = std::env::temp_dir().join("mixtab_registry_load");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        let fast = reg.get(Some("fast")).unwrap();
        let sets: Vec<Vec<u32>> = (0..20u32)
            .map(|i| (i * 40..i * 40 + 70).collect())
            .collect();
        for (i, s) in sets.iter().enumerate() {
            fast.insert(i as u32, s.clone()).unwrap();
        }
        let snap = dir.join("fast.mxsh").display().to_string();
        assert_eq!(fast.save_index(&snap).unwrap(), sets.len());

        // A snapshot of "fast" does not load into the default scheme
        // (different spec provenance) or parse from a missing path.
        assert!(reg.get(None).unwrap().load_index(&snap).is_err());
        assert!(fast.load_index(&dir.join("missing").display().to_string()).is_err());
        // ...and the failed loads left the old index AND sketch store
        // serving.
        assert!(fast.query(&sets[0]).unwrap().contains(&0));
        assert!(fast.estimate(0, 1).is_ok());

        // A *successful* load replaces the sketch store with the sidecar
        // written at save time — estimates keep serving.
        let before = fast.estimate(0, 1).unwrap();
        let (entries, shards) = fast.load_index(&snap).unwrap();
        assert_eq!((entries, shards), (sets.len(), 3));
        assert_eq!(fast.sketch_store_len(), sets.len());
        assert_eq!(fast.estimate(0, 1).unwrap(), before);
        assert!(fast.query(&sets[0]).unwrap().contains(&0));

        // Reload into a *fresh* registry: queries, estimates and top-k
        // all serve straight from the snapshot + sidecar pair.
        let metrics2 = Metrics::new();
        let reg2 = SchemeRegistry::from_config(&registry_cfg(), &metrics2, None);
        let fast2 = reg2.get(Some("fast")).unwrap();
        let (entries, shards) = fast2.load_index(&snap).unwrap();
        assert_eq!((entries, shards), (sets.len(), 3));
        assert_eq!(fast2.index_len(), sets.len());
        for (i, s) in sets.iter().enumerate() {
            assert!(fast2.query(s).unwrap().contains(&(i as u32)), "set {i}");
        }
        assert_eq!(fast2.estimate(0, 1).unwrap(), before);
        let top = fast2.query_topk(&sets[0], 3).unwrap();
        assert_eq!(top.first().map(|s| s.id), Some(0));

        // Pre-sidecar snapshots (no `.sketches` file) still load, and
        // clear the store: queries serve, estimate needs re-insertion.
        std::fs::remove_file(sketches_path(&snap)).unwrap();
        let (entries, _) = fast2.load_index(&snap).unwrap();
        assert_eq!(entries, sets.len());
        assert_eq!(fast2.sketch_store_len(), 0);
        assert!(fast2.estimate(0, 1).is_err());
        assert!(fast2.query(&sets[0]).unwrap().contains(&0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The mutable-corpus surface: delete tombstones + drops the stored
    /// sketch, update supersedes content, compact reclaims postings, and
    /// `query_topk` never surfaces a deleted or superseded id.
    #[test]
    fn delete_update_compact_and_topk() {
        let metrics = Metrics::new();
        let reg = SchemeRegistry::from_config(&registry_cfg(), &metrics, None);
        let fast = reg.get(Some("fast")).unwrap();
        let sets: Vec<Vec<u32>> = (0..10u32)
            .map(|i| (i * 50..i * 50 + 80).collect())
            .collect();
        for (i, s) in sets.iter().enumerate() {
            fast.insert(i as u32, s.clone()).unwrap();
        }

        // Top-k over the full corpus: the exact-match id ranks first
        // with score 1.0, and results are score-descending.
        let top = fast.query_topk(&sets[3], 5).unwrap();
        assert_eq!(top.first().map(|s| (s.id, s.score)), Some((3, 1.0)));
        for w in top.windows(2) {
            assert!(w[0].score >= w[1].score, "{top:?}");
        }

        // Delete: gone from query, top-k, estimate and the store.
        assert!(fast.delete(3).unwrap());
        assert!(!fast.delete(3).unwrap(), "second delete reports not-live");
        assert!(!fast.query(&sets[3]).unwrap().contains(&3));
        assert!(fast.query_topk(&sets[3], 5).unwrap().iter().all(|s| s.id != 3));
        assert!(fast.estimate(3, 4).is_err());
        assert_eq!(fast.sketch_store_len(), sets.len() - 1);
        assert_eq!(fast.index_len(), sets.len() - 1);

        // Update supersedes: id 4 now holds set 8's content, so probing
        // the old content no longer surfaces it anywhere.
        fast.update(4, sets[8].clone()).unwrap();
        assert!(!fast.query(&sets[4]).unwrap().contains(&4));
        assert!(fast.query(&sets[8]).unwrap().contains(&4));
        assert!(fast.query_topk(&sets[4], 5).unwrap().iter().all(|s| s.id != 4));
        assert_eq!(fast.estimate(4, 8).unwrap(), 1.0);

        // Explicit compact purges the tombstoned postings and keeps
        // results identical.
        assert!(fast.tombstone_count() > 0);
        assert!(fast.compact().unwrap() > 0);
        assert_eq!(fast.tombstone_count(), 0);
        assert!(!fast.query(&sets[3]).unwrap().contains(&3));
        assert!(fast.query(&sets[8]).unwrap().contains(&4));

        // Index-less schemes error cleanly on every mutable-corpus op.
        let dense = reg.get(Some("dense")).unwrap();
        assert!(dense.delete(1).is_err());
        assert!(dense.update(1, vec![1, 2]).is_err());
        assert!(dense.compact().is_err());
        assert!(dense.query_topk(&[1, 2], 3).is_err());

        // Requesting more results than the candidate set can yield is a
        // short top-k response, counted per scheme.
        let huge = fast.query_topk(&sets[8], 500).unwrap();
        assert!(huge.len() < 500);

        // No pool attached, so threshold compactions (if any) ran inline.
        assert_eq!(fast.background_compactions(), 0);
        assert_eq!(dense.background_compactions(), 0);

        // Counters tracked the op mix.
        let s = metrics.snapshot();
        let c = s.get("schemes").unwrap().get("fast").unwrap();
        assert_eq!(c.get("inserts").unwrap().as_i64(), Some(10));
        assert_eq!(c.get("deletes").unwrap().as_i64(), Some(2));
        assert_eq!(c.get("updates").unwrap().as_i64(), Some(1));
        assert!(c.get("topk_queries").unwrap().as_i64().unwrap() >= 3);
        assert!(c.get("topk_short").unwrap().as_i64().unwrap() >= 1);
    }
}
