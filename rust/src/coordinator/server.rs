//! TCP front-end: an event-driven, pipelined, newline-delimited-JSON
//! server.
//!
//! One request per line, one response per line — served by a single
//! nonblocking event-loop thread that owns every socket plus a fixed
//! worker pool ([`crate::util::threadpool::ThreadPool`]) that runs the
//! handlers. The event loop accepts bytes, extracts frames, and admits
//! requests; completed responses flow back over an mpsc channel and are
//! written from bounded per-connection outbound queues. When a
//! connection's pending work hits `[service] conn_queue_cap` the loop
//! simply stops reading that socket, so a fast writer or stalled reader
//! exerts TCP backpressure instead of growing server memory.
//!
//! **Pipelining.** A request may carry a client-chosen `rid` tag (a
//! non-negative integer, exact up to 2^53 − 1). Tagged requests execute
//! concurrently on the pool and their responses may return out of order,
//! each echoing its tag. Untagged requests keep the legacy contract:
//! strictly one in flight per connection, responses in arrival order — a
//! client that never sends `rid` cannot observe the new architecture.
//! The two lanes share one admission path and one outbound queue.
//!
//! **Cross-connection batching.** Batchable ops (`sketch` without an
//! ad-hoc spec, `insert`, `delete`, `update`, `query`) route through an
//! [`OpBatcher`](crate::coordinator::batcher::OpBatcher) that coalesces
//! jobs *across connections* into one registry call per scheme
//! (fill-or-deadline dispatch). A full batch queue sheds the op to the
//! direct worker path (`op_shed` metric) — the batched entry points reuse
//! the per-item primitives, so results are bit-identical either way (the
//! `coordinator` integration harness proves this for every scheme
//! family).
//!
//! **Determinism.** All per-connection protocol state lives in
//! [`ConnState`], which does no IO and takes every timestamp as a
//! parameter. The concurrency harness drives it with scripted byte
//! sequences and fake clocks — no sleeps, no real sockets — and the
//! event loop is a thin IO shell around it.
//!
//! **Throttling lives here**, per connection — not in spec validation.
//! Spec parsing caps what one request can allocate, but only the
//! connection layer can bound how *often* a client pays that cost, so each
//! connection carries a token bucket (`[limits] requests_per_sec`/`burst`)
//! and an optional hard request budget (`max_requests_per_conn`).
//! Over-rate requests get an `Error` response (the connection stays up —
//! the client is told to back off); an exhausted budget closes the
//! connection after one final error. Both count into the `throttled`
//! metric. One connection's bucket never affects another's. A global
//! `[limits] max_connections` cap sheds whole connections at accept time
//! with one clean error line (`conns_rejected` metric) instead of letting
//! them hang.

use crate::coordinator::batcher::{BatchOp, OpBatcher, OpExecutor, OpJob};
use crate::coordinator::config::CoordinatorConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{parse_tagged_request, Request, Response};
use crate::coordinator::service::Coordinator;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, BufWriter, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Hard bound on one wire line. A peer that exceeds it without a newline
/// is protocol-broken: it gets one error response and the connection
/// closes. Far above any legitimate request (spec validation caps set
/// sizes well below this).
pub const MAX_LINE_BYTES: usize = 1 << 20;

const THROTTLE_MSG: &str = "rate limited: per-connection request rate exceeded";
const BUDGET_MSG: &str = "request budget exhausted: connection closing";
const CAPACITY_MSG: &str = "server at connection capacity: try again later";

/// Admission verdict for one request on one connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Admit {
    /// Serve it.
    Ok,
    /// Token bucket empty: reject this request, keep the connection.
    Throttled,
    /// Hard budget spent: reject and close the connection.
    BudgetExhausted,
}

/// Per-connection rate limiter: a continuous-refill token bucket plus an
/// optional lifetime request budget. Owned by the connection's
/// [`ConnState`] — no cross-connection state, so one noisy client cannot
/// starve another.
struct ConnLimiter {
    /// Tokens/second; `None` when rate limiting is off.
    rate: Option<f64>,
    capacity: f64,
    tokens: f64,
    last_refill: Instant,
    /// Remaining request budget; `None` when unlimited.
    budget: Option<u64>,
}

impl ConnLimiter {
    fn new(cfg: &CoordinatorConfig, now: Instant) -> Self {
        let capacity = cfg.effective_burst() as f64;
        Self {
            rate: (cfg.rate_limit_rps > 0.0).then_some(cfg.rate_limit_rps),
            capacity,
            tokens: capacity,
            last_refill: now,
            budget: (cfg.conn_request_budget > 0).then_some(cfg.conn_request_budget),
        }
    }

    /// Admission decision at time `now` (injected for deterministic tests).
    /// Only *admitted* requests consume the budget — a throttled request
    /// is the server's own rejection, and charging it would let the rate
    /// limiter silently convert "back off" into "connection closed".
    fn admit_at(&mut self, now: Instant) -> Admit {
        if self.budget == Some(0) {
            return Admit::BudgetExhausted;
        }
        if let Some(rate) = self.rate {
            let elapsed = now.duration_since(self.last_refill).as_secs_f64();
            self.last_refill = now;
            self.tokens = (self.tokens + elapsed * rate).min(self.capacity);
            if self.tokens < 1.0 {
                return Admit::Throttled;
            }
            self.tokens -= 1.0;
        }
        if let Some(n) = &mut self.budget {
            *n -= 1;
        }
        Admit::Ok
    }
}

/// One decoded request ready for execution, tagged with its pipeline id
/// (`None` = the ordered lane).
#[derive(Debug)]
pub struct Dispatch {
    pub rid: Option<u64>,
    pub req: Request,
}

/// Best-effort `rid` extraction for error responses synthesized *before*
/// the request body is parsed (throttle / budget rejections), so a
/// pipelined client can still map the error back to its request. Absent
/// or invalid tags echo nothing, matching the untagged wire format.
fn peek_rid(line: &str) -> Option<u64> {
    Json::parse(line)
        .ok()?
        .get("rid")?
        .as_i64()
        .and_then(|x| u64::try_from(x).ok())
}

/// Per-connection protocol state machine: framing, admission, the
/// pipelined/ordered dispatch lanes, and the bounded outbound queue.
///
/// Deliberately IO-free and clock-injected — every method takes `now` —
/// so the concurrency test harness can drive arbitrary interleavings of
/// partial reads, partial writes, completions, and timeouts without real
/// sockets or sleeps. The event loop is a thin shell that feeds it.
///
/// Backpressure invariant: `pending()` (requests admitted but not yet
/// fully written back) never exceeds the configured cap, because frame
/// extraction stops at the cap and [`Self::wants_read`] turns off — the
/// kernel socket buffer, and ultimately the peer, absorb the rest.
pub struct ConnState {
    limiter: ConnLimiter,
    metrics: Arc<Metrics>,
    idle_timeout: Option<Duration>,
    cap: usize,
    max_line: usize,
    /// Unconsumed inbound bytes (at most one partial frame plus whatever
    /// the cap kept us from extracting).
    inbuf: Vec<u8>,
    /// `inbuf[..scan_pos]` is known newline-free — resume point so a slow
    /// trickle of bytes is not rescanned quadratically.
    scan_pos: usize,
    /// Untagged requests admitted but not yet dispatched: the ordered
    /// lane executes strictly one at a time, in arrival order.
    ordered: VecDeque<Request>,
    ordered_inflight: bool,
    tagged_inflight: usize,
    /// Serialized response lines awaiting the socket.
    outq: VecDeque<Vec<u8>>,
    /// Bytes of `outq.front()` already written.
    out_pos: usize,
    last_activity: Instant,
    /// Budget exhausted or protocol broken: serve what was admitted,
    /// write everything out, then close. No further frames are read.
    close_after_drain: bool,
    read_closed: bool,
}

impl ConnState {
    pub fn new(cfg: &CoordinatorConfig, metrics: Arc<Metrics>, now: Instant) -> Self {
        Self {
            limiter: ConnLimiter::new(cfg, now),
            metrics,
            idle_timeout: match cfg.idle_timeout_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            cap: cfg.conn_queue_cap.max(1),
            max_line: MAX_LINE_BYTES,
            inbuf: Vec::new(),
            scan_pos: 0,
            ordered: VecDeque::new(),
            ordered_inflight: false,
            tagged_inflight: 0,
            outq: VecDeque::new(),
            out_pos: 0,
            last_activity: now,
            close_after_drain: false,
            read_closed: false,
        }
    }

    /// Override the line-length bound (tests shrink it to exercise the
    /// oversized-frame path without megabyte payloads).
    pub fn set_max_line(&mut self, n: usize) {
        self.max_line = n;
    }

    /// Requests admitted but not yet fully answered on the wire:
    /// in flight + queued for dispatch + queued for write. Frame
    /// extraction stops at the cap, and every admitted request produces
    /// exactly one response line, so this also bounds the outbound queue.
    pub fn pending(&self) -> usize {
        self.tagged_inflight
            + usize::from(self.ordered_inflight)
            + self.ordered.len()
            + self.outq.len()
    }

    /// Whether the event loop should read more bytes from the socket.
    pub fn wants_read(&self) -> bool {
        !self.read_closed
            && !self.close_after_drain
            && self.pending() < self.cap
            && self.inbuf.len() <= self.max_line
    }

    /// Whether there are response bytes waiting for the socket.
    pub fn has_output(&self) -> bool {
        !self.outq.is_empty()
    }

    /// Feed bytes read from the socket; returns requests to dispatch.
    pub fn on_bytes(&mut self, bytes: &[u8], now: Instant) -> Vec<Dispatch> {
        self.last_activity = now;
        self.inbuf.extend_from_slice(bytes);
        self.pump(now)
    }

    /// The peer closed its write side. Any complete buffered frames (and
    /// a final unterminated line, which the old blocking reader also
    /// served) are still processed; the connection closes once drained.
    pub fn on_eof(&mut self, now: Instant) -> Vec<Dispatch> {
        self.read_closed = true;
        self.pump(now)
    }

    /// A dispatched request completed: queue its wire line and return any
    /// newly unblocked dispatches (the next ordered request, or frames
    /// that were waiting on the pending cap).
    pub fn on_response(
        &mut self,
        rid: Option<u64>,
        resp: &Response,
        now: Instant,
    ) -> Vec<Dispatch> {
        match rid {
            Some(_) => self.tagged_inflight = self.tagged_inflight.saturating_sub(1),
            None => self.ordered_inflight = false,
        }
        self.enqueue_response(rid, resp);
        self.pump(now)
    }

    /// Next unwritten outbound bytes, if any.
    pub fn next_write(&self) -> Option<&[u8]> {
        self.outq.front().map(|buf| &buf[self.out_pos..])
    }

    /// Record `n` bytes written from [`Self::next_write`]. Completing a
    /// line frees a pending slot, which may unblock extraction — any new
    /// dispatches are returned.
    pub fn advance_write(&mut self, n: usize, now: Instant) -> Vec<Dispatch> {
        self.last_activity = now;
        self.out_pos += n;
        if self.outq.front().is_some_and(|buf| self.out_pos >= buf.len()) {
            self.outq.pop_front();
            self.out_pos = 0;
            return self.pump(now);
        }
        Vec::new()
    }

    /// Whether the connection should be torn down at `now`: peer gone or
    /// close requested (after the outbound queue drains), or idle-expired.
    pub fn should_close(&self, now: Instant) -> bool {
        ((self.close_after_drain || self.read_closed) && self.pending() == 0)
            || self.idle_expired(now)
    }

    /// `[service] idle_timeout_ms` check: a connection with nothing
    /// pending and no byte of activity for the window is reclaimed. Never
    /// fires while work is in flight, so a slow handler cannot trip it.
    pub fn idle_expired(&self, now: Instant) -> bool {
        match self.idle_timeout {
            Some(t) => {
                self.pending() == 0
                    && !self.close_after_drain
                    && !self.read_closed
                    && now.duration_since(self.last_activity) >= t
            }
            None => false,
        }
    }

    fn enqueue_response(&mut self, rid: Option<u64>, resp: &Response) {
        let mut line = resp.to_json_line_tagged(rid).into_bytes();
        line.push(b'\n');
        self.outq.push_back(line);
    }

    /// Extract frames while capacity allows, then top up the ordered lane.
    fn pump(&mut self, now: Instant) -> Vec<Dispatch> {
        let mut out = Vec::new();
        self.extract(now, &mut out);
        if !self.ordered_inflight {
            if let Some(req) = self.ordered.pop_front() {
                self.ordered_inflight = true;
                out.push(Dispatch { rid: None, req });
            }
        }
        out
    }

    fn extract(&mut self, now: Instant, out: &mut Vec<Dispatch>) {
        loop {
            if self.close_after_drain || self.pending() >= self.cap {
                return;
            }
            let raw = if let Some(off) = self.inbuf[self.scan_pos..]
                .iter()
                .position(|&b| b == b'\n')
            {
                let end = self.scan_pos + off;
                let mut raw: Vec<u8> = self.inbuf.drain(..=end).collect();
                raw.pop(); // the newline
                self.scan_pos = 0;
                raw
            } else {
                self.scan_pos = self.inbuf.len();
                if self.inbuf.len() > self.max_line {
                    self.inbuf.clear();
                    self.scan_pos = 0;
                    self.read_closed = true;
                    self.close_after_drain = true;
                    self.enqueue_response(
                        None,
                        &Response::Error {
                            message: format!(
                                "bad request: line exceeds {} byte limit",
                                self.max_line
                            ),
                        },
                    );
                    return;
                }
                if self.read_closed && !self.inbuf.is_empty() {
                    self.scan_pos = 0;
                    std::mem::take(&mut self.inbuf)
                } else {
                    return;
                }
            };
            let text = String::from_utf8_lossy(&raw);
            let line = text.trim();
            if line.is_empty() {
                continue; // blank keep-alives are free, as before
            }
            self.process_line(line, now, out);
        }
    }

    /// Admission, then parse, then lane routing for one wire line.
    fn process_line(&mut self, line: &str, now: Instant, out: &mut Vec<Dispatch>) {
        match self.limiter.admit_at(now) {
            Admit::Ok => {}
            Admit::Throttled => {
                Metrics::inc(&self.metrics.throttled);
                self.enqueue_response(
                    peek_rid(line),
                    &Response::Error {
                        message: THROTTLE_MSG.into(),
                    },
                );
                return;
            }
            Admit::BudgetExhausted => {
                Metrics::inc(&self.metrics.throttled);
                self.enqueue_response(
                    peek_rid(line),
                    &Response::Error {
                        message: BUDGET_MSG.into(),
                    },
                );
                self.close_after_drain = true;
                return;
            }
        }
        let (rid, parsed) = parse_tagged_request(line);
        match parsed {
            Ok(req) => match rid {
                Some(r) => {
                    Metrics::inc(&self.metrics.pipelined_requests);
                    self.tagged_inflight += 1;
                    out.push(Dispatch { rid: Some(r), req });
                }
                None => self.ordered.push_back(req),
            },
            Err(e) => self.enqueue_response(
                rid,
                &Response::Error {
                    message: format!("bad request: {e}"),
                },
            ),
        }
    }
}

/// What the server serves: anything mapping a request to a response.
/// [`Coordinator`] is the production handler; tests inject panicking or
/// recording handlers to drive the worker pool's containment paths.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: Request) -> Response;
}

impl Handler for Coordinator {
    fn handle(&self, req: Request) -> Response {
        Coordinator::handle(self, req)
    }
}

/// A completed request on its way back to the event loop.
struct Completion {
    conn: u64,
    rid: Option<u64>,
    resp: Response,
}

/// One live connection owned by the event loop.
struct Conn {
    id: u64,
    stream: TcpStream,
    state: ConnState,
}

/// Run the handler with panic containment: a panicking handler yields a
/// wire error on that one request while the worker, the pool, and every
/// other connection keep serving. (The coordinator never panics on
/// request paths; this guards injected handlers and future regressions.)
fn run_guarded(handler: &dyn Handler, req: Request) -> Response {
    match catch_unwind(AssertUnwindSafe(|| handler.handle(req))) {
        Ok(resp) => resp,
        Err(_) => Response::Error {
            message: "internal error: request handler panicked".into(),
        },
    }
}

/// The batchable subset: scheme-routed `sketch` (no ad-hoc spec),
/// `insert`, `delete`, `update`, `query`, and the doc ops (shingled here,
/// before enqueue). Everything else takes the direct worker path.
fn to_batch_op(req: Request) -> std::result::Result<(Option<String>, BatchOp), Request> {
    match req {
        Request::Sketch {
            set,
            spec: None,
            scheme,
        } => Ok((scheme, BatchOp::Sketch { set })),
        Request::LshInsert { id, set, scheme } => Ok((scheme, BatchOp::Insert { id, set })),
        Request::LshQuery { set, scheme } => Ok((scheme, BatchOp::Query { set })),
        Request::LshDelete { id, scheme } => Ok((scheme, BatchOp::Delete { id })),
        Request::LshUpdate { id, set, scheme } => Ok((scheme, BatchOp::Update { id, set })),
        // Doc ops shingle *before* enqueue, so they coalesce into the same
        // insert/query batches as raw-set ops. Tokenization is pure CPU on
        // the event-loop-adjacent path; the direct path uses the identical
        // `DOC_SHINGLE_W` shingler, keeping both lanes bit-identical (the
        // batching harness asserts this).
        Request::IndexDoc { id, text, scheme } => Ok((
            scheme,
            BatchOp::Insert {
                id,
                set: crate::data::shingle::byte_shingles(&text, crate::coordinator::service::DOC_SHINGLE_W),
            },
        )),
        Request::QueryDoc { text, scheme } => Ok((
            scheme,
            BatchOp::Query {
                set: crate::data::shingle::byte_shingles(&text, crate::coordinator::service::DOC_SHINGLE_W),
            },
        )),
        other => Err(other),
    }
}

fn from_batch_op(scheme: Option<String>, op: BatchOp) -> Request {
    match op {
        BatchOp::Sketch { set } => Request::Sketch {
            set,
            spec: None,
            scheme,
        },
        BatchOp::Insert { id, set } => Request::LshInsert { id, set, scheme },
        BatchOp::Query { set } => Request::LshQuery { set, scheme },
        BatchOp::Delete { id } => Request::LshDelete { id, scheme },
        BatchOp::Update { id, set } => Request::LshUpdate { id, set, scheme },
    }
}

/// Routes dispatches to the op batcher or the worker pool and owns the
/// return path. Dropping it (event-loop exit) drains the batcher.
struct Router {
    handler: Arc<dyn Handler>,
    batcher: Option<OpBatcher>,
    pool: Arc<ThreadPool>,
    metrics: Arc<Metrics>,
    done_tx: Sender<Completion>,
}

impl Router {
    fn dispatch_all(&self, conn: u64, dispatches: Vec<Dispatch>) {
        for d in dispatches {
            self.dispatch_one(conn, d);
        }
    }

    fn dispatch_one(&self, conn: u64, d: Dispatch) {
        let Dispatch { rid, req } = d;
        let req = if let Some(b) = &self.batcher {
            match to_batch_op(req) {
                Ok((scheme, op)) => {
                    let tx = self.done_tx.clone();
                    let job = OpJob {
                        scheme,
                        op,
                        done: Box::new(move |resp| {
                            let _ = tx.send(Completion { conn, rid, resp });
                        }),
                    };
                    match b.submit(job) {
                        Ok(()) => return,
                        Err(job) => {
                            // Queue full: shed to the direct path. The
                            // completion callback travels with the job, so
                            // the response still reaches the connection.
                            Metrics::inc(&self.metrics.op_shed);
                            let OpJob { scheme, op, done } = job;
                            let handler = Arc::clone(&self.handler);
                            self.pool.execute(move || {
                                done(run_guarded(&*handler, from_batch_op(scheme, op)));
                            });
                            return;
                        }
                    }
                }
                Err(req) => req,
            }
        } else {
            req
        };
        let handler = Arc::clone(&self.handler);
        let tx = self.done_tx.clone();
        self.pool.execute(move || {
            let resp = run_guarded(&*handler, req);
            let _ = tx.send(Completion { conn, rid, resp });
        });
    }
}

/// A running server: an accept thread, an event-loop thread, and a fixed
/// worker pool.
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_join: Option<JoinHandle<()>>,
    loop_join: Option<JoinHandle<()>>,
    /// Cumulative accepted connections (including capacity-rejected ones).
    connections: Arc<AtomicUsize>,
    /// Currently open connections (the `max_connections` gauge).
    live: Arc<AtomicUsize>,
    pool: Arc<ThreadPool>,
}

impl Server {
    /// Bind and serve `coordinator` on `listen` (use port 0 for an
    /// ephemeral port; the bound address is available via [`Server::addr`]).
    /// Wires the cross-connection [`OpBatcher`] when `[batcher] op_batch`
    /// is on (the default).
    pub fn start(coordinator: Arc<Coordinator>, listen: &str) -> Result<Server> {
        let cfg = coordinator.config().clone();
        let metrics = Arc::clone(&coordinator.metrics);
        let batcher = (cfg.op_batch > 0).then(|| {
            OpBatcher::spawn(
                Arc::clone(&coordinator) as Arc<dyn OpExecutor>,
                cfg.op_batch,
                cfg.op_max_delay_us,
                cfg.op_queue_cap,
                Arc::clone(&metrics),
            )
        });
        Self::start_inner(coordinator, batcher, cfg, metrics, listen)
    }

    /// Serve an arbitrary [`Handler`] — the concurrency harness injects
    /// panicking and recording handlers here. No op batcher: every
    /// request takes the direct worker path.
    pub fn start_with_handler(
        handler: Arc<dyn Handler>,
        cfg: CoordinatorConfig,
        listen: &str,
    ) -> Result<Server> {
        let metrics = Arc::new(Metrics::new());
        Self::start_inner(handler, None, cfg, metrics, listen)
    }

    fn start_inner(
        handler: Arc<dyn Handler>,
        batcher: Option<OpBatcher>,
        cfg: CoordinatorConfig,
        metrics: Arc<Metrics>,
        listen: &str,
    ) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicUsize::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(ThreadPool::new(cfg.request_workers.max(1)));
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let (done_tx, done_rx) = channel::<Completion>();
        let accept_join = {
            let stop = Arc::clone(&stop);
            let connections = Arc::clone(&connections);
            let live = Arc::clone(&live);
            let metrics = Arc::clone(&metrics);
            let max_conns = cfg.max_connections;
            std::thread::Builder::new()
                .name("mixtab-server".into())
                .spawn(move || {
                    accept_loop(listener, stop, connections, live, max_conns, metrics, conn_tx)
                })
                .expect("spawn server")
        };
        let loop_join = {
            let stop = Arc::clone(&stop);
            let live = Arc::clone(&live);
            let metrics = Arc::clone(&metrics);
            let router = Router {
                handler,
                batcher,
                pool: Arc::clone(&pool),
                metrics: Arc::clone(&metrics),
                done_tx,
            };
            std::thread::Builder::new()
                .name("mixtab-event-loop".into())
                .spawn(move || event_loop(conn_rx, done_rx, router, cfg, metrics, stop, live))
                .expect("spawn event loop")
        };
        Ok(Server {
            addr,
            stop,
            accept_join: Some(accept_join),
            loop_join: Some(loop_join),
            connections,
            live,
            pool,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Cumulative accepted connections over the server's lifetime.
    pub fn connection_count(&self) -> usize {
        self.connections.load(Ordering::Relaxed)
    }

    /// Currently open connections.
    pub fn live_connections(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Requests handed to the worker pool and not yet completed. Tests
    /// and shutdown paths use this to observe draining without sleeping.
    pub fn requests_in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    /// Request shutdown and join the accept and event-loop threads. The
    /// op batcher drains (every accepted op executes) and the worker pool
    /// joins when the last reference drops.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
        if let Some(j) = self.loop_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicUsize>,
    live: Arc<AtomicUsize>,
    max_conns: usize,
    metrics: Arc<Metrics>,
    conn_tx: Sender<TcpStream>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                connections.fetch_add(1, Ordering::Relaxed);
                let admitted = {
                    let prev = live.fetch_add(1, Ordering::SeqCst);
                    if max_conns > 0 && prev >= max_conns {
                        live.fetch_sub(1, Ordering::SeqCst);
                        false
                    } else {
                        true
                    }
                };
                if !admitted {
                    // Shed cleanly: one error line, then close — the
                    // client sees a parseable rejection, not a hang.
                    Metrics::inc(&metrics.conns_rejected);
                    let mut s = stream;
                    s.set_nonblocking(false).ok();
                    let line = Response::Error {
                        message: CAPACITY_MSG.into(),
                    }
                    .to_json_line();
                    let _ = s.write_all(line.as_bytes());
                    let _ = s.write_all(b"\n");
                    continue;
                }
                if conn_tx.send(stream).is_err() {
                    return; // event loop gone
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn event_loop(
    conn_rx: Receiver<TcpStream>,
    done_rx: Receiver<Completion>,
    router: Router,
    cfg: CoordinatorConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    live: Arc<AtomicUsize>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut next_id: u64 = 0;
    let mut read_buf = [0u8; 8192];
    // A completion picked up by the idle wait, handled next iteration.
    let mut carry: Option<Completion> = None;
    while !stop.load(Ordering::SeqCst) {
        let mut progress = false;

        // New connections from the accept thread.
        while let Ok(stream) = conn_rx.try_recv() {
            stream.set_nonblocking(true).ok();
            stream.set_nodelay(true).ok();
            conns.push(Conn {
                id: next_id,
                stream,
                state: ConnState::new(&cfg, Arc::clone(&metrics), Instant::now()),
            });
            next_id += 1;
            progress = true;
        }

        // Completed requests from the workers / batcher.
        while let Some(done) = carry.take().or_else(|| done_rx.try_recv().ok()) {
            progress = true;
            if let Some(conn) = conns.iter_mut().find(|c| c.id == done.conn) {
                let ds = conn.state.on_response(done.rid, &done.resp, Instant::now());
                router.dispatch_all(conn.id, ds);
            }
            // else: connection died with requests in flight — drop it.
        }

        // Socket IO, round-robin.
        let mut i = 0;
        while i < conns.len() {
            let mut dead = false;
            let conn = &mut conns[i];
            while conn.state.wants_read() {
                match conn.stream.read(&mut read_buf) {
                    Ok(0) => {
                        progress = true;
                        let ds = conn.state.on_eof(Instant::now());
                        router.dispatch_all(conn.id, ds);
                        break;
                    }
                    Ok(n) => {
                        progress = true;
                        let ds = conn.state.on_bytes(&read_buf[..n], Instant::now());
                        router.dispatch_all(conn.id, ds);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            while !dead {
                let Some(chunk) = conn.state.next_write() else {
                    break;
                };
                match conn.stream.write(chunk) {
                    Ok(0) => {
                        dead = true;
                    }
                    Ok(n) => {
                        progress = true;
                        let ds = conn.state.advance_write(n, Instant::now());
                        router.dispatch_all(conn.id, ds);
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        dead = true;
                    }
                }
            }
            let now = Instant::now();
            if dead || conn.state.should_close(now) {
                if !dead && conn.state.idle_expired(now) {
                    Metrics::inc(&metrics.idle_closed);
                }
                conns.swap_remove(i);
                live.fetch_sub(1, Ordering::SeqCst);
                progress = true;
            } else {
                i += 1;
            }
        }

        if !progress {
            // Nothing to do: park briefly on the completion channel so a
            // finishing worker wakes us immediately instead of after a
            // fixed sleep.
            if let Ok(done) = done_rx.recv_timeout(Duration::from_millis(1)) {
                carry = Some(done);
            }
        }
    }
    // Dropping the router drains the op batcher (accepted ops still
    // execute); completions to the dropped receiver are ignored.
    drop(router);
    drop(done_rx);
}

/// Minimal blocking client for tests, benches and examples. Speaks the
/// untagged (ordered-lane) protocol: one request, one in-order response.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request, wait for its response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.writer.write_all(req.to_json_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            crate::bail!("connection closed by server");
        }
        Response::from_json_line(line.trim_end())
    }
}

/// Client speaking the pipelined protocol: requests are tagged with an
/// auto-incrementing `rid` and sent without waiting; responses are
/// collected in whatever order the server returns them, each carrying
/// the tag of the request it answers.
pub struct PipelinedClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_rid: u64,
    read_timeout: Option<Duration>,
}

impl PipelinedClient {
    pub fn connect(addr: std::net::SocketAddr) -> Result<PipelinedClient> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(PipelinedClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            next_rid: 0,
            read_timeout: None,
        })
    }

    /// Connect with a read deadline already applied (see
    /// [`Self::set_read_timeout`]).
    pub fn connect_with_timeout(
        addr: std::net::SocketAddr,
        timeout: Option<Duration>,
    ) -> Result<PipelinedClient> {
        let mut client = Self::connect(addr)?;
        client.set_read_timeout(timeout)?;
        Ok(client)
    }

    /// Bound how long [`Self::recv`] waits for a response line (`None` =
    /// block forever, the default). On expiry `recv` returns an error that
    /// [`is_timeout`] classifies — a hung backend becomes a clean, typed
    /// failure instead of a caller blocked forever. A timed-out connection
    /// may hold a partial response line and MUST be dropped, not reused.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader
            .get_ref()
            .set_read_timeout(timeout)
            .context("set read timeout")?;
        self.read_timeout = timeout;
        Ok(())
    }

    /// Queue one tagged request (buffered; flushed by [`Self::recv`] or
    /// [`Self::flush`]); returns the rid assigned.
    pub fn send(&mut self, req: &Request) -> Result<u64> {
        let rid = self.next_rid;
        self.next_rid += 1;
        self.send_with_rid(req, rid)?;
        Ok(rid)
    }

    /// Queue one request under an explicit rid (rid reuse is the
    /// client's own problem — the server just echoes it).
    pub fn send_with_rid(&mut self, req: &Request, rid: u64) -> Result<()> {
        self.writer
            .write_all(req.to_json_line_tagged(rid).as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush().context("flush")
    }

    /// Receive the next response in server order: `(rid, response)`.
    /// `rid` is `None` only for errors the server could not attribute to
    /// a tagged request (e.g. a throttled line with an invalid tag).
    pub fn recv(&mut self) -> Result<(Option<u64>, Response)> {
        self.flush()?;
        let mut line = String::new();
        let n = match self.reader.read_line(&mut line) {
            Ok(n) => n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                let waited = self
                    .read_timeout
                    .map(|d| format!("{}ms", d.as_millis()))
                    .unwrap_or_else(|| "deadline".into());
                return Err(crate::util::error::Error::new(e)
                    .context(format!("read timeout: no response within {waited}")));
            }
            Err(e) => return Err(crate::util::error::Error::new(e).context("read response")),
        };
        if n == 0 {
            crate::bail!("connection closed by server");
        }
        Response::from_json_line_tagged(line.trim_end())
    }
}

/// True when `err` is a read-deadline expiry from
/// [`PipelinedClient::recv`] (a configured timeout fired), as opposed to
/// a closed connection or a protocol error. The health tracker uses this
/// to tell "peer is hung" from "peer refused us".
pub fn is_timeout(err: &crate::util::error::Error) -> bool {
    err.chain().any(|cause| {
        cause
            .downcast_ref::<std::io::Error>()
            .is_some_and(|io| matches!(io.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::CoordinatorConfig;
    use crate::coordinator::request::ExecPath;
    use std::collections::HashMap;

    fn native_coordinator() -> Arc<Coordinator> {
        Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt: false,
            fh_dim: 16,
            oph_k: 20,
            ..Default::default()
        }))
    }

    #[test]
    fn serves_requests_over_tcp() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Request::FhTransform {
                indices: vec![1, 2],
                values: vec![1.0, -1.0],
            })
            .unwrap();
        let Response::Fh { out, path, .. } = resp else {
            panic!("wrong response");
        };
        assert_eq!(out.len(), 16);
        assert_eq!(path, ExecPath::Native);
        // Second request on the same connection.
        let resp = client.call(&Request::Stats).unwrap();
        assert!(matches!(resp, Response::Stats { .. }));
        assert_eq!(server.connection_count(), 1);
        server.stop();
    }

    #[test]
    fn bad_line_yields_error_response() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        w.write_all(b"this is not json\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Response::from_json_line(line.trim_end()).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }

    #[test]
    fn conn_limiter_token_bucket_and_budget() {
        let t0 = Instant::now();
        // Bucket of 2, 1 token/s, no budget.
        let cfg = CoordinatorConfig {
            rate_limit_rps: 1.0,
            rate_limit_burst: 2,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        assert_eq!(lim.admit_at(t0), Admit::Ok);
        assert_eq!(lim.admit_at(t0), Admit::Ok);
        assert_eq!(lim.admit_at(t0), Admit::Throttled, "burst spent");
        // Refill after one second buys exactly one more.
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(lim.admit_at(t1), Admit::Ok);
        assert_eq!(lim.admit_at(t1), Admit::Throttled);
        // Refill never exceeds capacity.
        let t9 = t0 + Duration::from_secs(9);
        assert_eq!(lim.admit_at(t9), Admit::Ok);
        assert_eq!(lim.admit_at(t9), Admit::Ok);
        assert_eq!(lim.admit_at(t9), Admit::Throttled);

        // Hard budget, no rate limit: N requests then close.
        let cfg = CoordinatorConfig {
            conn_request_budget: 3,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        for _ in 0..3 {
            assert_eq!(lim.admit_at(t0), Admit::Ok);
        }
        assert_eq!(lim.admit_at(t0), Admit::BudgetExhausted);

        // Both knobs: throttled requests do NOT consume the budget — only
        // admitted ones do, so a rate-limited client is told to back off
        // without its connection lifetime being burned by the rejections.
        let cfg = CoordinatorConfig {
            rate_limit_rps: 1.0,
            rate_limit_burst: 1,
            conn_request_budget: 2,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        assert_eq!(lim.admit_at(t0), Admit::Ok); // budget 2 -> 1
        for _ in 0..10 {
            assert_eq!(lim.admit_at(t0), Admit::Throttled); // budget untouched
        }
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(lim.admit_at(t1), Admit::Ok); // budget 1 -> 0
        assert_eq!(lim.admit_at(t1), Admit::BudgetExhausted);

        // Both knobs off: everything admitted.
        let mut lim = ConnLimiter::new(&CoordinatorConfig::default(), t0);
        for _ in 0..1000 {
            assert_eq!(lim.admit_at(t0), Admit::Ok);
        }
    }

    #[test]
    fn multiple_clients() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let resp = c
                        .call(&Request::OphSketch {
                            set: (i * 10..i * 10 + 50).collect(),
                        })
                        .unwrap();
                    matches!(resp, Response::Sketch { .. })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap());
        }
        server.stop();
    }

    #[test]
    fn pipelined_requests_over_tcp() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let mut c = PipelinedClient::connect(server.addr()).unwrap();
        // Fire a burst of tagged requests without waiting, then collect.
        let mut rids = Vec::new();
        for i in 0..8u32 {
            rids.push(
                c.send(&Request::Sketch {
                    set: (i * 5..i * 5 + 30).collect(),
                    spec: None,
                    scheme: None,
                })
                .unwrap(),
            );
        }
        let mut got: HashMap<u64, Response> = HashMap::new();
        for _ in 0..8 {
            let (rid, resp) = c.recv().unwrap();
            got.insert(rid.expect("tagged response"), resp);
        }
        for rid in rids {
            assert!(
                matches!(got.get(&rid), Some(Response::SketchValue { .. })),
                "rid {rid} answered"
            );
        }
        server.stop();
    }

    #[test]
    fn panicking_handler_yields_wire_error_and_server_survives() {
        struct Panicky;
        impl Handler for Panicky {
            fn handle(&self, req: Request) -> Response {
                match req {
                    Request::Stats => Response::Error {
                        message: "ok".into(),
                    },
                    _ => panic!("injected handler panic"),
                }
            }
        }
        let cfg = CoordinatorConfig::default();
        let server = Server::start_with_handler(Arc::new(Panicky), cfg, "127.0.0.1:0").unwrap();
        let mut c = Client::connect(server.addr()).unwrap();
        let resp = c
            .call(&Request::OphSketch { set: vec![1, 2, 3] })
            .unwrap();
        let Response::Error { message } = resp else {
            panic!("expected error");
        };
        assert!(message.contains("panicked"), "got: {message}");
        // Same connection and pool keep serving after the panic.
        let resp = c.call(&Request::Stats).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }
}
