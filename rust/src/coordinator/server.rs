//! TCP front-end: newline-delimited JSON over a plain socket.
//!
//! One request per line, one response per line, connection-per-thread
//! (bounded by a worker pool). This is deliberately simple — the protocol
//! exists so the examples and benches can exercise the full service stack
//! end-to-end, not to compete with gRPC.

use crate::coordinator::request::{Request, Response};
use crate::coordinator::service::Coordinator;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// A running server (owns the listener thread).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    connections: Arc<AtomicUsize>,
}

impl Server {
    /// Bind and serve `coordinator` on `cfg.listen` (use port 0 for an
    /// ephemeral port; the bound address is available via [`Server::addr`]).
    pub fn start(coordinator: Arc<Coordinator>, listen: &str) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicUsize::new(0));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&connections);
        let join = std::thread::Builder::new()
            .name("mixtab-server".into())
            .spawn(move || accept_loop(listener, coordinator, stop2, conns2))
            .expect("spawn server");
        Ok(Server {
            addr,
            stop,
            join: Some(join),
            connections,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn connection_count(&self) -> usize {
        self.connections.load(Ordering::Relaxed)
    }

    /// Request shutdown and join the accept thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicUsize>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                connections.fetch_add(1, Ordering::Relaxed);
                let c = Arc::clone(&coordinator);
                let _ = std::thread::Builder::new()
                    .name("mixtab-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, &c);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn serve_connection(stream: TcpStream, coordinator: &Coordinator) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = match Request::from_json_line(&line) {
            Ok(req) => coordinator.handle(req),
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
        };
        writer.write_all(resp.to_json_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request, wait for its response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.writer.write_all(req.to_json_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Response::from_json_line(line.trim_end())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::CoordinatorConfig;
    use crate::coordinator::request::ExecPath;

    fn native_coordinator() -> Arc<Coordinator> {
        Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt: false,
            fh_dim: 16,
            oph_k: 20,
            ..Default::default()
        }))
    }

    #[test]
    fn serves_requests_over_tcp() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Request::FhTransform {
                indices: vec![1, 2],
                values: vec![1.0, -1.0],
            })
            .unwrap();
        let Response::Fh { out, path, .. } = resp else {
            panic!("wrong response");
        };
        assert_eq!(out.len(), 16);
        assert_eq!(path, ExecPath::Native);
        // Second request on the same connection.
        let resp = client.call(&Request::Stats).unwrap();
        assert!(matches!(resp, Response::Stats { .. }));
        assert_eq!(server.connection_count(), 1);
        server.stop();
    }

    #[test]
    fn bad_line_yields_error_response() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        w.write_all(b"this is not json\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Response::from_json_line(line.trim_end()).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }

    #[test]
    fn multiple_clients() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let resp = c
                        .call(&Request::OphSketch {
                            set: (i * 10..i * 10 + 50).collect(),
                        })
                        .unwrap();
                    matches!(resp, Response::Sketch { .. })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap());
        }
        server.stop();
    }
}
