//! TCP front-end: newline-delimited JSON over a plain socket.
//!
//! One request per line, one response per line, connection-per-thread
//! (bounded by a worker pool). This is deliberately simple — the protocol
//! exists so the examples and benches can exercise the full service stack
//! end-to-end, not to compete with gRPC.
//!
//! **Throttling lives here**, per connection — not in spec validation.
//! Spec parsing caps what one request can allocate, but only the
//! connection layer can bound how *often* a client pays that cost, so each
//! connection carries a token bucket (`[limits] requests_per_sec`/`burst`)
//! and an optional hard request budget (`max_requests_per_conn`).
//! Over-rate requests get an `Error` response (the connection stays up —
//! the client is told to back off); an exhausted budget closes the
//! connection after one final error. Both count into the `throttled`
//! metric. One connection's bucket never affects another's.

use crate::coordinator::config::CoordinatorConfig;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::service::Coordinator;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Admission verdict for one request on one connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Admit {
    /// Serve it.
    Ok,
    /// Token bucket empty: reject this request, keep the connection.
    Throttled,
    /// Hard budget spent: reject and close the connection.
    BudgetExhausted,
}

/// Per-connection rate limiter: a continuous-refill token bucket plus an
/// optional lifetime request budget. Owned by the connection thread — no
/// cross-connection state, so one noisy client cannot starve another.
struct ConnLimiter {
    /// Tokens/second; `None` when rate limiting is off.
    rate: Option<f64>,
    capacity: f64,
    tokens: f64,
    last_refill: Instant,
    /// Remaining request budget; `None` when unlimited.
    budget: Option<u64>,
}

impl ConnLimiter {
    fn new(cfg: &CoordinatorConfig, now: Instant) -> Self {
        let capacity = cfg.effective_burst() as f64;
        Self {
            rate: (cfg.rate_limit_rps > 0.0).then_some(cfg.rate_limit_rps),
            capacity,
            tokens: capacity,
            last_refill: now,
            budget: (cfg.conn_request_budget > 0).then_some(cfg.conn_request_budget),
        }
    }

    /// Admission decision at time `now` (injected for deterministic tests).
    /// Only *admitted* requests consume the budget — a throttled request
    /// is the server's own rejection, and charging it would let the rate
    /// limiter silently convert "back off" into "connection closed".
    fn admit_at(&mut self, now: Instant) -> Admit {
        if self.budget == Some(0) {
            return Admit::BudgetExhausted;
        }
        if let Some(rate) = self.rate {
            let elapsed = now.duration_since(self.last_refill).as_secs_f64();
            self.last_refill = now;
            self.tokens = (self.tokens + elapsed * rate).min(self.capacity);
            if self.tokens < 1.0 {
                return Admit::Throttled;
            }
            self.tokens -= 1.0;
        }
        if let Some(n) = &mut self.budget {
            *n -= 1;
        }
        Admit::Ok
    }

    fn admit(&mut self) -> Admit {
        self.admit_at(Instant::now())
    }
}

/// A running server (owns the listener thread).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    connections: Arc<AtomicUsize>,
}

impl Server {
    /// Bind and serve `coordinator` on `cfg.listen` (use port 0 for an
    /// ephemeral port; the bound address is available via [`Server::addr`]).
    pub fn start(coordinator: Arc<Coordinator>, listen: &str) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let connections = Arc::new(AtomicUsize::new(0));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&connections);
        let join = std::thread::Builder::new()
            .name("mixtab-server".into())
            .spawn(move || accept_loop(listener, coordinator, stop2, conns2))
            .expect("spawn server");
        Ok(Server {
            addr,
            stop,
            join: Some(join),
            connections,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn connection_count(&self) -> usize {
        self.connections.load(Ordering::Relaxed)
    }

    /// Request shutdown and join the accept thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    connections: Arc<AtomicUsize>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                connections.fetch_add(1, Ordering::Relaxed);
                let c = Arc::clone(&coordinator);
                let _ = std::thread::Builder::new()
                    .name("mixtab-conn".into())
                    .spawn(move || {
                        let _ = serve_connection(stream, &c);
                    });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn serve_connection(stream: TcpStream, coordinator: &Coordinator) -> Result<()> {
    stream.set_nodelay(true).ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut limiter = ConnLimiter::new(coordinator.config(), Instant::now());
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut close_after = false;
        let resp = match limiter.admit() {
            Admit::Ok => match Request::from_json_line(&line) {
                Ok(req) => coordinator.handle(req),
                Err(e) => Response::Error {
                    message: format!("bad request: {e}"),
                },
            },
            Admit::Throttled => {
                Metrics::inc(&coordinator.metrics.throttled);
                Response::Error {
                    message: "rate limited: per-connection request rate exceeded".into(),
                }
            }
            Admit::BudgetExhausted => {
                Metrics::inc(&coordinator.metrics.throttled);
                close_after = true;
                Response::Error {
                    message: "request budget exhausted: connection closing".into(),
                }
            }
        };
        writer.write_all(resp.to_json_line().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if close_after {
            break;
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, benches and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    /// Send one request, wait for its response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.writer.write_all(req.to_json_line().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Response::from_json_line(line.trim_end())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::CoordinatorConfig;
    use crate::coordinator::request::ExecPath;

    fn native_coordinator() -> Arc<Coordinator> {
        Arc::new(Coordinator::new(CoordinatorConfig {
            enable_pjrt: false,
            fh_dim: 16,
            oph_k: 20,
            ..Default::default()
        }))
    }

    #[test]
    fn serves_requests_over_tcp() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let mut client = Client::connect(server.addr()).unwrap();
        let resp = client
            .call(&Request::FhTransform {
                indices: vec![1, 2],
                values: vec![1.0, -1.0],
            })
            .unwrap();
        let Response::Fh { out, path, .. } = resp else {
            panic!("wrong response");
        };
        assert_eq!(out.len(), 16);
        assert_eq!(path, ExecPath::Native);
        // Second request on the same connection.
        let resp = client.call(&Request::Stats).unwrap();
        assert!(matches!(resp, Response::Stats { .. }));
        assert_eq!(server.connection_count(), 1);
        server.stop();
    }

    #[test]
    fn bad_line_yields_error_response() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        w.write_all(b"this is not json\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let resp = Response::from_json_line(line.trim_end()).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        server.stop();
    }

    #[test]
    fn conn_limiter_token_bucket_and_budget() {
        use std::time::Duration;
        let t0 = Instant::now();
        // Bucket of 2, 1 token/s, no budget.
        let cfg = CoordinatorConfig {
            rate_limit_rps: 1.0,
            rate_limit_burst: 2,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        assert_eq!(lim.admit_at(t0), Admit::Ok);
        assert_eq!(lim.admit_at(t0), Admit::Ok);
        assert_eq!(lim.admit_at(t0), Admit::Throttled, "burst spent");
        // Refill after one second buys exactly one more.
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(lim.admit_at(t1), Admit::Ok);
        assert_eq!(lim.admit_at(t1), Admit::Throttled);
        // Refill never exceeds capacity.
        let t9 = t0 + Duration::from_secs(9);
        assert_eq!(lim.admit_at(t9), Admit::Ok);
        assert_eq!(lim.admit_at(t9), Admit::Ok);
        assert_eq!(lim.admit_at(t9), Admit::Throttled);

        // Hard budget, no rate limit: N requests then close.
        let cfg = CoordinatorConfig {
            conn_request_budget: 3,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        for _ in 0..3 {
            assert_eq!(lim.admit_at(t0), Admit::Ok);
        }
        assert_eq!(lim.admit_at(t0), Admit::BudgetExhausted);

        // Both knobs: throttled requests do NOT consume the budget — only
        // admitted ones do, so a rate-limited client is told to back off
        // without its connection lifetime being burned by the rejections.
        let cfg = CoordinatorConfig {
            rate_limit_rps: 1.0,
            rate_limit_burst: 1,
            conn_request_budget: 2,
            ..Default::default()
        };
        let mut lim = ConnLimiter::new(&cfg, t0);
        assert_eq!(lim.admit_at(t0), Admit::Ok); // budget 2 -> 1
        for _ in 0..10 {
            assert_eq!(lim.admit_at(t0), Admit::Throttled); // budget untouched
        }
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(lim.admit_at(t1), Admit::Ok); // budget 1 -> 0
        assert_eq!(lim.admit_at(t1), Admit::BudgetExhausted);

        // Both knobs off: everything admitted.
        let mut lim = ConnLimiter::new(&CoordinatorConfig::default(), t0);
        for _ in 0..1000 {
            assert_eq!(lim.admit_at(t0), Admit::Ok);
        }
    }

    #[test]
    fn multiple_clients() {
        let server = Server::start(native_coordinator(), "127.0.0.1:0").unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let resp = c
                        .call(&Request::OphSketch {
                            set: (i * 10..i * 10 + 50).collect(),
                        })
                        .unwrap();
                    matches!(resp, Response::Sketch { .. })
                })
            })
            .collect();
        for h in handles {
            assert!(h.join().unwrap());
        }
        server.stop();
    }
}
