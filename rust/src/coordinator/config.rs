//! Coordinator configuration.
//!
//! Loaded from a TOML-subset file (see [`crate::util::config`]); every field
//! has a default so `CoordinatorConfig::default()` runs out of the box.
//!
//! ```toml
//! [service]
//! listen = "127.0.0.1:7878"
//! workers = 2
//!
//! [fh]
//! dim = 128
//! hash = "mixed_tab"
//! sign = "paired"
//! seed = 42
//!
//! [oph]
//! k = 200
//!
//! # Default sketch spec for the scheme-aware `sketch` endpoint. When the
//! # section is omitted, an OPH spec is derived from [fh]/[oph] above
//! # (hasher seed `[fh] seed ^ OPH_SEED_SALT`), so existing configs keep
//! # their exact pre-spec behaviour; setting a spec replaces that
//! # derivation, and stored sketches only stay comparable if it matches.
//! [sketch]
//! spec = "minhash(k=128,hash=mixed_tab,seed=7)"
//!
//! [lsh]
//! k = 10
//! l = 10
//!
//! [batcher]
//! enable_pjrt = true
//! max_delay_us = 200
//! queue_cap = 256
//! artifacts_dir = "artifacts"
//! ```

use crate::hash::HashFamily;
use crate::sketch::feature_hash::SignMode;
use crate::sketch::spec::{SketchScheme, SketchSpec};
use crate::util::config::Config;
use crate::util::error::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Seed salt separating the OPH sketcher's hash stream from the FH stream
/// (pre-spec behaviour, kept bit-identical).
pub const OPH_SEED_SALT: u64 = 0x09EB_57A1;

/// Seed salt for the LSH index's sketcher.
pub const LSH_SEED_SALT: u64 = 0x154A_11CE;

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// TCP listen address for the server front-end.
    pub listen: String,
    /// Sketch worker threads.
    pub workers: usize,
    /// FH output dimension d'.
    pub fh_dim: usize,
    /// Basic hash family for every sketch (the paper's variable).
    pub family: HashFamily,
    /// FH sign derivation.
    pub sign: SignMode,
    /// Root seed.
    pub seed: u64,
    /// OPH sketch size.
    pub oph_k: usize,
    /// Default spec for the scheme-aware `sketch` endpoint. `None` derives
    /// an OPH spec from `(family, seed, oph_k)` — see [`Self::sketch_spec`].
    pub sketch: Option<SketchSpec>,
    /// LSH parameters.
    pub lsh_k: usize,
    pub lsh_l: usize,
    /// Use the PJRT runtime when artifacts are present.
    pub enable_pjrt: bool,
    /// Batch window: how long the batcher waits to fill a batch.
    pub max_delay_us: u64,
    /// Bounded batcher queue; overflow sheds to the native path.
    pub queue_cap: usize,
    /// Where `manifest.json` lives.
    pub artifacts_dir: PathBuf,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            workers: 2,
            fh_dim: 128,
            family: HashFamily::MixedTab,
            sign: SignMode::Paired,
            seed: 42,
            oph_k: 200,
            sketch: None,
            lsh_k: 10,
            lsh_l: 10,
            enable_pjrt: true,
            max_delay_us: 200,
            queue_cap: 256,
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl CoordinatorConfig {
    /// Parse from config text.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = Self::default();
        let family_id = cfg.str_or("fh", "hash", HashFamily::MixedTab.id());
        let Some(family) = HashFamily::parse(&family_id) else {
            bail!("unknown hash family '{family_id}'");
        };
        let Some(sign) = SignMode::parse(&cfg.str_or("fh", "sign", "paired")) else {
            bail!("unknown sign mode '{}'", cfg.str_or("fh", "sign", "paired"));
        };
        let mut oph_k = cfg.usize_or("oph", "k", d.oph_k);
        let sketch = match cfg.get("sketch", "spec") {
            Some(value) => {
                // A mistyped value must not silently fall back to the
                // derived OPH default.
                let Some(text) = value.as_str() else {
                    bail!("[sketch] spec must be a string, got {value:?}");
                };
                let spec = SketchSpec::parse(text).context("[sketch] spec")?;
                // Keep the OPH-dependent paths (PJRT artifact lookup,
                // estimate endpoint) aligned with an OPH default spec.
                if let SketchScheme::Oph(p) = spec.scheme {
                    oph_k = p.k;
                }
                Some(spec)
            }
            None => None,
        };
        Ok(Self {
            listen: cfg.str_or("service", "listen", &d.listen),
            workers: cfg.usize_or("service", "workers", d.workers),
            fh_dim: cfg.usize_or("fh", "dim", d.fh_dim),
            family,
            sign,
            seed: cfg.i64_or("fh", "seed", d.seed as i64) as u64,
            oph_k,
            sketch,
            lsh_k: cfg.usize_or("lsh", "k", d.lsh_k),
            lsh_l: cfg.usize_or("lsh", "l", d.lsh_l),
            enable_pjrt: cfg.bool_or("batcher", "enable_pjrt", d.enable_pjrt),
            max_delay_us: cfg.i64_or("batcher", "max_delay_us", d.max_delay_us as i64) as u64,
            queue_cap: cfg.usize_or("batcher", "queue_cap", d.queue_cap),
            artifacts_dir: PathBuf::from(cfg.str_or(
                "batcher",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_config(&Config::load(path)?)
    }

    /// The spec served by the scheme-aware `sketch` endpoint: the `[sketch]`
    /// section when present, else the derived OPH default (bit-identical to
    /// the pre-spec coordinator's OPH sketcher).
    pub fn sketch_spec(&self) -> SketchSpec {
        self.sketch
            .unwrap_or_else(|| SketchSpec::oph(self.family, self.seed ^ OPH_SEED_SALT, self.oph_k))
    }

    /// The OPH spec backing the `oph` compatibility endpoint, the
    /// `estimate` endpoint, and the PJRT OPH batch path. Equals
    /// [`Self::sketch_spec`] when that is an OPH spec, else the derived
    /// default.
    pub fn oph_spec(&self) -> SketchSpec {
        let spec = self.sketch_spec();
        if matches!(spec.scheme, SketchScheme::Oph(_)) {
            spec
        } else {
            SketchSpec::oph(self.family, self.seed ^ OPH_SEED_SALT, self.oph_k)
        }
    }

    /// The FH transform spec (the `fh` endpoint and the PJRT plan path).
    pub fn fh_spec(&self) -> SketchSpec {
        SketchSpec::feature_hash(self.family, self.seed, self.fh_dim, self.sign)
    }

    /// The LSH index's sketch spec (bin count is overridden by the index's
    /// structural parameters).
    pub fn lsh_spec(&self) -> SketchSpec {
        SketchSpec::oph(
            self.family,
            self.seed ^ LSH_SEED_SALT,
            self.lsh_k * self.lsh_l,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.fh_dim, 128);
        assert_eq!(c.family, HashFamily::MixedTab);
        assert!(c.enable_pjrt);
        // Derived specs track the scalar fields.
        assert_eq!(
            c.sketch_spec(),
            SketchSpec::oph(HashFamily::MixedTab, 42 ^ OPH_SEED_SALT, 200)
        );
        assert_eq!(c.oph_spec(), c.sketch_spec());
        assert_eq!(
            c.fh_spec(),
            SketchSpec::feature_hash(HashFamily::MixedTab, 42, 128, SignMode::Paired)
        );
    }

    #[test]
    fn parses_overrides() {
        let cfg = Config::parse(
            "[fh]\ndim = 64\nhash = \"murmur3\"\nsign = \"separate\"\n[batcher]\nenable_pjrt = false\n[lsh]\nk = 8\nl = 12\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.fh_dim, 64);
        assert_eq!(c.family, HashFamily::Murmur3);
        assert_eq!(c.sign, SignMode::Separate);
        assert!(!c.enable_pjrt);
        assert_eq!((c.lsh_k, c.lsh_l), (8, 12));
        // No [sketch] section: the derived spec follows the [fh] family.
        assert_eq!(c.sketch_spec().family, HashFamily::Murmur3);
    }

    #[test]
    fn parses_sketch_spec_section() {
        let cfg = Config::parse(
            "[sketch]\nspec = \"minhash(k=32,hash=murmur3,seed=5)\"\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(
            c.sketch_spec(),
            SketchSpec::minhash(HashFamily::Murmur3, 5, 32)
        );
        // Non-OPH default spec: the OPH paths fall back to the derived spec.
        assert_eq!(c.oph_spec().scheme_id(), "oph");

        // An OPH spec keeps oph_k (and thus PJRT artifact lookup) in sync.
        let cfg = Config::parse("[sketch]\nspec = \"oph(k=64,seed=9)\"\n").unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.oph_k, 64);
        assert_eq!(c.oph_spec(), SketchSpec::oph(HashFamily::MixedTab, 9, 64));
    }

    #[test]
    fn rejects_bad_family() {
        let cfg = Config::parse("[fh]\nhash = \"md5\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn rejects_bad_sketch_spec() {
        let cfg = Config::parse("[sketch]\nspec = \"oph(k=nope)\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
        // Mistyped (non-string) spec errors instead of silently serving
        // the derived default.
        let cfg = Config::parse("[sketch]\nspec = 42\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }
}
