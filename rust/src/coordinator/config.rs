//! Coordinator configuration.
//!
//! Loaded from a TOML-subset file (see [`crate::util::config`]); every field
//! has a default so `CoordinatorConfig::default()` runs out of the box.
//!
//! ```toml
//! [service]
//! listen = "127.0.0.1:7878"
//! workers = 2
//!
//! [fh]
//! dim = 128
//! hash = "mixed_tab"
//! sign = "paired"
//! seed = 42
//!
//! [oph]
//! k = 200
//!
//! [lsh]
//! k = 10
//! l = 10
//!
//! [batcher]
//! enable_pjrt = true
//! max_delay_us = 200
//! queue_cap = 256
//! artifacts_dir = "artifacts"
//! ```

use crate::hash::HashFamily;
use crate::sketch::feature_hash::SignMode;
use crate::util::config::Config;
use crate::util::error::{bail, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// TCP listen address for the server front-end.
    pub listen: String,
    /// Sketch worker threads.
    pub workers: usize,
    /// FH output dimension d'.
    pub fh_dim: usize,
    /// Basic hash family for every sketch (the paper's variable).
    pub family: HashFamily,
    /// FH sign derivation.
    pub sign: SignMode,
    /// Root seed.
    pub seed: u64,
    /// OPH sketch size.
    pub oph_k: usize,
    /// LSH parameters.
    pub lsh_k: usize,
    pub lsh_l: usize,
    /// Use the PJRT runtime when artifacts are present.
    pub enable_pjrt: bool,
    /// Batch window: how long the batcher waits to fill a batch.
    pub max_delay_us: u64,
    /// Bounded batcher queue; overflow sheds to the native path.
    pub queue_cap: usize,
    /// Where `manifest.json` lives.
    pub artifacts_dir: PathBuf,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            workers: 2,
            fh_dim: 128,
            family: HashFamily::MixedTab,
            sign: SignMode::Paired,
            seed: 42,
            oph_k: 200,
            lsh_k: 10,
            lsh_l: 10,
            enable_pjrt: true,
            max_delay_us: 200,
            queue_cap: 256,
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl CoordinatorConfig {
    /// Parse from config text.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = Self::default();
        let family_id = cfg.str_or("fh", "hash", HashFamily::MixedTab.id());
        let Some(family) = HashFamily::parse(&family_id) else {
            bail!("unknown hash family '{family_id}'");
        };
        let sign = match cfg.str_or("fh", "sign", "paired").as_str() {
            "paired" => SignMode::Paired,
            "separate" => SignMode::Separate,
            other => bail!("unknown sign mode '{other}'"),
        };
        Ok(Self {
            listen: cfg.str_or("service", "listen", &d.listen),
            workers: cfg.usize_or("service", "workers", d.workers),
            fh_dim: cfg.usize_or("fh", "dim", d.fh_dim),
            family,
            sign,
            seed: cfg.i64_or("fh", "seed", d.seed as i64) as u64,
            oph_k: cfg.usize_or("oph", "k", d.oph_k),
            lsh_k: cfg.usize_or("lsh", "k", d.lsh_k),
            lsh_l: cfg.usize_or("lsh", "l", d.lsh_l),
            enable_pjrt: cfg.bool_or("batcher", "enable_pjrt", d.enable_pjrt),
            max_delay_us: cfg.i64_or("batcher", "max_delay_us", d.max_delay_us as i64) as u64,
            queue_cap: cfg.usize_or("batcher", "queue_cap", d.queue_cap),
            artifacts_dir: PathBuf::from(cfg.str_or(
                "batcher",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_config(&Config::load(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.fh_dim, 128);
        assert_eq!(c.family, HashFamily::MixedTab);
        assert!(c.enable_pjrt);
    }

    #[test]
    fn parses_overrides() {
        let cfg = Config::parse(
            "[fh]\ndim = 64\nhash = \"murmur3\"\nsign = \"separate\"\n[batcher]\nenable_pjrt = false\n[lsh]\nk = 8\nl = 12\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.fh_dim, 64);
        assert_eq!(c.family, HashFamily::Murmur3);
        assert_eq!(c.sign, SignMode::Separate);
        assert!(!c.enable_pjrt);
        assert_eq!((c.lsh_k, c.lsh_l), (8, 12));
    }

    #[test]
    fn rejects_bad_family() {
        let cfg = Config::parse("[fh]\nhash = \"md5\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }
}
