//! Coordinator configuration.
//!
//! Loaded from a TOML-subset file (see [`crate::util::config`]); every field
//! has a default so `CoordinatorConfig::default()` runs out of the box.
//!
//! ```toml
//! [service]
//! listen = "127.0.0.1:7878"
//! workers = 2          # shard fan-out pool width (< 2 = sequential fan-out)
//! request_workers = 4  # fixed pool executing decoded requests (event loop)
//! idle_timeout_ms = 0  # close connections idle this long (0 = never)
//! conn_queue_cap = 64  # per-connection pending cap (in-flight + queued replies)
//!
//! [fh]
//! dim = 128
//! hash = "mixed_tab"
//! sign = "paired"
//! seed = 42
//!
//! [oph]
//! k = 200
//!
//! # Default sketch spec for the scheme-aware `sketch` endpoint. When the
//! # section is omitted, an OPH spec is derived from [fh]/[oph] above
//! # (hasher seed `[fh] seed ^ OPH_SEED_SALT`), so existing configs keep
//! # their exact pre-spec behaviour; setting a spec replaces that
//! # derivation, and stored sketches only stay comparable if it matches.
//! [sketch]
//! spec = "minhash(k=128,hash=mixed_tab,seed=7)"
//!
//! [lsh]
//! k = 10
//! l = 10
//! shards = 4           # default-scheme index shards (1 = unsharded)
//!
//! [batcher]
//! enable_pjrt = true
//! max_delay_us = 200
//! queue_cap = 256
//! artifacts_dir = "artifacts"
//! # Cross-connection op batching: coalesce `sketch`/`insert`/`query`
//! # ops from different connections into batched calls (0 = off).
//! op_batch = 32
//! op_max_delay_us = 200
//! op_queue_cap = 256
//!
//! # Per-connection throttling at the server layer (0 disables either knob).
//! [limits]
//! requests_per_sec = 200     # token-bucket rate per connection
//! burst = 50                 # bucket capacity (defaults to requests_per_sec)
//! max_requests_per_conn = 0  # hard per-connection request budget
//! max_connections = 0        # global concurrent-connection cap (0 = unlimited)
//!
//! # Additional named schemes served concurrently with the default one.
//! # Each gets its own sketcher and (for OPH specs) its own sharded index;
//! # clients select one with the wire ops' optional `scheme` field.
//! [[schemes]]
//! name = "fast"
//! spec = "oph(k=64,hash=multiply_shift,seed=7)"
//! shards = 2
//!
//! [[schemes]]
//! name = "dense"
//! spec = "minhash(k=128,hash=mixed_tab,seed=9)"
//! ```

use crate::hash::HashFamily;
use crate::sketch::feature_hash::SignMode;
use crate::sketch::spec::{SketchScheme, SketchSpec};
use crate::util::config::{Config, Table, Value};
use crate::util::error::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Seed salt separating the OPH sketcher's hash stream from the FH stream
/// (pre-spec behaviour, kept bit-identical).
pub const OPH_SEED_SALT: u64 = 0x09EB_57A1;

/// Seed salt for the LSH index's sketcher.
pub const LSH_SEED_SALT: u64 = 0x154A_11CE;

/// Name of the implicit scheme every coordinator serves; it preserves the
/// single-scheme wire behaviour (and must not be shadowed by `[[schemes]]`).
pub const DEFAULT_SCHEME: &str = "default";

/// Upper bound on configured shards per scheme — sharding buys intra-host
/// parallelism, and hundreds of shards on one host is a config typo.
pub const MAX_SHARDS: usize = 256;

/// One `[[schemes]]` entry: a named sketch spec served alongside the
/// default scheme, with its own sharded index when the spec is OPH.
#[derive(Debug, Clone)]
pub struct SchemeConfig {
    pub name: String,
    pub spec: SketchSpec,
    /// Index shards for this scheme (ignored for non-OPH specs, which get
    /// no LSH index).
    pub shards: usize,
}

impl SchemeConfig {
    fn from_table(table: &Table) -> Result<Self> {
        let name = match table.get("name") {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => bail!("[[schemes]] name must be a string, got {v:?}"),
            None => bail!("[[schemes]] entry is missing 'name'"),
        };
        if name.is_empty() {
            bail!("[[schemes]] name must be non-empty");
        }
        if name == DEFAULT_SCHEME || name == "oph" {
            bail!("[[schemes]] name '{name}' is reserved");
        }
        let spec = match table.get("spec") {
            Some(Value::Str(s)) => {
                SketchSpec::parse(s).with_context(|| format!("[[schemes]] '{name}' spec"))?
            }
            Some(v) => bail!("[[schemes]] '{name}' spec must be a string, got {v:?}"),
            None => bail!("[[schemes]] '{name}' is missing 'spec'"),
        };
        let shards = match table.get("shards") {
            Some(v) => {
                let Some(n) = v.as_i64().and_then(|n| usize::try_from(n).ok()) else {
                    bail!("[[schemes]] '{name}' shards must be a non-negative integer");
                };
                n
            }
            None => 1,
        };
        if !(1..=MAX_SHARDS).contains(&shards) {
            bail!("[[schemes]] '{name}' shards must be in 1..={MAX_SHARDS}, got {shards}");
        }
        for key in table.keys() {
            if !matches!(key.as_str(), "name" | "spec" | "shards") {
                bail!("unknown key '{key}' in [[schemes]] '{name}'");
            }
        }
        Ok(Self { name, spec, shards })
    }
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// TCP listen address for the server front-end.
    pub listen: String,
    /// Worker threads for the shared shard fan-out pool (queries visit a
    /// scheme's shards in parallel). Below 2 — or with no multi-shard
    /// scheme configured — fan-out stays sequential; see
    /// [`Self::fanout_workers`].
    pub workers: usize,
    /// Fixed worker pool executing decoded requests behind the event
    /// loop — the serving concurrency, decoupled from connection count.
    pub request_workers: usize,
    /// Close a connection with no in-flight work after this long without
    /// traffic; 0 disables.
    pub idle_timeout_ms: u64,
    /// Per-connection pending cap: in-flight requests plus queued
    /// responses. At the cap the event loop stops reading the socket, so
    /// backpressure propagates to the client via TCP.
    pub conn_queue_cap: usize,
    /// FH output dimension d'.
    pub fh_dim: usize,
    /// Basic hash family for every sketch (the paper's variable).
    pub family: HashFamily,
    /// FH sign derivation.
    pub sign: SignMode,
    /// Root seed.
    pub seed: u64,
    /// OPH sketch size.
    pub oph_k: usize,
    /// Default spec for the scheme-aware `sketch` endpoint. `None` derives
    /// an OPH spec from `(family, seed, oph_k)` — see [`Self::sketch_spec`].
    pub sketch: Option<SketchSpec>,
    /// LSH parameters.
    pub lsh_k: usize,
    pub lsh_l: usize,
    /// Index shards for the default scheme (1 = unsharded; a one-shard
    /// index is bit-identical to the pre-sharding coordinator).
    pub lsh_shards: usize,
    /// Additional named schemes (`[[schemes]]`), served next to the
    /// default one by the scheme registry.
    pub schemes: Vec<SchemeConfig>,
    /// Per-connection token-bucket rate (requests/second); 0 disables.
    pub rate_limit_rps: f64,
    /// Token-bucket capacity; 0 derives `max(1, ⌈rate⌉)`.
    pub rate_limit_burst: u32,
    /// Hard per-connection request budget; 0 disables. Once exhausted the
    /// connection gets one budget-exhausted error and is closed.
    pub conn_request_budget: u64,
    /// Global concurrent-connection cap; 0 disables. Connection N+1 gets
    /// one clean error line and is closed, never left hanging.
    pub max_connections: usize,
    /// Use the PJRT runtime when artifacts are present.
    pub enable_pjrt: bool,
    /// Batch window: how long the batcher waits to fill a batch.
    pub max_delay_us: u64,
    /// Bounded batcher queue; overflow sheds to the native path.
    pub queue_cap: usize,
    /// Cross-connection op batch size for `sketch`/`insert`/`query`
    /// (fill-or-deadline dispatch); 0 turns op batching off.
    pub op_batch: usize,
    /// Op-batch window: how long the op batcher waits to fill a batch.
    pub op_max_delay_us: u64,
    /// Bounded op-batcher queue; overflow sheds to the direct path.
    pub op_queue_cap: usize,
    /// Where `manifest.json` lives.
    pub artifacts_dir: PathBuf,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            listen: "127.0.0.1:7878".into(),
            workers: 2,
            request_workers: 4,
            idle_timeout_ms: 0,
            conn_queue_cap: 64,
            fh_dim: 128,
            family: HashFamily::MixedTab,
            sign: SignMode::Paired,
            seed: 42,
            oph_k: 200,
            sketch: None,
            lsh_k: 10,
            lsh_l: 10,
            lsh_shards: 1,
            schemes: Vec::new(),
            rate_limit_rps: 0.0,
            rate_limit_burst: 0,
            conn_request_budget: 0,
            max_connections: 0,
            enable_pjrt: true,
            max_delay_us: 200,
            queue_cap: 256,
            op_batch: 32,
            op_max_delay_us: 200,
            op_queue_cap: 256,
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl CoordinatorConfig {
    /// Parse from config text.
    pub fn from_config(cfg: &Config) -> Result<Self> {
        let d = Self::default();
        let family_id = cfg.str_or("fh", "hash", HashFamily::MixedTab.id());
        let Some(family) = HashFamily::parse(&family_id) else {
            bail!("unknown hash family '{family_id}'");
        };
        let Some(sign) = SignMode::parse(&cfg.str_or("fh", "sign", "paired")) else {
            bail!("unknown sign mode '{}'", cfg.str_or("fh", "sign", "paired"));
        };
        let mut oph_k = cfg.usize_or("oph", "k", d.oph_k);
        let sketch = match cfg.get("sketch", "spec") {
            Some(value) => {
                // A mistyped value must not silently fall back to the
                // derived OPH default.
                let Some(text) = value.as_str() else {
                    bail!("[sketch] spec must be a string, got {value:?}");
                };
                let spec = SketchSpec::parse(text).context("[sketch] spec")?;
                // Keep the OPH-dependent paths (PJRT artifact lookup,
                // estimate endpoint) aligned with an OPH default spec.
                if let SketchScheme::Oph(p) = spec.scheme {
                    oph_k = p.k;
                }
                Some(spec)
            }
            None => None,
        };
        // The natural typo for `[[schemes]]` is `[schemes]`, which the
        // parser stores as a plain section — it would otherwise be
        // silently ignored and the named scheme never served.
        if cfg.sections().any(|s| s == "schemes") {
            bail!("[schemes] is a plain section — named schemes use [[schemes]] entries");
        }
        let mut schemes = Vec::new();
        for table in cfg.tables("schemes") {
            let scheme = SchemeConfig::from_table(table)?;
            if schemes.iter().any(|s: &SchemeConfig| s.name == scheme.name) {
                bail!("duplicate [[schemes]] name '{}'", scheme.name);
            }
            schemes.push(scheme);
        }
        let lsh_shards = cfg.usize_or("lsh", "shards", d.lsh_shards);
        if !(1..=MAX_SHARDS).contains(&lsh_shards) {
            bail!("[lsh] shards must be in 1..={MAX_SHARDS}, got {lsh_shards}");
        }
        let rate_limit_rps = cfg.f64_or("limits", "requests_per_sec", d.rate_limit_rps);
        if rate_limit_rps < 0.0 || !rate_limit_rps.is_finite() {
            bail!("[limits] requests_per_sec must be finite and >= 0, got {rate_limit_rps}");
        }
        let rate_limit_burst = cfg.i64_or("limits", "burst", d.rate_limit_burst as i64);
        if !(0..=u32::MAX as i64).contains(&rate_limit_burst) {
            bail!("[limits] burst must be in 0..={}, got {rate_limit_burst}", u32::MAX);
        }
        // A burst with no rate would be silently inert (the bucket is only
        // consulted when requests_per_sec > 0) — surface the dead setting.
        if rate_limit_burst > 0 && rate_limit_rps == 0.0 {
            bail!("[limits] burst is set but requests_per_sec is 0 — burst has no effect");
        }
        let conn_request_budget =
            cfg.i64_or("limits", "max_requests_per_conn", d.conn_request_budget as i64);
        if conn_request_budget < 0 {
            bail!("[limits] max_requests_per_conn must be >= 0, got {conn_request_budget}");
        }
        let max_connections = cfg.i64_or("limits", "max_connections", d.max_connections as i64);
        if max_connections < 0 {
            bail!("[limits] max_connections must be >= 0, got {max_connections}");
        }
        let request_workers = cfg.usize_or("service", "request_workers", d.request_workers);
        if request_workers == 0 {
            bail!("[service] request_workers must be >= 1");
        }
        let idle_timeout_ms = cfg.i64_or("service", "idle_timeout_ms", d.idle_timeout_ms as i64);
        if idle_timeout_ms < 0 {
            bail!("[service] idle_timeout_ms must be >= 0, got {idle_timeout_ms}");
        }
        let conn_queue_cap = cfg.usize_or("service", "conn_queue_cap", d.conn_queue_cap);
        if conn_queue_cap == 0 {
            bail!("[service] conn_queue_cap must be >= 1");
        }
        let op_batch = cfg.usize_or("batcher", "op_batch", d.op_batch);
        let op_max_delay_us = cfg.i64_or("batcher", "op_max_delay_us", d.op_max_delay_us as i64);
        if op_max_delay_us < 0 {
            bail!("[batcher] op_max_delay_us must be >= 0, got {op_max_delay_us}");
        }
        let op_queue_cap = cfg.usize_or("batcher", "op_queue_cap", d.op_queue_cap);
        if op_queue_cap == 0 {
            bail!("[batcher] op_queue_cap must be >= 1");
        }
        // The op-batch knobs are only consulted when op batching is on —
        // surface dead settings like the burst/rate pair above.
        if op_batch == 0
            && (cfg.get("batcher", "op_max_delay_us").is_some()
                || cfg.get("batcher", "op_queue_cap").is_some())
        {
            bail!("[batcher] op_max_delay_us/op_queue_cap have no effect when op_batch is 0");
        }
        Ok(Self {
            listen: cfg.str_or("service", "listen", &d.listen),
            workers: cfg.usize_or("service", "workers", d.workers),
            request_workers,
            idle_timeout_ms: idle_timeout_ms as u64,
            conn_queue_cap,
            fh_dim: cfg.usize_or("fh", "dim", d.fh_dim),
            family,
            sign,
            seed: cfg.i64_or("fh", "seed", d.seed as i64) as u64,
            oph_k,
            sketch,
            lsh_k: cfg.usize_or("lsh", "k", d.lsh_k),
            lsh_l: cfg.usize_or("lsh", "l", d.lsh_l),
            lsh_shards,
            schemes,
            rate_limit_rps,
            rate_limit_burst: rate_limit_burst as u32,
            conn_request_budget: conn_request_budget as u64,
            max_connections: max_connections as usize,
            enable_pjrt: cfg.bool_or("batcher", "enable_pjrt", d.enable_pjrt),
            max_delay_us: cfg.i64_or("batcher", "max_delay_us", d.max_delay_us as i64) as u64,
            queue_cap: cfg.usize_or("batcher", "queue_cap", d.queue_cap),
            op_batch,
            op_max_delay_us: op_max_delay_us as u64,
            op_queue_cap,
            artifacts_dir: PathBuf::from(cfg.str_or(
                "batcher",
                "artifacts_dir",
                d.artifacts_dir.to_str().unwrap(),
            )),
        })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        Self::from_config(&Config::load(path)?)
    }

    /// The spec served by the scheme-aware `sketch` endpoint: the `[sketch]`
    /// section when present, else the derived OPH default (bit-identical to
    /// the pre-spec coordinator's OPH sketcher).
    pub fn sketch_spec(&self) -> SketchSpec {
        self.sketch
            .unwrap_or_else(|| SketchSpec::oph(self.family, self.seed ^ OPH_SEED_SALT, self.oph_k))
    }

    /// The OPH spec backing the `oph` compatibility endpoint, the
    /// `estimate` endpoint, and the PJRT OPH batch path. Equals
    /// [`Self::sketch_spec`] when that is an OPH spec, else the derived
    /// default.
    pub fn oph_spec(&self) -> SketchSpec {
        let spec = self.sketch_spec();
        if matches!(spec.scheme, SketchScheme::Oph(_)) {
            spec
        } else {
            SketchSpec::oph(self.family, self.seed ^ OPH_SEED_SALT, self.oph_k)
        }
    }

    /// The FH transform spec (the `fh` endpoint and the PJRT plan path).
    pub fn fh_spec(&self) -> SketchSpec {
        SketchSpec::feature_hash(self.family, self.seed, self.fh_dim, self.sign)
    }

    /// The LSH index's sketch spec (bin count is overridden by the index's
    /// structural parameters).
    pub fn lsh_spec(&self) -> SketchSpec {
        SketchSpec::oph(
            self.family,
            self.seed ^ LSH_SEED_SALT,
            self.lsh_k * self.lsh_l,
        )
    }

    /// Width of the shared shard fan-out pool, or 0 when fan-out is
    /// sequential: parallel fan-out needs at least 2 workers *and* at
    /// least one multi-shard scheme to help (a pool no scheme can use
    /// would only cost idle threads — note an index later swapped in by
    /// `load_index` inherits this decision, so a single-shard config
    /// serves a loaded multi-shard snapshot sequentially).
    pub fn fanout_workers(&self) -> usize {
        let multi_shard = self.lsh_shards > 1 || self.schemes.iter().any(|s| s.shards > 1);
        if self.workers >= 2 && multi_shard {
            self.workers
        } else {
            0
        }
    }

    /// Effective token-bucket capacity when rate limiting is on: the
    /// configured burst, or `max(1, ⌈rate⌉)` when unset.
    pub fn effective_burst(&self) -> u32 {
        if self.rate_limit_burst > 0 {
            self.rate_limit_burst
        } else {
            (self.rate_limit_rps.ceil().max(1.0)) as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.fh_dim, 128);
        assert_eq!(c.family, HashFamily::MixedTab);
        assert!(c.enable_pjrt);
        // Derived specs track the scalar fields.
        assert_eq!(
            c.sketch_spec(),
            SketchSpec::oph(HashFamily::MixedTab, 42 ^ OPH_SEED_SALT, 200)
        );
        assert_eq!(c.oph_spec(), c.sketch_spec());
        assert_eq!(
            c.fh_spec(),
            SketchSpec::feature_hash(HashFamily::MixedTab, 42, 128, SignMode::Paired)
        );
    }

    #[test]
    fn parses_overrides() {
        let cfg = Config::parse(
            "[fh]\ndim = 64\nhash = \"murmur3\"\nsign = \"separate\"\n[batcher]\nenable_pjrt = false\n[lsh]\nk = 8\nl = 12\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.fh_dim, 64);
        assert_eq!(c.family, HashFamily::Murmur3);
        assert_eq!(c.sign, SignMode::Separate);
        assert!(!c.enable_pjrt);
        assert_eq!((c.lsh_k, c.lsh_l), (8, 12));
        // No [sketch] section: the derived spec follows the [fh] family.
        assert_eq!(c.sketch_spec().family, HashFamily::Murmur3);
    }

    #[test]
    fn parses_sketch_spec_section() {
        let cfg = Config::parse(
            "[sketch]\nspec = \"minhash(k=32,hash=murmur3,seed=5)\"\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(
            c.sketch_spec(),
            SketchSpec::minhash(HashFamily::Murmur3, 5, 32)
        );
        // Non-OPH default spec: the OPH paths fall back to the derived spec.
        assert_eq!(c.oph_spec().scheme_id(), "oph");

        // An OPH spec keeps oph_k (and thus PJRT artifact lookup) in sync.
        let cfg = Config::parse("[sketch]\nspec = \"oph(k=64,seed=9)\"\n").unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.oph_k, 64);
        assert_eq!(c.oph_spec(), SketchSpec::oph(HashFamily::MixedTab, 9, 64));

        // Pooled-source specs ride the same path: `pool=` survives the
        // config round-trip into the serving spec.
        let cfg = Config::parse(
            "[sketch]\nspec = \"simhash(bits=64,pool=256,hash=mixed_tab,seed=3)\"\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(
            c.sketch_spec(),
            SketchSpec::simhash_pooled(HashFamily::MixedTab, 3, 64, 256)
        );
        // ...and a bad pool (not a multiple of 64) is a config error.
        let cfg =
            Config::parse("[sketch]\nspec = \"minhash(k=32,pool=100)\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn rejects_bad_family() {
        let cfg = Config::parse("[fh]\nhash = \"md5\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }

    #[test]
    fn parses_schemes_shards_and_limits() {
        let cfg = Config::parse(
            "[lsh]\nk = 6\nl = 8\nshards = 4\n\n[limits]\nrequests_per_sec = 200\nburst = 50\nmax_requests_per_conn = 1000\n\n[[schemes]]\nname = \"fast\"\nspec = \"oph(k=64,hash=multiply_shift,seed=7)\"\nshards = 2\n\n[[schemes]]\nname = \"dense\"\nspec = \"minhash(k=32,seed=9)\"\n\n[[schemes]]\nname = \"pooled\"\nspec = \"minhash(k=32,pool=256,seed=9)\"\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.lsh_shards, 4);
        assert_eq!(c.rate_limit_rps, 200.0);
        assert_eq!(c.rate_limit_burst, 50);
        assert_eq!(c.effective_burst(), 50);
        assert_eq!(c.conn_request_budget, 1000);
        assert_eq!(c.schemes.len(), 3);
        assert_eq!(c.schemes[0].name, "fast");
        assert_eq!(
            c.schemes[0].spec,
            SketchSpec::oph(HashFamily::MultiplyShift, 7, 64)
        );
        assert_eq!(c.schemes[0].shards, 2);
        assert_eq!(c.schemes[1].name, "dense");
        assert_eq!(c.schemes[1].shards, 1);
        assert_eq!(
            c.schemes[2].spec,
            SketchSpec::minhash_pooled(HashFamily::MixedTab, 9, 32, 256)
        );
        // Burst derivation when unset.
        let c = CoordinatorConfig {
            rate_limit_rps: 2.5,
            ..CoordinatorConfig::default()
        };
        assert_eq!(c.effective_burst(), 3);
    }

    #[test]
    fn fanout_workers_derivation() {
        // Default: 2 workers but only single-shard schemes → sequential.
        assert_eq!(CoordinatorConfig::default().fanout_workers(), 0);
        // Multi-shard default scheme turns the pool on.
        let c = CoordinatorConfig {
            lsh_shards: 4,
            workers: 3,
            ..CoordinatorConfig::default()
        };
        assert_eq!(c.fanout_workers(), 3);
        // A multi-shard named scheme is enough.
        let c = CoordinatorConfig {
            schemes: vec![SchemeConfig {
                name: "fast".into(),
                spec: SketchSpec::oph(HashFamily::MixedTab, 1, 8),
                shards: 2,
            }],
            ..CoordinatorConfig::default()
        };
        assert_eq!(c.fanout_workers(), 2);
        // Fewer than 2 workers always means sequential.
        let c = CoordinatorConfig {
            lsh_shards: 4,
            workers: 1,
            ..CoordinatorConfig::default()
        };
        assert_eq!(c.fanout_workers(), 0);
    }

    #[test]
    fn rejects_bad_schemes_and_limits() {
        for bad in [
            // Missing name / spec.
            "[[schemes]]\nspec = \"oph(k=8)\"\n",
            "[[schemes]]\nname = \"x\"\n",
            // Reserved and duplicate names.
            "[[schemes]]\nname = \"default\"\nspec = \"oph(k=8)\"\n",
            "[[schemes]]\nname = \"oph\"\nspec = \"oph(k=8)\"\n",
            "[[schemes]]\nname = \"x\"\nspec = \"oph(k=8)\"\n[[schemes]]\nname = \"x\"\nspec = \"oph(k=9)\"\n",
            // Bad spec / non-string spec / unknown key / bad shard counts.
            "[[schemes]]\nname = \"x\"\nspec = \"oph(k=zero)\"\n",
            "[[schemes]]\nname = \"x\"\nspec = 42\n",
            "[[schemes]]\nname = \"x\"\nspec = \"oph(k=8)\"\nwibble = 1\n",
            "[[schemes]]\nname = \"x\"\nspec = \"oph(k=8)\"\nshards = 0\n",
            "[[schemes]]\nname = \"x\"\nspec = \"oph(k=8)\"\nshards = 100000\n",
            "[lsh]\nshards = 0\n",
            "[limits]\nrequests_per_sec = -1\n",
            "[limits]\nburst = -5\n",
            "[limits]\nburst = 4294967296\n",
            // Burst with no rate is inert — reject rather than ignore.
            "[limits]\nburst = 50\n",
            // Single-bracket [schemes] is the natural typo for [[schemes]].
            "[schemes]\nname = \"x\"\nspec = \"oph(k=8)\"\n",
            "[limits]\nmax_requests_per_conn = -5\n",
            // Event-loop / op-batching knobs.
            "[limits]\nmax_connections = -1\n",
            "[service]\nrequest_workers = 0\n",
            "[service]\nidle_timeout_ms = -1\n",
            "[service]\nconn_queue_cap = 0\n",
            "[batcher]\nop_max_delay_us = -1\n",
            "[batcher]\nop_queue_cap = 0\n",
            // Op-batch knobs with batching off are inert — reject.
            "[batcher]\nop_batch = 0\nop_max_delay_us = 100\n",
            "[batcher]\nop_batch = 0\nop_queue_cap = 16\n",
        ] {
            let cfg = Config::parse(bad).unwrap();
            assert!(
                CoordinatorConfig::from_config(&cfg).is_err(),
                "accepted: {bad}"
            );
        }
    }

    #[test]
    fn parses_event_loop_and_op_batch_knobs() {
        let c = CoordinatorConfig::default();
        assert_eq!(c.request_workers, 4);
        assert_eq!(c.idle_timeout_ms, 0);
        assert_eq!(c.conn_queue_cap, 64);
        assert_eq!(c.max_connections, 0);
        assert_eq!(c.op_batch, 32); // on by default
        let cfg = Config::parse(
            "[service]\nrequest_workers = 8\nidle_timeout_ms = 5000\nconn_queue_cap = 16\n\n[limits]\nmax_connections = 100\n\n[batcher]\nop_batch = 64\nop_max_delay_us = 50\nop_queue_cap = 512\n",
        )
        .unwrap();
        let c = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(c.request_workers, 8);
        assert_eq!(c.idle_timeout_ms, 5000);
        assert_eq!(c.conn_queue_cap, 16);
        assert_eq!(c.max_connections, 100);
        assert_eq!(c.op_batch, 64);
        assert_eq!(c.op_max_delay_us, 50);
        assert_eq!(c.op_queue_cap, 512);
        // op_batch = 0 alone is a legal way to turn batching off.
        let cfg = Config::parse("[batcher]\nop_batch = 0\n").unwrap();
        assert_eq!(CoordinatorConfig::from_config(&cfg).unwrap().op_batch, 0);
    }

    #[test]
    fn rejects_bad_sketch_spec() {
        let cfg = Config::parse("[sketch]\nspec = \"oph(k=nope)\"\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
        // Mistyped (non-string) spec errors instead of silently serving
        // the derived default.
        let cfg = Config::parse("[sketch]\nspec = 42\n").unwrap();
        assert!(CoordinatorConfig::from_config(&cfg).is_err());
    }
}
