//! The coordination layer: a sketching/similarity service in the deployment
//! shape the paper's applications live in (LSH ingest + query serving, SVM
//! featurisation).
//!
//! Rust owns the event loop, batching, worker topology and metrics; the
//! dense batched math executes through the PJRT runtime when artifacts are
//! available, with a bit-compatible native fallback.
//!
//! * [`config`] — service configuration (TOML-subset files + defaults).
//! * [`request`] — typed requests/responses + JSON wire codec.
//! * [`batcher`] — dynamic batchers: FH transforms (shed-to-native) and
//!   the cross-connection op batcher (max-batch/max-delay, bounded
//!   queues, shed-to-direct backpressure).
//! * [`registry`] — the scheme registry: named sketch schemes, each with
//!   its own sketcher, sharded index and store.
//! * [`service`] — the coordinator proper: routing across schemes.
//! * [`server`] — event-driven newline-delimited-JSON TCP front-end:
//!   nonblocking event loop + fixed worker pool, pipelined `rid`-tagged
//!   requests, per-connection rate limiting / request budgets /
//!   backpressure, and a global connection cap.
//! * [`metrics`] — counters (global, per-scheme, per-shard) and latency
//!   quantiles.
//! * [`cluster`] — the cross-host tier: router mode (replicated routing
//!   over remote backends, health-gated fan-out, shadow traffic).

pub mod config;
pub mod request;
pub mod batcher;
pub mod registry;
pub mod service;
pub mod server;
pub mod metrics;
pub mod cluster;

pub use config::{CoordinatorConfig, SchemeConfig};
pub use registry::{Scheme, SchemeRegistry};
pub use request::{Request, Response};
pub use service::Coordinator;
