//! The coordination layer: a sketching/similarity service in the deployment
//! shape the paper's applications live in (LSH ingest + query serving, SVM
//! featurisation).
//!
//! Rust owns the event loop, batching, worker topology and metrics; the
//! dense batched math executes through the PJRT runtime when artifacts are
//! available, with a bit-compatible native fallback.
//!
//! * [`config`] — service configuration (TOML-subset files + defaults).
//! * [`request`] — typed requests/responses + JSON wire codec.
//! * [`batcher`] — dynamic batcher for FH transforms (max-batch/max-delay,
//!   bounded queue, shed-to-native backpressure).
//! * [`service`] — the coordinator proper: routing, LSH shards, set store.
//! * [`server`] — newline-delimited-JSON TCP front-end.
//! * [`metrics`] — counters and latency quantiles.

pub mod config;
pub mod request;
pub mod batcher;
pub mod service;
pub mod server;
pub mod metrics;

pub use config::CoordinatorConfig;
pub use request::{Request, Response};
pub use service::Coordinator;
