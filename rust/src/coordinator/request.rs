//! Typed requests/responses + the newline-delimited JSON wire codec used by
//! the TCP front-end and the examples.
//!
//! Numeric wire caveat: sketch coordinates travel as JSON numbers (f64), so
//! values round-trip exactly only below 2^53. Densified OPH bins stay far
//! under that for realistic copy distances (`v + j·C` with `v < 2^32`,
//! `C = 2^33`), matching the pre-existing `sketch` response encoding.

use crate::sketch::bbit::BbitSketch;
use crate::sketch::oph::OphSketch;
use crate::sketch::sketcher::SketchValue;
use crate::util::json::{self, Json};
use crate::util::error::{bail, Context, Error, Result};

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Feature-hash a sparse vector; returns the dense d'-vector + ‖v′‖².
    FhTransform { indices: Vec<u32>, values: Vec<f64> },
    /// OPH-sketch a set with the service's OPH sketcher; returns the
    /// densified bins. Compatibility alias for the scheme-aware
    /// [`Request::Sketch`] — kept wire-stable for existing clients.
    OphSketch { set: Vec<u32> },
    /// Sketch a set with a named scheme's sketcher (`scheme`, default
    /// scheme when absent), or with an explicit ad-hoc per-request
    /// [`crate::sketch::SketchSpec`] string (`spec`). The two selectors
    /// are mutually exclusive on the wire.
    Sketch {
        set: Vec<u32>,
        spec: Option<String>,
        scheme: Option<String>,
    },
    /// Insert a set into a scheme's sharded LSH index (`scheme` absent =
    /// default scheme, the legacy behaviour). Every scheme also stores
    /// its own sketch of the set at insert time, backing `Estimate`.
    LshInsert {
        id: u32,
        set: Vec<u32>,
        scheme: Option<String>,
    },
    /// Query a scheme's sharded LSH index; returns merged candidate ids.
    LshQuery {
        set: Vec<u32>,
        scheme: Option<String>,
    },
    /// Delete a stored id from a scheme's index (tombstone + sketch-store
    /// drop); reports whether the id was live. Tombstoned postings are
    /// reclaimed by compaction.
    LshDelete {
        id: u32,
        scheme: Option<String>,
    },
    /// Replace a stored id's content (delete + insert as one op). The
    /// old postings are purged, never left serving stale candidates.
    LshUpdate {
        id: u32,
        set: Vec<u32>,
        scheme: Option<String>,
    },
    /// Top-k serving: LSH candidate retrieval re-ranked by the scheme's
    /// estimator over stored sketches; returns the k best (id, score)
    /// pairs, score-descending.
    LshQueryTopK {
        set: Vec<u32>,
        k: usize,
        scheme: Option<String>,
    },
    /// Explicitly compact a scheme's index, purging all tombstoned
    /// postings; reports how many posting entries were reclaimed.
    Compact {
        scheme: Option<String>,
    },
    /// Similarity estimate between two stored ids, compared from the
    /// sketches the scheme stored at insert time (never re-sketched).
    Estimate {
        a: u32,
        b: u32,
        scheme: Option<String>,
    },
    /// Shingle a raw document (w = 5 bytes) and insert it into a scheme's
    /// LSH index — the ingest path of a dedup/search service.
    IndexDoc {
        id: u32,
        text: String,
        scheme: Option<String>,
    },
    /// Shingle a raw document and query a scheme's LSH index.
    QueryDoc {
        text: String,
        scheme: Option<String>,
    },
    /// Snapshot a scheme's LSH index to a server-side path.
    SaveIndex {
        path: String,
        scheme: Option<String>,
    },
    /// Restore a scheme's LSH index from a snapshot written by
    /// `save_index` (provenance-checked against the scheme's spec).
    LoadIndex {
        path: String,
        scheme: Option<String>,
    },
    /// Service statistics snapshot.
    Stats,
}

/// Which execution path served an FH request (observable for tests/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    Pjrt,
    Native,
}

/// A service response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Fh {
        out: Vec<f32>,
        sqnorm: f64,
        path: ExecPath,
    },
    Sketch {
        bins: Vec<u64>,
    },
    /// Scheme-tagged sketch from the spec-driven `sketch` endpoint.
    SketchValue {
        value: SketchValue,
    },
    Inserted {
        id: u32,
    },
    Candidates {
        ids: Vec<u32>,
    },
    /// A `delete`: whether the id was live when deleted.
    Deleted {
        id: u32,
        existed: bool,
    },
    Updated {
        id: u32,
    },
    /// A `query_topk`: parallel arrays, `ids[i]` scored `scores[i]`,
    /// score-descending (ties broken by ascending id).
    TopK {
        ids: Vec<u32>,
        scores: Vec<f64>,
    },
    /// A `compact`: posting entries reclaimed across all shards.
    Compacted {
        purged: usize,
    },
    Estimate {
        jaccard: f64,
    },
    Saved {
        path: String,
        entries: usize,
    },
    /// A `load_index` restore: how many entries across how many shards
    /// the scheme now serves.
    Loaded {
        path: String,
        entries: usize,
        shards: usize,
    },
    Stats {
        json: Json,
    },
    Error {
        message: String,
    },
}

fn arr_u32(j: &Json, key: &str) -> Result<Vec<u32>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array '{key}'"))?
        .iter()
        .map(|v| {
            v.as_i64()
                .and_then(|x| u32::try_from(x).ok())
                .with_context(|| format!("bad u32 in '{key}'"))
        })
        .collect()
}

/// Reject fields the op does not define. Without this, a mistyped
/// selector — `"shceme"`, `"Scheme"`, a `spec` on an op that has none —
/// would be silently dropped and the request silently served by the
/// default scheme, which is exactly the failure mode the optional
/// `scheme` field must not have.
///
/// `rid` is the protocol-level pipeline tag (see [`parse_tagged_request`])
/// and is legal on every op, like `op` itself. It is named `rid` rather
/// than `id` because `insert`/`index_doc` already use `id` as payload.
fn check_keys(j: &Json, op: &str, allowed: &[&str]) -> Result<()> {
    let Some(obj) = j.as_obj() else { return Ok(()) };
    for key in obj.keys() {
        if key != "op" && key != "rid" && !allowed.contains(&key.as_str()) {
            bail!("unknown field '{key}' for op '{op}'");
        }
    }
    Ok(())
}

/// Decode one wire line into its pipeline tag and request.
///
/// The tag (`rid`, a client-chosen non-negative integer — exact below
/// 2^53, the JSON number limit) marks the request as pipelined: the
/// server may return its response out of order, echoing the tag.
/// Untagged requests keep the legacy strictly-sequential contract.
///
/// The tag is extracted *before* the request body is validated, so a
/// malformed pipelined request still gets its error response mapped back
/// to the right tag; if the tag itself is invalid it is reported as the
/// request error (with no tag to echo).
pub fn parse_tagged_request(line: &str) -> (Option<u64>, Result<Request>) {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(Error::msg(e).context("parse request json"))),
    };
    let rid = match j.get("rid") {
        None | Some(Json::Null) => None,
        Some(v) => match v.as_i64().and_then(|x| u64::try_from(x).ok()) {
            Some(r) => Some(r),
            None => {
                return (
                    None,
                    Err(Error::msg("'rid' must be a non-negative integer")),
                )
            }
        },
    };
    (rid, Request::from_json_line(line))
}

/// Optional string field: absent/null means `None`; any other non-string
/// value is a client bug and must error rather than be masked as a default.
fn opt_str(j: &Json, key: &str) -> Result<Option<String>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_str()
                .with_context(|| format!("'{key}' must be a string"))?
                .to_string(),
        )),
    }
}

fn arr_f64(j: &Json, key: &str) -> Result<Vec<f64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array '{key}'"))?
        .iter()
        .map(|v| v.as_f64().with_context(|| format!("bad number in '{key}'")))
        .collect()
}

/// Encode a [`SketchValue`] into a JSON object (`scheme` + payload). Used
/// by the `sketch_value` response and the `mixtab sketch` CLI.
pub fn sketch_value_to_json(value: &SketchValue) -> Json {
    let j = Json::obj().set("scheme", value.scheme_id());
    match value {
        SketchValue::Oph(s) => j.set(
            "bins",
            Json::Arr(s.bins.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        SketchValue::MinHash(vals) => {
            j.set("vals", vals.iter().map(|&v| v as usize).collect::<Vec<_>>())
        }
        SketchValue::SimHash(bits) => j.set(
            "bits",
            bits.iter().map(|&b| b as usize).collect::<Vec<_>>(),
        ),
        SketchValue::FeatureHash(out) => j.set(
            "out",
            Json::Arr(out.iter().map(|&v| Json::Num(v)).collect()),
        ),
        SketchValue::BBit(s) => j.set("b", s.b as usize).set(
            "vals",
            s.vals.iter().map(|&v| v as usize).collect::<Vec<_>>(),
        ),
    }
}

/// Decode the [`sketch_value_to_json`] form.
pub fn sketch_value_from_json(j: &Json) -> Result<SketchValue> {
    let scheme = j
        .get("scheme")
        .and_then(Json::as_str)
        .context("missing 'scheme'")?;
    Ok(match scheme {
        "oph" => SketchValue::Oph(OphSketch {
            bins: arr_f64(j, "bins")?.iter().map(|&v| v as u64).collect(),
        }),
        "minhash" => SketchValue::MinHash(arr_u32(j, "vals")?),
        "simhash" => SketchValue::SimHash(
            arr_f64(j, "bits")?.iter().map(|&v| v != 0.0).collect(),
        ),
        "featurehash" => SketchValue::FeatureHash(arr_f64(j, "out")?),
        "bbit" => SketchValue::BBit(BbitSketch {
            b: j.get("b")
                .and_then(Json::as_i64)
                .and_then(|x| u32::try_from(x).ok())
                .context("missing 'b'")?,
            vals: arr_f64(j, "vals")?
                .iter()
                .map(|&v| v as u16)
                .collect(),
        }),
        other => bail!("unknown sketch scheme '{other}' in response"),
    })
}

impl Request {
    /// Decode one wire line.
    pub fn from_json_line(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("parse request json")?;
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .context("missing 'op'")?;
        Ok(match op {
            "fh" => {
                check_keys(&j, op, &["indices", "values"])?;
                Request::FhTransform {
                    indices: arr_u32(&j, "indices")?,
                    values: arr_f64(&j, "values")?,
                }
            }
            "oph" => {
                check_keys(&j, op, &["set"])?;
                Request::OphSketch {
                    set: arr_u32(&j, "set")?,
                }
            }
            "sketch" => {
                check_keys(&j, op, &["set", "spec", "scheme"])?;
                Request::Sketch {
                    set: arr_u32(&j, "set")?,
                    spec: opt_str(&j, "spec")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "insert" => {
                check_keys(&j, op, &["id", "set", "scheme"])?;
                Request::LshInsert {
                    id: j
                        .get("id")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'id'")?,
                    set: arr_u32(&j, "set")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "query" => {
                check_keys(&j, op, &["set", "scheme"])?;
                Request::LshQuery {
                    set: arr_u32(&j, "set")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "delete" => {
                check_keys(&j, op, &["id", "scheme"])?;
                Request::LshDelete {
                    id: j
                        .get("id")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'id'")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "update" => {
                check_keys(&j, op, &["id", "set", "scheme"])?;
                Request::LshUpdate {
                    id: j
                        .get("id")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'id'")?,
                    set: arr_u32(&j, "set")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "query_topk" => {
                check_keys(&j, op, &["set", "k", "scheme"])?;
                Request::LshQueryTopK {
                    set: arr_u32(&j, "set")?,
                    k: j
                        .get("k")
                        .and_then(Json::as_usize)
                        .context("missing 'k'")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "compact" => {
                check_keys(&j, op, &["scheme"])?;
                Request::Compact {
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "estimate" => {
                check_keys(&j, op, &["a", "b", "scheme"])?;
                Request::Estimate {
                    a: j.get("a")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'a'")?,
                    b: j.get("b")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'b'")?,
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "index_doc" => {
                check_keys(&j, op, &["id", "text", "scheme"])?;
                Request::IndexDoc {
                    id: j
                        .get("id")
                        .and_then(Json::as_i64)
                        .and_then(|x| u32::try_from(x).ok())
                        .context("missing 'id'")?,
                    text: j
                        .get("text")
                        .and_then(Json::as_str)
                        .context("missing 'text'")?
                        .to_string(),
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "query_doc" => {
                check_keys(&j, op, &["text", "scheme"])?;
                Request::QueryDoc {
                    text: j
                        .get("text")
                        .and_then(Json::as_str)
                        .context("missing 'text'")?
                        .to_string(),
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "save_index" => {
                check_keys(&j, op, &["path", "scheme"])?;
                Request::SaveIndex {
                    path: j
                        .get("path")
                        .and_then(Json::as_str)
                        .context("missing 'path'")?
                        .to_string(),
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "load_index" => {
                check_keys(&j, op, &["path", "scheme"])?;
                Request::LoadIndex {
                    path: j
                        .get("path")
                        .and_then(Json::as_str)
                        .context("missing 'path'")?
                        .to_string(),
                    scheme: opt_str(&j, "scheme")?,
                }
            }
            "stats" => {
                check_keys(&j, op, &[])?;
                Request::Stats
            }
            other => bail!("unknown op '{other}'"),
        })
    }

    /// Encode for the wire.
    pub fn to_json_line(&self) -> String {
        json::to_string(&self.to_json())
    }

    /// Encode for the wire with a pipeline tag (see [`parse_tagged_request`]).
    pub fn to_json_line_tagged(&self, rid: u64) -> String {
        json::to_string(&self.to_json().set("rid", rid as usize))
    }

    fn to_json(&self) -> Json {
        let j = match self {
            Request::FhTransform { indices, values } => Json::obj()
                .set("op", "fh")
                .set("indices", indices.iter().map(|&x| x as usize).collect::<Vec<_>>())
                .set("values", Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())),
            Request::OphSketch { set } => Json::obj()
                .set("op", "oph")
                .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Request::Sketch { set, spec, scheme } => {
                let mut j = Json::obj()
                    .set("op", "sketch")
                    .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>());
                if let Some(s) = spec {
                    j = j.set("spec", s.as_str());
                }
                if let Some(s) = scheme {
                    j = j.set("scheme", s.as_str());
                }
                j
            }
            Request::LshInsert { id, set, scheme } => {
                let j = Json::obj()
                    .set("op", "insert")
                    .set("id", *id as usize)
                    .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::LshQuery { set, scheme } => {
                let j = Json::obj()
                    .set("op", "query")
                    .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::LshDelete { id, scheme } => {
                let j = Json::obj().set("op", "delete").set("id", *id as usize);
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::LshUpdate { id, set, scheme } => {
                let j = Json::obj()
                    .set("op", "update")
                    .set("id", *id as usize)
                    .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::LshQueryTopK { set, k, scheme } => {
                let j = Json::obj()
                    .set("op", "query_topk")
                    .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>())
                    .set("k", *k);
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::Compact { scheme } => {
                let j = Json::obj().set("op", "compact");
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::Estimate { a, b, scheme } => {
                let j = Json::obj()
                    .set("op", "estimate")
                    .set("a", *a as usize)
                    .set("b", *b as usize);
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::IndexDoc { id, text, scheme } => {
                let j = Json::obj()
                    .set("op", "index_doc")
                    .set("id", *id as usize)
                    .set("text", text.as_str());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::QueryDoc { text, scheme } => {
                let j = Json::obj().set("op", "query_doc").set("text", text.as_str());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::SaveIndex { path, scheme } => {
                let j = Json::obj().set("op", "save_index").set("path", path.as_str());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::LoadIndex { path, scheme } => {
                let j = Json::obj().set("op", "load_index").set("path", path.as_str());
                match scheme {
                    Some(s) => j.set("scheme", s.as_str()),
                    None => j,
                }
            }
            Request::Stats => Json::obj().set("op", "stats"),
        };
        j
    }
}

impl Response {
    pub fn to_json_line(&self) -> String {
        json::to_string(&self.to_json())
    }

    /// Encode for the wire, echoing the request's pipeline tag when it
    /// had one. Untagged responses are byte-identical to the legacy wire
    /// format, so un-pipelined clients never see a `rid` key.
    pub fn to_json_line_tagged(&self, rid: Option<u64>) -> String {
        match rid {
            Some(r) => json::to_string(&self.to_json().set("rid", r as usize)),
            None => self.to_json_line(),
        }
    }

    fn to_json(&self) -> Json {
        let j = match self {
            Response::Fh { out, sqnorm, path } => Json::obj()
                .set("ok", true)
                .set("type", "fh")
                .set(
                    "out",
                    Json::Arr(out.iter().map(|&v| Json::Num(v as f64)).collect()),
                )
                .set("sqnorm", *sqnorm)
                .set(
                    "path",
                    match path {
                        ExecPath::Pjrt => "pjrt",
                        ExecPath::Native => "native",
                    },
                ),
            Response::Sketch { bins } => Json::obj().set("ok", true).set("type", "sketch").set(
                "bins",
                Json::Arr(bins.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
            Response::SketchValue { value } => sketch_value_to_json(value)
                .set("ok", true)
                .set("type", "sketch_value"),
            Response::Inserted { id } => Json::obj()
                .set("ok", true)
                .set("type", "inserted")
                .set("id", *id as usize),
            Response::Candidates { ids } => Json::obj()
                .set("ok", true)
                .set("type", "candidates")
                .set("ids", ids.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Response::Deleted { id, existed } => Json::obj()
                .set("ok", true)
                .set("type", "deleted")
                .set("id", *id as usize)
                .set("existed", *existed),
            Response::Updated { id } => Json::obj()
                .set("ok", true)
                .set("type", "updated")
                .set("id", *id as usize),
            Response::TopK { ids, scores } => Json::obj()
                .set("ok", true)
                .set("type", "topk")
                .set("ids", ids.iter().map(|&x| x as usize).collect::<Vec<_>>())
                .set(
                    "scores",
                    Json::Arr(scores.iter().map(|&v| Json::Num(v)).collect()),
                ),
            Response::Compacted { purged } => Json::obj()
                .set("ok", true)
                .set("type", "compacted")
                .set("purged", *purged),
            Response::Estimate { jaccard } => Json::obj()
                .set("ok", true)
                .set("type", "estimate")
                .set("jaccard", *jaccard),
            Response::Saved { path, entries } => Json::obj()
                .set("ok", true)
                .set("type", "saved")
                .set("path", path.as_str())
                .set("entries", *entries),
            Response::Loaded {
                path,
                entries,
                shards,
            } => Json::obj()
                .set("ok", true)
                .set("type", "loaded")
                .set("path", path.as_str())
                .set("entries", *entries)
                .set("shards", *shards),
            Response::Stats { json } => Json::obj()
                .set("ok", true)
                .set("type", "stats")
                .set("stats", json.clone()),
            Response::Error { message } => {
                Json::obj().set("ok", false).set("error", message.as_str())
            }
        };
        j
    }

    /// Decode one wire line plus its pipeline tag (client side). A
    /// response without a `rid` key yields `None` — either the request
    /// was untagged, or the server is pre-pipelining.
    pub fn from_json_line_tagged(line: &str) -> Result<(Option<u64>, Response)> {
        let j = Json::parse(line).context("parse response json")?;
        let rid = j
            .get("rid")
            .and_then(Json::as_i64)
            .and_then(|x| u64::try_from(x).ok());
        Ok((rid, Response::from_json_line(line)?))
    }

    /// Decode one wire line (client side).
    pub fn from_json_line(line: &str) -> Result<Response> {
        let j = Json::parse(line).context("parse response json")?;
        if j.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown error")
                .to_string();
            return Ok(Response::Error { message: msg });
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .context("missing 'type'")?;
        Ok(match ty {
            "fh" => Response::Fh {
                out: j
                    .get("out")
                    .and_then(Json::as_arr)
                    .context("missing out")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                    .collect(),
                sqnorm: j.get("sqnorm").and_then(Json::as_f64).context("sqnorm")?,
                path: match j.get("path").and_then(Json::as_str) {
                    Some("pjrt") => ExecPath::Pjrt,
                    _ => ExecPath::Native,
                },
            },
            "sketch" => Response::Sketch {
                bins: j
                    .get("bins")
                    .and_then(Json::as_arr)
                    .context("missing bins")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as u64)
                    .collect(),
            },
            "sketch_value" => Response::SketchValue {
                value: sketch_value_from_json(&j)?,
            },
            "inserted" => Response::Inserted {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("id")?,
            },
            "candidates" => Response::Candidates {
                ids: arr_u32(&j, "ids")?,
            },
            "deleted" => Response::Deleted {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("id")?,
                existed: j
                    .get("existed")
                    .and_then(Json::as_bool)
                    .context("existed")?,
            },
            "updated" => Response::Updated {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("id")?,
            },
            "topk" => {
                let ids = arr_u32(&j, "ids")?;
                let scores = arr_f64(&j, "scores")?;
                if ids.len() != scores.len() {
                    bail!("topk ids/scores length mismatch");
                }
                Response::TopK { ids, scores }
            }
            "compacted" => Response::Compacted {
                purged: j
                    .get("purged")
                    .and_then(Json::as_usize)
                    .context("purged")?,
            },
            "estimate" => Response::Estimate {
                jaccard: j.get("jaccard").and_then(Json::as_f64).context("jaccard")?,
            },
            "saved" => Response::Saved {
                path: j
                    .get("path")
                    .and_then(Json::as_str)
                    .context("path")?
                    .to_string(),
                entries: j
                    .get("entries")
                    .and_then(Json::as_usize)
                    .context("entries")?,
            },
            "loaded" => Response::Loaded {
                path: j
                    .get("path")
                    .and_then(Json::as_str)
                    .context("path")?
                    .to_string(),
                entries: j
                    .get("entries")
                    .and_then(Json::as_usize)
                    .context("entries")?,
                shards: j
                    .get("shards")
                    .and_then(Json::as_usize)
                    .context("shards")?,
            },
            "stats" => Response::Stats {
                json: j.get("stats").cloned().unwrap_or(Json::Null),
            },
            other => bail!("unknown response type '{other}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::FhTransform {
                indices: vec![1, 5, 9],
                values: vec![0.5, -1.0, 2.0],
            },
            Request::OphSketch { set: vec![7, 8, 9] },
            Request::Sketch {
                set: vec![1, 2, 3],
                spec: None,
                scheme: None,
            },
            Request::Sketch {
                set: vec![4, 5],
                spec: Some("minhash(k=16,hash=murmur3,seed=7)".into()),
                scheme: None,
            },
            Request::Sketch {
                set: vec![6],
                spec: None,
                scheme: Some("fast".into()),
            },
            Request::LshInsert {
                id: 3,
                set: vec![1, 2],
                scheme: None,
            },
            Request::LshInsert {
                id: 4,
                set: vec![3],
                scheme: Some("fast".into()),
            },
            Request::LshQuery {
                set: vec![4],
                scheme: None,
            },
            Request::LshQuery {
                set: vec![5],
                scheme: Some("fast".into()),
            },
            Request::LshDelete {
                id: 6,
                scheme: None,
            },
            Request::LshDelete {
                id: 7,
                scheme: Some("fast".into()),
            },
            Request::LshUpdate {
                id: 8,
                set: vec![9, 10],
                scheme: None,
            },
            Request::LshUpdate {
                id: 9,
                set: vec![11],
                scheme: Some("fast".into()),
            },
            Request::LshQueryTopK {
                set: vec![1, 2],
                k: 10,
                scheme: None,
            },
            Request::LshQueryTopK {
                set: vec![3],
                k: 1,
                scheme: Some("fast".into()),
            },
            Request::Compact { scheme: None },
            Request::Compact {
                scheme: Some("fast".into()),
            },
            Request::Estimate {
                a: 1,
                b: 2,
                scheme: None,
            },
            Request::Estimate {
                a: 3,
                b: 4,
                scheme: Some("fast".into()),
            },
            Request::IndexDoc {
                id: 7,
                text: "the quick brown fox".into(),
                scheme: None,
            },
            Request::IndexDoc {
                id: 8,
                text: "jumps over".into(),
                scheme: Some("fast".into()),
            },
            Request::QueryDoc {
                text: "lazy dog".into(),
                scheme: None,
            },
            Request::QueryDoc {
                text: "lazy dog".into(),
                scheme: Some("fast".into()),
            },
            Request::SaveIndex {
                path: "/tmp/x.mxls".into(),
                scheme: None,
            },
            Request::SaveIndex {
                path: "/tmp/x.mxsh".into(),
                scheme: Some("fast".into()),
            },
            Request::LoadIndex {
                path: "/tmp/x.mxls".into(),
                scheme: None,
            },
            Request::LoadIndex {
                path: "/tmp/x.mxsh".into(),
                scheme: Some("fast".into()),
            },
            Request::Stats,
        ];
        for r in reqs {
            let line = r.to_json_line();
            assert!(!line.contains('\n'));
            let back = Request::from_json_line(&line).unwrap();
            assert_eq!(back, r, "line: {line}");
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Fh {
                out: vec![1.0, -0.5],
                sqnorm: 1.25,
                path: ExecPath::Pjrt,
            },
            Response::Sketch { bins: vec![5, 1 << 40] },
            Response::SketchValue {
                value: SketchValue::Oph(OphSketch {
                    bins: vec![5, 1 << 40],
                }),
            },
            Response::SketchValue {
                value: SketchValue::MinHash(vec![1, u32::MAX, 42]),
            },
            Response::SketchValue {
                value: SketchValue::SimHash(vec![true, false, true]),
            },
            Response::SketchValue {
                value: SketchValue::FeatureHash(vec![1.5, -0.25, 0.0]),
            },
            Response::SketchValue {
                value: SketchValue::BBit(BbitSketch {
                    b: 2,
                    vals: vec![0, 3, 1 << 2],
                }),
            },
            Response::Inserted { id: 9 },
            Response::Candidates { ids: vec![1, 2, 3] },
            Response::Deleted {
                id: 4,
                existed: true,
            },
            Response::Deleted {
                id: 5,
                existed: false,
            },
            Response::Updated { id: 6 },
            Response::TopK {
                ids: vec![3, 1, 2],
                scores: vec![1.0, 0.5, 0.25],
            },
            Response::TopK {
                ids: vec![],
                scores: vec![],
            },
            Response::Compacted { purged: 96 },
            Response::Estimate { jaccard: 0.75 },
            Response::Saved {
                path: "/tmp/x.mxls".into(),
                entries: 12,
            },
            Response::Loaded {
                path: "/tmp/x.mxsh".into(),
                entries: 12,
                shards: 3,
            },
            Response::Error {
                message: "nope".into(),
            },
        ];
        for r in resps {
            let line = r.to_json_line();
            let back = Response::from_json_line(&line).unwrap();
            assert_eq!(back, r, "line: {line}");
        }
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(Request::from_json_line("{}").is_err());
        assert!(Request::from_json_line("{\"op\":\"zzz\"}").is_err());
        assert!(Request::from_json_line("{\"op\":\"fh\"}").is_err());
        assert!(Request::from_json_line("not json").is_err());
        // Negative ids rejected.
        assert!(Request::from_json_line("{\"op\":\"insert\",\"id\":-1,\"set\":[]}").is_err());
        assert!(Request::from_json_line("{\"op\":\"delete\",\"id\":-1}").is_err());
        assert!(Request::from_json_line("{\"op\":\"update\",\"id\":-1,\"set\":[1]}").is_err());
        // The mutation ops require their payload fields.
        assert!(Request::from_json_line("{\"op\":\"delete\"}").is_err());
        assert!(Request::from_json_line("{\"op\":\"update\",\"id\":1}").is_err());
        assert!(Request::from_json_line("{\"op\":\"query_topk\",\"set\":[1]}").is_err());
        assert!(Request::from_json_line("{\"op\":\"query_topk\",\"k\":3}").is_err());
        // Mismatched topk response arrays are rejected client-side.
        assert!(Response::from_json_line(
            "{\"ok\":true,\"type\":\"topk\",\"ids\":[1,2],\"scores\":[0.5]}"
        )
        .is_err());
        // Scheme-aware sketch: missing set / unknown scheme rejected.
        assert!(Request::from_json_line("{\"op\":\"sketch\"}").is_err());
        // A non-string spec/scheme is an error, not a fallback to the default.
        assert!(Request::from_json_line("{\"op\":\"sketch\",\"set\":[1],\"spec\":42}").is_err());
        assert!(Request::from_json_line("{\"op\":\"sketch\",\"set\":[1],\"scheme\":42}").is_err());
        assert!(
            Request::from_json_line("{\"op\":\"insert\",\"id\":1,\"set\":[1],\"scheme\":42}")
                .is_err()
        );
        assert!(Request::from_json_line("{\"op\":\"query\",\"set\":[1],\"scheme\":42}").is_err());
        // An explicit null spec/scheme means "use the default".
        let r =
            Request::from_json_line("{\"op\":\"sketch\",\"set\":[1],\"spec\":null,\"scheme\":null}")
                .unwrap();
        assert_eq!(
            r,
            Request::Sketch {
                set: vec![1],
                spec: None,
                scheme: None
            }
        );
        // The persistence/estimate ops honour and validate `scheme` too.
        let r = Request::from_json_line(
            "{\"op\":\"estimate\",\"a\":1,\"b\":2,\"scheme\":\"fast\"}",
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Estimate {
                a: 1,
                b: 2,
                scheme: Some("fast".into())
            }
        );
        assert!(
            Request::from_json_line("{\"op\":\"estimate\",\"a\":1,\"b\":2,\"scheme\":42}").is_err()
        );
        assert!(
            Request::from_json_line("{\"op\":\"save_index\",\"path\":\"p\",\"scheme\":42}")
                .is_err()
        );
        assert!(
            Request::from_json_line("{\"op\":\"load_index\",\"path\":\"p\",\"scheme\":42}")
                .is_err()
        );
        assert!(Request::from_json_line("{\"op\":\"load_index\"}").is_err());
        // Unknown fields are rejected on every op — a mistyped `scheme`
        // must not silently serve the default.
        for bad in [
            "{\"op\":\"estimate\",\"a\":1,\"b\":2,\"shceme\":\"fast\"}",
            "{\"op\":\"estimate\",\"a\":1,\"b\":2,\"spec\":\"oph(k=8)\"}",
            "{\"op\":\"sketch\",\"set\":[1],\"Scheme\":\"fast\"}",
            "{\"op\":\"insert\",\"id\":1,\"set\":[1],\"shard\":0}",
            "{\"op\":\"query\",\"set\":[1],\"schemes\":\"fast\"}",
            "{\"op\":\"index_doc\",\"id\":1,\"text\":\"t\",\"shceme\":\"x\"}",
            "{\"op\":\"query_doc\",\"text\":\"t\",\"shceme\":\"x\"}",
            "{\"op\":\"save_index\",\"path\":\"p\",\"wibble\":1}",
            "{\"op\":\"load_index\",\"path\":\"p\",\"wibble\":1}",
            "{\"op\":\"delete\",\"id\":1,\"set\":[2]}",
            "{\"op\":\"delete\",\"id\":1,\"shceme\":\"fast\"}",
            "{\"op\":\"update\",\"id\":1,\"set\":[1],\"k\":3}",
            "{\"op\":\"query_topk\",\"set\":[1],\"k\":3,\"spec\":\"oph(k=8)\"}",
            "{\"op\":\"compact\",\"path\":\"p\"}",
            "{\"op\":\"oph\",\"set\":[1],\"scheme\":\"fast\"}",
            "{\"op\":\"stats\",\"scheme\":\"fast\"}",
            "{\"op\":\"fh\",\"indices\":[1],\"values\":[1.0],\"scheme\":\"x\"}",
        ] {
            assert!(Request::from_json_line(bad).is_err(), "accepted: {bad}");
        }
        assert!(
            Response::from_json_line("{\"ok\":true,\"type\":\"sketch_value\",\"scheme\":\"zzz\"}")
                .is_err()
        );
    }

    /// The pipeline tag: legal on every op, echoed on the response,
    /// invisible when absent.
    #[test]
    fn rid_tag_roundtrip() {
        // Every op accepts `rid`.
        for (line, rid) in [
            ("{\"op\":\"stats\",\"rid\":7}", Some(7)),
            ("{\"op\":\"oph\",\"set\":[1],\"rid\":0}", Some(0)),
            ("{\"op\":\"sketch\",\"set\":[1],\"rid\":9007199254740991}", Some((1u64 << 53) - 1)),
            ("{\"op\":\"insert\",\"id\":1,\"set\":[2],\"rid\":3}", Some(3)),
            ("{\"op\":\"query\",\"set\":[2]}", None),
            ("{\"op\":\"stats\",\"rid\":null}", None),
        ] {
            let (got, req) = parse_tagged_request(line);
            assert_eq!(got, rid, "line: {line}");
            assert!(req.is_ok(), "line: {line}");
        }
        // The tag survives a malformed body — the server needs it to
        // route the error response.
        let (rid, req) = parse_tagged_request("{\"op\":\"sketch\",\"rid\":4}");
        assert_eq!(rid, Some(4));
        assert!(req.is_err());
        // An invalid tag is itself the error.
        for bad in [
            "{\"op\":\"stats\",\"rid\":-1}",
            "{\"op\":\"stats\",\"rid\":\"x\"}",
            "{\"op\":\"stats\",\"rid\":1.5}",
        ] {
            let (rid, req) = parse_tagged_request(bad);
            assert_eq!(rid, None, "line: {bad}");
            assert!(req.is_err(), "accepted: {bad}");
        }
        // Request-side tagged encode round-trips.
        let req = Request::LshQuery {
            set: vec![4, 5],
            scheme: Some("fast".into()),
        };
        let line = req.to_json_line_tagged(42);
        let (rid, back) = parse_tagged_request(&line);
        assert_eq!(rid, Some(42));
        assert_eq!(back.unwrap(), req);
        // Response-side: tag echoed when present, absent otherwise.
        let resp = Response::Candidates { ids: vec![1, 2] };
        let line = resp.to_json_line_tagged(Some(42));
        let (rid, back) = Response::from_json_line_tagged(&line).unwrap();
        assert_eq!((rid, back), (Some(42), resp.clone()));
        let line = resp.to_json_line_tagged(None);
        assert!(!line.contains("rid"), "line: {line}");
        assert_eq!(line, resp.to_json_line());
        let (rid, back) = Response::from_json_line_tagged(&line).unwrap();
        assert_eq!((rid, back), (None, resp));
        // Error responses echo the tag too.
        let err = Response::Error { message: "nope".into() };
        let (rid, back) =
            Response::from_json_line_tagged(&err.to_json_line_tagged(Some(7))).unwrap();
        assert_eq!((rid, back), (Some(7), err));
    }

    /// The pre-spec `oph` op and `sketch` response type stay wire-stable —
    /// the compatibility-alias contract for existing clients.
    #[test]
    fn oph_compatibility_alias_wire_format() {
        let req = Request::OphSketch { set: vec![1, 2, 3] };
        let line = req.to_json_line();
        assert!(line.contains("\"op\":\"oph\""), "line: {line}");
        assert_eq!(Request::from_json_line(&line).unwrap(), req);

        let resp = Response::Sketch { bins: vec![4, 5] };
        let line = resp.to_json_line();
        assert!(line.contains("\"type\":\"sketch\""), "line: {line}");
        assert_eq!(Response::from_json_line(&line).unwrap(), resp);

        // Pre-scheme `insert`/`query` lines (no `scheme` key) still decode,
        // selecting the default scheme.
        let r = Request::from_json_line("{\"op\":\"insert\",\"id\":1,\"set\":[2,3]}").unwrap();
        assert_eq!(
            r,
            Request::LshInsert {
                id: 1,
                set: vec![2, 3],
                scheme: None
            }
        );
        let r = Request::from_json_line("{\"op\":\"query\",\"set\":[2]}").unwrap();
        assert_eq!(
            r,
            Request::LshQuery {
                set: vec![2],
                scheme: None
            }
        );
        // Pre-scheme `estimate`/`index_doc`/`query_doc`/`save_index`
        // lines (no `scheme` key) still decode to the default scheme.
        let r = Request::from_json_line("{\"op\":\"estimate\",\"a\":1,\"b\":2}").unwrap();
        assert_eq!(
            r,
            Request::Estimate {
                a: 1,
                b: 2,
                scheme: None
            }
        );
        let r = Request::from_json_line("{\"op\":\"save_index\",\"path\":\"/tmp/x\"}").unwrap();
        assert_eq!(
            r,
            Request::SaveIndex {
                path: "/tmp/x".into(),
                scheme: None
            }
        );
        let r = Request::from_json_line("{\"op\":\"index_doc\",\"id\":1,\"text\":\"t\"}").unwrap();
        assert_eq!(
            r,
            Request::IndexDoc {
                id: 1,
                text: "t".into(),
                scheme: None
            }
        );
        let r = Request::from_json_line("{\"op\":\"query_doc\",\"text\":\"t\"}").unwrap();
        assert_eq!(
            r,
            Request::QueryDoc {
                text: "t".into(),
                scheme: None
            }
        );

        // And the new endpoint round-trips a spec string untouched.
        let spec = "oph(k=200,layout=mod,densify=paper,hash=mixed_tab,seed=42)";
        let req = Request::Sketch {
            set: vec![9],
            spec: Some(spec.into()),
            scheme: None,
        };
        let back = Request::from_json_line(&req.to_json_line()).unwrap();
        assert_eq!(back, req);
    }
}
