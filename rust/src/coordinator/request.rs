//! Typed requests/responses + the newline-delimited JSON wire codec used by
//! the TCP front-end and the examples.

use crate::util::json::{self, Json};
use crate::util::error::{bail, Context, Result};

/// A client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Feature-hash a sparse vector; returns the dense d'-vector + ‖v′‖².
    FhTransform { indices: Vec<u32>, values: Vec<f64> },
    /// OPH-sketch a set; returns the densified bins.
    OphSketch { set: Vec<u32> },
    /// Insert a set into the LSH index (also stores it for `Estimate`).
    LshInsert { id: u32, set: Vec<u32> },
    /// Query the LSH index; returns candidate ids.
    LshQuery { set: Vec<u32> },
    /// Estimate J between two stored ids from their sketches.
    Estimate { a: u32, b: u32 },
    /// Shingle a raw document (w = 5 bytes) and insert it into the LSH
    /// index — the ingest path of a dedup/search service.
    IndexDoc { id: u32, text: String },
    /// Shingle a raw document and query the LSH index.
    QueryDoc { text: String },
    /// Snapshot the LSH index to a server-side path.
    SaveIndex { path: String },
    /// Service statistics snapshot.
    Stats,
}

/// Which execution path served an FH request (observable for tests/metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    Pjrt,
    Native,
}

/// A service response.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Fh {
        out: Vec<f32>,
        sqnorm: f64,
        path: ExecPath,
    },
    Sketch {
        bins: Vec<u64>,
    },
    Inserted {
        id: u32,
    },
    Candidates {
        ids: Vec<u32>,
    },
    Estimate {
        jaccard: f64,
    },
    Saved {
        path: String,
        entries: usize,
    },
    Stats {
        json: Json,
    },
    Error {
        message: String,
    },
}

fn arr_u32(j: &Json, key: &str) -> Result<Vec<u32>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array '{key}'"))?
        .iter()
        .map(|v| {
            v.as_i64()
                .and_then(|x| u32::try_from(x).ok())
                .with_context(|| format!("bad u32 in '{key}'"))
        })
        .collect()
}

fn arr_f64(j: &Json, key: &str) -> Result<Vec<f64>> {
    j.get(key)
        .and_then(Json::as_arr)
        .with_context(|| format!("missing array '{key}'"))?
        .iter()
        .map(|v| v.as_f64().with_context(|| format!("bad number in '{key}'")))
        .collect()
}

impl Request {
    /// Decode one wire line.
    pub fn from_json_line(line: &str) -> Result<Request> {
        let j = Json::parse(line).context("parse request json")?;
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .context("missing 'op'")?;
        Ok(match op {
            "fh" => Request::FhTransform {
                indices: arr_u32(&j, "indices")?,
                values: arr_f64(&j, "values")?,
            },
            "oph" => Request::OphSketch {
                set: arr_u32(&j, "set")?,
            },
            "insert" => Request::LshInsert {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("missing 'id'")?,
                set: arr_u32(&j, "set")?,
            },
            "query" => Request::LshQuery {
                set: arr_u32(&j, "set")?,
            },
            "estimate" => Request::Estimate {
                a: j.get("a")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("missing 'a'")?,
                b: j.get("b")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("missing 'b'")?,
            },
            "index_doc" => Request::IndexDoc {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("missing 'id'")?,
                text: j
                    .get("text")
                    .and_then(Json::as_str)
                    .context("missing 'text'")?
                    .to_string(),
            },
            "query_doc" => Request::QueryDoc {
                text: j
                    .get("text")
                    .and_then(Json::as_str)
                    .context("missing 'text'")?
                    .to_string(),
            },
            "save_index" => Request::SaveIndex {
                path: j
                    .get("path")
                    .and_then(Json::as_str)
                    .context("missing 'path'")?
                    .to_string(),
            },
            "stats" => Request::Stats,
            other => bail!("unknown op '{other}'"),
        })
    }

    /// Encode for the wire.
    pub fn to_json_line(&self) -> String {
        let j = match self {
            Request::FhTransform { indices, values } => Json::obj()
                .set("op", "fh")
                .set("indices", indices.iter().map(|&x| x as usize).collect::<Vec<_>>())
                .set("values", Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())),
            Request::OphSketch { set } => Json::obj()
                .set("op", "oph")
                .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Request::LshInsert { id, set } => Json::obj()
                .set("op", "insert")
                .set("id", *id as usize)
                .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Request::LshQuery { set } => Json::obj()
                .set("op", "query")
                .set("set", set.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Request::Estimate { a, b } => Json::obj()
                .set("op", "estimate")
                .set("a", *a as usize)
                .set("b", *b as usize),
            Request::IndexDoc { id, text } => Json::obj()
                .set("op", "index_doc")
                .set("id", *id as usize)
                .set("text", text.as_str()),
            Request::QueryDoc { text } => {
                Json::obj().set("op", "query_doc").set("text", text.as_str())
            }
            Request::SaveIndex { path } => {
                Json::obj().set("op", "save_index").set("path", path.as_str())
            }
            Request::Stats => Json::obj().set("op", "stats"),
        };
        json::to_string(&j)
    }
}

impl Response {
    pub fn to_json_line(&self) -> String {
        let j = match self {
            Response::Fh { out, sqnorm, path } => Json::obj()
                .set("ok", true)
                .set("type", "fh")
                .set(
                    "out",
                    Json::Arr(out.iter().map(|&v| Json::Num(v as f64)).collect()),
                )
                .set("sqnorm", *sqnorm)
                .set(
                    "path",
                    match path {
                        ExecPath::Pjrt => "pjrt",
                        ExecPath::Native => "native",
                    },
                ),
            Response::Sketch { bins } => Json::obj().set("ok", true).set("type", "sketch").set(
                "bins",
                Json::Arr(bins.iter().map(|&v| Json::Num(v as f64)).collect()),
            ),
            Response::Inserted { id } => Json::obj()
                .set("ok", true)
                .set("type", "inserted")
                .set("id", *id as usize),
            Response::Candidates { ids } => Json::obj()
                .set("ok", true)
                .set("type", "candidates")
                .set("ids", ids.iter().map(|&x| x as usize).collect::<Vec<_>>()),
            Response::Estimate { jaccard } => Json::obj()
                .set("ok", true)
                .set("type", "estimate")
                .set("jaccard", *jaccard),
            Response::Saved { path, entries } => Json::obj()
                .set("ok", true)
                .set("type", "saved")
                .set("path", path.as_str())
                .set("entries", *entries),
            Response::Stats { json } => Json::obj()
                .set("ok", true)
                .set("type", "stats")
                .set("stats", json.clone()),
            Response::Error { message } => {
                Json::obj().set("ok", false).set("error", message.as_str())
            }
        };
        json::to_string(&j)
    }

    /// Decode one wire line (client side).
    pub fn from_json_line(line: &str) -> Result<Response> {
        let j = Json::parse(line).context("parse response json")?;
        if j.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unknown error")
                .to_string();
            return Ok(Response::Error { message: msg });
        }
        let ty = j
            .get("type")
            .and_then(Json::as_str)
            .context("missing 'type'")?;
        Ok(match ty {
            "fh" => Response::Fh {
                out: j
                    .get("out")
                    .and_then(Json::as_arr)
                    .context("missing out")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(f64::NAN) as f32)
                    .collect(),
                sqnorm: j.get("sqnorm").and_then(Json::as_f64).context("sqnorm")?,
                path: match j.get("path").and_then(Json::as_str) {
                    Some("pjrt") => ExecPath::Pjrt,
                    _ => ExecPath::Native,
                },
            },
            "sketch" => Response::Sketch {
                bins: j
                    .get("bins")
                    .and_then(Json::as_arr)
                    .context("missing bins")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0) as u64)
                    .collect(),
            },
            "inserted" => Response::Inserted {
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .and_then(|x| u32::try_from(x).ok())
                    .context("id")?,
            },
            "candidates" => Response::Candidates {
                ids: arr_u32(&j, "ids")?,
            },
            "estimate" => Response::Estimate {
                jaccard: j.get("jaccard").and_then(Json::as_f64).context("jaccard")?,
            },
            "saved" => Response::Saved {
                path: j
                    .get("path")
                    .and_then(Json::as_str)
                    .context("path")?
                    .to_string(),
                entries: j
                    .get("entries")
                    .and_then(Json::as_usize)
                    .context("entries")?,
            },
            "stats" => Response::Stats {
                json: j.get("stats").cloned().unwrap_or(Json::Null),
            },
            other => bail!("unknown response type '{other}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            Request::FhTransform {
                indices: vec![1, 5, 9],
                values: vec![0.5, -1.0, 2.0],
            },
            Request::OphSketch { set: vec![7, 8, 9] },
            Request::LshInsert {
                id: 3,
                set: vec![1, 2],
            },
            Request::LshQuery { set: vec![4] },
            Request::Estimate { a: 1, b: 2 },
            Request::IndexDoc {
                id: 7,
                text: "the quick brown fox".into(),
            },
            Request::QueryDoc {
                text: "lazy dog".into(),
            },
            Request::SaveIndex {
                path: "/tmp/x.mxls".into(),
            },
            Request::Stats,
        ];
        for r in reqs {
            let line = r.to_json_line();
            assert!(!line.contains('\n'));
            let back = Request::from_json_line(&line).unwrap();
            assert_eq!(back, r, "line: {line}");
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = vec![
            Response::Fh {
                out: vec![1.0, -0.5],
                sqnorm: 1.25,
                path: ExecPath::Pjrt,
            },
            Response::Sketch { bins: vec![5, 1 << 40] },
            Response::Inserted { id: 9 },
            Response::Candidates { ids: vec![1, 2, 3] },
            Response::Estimate { jaccard: 0.75 },
            Response::Saved {
                path: "/tmp/x.mxls".into(),
                entries: 12,
            },
            Response::Error {
                message: "nope".into(),
            },
        ];
        for r in resps {
            let line = r.to_json_line();
            let back = Response::from_json_line(&line).unwrap();
            assert_eq!(back, r, "line: {line}");
        }
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(Request::from_json_line("{}").is_err());
        assert!(Request::from_json_line("{\"op\":\"zzz\"}").is_err());
        assert!(Request::from_json_line("{\"op\":\"fh\"}").is_err());
        assert!(Request::from_json_line("not json").is_err());
        // Negative ids rejected.
        assert!(Request::from_json_line("{\"op\":\"insert\",\"id\":-1,\"set\":[]}").is_err());
    }
}
