//! Dynamic batchers: FH rows for PJRT, and wire ops across connections.
//!
//! [`FhBatcher`]: the PJRT artifacts are compiled for a fixed `[batch, nnz]`
//! shape, so the batcher's job is classic serving-systems work: accumulate
//! single-row requests into a full batch, dispatch when the batch fills
//! **or** the oldest request has waited `max_delay_us` (bounded tail
//! latency), pad the remainder, and scatter per-row results back to the
//! waiting callers.
//!
//! [`OpBatcher`] generalises the same fill-or-deadline loop to whole wire
//! ops (`sketch`/`insert`/`query`), so requests from *different*
//! connections coalesce into batched coordinator calls. It is generic over
//! an [`OpExecutor`] so the deterministic test harness can inject gating
//! and counting executors.
//!
//! Backpressure: both submit queues are bounded; when the consumer falls
//! behind, `submit` fails fast and the caller runs the bit-compatible
//! direct path instead — load shedding rather than queue collapse.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Response;
use crate::runtime::artifact::ArtifactKind;
use crate::runtime::executor::ExecutorHandle;
use crate::util::error::{format_err, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One row's result: the dense output and its squared norm.
pub type RowResult = Result<(Vec<f32>, f64)>;

struct RowJob {
    /// Padded to exactly `nnz` by `submit`.
    bins: Vec<i32>,
    vals: Vec<f32>,
    reply: Sender<RowResult>,
}

/// Handle to the batcher thread.
pub struct FhBatcher {
    tx: SyncSender<RowJob>,
    batch: usize,
    nnz: usize,
    dim: usize,
}

impl FhBatcher {
    /// Spawn the batcher for one FH artifact.
    pub fn spawn(
        executor: Arc<ExecutorHandle>,
        artifact_name: &str,
        kind: ArtifactKind,
        max_delay_us: u64,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Result<Self> {
        let ArtifactKind::Fh { batch, nnz, dim } = kind else {
            return Err(format_err!("batcher needs an fh artifact"));
        };
        let (tx, rx) = std::sync::mpsc::sync_channel::<RowJob>(queue_cap);
        let name = artifact_name.to_string();
        std::thread::Builder::new()
            .name("mixtab-batcher".into())
            .spawn(move || {
                batcher_loop(executor, name, batch, nnz, dim, max_delay_us, rx, metrics)
            })
            .expect("spawn batcher");
        Ok(Self {
            tx,
            batch,
            nnz,
            dim,
        })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn max_nnz(&self) -> usize {
        self.nnz
    }

    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Submit one row (already hashed to (bin, signed-value) pairs).
    /// Returns a receiver for the row result, or `None` when the queue is
    /// full or the row exceeds the compiled nnz bound — callers then take
    /// the native path.
    pub fn submit(&self, mut bins: Vec<i32>, mut vals: Vec<f32>) -> Option<Receiver<RowResult>> {
        if bins.len() > self.nnz || bins.len() != vals.len() {
            return None;
        }
        bins.resize(self.nnz, 0);
        vals.resize(self.nnz, 0.0);
        let (reply, rx) = channel();
        match self.tx.try_send(RowJob { bins, vals, reply }) {
            Ok(()) => Some(rx),
            Err(TrySendError::Full(_) | TrySendError::Disconnected(_)) => None,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    executor: Arc<ExecutorHandle>,
    name: String,
    batch: usize,
    nnz: usize,
    dim: usize,
    max_delay_us: u64,
    rx: Receiver<RowJob>,
    metrics: Arc<Metrics>,
) {
    let max_delay = Duration::from_micros(max_delay_us);
    loop {
        // Block for the first row of the next batch.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // all senders dropped — shut down
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + max_delay;
        while jobs.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Assemble the padded batch.
        let rows = jobs.len();
        let mut bins = Vec::with_capacity(batch * nnz);
        let mut vals = Vec::with_capacity(batch * nnz);
        for j in &jobs {
            bins.extend_from_slice(&j.bins);
            vals.extend_from_slice(&j.vals);
        }
        bins.resize(batch * nnz, 0);
        vals.resize(batch * nnz, 0.0);

        Metrics::inc(&metrics.pjrt_batches);
        Metrics::add(&metrics.pjrt_batch_rows, rows as u64);

        match executor.run_fh(&name, bins, vals) {
            Ok(out) => {
                for (r, job) in jobs.into_iter().enumerate() {
                    let row = out.out[r * dim..(r + 1) * dim].to_vec();
                    let sq = out.sqnorm[r] as f64;
                    let _ = job.reply.send(Ok((row, sq)));
                }
            }
            Err(e) => {
                let msg = format!("pjrt batch failed: {e}");
                for job in jobs {
                    let _ = job.reply.send(Err(format_err!("{msg}")));
                }
            }
        }
    }
}

/// A batchable wire op: the scheme-routed subset of the protocol whose
/// batched execution is bit-identical to per-request serving (ad-hoc-spec
/// sketches, doc ops, persistence, and stats stay on the direct path).
#[derive(Debug, Clone, PartialEq)]
pub enum BatchOp {
    Sketch { set: Vec<u32> },
    Insert { id: u32, set: Vec<u32> },
    Query { set: Vec<u32> },
    Delete { id: u32 },
    Update { id: u32, set: Vec<u32> },
}

/// One queued op plus its completion callback. The callback is invoked
/// exactly once with the op's response — by the executor on the batch
/// path, or by the caller after a shed.
pub struct OpJob {
    /// Scheme selector as it appeared on the wire (`None` = default).
    pub scheme: Option<String>,
    pub op: BatchOp,
    pub done: Box<dyn FnOnce(Response) + Send + 'static>,
}

impl OpJob {
    /// Deliver the response, consuming the job.
    pub fn complete(self, resp: Response) {
        (self.done)(resp);
    }
}

/// Executes one collected batch, completing every job. Implementors must
/// not panic (the coordinator's no-panic request invariant) and must
/// complete every job exactly once — a dropped callback leaves the
/// connection's pending slot occupied forever.
pub trait OpExecutor: Send + Sync + 'static {
    fn run_ops(&self, jobs: Vec<OpJob>);
}

/// Cross-connection op batcher: the [`FhBatcher`] fill-or-deadline loop,
/// lifted from FH rows to whole wire ops.
pub struct OpBatcher {
    /// `Some` until drop; taken then so the loop's `recv` sees
    /// disconnection and drains.
    tx: Option<SyncSender<OpJob>>,
    join: Option<JoinHandle<()>>,
}

impl OpBatcher {
    /// Spawn the batcher thread. `max_batch >= 1`; `queue_cap` bounds the
    /// submit queue (overflow sheds to the caller).
    pub fn spawn(
        executor: Arc<dyn OpExecutor>,
        max_batch: usize,
        max_delay_us: u64,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        assert!(max_batch >= 1, "op batcher needs max_batch >= 1");
        let (tx, rx) = std::sync::mpsc::sync_channel::<OpJob>(queue_cap);
        let join = std::thread::Builder::new()
            .name("mixtab-op-batcher".into())
            .spawn(move || op_batcher_loop(executor, max_batch, max_delay_us, rx, metrics))
            .expect("spawn op batcher");
        Self {
            tx: Some(tx),
            join: Some(join),
        }
    }

    /// Submit one op. On a full (or shut-down) queue the job is handed
    /// back so the caller can run it on the direct path — load shedding,
    /// never silent loss.
    pub fn submit(&self, job: OpJob) -> std::result::Result<(), OpJob> {
        let tx = self.tx.as_ref().expect("op batcher sender taken");
        match tx.try_send(job) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(j) | TrySendError::Disconnected(j)) => Err(j),
        }
    }
}

impl Drop for OpBatcher {
    /// Drain-on-shutdown: dropping the sender lets the loop's `recv` keep
    /// returning already-queued jobs until the channel is empty, so every
    /// accepted op is still executed and completed before the thread exits.
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn op_batcher_loop(
    executor: Arc<dyn OpExecutor>,
    max_batch: usize,
    max_delay_us: u64,
    rx: Receiver<OpJob>,
    metrics: Arc<Metrics>,
) {
    let max_delay = Duration::from_micros(max_delay_us);
    loop {
        // Block for the first op of the next batch.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // sender dropped and queue drained — shut down
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + max_delay;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Metrics::inc(&metrics.op_batches);
        Metrics::add(&metrics.op_batch_rows, jobs.len() as u64);
        executor.run_ops(jobs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::Manifest;

    fn artifacts_available() -> Option<Manifest> {
        if cfg!(not(feature = "xla")) {
            return None; // PJRT engine is a stub; ExecutorHandle::spawn would fail
        }
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn batches_and_scatters() {
        let Some(manifest) = artifacts_available() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let Some(meta) = manifest.find_fh(128, 512) else {
            eprintln!("skipping: no fh d'=128 artifact");
            return;
        };
        let sub = Manifest {
            artifacts: vec![meta.clone()],
        };
        let exec = Arc::new(ExecutorHandle::spawn(sub).expect("executor"));
        let metrics = Arc::new(Metrics::new());
        let b = FhBatcher::spawn(exec, &meta.name, meta.kind, 500, 64, Arc::clone(&metrics))
            .expect("batcher");
        // Submit several rows concurrently; each puts value v into bin r.
        let mut rxs = Vec::new();
        for r in 0..5 {
            let rx = b
                .submit(vec![r as i32], vec![(r + 1) as f32])
                .expect("submit");
            rxs.push((r, rx));
        }
        for (r, rx) in rxs {
            let (row, sq) = rx.recv().unwrap().unwrap();
            assert_eq!(row.len(), 128);
            assert_eq!(row[r], (r + 1) as f32, "row {r}");
            let expect_sq = ((r + 1) * (r + 1)) as f64;
            assert!((sq - expect_sq).abs() < 1e-4);
        }
        assert!(metrics.pjrt_batches.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    }

    #[test]
    fn oversized_row_rejected() {
        let Some(manifest) = artifacts_available() else {
            return;
        };
        let Some(meta) = manifest.find_fh(128, 512) else {
            return;
        };
        let sub = Manifest {
            artifacts: vec![meta.clone()],
        };
        let exec = Arc::new(ExecutorHandle::spawn(sub).expect("executor"));
        let b = FhBatcher::spawn(
            exec,
            &meta.name,
            meta.kind,
            100,
            4,
            Arc::new(Metrics::new()),
        )
        .unwrap();
        let big = vec![0i32; 100_000];
        let vals = vec![0f32; 100_000];
        assert!(b.submit(big, vals).is_none());
        // Mismatched lengths rejected too.
        assert!(b.submit(vec![1, 2], vec![0.5]).is_none());
    }
}
