//! Shadow routing: mirror live traffic to a candidate backend/scheme and
//! diff the answers — the paper's hash-family comparison as a service.
//!
//! The mirror never blocks the primary response: the router hands the
//! already-answered op to a bounded queue and a dedicated mirror thread
//! replays it against the shadow backend, comparing responses and
//! accumulating latency deltas in [`ShadowCounters`]. A full queue sheds
//! (counted — divergence numbers are only meaningful while `shed == 0`,
//! because a shed *write* leaves the shadow's corpus behind). A
//! *disconnected* queue means the mirror thread itself died; that is a
//! separate `mirror_dead` counter plus a one-time warning, because "the
//! mirror is gone" and "the mirror is briefly behind" call for different
//! operator responses.
//!
//! **Writes always mirror; reads are sampled.** `shadow_fraction` only
//! samples read ops: if writes were sampled too, the shadow would hold a
//! different corpus and every comparison would diverge for reasons that
//! have nothing to do with the scheme under test. Mirroring all writes
//! keeps the corpora identical, so a divergence is exactly what the
//! experiment is after: the two schemes answering differently on the
//! same data. The FIFO queue preserves the router's submission order,
//! so a mirrored read replays after the writes it followed.
//!
//! Sampling is a deterministic accumulator (mirror read *n* when the
//! mirrored count falls behind `fraction × seen`), not a coin flip —
//! tests can predict exactly which ops mirror.

use super::client::BackendPool;
use super::metrics::ShadowCounters;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Request, Response};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One mirrored op: the (scheme-rewritten) request plus the primary's
/// answer and latency for the diff.
struct MirrorJob {
    req: Request,
    primary: Response,
    primary_us: u64,
}

/// Deterministic read-sampling accumulator.
#[derive(Debug, Default)]
struct Sampler {
    seen: u64,
    mirrored: u64,
}

impl Sampler {
    /// Admit read #`seen+1` iff the mirrored count has fallen behind the
    /// target rate. Fraction 0.5 mirrors reads 2, 4, 6, …; fraction 1.0
    /// mirrors every read; fraction 0.0 none.
    fn admit(&mut self, fraction: f64) -> bool {
        self.seen += 1;
        let target = (self.seen as f64 * fraction).floor() as u64;
        if self.mirrored < target {
            self.mirrored += 1;
            true
        } else {
            false
        }
    }
}

/// The shadow mirror: bounded queue + one replay thread.
pub struct ShadowRouter {
    tx: Option<SyncSender<MirrorJob>>,
    handle: Option<JoinHandle<()>>,
    fraction: f64,
    scheme: Option<String>,
    sampler: Mutex<Sampler>,
    counters: Arc<ShadowCounters>,
    /// Set once when the mirror thread is first observed gone, so the
    /// transition logs exactly one line instead of one per dropped op.
    dead_logged: AtomicBool,
}

impl ShadowRouter {
    /// Spawn the mirror thread against `addr`. `scheme` rewrites the
    /// scheme on every mirrored op (A/B across schemes); `None` keeps
    /// the op's own scheme (A/B across backends).
    pub fn spawn(
        addr: &str,
        fraction: f64,
        scheme: Option<String>,
        queue_cap: usize,
        read_timeout: Option<Duration>,
        counters: Arc<ShadowCounters>,
    ) -> ShadowRouter {
        let (tx, rx) = sync_channel(queue_cap.max(1));
        let pool = BackendPool::new(addr, read_timeout);
        let thread_counters = Arc::clone(&counters);
        let handle = std::thread::Builder::new()
            .name("mixtab-shadow".into())
            .spawn(move || mirror_loop(rx, pool, thread_counters))
            .expect("spawn shadow mirror thread");
        ShadowRouter {
            tx: Some(tx),
            handle: Some(handle),
            fraction,
            scheme,
            sampler: Mutex::new(Sampler::default()),
            counters,
            dead_logged: AtomicBool::new(false),
        }
    }

    /// Mirror a write op (always, unsampled — see module docs).
    pub fn mirror_write(&self, req: Request, primary: &Response, primary_us: u64) {
        self.submit(req, primary, primary_us);
    }

    /// Mirror a read op at the configured fraction.
    pub fn mirror_read(&self, req: Request, primary: &Response, primary_us: u64) {
        let admitted = crate::util::sync::lock_unpoisoned(&self.sampler).admit(self.fraction);
        if admitted {
            self.submit(req, primary, primary_us);
        }
    }

    fn submit(&self, req: Request, primary: &Response, primary_us: u64) {
        let job = MirrorJob {
            req: rewrite_scheme(req, self.scheme.as_deref()),
            primary: primary.clone(),
            primary_us,
        };
        match self.tx.as_ref().expect("mirror running").try_send(job) {
            Ok(()) => Metrics::inc(&self.counters.mirrored),
            // A full queue is transient backpressure; a disconnected
            // channel means the mirror thread died (panic) and nothing
            // will mirror again. Conflating the two under `shed` hid
            // dead mirrors behind a counter operators read as "briefly
            // overloaded" — count them apart and log the transition once.
            Err(TrySendError::Full(_)) => Metrics::inc(&self.counters.shed),
            Err(TrySendError::Disconnected(_)) => {
                Metrics::inc(&self.counters.mirror_dead);
                if !self.dead_logged.swap(true, Ordering::Relaxed) {
                    crate::util::logging::warn!(
                        "shadow mirror thread is gone; dropping all mirrored ops from here on"
                    );
                }
            }
        }
    }
}

impl Drop for ShadowRouter {
    /// Disconnect the queue and join the mirror thread — accepted jobs
    /// still replay (the loop drains the channel before exiting), so a
    /// shutdown right after a burst loses nothing it admitted.
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Replay loop: runs until every sender is gone and the queue is drained.
fn mirror_loop(rx: Receiver<MirrorJob>, pool: BackendPool, counters: Arc<ShadowCounters>) {
    while let Ok(job) = rx.recv() {
        let t = Instant::now();
        match pool.call(&job.req) {
            Ok(shadow) => {
                let shadow_us = t.elapsed().as_micros() as u64;
                Metrics::inc(&counters.compared);
                Metrics::add(&counters.primary_lat_us, job.primary_us);
                Metrics::add(&counters.shadow_lat_us, shadow_us);
                if shadow != job.primary {
                    Metrics::inc(&counters.divergence);
                }
            }
            Err(_) => {
                // Transport failure to the shadow: not a divergence (the
                // schemes never got to disagree), just a mirror error.
                Metrics::inc(&counters.errors);
            }
        }
    }
}

/// Rewrite the scheme selector on ops that carry one; other ops pass
/// through untouched.
fn rewrite_scheme(req: Request, scheme: Option<&str>) -> Request {
    let Some(name) = scheme else {
        return req;
    };
    let s = Some(name.to_string());
    match req {
        Request::Sketch { set, spec, .. } => Request::Sketch {
            set,
            spec,
            scheme: s,
        },
        Request::LshInsert { id, set, .. } => Request::LshInsert { id, set, scheme: s },
        Request::LshDelete { id, .. } => Request::LshDelete { id, scheme: s },
        Request::LshUpdate { id, set, .. } => Request::LshUpdate { id, set, scheme: s },
        Request::LshQuery { set, .. } => Request::LshQuery { set, scheme: s },
        Request::LshQueryTopK { set, k, .. } => Request::LshQueryTopK { set, k, scheme: s },
        Request::Compact { .. } => Request::Compact { scheme: s },
        Request::Estimate { a, b, .. } => Request::Estimate { a, b, scheme: s },
        Request::IndexDoc { id, text, .. } => Request::IndexDoc { id, text, scheme: s },
        Request::QueryDoc { text, .. } => Request::QueryDoc { text, scheme: s },
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_is_deterministic() {
        let mut s = Sampler::default();
        let pattern: Vec<bool> = (0..8).map(|_| s.admit(0.5)).collect();
        assert_eq!(
            pattern,
            vec![false, true, false, true, false, true, false, true],
            "fraction 0.5 mirrors every second read"
        );
        let mut all = Sampler::default();
        assert!((0..10).all(|_| all.admit(1.0)), "fraction 1.0 mirrors all");
        let mut none = Sampler::default();
        assert!((0..10).all(|_| !none.admit(0.0)), "fraction 0.0 mirrors none");
        // A quarter: 1 in 4, deterministic positions.
        let mut q = Sampler::default();
        let n = (0..100).filter(|_| q.admit(0.25)).count();
        assert_eq!(n, 25);
    }

    #[test]
    fn dead_mirror_counts_apart_from_backpressure_shed() {
        // Receiver dropped = the mirror thread is gone. Every submit
        // lands in `mirror_dead`, never `shed`, and the transition flag
        // latches after the first drop.
        let (tx, rx) = sync_channel(4);
        drop(rx);
        let counters = Arc::new(ShadowCounters::default());
        let dead = ShadowRouter {
            tx: Some(tx),
            handle: None,
            fraction: 1.0,
            scheme: None,
            sampler: Mutex::new(Sampler::default()),
            counters: Arc::clone(&counters),
            dead_logged: AtomicBool::new(false),
        };
        let resp = Response::Error {
            message: "x".into(),
        };
        dead.mirror_write(Request::Stats, &resp, 1);
        dead.mirror_write(Request::Stats, &resp, 1);
        assert_eq!(counters.mirror_dead.load(Ordering::Relaxed), 2);
        assert_eq!(counters.shed.load(Ordering::Relaxed), 0);
        assert!(dead.dead_logged.load(Ordering::Relaxed));

        // Receiver alive but queue full = backpressure. Only `shed`
        // moves and the dead flag stays clear.
        let (tx, _rx) = sync_channel(1);
        let counters = Arc::new(ShadowCounters::default());
        let full = ShadowRouter {
            tx: Some(tx),
            handle: None,
            fraction: 1.0,
            scheme: None,
            sampler: Mutex::new(Sampler::default()),
            counters: Arc::clone(&counters),
            dead_logged: AtomicBool::new(false),
        };
        full.mirror_write(Request::Stats, &resp, 1);
        full.mirror_write(Request::Stats, &resp, 1);
        assert_eq!(counters.mirrored.load(Ordering::Relaxed), 1);
        assert_eq!(counters.shed.load(Ordering::Relaxed), 1);
        assert_eq!(counters.mirror_dead.load(Ordering::Relaxed), 0);
        assert!(!full.dead_logged.load(Ordering::Relaxed));
    }

    #[test]
    fn rewrite_scheme_touches_only_scheme_ops() {
        let q = Request::LshQuery {
            set: vec![1, 2],
            scheme: None,
        };
        assert_eq!(
            rewrite_scheme(q.clone(), Some("cand")),
            Request::LshQuery {
                set: vec![1, 2],
                scheme: Some("cand".into()),
            }
        );
        assert_eq!(rewrite_scheme(q.clone(), None), q);
        assert_eq!(rewrite_scheme(Request::Stats, Some("cand")), Request::Stats);
    }
}
