//! The cluster router: a [`Handler`] that owns no indexes and serves the
//! wire protocol by routing every op to remote backends.
//!
//! **Routing discipline.** Inserts route by hashing the id with the
//! default scheme's spec hash family, seeded `lsh seed ^`
//! [`CLUSTER_ROUTE_SALT`] — exactly the `ShardedIndex` discipline one
//! level up, with a distinct salt so cross-host placement is independent
//! of intra-host shard placement (the same id must not systematically
//! land in the same-numbered shard of every backend). The hash picks a
//! slot on a weight-expanded ring; walking the ring collects `replicas`
//! distinct backends serving the op's scheme.
//!
//! **Merge discipline.** Queries fan out to every routable backend
//! serving the scheme and merge candidates with concat → sort → dedup —
//! the `ShardedIndex::merge` invariant, which makes the merged result a
//! pure set union: independent of backend count, visit order, and how
//! ids were replicated. This is what makes router fan-out over N
//! backends result-identical to one `ShardedIndex` holding the same
//! corpus (the cluster e2e proves it).
//!
//! **Health.** Every send is gated by the backend's
//! [`BackendHealth`](super::health::BackendHealth) machine; transport
//! failures feed it, application-level `Error` responses do not (an
//! answering backend is alive). Shedding happens in the worker handling
//! the op — the event loop never blocks on a dead backend.

use super::client::{self, BackendPool};
use super::config::ClusterConfig;
use super::health::BackendHealth;
use super::metrics::ClusterMetrics;
use super::shadow::ShadowRouter;
use crate::coordinator::config::{CoordinatorConfig, DEFAULT_SCHEME};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::{self, Handler, PipelinedClient};
use crate::hash::Hasher32;
use crate::lsh::TopK;
use crate::util::error::Result;
use std::collections::HashMap;
use crate::util::sync::lock_unpoisoned;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Seed salt for cross-host routing. Distinct from `SHARD_ROUTE_SALT` so
/// backend choice and (inside each backend) shard choice are independent
/// hash streams of the same family.
pub const CLUSTER_ROUTE_SALT: u64 = 0xC105_7EED;

/// One configured backend: pool + health machine + counters.
struct Backend {
    cfg: super::config::BackendConfig,
    pool: BackendPool,
    health: Mutex<BackendHealth>,
    counters: Arc<super::metrics::BackendCounters>,
}

/// The router-mode request handler.
pub struct ClusterRouter {
    backends: Vec<Backend>,
    /// Weight-expanded ring: backend index repeated `weight` times,
    /// config order. Weight-0 (shadow-only) backends never appear.
    slots: Vec<usize>,
    replicas: usize,
    route: Box<dyn Hasher32>,
    /// Round-robin cursor for ops without an id to hash.
    rr: AtomicUsize,
    metrics: ClusterMetrics,
    shadow: Option<ShadowRouter>,
}

impl ClusterRouter {
    /// Build from a parsed topology. `coord` supplies the routing spec
    /// (hash family + seed — the same values every backend derives its
    /// own sharding from, so one config file can serve both roles).
    pub fn new(cluster: ClusterConfig, coord: &CoordinatorConfig) -> Result<ClusterRouter> {
        let lsh = coord.lsh_spec();
        let route = lsh.family.build(lsh.seed ^ CLUSTER_ROUTE_SALT);
        let names: Vec<String> = cluster.backends.iter().map(|b| b.name.clone()).collect();
        let metrics = ClusterMetrics::new(&names);
        let mut slots = Vec::new();
        for (i, b) in cluster.backends.iter().enumerate() {
            for _ in 0..b.weight {
                slots.push(i);
            }
        }
        crate::ensure!(!slots.is_empty(), "router needs a routable backend");
        let shadow = match &cluster.shadow_backend {
            Some(name) => {
                let target = cluster
                    .backends
                    .iter()
                    .find(|b| &b.name == name)
                    .expect("validated by ClusterConfig");
                Some(ShadowRouter::spawn(
                    &target.addr,
                    cluster.shadow_fraction,
                    cluster.shadow_scheme.clone(),
                    cluster.shadow_queue,
                    cluster.read_timeout(),
                    Arc::clone(&metrics.shadow),
                ))
            }
            None => None,
        };
        let backends = cluster
            .backends
            .iter()
            .enumerate()
            .map(|(i, b)| Backend {
                cfg: b.clone(),
                pool: BackendPool::new(&b.addr, cluster.read_timeout()),
                health: Mutex::new(BackendHealth::new(cluster.error_limit, cluster.cooloff())),
                counters: Arc::clone(&metrics.backends[i]),
            })
            .collect();
        Ok(ClusterRouter {
            backends,
            slots,
            replicas: cluster.replicas,
            route,
            rr: AtomicUsize::new(0),
            metrics,
            shadow,
        })
    }

    /// The `replicas` distinct routable backends for `id` under `scheme`,
    /// primary first: hash the id onto the weight ring, then walk it
    /// collecting distinct backends that serve the scheme. Deterministic
    /// in `(spec, topology, id)` — a second router over the same config
    /// routes identically, which is what makes replicas findable.
    fn replicas_for(&self, scheme: &str, id: u32) -> Vec<usize> {
        let start = self.route.hash(id) as usize % self.slots.len();
        let mut out = Vec::new();
        for off in 0..self.slots.len() {
            let b = self.slots[(start + off) % self.slots.len()];
            if !out.contains(&b) && self.backends[b].cfg.serves(scheme) {
                out.push(b);
                if out.len() == self.replicas {
                    break;
                }
            }
        }
        out
    }

    /// Every routable backend serving `scheme`, config order (the query
    /// fan-out set).
    fn eligible(&self, scheme: &str) -> Vec<usize> {
        self.backends
            .iter()
            .enumerate()
            .filter(|(_, b)| b.cfg.weight > 0 && b.cfg.serves(scheme))
            .map(|(i, _)| i)
            .collect()
    }

    fn note_success(&self, b: usize) {
        lock_unpoisoned(&self.backends[b].health).on_success(Instant::now());
    }

    fn note_transport_error(&self, b: usize, err: &crate::util::error::Error) {
        let backend = &self.backends[b];
        Metrics::inc(&backend.counters.errors);
        if server::is_timeout(err) {
            Metrics::inc(&backend.counters.timeouts);
        }
        lock_unpoisoned(&backend.health).on_error(Instant::now());
    }

    /// Fan one request out to `targets`: health-gate, send to every
    /// admitted backend, then collect responses (send-all-then-recv — the
    /// fan-out costs one round trip, not one per backend). Returns one
    /// entry per *admitted* backend; shed backends only bump their `shed`
    /// counter.
    fn fanout_call(&self, targets: &[usize], req: &Request) -> Vec<(usize, Result<Response>)> {
        let now = Instant::now();
        let mut inflight: Vec<(usize, PipelinedClient, u64)> = Vec::new();
        let mut results: Vec<(usize, Result<Response>)> = Vec::new();
        for &b in targets {
            let backend = &self.backends[b];
            if !lock_unpoisoned(&backend.health).admit_at(now) {
                Metrics::inc(&backend.counters.shed);
                continue;
            }
            Metrics::inc(&backend.counters.requests);
            let sent = backend.pool.checkout().and_then(|mut conn| {
                let rid = client::send_tagged(&mut conn, req)?;
                Ok((conn, rid))
            });
            match sent {
                Ok((conn, rid)) => inflight.push((b, conn, rid)),
                Err(e) => {
                    self.note_transport_error(b, &e);
                    results.push((b, Err(e)));
                }
            }
        }
        for (b, mut conn, rid) in inflight {
            match client::recv_tagged(&mut conn, rid) {
                Ok(resp) => {
                    self.backends[b].pool.checkin(conn);
                    self.note_success(b);
                    results.push((b, Ok(resp)));
                }
                Err(e) => {
                    self.note_transport_error(b, &e);
                    results.push((b, Err(e)));
                }
            }
        }
        results
    }

    /// Route a single-target op (sketch and friends): try targets
    /// round-robin, skipping shedding backends and — these ops are pure —
    /// retrying past transport *and* application errors; the first clean
    /// answer wins.
    fn route_one(&self, targets: &[usize], req: &Request) -> Response {
        if targets.is_empty() {
            return self.error_resp(format!(
                "no backend serves scheme '{}'",
                op_scheme(req)
            ));
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % targets.len();
        let now = Instant::now();
        let mut fallback: Option<Response> = None;
        for off in 0..targets.len() {
            let b = targets[(start + off) % targets.len()];
            let backend = &self.backends[b];
            if !lock_unpoisoned(&backend.health).admit_at(now) {
                Metrics::inc(&backend.counters.shed);
                continue;
            }
            Metrics::inc(&backend.counters.requests);
            match backend.pool.call(req) {
                Ok(Response::Error { message }) => {
                    self.note_success(b);
                    fallback.get_or_insert(Response::Error { message });
                }
                Ok(resp) => {
                    self.note_success(b);
                    return resp;
                }
                Err(e) => self.note_transport_error(b, &e),
            }
        }
        match fallback {
            Some(resp) => {
                Metrics::inc(&self.metrics.errors);
                resp
            }
            None => self.error_resp(format!(
                "no healthy backend for scheme '{}'",
                op_scheme(req)
            )),
        }
    }

    /// Replicated write: succeed iff any replica acked. A replica in
    /// cooloff just misses this insert — queries still find the id on the
    /// surviving replicas, which is the point of replication.
    fn route_write(&self, id: u32, req: &Request) -> Response {
        let scheme = op_scheme(req);
        let targets = self.replicas_for(scheme, id);
        if targets.is_empty() {
            return self.error_resp(format!("no backend serves scheme '{scheme}'"));
        }
        let mut acked: Option<Response> = None;
        let mut app_error: Option<Response> = None;
        let mut transport = 0usize;
        for (_, result) in self.fanout_call(&targets, req) {
            match result {
                Ok(Response::Error { message }) => {
                    app_error.get_or_insert(Response::Error { message });
                }
                Ok(resp) => {
                    acked.get_or_insert(resp);
                }
                Err(_) => transport += 1,
            }
        }
        if let Some(resp) = acked {
            return resp;
        }
        if let Some(resp) = app_error {
            Metrics::inc(&self.metrics.errors);
            return resp;
        }
        self.error_resp(format!(
            "write failed on all {} replica(s) ({transport} transport error(s), rest shedding)",
            targets.len()
        ))
    }

    /// Fanned-out read: merge candidate unions over every backend that
    /// answered; any one healthy backend keeps queries succeeding.
    fn route_read(&self, req: &Request) -> Response {
        let scheme = op_scheme(req);
        let targets = self.eligible(scheme);
        if targets.is_empty() {
            return self.error_resp(format!("no backend serves scheme '{scheme}'"));
        }
        let mut ids_all: Vec<u32> = Vec::new();
        let mut answered = 0usize;
        let mut app_error: Option<Response> = None;
        for (_, result) in self.fanout_call(&targets, req) {
            match result {
                Ok(Response::Candidates { ids }) => {
                    answered += 1;
                    ids_all.extend(ids);
                }
                Ok(Response::Error { message }) => {
                    app_error.get_or_insert(Response::Error { message });
                }
                // A non-candidates success (protocol drift) — treat like
                // an app error rather than fold garbage into the merge.
                Ok(_) => {
                    app_error.get_or_insert(self.plain_error(
                        "backend answered a query with a non-candidates response",
                    ));
                }
                Err(_) => {}
            }
        }
        if answered > 0 {
            // The shard-merge invariant, across hosts: sorted-dedup union
            // is independent of backend count and replication layout.
            ids_all.sort_unstable();
            ids_all.dedup();
            return Response::Candidates { ids: ids_all };
        }
        match app_error {
            Some(resp) => {
                Metrics::inc(&self.metrics.errors);
                resp
            }
            None => self.error_resp(format!("query failed on all backends for scheme '{scheme}'")),
        }
    }

    /// Fanned-out top-k: every backend re-ranks its own corpus slice,
    /// the router merges the per-backend rankings. Replication means the
    /// same id can arrive from several backends — dedup by id (keeping
    /// the best score; replicas of one corpus score identically) before
    /// the final bounded selection, so the merged ranking is independent
    /// of backend count and replication layout, like the candidate
    /// union.
    fn route_topk(&self, k: usize, req: &Request) -> Response {
        let scheme = op_scheme(req);
        let targets = self.eligible(scheme);
        if targets.is_empty() {
            return self.error_resp(format!("no backend serves scheme '{scheme}'"));
        }
        let mut best: HashMap<u32, f64> = HashMap::new();
        let mut answered = 0usize;
        let mut app_error: Option<Response> = None;
        for (_, result) in self.fanout_call(&targets, req) {
            match result {
                Ok(Response::TopK { ids, scores }) => {
                    answered += 1;
                    for (id, score) in ids.into_iter().zip(scores) {
                        let slot = best.entry(id).or_insert(f64::NEG_INFINITY);
                        if score > *slot {
                            *slot = score;
                        }
                    }
                }
                Ok(Response::Error { message }) => {
                    app_error.get_or_insert(Response::Error { message });
                }
                Ok(_) => {
                    app_error.get_or_insert(self.plain_error(
                        "backend answered a top-k query with a non-topk response",
                    ));
                }
                Err(_) => {}
            }
        }
        if answered > 0 {
            // TopK's total order (score, then id) makes the selection a
            // pure function of the deduped multiset — hash-map iteration
            // order cannot leak into the answer.
            let mut top = TopK::new(k);
            for (id, score) in best {
                top.offer(id, score);
            }
            let ranked = top.into_sorted();
            if ranked.len() < k {
                // The merged, deduped cluster-wide candidate set fell
                // short of the requested k — same signal as the
                // single-host `topk_short`, observed after the merge.
                Metrics::inc(&self.metrics.topk_short);
            }
            return Response::TopK {
                ids: ranked.iter().map(|s| s.id).collect(),
                scores: ranked.iter().map(|s| s.score).collect(),
            };
        }
        match app_error {
            Some(resp) => {
                Metrics::inc(&self.metrics.errors);
                resp
            }
            None => self.error_resp(format!(
                "top-k query failed on all backends for scheme '{scheme}'"
            )),
        }
    }

    /// Fanned-out compaction: every eligible backend compacts its slice;
    /// the response sums purged postings cluster-wide. Partial success
    /// (some backends shedding) still reports the purges that happened —
    /// a missed backend just compacts on its own threshold later.
    fn route_compact(&self, req: &Request) -> Response {
        let scheme = op_scheme(req);
        let targets = self.eligible(scheme);
        if targets.is_empty() {
            return self.error_resp(format!("no backend serves scheme '{scheme}'"));
        }
        let mut purged = 0usize;
        let mut answered = 0usize;
        let mut app_error: Option<Response> = None;
        for (_, result) in self.fanout_call(&targets, req) {
            match result {
                Ok(Response::Compacted { purged: p }) => {
                    answered += 1;
                    purged += p;
                }
                Ok(Response::Error { message }) => {
                    app_error.get_or_insert(Response::Error { message });
                }
                Ok(_) => {
                    app_error.get_or_insert(self.plain_error(
                        "backend answered a compact with a non-compacted response",
                    ));
                }
                Err(_) => {}
            }
        }
        if answered > 0 {
            return Response::Compacted { purged };
        }
        match app_error {
            Some(resp) => {
                Metrics::inc(&self.metrics.errors);
                resp
            }
            None => self.error_resp(format!(
                "compact failed on all backends for scheme '{scheme}'"
            )),
        }
    }

    fn error_resp(&self, message: String) -> Response {
        Metrics::inc(&self.metrics.errors);
        Response::Error { message }
    }

    /// An error response *without* bumping the error counter (used where
    /// the caller decides whether it becomes the final answer).
    fn plain_error(&self, message: &str) -> Response {
        Response::Error {
            message: message.to_string(),
        }
    }

    /// The router's `stats` payload: cluster counters + per-backend
    /// health read under the health locks.
    pub fn stats_json(&self) -> crate::util::json::Json {
        let health: Vec<(&'static str, u64, u64)> = self
            .backends
            .iter()
            .map(|b| {
                let h = lock_unpoisoned(&b.health);
                (h.state().label(), h.epoch(), h.cooloff_trips())
            })
            .collect();
        self.metrics.snapshot(&health)
    }

    /// Test/introspection handle: the metrics block.
    pub fn metrics(&self) -> &ClusterMetrics {
        &self.metrics
    }

    /// Test/introspection handle: replica routing (exposed so property
    /// tests can assert determinism and replica-count clamping).
    pub fn route_of(&self, scheme: &str, id: u32) -> Vec<usize> {
        self.replicas_for(scheme, id)
    }
}

impl Handler for ClusterRouter {
    fn handle(&self, req: Request) -> Response {
        let t = Instant::now();
        match req {
            Request::Stats => Response::Stats {
                json: self.stats_json(),
            },
            Request::SaveIndex { .. } | Request::LoadIndex { .. } => self.plain_error(
                "save_index/load_index are not routed — snapshot backends directly",
            ),
            req @ (Request::LshInsert { .. } | Request::IndexDoc { .. }) => {
                Metrics::inc(&self.metrics.inserts);
                let id = match &req {
                    Request::LshInsert { id, .. } | Request::IndexDoc { id, .. } => *id,
                    _ => unreachable!(),
                };
                let resp = self.route_write(id, &req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_write(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            // Mutations route like inserts: same replica set (the hash is
            // a function of the id), so a delete/update reaches exactly
            // the backends holding the id. A replica in cooloff misses
            // the mutation and serves the stale id until it catches up —
            // the same staleness window replicated inserts already have.
            req @ Request::LshDelete { .. } => {
                Metrics::inc(&self.metrics.deletes);
                let id = match &req {
                    Request::LshDelete { id, .. } => *id,
                    _ => unreachable!(),
                };
                let resp = self.route_write(id, &req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_write(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ Request::LshUpdate { .. } => {
                Metrics::inc(&self.metrics.updates);
                let id = match &req {
                    Request::LshUpdate { id, .. } => *id,
                    _ => unreachable!(),
                };
                let resp = self.route_write(id, &req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_write(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ Request::LshQueryTopK { .. } => {
                Metrics::inc(&self.metrics.topk_queries);
                let k = match &req {
                    Request::LshQueryTopK { k, .. } => *k,
                    _ => unreachable!(),
                };
                let resp = self.route_topk(k, &req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_read(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ Request::Compact { .. } => {
                Metrics::inc(&self.metrics.compactions);
                let resp = self.route_compact(&req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_write(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ (Request::LshQuery { .. } | Request::QueryDoc { .. }) => {
                Metrics::inc(&self.metrics.queries);
                let resp = self.route_read(&req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_read(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ Request::Estimate { .. } => {
                Metrics::inc(&self.metrics.estimates);
                let (a, scheme) = match &req {
                    Request::Estimate { a, scheme, .. } => {
                        (*a, scheme.as_deref().unwrap_or(DEFAULT_SCHEME).to_string())
                    }
                    _ => unreachable!(),
                };
                // Estimates read stored sketches, so only `a`'s replicas
                // can answer; `route_one` retries past "unknown id" app
                // errors in case a replica missed one of the two inserts.
                let targets = self.replicas_for(&scheme, a);
                let resp = self.route_one(&targets, &req);
                if let Some(shadow) = &self.shadow {
                    shadow.mirror_read(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
            req @ (Request::Sketch { .. } | Request::OphSketch { .. } | Request::FhTransform { .. }) => {
                Metrics::inc(&self.metrics.sketches);
                let targets = self.eligible(op_scheme(&req));
                let resp = self.route_one(&targets, &req);
                if let (Some(shadow), Request::Sketch { .. }) = (&self.shadow, &req) {
                    shadow.mirror_read(req, &resp, t.elapsed().as_micros() as u64);
                }
                resp
            }
        }
    }
}

/// The scheme an op addresses (absent = the default scheme, matching the
/// registry's resolution).
fn op_scheme(req: &Request) -> &str {
    match req {
        Request::Sketch { scheme, .. }
        | Request::LshInsert { scheme, .. }
        | Request::LshDelete { scheme, .. }
        | Request::LshUpdate { scheme, .. }
        | Request::LshQuery { scheme, .. }
        | Request::LshQueryTopK { scheme, .. }
        | Request::Compact { scheme, .. }
        | Request::Estimate { scheme, .. }
        | Request::IndexDoc { scheme, .. }
        | Request::QueryDoc { scheme, .. }
        | Request::SaveIndex { scheme, .. }
        | Request::LoadIndex { scheme, .. } => scheme.as_deref().unwrap_or(DEFAULT_SCHEME),
        Request::FhTransform { .. } | Request::OphSketch { .. } | Request::Stats => DEFAULT_SCHEME,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::config::Config;

    fn router(text: &str) -> ClusterRouter {
        let cluster = ClusterConfig::from_config(&Config::parse(text).unwrap()).unwrap();
        ClusterRouter::new(cluster, &CoordinatorConfig::default()).unwrap()
    }

    const THREE: &str = "[[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:1\"\n\n[[backends]]\nname = \"b1\"\naddr = \"127.0.0.1:2\"\n\n[[backends]]\nname = \"b2\"\naddr = \"127.0.0.1:3\"\n";

    #[test]
    fn routing_is_deterministic_and_replicated() {
        let r1 = router(THREE);
        let r2 = router(THREE);
        for id in 0..500u32 {
            let route = r1.route_of(DEFAULT_SCHEME, id);
            assert_eq!(route, r2.route_of(DEFAULT_SCHEME, id), "id {id}");
            assert_eq!(route.len(), 2, "replicas honoured for id {id}");
            assert_ne!(route[0], route[1], "replicas are distinct backends");
        }
        // All backends get primary traffic somewhere.
        let mut primaries = std::collections::HashSet::new();
        for id in 0..500u32 {
            primaries.insert(r1.route_of(DEFAULT_SCHEME, id)[0]);
        }
        assert_eq!(primaries.len(), 3);
    }

    #[test]
    fn replicas_clamp_to_eligible_backends() {
        // replicas = 5 over 3 backends: every id routes to all 3.
        let text = format!("[cluster]\nreplicas = 5\n\n{THREE}");
        let r = router(&text);
        for id in 0..50u32 {
            assert_eq!(r.route_of(DEFAULT_SCHEME, id).len(), 3);
        }
    }

    #[test]
    fn scheme_filter_and_weight_shape_routing() {
        let text = "[cluster]\nreplicas = 1\nshadow_backend = \"cand\"\n\n[[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:1\"\nweight = 3\n\n[[backends]]\nname = \"b1\"\naddr = \"127.0.0.1:2\"\nschemes = [\"fast\"]\n\n[[backends]]\nname = \"cand\"\naddr = \"127.0.0.1:3\"\nweight = 0\n";
        let r = router(text);
        // b1 only serves "fast"; default-scheme ids all land on b0.
        for id in 0..100u32 {
            assert_eq!(r.route_of(DEFAULT_SCHEME, id), vec![0], "id {id}");
        }
        // "fast" ops may land on either routable backend, never the
        // weight-0 shadow.
        let mut seen = std::collections::HashSet::new();
        for id in 0..200u32 {
            let route = r.route_of("fast", id);
            assert_eq!(route.len(), 1);
            assert_ne!(route[0], 2, "weight-0 backend took primary traffic");
            seen.insert(route[0]);
        }
        assert_eq!(seen.len(), 2, "weighted ring still reaches both");
        // No backend serves an unknown scheme once filters apply.
        assert!(r.route_of("nope", 7).is_empty());
        assert!(r.eligible("nope").is_empty());
    }

    #[test]
    fn salt_decorrelates_cluster_and_shard_routing() {
        // Same family+seed, different salts: the cluster route must not
        // be a function of the shard route. With 2 targets each, the
        // agreement rate of independent streams is ~1/2 — assert it is
        // nowhere near 1.
        let text = "[[backends]]\nname = \"b0\"\naddr = \"127.0.0.1:1\"\n\n[[backends]]\nname = \"b1\"\naddr = \"127.0.0.1:2\"\n";
        let cluster = ClusterConfig::from_config(&Config::parse(text).unwrap()).unwrap();
        let cluster = ClusterConfig {
            replicas: 1,
            ..cluster
        };
        let coord = CoordinatorConfig::default();
        let r = ClusterRouter::new(cluster, &coord).unwrap();
        let lsh = coord.lsh_spec();
        let shard_route = lsh
            .family
            .build(lsh.seed ^ crate::lsh::sharded::SHARD_ROUTE_SALT);
        let agree = (0..2000u32)
            .filter(|&id| {
                r.route_of(DEFAULT_SCHEME, id)[0] == (shard_route.hash(id) as usize % 2)
            })
            .count();
        assert!(
            (600..1400).contains(&agree),
            "cluster and shard routes look correlated: {agree}/2000 agree"
        );
    }
}
