//! Router-mode metrics: per-backend traffic/health counters plus the
//! shadow mirror's divergence and latency-delta tracking.
//!
//! Same discipline as [`crate::coordinator::metrics`]: the request path
//! bumps lock-free atomics through `Arc`ed blocks; locks exist only at
//! snapshot time. Health *state* (healthy/cooloff/half_open, epoch, trip
//! count) lives in the router's [`super::health::BackendHealth`] machines
//! and is folded into the snapshot by the router, which is the only
//! component holding both.

use crate::coordinator::metrics::Metrics;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Counters for one backend's primary (routed) traffic.
#[derive(Debug)]
pub struct BackendCounters {
    pub name: String,
    /// Ops sent to this backend (including failed attempts).
    pub requests: AtomicU64,
    /// Transport failures (connect/send/recv) — the signal feeding the
    /// health tracker. Application-level `Error` responses don't count.
    pub errors: AtomicU64,
    /// Subset of `errors` that were read-deadline expiries.
    pub timeouts: AtomicU64,
    /// Ops not sent because the backend was shedding (cooloff/half-open).
    pub shed: AtomicU64,
}

impl BackendCounters {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// JSON block, with the health fields the router reads off the
    /// backend's state machine at snapshot time.
    pub fn snapshot(&self, state: &str, epoch: u64, cooloff_trips: u64) -> Json {
        Json::obj()
            .set("requests", self.requests.load(Ordering::Relaxed) as usize)
            .set("errors", self.errors.load(Ordering::Relaxed) as usize)
            .set("timeouts", self.timeouts.load(Ordering::Relaxed) as usize)
            .set("shed", self.shed.load(Ordering::Relaxed) as usize)
            .set("state", state)
            .set("epoch", epoch as usize)
            .set("cooloff_trips", cooloff_trips as usize)
    }
}

/// Counters for the shadow mirror.
#[derive(Debug, Default)]
pub struct ShadowCounters {
    /// Ops handed to the mirror thread (writes + sampled reads).
    pub mirrored: AtomicU64,
    /// Ops dropped because the mirror queue was full. Divergence numbers
    /// are only trustworthy while this stays 0 — a shed write leaves the
    /// shadow's corpus behind the primary's.
    pub shed: AtomicU64,
    /// Ops dropped because the mirror thread is gone (channel
    /// disconnected). Distinct from `shed`: a full queue is transient
    /// backpressure, a dead mirror is permanent — once this moves, every
    /// later divergence/latency number predates the death.
    pub mirror_dead: AtomicU64,
    /// Mirrored ops whose responses were compared against the primary's.
    pub compared: AtomicU64,
    /// Comparisons whose shadow response differed from the primary's —
    /// the paper's hash-family comparison, observed on live traffic.
    pub divergence: AtomicU64,
    /// Transport failures talking to the shadow backend (excluded from
    /// comparison; the primary was never affected).
    pub errors: AtomicU64,
    /// Summed primary/shadow latency (µs) over compared ops; the
    /// snapshot exposes the mean delta.
    pub primary_lat_us: AtomicU64,
    pub shadow_lat_us: AtomicU64,
}

impl ShadowCounters {
    pub fn snapshot(&self) -> Json {
        let compared = self.compared.load(Ordering::Relaxed);
        let p = self.primary_lat_us.load(Ordering::Relaxed);
        let s = self.shadow_lat_us.load(Ordering::Relaxed);
        let (mean_p, mean_s) = if compared == 0 {
            (0.0, 0.0)
        } else {
            (p as f64 / compared as f64, s as f64 / compared as f64)
        };
        Json::obj()
            .set("mirrored", self.mirrored.load(Ordering::Relaxed) as usize)
            .set("shed", self.shed.load(Ordering::Relaxed) as usize)
            .set(
                "mirror_dead",
                self.mirror_dead.load(Ordering::Relaxed) as usize,
            )
            .set("compared", compared as usize)
            .set("divergence", self.divergence.load(Ordering::Relaxed) as usize)
            .set("errors", self.errors.load(Ordering::Relaxed) as usize)
            .set("primary_lat_us_mean", mean_p)
            .set("shadow_lat_us_mean", mean_s)
            .set("latency_delta_us_mean", mean_s - mean_p)
    }
}

/// All router-mode counters. The router serves these from its `stats`
/// op (a router owns no indexes, so the plain coordinator snapshot would
/// be empty noise); top-level `lsh_inserts`/`lsh_queries`/`errors` keys
/// mirror the single-host snapshot shape so the loadtest's external mode
/// reads either kind of server.
#[derive(Debug)]
pub struct ClusterMetrics {
    /// Routed op counts, summed across backends (one per client op, not
    /// per replica).
    pub inserts: AtomicU64,
    pub deletes: AtomicU64,
    pub updates: AtomicU64,
    pub queries: AtomicU64,
    pub topk_queries: AtomicU64,
    /// `query_topk` responses whose merged result carried fewer than the
    /// requested k ids (mirrors the single-host `topk_short`).
    pub topk_short: AtomicU64,
    pub compactions: AtomicU64,
    pub sketches: AtomicU64,
    pub estimates: AtomicU64,
    /// Client ops answered with an `Error` response.
    pub errors: AtomicU64,
    /// Per-backend blocks, config order.
    pub backends: Vec<Arc<BackendCounters>>,
    /// Shared with the shadow mirror thread.
    pub shadow: Arc<ShadowCounters>,
}

impl ClusterMetrics {
    pub fn new(backend_names: &[String]) -> Self {
        Self {
            inserts: AtomicU64::new(0),
            deletes: AtomicU64::new(0),
            updates: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            topk_queries: AtomicU64::new(0),
            topk_short: AtomicU64::new(0),
            compactions: AtomicU64::new(0),
            sketches: AtomicU64::new(0),
            estimates: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            backends: backend_names
                .iter()
                .map(|n| Arc::new(BackendCounters::new(n)))
                .collect(),
            shadow: Arc::new(ShadowCounters::default()),
        }
    }

    /// Assemble the `stats` JSON. `health` carries `(state_label, epoch,
    /// cooloff_trips)` per backend, config order — read by the router
    /// under its health locks.
    pub fn snapshot(&self, health: &[(&'static str, u64, u64)]) -> Json {
        debug_assert_eq!(health.len(), self.backends.len());
        let mut backends = Json::obj();
        for (block, (state, epoch, trips)) in self.backends.iter().zip(health) {
            backends = backends.set(&block.name, block.snapshot(state, *epoch, *trips));
        }
        Json::obj()
            .set("router", true)
            .set("lsh_inserts", self.inserts.load(Ordering::Relaxed) as usize)
            .set("lsh_deletes", self.deletes.load(Ordering::Relaxed) as usize)
            .set("lsh_updates", self.updates.load(Ordering::Relaxed) as usize)
            .set("lsh_queries", self.queries.load(Ordering::Relaxed) as usize)
            .set(
                "topk_queries",
                self.topk_queries.load(Ordering::Relaxed) as usize,
            )
            .set(
                "topk_short",
                self.topk_short.load(Ordering::Relaxed) as usize,
            )
            .set(
                "compactions",
                self.compactions.load(Ordering::Relaxed) as usize,
            )
            .set(
                "sketch_requests",
                self.sketches.load(Ordering::Relaxed) as usize,
            )
            .set("estimates", self.estimates.load(Ordering::Relaxed) as usize)
            .set("errors", self.errors.load(Ordering::Relaxed) as usize)
            .set("backends", backends)
            .set("shadow", self.shadow.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_shape() {
        let m = ClusterMetrics::new(&["b0".into(), "b1".into()]);
        Metrics::inc(&m.inserts);
        Metrics::add(&m.queries, 3);
        Metrics::add(&m.deletes, 2);
        Metrics::inc(&m.updates);
        Metrics::inc(&m.topk_queries);
        Metrics::inc(&m.topk_short);
        Metrics::inc(&m.compactions);
        Metrics::inc(&m.shadow.mirror_dead);
        Metrics::inc(&m.backends[0].requests);
        Metrics::inc(&m.backends[1].errors);
        Metrics::inc(&m.backends[1].timeouts);
        Metrics::add(&m.shadow.mirrored, 4);
        Metrics::add(&m.shadow.compared, 2);
        Metrics::inc(&m.shadow.divergence);
        Metrics::add(&m.shadow.primary_lat_us, 100);
        Metrics::add(&m.shadow.shadow_lat_us, 300);
        let s = m.snapshot(&[("healthy", 0, 0), ("cooloff", 2, 3)]);
        assert_eq!(s.get("router").unwrap().as_bool(), Some(true));
        assert_eq!(s.get("lsh_inserts").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("lsh_queries").unwrap().as_i64(), Some(3));
        assert_eq!(s.get("lsh_deletes").unwrap().as_i64(), Some(2));
        assert_eq!(s.get("lsh_updates").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("topk_queries").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("topk_short").unwrap().as_i64(), Some(1));
        assert_eq!(s.get("compactions").unwrap().as_i64(), Some(1));
        let b0 = s.get("backends").unwrap().get("b0").unwrap();
        assert_eq!(b0.get("requests").unwrap().as_i64(), Some(1));
        assert_eq!(b0.get("state").unwrap().as_str(), Some("healthy"));
        let b1 = s.get("backends").unwrap().get("b1").unwrap();
        assert_eq!(b1.get("errors").unwrap().as_i64(), Some(1));
        assert_eq!(b1.get("timeouts").unwrap().as_i64(), Some(1));
        assert_eq!(b1.get("state").unwrap().as_str(), Some("cooloff"));
        assert_eq!(b1.get("epoch").unwrap().as_i64(), Some(2));
        assert_eq!(b1.get("cooloff_trips").unwrap().as_i64(), Some(3));
        let sh = s.get("shadow").unwrap();
        assert_eq!(sh.get("mirrored").unwrap().as_i64(), Some(4));
        assert_eq!(sh.get("mirror_dead").unwrap().as_i64(), Some(1));
        assert_eq!(sh.get("divergence").unwrap().as_i64(), Some(1));
        assert_eq!(sh.get("latency_delta_us_mean").unwrap().as_f64(), Some(100.0));
    }
}
