//! Reconnecting connection pool for one backend.
//!
//! Router workers check a [`PipelinedClient`] out, run one-or-more
//! round trips, and check it back in on success. Any transport failure
//! drops the connection on the floor (a timed-out socket may hold a
//! partial response line — see `PipelinedClient::set_read_timeout`), so
//! the pool never recycles a connection in an unknown protocol state.
//! The next checkout reconnects; connect errors surface to the caller
//! and feed the health tracker like any other transport failure.

use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::PipelinedClient;
use crate::util::error::{Context, Result};
use crate::util::sync::lock_unpoisoned;
use std::net::ToSocketAddrs;
use std::sync::Mutex;
use std::time::Duration;

/// Idle connections kept per backend. Above this, checked-in connections
/// are simply closed — the pool bounds sockets, not concurrency (that is
/// the server's `request_workers`).
const MAX_IDLE: usize = 16;

/// A pool of pipelined connections to one backend address.
#[derive(Debug)]
pub struct BackendPool {
    addr: String,
    read_timeout: Option<Duration>,
    idle: Mutex<Vec<PipelinedClient>>,
}

impl BackendPool {
    pub fn new(addr: &str, read_timeout: Option<Duration>) -> Self {
        Self {
            addr: addr.to_string(),
            read_timeout,
            idle: Mutex::new(Vec::new()),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Take an idle connection or dial a new one (read deadline applied).
    pub fn checkout(&self) -> Result<PipelinedClient> {
        if let Some(conn) = lock_unpoisoned(&self.idle).pop() {
            return Ok(conn);
        }
        let sock = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolve backend '{}'", self.addr))?
            .next()
            .with_context(|| format!("backend '{}' resolved to no address", self.addr))?;
        PipelinedClient::connect_with_timeout(sock, self.read_timeout)
            .with_context(|| format!("connect backend '{}'", self.addr))
    }

    /// Return a connection that completed its round trips cleanly.
    pub fn checkin(&self, conn: PipelinedClient) {
        let mut idle = lock_unpoisoned(&self.idle);
        if idle.len() < MAX_IDLE {
            idle.push(conn);
        }
    }

    /// One blocking round trip: checkout → send → recv → checkin. Any
    /// `Err` is a transport failure (the connection is already dropped);
    /// an application-level problem comes back as `Ok(Response::Error)`.
    pub fn call(&self, req: &Request) -> Result<Response> {
        let mut conn = self.checkout()?;
        let resp = roundtrip(&mut conn, req)?;
        self.checkin(conn);
        Ok(resp)
    }
}

/// Send one tagged request and flush it; returns the rid to collect.
/// Split from [`recv_tagged`] so the router can send to every replica
/// first and only then block on responses — fan-out latency is one round
/// trip, not one per replica.
pub fn send_tagged(conn: &mut PipelinedClient, req: &Request) -> Result<u64> {
    let rid = conn.send(req)?;
    conn.flush()?;
    Ok(rid)
}

/// Wait for the (single in-flight) response to `rid`.
pub fn recv_tagged(conn: &mut PipelinedClient, rid: u64) -> Result<Response> {
    let (got, resp) = conn.recv()?;
    if got != Some(rid) {
        // One request in flight ⇒ the first response must answer it; a
        // mismatch means the stream is desynchronized. The caller drops
        // the connection by construction (we never hand it back).
        crate::bail!("backend answered rid {got:?} to request {rid} — stream desynchronized");
    }
    Ok(resp)
}

/// Send one tagged request and wait for its (single in-flight) response.
pub fn roundtrip(conn: &mut PipelinedClient, req: &Request) -> Result<Response> {
    let rid = send_tagged(conn, req)?;
    recv_tagged(conn, rid)
}
