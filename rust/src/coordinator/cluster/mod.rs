//! Cross-host distribution tier: router mode for the coordinator.
//!
//! A `[[backends]]` config section declares remote mixtab servers; the
//! router serves the same wire protocol as a plain coordinator but owns
//! no indexes — it routes every op to backends over the existing
//! pipelined protocol:
//!
//! - **Inserts** route deterministically by the same spec-hash-family +
//!   salt discipline as `ShardedIndex` ([`router::CLUSTER_ROUTE_SALT`]),
//!   replicated to `replicas` distinct backends.
//! - **Queries** fan out over every healthy backend serving the op's
//!   scheme and merge candidates with the sorted-dedup invariant —
//!   exactly the shard-merge contract, lifted across hosts.
//! - A per-backend **health tracker** ([`health`]) classifies transport
//!   failures: an error limit trips an epoch-tagged cooloff window, a
//!   half-open probe recovers, and routed traffic sheds around dead
//!   backends without stalling the event loop.
//! - **Shadow routing** ([`shadow`]) mirrors writes (always) and a
//!   configurable fraction of reads to a candidate backend, off the
//!   primary response path, recording result divergence and latency
//!   deltas — the paper's hash-family comparison run as a live service.

pub mod client;
pub mod config;
pub mod health;
pub mod metrics;
pub mod router;
pub mod shadow;

pub use config::{BackendConfig, ClusterConfig};
pub use health::{BackendHealth, HealthState};
pub use metrics::ClusterMetrics;
pub use router::ClusterRouter;
